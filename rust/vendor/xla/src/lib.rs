//! Vendored stand-in for the `xla` crate (xla_extension 0.5.1).
//!
//! The real bindings link against a downloaded PJRT C library, which
//! cannot be fetched in the offline build environment. This crate
//! mirrors exactly the API surface `jaxmg::runtime` consumes so the
//! whole workspace **compiles and tests from a clean checkout**:
//!
//! * [`PjRtClient::cpu`] succeeds and reports the `cpu` platform, so
//!   diagnostics (`jaxmg info`, `PjRtRuntime::platform`) work;
//! * anything that would actually *execute* an AOT artifact —
//!   [`HloModuleProto::from_text_file`], [`PjRtClient::compile`],
//!   [`PjRtLoadedExecutable::execute`] — fails at runtime with a
//!   pointed [`Error`] instead of a build error, which is what the
//!   artifact-gated integration tests assert.
//!
//! Swapping the real bindings back in is a one-line change in
//! `rust/Cargo.toml` (point the `xla` path/registry dependency at
//! xla_extension); no `jaxmg` source changes are required.

use std::fmt;
use std::path::Path;

/// Errors surfaced by the XLA boundary.
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    fn stub(op: &str) -> Self {
        Error {
            msg: format!(
                "{op}: the vendored xla interface crate has no PJRT runtime — \
                 link the real xla_extension bindings to execute AOT artifacts"
            ),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

/// Result alias matching the real crate.
pub type Result<T> = std::result::Result<T, Error>;

/// XLA element types the jaxmg artifacts use (real planes only —
/// complex values cross the boundary as split re/im planes).
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
pub enum ElementType {
    F32,
    F64,
}

/// Types with an XLA element-type tag (subset: the real crate covers
/// every primitive; jaxmg only moves `f32`/`f64` planes).
pub trait ArrayElement: Copy + 'static {
    const TY: ElementType;
}

/// Types that can cross the literal boundary natively.
pub trait NativeType: Copy + Default + Send + Sync + 'static {}

impl ArrayElement for f32 {
    const TY: ElementType = ElementType::F32;
}
impl ArrayElement for f64 {
    const TY: ElementType = ElementType::F64;
}
impl NativeType for f32 {}
impl NativeType for f64 {}

/// PJRT client handle (CPU platform only in the stand-in).
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    /// Create the CPU client. Always succeeds — creating a client does
    /// not require the native library in the stand-in.
    pub fn cpu() -> Result<Self> {
        Ok(PjRtClient { _priv: () })
    }

    /// Platform name, as the real CPU client reports it.
    pub fn platform_name(&self) -> String {
        "cpu".to_string()
    }

    /// Compile a computation. Unreachable in practice: producing an
    /// [`XlaComputation`] already requires parsing an artifact, which
    /// the stand-in refuses; kept for API parity.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::stub("PjRtClient::compile"))
    }
}

/// Parsed HLO module (never constructed by the stand-in).
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    /// Parse an HLO text artifact. The stand-in cannot parse HLO, so
    /// this fails with a pointed runtime error — the caller's
    /// missing-artifact check fires first when the file is absent.
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<Self> {
        Err(Error {
            msg: format!(
                "cannot parse HLO artifact {:?}: the vendored xla interface crate has no \
                 PJRT runtime — link the real xla_extension bindings to run the AOT path",
                path.as_ref()
            ),
        })
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    /// Wrap a parsed module.
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation { _priv: () }
    }
}

/// A host literal (dense typed buffer).
pub struct Literal {
    _priv: (),
}

impl Literal {
    /// Scalar literal.
    pub fn scalar<T: NativeType>(_v: T) -> Literal {
        Literal { _priv: () }
    }

    /// Shaped literal from raw bytes (one copy).
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        Ok(Literal { _priv: () })
    }

    /// Destructure a tuple literal.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::stub("Literal::to_tuple"))
    }

    /// Copy out as a typed vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::stub("Literal::to_vec"))
    }
}

/// A compiled executable (never constructed by the stand-in).
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    /// Execute on a set of input literals.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::stub("PjRtLoadedExecutable::execute"))
    }
}

/// A device buffer produced by execution.
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    /// Copy back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::stub("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_reports_platform() {
        let c = PjRtClient::cpu().unwrap();
        assert_eq!(c.platform_name(), "cpu");
    }

    #[test]
    fn artifact_parse_fails_with_pointed_message() {
        let err = HloModuleProto::from_text_file("artifacts/potf2_f64_64.hlo.txt").unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("xla_extension"), "unpointed: {msg}");
    }

    #[test]
    fn element_type_tags() {
        assert_eq!(<f32 as ArrayElement>::TY, ElementType::F32);
        assert_eq!(<f64 as ArrayElement>::TY, ElementType::F64);
    }
}
