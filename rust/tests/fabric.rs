//! Acceptance tests for the two-tier multi-node fabric.
//!
//! The fabric's core contract is that it is a *pricing* overlay, never
//! a numerics fork: every solver runs on a [`Fabric`] exactly as on a
//! flat [`SimNode`], hierarchical (ring-of-rings) collectives change
//! only when bytes move, and a one-island fabric is bitwise a flat
//! node — results, makespans, and per-device stream horizons alike.
//!
//! The grid-native potrf schedule on the fabric is additionally pinned
//! against `tests/golden/potrf_fabric_timelines.txt`. The committed
//! snapshot was generated offline by `tests/golden/gen_potrf_fabric.py`
//! (an exact integer-ns replication of the hierarchical dispatch); this
//! suite verifies the live scheduler against it, bootstrapping or
//! regenerating under `UPDATE_GOLDEN=1` as `golden_timeline.rs` does.

use jaxmg::costmodel::GpuCostModel;
use jaxmg::device::SimNode;
use jaxmg::fabric::Fabric;
use jaxmg::layout::{BlockCyclic1D, BlockCyclic2D};
use jaxmg::linalg::Matrix;
use jaxmg::scalar::{c32, c64, Scalar};
use jaxmg::solver::{
    potrf_dist, potri_dist, potrs_dist, syevd_dist, Ctx, DeviceTimeline, PipelineConfig,
    SolverBackend,
};
use jaxmg::tile::{DistMatrix, LayoutKind};
use std::fmt::Write as _;

/// Run the full Cholesky chain (factor → solve → inverse) on `node`
/// under `cfg`, optionally forcing flat (non-hierarchical) collective
/// dispatch, returning the gathered factor, solution, inverse, and the
/// simulated makespan.
fn chol_chain_on<S: Scalar>(
    node: &SimNode,
    lay: LayoutKind,
    a: &Matrix<S>,
    b: &Matrix<S>,
    cfg: PipelineConfig,
    flat: bool,
) -> (Matrix<S>, Matrix<S>, Matrix<S>, f64) {
    let model = GpuCostModel::h200();
    let backend = SolverBackend::<S>::Native;
    let mut dm = DistMatrix::scatter(node, a, lay).unwrap();
    node.reset_accounting();
    let mut ctx = Ctx::with_pipeline(node, &model, &backend, cfg);
    if flat {
        ctx = ctx.with_flat_collectives();
    }
    potrf_dist(&ctx, &mut dm).unwrap();
    let l = dm.gather().unwrap();
    let x = potrs_dist(&ctx, &dm, b).unwrap();
    potri_dist(&ctx, &mut dm).unwrap();
    let inv = dm.gather().unwrap();
    (l, x, inv, node.sim_time())
}

/// The whole Cholesky chain on a 2×8 fabric — 1D, island-aligned and
/// island-crossing grids, hierarchical and flat dispatch — is bitwise
/// the flat 16-device node's, ragged edge tiles included.
fn fabric_cholesky_matches_flat_node<S: Scalar>(seed: u64) {
    let (n, tile, nrhs) = (67usize, 4usize, 2usize); // ragged: 67 % 4 != 0
    let a = Matrix::<S>::spd_random(n, seed);
    let b = Matrix::<S>::random(n, nrhs, seed + 50);
    let lay_1d = LayoutKind::BlockCyclic(BlockCyclic1D::new(n, tile, 16).unwrap());
    let flat_node = SimNode::new_uniform(16, 1 << 26);
    let (l1, x1, i1, _) =
        chol_chain_on::<S>(&flat_node, lay_1d, &a, &b, PipelineConfig::barrier(), false);
    let fab = Fabric::h200(2);
    let grids: Vec<LayoutKind> = vec![
        lay_1d,
        LayoutKind::Grid(BlockCyclic2D::new(n, n, tile, tile, 4, 4).unwrap()),
        LayoutKind::Grid(BlockCyclic2D::new(n, n, tile, tile, 2, 8).unwrap()),
    ];
    for lay in grids {
        for flat in [false, true] {
            let (l2, x2, i2, _) =
                chol_chain_on::<S>(fab.node(), lay, &a, &b, PipelineConfig::barrier(), flat);
            let tag = if flat { "flat" } else { "hier" };
            assert_eq!(l1.as_slice(), l2.as_slice(), "{tag} factor diverges ({:?})", S::DTYPE);
            assert_eq!(x1.as_slice(), x2.as_slice(), "{tag} solution diverges ({:?})", S::DTYPE);
            assert_eq!(i1.as_slice(), i2.as_slice(), "{tag} inverse diverges ({:?})", S::DTYPE);
        }
    }
}

#[test]
fn fabric_cholesky_bitwise_f32() {
    fabric_cholesky_matches_flat_node::<f32>(0xFAB1);
}

#[test]
fn fabric_cholesky_bitwise_f64() {
    fabric_cholesky_matches_flat_node::<f64>(0xFAB2);
}

#[test]
fn fabric_cholesky_bitwise_c64() {
    fabric_cholesky_matches_flat_node::<c32>(0xFAB3);
}

#[test]
fn fabric_cholesky_bitwise_c128() {
    fabric_cholesky_matches_flat_node::<c64>(0xFAB4);
}

/// syevd on the fabric: eigenvalues and eigenvectors bitwise the flat
/// node's.
fn fabric_syevd_matches_flat_node<S: Scalar>(seed: u64) {
    let (n, tile) = (67usize, 4usize);
    let a = Matrix::<S>::spd_random(n, seed);
    let lay = LayoutKind::BlockCyclic(BlockCyclic1D::new(n, tile, 16).unwrap());
    let model = GpuCostModel::h200();
    let backend = SolverBackend::<S>::Native;
    let run = |node: &SimNode| -> (Vec<S::Real>, Matrix<S>) {
        let mut dm = DistMatrix::scatter(node, &a, lay).unwrap();
        node.reset_accounting();
        let ctx = Ctx::new(node, &model, &backend);
        let w = syevd_dist(&ctx, &mut dm).unwrap();
        (w, dm.gather().unwrap())
    };
    let flat_node = SimNode::new_uniform(16, 1 << 26);
    let fab = Fabric::h200(2);
    let (w1, v1) = run(&flat_node);
    let (w2, v2) = run(fab.node());
    assert_eq!(w1, w2, "fabric changed syevd eigenvalues ({:?})", S::DTYPE);
    assert_eq!(v1.as_slice(), v2.as_slice(), "fabric changed syevd eigenvectors ({:?})", S::DTYPE);
}

#[test]
fn fabric_syevd_bitwise_f64() {
    fabric_syevd_matches_flat_node::<f64>(0xFAB5);
}

#[test]
fn fabric_syevd_bitwise_c128() {
    fabric_syevd_matches_flat_node::<c64>(0xFAB6);
}

/// A one-island fabric IS a flat node: factor, makespan, and every
/// per-device stream horizon are bitwise `SimNode::new_uniform`'s, and
/// no fabric traffic is recorded.
#[test]
fn one_island_fabric_timelines_are_bitwise_flat() {
    let (ndev, tile, n) = (8usize, 8usize, 64usize);
    let a = Matrix::<f64>::spd_random(n, 0xFAB7);
    let lay = LayoutKind::BlockCyclic(BlockCyclic1D::new(n, tile, ndev).unwrap());
    let model = GpuCostModel::h200();
    let backend = SolverBackend::<f64>::Native;
    let run = |node: &SimNode| -> (Matrix<f64>, f64, Vec<DeviceTimeline>) {
        let mut dm = DistMatrix::scatter(node, &a, lay).unwrap();
        node.reset_accounting();
        let ctx = Ctx::with_pipeline(node, &model, &backend, PipelineConfig::lookahead(2));
        potrf_dist(&ctx, &mut dm).unwrap();
        let snap = ctx.timeline_snapshot().unwrap();
        (dm.gather().unwrap(), node.sim_time(), snap)
    };
    let flat_node = SimNode::new_uniform(ndev, 1 << 26);
    let fab = Fabric::new(1, ndev, 1 << 26);
    let (l1, t1, s1) = run(&flat_node);
    let (l2, t2, s2) = run(fab.node());
    assert_eq!(l1.as_slice(), l2.as_slice(), "1-island fabric changed the factor");
    assert_eq!(t1, t2, "1-island fabric changed the makespan");
    assert_eq!(s1.len(), s2.len());
    for (a, b) in s1.iter().zip(&s2) {
        assert_eq!(a.device, b.device);
        assert_eq!(a.compute_horizon, b.compute_horizon, "dev {} compute drifted", a.device);
        assert_eq!(a.panel_horizon, b.panel_horizon, "dev {} panel drifted", a.device);
        assert_eq!(a.copy_horizon, b.copy_horizon, "dev {} copy drifted", a.device);
        assert_eq!(a.busy, b.busy, "dev {} busy drifted", a.device);
    }
    let m = fab.node().metrics().snapshot();
    assert_eq!(m.fabric_bcasts, 0, "1-island fabric must never stage a hierarchical bcast");
    assert_eq!(m.fabric_inter_bytes, 0);
}

/// Hierarchical dispatch on a 2-island fabric records fabric traffic
/// (inter + intra bytes, staged broadcasts), flat dispatch records no
/// staged broadcasts, and the numerics agree bitwise either way. The
/// lookahead schedule stays a strict win over the barrier one on the
/// fabric, with identical factors.
#[test]
fn hierarchical_dispatch_counts_fabric_traffic_and_keeps_numerics() {
    let (n, tile) = (64usize, 4usize);
    let a = Matrix::<f64>::spd_random(n, 0xFAB8);
    let lay = LayoutKind::Grid(BlockCyclic2D::new(n, n, tile, tile, 4, 4).unwrap());
    let model = GpuCostModel::h200();
    let backend = SolverBackend::<f64>::Native;
    let run = |cfg: PipelineConfig, flat: bool| -> (Matrix<f64>, f64, u64, u64, u64) {
        let fab = Fabric::h200(2);
        let node = fab.node();
        let mut dm = DistMatrix::scatter(node, &a, lay).unwrap();
        node.reset_accounting();
        let mut ctx = Ctx::with_pipeline(node, &model, &backend, cfg);
        if flat {
            ctx = ctx.with_flat_collectives();
        }
        potrf_dist(&ctx, &mut dm).unwrap();
        let t = node.sim_time();
        let m = node.metrics().snapshot();
        (dm.gather().unwrap(), t, m.fabric_inter_bytes, m.fabric_intra_bytes, m.fabric_bcasts)
    };
    let (l_hier, t_look, inter, intra, bcasts) = run(PipelineConfig::lookahead(2), false);
    assert!(inter > 0, "island-crossing rings must cross the fabric");
    assert!(intra > 0, "hierarchical stages must fan out island-locally");
    assert!(bcasts > 0, "hierarchical broadcasts must be counted");
    let (l_flat, _, _, _, flat_bcasts) = run(PipelineConfig::lookahead(2), true);
    assert_eq!(flat_bcasts, 0, "flat dispatch must never stage a hierarchical bcast");
    assert_eq!(l_hier.as_slice(), l_flat.as_slice(), "collective dispatch changed numerics");
    let (l_barrier, t_barrier, _, _, _) = run(PipelineConfig::barrier(), false);
    assert_eq!(l_hier.as_slice(), l_barrier.as_slice(), "schedule changed fabric numerics");
    assert!(
        t_look < t_barrier,
        "fabric lookahead {t_look} !< barrier {t_barrier} (p=4 q=4 tile={tile} n={n})"
    );
}

// ---------------------------------------------------------------------------
// golden snapshot: the grid-native potrf schedule on the fabric
// ---------------------------------------------------------------------------

/// `(p, q, tile, n)` grid-native configurations on the 2×8 fabric —
/// `p·q = 16` always. The committed snapshot was generated offline by
/// `tests/golden/gen_potrf_fabric.py`.
const FABRIC_GRID: &[(usize, usize, usize, usize)] =
    &[(2, 8, 4, 64), (4, 4, 4, 64), (4, 4, 8, 128)];

fn run_potrf2d_fabric(
    p: usize,
    q: usize,
    tile: usize,
    n: usize,
    cfg: PipelineConfig,
) -> (Matrix<f64>, f64, Option<Vec<DeviceTimeline>>) {
    let fab = Fabric::h200(2);
    let node = fab.node();
    let model = GpuCostModel::h200();
    let backend = SolverBackend::<f64>::Native;
    let a = Matrix::<f64>::spd_random(n, 0xD15C0 + n as u64);
    let lay = LayoutKind::Grid(BlockCyclic2D::new(n, n, tile, tile, p, q).unwrap());
    let mut dm = DistMatrix::scatter(node, &a, lay).unwrap();
    node.reset_accounting();
    let ctx = Ctx::with_pipeline(node, &model, &backend, cfg);
    potrf_dist(&ctx, &mut dm).unwrap();
    let snap = ctx.timeline_snapshot();
    // As in `golden_timeline.rs`: the makespan is captured before the
    // verification gather, whose H2D charges are not part of the
    // factorization schedule the snapshot pins.
    let makespan = node.sim_time();
    (dm.gather().unwrap(), makespan, snap)
}

#[test]
fn fabric_lookahead_beats_barrier_on_every_config() {
    for &(p, q, tile, n) in FABRIC_GRID {
        let (l_barrier, t_barrier, _) = run_potrf2d_fabric(p, q, tile, n, PipelineConfig::barrier());
        let (l_look, t_look, _) = run_potrf2d_fabric(p, q, tile, n, PipelineConfig::lookahead(2));
        assert_eq!(
            l_barrier.as_slice(),
            l_look.as_slice(),
            "schedule changed fabric numerics (p={p} q={q} tile={tile} n={n})"
        );
        assert!(
            t_look < t_barrier,
            "fabric lookahead {t_look} !< barrier {t_barrier} (p={p} q={q} tile={tile} n={n})"
        );
    }
}

fn render_fabric_snapshot() -> String {
    let mut out = String::new();
    out.push_str(
        "# golden fabric potrf timelines (µs, 2x8 two-tier fabric) — \
         regenerate with UPDATE_GOLDEN=1\n",
    );
    for &(p, q, tile, n) in FABRIC_GRID {
        let (_, t_barrier, _) = run_potrf2d_fabric(p, q, tile, n, PipelineConfig::barrier());
        let (_, t_look, snap) = run_potrf2d_fabric(p, q, tile, n, PipelineConfig::lookahead(2));
        let snap = snap.expect("pipelined run has a timeline");
        writeln!(out, "config islands=2 per_island=8 p={p} q={q} tile={tile} n={n}").unwrap();
        writeln!(out, "  barrier_makespan_us   {:.3}", t_barrier * 1e6).unwrap();
        writeln!(out, "  lookahead_makespan_us {:.3}", t_look * 1e6).unwrap();
        for d in &snap {
            writeln!(
                out,
                "  dev {} compute {:.3} panel {:.3} copy {:.3} busy {:.3}",
                d.device,
                d.compute_horizon * 1e6,
                d.panel_horizon * 1e6,
                d.copy_horizon * 1e6,
                d.busy * 1e6
            )
            .unwrap();
        }
    }
    out
}

/// Exact-compare a rendered snapshot against its checked-in golden
/// file, bootstrapping (or regenerating under `UPDATE_GOLDEN=1`) it.
fn check_golden(file: &str, rendered: String) {
    let golden_dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden");
    let golden_path = golden_dir.join(file);
    let update = std::env::var_os("UPDATE_GOLDEN").is_some();
    if update || !golden_path.exists() {
        std::fs::create_dir_all(&golden_dir).unwrap();
        std::fs::write(&golden_path, &rendered).unwrap();
        eprintln!("golden timeline snapshot written to {golden_path:?}");
        return;
    }
    let golden = std::fs::read_to_string(&golden_path).unwrap();
    assert_eq!(
        golden, rendered,
        "per-device fabric timelines drifted from {golden_path:?} — a perf regression (or an \
         intentional scheduler/cost-model change: rerun with UPDATE_GOLDEN=1 and review the diff)"
    );
}

#[test]
fn fabric_potrf2d_timelines_match_golden_snapshot() {
    check_golden("potrf_fabric_timelines.txt", render_fabric_snapshot());
}
