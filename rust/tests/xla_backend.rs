//! Integration: the AOT-compiled XLA kernels against the native
//! reference backend, and full solves running end-to-end on the XLA
//! path.
//!
//! The artifact-requiring tests are `#[ignore]`d so the tier-1 suite
//! passes from a clean checkout with no XLA artifacts; run them with
//! `make test-xla` (= `cargo test --test xla_backend -- --ignored`)
//! after `make artifacts`. When run without artifacts they fail with a
//! pointed message, not a build error — asserted by the always-on
//! `missing_artifacts_fail_with_pointed_message` below, so the failure
//! mode itself is pinned rather than silently skipped.

use jaxmg::coordinator::{BackendKind, ExecMode, JaxMg, Mesh};
use jaxmg::costmodel::GpuCostModel;
use jaxmg::device::SimNode;
use jaxmg::linalg::{tol_for, FrobNorm, Matrix};
use jaxmg::runtime::{PjRtRuntime, XlaKernels};
use jaxmg::scalar::{c32, c64, Scalar};
use jaxmg::solver::{NativeKernels, TileKernels};
use std::sync::Arc;

fn artifacts_dir() -> std::path::PathBuf {
    // Tests run from the crate root.
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn runtime() -> Arc<PjRtRuntime> {
    Arc::new(PjRtRuntime::new(artifacts_dir()).expect("PJRT CPU client"))
}

fn xla_kernels<S: Scalar>(tile: usize) -> XlaKernels<S>
where
    S::Real: xla::NativeType + xla::ArrayElement,
{
    XlaKernels::<S>::new(runtime(), tile).expect("artifacts present — run `make artifacts`")
}

fn cross_check_gemms<S: Scalar>(tile: usize, seed: u64)
where
    S::Real: xla::NativeType + xla::ArrayElement,
{
    let xk = xla_kernels::<S>(tile);
    let nk = NativeKernels;
    // Shapes exercise padding (m, n, k not multiples of tile).
    let (m, n, k) = (tile * 2 - 3, tile + 1, tile * 2 - 1);
    let a = Matrix::<S>::random(m, k, seed);
    let b = Matrix::<S>::random(k, n, seed + 1);
    let c0 = Matrix::<S>::random(m, n, seed + 2);
    let alpha = S::from_f64(-1.0);

    let mut c_xla = c0.clone();
    xk.gemm_nn(&mut c_xla, &a, &b, alpha).unwrap();
    let mut c_nat = c0.clone();
    nk.gemm_nn(&mut c_nat, &a, &b, alpha).unwrap();
    assert!(c_xla.rel_err(&c_nat) < tol_for::<S>(m.max(k)), "gemm_nn mismatch {:?}", S::DTYPE);

    let bh = Matrix::<S>::random(n, k, seed + 3);
    let mut c_xla = c0.clone();
    xk.gemm_nh(&mut c_xla, &a, &bh, alpha).unwrap();
    let mut c_nat = c0.clone();
    nk.gemm_nh(&mut c_nat, &a, &bh, alpha).unwrap();
    assert!(c_xla.rel_err(&c_nat) < tol_for::<S>(m.max(k)), "gemm_nh mismatch {:?}", S::DTYPE);

    let ah = Matrix::<S>::random(k, m, seed + 4);
    let mut c_xla = c0.clone();
    xk.gemm_hn(&mut c_xla, &ah, &b, alpha).unwrap();
    let mut c_nat = c0.clone();
    nk.gemm_hn(&mut c_nat, &ah, &b, alpha).unwrap();
    assert!(c_xla.rel_err(&c_nat) < tol_for::<S>(m.max(k)), "gemm_hn mismatch {:?}", S::DTYPE);
}

#[test]
#[ignore = "requires AOT artifacts: run `make artifacts`, then `make test-xla`"]
fn xla_gemm_matches_native_f32() {
    cross_check_gemms::<f32>(8, 1);
}

#[test]
#[ignore = "requires AOT artifacts: run `make artifacts`, then `make test-xla`"]
fn xla_gemm_matches_native_f64() {
    cross_check_gemms::<f64>(8, 2);
}

#[test]
#[ignore = "requires AOT artifacts: run `make artifacts`, then `make test-xla`"]
fn xla_gemm_matches_native_c64() {
    cross_check_gemms::<c32>(8, 3);
}

#[test]
#[ignore = "requires AOT artifacts: run `make artifacts`, then `make test-xla`"]
fn xla_gemm_matches_native_c128() {
    cross_check_gemms::<c64>(8, 4);
}

fn cross_check_panel<S: Scalar>(tile: usize, seed: u64)
where
    S::Real: xla::NativeType + xla::ArrayElement,
{
    let xk = xla_kernels::<S>(tile);
    let nk = NativeKernels;
    // potf2 on a tile smaller than T exercises identity padding.
    let n = tile - 2;
    let a = Matrix::<S>::spd_random(n, seed);
    let l_xla = TileKernels::<S>::potf2(&xk, &a).unwrap();
    let l_nat = TileKernels::<S>::potf2(&nk, &a).unwrap();
    assert!(l_xla.rel_err(&l_nat) < tol_for::<S>(n), "potf2 mismatch {:?}", S::DTYPE);

    // Panel solve with a tall B (chunked rows).
    let b = Matrix::<S>::random(3 * tile - 1, n, seed + 1);
    let x_xla = xk.trsm_rlhc(&b, &l_xla).unwrap();
    let x_nat = nk.trsm_rlhc(&b, &l_nat).unwrap();
    assert!(x_xla.rel_err(&x_nat) < tol_for::<S>(n) * 10.0, "trsm_rlhc mismatch {:?}", S::DTYPE);

    // Left solves with a wide RHS (chunked cols).
    let b2 = Matrix::<S>::random(n, 2 * tile + 3, seed + 2);
    let y_xla = xk.trsm_llnn(&l_xla, &b2).unwrap();
    let y_nat = nk.trsm_llnn(&l_nat, &b2).unwrap();
    assert!(y_xla.rel_err(&y_nat) < tol_for::<S>(n) * 10.0, "trsm_llnn mismatch {:?}", S::DTYPE);

    let z_xla = xk.trsm_llhn(&l_xla, &b2).unwrap();
    let z_nat = nk.trsm_llhn(&l_nat, &b2).unwrap();
    assert!(z_xla.rel_err(&z_nat) < tol_for::<S>(n) * 10.0, "trsm_llhn mismatch {:?}", S::DTYPE);
}

#[test]
#[ignore = "requires AOT artifacts: run `make artifacts`, then `make test-xla`"]
fn xla_panel_matches_native_f64() {
    cross_check_panel::<f64>(8, 10);
}

#[test]
#[ignore = "requires AOT artifacts: run `make artifacts`, then `make test-xla`"]
fn xla_panel_matches_native_c128() {
    cross_check_panel::<c64>(8, 11);
}

#[test]
#[ignore = "requires AOT artifacts: run `make artifacts`, then `make test-xla`"]
fn xla_panel_matches_native_f32() {
    cross_check_panel::<f32>(8, 12);
}

#[test]
#[ignore = "requires AOT artifacts: run `make artifacts`, then `make test-xla`"]
fn xla_potf2_rejects_nonpd() {
    let xk = xla_kernels::<f64>(8);
    let mut a = Matrix::<f64>::eye(6);
    a[(3, 3)] = -2.0;
    match TileKernels::<f64>::potf2(&xk, &a) {
        Err(jaxmg::Error::NotPositiveDefinite { minor }) => assert!(minor >= 4),
        other => panic!("expected NotPositiveDefinite, got {other:?}"),
    }
}

// ---- end-to-end solves on the XLA backend --------------------------------

fn mg(ndev: usize, tile: usize) -> JaxMg {
    let node = SimNode::new_uniform(ndev, 1 << 26);
    JaxMg::builder()
        .mesh(Mesh::new_1d(node, "x"))
        .tile_size(tile)
        .exec_mode(ExecMode::Spmd)
        .backend(BackendKind::Xla)
        .artifacts_dir(artifacts_dir())
        .build()
        .expect("XLA backend context")
}

#[test]
#[ignore = "requires AOT artifacts: run `make artifacts`, then `make test-xla`"]
fn e2e_potrs_on_xla_backend() {
    let ctx = mg(4, 8);
    let n = 32;
    let a = Matrix::<f64>::spd_random(n, 20);
    let x_true = Matrix::<f64>::random(n, 2, 21);
    let b = a.matmul(&x_true);
    let x = ctx.potrs(&a, &b).unwrap();
    assert!(x.rel_err(&x_true) < tol_for::<f64>(n) * 10.0);
}

#[test]
#[ignore = "requires AOT artifacts: run `make artifacts`, then `make test-xla`"]
fn e2e_potrs_paper_matrix_f32() {
    // Fig. 3a configuration: float32, diag(1..N), b = ones.
    let ctx = mg(4, 8);
    let n = 32;
    let a = Matrix::<f32>::spd_diag(n);
    let b = Matrix::<f32>::ones(n, 1);
    let x = ctx.potrs(&a, &b).unwrap();
    for i in 0..n {
        assert!((x[(i, 0)] - 1.0 / (i + 1) as f32).abs() < 1e-5);
    }
}

#[test]
#[ignore = "requires AOT artifacts: run `make artifacts`, then `make test-xla`"]
fn e2e_potri_c128_on_xla_backend() {
    // Fig. 3b configuration: complex128 inverse.
    let ctx = mg(2, 8);
    let n = 16;
    let a = Matrix::<c64>::spd_random(n, 22);
    let inv = ctx.potri(&a).unwrap();
    assert!(a.matmul(&inv).rel_err(&Matrix::eye(n)) < tol_for::<c64>(n) * 10.0);
}

#[test]
#[ignore = "requires AOT artifacts: run `make artifacts`, then `make test-xla`"]
fn e2e_syevd_f64_on_xla_backend() {
    // Fig. 3c configuration: float64 eigendecomposition.
    let ctx = mg(2, 8);
    let n = 16;
    let a = Matrix::<f64>::spd_diag(n);
    let (vals, _) = ctx.syevd(&a).unwrap();
    for i in 0..n {
        assert!((vals[i] - (i + 1) as f64).abs() < 1e-8);
    }
}

#[test]
#[ignore = "requires AOT artifacts: run `make artifacts`, then `make test-xla`"]
fn executable_cache_reused_across_solves() {
    let rt = runtime();
    let xk = XlaKernels::<f64>::new(rt.clone(), 8).unwrap();
    let a = Matrix::<f64>::spd_random(8, 30);
    let _ = TileKernels::<f64>::potf2(&xk, &a).unwrap();
    let cached_after_one = rt.cached();
    let _ = TileKernels::<f64>::potf2(&xk, &a).unwrap();
    assert_eq!(rt.cached(), cached_after_one, "second call must hit the cache");
    assert!(cached_after_one >= 1);
}

#[test]
#[ignore = "requires AOT artifacts: run `make artifacts`, then `make test-xla`"]
fn native_and_xla_agree_on_full_potrf() {
    // The strongest cross-check: identical factorizations through two
    // completely different compute stacks (Rust loops vs AOT XLA).
    let model = GpuCostModel::h200();
    let n = 24;
    let a = Matrix::<f64>::spd_random(n, 31);

    let run = |backend: jaxmg::solver::SolverBackend<f64>| -> Matrix<f64> {
        use jaxmg::layout::BlockCyclic1D;
        use jaxmg::solver::{potrf_dist, Ctx};
        use jaxmg::tile::{DistMatrix, Layout1D};
        let node = SimNode::new_uniform(3, 1 << 26);
        let ctx = Ctx::new(&node, &model, &backend);
        let lay = Layout1D::BlockCyclic(BlockCyclic1D::new(n, 8, 3).unwrap());
        let mut dm = DistMatrix::scatter(&node, &a, lay).unwrap();
        potrf_dist(&ctx, &mut dm).unwrap();
        dm.gather().unwrap()
    };

    let l_native = run(jaxmg::solver::SolverBackend::Native);
    let l_xla = run(jaxmg::solver::SolverBackend::Xla(Arc::new(xla_kernels::<f64>(8))));
    assert!(l_native.rel_err(&l_xla) < 1e-12);
}

/// Always-on guard (not `#[ignore]`d): with no artifacts present, the
/// XLA backend must fail at construction with the pointed
/// `make artifacts` message — never a build error, never a panic from
/// deeper in the stack.
#[test]
fn missing_artifacts_fail_with_pointed_message() {
    if artifacts_dir().join(".stamp").exists() {
        return; // artifacts built — the ignored suite above covers this
    }
    match XlaKernels::<f64>::new(runtime(), 8) {
        Err(e) => {
            let msg = format!("{e}");
            assert!(msg.contains("make artifacts"), "unpointed error: {msg}");
        }
        Ok(_) => panic!("artifacts absent but XlaKernels::new succeeded"),
    }
}
