//! Property tests for the 2D tile-grid layout stack: redistribution
//! round-trips across 2D↔1D↔contiguous chains are bitwise identity for
//! all four dtypes (ragged edge tiles included), tile cycles cover
//! every tile slot exactly once, and the `P = 1` compatibility path
//! runs the 1D solvers bitwise-identically on 2D handles.
//!
//! Same deterministic seeded harness as `properties.rs` (the vendored
//! crate set has no proptest).

use jaxmg::costmodel::GpuCostModel;
use jaxmg::device::SimNode;
use jaxmg::layout::{
    cycle_decomposition, tile_permutation_between, BlockCyclic1D, BlockCyclic2D, ContiguousBlock,
    ContiguousGrid2D, MatrixLayout, Redistributor,
};
use jaxmg::linalg::Matrix;
use jaxmg::rng::Rng;
use jaxmg::scalar::{c32, c64, Scalar};
use jaxmg::solver::{
    potrf_dist, potri_dist, potrs_dist, syevd_dist, Ctx, PipelineConfig, SolverBackend,
};
use jaxmg::tile::{DistMatrix, LayoutKind};

const CASES: u64 = 25;

/// Run `f` over `CASES` seeded trials, labelling failures with the seed.
fn for_all(name: &str, f: impl Fn(&mut Rng)) {
    for seed in 0..CASES {
        let mut rng = Rng::new(0x2D2D_0000 + seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            panic!("property {name:?} failed at seed {seed}: {e:?}");
        }
    }
}

/// Contiguous → 2D grid → 1D cyclic → contiguous, asserting bitwise
/// content identity after every hop.
fn chain_roundtrip<S: Scalar>(rng: &mut Rng) {
    let p = rng.range(1, 3);
    let q = rng.range(1, 3);
    let ndev = p * q;
    let tr = rng.range(1, 5);
    let tc = rng.range(1, 5);
    let rows = rng.range(1, 20);
    let n = rng.range(1, 20);
    let node = SimNode::new_uniform(ndev, 1 << 26);
    let a = Matrix::<S>::random(rows, n, rng.next_u64());

    let contig = LayoutKind::Contiguous(ContiguousBlock::new(n, ndev).unwrap());
    let grid = LayoutKind::Grid(BlockCyclic2D::new(rows, n, tr, tc, p, q).unwrap());
    let cyc1d = LayoutKind::BlockCyclic(BlockCyclic1D::new(n, tc, ndev).unwrap());

    let mut dm = DistMatrix::scatter(&node, &a, contig).unwrap();
    let used_before: usize = node.memory_reports().iter().map(|r| r.used).sum();

    Redistributor::convert(&mut dm, grid).unwrap();
    assert_eq!(dm.gather().unwrap(), a, "contiguous→2D corrupted content");
    Redistributor::convert(&mut dm, cyc1d).unwrap();
    assert_eq!(dm.gather().unwrap(), a, "2D→1D corrupted content");
    Redistributor::convert(&mut dm, contig).unwrap();
    assert_eq!(dm.gather().unwrap(), a, "1D→contiguous corrupted content");

    // Per-device bytes may differ between layouts, but total storage is
    // conserved and no staging buffers leak.
    let used_after: usize = node.memory_reports().iter().map(|r| r.used).sum();
    assert_eq!(used_before, used_after, "redistribution chain leaked device memory");
}

#[test]
fn prop_chain_roundtrip_f32() {
    for_all("chain_f32", |rng| chain_roundtrip::<f32>(rng));
}

#[test]
fn prop_chain_roundtrip_f64() {
    for_all("chain_f64", |rng| chain_roundtrip::<f64>(rng));
}

#[test]
fn prop_chain_roundtrip_c64() {
    for_all("chain_c64", |rng| chain_roundtrip::<c32>(rng));
}

#[test]
fn prop_chain_roundtrip_c128() {
    for_all("chain_c128", |rng| chain_roundtrip::<c64>(rng));
}

#[test]
fn ragged_edge_chain_all_dtypes() {
    // Pinned ragged shapes: n % (tile_c·q) ≠ 0 and m % (tile_r·p) ≠ 0.
    fn case<S: Scalar>(seed: u64) {
        let (rows, n, tr, tc, p, q) = (10usize, 14usize, 4usize, 3usize, 2usize, 2usize);
        assert!(n % (tc * q) != 0 && rows % (tr * p) != 0);
        let node = SimNode::new_uniform(p * q, 1 << 26);
        let a = Matrix::<S>::random(rows, n, seed);
        let contig = LayoutKind::Contiguous(ContiguousBlock::new(n, p * q).unwrap());
        let grid = LayoutKind::Grid(BlockCyclic2D::new(rows, n, tr, tc, p, q).unwrap());
        let shard = LayoutKind::GridContig(ContiguousGrid2D::new(rows, n, tr, tc, p, q).unwrap());
        let mut dm = DistMatrix::scatter(&node, &a, shard).unwrap();
        for target in [grid, contig, shard] {
            Redistributor::convert(&mut dm, target).unwrap();
            assert_eq!(dm.gather().unwrap(), a, "ragged hop corrupted content");
        }
    }
    case::<f32>(1);
    case::<f64>(2);
    case::<c32>(3);
    case::<c64>(4);
}

#[test]
fn prop_tile_cycles_cover_all_slots_exactly_once() {
    for_all("tile_cycle_cover", |rng| {
        // Uniform tilings whose tile-grid divides every candidate
        // device grid, so per-device counts always match.
        let ndev = [2usize, 4, 6][rng.range(0, 2)];
        let tr = rng.range(1, 4);
        let tc = rng.range(1, 4);
        let m = tr * ndev * rng.range(1, 3);
        let n = tc * ndev * rng.range(1, 3);
        // Two random factorizations of ndev.
        let factorizations: Vec<(usize, usize)> =
            (1..=ndev).filter(|d| ndev % d == 0).map(|d| (d, ndev / d)).collect();
        let (p1, q1) = factorizations[rng.range(0, factorizations.len() - 1)];
        let (p2, q2) = factorizations[rng.range(0, factorizations.len() - 1)];
        let src = BlockCyclic2D::new(m, n, tr, tc, p1, q1).unwrap();
        let dst = BlockCyclic2D::new(m, n, tr, tc, p2, q2).unwrap();
        let perm = tile_permutation_between(&src, &dst).unwrap();
        let total: usize = (0..src.num_devices()).map(|d| src.tiles_on(d)).sum();
        assert_eq!(perm.len(), total);
        let cycles = cycle_decomposition(&perm);
        let mut count = vec![0usize; total];
        for c in &cycles {
            for &s in &c.slots {
                count[s] += 1;
            }
        }
        assert!(count.iter().all(|&k| k == 1), "cycles must cover every tile slot exactly once");
    });
}

#[test]
fn prop_uniform_regrid_runs_in_place() {
    for_all("uniform_regrid_in_place", |rng| {
        let tr = rng.range(1, 4);
        let tc = rng.range(1, 4);
        let m = tr * 4 * rng.range(1, 3);
        let n = tc * 4 * rng.range(1, 3);
        let node = SimNode::new_uniform(4, 1 << 26);
        let a = Matrix::<f64>::random(m, n, rng.next_u64());
        let g22 = LayoutKind::Grid(BlockCyclic2D::new(m, n, tr, tc, 2, 2).unwrap());
        let g41 = LayoutKind::Grid(BlockCyclic2D::new(m, n, tr, tc, 4, 1).unwrap());
        let mut dm = DistMatrix::scatter(&node, &a, g22).unwrap();
        let before: usize = node.memory_reports().iter().map(|r| r.used).sum();
        let plan = Redistributor::convert(&mut dm, g41).unwrap();
        assert!(plan.in_place, "uniform regrid with matching counts must run in place");
        let after: usize = node.memory_reports().iter().map(|r| r.used).sum();
        assert_eq!(before, after, "staging tiles leaked");
        assert_eq!(dm.gather().unwrap(), a);
    });
}

#[test]
fn p1_grid_potrf_potrs_bitwise_match_1d() {
    // Acceptance: the whole 1D solver chain, run on a P=1 grid handle,
    // is bitwise identical to the native 1D layout — results and
    // simulated schedule.
    let (n, tile, ndev, nrhs) = (24usize, 4usize, 4usize, 2usize);
    let a = Matrix::<f64>::spd_random(n, 0xB17);
    let x_true = Matrix::<f64>::random(n, nrhs, 0xB18);
    let b = a.matmul(&x_true);
    let model = GpuCostModel::h200();
    let backend = SolverBackend::<f64>::Native;

    let run = |lay: LayoutKind| -> (Matrix<f64>, Matrix<f64>, f64) {
        let node = SimNode::new_uniform(ndev, 1 << 26);
        let ctx = Ctx::with_pipeline(&node, &model, &backend, PipelineConfig::lookahead(2));
        let mut dm = DistMatrix::scatter(&node, &a, lay).unwrap();
        node.reset_accounting();
        potrf_dist(&ctx, &mut dm).unwrap();
        let x = potrs_dist(&ctx, &dm, &b).unwrap();
        (dm.gather().unwrap(), x, node.sim_time())
    };

    let (l1, x1, t1) = run(LayoutKind::BlockCyclic(BlockCyclic1D::new(n, tile, ndev).unwrap()));
    let (l2, x2, t2) =
        run(LayoutKind::Grid(BlockCyclic2D::new(n, n, n, tile, 1, ndev).unwrap()));
    assert_eq!(l1.as_slice(), l2.as_slice(), "P=1 grid changed the factor");
    assert_eq!(x1.as_slice(), x2.as_slice(), "P=1 grid changed the solution");
    assert_eq!(t1, t2, "P=1 grid changed the simulated schedule");
}

/// Run the whole Cholesky chain (factor → solve → inverse) on one
/// layout under `cfg`, returning the gathered factor, solution and
/// inverse plus the simulated makespan.
fn chol_chain<S: Scalar>(
    lay: LayoutKind,
    a: &Matrix<S>,
    b: &Matrix<S>,
    cfg: PipelineConfig,
) -> (Matrix<S>, Matrix<S>, Matrix<S>, f64) {
    let node = SimNode::new_uniform(lay.num_devices(), 1 << 26);
    let model = GpuCostModel::h200();
    let backend = SolverBackend::<S>::Native;
    let mut dm = DistMatrix::scatter(&node, a, lay).unwrap();
    node.reset_accounting();
    let ctx = Ctx::with_pipeline(&node, &model, &backend, cfg);
    potrf_dist(&ctx, &mut dm).unwrap();
    let l = dm.gather().unwrap();
    let x = potrs_dist(&ctx, &dm, b).unwrap();
    potri_dist(&ctx, &mut dm).unwrap();
    let inv = dm.gather().unwrap();
    (l, x, inv, node.sim_time())
}

/// Acceptance: `potrf/potrs/potri_dist` executing grid-natively on
/// `P × Q` grids (ragged edge tiles included) produce **bitwise** the
/// 1D path's factor, solution and inverse — for grid-native `P = 1`
/// (`1 × Q`, square tiles) and `P > 1` alike.
fn grid_native_cholesky_matches_1d<S: Scalar>(seed: u64) {
    let (n, tile, nrhs) = (21usize, 4usize, 2usize); // ragged: 21 % 4 != 0
    let a = Matrix::<S>::spd_random(n, seed);
    let b = Matrix::<S>::random(n, nrhs, seed + 50);
    let (l1, x1, i1, _) = chol_chain::<S>(
        LayoutKind::BlockCyclic(BlockCyclic1D::new(n, tile, 4).unwrap()),
        &a,
        &b,
        PipelineConfig::barrier(),
    );
    for (p, q) in [(2usize, 2usize), (4, 1), (1, 4)] {
        let lay = LayoutKind::Grid(BlockCyclic2D::new(n, n, tile, tile, p, q).unwrap());
        let (l2, x2, i2, _) = chol_chain::<S>(lay, &a, &b, PipelineConfig::barrier());
        assert_eq!(l1.as_slice(), l2.as_slice(), "{p}x{q} factor diverges ({:?})", S::DTYPE);
        assert_eq!(x1.as_slice(), x2.as_slice(), "{p}x{q} solution diverges ({:?})", S::DTYPE);
        assert_eq!(i1.as_slice(), i2.as_slice(), "{p}x{q} inverse diverges ({:?})", S::DTYPE);
    }
}

#[test]
fn grid_native_cholesky_bitwise_f32() {
    grid_native_cholesky_matches_1d::<f32>(0x61D1);
}

#[test]
fn grid_native_cholesky_bitwise_f64() {
    grid_native_cholesky_matches_1d::<f64>(0x61D2);
}

#[test]
fn grid_native_cholesky_bitwise_c64() {
    grid_native_cholesky_matches_1d::<c32>(0x61D3);
}

#[test]
fn grid_native_cholesky_bitwise_c128() {
    grid_native_cholesky_matches_1d::<c64>(0x61D4);
}

#[test]
fn grid_chain_pipelined_matches_barrier_bitwise() {
    // The lookahead schedule is a timing overlay on the grid paths too:
    // identical numerics, and the full pipelined chain never runs
    // slower than the barrier one.
    let n = 24usize;
    let a = Matrix::<f64>::spd_random(n, 0x61D5);
    let b = Matrix::<f64>::random(n, 2, 0x61D6);
    let lay = LayoutKind::Grid(BlockCyclic2D::new(n, n, 4, 4, 2, 2).unwrap());
    let (l_b, x_b, i_b, t_b) = chol_chain::<f64>(lay, &a, &b, PipelineConfig::barrier());
    let (l_l, x_l, i_l, t_l) = chol_chain::<f64>(lay, &a, &b, PipelineConfig::lookahead(2));
    assert_eq!(l_b.as_slice(), l_l.as_slice(), "schedule changed the grid factor");
    assert_eq!(x_b.as_slice(), x_l.as_slice(), "schedule changed the grid solution");
    assert_eq!(i_b.as_slice(), i_l.as_slice(), "schedule changed the grid inverse");
    assert!(t_l <= t_b, "grid pipelined chain {t_l} slower than barrier {t_b}");
}

#[test]
fn grid_native_potrf_rides_rings_and_counts_metrics() {
    let n = 32usize;
    let node = SimNode::new_uniform(4, 1 << 26);
    let model = GpuCostModel::h200();
    let backend = SolverBackend::<f64>::Native;
    let ctx = Ctx::new(&node, &model, &backend);
    let a = Matrix::<f64>::spd_random(n, 0x61D7);
    let b = Matrix::<f64>::ones(n, 1);
    let lay = LayoutKind::Grid(BlockCyclic2D::new(n, n, 4, 4, 2, 2).unwrap());
    let mut dm = DistMatrix::scatter(&node, &a, lay).unwrap();
    node.reset_accounting();
    potrf_dist(&ctx, &mut dm).unwrap();
    let _ = potrs_dist(&ctx, &dm, &b).unwrap();
    let m = node.metrics().snapshot();
    assert_eq!(m.grid_solves, 2, "potrf + potrs must both record a grid-native solve");
    assert_eq!(m.grid_peak_p, 2);
    assert_eq!(m.grid_peak_q, 2);
    assert!(m.grid_row_bytes > 0, "row rings must carry panel segments");
    assert!(m.grid_col_bytes > 0, "column rings must carry blocks/reductions");
    assert!(m.peer_bytes >= m.grid_row_bytes + m.grid_col_bytes);
    for d in 0..4 {
        assert!(node.device(d).unwrap().clock().now() > 0.0, "device {d} idle");
    }
}

#[test]
fn grid_native_rejects_rectangular_tiles() {
    let node = SimNode::new_uniform(4, 1 << 24);
    let model = GpuCostModel::h200();
    let backend = SolverBackend::<f64>::Native;
    let ctx = Ctx::new(&node, &model, &backend);
    let a = Matrix::<f64>::spd_random(12, 0x61D8);
    let lay = LayoutKind::Grid(BlockCyclic2D::new(12, 12, 4, 3, 2, 2).unwrap());
    let mut dm = DistMatrix::scatter(&node, &a, lay).unwrap();
    assert!(matches!(potrf_dist(&ctx, &mut dm), Err(jaxmg::Error::Layout(_))));
}

#[test]
fn grid_native_potri_frees_its_workspace() {
    let n = 16usize;
    let node = SimNode::new_uniform(4, 1 << 24);
    let model = GpuCostModel::h200();
    let backend = SolverBackend::<f64>::Native;
    let ctx = Ctx::new(&node, &model, &backend);
    let a = Matrix::<f64>::spd_random(n, 0x61D9);
    let lay = LayoutKind::Grid(BlockCyclic2D::new(n, n, 4, 4, 2, 2).unwrap());
    let mut dm = DistMatrix::scatter(&node, &a, lay).unwrap();
    potrf_dist(&ctx, &mut dm).unwrap();
    potri_dist(&ctx, &mut dm).unwrap();
    for rep in node.memory_reports() {
        assert_eq!(rep.allocations, 1, "grid potri leaked its X workspace");
    }
    // And the inverse is right.
    use jaxmg::linalg::{tol_for, FrobNorm};
    let inv = dm.gather().unwrap();
    assert!(a.matmul(&inv).rel_err(&Matrix::eye(n)) < tol_for::<f64>(n) * 10.0);
}

#[test]
fn grid_syevd_end_to_end_from_2d_shard() {
    // The 2D deployment story: a 2D-mesh shard arrives, is redistributed
    // to the 2D cyclic compute layout in place (uniform tiling), syevd
    // runs on the grid, and the eigenpairs verify against the matrix.
    let n = 16usize;
    let node = SimNode::new_uniform(4, 1 << 26);
    let model = GpuCostModel::h200();
    let backend = SolverBackend::<f64>::Native;
    let ctx = Ctx::new(&node, &model, &backend);
    let a = Matrix::<f64>::hermitian_random(n, 0xE16);
    let shard = LayoutKind::GridContig(ContiguousGrid2D::new(n, n, 4, 4, 2, 2).unwrap());
    let cyclic = LayoutKind::Grid(BlockCyclic2D::new(n, n, 4, 4, 2, 2).unwrap());
    let mut dm = DistMatrix::scatter(&node, &a, shard).unwrap();
    let plan = Redistributor::convert(&mut dm, cyclic).unwrap();
    assert!(plan.in_place, "uniform shard→cyclic must use the tile cycle walk");
    let vals = syevd_dist(&ctx, &mut dm).unwrap();
    let vecs = dm.gather().unwrap();
    let av = a.matmul(&vecs);
    let mut vl = vecs.clone();
    for j in 0..n {
        for i in 0..n {
            let v = vl[(i, j)] * vals[j];
            vl[(i, j)] = v;
        }
    }
    use jaxmg::linalg::FrobNorm;
    assert!(av.rel_err(&vl) < 1e-8, "grid syevd residual: {}", av.rel_err(&vl));
}
