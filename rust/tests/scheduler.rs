//! Scheduler property suite: the SLO-aware queue's guarantees hold
//! end-to-end through the SPMD front — no starvation under continuous
//! interactive pressure, policy choice never changes numerics,
//! panel-boundary preemption is bitwise invisible to the preempted
//! solve (all four dtypes), and tenant quotas never over-admit.

use jaxmg::coordinator::{
    DistRoutine, Footprint, SchedConfig, SchedPolicy, Slo, SloClass, SmallConfig, SolveService,
};
use jaxmg::device::SimNode;
use jaxmg::linalg::Matrix;
use jaxmg::scalar::{c32, c64, DType, Scalar};

fn edf_config() -> SchedConfig {
    SchedConfig { policy: SchedPolicy::EdfSjf, ..SchedConfig::default() }
}

/// Continuous interactive pressure on a single worker must not starve
/// a queued batch-class solve: every pass-over ages it, and past
/// `max_skips` it becomes an urgent barrier the scheduler must clear.
#[test]
fn batch_class_work_survives_interactive_pressure() {
    let node = SimNode::new_uniform(2, 1 << 26);
    let mut sched = edf_config();
    sched.max_skips = 3;
    let svc = SolveService::with_config(node.clone(), 1, SmallConfig::with_tile(16), sched);

    let a = Matrix::<f64>::spd_random(64, 1);
    let b = Matrix::<f64>::random(64, 1, 2);
    let batch = svc
        .submit_dist_slo(DistRoutine::Potrs, a.clone(), Some(b.clone()), Slo::batch())
        .unwrap();

    // Keep three interactive solves outstanding at all times, so the
    // lone worker always has a better-ranked candidate than the batch
    // solve; only the anti-starvation barrier can let it through.
    let submit_interactive = |i: u64| {
        let ia = Matrix::<f64>::spd_random(32, 100 + i);
        let ib = Matrix::<f64>::random(32, 1, 200 + i);
        svc.submit_dist_slo(DistRoutine::Potrs, ia, Some(ib), Slo::interactive()).unwrap()
    };
    let mut window: std::collections::VecDeque<_> = (0..3).map(submit_interactive).collect();
    let mut rounds = 0usize;
    while !batch.is_ready() && rounds < 40 {
        window.pop_front().unwrap().wait();
        window.push_back(submit_interactive(10 + rounds as u64));
        rounds += 1;
    }
    assert!(
        batch.is_ready(),
        "batch-class solve starved behind {rounds} rounds of interactive traffic"
    );
    batch.wait();
    for h in window {
        h.wait();
    }
    svc.drain();
    let m = node.metrics().snapshot();
    assert_eq!(m.class_completed[SloClass::Batch.index()], 1);
    assert!(m.class_completed[SloClass::Interactive.index()] >= 3);
}

/// The same submissions under FIFO and EDF/SJF must produce bitwise
/// identical solutions: scheduling reorders execution, never math.
#[test]
fn policy_choice_never_changes_numerics() {
    let run = |sched: SchedConfig| -> Vec<Vec<f64>> {
        let node = SimNode::new_uniform(4, 1 << 26);
        let svc = SolveService::with_config(node, 2, SmallConfig::with_tile(16), sched);
        let handles: Vec<_> = (0..6)
            .map(|i| {
                let n = 48 + 16 * (i % 3);
                let a = Matrix::<f64>::spd_random(n, i as u64);
                let b = Matrix::<f64>::random(n, 1, 50 + i as u64);
                let slo = match i % 3 {
                    0 => Slo::interactive().with_deadline_ns(5_000_000),
                    1 => Slo::standard(),
                    _ => Slo::batch(),
                };
                svc.submit_dist_slo(DistRoutine::Potrs, a, Some(b), slo).unwrap()
            })
            .collect();
        let out = handles.into_iter().map(|h| h.wait().0.as_slice().to_vec()).collect();
        svc.drain();
        out
    };
    let fifo = run(SchedConfig::default());
    let edf = run(edf_config());
    for (i, (f, e)) in fifo.iter().zip(&edf).enumerate() {
        assert_eq!(f, e, "solve {i} differs between FIFO and EDF/SJF");
    }
}

/// A solve preempted at panel boundaries must produce bitwise the same
/// result as an undisturbed run — for every dtype the paper serves.
#[test]
fn preempted_solves_are_bitwise_identical_across_dtypes() {
    fn check<S: Scalar>() {
        let n = 192;
        let a = Matrix::<S>::spd_random(n, 7);
        let b = Matrix::<S>::random(n, 1, 8);

        // Reference: FIFO service, nothing else in flight, no hook.
        let node_ref = SimNode::new_uniform(4, 1 << 26);
        let svc_ref = SolveService::with_config(
            node_ref,
            1,
            SmallConfig::with_tile(16),
            SchedConfig::default(),
        );
        let (x_ref, _) = svc_ref
            .submit_dist_slo(DistRoutine::Potrs, a.clone(), Some(b.clone()), Slo::standard())
            .unwrap()
            .wait();
        svc_ref.drain();

        // Same solve as preemptible batch work, with interactive
        // traffic submitted behind it on the same lone worker.
        let node = SimNode::new_uniform(4, 1 << 26);
        let svc = SolveService::with_config(node, 1, SmallConfig::with_tile(16), edf_config());
        let batch = svc
            .submit_dist_slo(DistRoutine::Potrs, a, Some(b), Slo::batch())
            .unwrap();
        let inters: Vec<_> = (0..3)
            .map(|i| {
                let ia = Matrix::<S>::spd_random(32, 300 + i);
                let ib = Matrix::<S>::random(32, 1, 400 + i);
                svc.submit_dist_slo(DistRoutine::Potrs, ia, Some(ib), Slo::interactive()).unwrap()
            })
            .collect();
        let (x, _) = batch.wait();
        for h in inters {
            h.wait();
        }
        svc.drain();
        assert!(
            x.as_slice() == x_ref.as_slice(),
            "{}: preemption changed the preempted solve's bits",
            S::DTYPE.name()
        );
    }
    check::<f32>();
    check::<f64>();
    check::<c32>();
    check::<c64>();
}

/// An interactive solve queued behind a long batch-class factorization
/// on a single worker completes via panel-boundary preemption — the
/// worker yields inside the batch solve rather than after it.
#[test]
fn interactive_work_preempts_at_panel_boundaries() {
    let node = SimNode::new_uniform(4, 1 << 27);
    let svc = SolveService::with_config(node.clone(), 1, SmallConfig::with_tile(16), edf_config());

    // 48 panels: plenty of preemption points after the poll below.
    let n = 768;
    let a = Matrix::<f64>::spd_diag(n);
    let b = Matrix::<f64>::ones(n, 1);
    let batch = svc.submit_dist_slo(DistRoutine::Potrs, a, Some(b), Slo::batch()).unwrap();
    while svc.in_flight() == 0 {
        std::thread::yield_now();
    }

    let ia = Matrix::<f64>::spd_random(32, 5);
    let ib = Matrix::<f64>::random(32, 1, 6);
    let inter =
        svc.submit_dist_slo(DistRoutine::Potrs, ia, Some(ib), Slo::interactive()).unwrap();
    inter.wait();
    let (x, _) = batch.wait();
    assert!((x[(n - 1, 0)] - 1.0 / n as f64).abs() < 1e-10, "batch solve corrupted");
    svc.drain();
    let m = node.metrics().snapshot();
    assert!(
        m.service_preemptions >= 1,
        "interactive solve should have run at a panel boundary, preemptions = {}",
        m.service_preemptions
    );
    assert_eq!(m.class_completed[SloClass::Interactive.index()], 1);
}

/// Tenant quotas bound the *peak* admitted footprint under concurrent
/// load, and fully drain afterwards.
#[test]
fn tenant_quota_never_over_admits() {
    let node = SimNode::new_uniform(2, 1 << 26);
    let fp = Footprint::for_routine("potrf", 96, 0, 16, 2, DType::F64).unwrap();
    let per_solve: usize = (0..2).map(|d| fp.bytes(d)).sum();
    // Room for exactly two concurrent solves of this tenant.
    let quota = 2 * per_solve;
    let sched = SchedConfig { tenant_quota: Some(quota), ..edf_config() };
    let svc = SolveService::with_config(node, 4, SmallConfig::with_tile(16), sched);

    let tenant = 9u32;
    let handles: Vec<_> = (0..10)
        .map(|i| {
            let a = Matrix::<f64>::spd_random(96, i as u64);
            svc.submit_dist_slo(
                DistRoutine::Potrf,
                a,
                None,
                Slo::standard().with_tenant(tenant),
            )
            .unwrap()
        })
        .collect();
    for h in handles {
        h.wait();
    }
    svc.drain();
    assert!(
        svc.tenant_peak(tenant) <= quota,
        "peak admitted {} exceeded quota {quota}",
        svc.tenant_peak(tenant)
    );
    assert!(svc.tenant_peak(tenant) > 0, "nothing was ever admitted");
    assert_eq!(svc.tenant_admitted(tenant), 0, "quota accounting leaked");
}
