//! Layout round-trips under the pipelined solver path, for all four
//! dtypes (`f32`/`f64`/`c32`/`c64` — the complex dtypes exercise the
//! split-plane `Scalar` plumbing end to end: scatter, permutation-cycle
//! redistribution, pipelined solve, gather).
//!
//! Shape: contiguous scatter → §2.1 redistribution to block-cyclic →
//! lookahead-pipelined `potrf` + `potrs` → gather, cross-checked
//! bitwise against the barrier schedule, then the factor is
//! redistributed back to the contiguous layout and gathered again — the
//! inverse conversion must preserve it exactly.

use jaxmg::costmodel::GpuCostModel;
use jaxmg::layout::{BlockCyclic1D, ContiguousBlock, Redistributor};
use jaxmg::linalg::Matrix;
use jaxmg::prelude::*;
use jaxmg::solver::{potrf_dist, potrs_dist, Ctx};
use jaxmg::tile::{DistMatrix, Layout1D};

/// One full round-trip under `cfg`; returns (factor, solution).
fn solve_via_redistribution<S: Scalar>(
    n: usize,
    tile: usize,
    ndev: usize,
    seed: u64,
    cfg: PipelineConfig,
) -> (Matrix<S>, Matrix<S>) {
    let node = SimNode::new_uniform(ndev, 1 << 26);
    let model = GpuCostModel::h200();
    let backend = SolverBackend::<S>::Native;
    let ctx = Ctx::with_pipeline(&node, &model, &backend, cfg);

    let a = Matrix::<S>::spd_random(n, seed);
    let x_true = Matrix::<S>::random(n, 2, seed + 1);
    let b = a.matmul(&x_true);

    // JAX hands the backend contiguous shards; §2.1 converts in place
    // (or falls back out of place for unbalanced shapes).
    let contig = Layout1D::Contiguous(ContiguousBlock::new(n, ndev).unwrap());
    let cyclic = Layout1D::BlockCyclic(BlockCyclic1D::new(n, tile, ndev).unwrap());
    let mut dm = DistMatrix::scatter(&node, &a, contig).unwrap();
    Redistributor::convert(&mut dm, cyclic).unwrap();

    potrf_dist(&ctx, &mut dm).unwrap();
    let x = potrs_dist(&ctx, &dm, &b).unwrap();
    let factor = dm.gather().unwrap();

    // Inverse conversion must hand back exactly the factor's columns.
    Redistributor::convert(&mut dm, contig).unwrap();
    assert_eq!(
        dm.gather().unwrap().as_slice(),
        factor.as_slice(),
        "inverse redistribution corrupted the factor ({:?})",
        S::DTYPE
    );
    dm.free().unwrap();

    // Workspace hygiene: nothing but the freed panels were held.
    for rep in node.memory_reports() {
        assert_eq!(rep.used, 0, "leaked device memory ({:?})", S::DTYPE);
    }
    (factor, x)
}

fn roundtrip_all_schedules<S: Scalar>(n: usize, tile: usize, ndev: usize, seed: u64) {
    let (l_barrier, x_barrier) =
        solve_via_redistribution::<S>(n, tile, ndev, seed, PipelineConfig::barrier());
    let (l_look, x_look) =
        solve_via_redistribution::<S>(n, tile, ndev, seed, PipelineConfig::lookahead(2));
    assert_eq!(
        l_barrier.as_slice(),
        l_look.as_slice(),
        "pipelining changed the factor ({:?})",
        S::DTYPE
    );
    assert_eq!(
        x_barrier.as_slice(),
        x_look.as_slice(),
        "pipelining changed the solution ({:?})",
        S::DTYPE
    );
    // Sanity: the solve actually solved (seeded generators reproduce
    // the true solution exactly).
    use jaxmg::linalg::{tol_for, FrobNorm};
    let x_true = Matrix::<S>::random(n, 2, seed + 1);
    assert!(x_look.rel_err(&x_true) < tol_for::<S>(n) * 20.0);
}

#[test]
fn pipelined_redistribution_roundtrip_f32() {
    roundtrip_all_schedules::<f32>(32, 4, 4, 41); // balanced: in-place cycles
    roundtrip_all_schedules::<f32>(26, 4, 3, 42); // ragged: out-of-place fallback
}

#[test]
fn pipelined_redistribution_roundtrip_f64() {
    roundtrip_all_schedules::<f64>(48, 4, 4, 43);
    roundtrip_all_schedules::<f64>(29, 5, 2, 44);
}

#[test]
fn pipelined_redistribution_roundtrip_c32() {
    roundtrip_all_schedules::<c32>(24, 3, 4, 45); // split-plane dtype, in-place-ish
    roundtrip_all_schedules::<c32>(22, 4, 3, 46);
}

#[test]
fn pipelined_redistribution_roundtrip_c64() {
    roundtrip_all_schedules::<c64>(32, 4, 4, 47);
    roundtrip_all_schedules::<c64>(27, 4, 3, 48);
}
