#!/usr/bin/env python3
"""Offline generator for `potrf2d_timelines.txt`.

This container has no Rust toolchain, so the golden snapshot of the
grid-native potrf schedule is produced by an exact integer-nanosecond
replication of the simulator's arithmetic: the same H200 cost-model
constants, the same `SimClock`/`Stream` u64-ns state transitions
(`round(seconds * 1e9)` half-away-from-zero), and the same charge
sequence as `solver::potrf::potrf_dist_grid` under both the barrier and
lookahead(2) schedules. The sibling `replicate_1d` methodology was
validated byte-for-byte against the committed `potrf_timelines.txt`
before this generator was trusted.

Timing depends only on shapes and model constants — never on matrix
values — so no numerics are replicated here.

Regenerate (with a Rust toolchain) via
`UPDATE_GOLDEN=1 cargo test --test golden_timeline`, or (without one)
`python3 gen_potrf2d.py > potrf2d_timelines.txt`.
"""
import math

# ---- GpuCostModel::h200 (f64 dtype) ----
F64_FLOPS = 30e12
PANEL_EFF = 0.25
LAUNCH = 8e-6
NVLINK_BW = 450e9
COPY_LAT = 5e-6
ESIZE = 8  # f64


def rnd(x):
    """Rust `f64::round` (half away from zero) for non-negative x."""
    return int(math.floor(x + 0.5))


def flops_potf2(n):
    return int((float(n) * float(n) * float(n)) / 3.0)


def flops_trsm(m, n, tri):
    return int(float(m) * float(n) * float(tri))


def flops_gemm(m, n, k):
    return int(2.0 * float(m) * float(n) * float(k))


def panel_time(fl):
    return LAUNCH + float(fl) / (F64_FLOPS * PANEL_EFF)


def gemm_util(d):
    d = float(d)
    return d / (d + 192.0)


def copy_time(bytes_):
    return COPY_LAT + float(bytes_) / NVLINK_BW


class Stream:
    """`device::Stream`: u64-ns horizon, issue_after = max+add."""

    def __init__(self):
        self.h = 0

    def horizon(self):
        return self.h * 1e-9

    def issue_after(self, not_before, secs):
        nb = rnd(not_before * 1e9)
        dur = rnd(secs * 1e9)
        self.h = max(self.h, nb) + dur
        return self.h * 1e-9


class Clock:
    """`device::SimClock`: u64-ns accumulator."""

    def __init__(self):
        self.ns = 0

    def now(self):
        return self.ns * 1e-9

    def advance(self, secs):
        self.ns += rnd(secs * 1e9)

    def sync_to(self, sec):
        self.ns = max(self.ns, rnd(sec * 1e9))


def tile_len(tt, n, t):
    return min(t, n - tt * t)


def run_grid_potrf(p, q, tile, n, lookahead):
    """Replicates `potrf_dist_grid`'s charges. lookahead=0 → barrier.

    Returns (makespan_seconds, snapshot or None) where snapshot is a
    list of (dev, compute_h, panel_h, copy_h, busy_s).
    """
    nt = (n + tile - 1) // tile
    ndev = p * q
    dev = lambda r, c: r * q + c
    pipelined = lookahead > 0
    if pipelined:
        compute = [Stream() for _ in range(ndev)]
        panelst = [Stream() for _ in range(ndev)]
        copyst = [Stream() for _ in range(ndev)]
        busy = [0] * ndev
    else:
        clk = [Clock() for _ in range(ndev)]
    colgate = [0.0] * nt
    step_done = [0.0] * nt

    for t in range(nt):
        tk = tile_len(t, n, tile)
        k1 = t * tile + tk
        rt = t % p
        ct = t % q
        diag = dev(rt, ct)

        # 1. potf2 on the diagonal owner.
        nb = colgate[t]
        if t > lookahead:
            nb = max(nb, step_done[t - 1 - lookahead])
        secs = panel_time(flops_potf2(tk))
        potf2_done = 0.0
        if pipelined:
            potf2_done = panelst[diag].issue_after(nb, secs)
            busy[diag] += rnd(secs * 1e9)
        else:
            clk[diag].advance(secs)

        below = n - k1
        if below == 0:
            continue

        seg = [0] * p
        for j in range(t + 1, nt):
            seg[j % p] += tile_len(j, n, tile)
        cols_of = [0] * q
        for k in range(t + 1, nt):
            cols_of[k % q] += tile_len(k, n, tile)

        # 2. L_tt column ring.
        ltt_members = [dev(r, ct) for r in range(p) if r != rt and seg[r] > 0]
        ltt_arrival = [0.0] * ndev
        ltt_bytes = tk * tk * ESIZE
        if ltt_members:
            recv = len(ltt_members)
            for m in ltt_members:
                tcopy = copy_time(ltt_bytes) / recv
                if pipelined:
                    done = copyst[diag].issue_after(potf2_done, tcopy)
                    busy[diag] += rnd(tcopy * 1e9)
                    ltt_arrival[m] = done
                else:
                    clk[diag].advance(tcopy)
                    clk[m].sync_to(clk[diag].now())

        # 3. Panel trsm split across the P row owners.
        trsm_done = [0.0] * p
        for r in range(p):
            if seg[r] == 0:
                continue
            src = dev(r, ct)
            fl = flops_trsm(seg[r], tk, tk)
            secs = panel_time(fl)
            if pipelined:
                arrive = potf2_done if src == diag else ltt_arrival[src]
                trsm_done[r] = panelst[src].issue_after(max(nb, arrive), secs)
                busy[src] += rnd(secs * 1e9)
            else:
                clk[src].advance(secs)

        # 4. Row rings.
        row_arrival = [0.0] * ndev
        for r in range(p):
            if seg[r] == 0:
                continue
            src = dev(r, ct)
            members = [dev(r, c) for c in range(q) if c != ct and cols_of[c] > 0]
            if not members:
                continue
            bytes_ = seg[r] * tk * ESIZE
            recv = len(members)
            for m in members:
                tcopy = copy_time(bytes_) / recv
                if pipelined:
                    done = copyst[src].issue_after(trsm_done[r], tcopy)
                    busy[src] += rnd(tcopy * 1e9)
                    row_arrival[m] = done
                else:
                    clk[src].advance(tcopy)
                    clk[m].sync_to(clk[src].now())

        # 5. Column rings (transposed panel blocks).
        colt_arrival = [0.0] * ndev
        for c in range(q):
            if cols_of[c] == 0:
                continue
            blk = [0] * p
            for k in range(t + 1, nt):
                if k % q == c:
                    blk[k % p] += tile_len(k, n, tile)
            for rs in range(p):
                if blk[rs] == 0:
                    continue
                src = dev(rs, c)
                members = [dev(r, c) for r in range(p) if r != rs and seg[r] > 0]
                if not members:
                    continue
                bytes_ = blk[rs] * tk * ESIZE
                recv = len(members)
                src_ready = trsm_done[rs] if c == ct else row_arrival[src]
                for m in members:
                    tcopy = copy_time(bytes_) / recv
                    if pipelined:
                        done = copyst[src].issue_after(src_ready, tcopy)
                        busy[src] += rnd(tcopy * 1e9)
                        colt_arrival[m] = max(colt_arrival[m], done)
                    else:
                        clk[src].advance(tcopy)
                        clk[m].sync_to(clk[src].now())

        # 6. Fused local trailing GEMMs, split lookahead-first: each
        # device updates its piece of the NEXT panel column (tile
        # column t+1) as its own launch before the rest of its local
        # trailing block, so the next panel factors while the bulk
        # update is still in flight (the classic lookahead split).
        fl_next = [0] * ndev
        fl_rest = [0] * ndev
        for j in range(t + 1, nt):
            r = j % p
            for k in range(t + 1, j + 1):
                f = flops_gemm(tile_len(j, n, tile), tile_len(k, n, tile), tk)
                if k == t + 1:
                    fl_next[dev(r, k % q)] += f
                else:
                    fl_rest[dev(r, k % q)] += f
        next_w = tile_len(t + 1, n, tile)
        cnext = (t + 1) % q
        step_max = 0.0
        for r in range(p):
            for c in range(q):
                d = dev(r, c)
                if fl_next[d] == 0 and fl_rest[d] == 0:
                    continue
                if pipelined:
                    panel_arr = trsm_done[r] if c == ct else row_arrival[d]
                    dep = max(panel_arr, colt_arrival[d])
                if fl_next[d] > 0:
                    util = gemm_util(min(tk, seg[r], next_w))
                    secs = LAUNCH + float(fl_next[d]) / (F64_FLOPS * util)
                    if pipelined:
                        done = compute[d].issue_after(dep, secs)
                        busy[d] += rnd(secs * 1e9)
                        if done > step_max:
                            step_max = done
                        if done > colgate[t + 1]:
                            colgate[t + 1] = done
                    else:
                        clk[d].advance(secs)
                if fl_rest[d] > 0:
                    rest_w = cols_of[c] - (next_w if c == cnext else 0)
                    util = gemm_util(min(tk, seg[r], rest_w))
                    secs = LAUNCH + float(fl_rest[d]) / (F64_FLOPS * util)
                    if pipelined:
                        done = compute[d].issue_after(dep, secs)
                        busy[d] += rnd(secs * 1e9)
                        if done > step_max:
                            step_max = done
                        for k in range(t + 2, nt):
                            if k % q != c:
                                continue
                            touches = any(j % p == r for j in range(k, nt))
                            if touches and done > colgate[k]:
                                colgate[k] = done
                    else:
                        clk[d].advance(secs)
        step_done[t] = step_max

    if pipelined:
        makespan = 0.0
        snap = []
        for d in range(ndev):
            h = max(compute[d].h, panelst[d].h, copyst[d].h) * 1e-9
            makespan = max(makespan, h)
            snap.append((d, compute[d].horizon(), panelst[d].horizon(),
                         copyst[d].horizon(), busy[d] * 1e-9))
        return makespan, snap
    return max(c.now() for c in clk), None


GRID2D = [(2, 2, 4, 32), (2, 2, 8, 64), (2, 4, 8, 128)]


def render():
    out = []
    out.append("# golden grid potrf timelines (µs) — regenerate with UPDATE_GOLDEN=1")
    for (p, q, tile, n) in GRID2D:
        tb, _ = run_grid_potrf(p, q, tile, n, 0)
        tl, snap = run_grid_potrf(p, q, tile, n, 2)
        out.append(f"config p={p} q={q} tile={tile} n={n}")
        out.append(f"  barrier_makespan_us   {tb * 1e6:.3f}")
        out.append(f"  lookahead_makespan_us {tl * 1e6:.3f}")
        for (d, c, pa, cp, b) in snap:
            out.append(
                f"  dev {d} compute {c * 1e6:.3f} panel {pa * 1e6:.3f} "
                f"copy {cp * 1e6:.3f} busy {b * 1e6:.3f}"
            )
    return "\n".join(out) + "\n"


if __name__ == "__main__":
    import sys
    text = render()
    sys.stdout.write(text)
    for (p, q, tile, n) in GRID2D:
        tb, _ = run_grid_potrf(p, q, tile, n, 0)
        tl, _ = run_grid_potrf(p, q, tile, n, 2)
        assert tl < tb, f"lookahead must strictly beat barrier at {(p, q, tile, n)}"
        sys.stderr.write(
            f"(p={p} q={q} tile={tile} n={n}) barrier {tb*1e6:.3f}us "
            f"lookahead {tl*1e6:.3f}us  win {(1 - tl/tb)*100:.1f}%\n"
        )
