#!/usr/bin/env python3
"""Offline generator for `potri_timelines.txt`.

This container has no Rust toolchain, so the golden snapshot of the
distributed inverse's schedule is produced by an exact integer-ns
replication of the simulator's arithmetic: the same H200 cost-model
constants, the same `SimClock`/`Stream` u64-ns state transitions
(`round(seconds * 1e9)` half-away-from-zero), and the same charge
sequence as `solver::potri::potri_dist` (the 1D columnar path) under
both the barrier and pipelined schedules. The factorization is excluded
— the test factors under a barrier context and resets the accounting,
so the snapshot isolates potri's two phases: the trtri column pipelines
(phase 1) and the lauum panel-broadcast rounds (phase 2), plus the
final local write-back of the inverse.

Timing depends only on shapes and model constants — never on matrix
values — so no numerics are replicated here. The charge sequence per
(t, j) of phase 1 is: trsm panel charge on tile j's owner, a p2p of the
solved block to tile t's owner, the tail GEMM on j's owner, and a p2p
tail hand-off to tile j+1's owner. Phase 2 per round ti: the packed
panel rides the owner's copy stream to every other device (fencing
their compute streams), then each tile column's owner runs its GEMM_HN
contraction. The write-back is a same-device copy at local (HBM)
bandwidth.

Regenerate (with a Rust toolchain) via
`UPDATE_GOLDEN=1 cargo test --test golden_timeline`, or (without one)
`python3 gen_potri.py > potri_timelines.txt`.
"""
import math

# ---- GpuCostModel::h200 (f64 dtype) / NodeTopology uniform node ----
F64_FLOPS = 30e12
PANEL_EFF = 0.25
LAUNCH = 8e-6
NVLINK_BW = 450e9
LOCAL_BW = 4.8e12
COPY_LAT = 5e-6
ESIZE = 8  # f64


def rnd(x):
    """Rust `f64::round` (half away from zero) for non-negative x."""
    return int(math.floor(x + 0.5))


def flops_trsm(m, n, tri):
    return int(float(m) * float(n) * float(tri))


def flops_gemm(m, n, k):
    return int(2.0 * float(m) * float(n) * float(k))


def panel_time(fl):
    return LAUNCH + float(fl) / (F64_FLOPS * PANEL_EFF)


def gemm_time(m, n, k):
    d = float(min(m, n, k))
    util = d / (d + 192.0)
    return LAUNCH + float(flops_gemm(m, n, k)) / (F64_FLOPS * util)


def copy_time(bytes_, local=False):
    bw = LOCAL_BW if local else NVLINK_BW
    return COPY_LAT + float(bytes_) / bw


class Stream:
    """`device::Stream`: u64-ns horizon, issue_after = max+add."""

    def __init__(self):
        self.h = 0

    def horizon(self):
        return self.h * 1e-9

    def issue(self, secs):
        self.h += rnd(secs * 1e9)
        return self.h * 1e-9

    def issue_after(self, not_before, secs):
        nb = rnd(not_before * 1e9)
        dur = rnd(secs * 1e9)
        self.h = max(self.h, nb) + dur
        return self.h * 1e-9

    def wait_event(self, sec):
        self.h = max(self.h, rnd(sec * 1e9))


class Clock:
    """`device::SimClock`: u64-ns accumulator."""

    def __init__(self):
        self.ns = 0

    def now(self):
        return self.ns * 1e-9

    def advance(self, secs):
        self.ns += rnd(secs * 1e9)

    def sync_to(self, sec):
        self.ns = max(self.ns, rnd(sec * 1e9))


def tile_len(t, n, tile):
    return min(tile, n - t * tile)


def run_potri(ndev, tile, n, pipelined):
    """Replicates `potri_dist`'s 1D charges, post-factor isolated.

    Returns (makespan_seconds, snapshot or None) where snapshot is a
    list of (dev, compute_h, panel_h, copy_h, busy_s).
    """
    nt = (n + tile - 1) // tile
    owner = lambda t: t % ndev
    if pipelined:
        compute = [Stream() for _ in range(ndev)]
        copyst = [Stream() for _ in range(ndev)]
        busy = [0] * ndev
    else:
        clk = [Clock() for _ in range(ndev)]

    def p2p(src, dst, bytes_):
        """`Ctx::charge_p2p`: sender copy stream gated on its compute
        horizon, receiver compute fenced (barrier: clock advance+sync)."""
        if src == dst or bytes_ == 0:
            return
        t = copy_time(bytes_)
        if pipelined:
            done = copyst[src].issue_after(compute[src].horizon(), t)
            compute[dst].wait_event(done)
            busy[src] += rnd(t * 1e9)
        else:
            clk[src].advance(t)
            clk[dst].sync_to(clk[src].now())

    def kernel(dev, secs):
        """`Ctx::charge_device_time`: compute stream (or the clock)."""
        if pipelined:
            compute[dev].issue(secs)
            busy[dev] += rnd(secs * 1e9)
        else:
            clk[dev].advance(secs)

    def panel_copy(src, dst, bytes_):
        """`Ctx::panel_copy` gated on `device_ready(src)` (the sender's
        compute horizon); barrier is `SimNode::peer_copy`."""
        t = copy_time(bytes_, local=(src == dst))
        if pipelined:
            done = copyst[src].issue_after(compute[src].horizon(), t)
            busy[src] += rnd(t * 1e9)
            compute[dst].wait_event(done)
        else:
            if src == dst:
                clk[src].advance(t)
            else:
                clk[src].advance(t)
                clk[dst].sync_to(clk[src].now())

    # ---- Phase 1: X = L^-1, one pipeline per column tile.
    for t in range(nt):
        tk = tile_len(t, n, tile)
        for j in range(t, nt):
            tj = tile_len(j, n, tile)
            j1 = j * tile + tj
            # trsm of the diagonal block on j's owner.
            kernel(owner(j), panel_time(flops_trsm(tj, tk, tj)))
            # Solved block ships to the column's owner.
            p2p(owner(j), owner(t), tj * tk * ESIZE)
            below = n - j1
            if below > 0:
                # Tail update, then hand the running tail downstream.
                kernel(owner(j), gemm_time(below, tk, tj))
                p2p(owner(j), owner(j + 1), below * tk * ESIZE)

    # ---- Phase 2: A^-1 = X^H * X, panel-broadcast rounds.
    for ti in range(nt):
        tki = tile_len(ti, n, tile)
        pi_rows = n - ti * tile
        panel_bytes = pi_rows * tki * ESIZE
        for d in range(ndev):
            if d == owner(ti):
                continue
            panel_copy(owner(ti), d, panel_bytes)
        for tj in range(nt):
            tkj = tile_len(tj, n, tile)
            kmax = max(ti * tile, tj * tile)
            kernel(owner(tj), gemm_time(tki, tkj, n - kmax))

    # ---- Write the inverse back into `a` (local device copies).
    for d in range(ndev):
        lc = sum(tile_len(t, n, tile) for t in range(nt) if owner(t) == d)
        if lc == 0:
            continue
        panel_copy(d, d, n * lc * ESIZE)

    if pipelined:
        makespan = 0.0
        snap = []
        for d in range(ndev):
            h = max(compute[d].h, copyst[d].h) * 1e-9
            makespan = max(makespan, h)
            # The panel (priority) stream is never used by potri.
            snap.append((d, compute[d].horizon(), 0.0,
                         copyst[d].horizon(), busy[d] * 1e-9))
        return makespan, snap
    return max(c.now() for c in clk), None


GRID = [(4, 4, 32), (4, 8, 64), (8, 8, 128)]


def render():
    out = []
    out.append("# golden potri timelines (µs) — regenerate with UPDATE_GOLDEN=1")
    for (ndev, tile, n) in GRID:
        tb, _ = run_potri(ndev, tile, n, False)
        tl, snap = run_potri(ndev, tile, n, True)
        out.append(f"config ndev={ndev} tile={tile} n={n}")
        out.append(f"  barrier_makespan_us   {tb * 1e6:.3f}")
        out.append(f"  lookahead_makespan_us {tl * 1e6:.3f}")
        for (d, c, pa, cp, b) in snap:
            out.append(
                f"  dev {d} compute {c * 1e6:.3f} panel {pa * 1e6:.3f} "
                f"copy {cp * 1e6:.3f} busy {b * 1e6:.3f}"
            )
    return "\n".join(out) + "\n"


if __name__ == "__main__":
    import sys
    text = render()
    sys.stdout.write(text)
    for (ndev, tile, n) in GRID:
        tb, _ = run_potri(ndev, tile, n, False)
        tl, _ = run_potri(ndev, tile, n, True)
        assert tl < tb, f"pipelined must strictly beat barrier at {(ndev, tile, n)}"
        sys.stderr.write(
            f"(ndev={ndev} tile={tile} n={n}) barrier {tb*1e6:.3f}us "
            f"pipelined {tl*1e6:.3f}us  win {(1 - tl/tb)*100:.1f}%\n"
        )
