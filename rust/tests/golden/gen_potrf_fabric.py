#!/usr/bin/env python3
"""Offline generator for `potrf_fabric_timelines.txt`.

This container has no Rust toolchain, so the golden snapshot of the
grid-native potrf schedule **on a two-tier fabric** is produced by an
exact integer-nanosecond replication of the simulator's arithmetic —
the same methodology as the sibling `gen_potrf2d.py` (validated
byte-for-byte against the committed flat snapshots), extended with the
hierarchical ring-of-rings dispatch of `Ctx::pipelined_group_broadcast`
/ `Ctx::barrier_group_broadcast`:

* a broadcast whose receivers span islands splits into stage B (one
  representative per remote island crosses the inter-node link at full
  contended cost, serialized on the sender's copy stream / clock),
  stage A (the sender's own island takes flat `ring_share_time`
  shares), and stage C (each representative relays island-locally on
  its *own* copy stream / clock, islands fanning out in parallel);
* an island-local broadcast is bitwise the flat single-node
  arithmetic (`NodeTopology::ring_share_time` over NVLink).

The topology is `NodeTopology::two_tier(2, 8)`: NVLink (450 GB/s,
5 µs) within an island, the inter-node fabric (50 GB/s, 10 µs) across.
Timing depends only on shapes and model constants — never on matrix
values — so no numerics are replicated here.

Regenerate (with a Rust toolchain) via
`UPDATE_GOLDEN=1 cargo test --test fabric`, or (without one)
`python3 gen_potrf_fabric.py > potrf_fabric_timelines.txt`.
"""
import math

# ---- GpuCostModel::h200 (f64 dtype) ----
F64_FLOPS = 30e12
PANEL_EFF = 0.25
LAUNCH = 8e-6
ESIZE = 8  # f64

# ---- NodeTopology::two_tier ----
NVLINK_BW = 450e9
COPY_LAT = 5e-6
INTER_BW = 50e9
INTER_LAT = 10e-6

ISLANDS = 2
PER_ISLAND = 8


def island_of(d):
    return d // PER_ISLAND


def link_is_inter(i, j):
    return i != j and island_of(i) != island_of(j)


def contended_time(i, j, bytes_, conc):
    if link_is_inter(i, j):
        return INTER_LAT + float(bytes_) * float(max(conc, 1)) / INTER_BW
    return COPY_LAT + float(bytes_) * float(max(conc, 1)) / NVLINK_BW


def ring_share_time(i, j, bytes_, fanout, conc):
    f = float(max(fanout, 1))
    if link_is_inter(i, j):
        return INTER_LAT / f + float(bytes_) * float(max(conc, 1)) / INTER_BW
    return contended_time(i, j, bytes_, conc) / f


def rnd(x):
    """Rust `f64::round` (half away from zero) for non-negative x."""
    return int(math.floor(x + 0.5))


def flops_potf2(n):
    return int((float(n) ** 3) / 3.0)


def flops_trsm(m, n, tri):
    return int(float(m) * float(n) * float(tri))


def flops_gemm(m, n, k):
    return int(2.0 * float(m) * float(n) * float(k))


def panel_time(fl):
    return LAUNCH + float(fl) / (F64_FLOPS * PANEL_EFF)


def gemm_util(d):
    d = float(d)
    return d / (d + 192.0)


class Stream:
    """`device::Stream`: u64-ns horizon, issue_after = max+add."""

    def __init__(self):
        self.h = 0

    def horizon(self):
        return self.h * 1e-9

    def issue_after(self, not_before, secs):
        nb = rnd(not_before * 1e9)
        dur = rnd(secs * 1e9)
        self.h = max(self.h, nb) + dur
        return self.h * 1e-9


class Clock:
    """`device::SimClock`: u64-ns accumulator."""

    def __init__(self):
        self.ns = 0

    def now(self):
        return self.ns * 1e-9

    def advance(self, secs):
        self.ns += rnd(secs * 1e9)

    def sync_to(self, sec):
        self.ns = max(self.ns, rnd(sec * 1e9))


def hier_split(frm, members):
    """`Ctx::hier_split`: (locals, [(rep, rest)]) or None if island-local."""
    home = island_of(frm)
    locals_, islands, remotes = [], [], []
    for d in members:
        if d == frm:
            continue
        isl = island_of(d)
        if isl == home:
            locals_.append(d)
        else:
            if isl in islands:
                remotes[islands.index(isl)][1].append(d)
            else:
                islands.append(isl)
                remotes.append((d, []))
    if not remotes:
        return None
    return locals_, remotes


def pipelined_ring(copyst, busy, frm, members, bytes_, not_before, conc):
    """`Ctx::pipelined_group_broadcast` (fence-free ring form): returns
    (device, delivery) pairs."""
    receivers = sum(1 for d in members if d != frm)
    if receivers == 0 or bytes_ == 0:
        return []
    arrivals = []
    split = hier_split(frm, members)
    if split is not None:
        locals_, remotes = split
        rep_done = []
        # Stage B: fabric crossings, serialized on the sender.
        for rep, _ in remotes:
            tb = contended_time(frm, rep, bytes_, conc)
            done = copyst[frm].issue_after(not_before, tb)
            busy[frm] += rnd(tb * 1e9)
            arrivals.append((rep, done))
            rep_done.append(done)
        # Stage A: the sender's own island, flat shares.
        for d in locals_:
            ta = ring_share_time(frm, d, bytes_, len(locals_), conc)
            done = copyst[frm].issue_after(not_before, ta)
            busy[frm] += rnd(ta * 1e9)
            arrivals.append((d, done))
        # Stage C: representatives relay island-locally in parallel.
        for (rep, rest), rdone in zip(remotes, rep_done):
            for d in rest:
                tc = ring_share_time(rep, d, bytes_, len(rest), conc)
                done = copyst[rep].issue_after(rdone, tc)
                busy[rep] += rnd(tc * 1e9)
                arrivals.append((d, done))
    else:
        for d in members:
            if d == frm:
                continue
            t = ring_share_time(frm, d, bytes_, receivers, conc)
            done = copyst[frm].issue_after(not_before, t)
            busy[frm] += rnd(t * 1e9)
            arrivals.append((d, done))
    return arrivals


def barrier_ring(clk, frm, members, bytes_, conc):
    """`Ctx::barrier_group_broadcast`: the same dispatch on clocks."""
    receivers = sum(1 for d in members if d != frm)
    if receivers == 0 or bytes_ == 0:
        return
    split = hier_split(frm, members)
    if split is not None:
        locals_, remotes = split
        for rep, _ in remotes:
            clk[frm].advance(contended_time(frm, rep, bytes_, conc))
            clk[rep].sync_to(clk[frm].now())
        for d in locals_:
            clk[frm].advance(ring_share_time(frm, d, bytes_, len(locals_), conc))
            clk[d].sync_to(clk[frm].now())
        for rep, rest in remotes:
            for d in rest:
                clk[rep].advance(ring_share_time(rep, d, bytes_, len(rest), conc))
                clk[d].sync_to(clk[rep].now())
    else:
        for d in members:
            if d == frm:
                continue
            clk[frm].advance(ring_share_time(frm, d, bytes_, receivers, conc))
            clk[d].sync_to(clk[frm].now())


def tile_len(tt, n, t):
    return min(t, n - tt * t)


def run_grid_potrf(p, q, tile, n, lookahead):
    """Replicates `potrf_dist_grid`'s charges on the 2×8 fabric.
    lookahead=0 → barrier. Returns (makespan_seconds, snapshot or None)."""
    nt = (n + tile - 1) // tile
    ndev = p * q
    assert ndev == ISLANDS * PER_ISLAND
    dev = lambda r, c: r * q + c
    pipelined = lookahead > 0
    if pipelined:
        compute = [Stream() for _ in range(ndev)]
        panelst = [Stream() for _ in range(ndev)]
        copyst = [Stream() for _ in range(ndev)]
        busy = [0] * ndev
    else:
        clk = [Clock() for _ in range(ndev)]
    colgate = [0.0] * nt
    step_done = [0.0] * nt

    for t in range(nt):
        tk = tile_len(t, n, tile)
        k1 = t * tile + tk
        rt = t % p
        ct = t % q
        diag = dev(rt, ct)

        # 1. potf2 on the diagonal owner.
        nb = colgate[t]
        if t > lookahead:
            nb = max(nb, step_done[t - 1 - lookahead])
        secs = panel_time(flops_potf2(tk))
        potf2_done = 0.0
        if pipelined:
            potf2_done = panelst[diag].issue_after(nb, secs)
            busy[diag] += rnd(secs * 1e9)
        else:
            clk[diag].advance(secs)

        below = n - k1
        if below == 0:
            continue

        seg = [0] * p
        for j in range(t + 1, nt):
            seg[j % p] += tile_len(j, n, tile)
        cols_of = [0] * q
        for k in range(t + 1, nt):
            cols_of[k % q] += tile_len(k, n, tile)

        # 2. L_tt column ring (hierarchical when column ct spans islands).
        ltt_members = [dev(r, ct) for r in range(p) if r != rt and seg[r] > 0]
        ltt_arrival = [0.0] * ndev
        ltt_bytes = tk * tk * ESIZE
        if ltt_members:
            if pipelined:
                for m, done in pipelined_ring(copyst, busy, diag, ltt_members,
                                              ltt_bytes, potf2_done, 1):
                    ltt_arrival[m] = done
            else:
                barrier_ring(clk, diag, ltt_members, ltt_bytes, 1)

        # 3. Panel trsm split across the P row owners.
        trsm_done = [0.0] * p
        for r in range(p):
            if seg[r] == 0:
                continue
            src = dev(r, ct)
            fl = flops_trsm(seg[r], tk, tk)
            secs = panel_time(fl)
            if pipelined:
                arrive = potf2_done if src == diag else ltt_arrival[src]
                trsm_done[r] = panelst[src].issue_after(max(nb, arrive), secs)
                busy[src] += rnd(secs * 1e9)
            else:
                clk[src].advance(secs)

        # 4. Row rings (island-local when q divides the island width).
        row_arrival = [0.0] * ndev
        for r in range(p):
            if seg[r] == 0:
                continue
            src = dev(r, ct)
            members = [dev(r, c) for c in range(q) if c != ct and cols_of[c] > 0]
            if not members:
                continue
            bytes_ = seg[r] * tk * ESIZE
            if pipelined:
                for m, done in pipelined_ring(copyst, busy, src, members,
                                              bytes_, trsm_done[r], 1):
                    row_arrival[m] = done
            else:
                barrier_ring(clk, src, members, bytes_, 1)

        # 5. Column rings with the per-link contention share.
        colt_arrival = [0.0] * ndev
        for c in range(q):
            if cols_of[c] == 0:
                continue
            blk = [0] * p
            for k in range(t + 1, nt):
                if k % q == c:
                    blk[k % p] += tile_len(k, n, tile)
            conc = sum(1 for b in blk if b > 0)
            for rs in range(p):
                if blk[rs] == 0:
                    continue
                src = dev(rs, c)
                members = [dev(r, c) for r in range(p) if r != rs and seg[r] > 0]
                if not members:
                    continue
                bytes_ = blk[rs] * tk * ESIZE
                if pipelined:
                    src_ready = trsm_done[rs] if c == ct else row_arrival[src]
                    for m, done in pipelined_ring(copyst, busy, src, members,
                                                  bytes_, src_ready, conc):
                        colt_arrival[m] = max(colt_arrival[m], done)
                else:
                    barrier_ring(clk, src, members, bytes_, conc)

        # 6. Fused local trailing GEMMs, split lookahead-first.
        fl_next = [0] * ndev
        fl_rest = [0] * ndev
        for j in range(t + 1, nt):
            r = j % p
            for k in range(t + 1, j + 1):
                f = flops_gemm(tile_len(j, n, tile), tile_len(k, n, tile), tk)
                if k == t + 1:
                    fl_next[dev(r, k % q)] += f
                else:
                    fl_rest[dev(r, k % q)] += f
        next_w = tile_len(t + 1, n, tile)
        cnext = (t + 1) % q
        step_max = 0.0
        for r in range(p):
            for c in range(q):
                d = dev(r, c)
                if fl_next[d] == 0 and fl_rest[d] == 0:
                    continue
                if pipelined:
                    panel_arr = trsm_done[r] if c == ct else row_arrival[d]
                    dep = max(panel_arr, colt_arrival[d])
                if fl_next[d] > 0:
                    util = gemm_util(min(tk, seg[r], next_w))
                    secs = LAUNCH + float(fl_next[d]) / (F64_FLOPS * util)
                    if pipelined:
                        done = compute[d].issue_after(dep, secs)
                        busy[d] += rnd(secs * 1e9)
                        if done > step_max:
                            step_max = done
                        if done > colgate[t + 1]:
                            colgate[t + 1] = done
                    else:
                        clk[d].advance(secs)
                if fl_rest[d] > 0:
                    rest_w = cols_of[c] - (next_w if c == cnext else 0)
                    util = gemm_util(min(tk, seg[r], rest_w))
                    secs = LAUNCH + float(fl_rest[d]) / (F64_FLOPS * util)
                    if pipelined:
                        done = compute[d].issue_after(dep, secs)
                        busy[d] += rnd(secs * 1e9)
                        if done > step_max:
                            step_max = done
                        for k in range(t + 2, nt):
                            if k % q != c:
                                continue
                            touches = any(j % p == r for j in range(k, nt))
                            if touches and done > colgate[k]:
                                colgate[k] = done
                    else:
                        clk[d].advance(secs)
        step_done[t] = step_max

    if pipelined:
        makespan = 0.0
        snap = []
        for d in range(ndev):
            h = max(compute[d].h, panelst[d].h, copyst[d].h) * 1e-9
            makespan = max(makespan, h)
            snap.append((d, compute[d].horizon(), panelst[d].horizon(),
                         copyst[d].horizon(), busy[d] * 1e-9))
        return makespan, snap
    return max(c.now() for c in clk), None


# (p, q, tile, n) on the 2×8 fabric — p·q = 16 always.
GRID_FAB = [(2, 8, 4, 64), (4, 4, 4, 64), (4, 4, 8, 128)]


def render():
    out = []
    out.append("# golden fabric potrf timelines (µs, 2x8 two-tier fabric) — "
               "regenerate with UPDATE_GOLDEN=1")
    for (p, q, tile, n) in GRID_FAB:
        tb, _ = run_grid_potrf(p, q, tile, n, 0)
        tl, snap = run_grid_potrf(p, q, tile, n, 2)
        out.append(f"config islands={ISLANDS} per_island={PER_ISLAND} "
                   f"p={p} q={q} tile={tile} n={n}")
        out.append(f"  barrier_makespan_us   {tb * 1e6:.3f}")
        out.append(f"  lookahead_makespan_us {tl * 1e6:.3f}")
        for (d, c, pa, cp, b) in snap:
            out.append(
                f"  dev {d} compute {c * 1e6:.3f} panel {pa * 1e6:.3f} "
                f"copy {cp * 1e6:.3f} busy {b * 1e6:.3f}"
            )
    return "\n".join(out) + "\n"


if __name__ == "__main__":
    import sys
    text = render()
    sys.stdout.write(text)
    for (p, q, tile, n) in GRID_FAB:
        tb, _ = run_grid_potrf(p, q, tile, n, 0)
        tl, _ = run_grid_potrf(p, q, tile, n, 2)
        assert tl < tb, f"lookahead must strictly beat barrier at {(p, q, tile, n)}"
        sys.stderr.write(
            f"(p={p} q={q} tile={tile} n={n}) barrier {tb*1e6:.3f}us "
            f"lookahead {tl*1e6:.3f}us  win {(1 - tl/tb)*100:.1f}%\n"
        )
