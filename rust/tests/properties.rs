//! Property-based tests over randomized configurations.
//!
//! The vendored crate set has no proptest, so this file carries a small
//! deterministic harness: every property runs `CASES` seeded trials and
//! reports the failing seed, which reproduces the case exactly.

use jaxmg::coordinator::{Footprint, SolveService};
use jaxmg::costmodel::{workspace, GpuCostModel};
use jaxmg::device::SimNode;
use jaxmg::ipc::{AddressSpace, IpcRegistry};
use jaxmg::layout::{
    cycle_decomposition, permutation_between, BlockCyclic1D, ColumnLayout, ContiguousBlock,
    Redistributor,
};
use jaxmg::linalg::{self, tol_for, FrobNorm, Matrix};
use jaxmg::rng::Rng;
use jaxmg::scalar::{c64, DType, Scalar};
use jaxmg::solver::{potrf_dist, potrs_dist, syevd_dist, Ctx, SolverBackend};
use jaxmg::tile::{DistMatrix, Layout1D};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

const CASES: u64 = 40;

/// Run `f` over `CASES` seeded trials, labelling failures with the seed.
fn for_all(name: &str, f: impl Fn(&mut Rng)) {
    for seed in 0..CASES {
        let mut rng = Rng::new(0xA5A5_0000 + seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            panic!("property {name:?} failed at seed {seed}: {e:?}");
        }
    }
}

#[test]
fn prop_block_cyclic_is_bijection() {
    for_all("block_cyclic_bijection", |rng| {
        let n = rng.range(1, 200);
        let t = rng.range(1, 32);
        let d = rng.range(1, 9);
        let l = BlockCyclic1D::new(n, t, d).unwrap();
        let mut seen = vec![false; n];
        for dev in 0..d {
            for loc in 0..l.local_cols(dev) {
                let g = l.global_index(dev, loc);
                assert!(!seen[g]);
                seen[g] = true;
                assert_eq!(l.place(g), (dev, loc));
            }
        }
        assert!(seen.iter().all(|&b| b));
    });
}

#[test]
fn prop_cycle_decomposition_partitions_slots() {
    for_all("cycles_partition", |rng| {
        let n = rng.range(1, 120);
        let perm = rng.permutation(n);
        let cycles = cycle_decomposition(&perm);
        let mut count = vec![0usize; n];
        for c in &cycles {
            // Rotating along the cycle must follow the permutation.
            for w in 0..c.slots.len() {
                let from = c.slots[w];
                let to = c.slots[(w + 1) % c.slots.len()];
                assert_eq!(perm[from], to);
            }
            for &s in &c.slots {
                count[s] += 1;
            }
        }
        assert!(count.iter().all(|&c| c == 1), "cycles must partition the slots");
    });
}

#[test]
fn prop_layout_permutation_sends_columns_home() {
    for_all("perm_sends_home", |rng| {
        let d = rng.range(1, 8);
        let t = rng.range(1, 16);
        let n = t * d * rng.range(1, 6); // balanced so in-place applies
        let src = ContiguousBlock::new(n, d).unwrap();
        let dst = BlockCyclic1D::new(n, t, d).unwrap();
        let perm = permutation_between(&src, &dst).unwrap();
        for g in 0..n {
            let (sd, sl) = src.place(g);
            let to = perm[src.slot_of(sd, sl)];
            let (dd, dl) = dst.slot_to_place(to);
            assert_eq!(dst.global_index(dd, dl), g);
        }
    });
}

#[test]
fn prop_redistribution_roundtrip_preserves_content() {
    for_all("redist_roundtrip", |rng| {
        let d = rng.range(1, 6);
        let t = rng.range(1, 10);
        let n = rng.range(1, 12) * t.max(1) * d; // mostly balanced
        let n = if rng.next_below(4) == 0 { n + rng.range(1, 5) } else { n }; // sometimes ragged
        let rows = rng.range(1, 12);
        let node = SimNode::new_uniform(d, 1 << 26);
        let a = Matrix::<f64>::random(rows, n, rng.next_u64());
        let contig = Layout1D::Contiguous(ContiguousBlock::new(n, d).unwrap());
        let cyclic = Layout1D::BlockCyclic(BlockCyclic1D::new(n, t, d).unwrap());
        let mut dm = DistMatrix::scatter(&node, &a, contig).unwrap();
        Redistributor::convert(&mut dm, cyclic).unwrap();
        assert_eq!(dm.gather().unwrap(), a, "forward conversion corrupted data");
        Redistributor::convert(&mut dm, contig).unwrap();
        assert_eq!(dm.gather().unwrap(), a, "inverse conversion corrupted data");
    });
}

#[test]
fn prop_redistribution_no_leaks() {
    for_all("redist_no_leak", |rng| {
        let d = rng.range(2, 5);
        let t = rng.range(1, 6);
        let n = t * d * rng.range(1, 4);
        let node = SimNode::new_uniform(d, 1 << 24);
        let a = Matrix::<f32>::random(4, n, rng.next_u64());
        let contig = Layout1D::Contiguous(ContiguousBlock::new(n, d).unwrap());
        let cyclic = Layout1D::BlockCyclic(BlockCyclic1D::new(n, t, d).unwrap());
        let mut dm = DistMatrix::scatter(&node, &a, contig).unwrap();
        let before: usize = node.memory_reports().iter().map(|r| r.used).sum();
        Redistributor::convert(&mut dm, cyclic).unwrap();
        let after: usize = node.memory_reports().iter().map(|r| r.used).sum();
        assert_eq!(before, after, "staging buffers must be freed");
    });
}

#[test]
fn prop_potrf_potrs_random_configs() {
    for_all("potrf_potrs", |rng| {
        let d = rng.range(1, 5);
        let t = rng.range(1, 8);
        let n = rng.range(2, 40);
        let nrhs = rng.range(1, 4);
        let node = SimNode::new_uniform(d, 1 << 26);
        let model = GpuCostModel::h200();
        let backend = SolverBackend::<f64>::Native;
        let ctx = Ctx::new(&node, &model, &backend);
        let a = Matrix::<f64>::spd_random(n, rng.next_u64());
        let x_true = Matrix::<f64>::random(n, nrhs, rng.next_u64());
        let b = a.matmul(&x_true);
        let lay = Layout1D::BlockCyclic(BlockCyclic1D::new(n, t, d).unwrap());
        let mut dm = DistMatrix::scatter(&node, &a, lay).unwrap();
        potrf_dist(&ctx, &mut dm).unwrap();
        let x = potrs_dist(&ctx, &dm, &b).unwrap();
        assert!(
            x.rel_err(&x_true) < tol_for::<f64>(n) * 20.0,
            "potrs residual too large: {} (n={n} t={t} d={d})",
            x.rel_err(&x_true)
        );
    });
}

#[test]
fn prop_syevd_eigen_identity() {
    for_all("syevd_identity", |rng| {
        let d = rng.range(1, 4);
        let t = rng.range(1, 6);
        let n = rng.range(2, 24);
        let node = SimNode::new_uniform(d, 1 << 26);
        let model = GpuCostModel::h200();
        let backend = SolverBackend::<c64>::Native;
        let ctx = Ctx::new(&node, &model, &backend);
        let a = Matrix::<c64>::hermitian_random(n, rng.next_u64());
        let lay = Layout1D::BlockCyclic(BlockCyclic1D::new(n, t, d).unwrap());
        let mut dm = DistMatrix::scatter(&node, &a, lay).unwrap();
        let vals = syevd_dist(&ctx, &mut dm).unwrap();
        let v = dm.gather().unwrap();
        // Residual ‖A·V − V·Λ‖ / ‖V·Λ‖.
        let av = a.matmul(&v);
        let mut vl = v.clone();
        for j in 0..n {
            let lam = c64::from_real(vals[j]);
            for i in 0..n {
                let x = vl[(i, j)] * lam;
                vl[(i, j)] = x;
            }
        }
        assert!(
            av.rel_err(&vl) < tol_for::<c64>(n) * 50.0,
            "eigen residual {} (n={n} t={t} d={d})",
            av.rel_err(&vl)
        );
        // Values must be sorted ascending.
        for k in 1..n {
            assert!(vals[k - 1] <= vals[k] + 1e-12);
        }
    });
}

#[test]
fn prop_potrf_matches_host_reference() {
    for_all("potrf_vs_host", |rng| {
        let d = rng.range(1, 5);
        let t = rng.range(1, 8);
        let n = rng.range(1, 32);
        let node = SimNode::new_uniform(d, 1 << 26);
        let model = GpuCostModel::h200();
        let backend = SolverBackend::<f32>::Native;
        let ctx = Ctx::new(&node, &model, &backend);
        let a = Matrix::<f32>::spd_random(n, rng.next_u64());
        let lay = Layout1D::BlockCyclic(BlockCyclic1D::new(n, t, d).unwrap());
        let mut dm = DistMatrix::scatter(&node, &a, lay).unwrap();
        potrf_dist(&ctx, &mut dm).unwrap();
        let l = dm.gather().unwrap();
        let l_ref = linalg::potrf(&a).unwrap();
        assert!(l.rel_err(&l_ref) < tol_for::<f32>(n) * 10.0);
    });
}

#[test]
fn prop_workspace_monotone() {
    for_all("workspace_monotone", |rng| {
        let n = rng.range(64, 1 << 14);
        let t = rng.range(1, 1024);
        let d = rng.range(1, 16);
        for dt in [DType::F32, DType::F64, DType::C64, DType::C128] {
            // More devices → smaller per-device footprint.
            assert!(
                workspace::potrs_bytes(n, 1, t, d, dt) >= workspace::potrs_bytes(n, 1, t, d + 1, dt)
            );
            // Bigger matrix → bigger footprint.
            assert!(workspace::syevd_bytes(n + 64, t, d, dt) >= workspace::syevd_bytes(n, t, d, dt));
            // potri and syevd always need more than potrs (paper §3).
            assert!(workspace::potri_bytes(n, t, d, dt) > workspace::potrs_bytes(n, 1, t, d, dt));
        }
    });
}

#[test]
fn prop_ipc_registry_never_leaks_across_spaces() {
    for_all("ipc_lifecycle", |rng| {
        let reg = IpcRegistry::new();
        let exporter = AddressSpace(rng.range(0, 7));
        let ptr = jaxmg::device::DevPtr {
            device: rng.range(0, 7),
            alloc_id: rng.next_u64().max(1),
            offset: 0,
        };
        let h = reg.export(exporter, ptr).unwrap();
        // Exporter can never open its own handle.
        assert!(reg.open(exporter, h).is_err());
        // Any other space can, exactly once.
        let other = AddressSpace(exporter.0 + 1);
        let opened = reg.open(other, h).unwrap();
        assert_eq!(opened, ptr);
        assert!(reg.open(other, h).is_err());
        // After close, reopen succeeds.
        reg.close(other, h).unwrap();
        assert!(reg.open(other, h).is_ok());
        // After revoke, nothing opens.
        reg.revoke(exporter, h).unwrap();
        assert!(reg.open(AddressSpace(exporter.0 + 2), h).is_err());
    });
}

/// One pipelined potrs solve on `node` (shared or fresh): returns the
/// gathered factor and the solution, both bitwise-deterministic in
/// `(n, tile, nrhs, seed)` and independent of node state.
fn one_solve<S: Scalar>(
    node: &SimNode,
    n: usize,
    tile: usize,
    nrhs: usize,
    seed: u64,
) -> (Matrix<S>, Matrix<S>) {
    let ndev = node.num_devices();
    let model = GpuCostModel::h200();
    let backend = SolverBackend::<S>::Native;
    let ctx = Ctx::pipelined(node, &model, &backend);
    let a = Matrix::<S>::spd_random(n, seed);
    let x_true = Matrix::<S>::random(n, nrhs, seed + 1);
    let b = a.matmul(&x_true);
    let lay = Layout1D::BlockCyclic(BlockCyclic1D::new(n, tile, ndev).unwrap());
    let mut dm = DistMatrix::scatter(node, &a, lay).unwrap();
    potrf_dist(&ctx, &mut dm).unwrap();
    let x = potrs_dist(&ctx, &dm, &b).unwrap();
    let l = dm.gather().unwrap();
    dm.free().unwrap();
    (l, x)
}

#[test]
fn prop_concurrent_service_solves_match_serial_bitwise() {
    // Random mixes of solve sizes/dtypes admitted concurrently must
    // produce results identical to the same solves run serially.
    for_all("service_concurrent_vs_serial", |rng| {
        let ndev = rng.range(2, 4);
        let vram = 1usize << 26;
        let node = SimNode::new_uniform(ndev, vram);
        let svc = SolveService::new(node.clone(), 3);
        let k = rng.range(3, 5);
        let configs: Vec<(usize, usize, usize, u64, bool)> = (0..k)
            .map(|_| {
                (
                    rng.range(4, 28),
                    rng.range(1, 6),
                    rng.range(1, 3),
                    rng.next_u64() >> 1, // headroom for seed+1
                    rng.next_below(2) == 0,
                )
            })
            .collect();
        let mut f64_handles = Vec::new();
        let mut c64_handles = Vec::new();
        for &(n, tile, nrhs, seed, is_f64) in &configs {
            let dtype = if is_f64 { DType::F64 } else { DType::C128 };
            let fp = Footprint::for_routine("potrs", n, nrhs, tile, ndev, dtype).unwrap();
            let node2 = node.clone();
            if is_f64 {
                f64_handles.push((
                    n,
                    svc.submit(fp, move || one_solve::<f64>(&node2, n, tile, nrhs, seed)).unwrap(),
                ));
            } else {
                c64_handles.push((
                    n,
                    svc.submit(fp, move || one_solve::<c64>(&node2, n, tile, nrhs, seed)).unwrap(),
                ));
            }
        }
        svc.drain();
        // Serial reference on a fresh node, same configs in order.
        let serial = SimNode::new_uniform(ndev, vram);
        let mut f64_it = f64_handles.into_iter();
        let mut c64_it = c64_handles.into_iter();
        for &(n, tile, nrhs, seed, is_f64) in &configs {
            if is_f64 {
                let (_, h) = f64_it.next().unwrap();
                let ((l, x), _stats) = h.wait();
                let (l_ref, x_ref) = one_solve::<f64>(&serial, n, tile, nrhs, seed);
                assert_eq!(l.as_slice(), l_ref.as_slice(), "factor diverged (n={n})");
                assert_eq!(x.as_slice(), x_ref.as_slice(), "solution diverged (n={n})");
            } else {
                let (_, h) = c64_it.next().unwrap();
                let ((l, x), _stats) = h.wait();
                let (l_ref, x_ref) = one_solve::<c64>(&serial, n, tile, nrhs, seed);
                assert_eq!(l.as_slice(), l_ref.as_slice(), "c128 factor diverged (n={n})");
                assert_eq!(x.as_slice(), x_ref.as_slice(), "c128 solution diverged (n={n})");
            }
        }
        // Nothing leaked on the shared node.
        for rep in node.memory_reports() {
            assert_eq!(rep.used, 0, "service solves leaked device memory");
        }
    });
}

#[test]
fn prop_service_capacity_accountant_never_overadmits() {
    // The admission accountant must never reserve past SimNode
    // capacity, whatever the random footprint mix, and every
    // admissible solve must complete.
    for_all("service_capacity_accountant", |rng| {
        let ndev = rng.range(1, 4);
        let cap = rng.range(1024, 8192);
        let node = SimNode::new_uniform(ndev, cap);
        let svc = SolveService::new(node, rng.range(1, 4));
        let jobs = rng.range(2, 8);
        let cur = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        let mut max_fp = 0usize;
        for _ in 0..jobs {
            let bytes = rng.range(1, cap);
            max_fp = max_fp.max(bytes);
            let cur = cur.clone();
            handles.push((
                bytes,
                svc.submit(Footprint::uniform(ndev, bytes), move || {
                    cur.fetch_add(1, Ordering::SeqCst);
                    std::thread::sleep(Duration::from_millis(1));
                    cur.fetch_sub(1, Ordering::SeqCst);
                })
                .unwrap(),
            ));
        }
        for (_, h) in handles {
            h.wait();
        }
        for (d, &pk) in svc.peak_reserved().iter().enumerate() {
            assert!(pk <= cap, "device {d} over-admitted: reserved {pk} of {cap}");
            assert!(pk >= max_fp, "largest admitted footprint must show in the peak");
        }
        assert_eq!(svc.pending(), 0);
        assert_eq!(svc.in_flight(), 0);
        assert_eq!(svc.reserved(), vec![0; ndev]);
    });
}

#[test]
fn service_runs_two_solves_in_flight_with_serial_identical_results() {
    // Acceptance: >= 2 simultaneous in-flight solves, results bitwise
    // equal to serial execution.
    let ndev = 4;
    let vram = 1usize << 26;
    let node = SimNode::new_uniform(ndev, vram);
    let svc = SolveService::new(node.clone(), 4);
    let cur = Arc::new(AtomicUsize::new(0));
    let peak = Arc::new(AtomicUsize::new(0));
    let configs = [(24usize, 4usize, 1usize, 900u64), (28, 4, 2, 901), (20, 2, 1, 902), (24, 3, 2, 903)];
    let handles: Vec<_> = configs
        .iter()
        .map(|&(n, tile, nrhs, seed)| {
            let node2 = node.clone();
            let cur = cur.clone();
            let peak = peak.clone();
            let fp = Footprint::for_routine("potrs", n, nrhs, tile, ndev, DType::F64).unwrap();
            svc.submit(fp, move || {
                let now = cur.fetch_add(1, Ordering::SeqCst) + 1;
                peak.fetch_max(now, Ordering::SeqCst);
                // Hold the in-flight window open long enough for the
                // other workers to join it.
                std::thread::sleep(Duration::from_millis(30));
                let out = one_solve::<f64>(&node2, n, tile, nrhs, seed);
                cur.fetch_sub(1, Ordering::SeqCst);
                out
            })
            .unwrap()
        })
        .collect();
    let results: Vec<_> = handles.into_iter().map(|h| h.wait()).collect();
    assert!(
        peak.load(Ordering::SeqCst) >= 2,
        "expected >= 2 simultaneous in-flight solves, saw {}",
        peak.load(Ordering::SeqCst)
    );
    // Per-solve metrics came back on the cost-model clock (the solve
    // charges sim time; the wall sleep above must NOT leak into it),
    // and the aggregate counters moved.
    for (_, stats) in &results {
        assert!(stats.exec_ns > 0, "cost-model exec time must be charged");
        assert!(stats.exec_secs() > 0.0);
    }
    let m = node.metrics().snapshot();
    assert_eq!(m.service_completed, configs.len() as u64);
    assert!(m.service_exec_ns > 0);
    // Bitwise-identical to the same solves run serially on a fresh node.
    let serial = SimNode::new_uniform(ndev, vram);
    for (i, &(n, tile, nrhs, seed)) in configs.iter().enumerate() {
        let (l_ref, x_ref) = one_solve::<f64>(&serial, n, tile, nrhs, seed);
        let ((l, x), _) = &results[i];
        assert_eq!(l.as_slice(), l_ref.as_slice(), "factor {i} diverged");
        assert_eq!(x.as_slice(), x_ref.as_slice(), "solution {i} diverged");
    }
}

#[test]
fn prop_peer_copy_data_integrity() {
    for_all("peer_copy_integrity", |rng| {
        let d = rng.range(2, 6);
        let node = SimNode::new_uniform(d, 1 << 20);
        let len = rng.range(1, 256);
        let src_dev = rng.range(0, d - 1);
        let dst_dev = rng.range(0, d - 1);
        let a = node.alloc_scalars::<f64>(src_dev, len).unwrap();
        let b = node.alloc_scalars::<f64>(dst_dev, len).unwrap();
        let mut data = vec![0.0f64; len];
        rng.fill(&mut data);
        node.write_slice(a, 0, &data).unwrap();
        node.peer_copy(a, 0, b, 0, len * 8).unwrap();
        let mut out = vec![0.0f64; len];
        node.read_slice(b, 0, &mut out).unwrap();
        assert_eq!(data, out);
    });
}
