//! Acceptance tests for the mixed-precision tier.
//!
//! Pins the issue's acceptance criteria end to end:
//! * refined residuals meet the requested tolerance for both
//!   f64→f32 and c128→c64 across the 1D, 2×2-grid and two-tier
//!   fabric layouts;
//! * mixed results are bitwise deterministic across schedules
//!   (barrier vs lookahead) and across fabric vs flat nodes;
//! * a stalled refinement falls back typed to the full-precision
//!   path and still returns a correct answer — through the raw
//!   solver entry point and through both serving fronts, with zero
//!   lost requests;
//! * the cost-model router picks Mixed when the replay says it wins
//!   and the serving fronts then execute genuinely mixed (metrics
//!   move, refinement histogram fills);
//! * the factor cache keys mixed factors under the *working* dtype —
//!   repeat solves hit, and a fallback never seeds the cache.
//!
//! The router's crossover under the real H200 constants sits far
//! above test-sized systems (launch overhead dominates small n), so
//! the serving-front tests run a slowed clone of the cost model —
//! flop rates cut by 1e5 with the f64:f32 ratio preserved — which
//! moves the crossover below n ≈ 100 without touching numerics.

use jaxmg::coordinator::{DistRoutine, Slo, SmallConfig, SolveService};
use jaxmg::costmodel::GpuCostModel;
use jaxmg::device::SimNode;
use jaxmg::fabric::Fabric;
use jaxmg::layout::{BlockCyclic1D, BlockCyclic2D};
use jaxmg::linalg::Matrix;
use jaxmg::scalar::{c64, DType, Scalar};
use jaxmg::serve::{MpmdConfig, MpmdService};
use jaxmg::solver::{
    solve_dist_prec, MixedRun, PipelineConfig, Precision, RefineOptions, DEFAULT_REFINE_CAP,
};
use jaxmg::tile::LayoutKind;

/// ‖b − A·x‖_F / ‖b‖_F — the same residual the refinement loop
/// reports, recomputed independently from the returned iterate.
fn rel_residual<S: Scalar>(a: &Matrix<S>, x: &Matrix<S>, b: &Matrix<S>) -> f64 {
    b.sub(&a.matmul(x)).norm_fro() / b.norm_fro()
}

/// H200 with the flop rates slowed 1e5× (ratio preserved): compute
/// dominates launch overhead at test sizes, so the router's replay
/// sees the same Mixed-wins shape it sees at n ≥ 16384 for real.
fn slow_model() -> GpuCostModel {
    let mut m = GpuCostModel::h200();
    m.f64_flops /= 1e5;
    m.f32_flops /= 1e5;
    m
}

fn lay1d(n: usize, tile: usize, ndev: usize) -> LayoutKind {
    LayoutKind::BlockCyclic(BlockCyclic1D::new(n, tile, ndev).unwrap())
}

fn grid2d(n: usize, tile: usize, p: usize, q: usize) -> LayoutKind {
    LayoutKind::Grid(BlockCyclic2D::new(n, n, tile, tile, p, q).unwrap())
}

/// Forced-Mixed solve on `node`/`layout`; asserts convergence at
/// `tol` and returns the verified solution.
fn converge_on<S: jaxmg::solver::MixedCapable>(
    node: &SimNode,
    layout: LayoutKind,
    working: DType,
    seed: u64,
    cond: f64,
    tol: f64,
) -> Matrix<S> {
    let model = GpuCostModel::h200();
    let n = 67; // ragged: 67 % 4 != 0 exercises edge tiles
    let a = Matrix::<S>::spd_random_cond(n, seed, cond);
    let b = Matrix::<S>::random(n, 2, seed + 100);
    let run = MixedRun::new(node, &model, PipelineConfig::barrier(), layout);
    let opts = RefineOptions { tol, max_iters: DEFAULT_REFINE_CAP };
    let (x, out) =
        solve_dist_prec::<S>(&run, Precision::Mixed(working), &a, &b, opts).unwrap();
    assert!(out.mixed, "refinement should converge at cond {cond}");
    assert!(!out.fell_back);
    assert!(
        out.report.residual <= tol,
        "reported residual {} > tol {tol}",
        out.report.residual
    );
    assert!(out.report.iters >= 1, "a working-dtype factor cannot meet {tol} unrefined");
    assert!(out.report.bytes_saved > 0);
    let res = rel_residual(&a, &x, &b);
    assert!(res <= tol, "independent residual {res} > tol {tol} ({:?})", S::DTYPE);
    x
}

#[test]
fn mixed_residual_meets_tolerance_f64_all_layouts() {
    let node = SimNode::new_uniform(4, 1 << 26);
    converge_on::<f64>(&node, lay1d(67, 4, 4), DType::F32, 0xA1, 1e3, 1e-10);
    converge_on::<f64>(&node, grid2d(67, 4, 2, 2), DType::F32, 0xA2, 1e3, 1e-10);
    let fab = Fabric::h200(2); // 2 islands × 8 devices
    converge_on::<f64>(fab.node(), lay1d(67, 4, 16), DType::F32, 0xA3, 1e3, 1e-10);
    converge_on::<f64>(fab.node(), grid2d(67, 4, 2, 8), DType::F32, 0xA4, 1e3, 1e-10);
    assert!(node.metrics().snapshot().mixed_solves >= 2);
}

#[test]
fn mixed_residual_meets_tolerance_c128_all_layouts() {
    let node = SimNode::new_uniform(4, 1 << 26);
    converge_on::<c64>(&node, lay1d(67, 4, 4), DType::C64, 0xB1, 1e2, 1e-9);
    converge_on::<c64>(&node, grid2d(67, 4, 2, 2), DType::C64, 0xB2, 1e2, 1e-9);
    let fab = Fabric::h200(2);
    converge_on::<c64>(fab.node(), lay1d(67, 4, 16), DType::C64, 0xB3, 1e2, 1e-9);
    converge_on::<c64>(fab.node(), grid2d(67, 4, 2, 8), DType::C64, 0xB4, 1e2, 1e-9);
}

/// The refinement loop is host-side and schedule-independent: the
/// same request solved under barrier and lookahead scheduling, and on
/// a fabric vs a flat node, is bitwise one answer.
#[test]
fn mixed_solution_is_bitwise_identical_across_schedules_and_fabric() {
    let n = 67;
    let a = Matrix::<f64>::spd_random_cond(n, 0xC1, 1e3);
    let b = Matrix::<f64>::random(n, 2, 0xC2);
    let model = GpuCostModel::h200();
    let opts = RefineOptions { tol: 1e-10, max_iters: DEFAULT_REFINE_CAP };
    let solve = |node: &SimNode, ndev: usize, cfg: PipelineConfig| -> Vec<f64> {
        let run = MixedRun::new(node, &model, cfg, lay1d(n, 4, ndev));
        let (x, out) =
            solve_dist_prec::<f64>(&run, Precision::Mixed(DType::F32), &a, &b, opts).unwrap();
        assert!(out.mixed);
        x.as_slice().to_vec()
    };
    let flat = SimNode::new_uniform(16, 1 << 26);
    let reference = solve(&flat, 16, PipelineConfig::barrier());
    assert_eq!(reference, solve(&flat, 16, PipelineConfig::lookahead(2)));
    let fab = Fabric::h200(2);
    assert_eq!(reference, solve(fab.node(), 16, PipelineConfig::barrier()));
    assert_eq!(reference, solve(fab.node(), 16, PipelineConfig::lookahead(2)));
}

/// An unreachable tolerance (below the f64 refinement floor) stalls,
/// and the typed fallback reruns the request at full precision on the
/// same run — the caller still gets the right answer, and the metrics
/// record the fallback rather than a mixed solve.
#[test]
fn mixed_cap_fallback_returns_full_precision_result() {
    let node = SimNode::new_uniform(4, 1 << 26);
    let model = GpuCostModel::h200();
    let n = 67;
    let a = Matrix::<f64>::spd_random_cond(n, 0xD1, 1e4);
    let b = Matrix::<f64>::random(n, 2, 0xD2);
    let run = MixedRun::new(&node, &model, PipelineConfig::barrier(), lay1d(n, 4, 4));
    let opts = RefineOptions { tol: 1e-15, max_iters: DEFAULT_REFINE_CAP };
    let (x, out) =
        solve_dist_prec::<f64>(&run, Precision::Mixed(DType::F32), &a, &b, opts).unwrap();
    assert!(out.fell_back, "1e-15 sits below the f64 refinement floor");
    assert!(!out.mixed);
    // The fallback is the plain full-precision path: bitwise the
    // answer Precision::Full computes for the same request.
    let (x_full, out_full) =
        solve_dist_prec::<f64>(&run, Precision::Full, &a, &b, opts).unwrap();
    assert!(!out_full.mixed && !out_full.fell_back);
    assert_eq!(x.as_slice(), x_full.as_slice());
    assert!(rel_residual(&a, &x, &b) <= 1e-12);
    let m = node.metrics().snapshot();
    assert!(m.mixed_fallbacks >= 1);
    assert_eq!(m.mixed_solves, 0);
}

// ---------------------------------------------------------------
// Serving fronts: the router picks Mixed off the slowed cost model
// and the execution tier actually runs mixed (or falls back typed).
// ---------------------------------------------------------------

const TILE: usize = 16;
const N: usize = 160;

fn spd_case(seed: u64, cond: f64) -> (Matrix<f64>, Matrix<f64>) {
    (Matrix::<f64>::spd_random_cond(N, seed, cond), Matrix::<f64>::random(N, 2, seed + 100))
}

#[test]
fn spmd_front_routes_mixed_and_meets_tolerance() {
    let node = SimNode::new_uniform(4, 1 << 28);
    let mut cfg = SmallConfig::with_tile(TILE);
    cfg.model = slow_model();
    let svc = SolveService::with_small_config(node.clone(), 2, cfg);
    let (a, b) = spd_case(0xE1, 1e3);
    let slo = Slo::standard().with_tolerance(1e-8, 1e3);
    let h = svc
        .submit_dist_slo(DistRoutine::Potrs, a.clone(), Some(b.clone()), slo)
        .unwrap();
    let (x, _stats) = h.wait();
    svc.drain();
    assert!(rel_residual(&a, &x, &b) <= 1e-8);
    let m = node.metrics().snapshot();
    assert!(m.mixed_solves >= 1, "the slowed model must route this request Mixed");
    assert_eq!(m.mixed_fallbacks, 0);
    assert!(m.refine_iters.iter().sum::<u64>() >= 1);
    assert!(m.mixed_bytes_saved > 0);
}

#[test]
fn spmd_front_without_numeric_policy_stays_full_precision() {
    let node = SimNode::new_uniform(4, 1 << 28);
    let mut cfg = SmallConfig::with_tile(TILE);
    cfg.model = slow_model();
    let svc = SolveService::with_small_config(node.clone(), 2, cfg);
    let (a, b) = spd_case(0xE2, 1e3);
    let h = svc.submit_dist(DistRoutine::Potrs, a.clone(), Some(b.clone())).unwrap();
    let (x, _) = h.wait();
    svc.drain();
    assert!(rel_residual(&a, &x, &b) <= 1e-12);
    assert_eq!(node.metrics().snapshot().mixed_solves, 0, "no tolerance, no mixed tier");
}

#[test]
fn spmd_front_cap_fallback_loses_no_requests() {
    let node = SimNode::new_uniform(4, 1 << 28);
    let mut cfg = SmallConfig::with_tile(TILE);
    cfg.model = slow_model();
    let svc = SolveService::with_small_config(node.clone(), 2, cfg);
    // Stall bait the router cannot see coming: the *claimed* κ budget
    // (1e3) prices a few refinement iterations so the request routes
    // Mixed, but the actual matrix is far worse conditioned
    // (κ = 3e8 > 1/ε_f32) — the f32 residual cannot contract, the
    // stall detector fires at runtime, and every request falls back
    // typed to full precision.
    let slo = Slo::standard().with_tolerance(1e-8, 1e3);
    let mut pending = Vec::new();
    let mut cases = Vec::new();
    for i in 0..4u64 {
        let (a, b) = spd_case(0xF0 + i, 3e8);
        pending.push(
            svc.submit_dist_slo(DistRoutine::Potrs, a.clone(), Some(b.clone()), slo)
                .unwrap(),
        );
        cases.push((a, b));
    }
    for (h, (a, b)) in pending.into_iter().zip(&cases) {
        let (x, _) = h.wait(); // panics on a lost request
        assert!(rel_residual(a, &x, b) <= 1e-8, "fallback must serve the requested tolerance");
    }
    svc.drain();
    let m = node.metrics().snapshot();
    assert!(m.mixed_fallbacks >= 4);
    assert_eq!(m.mixed_solves, 0);
    // An *honestly declared* unreachable tolerance never reaches the
    // runtime stall: 1e-15 sits below the f64 residual floor κ·ε_f64,
    // so the router declines Mixed up front and the request runs Full —
    // no fallback makespan is ever paid.
    let (a, b) = spd_case(0xFF, 1e4);
    let slo_floor = Slo::standard().with_tolerance(1e-15, 1e4);
    let h = svc
        .submit_dist_slo(DistRoutine::Potrs, a.clone(), Some(b.clone()), slo_floor)
        .unwrap();
    let (x, _) = h.wait();
    svc.drain();
    assert!(rel_residual(&a, &x, &b) <= 1e-12);
    let m2 = node.metrics().snapshot();
    assert_eq!(
        m2.mixed_fallbacks, m.mixed_fallbacks,
        "a floor-violating tolerance must be declined by the router, not attempted"
    );
    assert_eq!(m2.mixed_solves, 0);
}

#[test]
fn mpmd_front_routes_mixed_and_falls_back_typed() {
    let node = SimNode::new_uniform(4, 1 << 28);
    let mut cfg = MpmdConfig::with_tile(TILE);
    cfg.model = slow_model();
    let svc = MpmdService::with_config(node.clone(), cfg);

    // Converging request: genuinely mixed through the workers.
    let (a, b) = spd_case(0x101, 1e3);
    let slo = Slo::standard().with_tolerance(1e-8, 1e3);
    let h = svc.submit_potrs_slo(a.clone(), b.clone(), slo).unwrap();
    let (x, _) = h.wait();
    assert!(rel_residual(&a, &x, &b) <= 1e-8);
    assert!(node.metrics().snapshot().mixed_solves >= 1);

    // Stall bait (understated κ budget: claimed 1e3, actual 3e8 blows
    // the f32 headroom): typed fallback, request still served.
    let (a2, b2) = spd_case(0x102, 3e8);
    let slo2 = Slo::standard().with_tolerance(1e-8, 1e3);
    let h2 = svc.submit_potrs_slo(a2.clone(), b2.clone(), slo2).unwrap();
    let (x2, _) = h2.wait();
    assert!(rel_residual(&a2, &x2, &b2) <= 1e-8);
    svc.drain();
    let m = node.metrics().snapshot();
    assert!(m.mixed_fallbacks >= 1);
    assert_eq!(svc.reserved(), vec![0; 4], "reservations must drain to zero");
}

#[test]
fn mpmd_factor_cache_keys_mixed_under_working_dtype() {
    let node = SimNode::new_uniform(4, 1 << 28);
    let mut cfg = MpmdConfig::with_tile(TILE);
    cfg.model = slow_model();
    cfg.factor_cache = true;
    let svc = MpmdService::with_config(node.clone(), cfg);
    let (a, b) = spd_case(0x201, 1e3);
    let slo = Slo::standard().with_tolerance(1e-8, 1e3);

    let (x1, _) = svc.submit_potrs_slo(a.clone(), b.clone(), slo).unwrap().wait();
    let after_first = node.metrics().snapshot();
    assert_eq!(after_first.cache_hits, 0);
    assert!(after_first.cache_misses >= 1);

    // Same A, same grid: the resident working-dtype factor is reused
    // and refinement still runs against the f64 operands.
    let (x2, _) = svc.submit_potrs_slo(a.clone(), b.clone(), slo).unwrap().wait();
    svc.drain();
    let m = node.metrics().snapshot();
    assert!(m.cache_hits >= 1, "repeat mixed solve must hit the working-dtype key");
    assert!(m.mixed_solves >= 2);
    assert!(rel_residual(&a, &x1, &b) <= 1e-8);
    assert!(rel_residual(&a, &x2, &b) <= 1e-8);
}

#[test]
fn mpmd_fallback_never_seeds_the_factor_cache() {
    let node = SimNode::new_uniform(4, 1 << 28);
    let mut cfg = MpmdConfig::with_tile(TILE);
    cfg.model = slow_model();
    cfg.factor_cache = true;
    let svc = MpmdService::with_config(node.clone(), cfg);
    // Understated κ budget: routed Mixed off the claimed 1e3, stalls
    // at runtime on the actual κ = 3e8 matrix — always falls back.
    let (a, b) = spd_case(0x301, 3e8);
    let slo = Slo::standard().with_tolerance(1e-8, 1e3);
    for _ in 0..2 {
        let (x, _) = svc.submit_potrs_slo(a.clone(), b.clone(), slo).unwrap().wait();
        assert!(rel_residual(&a, &x, &b) <= 1e-8);
    }
    svc.drain();
    let m = node.metrics().snapshot();
    assert!(m.mixed_fallbacks >= 2);
    assert_eq!(
        m.cache_hits, 0,
        "a fallen-back mixed attempt must not leave a working-dtype factor behind"
    );
}
