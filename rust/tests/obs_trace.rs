//! End-to-end tracing regression suite.
//!
//! Four guarantees the observability subsystem makes, each pinned
//! here:
//!
//! 1. **Determinism** — the Chrome-trace export of the `(2, 2, 4, 32)`
//!    grid-native lookahead potrf run is byte-pinned in
//!    `tests/golden/potrf2d_trace.json`, the same discipline as
//!    `tests/golden/potrf2d_timelines.txt`. Any change to span
//!    content, ordering, or the JSON encoder fails loudly; regenerate
//!    intentionally with `UPDATE_GOLDEN=1 cargo test --test obs_trace`.
//! 2. **Passivity** — enabling the tracer changes no timeline by a
//!    single nanosecond and no factor by a single bit.
//! 3. **Complete span trees** — every submitted request, including
//!    pod-coalesced smalls, killed-worker requeues, and preempted
//!    solves, yields exactly one root span and no orphaned parents.
//! 4. **Zero drift on barrier schedules** — the planner estimates the
//!    [`DriftMonitor`](jaxmg::obs::DriftMonitor) records are bitwise
//!    [`Predictor::dist_makespan`] through [`secs_to_ns`].

use jaxmg::batch::SmallRoutine;
use jaxmg::coordinator::{plan_dist, secs_to_ns, DistRoutine, Slo, SmallConfig, SolveService};
use jaxmg::costmodel::{GpuCostModel, Predictor};
use jaxmg::device::SimNode;
use jaxmg::layout::BlockCyclic2D;
use jaxmg::linalg::Matrix;
use jaxmg::obs::{chrome_trace_json, validate_chrome_json, SpanId, SpanRec, TraceId};
use jaxmg::scalar::DType;
use jaxmg::serve::{MpmdConfig, MpmdService};
use jaxmg::solver::{lift_timeline_spans, potrf_dist, Ctx, PipelineConfig, SolverBackend};
use jaxmg::tile::{DistMatrix, LayoutKind};
use std::collections::{BTreeMap, BTreeSet};

/// The offline grid-native potrf run of `golden_timeline::run_potrf2d`,
/// optionally traced: one minted trace, per-charge spans via
/// [`Ctx::with_trace`], lifted stage spans, and a closed root.
fn traced_potrf2d(
    p: usize,
    q: usize,
    tile: usize,
    n: usize,
    cfg: PipelineConfig,
    trace_on: bool,
) -> (Matrix<f64>, u64, Vec<SpanRec>) {
    let node = SimNode::new_uniform(p * q, 1 << 27);
    let model = GpuCostModel::h200();
    let backend = SolverBackend::<f64>::Native;
    let a = Matrix::<f64>::spd_random(n, 0xD15C0 + n as u64);
    let lay = LayoutKind::Grid(BlockCyclic2D::new(n, n, tile, tile, p, q).unwrap());
    let mut dm = DistMatrix::scatter(&node, &a, lay).unwrap();
    node.reset_accounting();
    let tracer = node.tracer().clone();
    let (trace, root) = if trace_on {
        tracer.enable();
        tracer.new_trace()
    } else {
        (TraceId(0), SpanId(0))
    };
    let ctx = Ctx::with_pipeline(&node, &model, &backend, cfg).with_trace(trace, root);
    potrf_dist(&ctx, &mut dm).unwrap();
    // Capture the makespan BEFORE the verification gather, exactly as
    // the golden-timeline suite does.
    let end_ns = node.sim_time_ns();
    if trace_on {
        if let Some(snap) = ctx.timeline_snapshot() {
            lift_timeline_spans(&tracer, trace, root, &snap);
        }
        tracer.close_root(trace, root, "request:potrf", 0, 0, end_ns, 0, 0);
    }
    (dm.gather().unwrap(), end_ns, tracer.spans())
}

/// Exact-compare a rendered artifact against its checked-in golden
/// file, bootstrapping (or regenerating under `UPDATE_GOLDEN=1`) it.
fn check_golden(file: &str, rendered: String) {
    let golden_dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden");
    let golden_path = golden_dir.join(file);
    let update = std::env::var_os("UPDATE_GOLDEN").is_some();
    if update || !golden_path.exists() {
        std::fs::create_dir_all(&golden_dir).unwrap();
        std::fs::write(&golden_path, &rendered).unwrap();
        eprintln!("golden trace written to {golden_path:?}");
        return;
    }
    let golden = std::fs::read_to_string(&golden_path).unwrap();
    assert_eq!(
        golden, rendered,
        "trace export drifted from {golden_path:?} — spans, ordering, or the JSON \
         encoder changed (intentional: rerun with UPDATE_GOLDEN=1 and review the diff)"
    );
}

// ---------------------------------------------------------------------------
// 1. byte-pinned Chrome-trace export
// ---------------------------------------------------------------------------

#[test]
fn potrf2d_lookahead_trace_matches_golden_chrome_json() {
    let (_, _, spans) = traced_potrf2d(2, 2, 4, 32, PipelineConfig::lookahead(2), true);
    assert!(!spans.is_empty(), "traced run recorded no spans");
    let json = chrome_trace_json(&spans);
    let events = validate_chrome_json(&json).expect("export must be valid chrome JSON");
    assert!(events > 0, "trace has no complete events");
    check_golden("potrf2d_trace.json", json);
}

// ---------------------------------------------------------------------------
// 2. passivity: tracing never charges simulated time
// ---------------------------------------------------------------------------

#[test]
fn tracing_changes_no_timeline_by_a_single_ns() {
    let (l_off, t_off, s_off) = traced_potrf2d(2, 2, 4, 32, PipelineConfig::lookahead(2), false);
    let (l_on, t_on, s_on) = traced_potrf2d(2, 2, 4, 32, PipelineConfig::lookahead(2), true);
    assert!(s_off.is_empty(), "disabled tracer must record nothing");
    assert!(!s_on.is_empty(), "enabled tracer must record spans");
    assert_eq!(t_off, t_on, "tracing shifted the lookahead makespan");
    assert_eq!(l_off.as_slice(), l_on.as_slice(), "tracing changed the factor");

    let (l_off, t_off, _) = traced_potrf2d(2, 2, 4, 32, PipelineConfig::barrier(), false);
    let (l_on, t_on, _) = traced_potrf2d(2, 2, 4, 32, PipelineConfig::barrier(), true);
    assert_eq!(t_off, t_on, "tracing shifted the barrier makespan");
    assert_eq!(l_off.as_slice(), l_on.as_slice(), "tracing changed the factor");
}

// ---------------------------------------------------------------------------
// 3. span-tree completeness under load (and under a worker kill)
// ---------------------------------------------------------------------------

/// Every trace id in `spans` must form exactly one rooted tree:
/// one span with `parent == SpanId(0)`, every other parent resolving
/// to a span id recorded in the same trace, and no inverted clocks.
/// Returns the number of distinct traces (== number of roots).
fn assert_span_forest(spans: &[SpanRec]) -> usize {
    let mut by_trace: BTreeMap<u64, Vec<&SpanRec>> = BTreeMap::new();
    for s in spans {
        assert_ne!(s.trace.0, 0, "recorded span without a trace id: {s:?}");
        by_trace.entry(s.trace.0).or_default().push(s);
    }
    for (trace, ss) in &by_trace {
        let ids: BTreeSet<u64> = ss.iter().map(|s| s.span.0).collect();
        let roots = ss.iter().filter(|s| s.parent == SpanId(0)).count();
        assert_eq!(roots, 1, "trace {trace} has {roots} root spans (want exactly 1)");
        for s in ss {
            if s.parent != SpanId(0) {
                assert!(
                    ids.contains(&s.parent.0),
                    "trace {trace}: span {} '{}' has orphan parent {}",
                    s.span.0,
                    s.name,
                    s.parent.0
                );
            }
            assert!(s.t1_ns >= s.t0_ns, "span '{}' ends before it starts", s.name);
        }
    }
    by_trace.len()
}

#[test]
fn every_spmd_request_yields_one_complete_span_tree() {
    let node = SimNode::new_uniform(4, 1 << 30);
    node.tracer().enable();
    let svc = SolveService::with_small_config(node.clone(), 2, SmallConfig::with_tile(16));

    let a = Matrix::<f64>::spd_random(96, 7);
    let b = a.matmul(&Matrix::<f64>::random(96, 1, 8));
    let d1 = svc.submit_dist(DistRoutine::Potrf, a.clone(), None).unwrap();
    let d2 = svc
        .submit_dist_slo(DistRoutine::Potrs, a.clone(), Some(b.clone()), Slo::interactive())
        .unwrap();
    let smalls: Vec<_> = (0..12)
        .map(|i| {
            let n = 12 + (i % 3) * 9;
            let sa = Matrix::<f64>::spd_random(n, 100 + i as u64);
            let sb = Matrix::<f64>::random(n, 1, 200 + i as u64);
            svc.submit_small(SmallRoutine::Potrs, sa, Some(sb)).unwrap()
        })
        .collect();
    let _ = d1.wait();
    let _ = d2.wait();
    svc.flush_small();
    for h in smalls {
        let _ = h.wait();
    }
    svc.drain();

    let spans = node.tracer().spans();
    let traces = assert_span_forest(&spans);
    // Two distributed requests plus at least one flushed pod bucket.
    assert!(traces >= 3, "expected >= 3 span trees, got {traces}");
    let decisions = node.tracer().decisions();
    assert!(
        decisions.iter().any(|d| d.kind == "admit"),
        "the SPMD service must log admit decisions"
    );
}

#[test]
fn mpmd_kill_drill_yields_complete_span_trees() {
    let node = SimNode::new_uniform(4, 1 << 30);
    let svc = MpmdService::with_config(node.clone(), MpmdConfig::with_tile(32));
    svc.tracer().enable();

    let a = Matrix::<f64>::spd_random(128, 1);
    let b = a.matmul(&Matrix::<f64>::random(128, 1, 2));
    let dist: Vec<_> = (0..4).map(|_| svc.submit_potrs(a.clone(), b.clone()).unwrap()).collect();
    let smalls: Vec<_> = (0..24)
        .map(|i| {
            let n = 12 + (i % 3) * 9;
            let sa = Matrix::<f64>::spd_random(n, 300 + i as u64);
            let sb = Matrix::<f64>::random(n, 1, 400 + i as u64);
            svc.submit_small(SmallRoutine::Potrs, sa, Some(sb)).unwrap()
        })
        .collect();
    svc.kill_worker(2).unwrap();
    for h in dist {
        let _ = h.wait();
    }
    svc.flush_small();
    for h in smalls {
        let _ = h.wait();
    }
    svc.drain();

    let spans = svc.tracer().spans();
    let traces = assert_span_forest(&spans);
    assert!(traces >= 5, "expected >= 5 span trees, got {traces}");
    let decisions = svc.tracer().decisions();
    assert!(
        decisions.iter().any(|d| d.kind == "kill"),
        "the kill must be in the decision log"
    );
}

// ---------------------------------------------------------------------------
// 4. zero drift against the Predictor on barrier schedules
// ---------------------------------------------------------------------------

#[test]
fn barrier_drift_is_bitwise_zero_against_the_predictor() {
    const N: usize = 128;
    const TILE: usize = 32;
    let node = SimNode::new_uniform(4, 1 << 30);
    // Barrier pipeline, no factor cache, no correction: every
    // submission re-plans and the ticket estimate IS the plan estimate.
    let svc = MpmdService::with_config(node.clone(), MpmdConfig::with_tile(TILE));
    svc.tracer().enable();
    for seed in 1..=3u64 {
        let a = Matrix::<f64>::spd_random(N, seed);
        let _ = svc.submit_potrf(a).unwrap().wait();
    }
    svc.drain();

    let stats = svc.tracer().drift().stats();
    assert!(!stats.is_empty(), "barrier potrf runs must record drift samples");
    let pred = Predictor {
        model: GpuCostModel::h200(),
        topo: node.topology().clone(),
        dtype: DType::F64,
    };
    for (key, st) in &stats {
        assert_eq!(key.routine, "potrf");
        assert_eq!(key.dtype, "float64");
        assert_eq!(key.n, N as u64);
        let model_ns = secs_to_ns(pred.dist_makespan(
            &key.routine,
            key.n as usize,
            0,
            TILE,
            key.grid.0 as usize,
            key.grid.1 as usize,
        ));
        // The recorded plan estimates are the Predictor's own numbers,
        // bitwise: model drift on a barrier schedule is exactly zero.
        assert_eq!(
            st.est_model_sum,
            st.samples as u128 * model_ns as u128,
            "plan estimates drifted from the Predictor for {key:?}"
        );
        // Uncorrected queue estimates equal the plan estimates.
        assert_eq!(
            st.est_used_sum, st.est_model_sum,
            "queue estimate diverged without correction for {key:?}"
        );
    }

    // And the planner's claim directly, without the service in between.
    let plan = plan_dist(
        "potrf",
        N,
        0,
        TILE,
        4,
        DType::F64,
        &GpuCostModel::h200(),
        node.topology(),
        None,
    )
    .unwrap();
    assert_eq!(
        plan.est_ns,
        secs_to_ns(pred.dist_makespan("potrf", N, 0, TILE, plan.grid.0, plan.grid.1)),
        "plan_dist estimate is not the Predictor makespan bitwise"
    );
}
