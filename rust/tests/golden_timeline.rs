//! Golden-timeline regression tests for the lookahead scheduler.
//!
//! For a fixed grid of `(ndev >= 4, tile, n >= 4*tile)` configurations
//! this suite:
//!
//! 1. asserts the lookahead schedule's simulated potrf makespan is
//!    **strictly** smaller than the barrier schedule's (the tentpole
//!    claim — devices stop idling between panel steps);
//! 2. asserts both schedules produce bitwise-identical factors;
//! 3. snapshots the per-device stream timelines (compute/panel/copy
//!    horizons + busy time, µs) into `tests/golden/potrf_timelines.txt`
//!    and compares against the checked-in snapshot on later runs, so
//!    any cost-model or scheduler drift fails loudly. The snapshot
//!    bootstraps itself on first run; regenerate intentionally with
//!    `UPDATE_GOLDEN=1 cargo test --test golden_timeline`.
//!
//! The same discipline covers the **potrs** solve schedule
//! (`tests/golden/potrs_timelines.txt`): the factor is produced under a
//! barrier context and the accounting reset, so the snapshot isolates
//! the two substitution sweeps — whose tail hand-offs and result
//! broadcasts ride the copy streams under the pipelined schedule.
//!
//! Everything here is deterministic: seeded matrices, an analytic cost
//! model, and single-threaded scheduling.

use jaxmg::costmodel::GpuCostModel;
use jaxmg::device::SimNode;
use jaxmg::layout::{BlockCyclic1D, BlockCyclic2D};
use jaxmg::linalg::Matrix;
use jaxmg::solver::{
    potrf_dist, potri_dist, potrs_dist, Ctx, DeviceTimeline, PipelineConfig, SolverBackend,
};
use jaxmg::tile::{DistMatrix, Layout1D, LayoutKind};
use std::fmt::Write as _;

/// `(ndev, tile, n)` — every entry satisfies ndev >= 4 and n >= 4*tile.
const GRID: &[(usize, usize, usize)] = &[(4, 4, 32), (4, 8, 64), (8, 8, 128)];

fn run_potrf(
    ndev: usize,
    tile: usize,
    n: usize,
    cfg: PipelineConfig,
) -> (Matrix<f64>, f64, Option<Vec<DeviceTimeline>>) {
    let node = SimNode::new_uniform(ndev, 1 << 27);
    let model = GpuCostModel::h200();
    let backend = SolverBackend::<f64>::Native;
    let a = Matrix::<f64>::spd_random(n, 0xD15C0 + n as u64);
    let lay = Layout1D::BlockCyclic(BlockCyclic1D::new(n, tile, ndev).unwrap());
    let mut dm = DistMatrix::scatter(&node, &a, lay).unwrap();
    node.reset_accounting();
    let ctx = Ctx::with_pipeline(&node, &model, &backend, cfg);
    potrf_dist(&ctx, &mut dm).unwrap();
    let snap = ctx.timeline_snapshot();
    // Capture the makespan BEFORE the verification gather: the
    // snapshot pins the factorization schedule, and the gather's H2D
    // charges are not part of it.
    let makespan = node.sim_time();
    (dm.gather().unwrap(), makespan, snap)
}

#[test]
fn lookahead_beats_barrier_on_every_grid_config() {
    for &(ndev, tile, n) in GRID {
        let (l_barrier, t_barrier, _) = run_potrf(ndev, tile, n, PipelineConfig::barrier());
        let (l_look, t_look, _) = run_potrf(ndev, tile, n, PipelineConfig::lookahead(2));
        assert_eq!(
            l_barrier.as_slice(),
            l_look.as_slice(),
            "schedule changed numerics (ndev={ndev} tile={tile} n={n})"
        );
        assert!(
            t_look < t_barrier,
            "lookahead {t_look} !< barrier {t_barrier} (ndev={ndev} tile={tile} n={n})"
        );
    }
}

#[test]
fn deeper_lookahead_never_slower_than_depth_one() {
    for &(ndev, tile, n) in GRID {
        let (_, t1, _) = run_potrf(ndev, tile, n, PipelineConfig::lookahead(1));
        let (_, t4, _) = run_potrf(ndev, tile, n, PipelineConfig::lookahead(4));
        // Relaxing the depth bound only removes constraints.
        assert!(
            t4 <= t1 + 1e-12,
            "depth-4 {t4} slower than depth-1 {t1} (ndev={ndev} tile={tile} n={n})"
        );
    }
}

fn render_snapshot() -> String {
    let mut out = String::new();
    out.push_str("# golden potrf timelines (µs) — regenerate with UPDATE_GOLDEN=1\n");
    for &(ndev, tile, n) in GRID {
        let (_, t_barrier, _) = run_potrf(ndev, tile, n, PipelineConfig::barrier());
        let (_, t_look, snap) = run_potrf(ndev, tile, n, PipelineConfig::lookahead(2));
        let snap = snap.expect("pipelined run has a timeline");
        writeln!(out, "config ndev={ndev} tile={tile} n={n}").unwrap();
        writeln!(out, "  barrier_makespan_us   {:.3}", t_barrier * 1e6).unwrap();
        writeln!(out, "  lookahead_makespan_us {:.3}", t_look * 1e6).unwrap();
        for d in &snap {
            writeln!(
                out,
                "  dev {} compute {:.3} panel {:.3} copy {:.3} busy {:.3}",
                d.device,
                d.compute_horizon * 1e6,
                d.panel_horizon * 1e6,
                d.copy_horizon * 1e6,
                d.busy * 1e6
            )
            .unwrap();
        }
    }
    out
}

/// Exact-compare a rendered snapshot against its checked-in golden
/// file, bootstrapping (or regenerating under `UPDATE_GOLDEN=1`) it.
fn check_golden(file: &str, rendered: String) {
    let golden_dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden");
    let golden_path = golden_dir.join(file);
    let update = std::env::var_os("UPDATE_GOLDEN").is_some();
    if update || !golden_path.exists() {
        std::fs::create_dir_all(&golden_dir).unwrap();
        std::fs::write(&golden_path, &rendered).unwrap();
        eprintln!("golden timeline snapshot written to {golden_path:?}");
        return;
    }
    let golden = std::fs::read_to_string(&golden_path).unwrap();
    assert_eq!(
        golden, rendered,
        "per-device timelines drifted from {golden_path:?} — a perf regression (or an \
         intentional scheduler/cost-model change: rerun with UPDATE_GOLDEN=1 and review the diff)"
    );
}

#[test]
fn per_device_timelines_match_golden_snapshot() {
    check_golden("potrf_timelines.txt", render_snapshot());
}

// ---------------------------------------------------------------------------
// potrs: the solve schedule, isolated from the factorization
// ---------------------------------------------------------------------------

/// Factor under a barrier context, reset the accounting, then run the
/// `potrs` solve alone under `cfg` — the snapshot captures the two
/// substitution sweeps, not the factorization.
fn run_potrs(
    ndev: usize,
    tile: usize,
    n: usize,
    cfg: PipelineConfig,
) -> (Matrix<f64>, f64, Option<Vec<DeviceTimeline>>) {
    let node = SimNode::new_uniform(ndev, 1 << 27);
    let model = GpuCostModel::h200();
    let backend = SolverBackend::<f64>::Native;
    let a = Matrix::<f64>::spd_random(n, 0xD15C0 + n as u64);
    let b = Matrix::<f64>::ones(n, 1);
    let lay = Layout1D::BlockCyclic(BlockCyclic1D::new(n, tile, ndev).unwrap());
    let mut dm = DistMatrix::scatter(&node, &a, lay).unwrap();
    {
        let fctx = Ctx::new(&node, &model, &backend);
        potrf_dist(&fctx, &mut dm).unwrap();
    }
    node.reset_accounting();
    let ctx = Ctx::with_pipeline(&node, &model, &backend, cfg);
    let x = potrs_dist(&ctx, &dm, &b).unwrap();
    let snap = ctx.timeline_snapshot();
    (x, node.sim_time(), snap)
}

#[test]
fn potrs_lookahead_beats_barrier_on_every_grid_config() {
    for &(ndev, tile, n) in GRID {
        let (x_barrier, t_barrier, _) = run_potrs(ndev, tile, n, PipelineConfig::barrier());
        let (x_look, t_look, _) = run_potrs(ndev, tile, n, PipelineConfig::lookahead(2));
        assert_eq!(
            x_barrier.as_slice(),
            x_look.as_slice(),
            "schedule changed potrs numerics (ndev={ndev} tile={tile} n={n})"
        );
        assert!(
            t_look < t_barrier,
            "potrs lookahead {t_look} !< barrier {t_barrier} (ndev={ndev} tile={tile} n={n})"
        );
    }
}

fn render_potrs_snapshot() -> String {
    let mut out = String::new();
    out.push_str("# golden potrs timelines (µs) — regenerate with UPDATE_GOLDEN=1\n");
    for &(ndev, tile, n) in GRID {
        let (_, t_barrier, _) = run_potrs(ndev, tile, n, PipelineConfig::barrier());
        let (_, t_look, snap) = run_potrs(ndev, tile, n, PipelineConfig::lookahead(2));
        let snap = snap.expect("pipelined run has a timeline");
        writeln!(out, "config ndev={ndev} tile={tile} n={n} nrhs=1").unwrap();
        writeln!(out, "  barrier_makespan_us   {:.3}", t_barrier * 1e6).unwrap();
        writeln!(out, "  lookahead_makespan_us {:.3}", t_look * 1e6).unwrap();
        for d in &snap {
            writeln!(
                out,
                "  dev {} compute {:.3} panel {:.3} copy {:.3} busy {:.3}",
                d.device,
                d.compute_horizon * 1e6,
                d.panel_horizon * 1e6,
                d.copy_horizon * 1e6,
                d.busy * 1e6
            )
            .unwrap();
        }
    }
    out
}

#[test]
fn potrs_timelines_match_golden_snapshot() {
    check_golden("potrs_timelines.txt", render_potrs_snapshot());
}

// ---------------------------------------------------------------------------
// grid-native potrf: the 2D execution schedule
// ---------------------------------------------------------------------------

/// `(p, q, tile, n)` grid-native configurations. The committed
/// snapshot was generated offline by `tests/golden/gen_potrf2d.py`
/// (an exact integer-ns replication of this schedule); this test
/// verifies the live scheduler against it.
const GRID2D: &[(usize, usize, usize, usize)] = &[(2, 2, 4, 32), (2, 2, 8, 64), (2, 4, 8, 128)];

fn run_potrf2d(
    p: usize,
    q: usize,
    tile: usize,
    n: usize,
    cfg: PipelineConfig,
) -> (Matrix<f64>, f64, Option<Vec<DeviceTimeline>>) {
    let node = SimNode::new_uniform(p * q, 1 << 27);
    let model = GpuCostModel::h200();
    let backend = SolverBackend::<f64>::Native;
    let a = Matrix::<f64>::spd_random(n, 0xD15C0 + n as u64);
    let lay = LayoutKind::Grid(BlockCyclic2D::new(n, n, tile, tile, p, q).unwrap());
    let mut dm = DistMatrix::scatter(&node, &a, lay).unwrap();
    node.reset_accounting();
    let ctx = Ctx::with_pipeline(&node, &model, &backend, cfg);
    potrf_dist(&ctx, &mut dm).unwrap();
    let snap = ctx.timeline_snapshot();
    // As in `run_potrf`: the gather's H2D charges are not part of the
    // factorization schedule the snapshot pins.
    let makespan = node.sim_time();
    (dm.gather().unwrap(), makespan, snap)
}

#[test]
fn grid_lookahead_beats_barrier_on_every_grid_config() {
    for &(p, q, tile, n) in GRID2D {
        let (l_barrier, t_barrier, _) = run_potrf2d(p, q, tile, n, PipelineConfig::barrier());
        let (l_look, t_look, _) = run_potrf2d(p, q, tile, n, PipelineConfig::lookahead(2));
        assert_eq!(
            l_barrier.as_slice(),
            l_look.as_slice(),
            "schedule changed grid numerics (p={p} q={q} tile={tile} n={n})"
        );
        assert!(
            t_look < t_barrier,
            "grid lookahead {t_look} !< barrier {t_barrier} (p={p} q={q} tile={tile} n={n})"
        );
    }
}

fn render_potrf2d_snapshot() -> String {
    let mut out = String::new();
    out.push_str("# golden grid potrf timelines (µs) — regenerate with UPDATE_GOLDEN=1\n");
    for &(p, q, tile, n) in GRID2D {
        let (_, t_barrier, _) = run_potrf2d(p, q, tile, n, PipelineConfig::barrier());
        let (_, t_look, snap) = run_potrf2d(p, q, tile, n, PipelineConfig::lookahead(2));
        let snap = snap.expect("pipelined run has a timeline");
        writeln!(out, "config p={p} q={q} tile={tile} n={n}").unwrap();
        writeln!(out, "  barrier_makespan_us   {:.3}", t_barrier * 1e6).unwrap();
        writeln!(out, "  lookahead_makespan_us {:.3}", t_look * 1e6).unwrap();
        for d in &snap {
            writeln!(
                out,
                "  dev {} compute {:.3} panel {:.3} copy {:.3} busy {:.3}",
                d.device,
                d.compute_horizon * 1e6,
                d.panel_horizon * 1e6,
                d.copy_horizon * 1e6,
                d.busy * 1e6
            )
            .unwrap();
        }
    }
    out
}

#[test]
fn potrf2d_timelines_match_golden_snapshot() {
    check_golden("potrf2d_timelines.txt", render_potrf2d_snapshot());
}

// ---------------------------------------------------------------------------
// potri: the two-phase inverse schedule, isolated from the factorization
// ---------------------------------------------------------------------------

/// Factor under a barrier context, reset the accounting, then run
/// `potri` alone under `cfg` — the snapshot captures the trtri column
/// pipelines (phase 1), the lauum panel-broadcast rounds (phase 2) and
/// the local write-back, not the factorization. The committed snapshot
/// was generated offline by `tests/golden/gen_potri.py` (an exact
/// integer-ns replication of this schedule); this test verifies the
/// live scheduler against it.
fn run_potri(
    ndev: usize,
    tile: usize,
    n: usize,
    cfg: PipelineConfig,
) -> (Matrix<f64>, f64, Option<Vec<DeviceTimeline>>) {
    let node = SimNode::new_uniform(ndev, 1 << 27);
    let model = GpuCostModel::h200();
    let backend = SolverBackend::<f64>::Native;
    let a = Matrix::<f64>::spd_random(n, 0xD15C0 + n as u64);
    let lay = Layout1D::BlockCyclic(BlockCyclic1D::new(n, tile, ndev).unwrap());
    let mut dm = DistMatrix::scatter(&node, &a, lay).unwrap();
    {
        let fctx = Ctx::new(&node, &model, &backend);
        potrf_dist(&fctx, &mut dm).unwrap();
    }
    node.reset_accounting();
    let ctx = Ctx::with_pipeline(&node, &model, &backend, cfg);
    potri_dist(&ctx, &mut dm).unwrap();
    let snap = ctx.timeline_snapshot();
    let makespan = node.sim_time();
    (dm.gather().unwrap(), makespan, snap)
}

#[test]
fn potri_pipelined_beats_barrier_on_every_grid_config() {
    for &(ndev, tile, n) in GRID {
        let (inv_barrier, t_barrier, _) = run_potri(ndev, tile, n, PipelineConfig::barrier());
        let (inv_look, t_look, _) = run_potri(ndev, tile, n, PipelineConfig::lookahead(2));
        assert_eq!(
            inv_barrier.as_slice(),
            inv_look.as_slice(),
            "schedule changed potri numerics (ndev={ndev} tile={tile} n={n})"
        );
        assert!(
            t_look < t_barrier,
            "potri pipelined {t_look} !< barrier {t_barrier} (ndev={ndev} tile={tile} n={n})"
        );
    }
}

fn render_potri_snapshot() -> String {
    let mut out = String::new();
    out.push_str("# golden potri timelines (µs) — regenerate with UPDATE_GOLDEN=1\n");
    for &(ndev, tile, n) in GRID {
        let (_, t_barrier, _) = run_potri(ndev, tile, n, PipelineConfig::barrier());
        let (_, t_look, snap) = run_potri(ndev, tile, n, PipelineConfig::lookahead(2));
        let snap = snap.expect("pipelined run has a timeline");
        writeln!(out, "config ndev={ndev} tile={tile} n={n}").unwrap();
        writeln!(out, "  barrier_makespan_us   {:.3}", t_barrier * 1e6).unwrap();
        writeln!(out, "  lookahead_makespan_us {:.3}", t_look * 1e6).unwrap();
        for d in &snap {
            writeln!(
                out,
                "  dev {} compute {:.3} panel {:.3} copy {:.3} busy {:.3}",
                d.device,
                d.compute_horizon * 1e6,
                d.panel_horizon * 1e6,
                d.copy_horizon * 1e6,
                d.busy * 1e6
            )
            .unwrap();
        }
    }
    out
}

#[test]
fn potri_timelines_match_golden_snapshot() {
    check_golden("potri_timelines.txt", render_potri_snapshot());
}
