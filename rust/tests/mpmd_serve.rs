//! MPMD serving subsystem: end-to-end, failure-mode, and parity tests.
//!
//! Pins the acceptance criteria of the serve layer:
//! * an MPMD end-to-end solve (worker-staged shards → IPC export/open →
//!   single-caller dist solve → publish → gather) is **bitwise
//!   identical** to the SPMD `SolveService` path for all four dtypes;
//! * ≥2 solves run in flight across the workers;
//! * killing a worker mid-workload loses no requests — its solves
//!   re-queue with the device excluded and complete on the rest;
//! * a worker panic (injected fault) re-queues the in-flight solve the
//!   same way;
//! * IPC misuse (self-open, double-open, stale-after-free) surfaces as
//!   typed `Error::Ipc`.

use jaxmg::batch::SmallRoutine;
use jaxmg::coordinator::{ServeError, Slo, SloClass, SmallConfig, SolveService};
use jaxmg::ipc::{AddressSpace, IpcRegistry};
use jaxmg::linalg::{tol_for, FrobNorm, Matrix};
use jaxmg::prelude::*;
use jaxmg::scalar::{c32, c64};
use jaxmg::serve::{MpmdConfig, MpmdService};
use std::time::{Duration, Instant};

const TILE: usize = 8;
const NDEV: usize = 4;

/// The SPMD reference: the same solve through `SolveService`'s
/// distributed route (small_dim = 0 forces every request down it).
fn spmd_potrs<S: Scalar>(a: &Matrix<S>, b: &Matrix<S>) -> Matrix<S> {
    let node = SimNode::new_uniform(NDEV, 1 << 24);
    let mut cfg = SmallConfig::with_tile(TILE);
    cfg.policy.small_dim = 0;
    let svc = SolveService::with_small_config(node, 2, cfg);
    let h = svc.submit_small(SmallRoutine::Potrs, a.clone(), Some(b.clone())).unwrap();
    let (x, _) = h.wait();
    svc.drain();
    x
}

fn mpmd_potrs<S: Scalar>(a: &Matrix<S>, b: &Matrix<S>) -> Matrix<S> {
    let node = SimNode::new_uniform(NDEV, 1 << 24);
    let svc = MpmdService::with_config(node.clone(), MpmdConfig::with_tile(TILE));
    let h = svc.submit_potrs(a.clone(), b.clone()).unwrap();
    let (x, stats) = h.wait();
    assert_eq!(stats.batch_size, 1);
    svc.drain();
    // The full IPC choreography actually ran: ndev-1 exports, each
    // opened and closed by rank 0, nothing leaked.
    let m = node.metrics().snapshot();
    assert_eq!(m.ipc_exports, (NDEV - 1) as u64);
    assert_eq!(m.ipc_opens, (NDEV - 1) as u64);
    assert_eq!(m.ipc_open_balance(), 0, "caller leaked ipc mappings");
    assert_eq!(m.ipc_revokes, (NDEV - 1) as u64, "shard frees must revoke exports");
    assert_eq!(svc.reserved(), vec![0; NDEV], "reservations must drain to zero");
    for rep in node.memory_reports() {
        assert_eq!(rep.used, 0, "worker leaked device memory");
    }
    x
}

fn mpmd_matches_spmd_bitwise<S: Scalar>(seed: u64) {
    let n = 24;
    let a = Matrix::<S>::spd_random(n, seed);
    let b = Matrix::<S>::random(n, 2, seed + 100);
    let spmd = spmd_potrs(&a, &b);
    let mpmd = mpmd_potrs(&a, &b);
    assert_eq!(spmd.as_slice(), mpmd.as_slice(), "MPMD numerics diverge from SPMD");
}

#[test]
fn mpmd_matches_spmd_bitwise_f32() {
    mpmd_matches_spmd_bitwise::<f32>(11);
}

#[test]
fn mpmd_matches_spmd_bitwise_f64() {
    mpmd_matches_spmd_bitwise::<f64>(12);
}

#[test]
fn mpmd_matches_spmd_bitwise_c64() {
    mpmd_matches_spmd_bitwise::<c32>(13);
}

#[test]
fn mpmd_matches_spmd_bitwise_c128() {
    mpmd_matches_spmd_bitwise::<c64>(14);
}

/// The 2D regression the frontend's old 1D-only routing guard made
/// impossible: a solve pinned to a 2×2 grid — workers stage and
/// IPC-export **2D tile shards** — round-trips bitwise against the
/// SPMD `SolveService` on the same grid AND against the plain 1D path.
fn mpmd_2d_matches_spmd<S: Scalar>(seed: u64) {
    let n = 24;
    let a = Matrix::<S>::spd_random(n, seed);
    let b = Matrix::<S>::random(n, 2, seed + 100);

    // SPMD reference on the same forced 2×2 grid.
    let spmd_node = SimNode::new_uniform(NDEV, 1 << 24);
    let mut scfg = jaxmg::coordinator::SmallConfig::with_tile(TILE);
    scfg.grid = Some((2, 2));
    let spmd = SolveService::with_small_config(spmd_node.clone(), 2, scfg);
    let (x_spmd, st_spmd) = spmd
        .submit_dist(jaxmg::coordinator::DistRoutine::Potrs, a.clone(), Some(b.clone()))
        .unwrap()
        .wait();
    assert_eq!(st_spmd.grid, (2, 2));
    spmd.drain();
    assert!(spmd_node.metrics().snapshot().grid_solves >= 2);

    // MPMD on the same forced grid: workers stage 2D tile shards.
    let mpmd_node = SimNode::new_uniform(NDEV, 1 << 24);
    let mut mcfg = MpmdConfig::with_tile(TILE);
    mcfg.grid = Some((2, 2));
    let svc = MpmdService::with_config(mpmd_node.clone(), mcfg);
    let (x_mpmd, st_mpmd) = svc.submit_potrs(a.clone(), b.clone()).unwrap().wait();
    assert_eq!(st_mpmd.grid, (2, 2));
    svc.drain();
    let m = mpmd_node.metrics().snapshot();
    assert_eq!(m.ipc_exports, (NDEV - 1) as u64, "every non-caller worker exports its 2D shard");
    assert_eq!(m.ipc_open_balance(), 0, "caller leaked ipc mappings");
    assert!(m.grid_solves >= 2, "the MPMD solve must run grid-native");
    assert!(m.grid_row_bytes > 0 && m.grid_col_bytes > 0);
    assert_eq!(svc.reserved(), vec![0; NDEV]);
    for rep in mpmd_node.memory_reports() {
        assert_eq!(rep.used, 0, "worker leaked device memory");
    }

    assert_eq!(
        x_spmd.as_slice(),
        x_mpmd.as_slice(),
        "MPMD 2D-grid numerics diverge from SPMD"
    );
    // And the 2D result is bitwise the 1D (autotuned small-shape) one.
    let x_1d = spmd_potrs(&a, &b);
    assert_eq!(x_spmd.as_slice(), x_1d.as_slice(), "2D grid numerics diverge from 1D");
}

#[test]
fn mpmd_2d_grid_matches_spmd_bitwise_f32() {
    mpmd_2d_matches_spmd::<f32>(61);
}

#[test]
fn mpmd_2d_grid_matches_spmd_bitwise_f64() {
    mpmd_2d_matches_spmd::<f64>(62);
}

#[test]
fn mpmd_2d_grid_matches_spmd_bitwise_c64() {
    mpmd_2d_matches_spmd::<c32>(63);
}

#[test]
fn mpmd_2d_grid_matches_spmd_bitwise_c128() {
    mpmd_2d_matches_spmd::<c64>(64);
}

#[test]
fn mpmd_potri_and_syevd_end_to_end() {
    let node = SimNode::new_uniform(3, 1 << 24);
    let svc = MpmdService::with_config(node, MpmdConfig::with_tile(4));
    let a = Matrix::<f64>::spd_random(18, 5);
    let inv_h = svc.submit_potri(a.clone()).unwrap();
    let eig_h = svc.submit_syevd(Matrix::<f64>::spd_diag(16)).unwrap();
    let (inv, _) = inv_h.wait();
    assert!(a.matmul(&inv).rel_err(&Matrix::eye(18)) < tol_for::<f64>(18) * 10.0);
    let ((vals, _vecs), _) = eig_h.wait();
    for (i, v) in vals.iter().enumerate() {
        assert!((v - (i + 1) as f64).abs() < 1e-10, "eigenvalue {i} wrong: {v}");
    }
    svc.drain();
}

#[test]
fn concurrent_solves_share_the_workers() {
    // ≥2 solves in flight across workers (acceptance criterion).
    let node = SimNode::new_uniform(NDEV, 1 << 26);
    let svc = MpmdService::with_config(node, MpmdConfig::with_tile(TILE));
    let n = 96;
    let a = Matrix::<f64>::spd_random(n, 3);
    let xt = Matrix::<f64>::random(n, 1, 4);
    let b = a.matmul(&xt);
    let handles: Vec<_> =
        (0..8).map(|_| svc.submit_potrs(a.clone(), b.clone()).unwrap()).collect();
    // Two router threads drain the queue concurrently; with 8 solves of
    // this size the 2-in-flight window is wide. Poll until observed.
    let deadline = Instant::now() + Duration::from_secs(30);
    let mut peak = 0;
    while Instant::now() < deadline {
        peak = peak.max(svc.in_flight());
        if peak >= 2 {
            break;
        }
        std::thread::yield_now();
    }
    assert!(peak >= 2, "never saw 2 solves in flight (peak {peak})");
    for h in handles {
        let (x, _) = h.wait();
        assert!(x.rel_err(&xt) < tol_for::<f64>(n) * 10.0);
    }
    svc.drain();
    assert_eq!(svc.reserved(), vec![0; NDEV]);
}

#[test]
fn killing_a_worker_loses_no_requests() {
    let node = SimNode::new_uniform(NDEV, 1 << 26);
    let svc = MpmdService::with_config(node.clone(), MpmdConfig::with_tile(TILE));
    let n = 64;
    let systems: Vec<(Matrix<f64>, Matrix<f64>, Matrix<f64>)> = (0..6)
        .map(|i| {
            let a = Matrix::<f64>::spd_random(n, 40 + i);
            let xt = Matrix::<f64>::random(n, 1, 50 + i);
            let b = a.matmul(&xt);
            (a, xt, b)
        })
        .collect();
    let handles: Vec<_> = systems
        .iter()
        .map(|(a, _, b)| svc.submit_potrs(a.clone(), b.clone()).unwrap())
        .collect();
    // Kill a worker mid-workload: its staged shards vanish, its pending
    // mailbox drains dead, in-flight solves touching it re-queue.
    svc.kill_worker(2).unwrap();
    assert_eq!(svc.alive_workers(), vec![0, 1, 3]);
    for (h, (_, xt, _)) in handles.into_iter().zip(&systems) {
        let (x, _) = h.wait();
        assert!(x.rel_err(xt) < tol_for::<f64>(n) * 10.0, "request lost/corrupted by the kill");
    }
    svc.drain();
    assert_eq!(svc.reserved(), vec![0; NDEV], "kill leaked reservations");
    // Post-kill traffic keeps flowing on the remaining devices.
    let (a, xt, b) = &systems[0];
    let (x, _) = svc.submit_potrs(a.clone(), b.clone()).unwrap().wait();
    assert!(x.rel_err(xt) < tol_for::<f64>(n) * 10.0);
    svc.drain();
}

#[test]
fn worker_panic_mid_solve_requeues_with_device_excluded() {
    let node = SimNode::new_uniform(3, 1 << 26);
    let svc = MpmdService::with_config(node.clone(), MpmdConfig::with_tile(TILE));
    // Arm the chaos fault: worker 1's process dies on its next job —
    // which is this solve's shard staging, i.e. mid-solve.
    svc.inject_worker_fault(1).unwrap();
    let n = 48;
    let a = Matrix::<f64>::spd_random(n, 7);
    let xt = Matrix::<f64>::random(n, 2, 8);
    let b = a.matmul(&xt);
    let (x, _) = svc.submit_potrs(a, b).unwrap().wait();
    assert!(x.rel_err(&xt) < tol_for::<f64>(n) * 10.0, "re-queued solve wrong");
    assert_eq!(svc.alive_workers(), vec![0, 2], "worker 1 must be dead");
    let m = node.metrics().snapshot();
    assert!(m.mpmd_requeues >= 1, "the failure must be visible as a re-queue");
    svc.drain();
    assert_eq!(svc.reserved(), vec![0; 3]);
}

#[test]
fn killed_worker_requeues_pinned_pods() {
    let node = SimNode::new_uniform(2, 1 << 24);
    let mut cfg = MpmdConfig::with_tile(16);
    cfg.policy.max_batch = 2;
    cfg.policy.max_dwell_ns = u64::MAX;
    let svc = MpmdService::with_config(node, cfg);
    // Worker 0 dies on its next job; the flushed pod (pinned to the
    // least-loaded live worker = 0) runs in dead mode and re-queues
    // onto worker 1.
    svc.inject_worker_fault(0).unwrap();
    let a1 = Matrix::<f64>::spd_random(10, 1);
    let a2 = Matrix::<f64>::spd_random(12, 2);
    let h1 = svc.submit_small(SmallRoutine::Potrf, a1.clone(), None).unwrap();
    let h2 = svc.submit_small(SmallRoutine::Potrf, a2.clone(), None).unwrap();
    let (l1, _) = h1.wait();
    let (l2, _) = h2.wait();
    assert_eq!(l1.as_slice(), jaxmg::linalg::potrf(&a1).unwrap().as_slice());
    assert_eq!(l2.as_slice(), jaxmg::linalg::potrf(&a2).unwrap().as_slice());
    assert_eq!(svc.alive_workers(), vec![1]);
    svc.drain();
    assert_eq!(svc.reserved(), vec![0, 0]);
}

#[test]
fn mpmd_small_solves_coalesce_into_pinned_pods() {
    let node = SimNode::new_uniform(NDEV, 1 << 24);
    let mut cfg = MpmdConfig::with_tile(16);
    cfg.policy.max_batch = 4;
    cfg.policy.max_dwell_ns = u64::MAX;
    let svc = MpmdService::with_config(node.clone(), cfg);
    let systems: Vec<Matrix<f64>> =
        (0..4).map(|i| Matrix::spd_random(10 + i, 70 + i as u64)).collect();
    let rhss: Vec<Matrix<f64>> =
        (0..4).map(|i| Matrix::random(10 + i, 2, 80 + i as u64)).collect();
    let handles: Vec<_> = systems
        .iter()
        .zip(&rhss)
        .map(|(a, b)| svc.submit_small(SmallRoutine::Potrs, a.clone(), Some(b.clone())).unwrap())
        .collect();
    assert_eq!(svc.pending_small(), 0, "the fourth submit fills the bucket");
    for (i, h) in handles.into_iter().enumerate() {
        let (x, stats) = h.wait();
        let l = jaxmg::linalg::potrf(&systems[i]).unwrap();
        let x_ref = jaxmg::linalg::potrs_from_chol(&l, &rhss[i]).unwrap();
        assert!(x.rel_err(&x_ref) < tol_for::<f64>(16), "request {i} wrong");
        assert_eq!(stats.batch_size, 4, "request {i} missed its bucket");
    }
    svc.drain();
    let m = node.metrics().snapshot();
    assert_eq!(m.batch_buckets, 1);
    assert_eq!(m.batch_solves, 4);
    assert!(m.mpmd_routed >= 1);
    assert_eq!(svc.reserved(), vec![0; NDEV]);
}

#[test]
fn frontend_tick_flushes_idle_mpmd_buckets() {
    // The serve-loop twin of the SPMD background flusher: a lone
    // small request must resolve with no further service calls.
    let node = SimNode::new_uniform(2, 1 << 22);
    let mut cfg = MpmdConfig::with_tile(16);
    cfg.policy.max_batch = 32;
    cfg.policy.max_dwell_ns = u64::MAX;
    cfg.policy.max_wall_dwell = Duration::from_millis(10);
    let svc = MpmdService::with_config(node, cfg);
    let h = svc.submit_small(SmallRoutine::Potrf, Matrix::<f64>::spd_random(8, 1), None).unwrap();
    let (l, stats) = h.wait();
    assert_eq!(l.rows(), 8);
    assert_eq!(stats.batch_size, 1);
    assert_eq!(svc.pending_small(), 0);
}

#[test]
fn all_workers_dead_surfaces_typed_no_live_workers() {
    // The requeue loop's terminal case: with every worker dead there is
    // no live subset left, so the dispatcher must resolve the waiter
    // with the typed error instead of spinning the request forever.
    let node = SimNode::new_uniform(2, 1 << 24);
    let svc = MpmdService::with_config(node, MpmdConfig::with_tile(TILE));
    svc.kill_worker(0).unwrap();
    svc.kill_worker(1).unwrap();
    assert!(svc.alive_workers().is_empty());
    let n = 16;
    let a = Matrix::<f64>::spd_random(n, 9);
    let b = Matrix::<f64>::ones(n, 1);
    match svc.submit_potrs(a, b).unwrap().wait_result() {
        Err(ServeError::NoLiveWorkers { total }) => assert_eq!(total, 2),
        Err(other) => panic!("expected NoLiveWorkers, got {other:?}"),
        Ok(_) => panic!("solve must not succeed with every worker dead"),
    }
    svc.drain();
}

#[test]
fn killing_every_worker_resolves_all_pending_requests() {
    // Kill the whole fleet with a workload queued and in flight: every
    // handle must resolve — success for solves that raced ahead of the
    // kill, `NoLiveWorkers` for the rest. No hang, no untyped failure.
    let node = SimNode::new_uniform(2, 1 << 26);
    let svc = MpmdService::with_config(node, MpmdConfig::with_tile(TILE));
    let n = 48;
    let a = Matrix::<f64>::spd_random(n, 33);
    let xt = Matrix::<f64>::random(n, 2, 34);
    let b = a.matmul(&xt);
    let handles: Vec<_> =
        (0..4).map(|_| svc.submit_potrs(a.clone(), b.clone()).unwrap()).collect();
    svc.kill_worker(0).unwrap();
    svc.kill_worker(1).unwrap();
    for h in handles {
        match h.wait_result() {
            Ok((x, _)) => assert!(x.rel_err(&xt) < tol_for::<f64>(n) * 10.0),
            Err(ServeError::NoLiveWorkers { total }) => assert_eq!(total, 2),
            Err(ServeError::Failed(msg)) => {
                panic!("expected typed NoLiveWorkers, got Failed({msg})")
            }
        }
    }
    svc.drain();
}

#[test]
fn straggler_injection_loses_no_requests() {
    // The kill drill generalized to slow-but-alive hardware: a dragged
    // device clock stretches every charge it hosts, yet every request
    // completes with correct numerics — zero loss under stragglers.
    let node = SimNode::new_uniform(NDEV, 1 << 26);
    let svc = MpmdService::with_config(node.clone(), MpmdConfig::with_tile(TILE));
    let n = 64;
    let a = Matrix::<f64>::spd_random(n, 90);
    let xt = Matrix::<f64>::random(n, 1, 91);
    let b = a.matmul(&xt);
    let handles: Vec<_> =
        (0..6).map(|_| svc.submit_potrs(a.clone(), b.clone()).unwrap()).collect();
    svc.inject_straggler(1, 4.0).unwrap();
    assert!(svc.degraded(), "drag on device 1 must flip the degraded signal");
    for h in handles {
        let (x, _) = h.wait();
        assert!(x.rel_err(&xt) < tol_for::<f64>(n) * 10.0, "request lost under straggler");
    }
    // Degraded-mode SLO accounting: an already-expired deadline is a
    // miss even against the relaxed (degrade_factor-scaled) budget.
    let slo = Slo::interactive().with_deadline_ns(1);
    let (x, _) = svc.submit_potrs_slo(a.clone(), b.clone(), slo).unwrap().wait();
    assert!(x.rel_err(&xt) < tol_for::<f64>(n) * 10.0);
    svc.drain();
    let m = node.metrics().snapshot();
    let i = SloClass::Interactive.index();
    assert_eq!(m.class_completed[i], 1);
    assert_eq!(m.class_deadline_misses[i], 1);
    svc.clear_straggler(1).unwrap();
    assert!(!svc.degraded());
    assert_eq!(svc.alive_workers().len(), NDEV, "stragglers are slow, not dead");
    assert_eq!(svc.reserved(), vec![0; NDEV]);
}

#[test]
fn zero_wall_dwell_mpmd_front_polls_instead_of_spinning() {
    // A zero wall-dwell policy used to drive the dispatcher's flush
    // cadence to zero (busy-spin); `flusher_tick`'s floor clamp keeps
    // it polling, and the stranded bucket still flushes.
    let node = SimNode::new_uniform(2, 1 << 22);
    let mut cfg = MpmdConfig::with_tile(16);
    cfg.policy.max_batch = 32;
    cfg.policy.max_dwell_ns = u64::MAX;
    cfg.policy.max_wall_dwell = Duration::ZERO;
    let svc = MpmdService::with_config(node, cfg);
    let h = svc.submit_small(SmallRoutine::Potrf, Matrix::<f64>::spd_random(8, 1), None).unwrap();
    let (l, stats) = h.wait();
    assert_eq!(l.rows(), 8);
    assert_eq!(stats.batch_size, 1);
    assert_eq!(svc.pending_small(), 0);
}

#[test]
fn ipc_misuse_is_typed_error_ipc() {
    let node = SimNode::new_uniform(2, 1 << 20);
    let reg = IpcRegistry::new();
    let ptr = node.alloc(1, 128).unwrap();
    let h = reg.export_bound(AddressSpace(1), &node, ptr).unwrap();
    // Self-open: CUDA forbids opening one's own export.
    match reg.open(AddressSpace(1), h) {
        Err(Error::Ipc(msg)) => assert!(msg.contains("exporting process"), "{msg}"),
        other => panic!("self-open must be Error::Ipc, got {other:?}"),
    }
    // Double-open in one space.
    reg.open(AddressSpace(0), h).unwrap();
    match reg.open(AddressSpace(0), h) {
        Err(Error::Ipc(msg)) => assert!(msg.contains("already open"), "{msg}"),
        other => panic!("double-open must be Error::Ipc, got {other:?}"),
    }
    reg.close(AddressSpace(0), h).unwrap();
    // Stale-after-free: the hardening bugfix.
    node.free(ptr).unwrap();
    match reg.open(AddressSpace(0), h) {
        Err(Error::Ipc(msg)) => assert!(msg.contains("stale"), "{msg}"),
        other => panic!("stale open must be Error::Ipc, got {other:?}"),
    }
}

#[test]
fn mpmd_overhead_is_charged_onto_the_timeline() {
    // The same potrs through both fronts: the MPMD projection carries
    // the cudaIpc round-trip the predictor pins, the SPMD one does not.
    let n = 32;
    let a = Matrix::<f64>::spd_random(n, 21);
    let b = Matrix::<f64>::ones(n, 1);

    let spmd_node = SimNode::new_uniform(NDEV, 1 << 24);
    {
        let mut cfg = SmallConfig::with_tile(TILE);
        cfg.policy.small_dim = 0;
        let svc = SolveService::with_small_config(spmd_node.clone(), 1, cfg);
        svc.submit_small(SmallRoutine::Potrs, a.clone(), Some(b.clone())).unwrap().wait();
        svc.drain();
    }
    let mpmd_node = SimNode::new_uniform(NDEV, 1 << 24);
    {
        let svc = MpmdService::with_config(mpmd_node.clone(), MpmdConfig::with_tile(TILE));
        svc.submit_potrs(a, b).unwrap().wait();
        svc.drain();
    }
    let gap = mpmd_node.sim_time() - spmd_node.sim_time();
    let model = jaxmg::costmodel::Predictor {
        model: jaxmg::costmodel::GpuCostModel::h200(),
        topo: mpmd_node.topology().clone(),
        dtype: jaxmg::scalar::DType::F64,
    };
    let predicted = model.mpmd_overhead(NDEV);
    assert!(
        (gap - predicted).abs() < 1e-12,
        "charged MPMD overhead {gap} != predicted {predicted}"
    );
}
