//! Property tests for the batched small-solve subsystem.
//!
//! The two acceptance claims:
//!
//! 1. **Bitwise identity** — for every dtype, a coalesced batch of `B`
//!    small solves produces results bitwise-identical to the `B`
//!    solves run individually (batch-of-one pods *and* the distributed
//!    single-tile path).
//! 2. **Throughput** — the batched sweep's simulated makespan is
//!    strictly below the serial one-at-a-time distributed path for a
//!    256-solve small-matrix workload, both driving the sweeps
//!    directly and end-to-end through `SolveService::submit_small`.

use jaxmg::batch::{potrf_batched, potri_batched, potrs_batched, PackedPod, SmallRoutine};
use jaxmg::coordinator::SmallConfig;
use jaxmg::costmodel::GpuCostModel;
use jaxmg::layout::BlockCyclic1D;
use jaxmg::linalg::Matrix;
use jaxmg::prelude::*;
use jaxmg::solver::{potrf_dist, potri_dist, potrs_dist, Ctx};
use jaxmg::tile::{DistMatrix, Layout1D};

fn ctx_parts() -> GpuCostModel {
    GpuCostModel::h200()
}

/// Solve one system through the distributed path with a single tile
/// (tile ≥ n), which runs the same whole-system kernel sequence the
/// batched sweeps use.
fn distributed_one<S: Scalar>(
    routine: SmallRoutine,
    a: &Matrix<S>,
    b: Option<&Matrix<S>>,
) -> Matrix<S> {
    let node = SimNode::new_uniform(4, 1 << 24);
    let model = ctx_parts();
    let backend = SolverBackend::<S>::Native;
    let ctx = Ctx::new(&node, &model, &backend);
    let n = a.rows();
    let lay = Layout1D::BlockCyclic(BlockCyclic1D::new(n, n.max(1), 4).unwrap());
    let mut dm = DistMatrix::scatter(&node, a, lay).unwrap();
    potrf_dist(&ctx, &mut dm).unwrap();
    match routine {
        SmallRoutine::Potrf => dm.gather().unwrap(),
        SmallRoutine::Potrs => potrs_dist(&ctx, &dm, b.unwrap()).unwrap(),
        SmallRoutine::Potri => {
            potri_dist(&ctx, &mut dm).unwrap();
            dm.gather().unwrap()
        }
    }
}

/// Run `systems` through one coalesced batch of size B.
fn batched_all<S: Scalar>(
    routine: SmallRoutine,
    systems: &[Matrix<S>],
    rhss: &[Matrix<S>],
) -> Vec<Matrix<S>> {
    let node = SimNode::new_uniform(4, 1 << 24);
    let model = ctx_parts();
    let backend = SolverBackend::<S>::Native;
    let ctx = Ctx::new(&node, &model, &backend);
    let mut pod = PackedPod::pack(&node, systems).unwrap();
    potrf_batched(&ctx, &mut pod).unwrap();
    match routine {
        SmallRoutine::Potrf => pod.gather().unwrap(),
        SmallRoutine::Potrs => {
            let mut pod_b = PackedPod::pack(&node, rhss).unwrap();
            potrs_batched(&ctx, &pod, &mut pod_b).unwrap();
            pod_b.gather().unwrap()
        }
        SmallRoutine::Potri => {
            potri_batched(&ctx, &mut pod).unwrap();
            pod.gather().unwrap()
        }
    }
}

fn bitwise_identity_for<S: Scalar>() {
    let b = 6usize;
    let systems: Vec<Matrix<S>> =
        (0..b).map(|i| Matrix::spd_random(8 + i, 100 + i as u64)).collect();
    let rhss: Vec<Matrix<S>> = (0..b).map(|i| Matrix::random(8 + i, 2, 200 + i as u64)).collect();
    for routine in [SmallRoutine::Potrf, SmallRoutine::Potrs, SmallRoutine::Potri] {
        let coalesced = batched_all(routine, &systems, &rhss);
        for i in 0..b {
            // Individually = a batch of one.
            let solo = batched_all(routine, &systems[i..i + 1], &rhss[i..i + 1]);
            assert_eq!(
                coalesced[i].as_slice(),
                solo[0].as_slice(),
                "batch-of-{b} != batch-of-1 ({routine:?}, {:?}, system {i})",
                S::DTYPE
            );
            // And the distributed path run one system at a time.
            let dist = distributed_one(routine, &systems[i], Some(&rhss[i]));
            assert_eq!(
                coalesced[i].as_slice(),
                dist.as_slice(),
                "batch != distributed single solve ({routine:?}, {:?}, system {i})",
                S::DTYPE
            );
        }
    }
}

#[test]
fn coalesced_batch_is_bitwise_identical_f32() {
    bitwise_identity_for::<f32>();
}

#[test]
fn coalesced_batch_is_bitwise_identical_f64() {
    bitwise_identity_for::<f64>();
}

#[test]
fn coalesced_batch_is_bitwise_identical_c64() {
    bitwise_identity_for::<c32>();
}

#[test]
fn coalesced_batch_is_bitwise_identical_c128() {
    bitwise_identity_for::<c64>();
}

/// The acceptance workload: 256 small potrs solves. The batched sweep
/// (pack → fused potrf/potrs → gather) must beat 256 one-at-a-time
/// distributed solves (scatter → potrf_dist → potrs_dist → gather) on
/// the simulated clock — strictly.
#[test]
fn batched_sweep_beats_serial_on_256_solve_workload() {
    let b = 256usize;
    let n = 16usize;
    let ndev = 8usize;
    let systems: Vec<Matrix<f64>> = (0..b).map(|i| Matrix::spd_random(n, i as u64)).collect();
    let rhss: Vec<Matrix<f64>> =
        (0..b).map(|i| Matrix::random(n, 1, 1000 + i as u64)).collect();
    let model = ctx_parts();
    let backend = SolverBackend::<f64>::Native;

    // Batched: one pod pair, two fused sweeps, one gather.
    let node_b = SimNode::new_uniform(ndev, 1 << 26);
    let ctx_b = Ctx::new(&node_b, &model, &backend);
    let mut pod = PackedPod::pack(&node_b, &systems).unwrap();
    let mut pod_rhs = PackedPod::pack(&node_b, &rhss).unwrap();
    potrf_batched(&ctx_b, &mut pod).unwrap();
    potrs_batched(&ctx_b, &pod, &mut pod_rhs).unwrap();
    let batched = pod_rhs.gather().unwrap();
    let t_batched = node_b.sim_time();

    // Serial: 256 full distributed solves, one after another.
    let node_s = SimNode::new_uniform(ndev, 1 << 26);
    let ctx_s = Ctx::new(&node_s, &model, &backend);
    let lay = Layout1D::BlockCyclic(BlockCyclic1D::new(n, 8, ndev).unwrap());
    let mut serial = Vec::with_capacity(b);
    for i in 0..b {
        let mut dm = DistMatrix::scatter(&node_s, &systems[i], lay).unwrap();
        potrf_dist(&ctx_s, &mut dm).unwrap();
        serial.push(potrs_dist(&ctx_s, &dm, &rhss[i]).unwrap());
        dm.free().unwrap();
    }
    let t_serial = node_s.sim_time();

    assert!(
        t_batched < t_serial,
        "batched makespan {t_batched} !< serial {t_serial} for the 256-solve workload"
    );
    // The win is structural (launch fusion + no collectives), not noise.
    assert!(t_serial / t_batched > 10.0, "win too thin: {}", t_serial / t_batched);
    // Same numerics up to the schedule: serial used a 2-tile blocked
    // factorization, so compare against the reference, not bitwise.
    for i in 0..b {
        let diff = batched[i].sub(&serial[i]).norm_fro() / serial[i].norm_fro().max(1e-300);
        assert!(diff < 1e-10, "solve {i} diverged between paths: {diff}");
    }
    // The batched path moved no peer bytes at all.
    assert_eq!(node_b.metrics().snapshot().peer_bytes, 0);
}

/// End-to-end through the service: the same mixed stream of small
/// solves, once with coalescing on and once forced distributed.
#[test]
fn service_makespan_batched_beats_distributed() {
    let b = 64usize;
    let n = 12usize;
    let systems: Vec<Matrix<f64>> = (0..b).map(|i| Matrix::spd_random(n, i as u64)).collect();
    let rhss: Vec<Matrix<f64>> = (0..b).map(|i| Matrix::random(n, 1, 500 + i as u64)).collect();

    let run = |small_dim: usize| -> (f64, u64) {
        let node = SimNode::new_uniform(4, 1 << 26);
        let mut cfg = SmallConfig::with_tile(8);
        cfg.policy.max_batch = 32;
        cfg.policy.small_dim = small_dim;
        let svc = SolveService::with_small_config(node.clone(), 2, cfg);
        let handles: Vec<_> = systems
            .iter()
            .zip(&rhss)
            .map(|(a, rhs)| {
                svc.submit_small(SmallRoutine::Potrs, a.clone(), Some(rhs.clone())).unwrap()
            })
            .collect();
        svc.flush_small();
        for h in handles {
            let (x, _) = h.wait();
            assert_eq!(x.rows(), n);
        }
        svc.drain();
        (node.sim_time(), node.metrics().snapshot().batch_solves)
    };

    let (t_batched, coalesced) = run(4 * 8);
    let (t_distributed, coalesced_off) = run(0);
    assert_eq!(coalesced, b as u64, "every small solve must coalesce");
    assert_eq!(coalesced_off, 0, "small_dim = 0 must force the distributed path");
    assert!(
        t_batched < t_distributed,
        "service batched makespan {t_batched} !< distributed {t_distributed}"
    );
}
