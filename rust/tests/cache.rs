//! Factor-cache + solve-DAG property suite.
//!
//! Pins the acceptance criteria of the caching layer:
//! * a repeat solve against the same `A` hits the resident factor and
//!   is **bitwise identical** to the cold path, for all four dtypes on
//!   both a 1D layout and a pinned 2×2 grid;
//! * a fused `potrf→potrs→potri` DAG matches three separate cold
//!   submits bitwise, for all four dtypes;
//! * resident factors and in-flight solves share one admission budget:
//!   the per-device accountant never passes capacity under concurrent
//!   repeat traffic, and pressure evicts rather than blocks;
//! * eviction leaves lowest recompute-cost × reuse first, LRU on ties;
//! * on the MPMD front, killing a worker drops every factor staged on
//!   it and loses zero requests; straggler injection invalidates too.

use jaxmg::coordinator::{
    DistRoutine, FactorCache, FactorKey, SmallConfig, SolveDag, SolveService,
};
use jaxmg::layout::BlockCyclic1D;
use jaxmg::linalg::{tol_for, FrobNorm, Matrix};
use jaxmg::prelude::*;
use jaxmg::scalar::{c32, c64, DType};
use jaxmg::serve::{MpmdConfig, MpmdService};
use jaxmg::tile::LayoutKind;

const TILE: usize = 8;
const NDEV: usize = 4;

fn cached_service(node: &SimNode, grid: Option<(usize, usize)>) -> SolveService {
    let mut cfg = SmallConfig::with_tile(TILE);
    cfg.factor_cache = true;
    cfg.grid = grid;
    SolveService::with_small_config(node.clone(), 2, cfg)
}

// ---------------------------------------------------------------------------
// Bitwise-identical hits, 4 dtypes × {1D, 2×2}
// ---------------------------------------------------------------------------

fn hit_matches_cold_bitwise<S: Scalar>(seed: u64, grid: Option<(usize, usize)>) {
    let node = SimNode::new_uniform(NDEV, 1 << 24);
    let svc = cached_service(&node, grid);
    let n = 24;
    let a = Matrix::<S>::spd_random(n, seed);
    let b = Matrix::<S>::random(n, 2, seed + 9);
    let (cold, s0) =
        svc.submit_dist(DistRoutine::Potrs, a.clone(), Some(b.clone())).unwrap().wait();
    assert!(!s0.cache_hit, "first sight of A cannot hit");
    assert_eq!(svc.cached_factors(), 1, "the cold factor must become resident");
    let (hot, s1) =
        svc.submit_dist(DistRoutine::Potrs, a.clone(), Some(b.clone())).unwrap().wait();
    assert!(s1.cache_hit, "repeat solve must hit the resident factor");
    assert_eq!(cold.as_slice(), hot.as_slice(), "cached solve diverges from cold");
    // potri rides the same resident L; its cold reference runs on a
    // fresh service so nothing is cached there.
    let (inv_hot, s2) = svc.submit_dist(DistRoutine::Potri, a.clone(), None).unwrap().wait();
    assert!(s2.cache_hit, "potri must reuse the cached factor");
    let node2 = SimNode::new_uniform(NDEV, 1 << 24);
    let svc2 = cached_service(&node2, grid);
    let (inv_cold, s3) = svc2.submit_dist(DistRoutine::Potri, a.clone(), None).unwrap().wait();
    assert!(!s3.cache_hit);
    assert_eq!(inv_cold.as_slice(), inv_hot.as_slice(), "cached potri diverges from cold");
    assert_eq!(svc2.cached_factors(), 0, "potri destroys L and must not seed the cache");
    let m = node.metrics().snapshot();
    assert!(m.cache_hits >= 2 && m.cache_misses >= 1, "probes must be visible in metrics");
    svc.drain();
    svc2.drain();
}

#[test]
fn hits_are_bitwise_identical_f32() {
    hit_matches_cold_bitwise::<f32>(101, None);
    hit_matches_cold_bitwise::<f32>(102, Some((2, 2)));
}

#[test]
fn hits_are_bitwise_identical_f64() {
    hit_matches_cold_bitwise::<f64>(103, None);
    hit_matches_cold_bitwise::<f64>(104, Some((2, 2)));
}

#[test]
fn hits_are_bitwise_identical_c64() {
    hit_matches_cold_bitwise::<c32>(105, None);
    hit_matches_cold_bitwise::<c32>(106, Some((2, 2)));
}

#[test]
fn hits_are_bitwise_identical_c128() {
    hit_matches_cold_bitwise::<c64>(107, None);
    hit_matches_cold_bitwise::<c64>(108, Some((2, 2)));
}

#[test]
fn syevd_bypasses_the_cache() {
    let node = SimNode::new_uniform(NDEV, 1 << 24);
    let svc = cached_service(&node, None);
    let a = Matrix::<f64>::spd_random(24, 55);
    let _ = svc.submit_syevd(a).unwrap().wait();
    assert_eq!(svc.cached_factors(), 0, "syevd shares no potrf prefix");
    let m = node.metrics().snapshot();
    assert_eq!(m.cache_hits + m.cache_misses, 0, "syevd must not even probe");
    svc.drain();
}

// ---------------------------------------------------------------------------
// Fused DAGs, 4 dtypes (grid pinned so cold references share the layout)
// ---------------------------------------------------------------------------

fn fused_dag_matches_cold<S: Scalar>(seed: u64) {
    let node = SimNode::new_uniform(NDEV, 1 << 24);
    let svc = cached_service(&node, Some((2, 2)));
    let n = 24;
    let a = Matrix::<S>::spd_random(n, seed);
    let b = Matrix::<S>::random(n, 3, seed + 5);
    let handles = svc
        .submit_dag(SolveDag::new(a.clone()).factor().solve(b.clone()).inverse())
        .unwrap();
    assert_eq!(handles.len(), 3, "one handle per stage");
    let mut fused = Vec::new();
    for h in handles {
        let (x, s) = h.wait();
        assert_eq!(s.fused_stages, 3, "every stage publishes the fused stage count");
        fused.push(x);
    }
    // Cold references: three separate submits on a fresh uncached
    // service with the same pinned grid.
    let node2 = SimNode::new_uniform(NDEV, 1 << 24);
    let mut cfg = SmallConfig::with_tile(TILE);
    cfg.grid = Some((2, 2));
    let svc2 = SolveService::with_small_config(node2, 2, cfg);
    let (l, _) = svc2.submit_dist(DistRoutine::Potrf, a.clone(), None).unwrap().wait();
    let (x, _) = svc2.submit_dist(DistRoutine::Potrs, a.clone(), Some(b.clone())).unwrap().wait();
    let (inv, _) = svc2.submit_dist(DistRoutine::Potri, a.clone(), None).unwrap().wait();
    assert_eq!(fused[0].as_slice(), l.as_slice(), "fused factor diverges from cold");
    assert_eq!(fused[1].as_slice(), x.as_slice(), "fused solve diverges from cold");
    assert_eq!(fused[2].as_slice(), inv.as_slice(), "fused inverse diverges from cold");
    let m = node.metrics().snapshot();
    assert!(m.dag_fused_stages >= 2, "fusion must be visible in metrics");
    svc.drain();
    svc2.drain();
}

#[test]
fn fused_dag_matches_cold_f32() {
    fused_dag_matches_cold::<f32>(201);
}

#[test]
fn fused_dag_matches_cold_f64() {
    fused_dag_matches_cold::<f64>(202);
}

#[test]
fn fused_dag_matches_cold_c64() {
    fused_dag_matches_cold::<c32>(203);
}

#[test]
fn fused_dag_matches_cold_c128() {
    fused_dag_matches_cold::<c64>(204);
}

// ---------------------------------------------------------------------------
// Shared admission budget
// ---------------------------------------------------------------------------

#[test]
fn resident_factors_share_the_admission_budget() {
    // VRAM small enough that ten resident factors cannot coexist with
    // in-flight solves: residency must yield (evictions), and the
    // per-device accountant must never pass capacity.
    let cap = 1 << 14;
    let node = SimNode::new_uniform(NDEV, cap);
    let svc = cached_service(&node, None);
    let n = 32;
    let mats: Vec<Matrix<f64>> =
        (0..10).map(|i| Matrix::<f64>::spd_random(n, 200 + i as u64)).collect();
    let mut handles = Vec::new();
    for round in 0..3u64 {
        for (i, a) in mats.iter().enumerate() {
            let b = Matrix::<f64>::random(n, 1, 300 + round * 10 + i as u64);
            handles.push(svc.submit_dist(DistRoutine::Potrs, a.clone(), Some(b)).unwrap());
        }
    }
    for h in handles {
        h.wait_result().expect("repeat traffic under pressure must not fail");
    }
    svc.drain();
    for (d, peak) in svc.peak_reserved().iter().enumerate() {
        assert!(*peak <= cap, "device {d} over-admitted: {peak} > {cap}");
    }
    let m = node.metrics().snapshot();
    assert!(m.cache_evictions > 0, "ten factors cannot all stay resident in {cap} B");
    // What stays reserved after the queue drains is exactly the
    // resident factors; evicting them all returns the accountant to 0.
    assert_eq!(svc.reserved().iter().sum::<usize>(), svc.cached_factor_bytes());
    svc.evict_cached_factors();
    assert_eq!(svc.reserved(), vec![0; NDEV], "eviction must release every resident byte");
    assert_eq!(svc.cached_factors(), 0);
}

// ---------------------------------------------------------------------------
// Eviction order
// ---------------------------------------------------------------------------

#[test]
fn eviction_order_follows_recompute_times_reuse() {
    let kind = LayoutKind::BlockCyclic(BlockCyclic1D::new(64, 16, 4).unwrap());
    let key = |content: u64| FactorKey { content, dtype: DType::F64, n: 64, tile: 16, grid: (1, 4) };
    let mut cache: FactorCache<u64> = FactorCache::new();
    // Recompute costs 100 / 10 / 40 ns.
    assert!(cache.insert(key(1), 1, kind, vec![8; 4], 100).is_none());
    assert!(cache.insert(key(2), 2, kind, vec![8; 4], 10).is_none());
    assert!(cache.insert(key(3), 3, kind, vec![8; 4], 40).is_none());
    // Reuse pumps entry 2's score past entry 3: 10·(4+1) = 50 > 40.
    for _ in 0..4 {
        assert!(cache.probe(&key(2)).is_some());
        assert!(cache.unpin(&key(2)).is_none());
    }
    let order: Vec<u64> =
        std::iter::from_fn(|| cache.pop_victim().map(|(_, e)| e.payload)).collect();
    assert_eq!(order, vec![3, 2, 1], "victims must leave lowest recompute×reuse first");
}

// ---------------------------------------------------------------------------
// MPMD: invalidation under failure, zero requests lost
// ---------------------------------------------------------------------------

#[test]
fn mpmd_kill_invalidates_residency_and_loses_nothing() {
    let node = SimNode::new_uniform(NDEV, 1 << 26);
    let mut cfg = MpmdConfig::with_tile(TILE);
    cfg.factor_cache = true;
    let svc = MpmdService::with_config(node.clone(), cfg);
    let n = 64;
    let a = Matrix::<f64>::spd_random(n, 91);
    let xt = Matrix::<f64>::random(n, 1, 92);
    let b = a.matmul(&xt);
    let (cold, s0) = svc.submit_potrs(a.clone(), b.clone()).unwrap().wait();
    assert!(!s0.cache_hit);
    assert_eq!(svc.cached_factors(), 1, "the mpmd cold factor must become resident");
    let (hot, s1) = svc.submit_potrs(a.clone(), b.clone()).unwrap().wait();
    assert!(s1.cache_hit, "mpmd repeat solve must hit");
    assert_eq!(cold.as_slice(), hot.as_slice(), "mpmd cached solve diverges from cold");
    // A burst of repeats in flight when a participant dies: residency
    // dies with the worker, every request still completes on the
    // survivors.
    let handles: Vec<_> =
        (0..6).map(|_| svc.submit_potrs(a.clone(), b.clone()).unwrap()).collect();
    svc.kill_worker(2).unwrap();
    assert_eq!(svc.cached_factors(), 0, "kill must drop factors staged on the dead worker");
    for h in handles {
        let (x, _) = h.wait();
        assert!(x.rel_err(&xt) < tol_for::<f64>(n) * 10.0, "request lost/corrupted by the kill");
    }
    // Post-kill traffic keeps flowing (and may re-cache on the shrunk
    // live set).
    let (x2, _) = svc.submit_potrs(a.clone(), b.clone()).unwrap().wait();
    assert!(x2.rel_err(&xt) < tol_for::<f64>(n) * 10.0);
    svc.drain();
    drop(svc);
    for rep in node.memory_reports() {
        assert_eq!(rep.used, 0, "cached shards must be freed at shutdown");
    }
}

#[test]
fn mpmd_straggler_injection_drops_residency() {
    let node = SimNode::new_uniform(NDEV, 1 << 26);
    let mut cfg = MpmdConfig::with_tile(TILE);
    cfg.factor_cache = true;
    let svc = MpmdService::with_config(node, cfg);
    let a = Matrix::<f64>::spd_random(32, 7);
    let b = Matrix::<f64>::random(32, 1, 8);
    let _ = svc.submit_potrs(a.clone(), b.clone()).unwrap().wait();
    assert_eq!(svc.cached_factors(), 1);
    svc.inject_straggler(1, 4.0).unwrap();
    assert_eq!(svc.cached_factors(), 0, "a degraded view invalidates resident factors");
    let (_, s) = svc.submit_potrs(a, b).unwrap().wait();
    assert!(!s.cache_hit, "the degraded repeat must refactor cold");
    svc.drain();
}
