//! Content-addressed factor cache: resident Cholesky factors as
//! first-class admitted footprint.
//!
//! The flagship workloads (GP posterior inverses, VMC stochastic
//! reconfiguration) re-solve against the same or slowly-varying SPD
//! matrix. A [`FactorCache`] keys each distributed factor `L` by a
//! content hash of `A`'s bytes plus the shape parameters that pin the
//! resident layout — dtype, `n`, tile, `(P, Q)` grid — and keeps the
//! factor's shards resident in device memory, so a repeat
//! `potrs`/`potri`/`potrf` skips the scatter and the factorization
//! entirely and runs only the triangular tail on the resident shards
//! (bitwise-identical to the cold path: the shards *are* the cold
//! path's bytes).
//!
//! The cache is deliberately a pure bookkeeping structure:
//!
//! * **Admission** stays with the caller's accountant. Resident bytes
//!   ([`Footprint::for_cached_factor`]) are charged against the same
//!   budget as in-flight solves — the SPMD service's central
//!   reservation table, the MPMD workers' per-device
//!   [`DeviceAdmission`] accountants — so factors and live work share
//!   one VRAM budget and the accountant never over-admits. When an
//!   admission fails, the caller pops victims ([`pop_victim`]) and
//!   frees/releases them itself, then retries.
//! * **Eviction order** is scored here:
//!   `recompute_ns × (hits + 1)` — the `Predictor`-estimated cost to
//!   rebuild the entry times its observed reuse — lowest score first,
//!   oldest-touch tiebreak. Pinned entries (a hit in flight) are never
//!   victims.
//! * **Invalidation** ([`invalidate`]) removes unpinned matching
//!   entries immediately and *dooms* pinned ones: a doomed entry stops
//!   matching probes and is handed back for teardown by the final
//!   [`unpin`] — resolving the invalidate-during-in-flight-hit race
//!   without blocking either side.
//!
//! The payload type `P` is generic because the two serving fronts keep
//! different things resident: the SPMD service holds the factor's
//! device panels (`Vec<DevPtr>`), the MPMD frontend holds per-worker
//! staged shards plus their IPC export handles.
//!
//! [`Footprint::for_cached_factor`]: super::Footprint::for_cached_factor
//! [`DeviceAdmission`]: super::DeviceAdmission
//! [`pop_victim`]: FactorCache::pop_victim
//! [`invalidate`]: FactorCache::invalidate
//! [`unpin`]: FactorCache::unpin

use std::collections::HashMap;

use crate::linalg::Matrix;
use crate::scalar::{DType, Scalar};
use crate::tile::LayoutKind;

/// FNV-1a over a byte stream — stable, dependency-free, and fast
/// enough that hashing a service-scale matrix is noise next to its
/// scatter.
fn fnv1a(bytes: &[u8], mut h: u64) -> u64 {
    const PRIME: u64 = 0x0000_0100_0000_01B3;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(PRIME);
    }
    h
}

const FNV_OFFSET: u64 = 0xCBF2_9CE4_8422_2325;

/// Content hash of a host matrix: FNV-1a over its column-major bytes,
/// seeded with the dimensions and dtype tag so equal byte patterns of
/// different shapes cannot collide structurally.
pub fn content_hash<S: Scalar>(a: &Matrix<S>) -> u64 {
    let mut h = fnv1a(&(a.rows() as u64).to_le_bytes(), FNV_OFFSET);
    h = fnv1a(&(a.cols() as u64).to_le_bytes(), h);
    h = fnv1a(&[S::DTYPE.size_of() as u8, S::DTYPE.is_complex() as u8], h);
    fnv1a(crate::device::as_bytes(a.as_slice()), h)
}

/// Identity of a cached factor: the content hash of `A` plus every
/// parameter that determines the resident shards' bytes and layout.
/// The consuming *routine* is deliberately excluded — a factor seeded
/// by a cold `potrf` or `potrs` serves later `potrs`/`potri`/`potrf`
/// repeats alike, because all three share the identical
/// scatter+factor prefix.
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
pub struct FactorKey {
    /// [`content_hash`] of the submitted `A`.
    pub content: u64,
    pub dtype: DType,
    pub n: usize,
    pub tile: usize,
    /// The `(P, Q)` process grid of the resident layout.
    pub grid: (usize, usize),
}

impl FactorKey {
    /// Key for `a` factored with `tile` on `grid`.
    pub fn of<S: Scalar>(a: &Matrix<S>, tile: usize, grid: (usize, usize)) -> Self {
        FactorKey { content: content_hash(a), dtype: S::DTYPE, n: a.rows(), tile, grid }
    }
}

/// One resident factor.
#[derive(Debug)]
pub struct FactorEntry<P> {
    /// Front-specific handle to the resident shards.
    pub payload: P,
    /// The layout the shards are stored in (what a hit reconstructs
    /// its [`crate::tile::DistMatrix`] view from).
    pub kind: LayoutKind,
    /// Bytes charged per device for the resident shards.
    pub resident: Vec<usize>,
    /// Predicted cost to rebuild this factor (scatter + potrf), in
    /// cost-model ns — [`crate::costmodel::Predictor::recompute_ns`].
    pub recompute_ns: u64,
    /// Hits observed since insert.
    pub hits: u64,
    pins: u32,
    doomed: bool,
    stamp: u64,
}

impl<P> FactorEntry<P> {
    /// Total resident bytes across devices.
    pub fn resident_bytes(&self) -> usize {
        self.resident.iter().sum()
    }

    /// Eviction score: predicted recompute cost × observed reuse
    /// (`hits + 1` so a fresh entry is worth one rebuild). Lowest
    /// score evicts first.
    pub fn score(&self) -> u64 {
        self.recompute_ns.saturating_mul(self.hits + 1)
    }
}

/// The cache proper. All methods take `&mut self`; both fronts wrap it
/// in a `Mutex` (lock order: cache before the admission state, and
/// never held across a solve).
#[derive(Debug)]
pub struct FactorCache<P> {
    entries: HashMap<FactorKey, FactorEntry<P>>,
    clock: u64,
}

impl<P> Default for FactorCache<P> {
    fn default() -> Self {
        Self::new()
    }
}

impl<P> FactorCache<P> {
    pub fn new() -> Self {
        FactorCache { entries: HashMap::new(), clock: 0 }
    }

    /// Live (non-doomed) entries.
    pub fn len(&self) -> usize {
        self.entries.values().filter(|e| !e.doomed).count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total resident bytes across live entries.
    pub fn resident_bytes(&self) -> usize {
        self.entries.values().filter(|e| !e.doomed).map(|e| e.resident_bytes()).sum()
    }

    /// Whether a live entry exists for `key`.
    pub fn contains(&self, key: &FactorKey) -> bool {
        self.entries.get(key).is_some_and(|e| !e.doomed)
    }

    /// Probe for `key`: on a live entry, pin it (it cannot be evicted
    /// until [`Self::unpin`]), count a hit, touch its LRU stamp, and
    /// return a clone of the payload plus the resident layout. Doomed
    /// entries never match.
    pub fn probe(&mut self, key: &FactorKey) -> Option<(P, LayoutKind)>
    where
        P: Clone,
    {
        self.clock += 1;
        let clock = self.clock;
        let e = self.entries.get_mut(key).filter(|e| !e.doomed)?;
        e.pins += 1;
        e.hits += 1;
        e.stamp = clock;
        Some((e.payload.clone(), e.kind))
    }

    /// Drop one pin taken by [`Self::probe`]. If the entry was doomed
    /// while pinned and this was the last pin, it is removed and
    /// returned for teardown (the caller frees the shards and releases
    /// the admission charge).
    pub fn unpin(&mut self, key: &FactorKey) -> Option<FactorEntry<P>> {
        let e = self.entries.get_mut(key)?;
        e.pins = e.pins.saturating_sub(1);
        if e.doomed && e.pins == 0 {
            return self.entries.remove(key);
        }
        None
    }

    /// Insert a freshly factored entry (unpinned, zero hits). The
    /// caller has already charged `resident` against its accountant.
    ///
    /// First insert wins: if the key is already occupied — two
    /// identical requests raced cold, or a doomed entry is still
    /// awaiting its last unpin — the duplicate is refused and handed
    /// back as a [`FactorEntry`] for the caller to tear down (free the
    /// shards, release the charge). Displacing in place would orphan
    /// any pin held on the resident entry.
    pub fn insert(
        &mut self,
        key: FactorKey,
        payload: P,
        kind: LayoutKind,
        resident: Vec<usize>,
        recompute_ns: u64,
    ) -> Option<FactorEntry<P>> {
        self.clock += 1;
        let entry = FactorEntry {
            payload,
            kind,
            resident,
            recompute_ns,
            hits: 0,
            pins: 0,
            doomed: false,
            stamp: self.clock,
        };
        if self.entries.contains_key(&key) {
            return Some(entry);
        }
        self.entries.insert(key, entry);
        None
    }

    /// Pop the eviction victim: the unpinned live entry with the
    /// lowest `score()`, oldest stamp on ties. `None` when everything
    /// is pinned (or the cache is empty) — the caller then gives up on
    /// making room rather than blocking.
    pub fn pop_victim(&mut self) -> Option<(FactorKey, FactorEntry<P>)> {
        let key = self
            .entries
            .iter()
            .filter(|(_, e)| e.pins == 0 && !e.doomed)
            .min_by_key(|(_, e)| (e.score(), e.stamp))
            .map(|(k, _)| *k)?;
        let e = self.entries.remove(&key).expect("victim just selected");
        Some((key, e))
    }

    /// Invalidate every entry matching `pred` (e.g. "touches device
    /// `d`" after a worker death, or "resident on a now-degraded
    /// subset view"). Unpinned matches are removed and returned for
    /// teardown; pinned matches are doomed — they stop matching
    /// probes, and the in-flight hit's final [`Self::unpin`] returns
    /// them for teardown instead.
    pub fn invalidate<F>(&mut self, mut pred: F) -> Vec<(FactorKey, FactorEntry<P>)>
    where
        F: FnMut(&FactorKey, &FactorEntry<P>) -> bool,
    {
        let keys: Vec<FactorKey> = self
            .entries
            .iter()
            .filter(|(k, e)| !e.doomed && pred(k, e))
            .map(|(k, _)| *k)
            .collect();
        let mut out = Vec::new();
        for k in keys {
            let pinned = self.entries.get(&k).map(|e| e.pins > 0).unwrap_or(false);
            if pinned {
                self.entries.get_mut(&k).expect("present").doomed = true;
            } else if let Some(e) = self.entries.remove(&k) {
                out.push((k, e));
            }
        }
        out
    }

    /// Remove everything removable (shutdown): every unpinned entry,
    /// doomed or not. Pinned entries are doomed and left for their
    /// unpins.
    pub fn drain(&mut self) -> Vec<(FactorKey, FactorEntry<P>)> {
        let keys: Vec<FactorKey> = self.entries.keys().copied().collect();
        let mut out = Vec::new();
        for k in keys {
            let pinned = self.entries.get(&k).map(|e| e.pins > 0).unwrap_or(false);
            if pinned {
                self.entries.get_mut(&k).expect("present").doomed = true;
            } else if let Some(e) = self.entries.remove(&k) {
                out.push((k, e));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::layout::BlockCyclic1D;

    fn kind() -> LayoutKind {
        LayoutKind::BlockCyclic(BlockCyclic1D::new(64, 16, 4).unwrap())
    }

    fn key(content: u64) -> FactorKey {
        FactorKey { content, dtype: DType::F64, n: 64, tile: 16, grid: (1, 4) }
    }

    #[test]
    fn content_hash_is_content_addressed() {
        let a = Matrix::<f64>::spd_random(32, 7);
        let b = Matrix::<f64>::spd_random(32, 7);
        let c = Matrix::<f64>::spd_random(32, 8);
        assert_eq!(content_hash(&a), content_hash(&b), "equal bytes must hash equal");
        assert_ne!(content_hash(&a), content_hash(&c), "different seeds must split");
        // dtype participates even when the byte pattern could agree.
        let f = Matrix::<f32>::zeros(8, 8);
        let d = Matrix::<f64>::zeros(4, 8);
        assert_ne!(content_hash(&f), content_hash(&d));
    }

    #[test]
    fn probe_pins_and_counts_hits() {
        let mut c: FactorCache<u32> = FactorCache::new();
        assert!(c.probe(&key(1)).is_none());
        c.insert(key(1), 10, kind(), vec![8; 4], 1000);
        assert_eq!(c.resident_bytes(), 32);
        let (p, _) = c.probe(&key(1)).expect("hit");
        assert_eq!(p, 10);
        // Pinned: not a victim.
        assert!(c.pop_victim().is_none());
        assert!(c.unpin(&key(1)).is_none());
        let (k, e) = c.pop_victim().expect("unpinned now");
        assert_eq!(k, key(1));
        assert_eq!(e.hits, 1);
        assert!(c.is_empty());
    }

    #[test]
    fn eviction_honors_recompute_times_reuse() {
        let mut c: FactorCache<u32> = FactorCache::new();
        // cheap-to-rebuild, never reused → lowest score, first victim.
        c.insert(key(1), 1, kind(), vec![1; 4], 100);
        // expensive, never reused.
        c.insert(key(2), 2, kind(), vec![1; 4], 10_000);
        // cheap but hot: 100 × (3+1) > 100 × 1 and < 10_000.
        c.insert(key(3), 3, kind(), vec![1; 4], 100);
        for _ in 0..3 {
            c.probe(&key(3)).expect("hit");
            c.unpin(&key(3));
        }
        let order: Vec<u32> = std::iter::from_fn(|| c.pop_victim().map(|(_, e)| e.payload))
            .collect();
        assert_eq!(order, vec![1, 3, 2], "victims must leave in score order");
    }

    #[test]
    fn lru_breaks_score_ties_and_first_insert_wins() {
        let mut c: FactorCache<u32> = FactorCache::new();
        c.insert(key(1), 1, kind(), vec![1; 4], 500);
        c.insert(key(2), 2, kind(), vec![1; 4], 500);
        // Equal scores: the earlier-stamped entry is the victim.
        let (_, first) = c.pop_victim().expect("victim");
        assert_eq!(first.payload, 1, "equal scores: older stamp evicts first");
        // A raced duplicate insert is refused and handed back intact.
        let dup = c.insert(key(2), 22, kind(), vec![3; 4], 500).expect("refused");
        assert_eq!(dup.payload, 22);
        assert_eq!(dup.resident_bytes(), 12);
        let (_, kept) = c.pop_victim().expect("original stays");
        assert_eq!(kept.payload, 2);
    }

    #[test]
    fn invalidate_dooms_pinned_entries_until_unpin() {
        let mut c: FactorCache<u32> = FactorCache::new();
        c.insert(key(1), 1, kind(), vec![4; 4], 100);
        c.insert(key(2), 2, kind(), vec![4; 4], 100);
        c.probe(&key(1)).expect("hit");
        let gone = c.invalidate(|_, _| true);
        assert_eq!(gone.len(), 1, "unpinned entry removed immediately");
        assert_eq!(gone[0].1.payload, 2);
        // Doomed entry no longer matches probes or victims.
        assert!(c.probe(&key(1)).is_none());
        assert!(c.pop_victim().is_none());
        assert_eq!(c.len(), 0);
        // The in-flight hit's unpin hands it back for teardown.
        let e = c.unpin(&key(1)).expect("doomed entry returned at last unpin");
        assert_eq!(e.payload, 1);
        assert!(c.entries.is_empty());
    }

    #[test]
    fn drain_empties_the_cache() {
        let mut c: FactorCache<u32> = FactorCache::new();
        c.insert(key(1), 1, kind(), vec![1; 4], 1);
        c.insert(key(2), 2, kind(), vec![1; 4], 1);
        assert_eq!(c.drain().len(), 2);
        assert!(c.is_empty());
    }
}
