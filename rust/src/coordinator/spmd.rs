//! SPMD execution driver (Fig. 2, left).
//!
//! `shard_map` launches one thread per GPU; all threads share one
//! virtual address space, so each worker simply writes its shard's
//! device pointer into a shared table (the POSIX-shm analogue) and the
//! single caller (the coordinator thread) gathers all of them.

use crate::device::{DevPtr, SimNode};
use crate::error::Result;
use crate::ipc::SharedPtrTable;
use std::sync::Arc;
use std::time::Duration;

/// Spawn one worker thread per device; worker `d` publishes `panels[d]`
/// into the shared table; the caller gathers all pointers.
///
/// Returns the pointers in device order, as the single caller sees them.
pub fn gather_pointers_spmd(node: &SimNode, panels: Vec<DevPtr>) -> Result<Vec<DevPtr>> {
    let ndev = node.num_devices();
    assert_eq!(panels.len(), ndev);
    let table = Arc::new(SharedPtrTable::new(ndev));

    std::thread::scope(|scope| -> Result<()> {
        for (d, ptr) in panels.iter().enumerate() {
            let table = table.clone();
            let ptr = *ptr;
            scope.spawn(move || {
                // Worker d: "this is my shard" (the shard_map body).
                table.publish(d, ptr).expect("worker publish");
            });
        }
        Ok(())
    })?;

    // Single caller: wait for every worker, then proceed with all pointers.
    table.gather(Duration::from_secs(10))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spmd_gathers_all_pointers_in_order() {
        let node = SimNode::new_uniform(4, 1 << 20);
        let panels: Vec<DevPtr> = (0..4).map(|d| node.alloc(d, 64).unwrap()).collect();
        let gathered = gather_pointers_spmd(&node, panels.clone()).unwrap();
        assert_eq!(gathered, panels);
    }

    #[test]
    fn spmd_single_device() {
        let node = SimNode::new_uniform(1, 1 << 20);
        let panels = vec![node.alloc(0, 16).unwrap()];
        let gathered = gather_pointers_spmd(&node, panels.clone()).unwrap();
        assert_eq!(gathered, panels);
    }
}
