//! The JAXMg front end: mesh + partition specs + the `potrs` / `potri`
//! / `syevd` entry points, wired through the SPMD/MPMD single-caller
//! machinery exactly as the paper describes.
//!
//! A call like the paper's
//!
//! ```python
//! mesh = jax.make_mesh((jax.device_count(),), ("x",))
//! out  = potrs(A, b, T_A=T_A, mesh=mesh, in_specs=(P("x", None), P(None, None)))
//! ```
//!
//! maps to
//!
//! ```no_run
//! # use jaxmg::prelude::*;
//! let node = SimNode::new_uniform(8, 1 << 30);
//! let mesh = Mesh::new_1d(node, "x");
//! let ctx  = JaxMg::builder().mesh(mesh).tile_size(256).build().unwrap();
//! let a = Matrix::<f32>::spd_diag(1024);
//! let b = Matrix::<f32>::ones(1024, 1);
//! let x = ctx.potrs(&a, &b).unwrap();
//! ```
//!
//! Internally each entry point follows the pipeline of §2:
//! 1. `device_put` the operands per the in_specs ([`PartitionSpec`]);
//! 2. worker-per-device pointer publication and single-caller gather
//!    (threads + shm table in SPMD, simulated processes + `cudaIpc`
//!    handles in MPMD — [`ExecMode`]);
//! 3. in-place redistribution to the 1D block-cyclic layout (§2.1);
//! 4. the distributed solve (`crate::solver`);
//! 5. gather of the replicated / distributed outputs.
//!
//! ## Lookahead pipelining
//!
//! [`JaxMgBuilder::pipeline`] (or the [`JaxMgBuilder::lookahead`]
//! shorthand) selects the solver *timing schedule*:
//! [`PipelineConfig::barrier`] (the default — every charge lands on the
//! device clocks, the seed behaviour) or
//! [`PipelineConfig::lookahead`]`(k)`, which issues kernels and copies
//! onto per-device compute/panel/copy streams with `k`-step panel
//! lookahead in `potrf` — the simulated makespans shrink and
//! [`JaxMg::metrics`]' `overlap_*` counters report the realized
//! overlap. Numerics are schedule-independent (bitwise).
//!
//! ## Concurrent solve service
//!
//! [`SolveService`] runs **multiple solves in flight** on one shared
//! node: policy-driven admission gated on a per-device VRAM
//! [`Footprint`] accountant, a worker pool, and per-solve
//! [`SolveStats`] (queue wait, execution time, chosen process grid —
//! all cost-model nanoseconds) on every [`ServiceHandle`]. See
//! `examples/e2e_driver.rs` for the end-to-end serving shape and
//! `rust/tests/properties.rs` for the concurrent-equals-serial and
//! never-over-admit properties. Small solves take
//! [`SolveService::submit_small`], which coalesces them into fused
//! batched sweeps (`crate::batch`) when the cost model says batching
//! wins — see `examples/batch_serve.rs`. A background dwell flusher
//! guarantees coalescer buckets honour their latency bound even when
//! traffic stops entirely.
//!
//! ## SLO-aware scheduling: how the queue orders work
//!
//! Both fronts share one scheduler (the internal `SloQueue`,
//! configured by [`SchedConfig`]). Every request carries an [`Slo`]
//! (priority [`SloClass`], optional absolute deadline, tenant id) and
//! a [`Predictor`](crate::costmodel::Predictor) makespan estimate
//! ([`DistPlan::est_ns`] — bitwise the autotuner's own replayed cost).
//! The decision table, evaluated each time a worker looks for work:
//!
//! | condition | candidate set | rationale |
//! |---|---|---|
//! | any entry bypassed ≥ [`SchedConfig::max_skips`] times | the **oldest** such entry, alone | anti-starvation barrier: nothing passes a starving request; admission waits until it fits (restores the FIFO guarantee) |
//! | [`SchedPolicy::Fifo`] (default) | the oldest entry, alone | the seed head-of-line semantics, bitwise-preserved baseline |
//! | [`SchedPolicy::EdfSjf`] | all entries, ranked `(class, deadline, est_ns, seq)` | interactive before standard before batch; earliest deadline first within a class (`None` last); shortest predicted makespan breaks ties; arrival order breaks *those* ties (FIFO within equal rank) |
//!
//! A ranked candidate that does not fit (VRAM footprint or
//! [`SchedConfig::tenant_quota`]) is skipped and the next candidate is
//! tried — small latency-sensitive solves backfill past a blocked
//! batch solve. Every such bypass of an older entry increments that
//! entry's skip count, feeding the barrier row above. Large SPMD
//! solves additionally expose **panel-boundary preemption points**:
//! between `potrf` panels a non-interactive solve yields its devices
//! to one queued interactive request (numerics are untouched —
//! pinned bitwise in `rust/tests/scheduler.rs`). Per-class p50/p99
//! latency histograms land in [`crate::metrics::Metrics`], computed
//! on the corrected cost-model clock.
//!
//! ## 2D-aware scheduling: how a solve picks its process grid
//!
//! Every distributed solve on either front flows through the shared
//! planner ([`plan_dist`]): per request,
//! [`crate::costmodel::Predictor::best_grid`] replays the routine's
//! schedule on every `P × Q` factorization of the (live) device count
//! and picks the smallest makespan — the way Lineax dispatches solvers
//! by operator structure, with the node as the operator. The decision
//! table the selector encodes:
//!
//! | regime | chosen shape | why | execution |
//! |---|---|---|---|
//! | small `n` (ring latency ≳ per-step work) | `(1, ndev)` — 1D | per-step ring latencies dwarf the split-panel win | the seed columnar path, **bitwise untouched** |
//! | paper-scale `potrf/potrs/potri` | `P > 1` (tall grids as `n` grows) | the per-step panel `trsm` is the serial term and splits across `P`; panel broadcasts shrink to `O(n·T/P)` rings | grid-native solvers (`crate::solver`), admission against [`Footprint::for_grid`]'s exact 2D shards |
//! | paper-scale `syevd` | `P > 1` | reflector collectives un-row-bind into `P` parallel row rings (§5) | the grid `syevd` path |
//! | operator override | [`SmallConfig::grid`] / `MpmdConfig::grid` | pin a shape for A/B or regression runs | as forced (`p·q` must equal the live device count) |
//!
//! Grid-native numerics are **bitwise identical** to the 1D path (the
//! host executes the same kernel sequence; only ownership and the
//! timeline change), so the selector can flip shapes per request
//! without changing results. The chosen shape is reported in
//! [`SolveStats::grid`] and in the `grid_*` metrics counters.
//!
//! ## Factor caching + solve-DAG fusion: what a repeat solve pays
//!
//! With [`SmallConfig::factor_cache`] (SPMD) or
//! `MpmdConfig::factor_cache` (MPMD) enabled, both fronts keep the
//! Cholesky factor `L` of a completed solve **resident on the
//! devices**, keyed by a content hash of `A`'s shards + dtype + tile +
//! grid ([`FactorKey`]). Resident factors are charged against the same
//! per-device admission accountant as in-flight solves (one VRAM
//! budget — the accountant never over-admits), and eviction removes
//! the entry with the lowest `Predictor`-estimated recompute cost ×
//! observed reuse first, LRU on ties. Chains submitted as a
//! [`SolveDag`] fuse into **one** admitted request sharing one
//! resident layout. The decision table, per submitted routine:
//!
//! | path | scatter | `potrf` | triangular stages | seeds the cache? |
//! |---|---|---|---|---|
//! | **cold** `potrf`/`potrs` (miss) | yes | yes | `potrs` runs | yes — `L` stays resident, bytes move from the solve's reservation to the cache's charge |
//! | **cold** `potri` (miss) | yes | yes | `potri` destroys `L` in place | no — nothing left to keep |
//! | **hit** `potrs` | skipped | skipped | runs on the resident shards | already resident (entry pinned for the solve's duration) |
//! | **hit** `potri` | skipped | skipped | runs on a scratch copy of `L` (gather → re-scatter), the resident entry survives | already resident |
//! | `syevd` | yes | — | — | bypasses the cache entirely (no `potrf` prefix to reuse) |
//! | **fused** [`SolveDag`] chain | once | once (or skipped on a hit) | all stages on one resident layout — intermediate gathers/re-scatters vanish | yes, when the chain does not end in [`DagStage::Inverse`] |
//!
//! Hits are **bitwise identical** to the cold path (pinned for all
//! four dtypes, 1D and 2D grids, in `rust/tests/cache.rs`): the cache
//! skips work, never changes it. Staleness is structural — a worker
//! death, straggler injection, or degraded live-set view invalidates
//! every entry staged on the affected device, and a re-queued solve
//! re-plans (and re-factors) on the shrunk set. Hit/miss/eviction
//! counts land in [`crate::metrics::Metrics`] and on
//! [`SolveStats::cache_hit`] / [`SolveStats::fused_stages`];
//! `benches/cache.rs` holds the ≥10× repeated-solve throughput bar.
//!
//! ## SPMD vs MPMD: which front to serve from
//!
//! Figure 2 of the paper describes both deployment shapes; this crate
//! implements each as a serving front sharing the admission/stats layer
//! (`admit`):
//!
//! | | **SPMD — [`SolveService`]** | **MPMD — [`crate::serve::MpmdService`]** |
//! |---|---|---|
//! | Fig. 2 mapping | left: threads + shm pointer table | right: processes + `cudaIpc` handles |
//! | worker granularity | one thread per GPU, shared address space | one (simulated) process per GPU, own [`crate::ipc::AddressSpace`] |
//! | pointer reconciliation | raw pointers via [`crate::ipc::SharedPtrTable`] | export/open via [`crate::ipc::IpcRegistry`] (bound handles, revoke-on-free) |
//! | admission | central FIFO accountant over all devices | each worker admits against **its own** device ([`DeviceAdmission`]) |
//! | per-solve overhead | none beyond staging | `Predictor::mpmd_overhead`: one export + handle ship + open per non-caller worker |
//! | worker failure | process-fatal (shared address space) | contained: dead worker's solves re-queued with its device excluded |
//! | choose it when | single-tenant node, lowest latency | production serving: isolation, partial-failure tolerance, per-GPU ownership |
//!
//! Numerics are **bitwise identical** between the two fronts (pinned in
//! `rust/tests/mpmd_serve.rs` for all four dtypes, 1D and 2D-grid
//! plans alike): both route through the same [`plan_dist`] planner —
//! same inputs → same grid → same layout → same solve schedule; the
//! mode only changes who stages shards (MPMD workers build and
//! IPC-export 1D panels or 2D tile shards with the same
//! `tile::build_panel` path) and how pointers reach the single caller.
//!
//! ## Observability
//!
//! Both fronts are instrumented end to end by [`crate::obs`]: every
//! submission mints a [`crate::obs::TraceId`], spans cover queue wait /
//! cache probes / pipeline stages / collectives on the integer-ns sim
//! clock, scheduler and cache decisions land in a JSONL decision log,
//! and a [`crate::obs::DriftMonitor`] compares
//! [`Predictor`](crate::costmodel::Predictor) estimates against
//! observed makespans per (routine, dtype, n, grid) — feeding back as
//! an [`SmallConfig::drift_correction`] /
//! `MpmdConfig::drift_correction` rescaling of queue estimates when
//! enabled. The tracer is purely passive (off by default, and charging
//! no simulated time when on). See `OBSERVABILITY.md` at the repo root
//! for the full trace model and export formats.

mod admit;
mod cache;
mod mpmd;
mod service;
mod spmd;

pub use admit::{
    duration_to_ns, plan_dist, plan_dist_prec, secs_to_ns, DeviceAdmission, DistPlan, DistRoutine,
    Footprint, GridPlanCache, NumericPolicy, SchedConfig, SchedPolicy, ServeError, ServiceHandle,
    Slo, SloClass, SloTicket, SolveStats,
};
pub use cache::{content_hash, FactorCache, FactorEntry, FactorKey};
pub use mpmd::gather_pointers_mpmd;
pub use service::{DagStage, JobQueue, SmallConfig, SolveDag, SolveHandle, SolveService};
pub use spmd::gather_pointers_spmd;

pub(crate) use admit::{
    handle_pair, panic_message, publish_error, publish_failure, publish_one, Slot, SloQueue,
    TenantQuotas,
};

use crate::costmodel::GpuCostModel;
use crate::device::SimNode;
use crate::error::{Error, Result};
use crate::layout::{BlockCyclic1D, ContiguousBlock};
use crate::linalg::Matrix;
use crate::metrics::MetricsSnapshot;
use crate::runtime::{PjRtRuntime, XlaKernels};
use crate::scalar::Scalar;
use crate::solver::{potrf_dist, potri_dist, potrs_dist, syevd_dist, Ctx, PipelineConfig, SolverBackend};
use crate::tile::{DistMatrix, Layout1D};
use std::sync::Arc;

/// 1D device mesh over the node (the paper only needs 1D meshes).
#[derive(Clone, Debug)]
pub struct Mesh {
    node: SimNode,
    axis: String,
}

impl Mesh {
    /// `jax.make_mesh((ndev,), (axis,))` analogue.
    pub fn new_1d(node: SimNode, axis: impl Into<String>) -> Self {
        Mesh { node, axis: axis.into() }
    }

    /// Devices in the mesh.
    pub fn num_devices(&self) -> usize {
        self.node.num_devices()
    }

    /// The mesh axis name.
    pub fn axis(&self) -> &str {
        &self.axis
    }

    /// The underlying simulated node.
    pub fn node(&self) -> &SimNode {
        &self.node
    }
}

/// `jax.sharding.PartitionSpec` for a 2D operand over a 1D mesh.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PartitionSpec {
    /// `P(axis, None)` — dimension 0 sharded over the mesh axis
    /// (the paper's layout for `A`).
    Sharded(String),
    /// `P(None, None)` — fully replicated (the paper's layout for `b`).
    Replicated,
}

impl PartitionSpec {
    /// The paper's `P("x", None)`.
    pub fn sharded(axis: impl Into<String>) -> Self {
        PartitionSpec::Sharded(axis.into())
    }

    /// The paper's `P(None, None)`.
    pub fn replicated() -> Self {
        PartitionSpec::Replicated
    }
}

/// How worker shards reach the single caller (paper §2.2, Fig. 2).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// One thread per GPU; pointers shared via the shm table.
    Spmd,
    /// One (simulated) process per GPU; pointers via cudaIpc handles.
    Mpmd,
}

/// Which tile-kernel backend executes the FLOPs.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust reference kernels.
    Native,
    /// AOT-compiled XLA executables (requires `make artifacts`).
    Xla,
}

/// Builder for [`JaxMg`].
pub struct JaxMgBuilder {
    mesh: Option<Mesh>,
    tile: usize,
    exec_mode: ExecMode,
    backend: BackendKind,
    artifacts_dir: Option<std::path::PathBuf>,
    model: GpuCostModel,
    pipeline: PipelineConfig,
}

impl Default for JaxMgBuilder {
    fn default() -> Self {
        JaxMgBuilder {
            mesh: None,
            tile: 128,
            exec_mode: ExecMode::Spmd,
            backend: BackendKind::Native,
            artifacts_dir: None,
            model: GpuCostModel::h200(),
            pipeline: PipelineConfig::barrier(),
        }
    }
}

impl JaxMgBuilder {
    /// Set the device mesh (required).
    pub fn mesh(mut self, mesh: Mesh) -> Self {
        self.mesh = Some(mesh);
        self
    }

    /// Set the tile size `T_A` (the paper's memory/perf trade-off knob).
    pub fn tile_size(mut self, t: usize) -> Self {
        self.tile = t;
        self
    }

    /// Choose SPMD (threads) or MPMD (processes) pointer reconciliation.
    pub fn exec_mode(mut self, m: ExecMode) -> Self {
        self.exec_mode = m;
        self
    }

    /// Choose the tile-kernel backend.
    pub fn backend(mut self, b: BackendKind) -> Self {
        self.backend = b;
        self
    }

    /// Override the artifact directory (default: `$JAXMG_ARTIFACTS` or `./artifacts`).
    pub fn artifacts_dir(mut self, d: impl Into<std::path::PathBuf>) -> Self {
        self.artifacts_dir = Some(d.into());
        self
    }

    /// Override the GPU cost model.
    pub fn cost_model(mut self, m: GpuCostModel) -> Self {
        self.model = m;
        self
    }

    /// Select the solver timing schedule (barrier vs lookahead
    /// pipelining). Default: [`PipelineConfig::barrier`].
    pub fn pipeline(mut self, p: PipelineConfig) -> Self {
        self.pipeline = p;
        self
    }

    /// Shorthand for [`JaxMgBuilder::pipeline`] with `k`-step panel
    /// lookahead (`k = 0` restores the barrier schedule).
    pub fn lookahead(mut self, k: usize) -> Self {
        self.pipeline = PipelineConfig::lookahead(k);
        self
    }

    /// Build the context. Fails if the mesh is missing, the tile size is
    /// zero, or (XLA backend) the PJRT client cannot start.
    pub fn build(self) -> Result<JaxMg> {
        let mesh = self.mesh.ok_or_else(|| Error::config("JaxMg requires a mesh"))?;
        if self.tile == 0 {
            return Err(Error::config("tile size T_A must be positive"));
        }
        let runtime = match self.backend {
            BackendKind::Native => None,
            BackendKind::Xla => {
                let dir = self.artifacts_dir.unwrap_or_else(PjRtRuntime::default_dir);
                Some(Arc::new(PjRtRuntime::new(dir)?))
            }
        };
        Ok(JaxMg {
            mesh,
            tile: self.tile,
            exec_mode: self.exec_mode,
            backend: self.backend,
            runtime,
            model: self.model,
            pipeline: self.pipeline,
        })
    }
}

/// The JAXMg context: the library's user-facing API object.
pub struct JaxMg {
    mesh: Mesh,
    tile: usize,
    exec_mode: ExecMode,
    backend: BackendKind,
    runtime: Option<Arc<PjRtRuntime>>,
    model: GpuCostModel,
    pipeline: PipelineConfig,
}

impl JaxMg {
    /// Start building a context.
    pub fn builder() -> JaxMgBuilder {
        JaxMgBuilder::default()
    }

    /// The mesh this context solves over.
    pub fn mesh(&self) -> &Mesh {
        &self.mesh
    }

    /// The configured tile size `T_A`.
    pub fn tile_size(&self) -> usize {
        self.tile
    }

    /// The configured execution mode.
    pub fn exec_mode(&self) -> ExecMode {
        self.exec_mode
    }

    /// The configured timing schedule.
    pub fn pipeline(&self) -> PipelineConfig {
        self.pipeline
    }

    /// Snapshot of the node metrics (copies, kernels, bytes).
    pub fn metrics(&self) -> MetricsSnapshot {
        self.mesh.node().metrics().snapshot()
    }

    /// Projected wall-clock (simulated H200 time) accumulated so far.
    pub fn projected_time(&self) -> f64 {
        self.mesh.node().sim_time()
    }

    /// Reset simulated clocks + metrics (between benchmark repetitions).
    pub fn reset_accounting(&self) {
        self.mesh.node().reset_accounting();
    }

    fn backend_for<S: Scalar>(&self) -> Result<SolverBackend<S>>
    where
        S::Real: xla::NativeType + xla::ArrayElement,
    {
        match self.backend {
            BackendKind::Native => Ok(SolverBackend::Native),
            BackendKind::Xla => {
                let rt = self.runtime.as_ref().expect("runtime exists for Xla backend");
                Ok(SolverBackend::Xla(Arc::new(XlaKernels::<S>::new(rt.clone(), self.tile)?)))
            }
        }
    }

    /// Validate in_specs against the paper's contract:
    /// `A: P(axis, None)`, `b: P(None, None)`.
    fn check_specs(&self, a_spec: &PartitionSpec, b_spec: Option<&PartitionSpec>) -> Result<()> {
        match a_spec {
            PartitionSpec::Sharded(ax) if ax == self.mesh.axis() => {}
            PartitionSpec::Sharded(ax) => {
                return Err(Error::config(format!(
                    "A sharded over unknown axis {ax:?} (mesh axis is {:?})",
                    self.mesh.axis()
                )))
            }
            PartitionSpec::Replicated => {
                return Err(Error::config("A must be sharded over the mesh axis: P(axis, None)"))
            }
        }
        if let Some(PartitionSpec::Sharded(_)) = b_spec {
            return Err(Error::config("b must be replicated: P(None, None)"));
        }
        Ok(())
    }

    /// `device_put(A, P(axis, None))` + worker pointer publication +
    /// single-caller gather + §2.1 redistribution → block-cyclic matrix.
    fn stage_matrix<S: Scalar>(&self, a: &Matrix<S>) -> Result<DistMatrix<S>> {
        let node = self.mesh.node();
        let n = a.require_square()?;
        let ndev = node.num_devices();
        let contig = Layout1D::Contiguous(ContiguousBlock::new(n, ndev)?);
        let mut dm = DistMatrix::scatter(node, a, contig)?;

        // §2.2: every worker publishes its shard pointer; the single
        // caller gathers them all before touching any shard.
        let gathered = match self.exec_mode {
            ExecMode::Spmd => gather_pointers_spmd(node, dm.panels().to_vec())?,
            ExecMode::Mpmd => gather_pointers_mpmd(node, dm.panels().to_vec())?,
        };
        debug_assert_eq!(gathered, dm.panels().to_vec(), "single-caller pointer mismatch");

        // §2.1: in-place conversion to the solver layout.
        let cyclic = Layout1D::BlockCyclic(BlockCyclic1D::new(n, self.tile, ndev)?);
        crate::layout::Redistributor::convert(&mut dm, cyclic)?;
        Ok(dm)
    }

    /// Paper API: solve `A·X = B` (A SPD/HPD sharded, B replicated).
    /// Full pipeline with explicit in_specs.
    pub fn potrs_with_specs<S: Scalar>(
        &self,
        a: &Matrix<S>,
        b: &Matrix<S>,
        a_spec: PartitionSpec,
        b_spec: PartitionSpec,
    ) -> Result<Matrix<S>>
    where
        S::Real: xla::NativeType + xla::ArrayElement,
    {
        self.check_specs(&a_spec, Some(&b_spec))?;
        let backend = self.backend_for::<S>()?;
        let ctx = Ctx::with_pipeline(self.mesh.node(), &self.model, &backend, self.pipeline);
        let mut dm = self.stage_matrix(a)?;
        potrf_dist(&ctx, &mut dm)?;
        let x = potrs_dist(&ctx, &dm, b)?;
        dm.free()?;
        Ok(x)
    }

    /// Solve `A·X = B` with the paper's default specs.
    pub fn potrs<S: Scalar>(&self, a: &Matrix<S>, b: &Matrix<S>) -> Result<Matrix<S>>
    where
        S::Real: xla::NativeType + xla::ArrayElement,
    {
        let ax = self.mesh.axis().to_string();
        self.potrs_with_specs(a, b, PartitionSpec::Sharded(ax), PartitionSpec::Replicated)
    }

    /// Invert an SPD/HPD matrix (`cusolverMgPotri` pipeline).
    pub fn potri<S: Scalar>(&self, a: &Matrix<S>) -> Result<Matrix<S>>
    where
        S::Real: xla::NativeType + xla::ArrayElement,
    {
        let backend = self.backend_for::<S>()?;
        let ctx = Ctx::with_pipeline(self.mesh.node(), &self.model, &backend, self.pipeline);
        let mut dm = self.stage_matrix(a)?;
        potrf_dist(&ctx, &mut dm)?;
        potri_dist(&ctx, &mut dm)?;
        let inv = dm.gather()?;
        dm.free()?;
        Ok(inv)
    }

    /// Eigendecomposition of a symmetric/Hermitian matrix
    /// (`cusolverMgSyevd` pipeline): ascending eigenvalues +
    /// eigenvector columns.
    pub fn syevd<S: Scalar>(&self, a: &Matrix<S>) -> Result<(Vec<S::Real>, Matrix<S>)>
    where
        S::Real: xla::NativeType + xla::ArrayElement,
    {
        let backend = self.backend_for::<S>()?;
        let ctx = Ctx::with_pipeline(self.mesh.node(), &self.model, &backend, self.pipeline);
        let mut dm = self.stage_matrix(a)?;
        let vals = syevd_dist(&ctx, &mut dm)?;
        let vecs = dm.gather()?;
        dm.free()?;
        Ok((vals, vecs))
    }

    /// Factor once, solve many: returns a reusable factorization handle
    /// (the composable-JAX-workflow story — e.g. repeated solves inside
    /// an optimization loop).
    pub fn factorize<S: Scalar>(&self, a: &Matrix<S>) -> Result<Factorized<'_, S>>
    where
        S::Real: xla::NativeType + xla::ArrayElement,
    {
        let backend = self.backend_for::<S>()?;
        let mut dm = self.stage_matrix(a)?;
        {
            let ctx = Ctx::with_pipeline(self.mesh.node(), &self.model, &backend, self.pipeline);
            potrf_dist(&ctx, &mut dm)?;
        }
        Ok(Factorized { ctx_owner: self, backend, dm })
    }
}

impl std::fmt::Debug for JaxMg {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "JaxMg(devices={}, T_A={}, mode={:?}, backend={:?})",
            self.mesh.num_devices(),
            self.tile,
            self.exec_mode,
            self.backend
        )
    }
}

/// A distributed Cholesky factorization kept on the devices for
/// repeated solves.
pub struct Factorized<'a, S: Scalar> {
    ctx_owner: &'a JaxMg,
    backend: SolverBackend<S>,
    dm: DistMatrix<S>,
}

impl<'a, S: Scalar> Factorized<'a, S> {
    /// Solve against a replicated RHS using the stored factor.
    pub fn solve(&self, b: &Matrix<S>) -> Result<Matrix<S>> {
        let ctx = Ctx::with_pipeline(
            self.ctx_owner.mesh.node(),
            &self.ctx_owner.model,
            &self.backend,
            self.ctx_owner.pipeline,
        );
        potrs_dist(&ctx, &self.dm, b)
    }

    /// Consume the factor and produce the inverse.
    pub fn into_inverse(mut self) -> Result<Matrix<S>> {
        let ctx = Ctx::with_pipeline(
            self.ctx_owner.mesh.node(),
            &self.ctx_owner.model,
            &self.backend,
            self.ctx_owner.pipeline,
        );
        potri_dist(&ctx, &mut self.dm)?;
        self.dm.gather()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{tol_for, FrobNorm};
    use crate::scalar::c64;

    fn ctx(ndev: usize, tile: usize, mode: ExecMode) -> JaxMg {
        let node = SimNode::new_uniform(ndev, 1 << 26);
        JaxMg::builder()
            .mesh(Mesh::new_1d(node, "x"))
            .tile_size(tile)
            .exec_mode(mode)
            .build()
            .unwrap()
    }

    #[test]
    fn potrs_end_to_end_spmd() {
        let mg = ctx(4, 4, ExecMode::Spmd);
        let a = Matrix::<f64>::spd_random(32, 1);
        let xt = Matrix::<f64>::random(32, 2, 2);
        let b = a.matmul(&xt);
        let x = mg.potrs(&a, &b).unwrap();
        assert!(x.rel_err(&xt) < tol_for::<f64>(32) * 10.0);
    }

    #[test]
    fn potrs_end_to_end_mpmd() {
        let mg = ctx(4, 4, ExecMode::Mpmd);
        let a = Matrix::<f64>::spd_random(32, 3);
        let xt = Matrix::<f64>::random(32, 1, 4);
        let b = a.matmul(&xt);
        let x = mg.potrs(&a, &b).unwrap();
        assert!(x.rel_err(&xt) < tol_for::<f64>(32) * 10.0);
    }

    #[test]
    fn potri_end_to_end() {
        let mg = ctx(3, 4, ExecMode::Spmd);
        let a = Matrix::<c64>::spd_random(18, 5);
        let inv = mg.potri(&a).unwrap();
        assert!(a.matmul(&inv).rel_err(&Matrix::eye(18)) < tol_for::<c64>(18) * 10.0);
    }

    #[test]
    fn syevd_end_to_end() {
        let mg = ctx(2, 4, ExecMode::Spmd);
        let a = Matrix::<f64>::spd_diag(16);
        let (vals, _) = mg.syevd(&a).unwrap();
        for i in 0..16 {
            assert!((vals[i] - (i + 1) as f64).abs() < 1e-10);
        }
    }

    #[test]
    fn factorize_reuses_factor() {
        let mg = ctx(2, 4, ExecMode::Spmd);
        let a = Matrix::<f64>::spd_random(16, 7);
        let f = mg.factorize(&a).unwrap();
        for seed in 0..3 {
            let xt = Matrix::<f64>::random(16, 1, 100 + seed);
            let b = a.matmul(&xt);
            let x = f.solve(&b).unwrap();
            assert!(x.rel_err(&xt) < tol_for::<f64>(16) * 10.0);
        }
    }

    #[test]
    fn spec_validation() {
        let mg = ctx(2, 4, ExecMode::Spmd);
        let a = Matrix::<f64>::spd_random(8, 8);
        let b = Matrix::<f64>::ones(8, 1);
        // Wrong axis name.
        let err = mg
            .potrs_with_specs(&a, &b, PartitionSpec::sharded("y"), PartitionSpec::replicated())
            .unwrap_err();
        assert!(matches!(err, Error::Config(_)));
        // Replicated A rejected.
        assert!(mg
            .potrs_with_specs(&a, &b, PartitionSpec::replicated(), PartitionSpec::replicated())
            .is_err());
        // Sharded b rejected.
        assert!(mg
            .potrs_with_specs(&a, &b, PartitionSpec::sharded("x"), PartitionSpec::sharded("x"))
            .is_err());
    }

    #[test]
    fn builder_validation() {
        assert!(JaxMg::builder().build().is_err()); // no mesh
        let node = SimNode::new_uniform(1, 1 << 20);
        assert!(JaxMg::builder().mesh(Mesh::new_1d(node, "x")).tile_size(0).build().is_err());
    }

    #[test]
    fn pipelined_context_matches_barrier_and_shrinks_projection() {
        let a = Matrix::<f64>::spd_random(48, 20);
        let b = Matrix::<f64>::ones(48, 2);
        let run = |look: usize| {
            let node = SimNode::new_uniform(4, 1 << 26);
            let mg = JaxMg::builder()
                .mesh(Mesh::new_1d(node, "x"))
                .tile_size(4)
                .lookahead(look)
                .build()
                .unwrap();
            let x = mg.potrs(&a, &b).unwrap();
            (x, mg.projected_time())
        };
        let (x_barrier, t_barrier) = run(0);
        let (x_look, t_look) = run(2);
        assert_eq!(x_barrier.as_slice(), x_look.as_slice(), "schedule changed numerics");
        assert!(t_look < t_barrier, "lookahead projection {t_look} !< barrier {t_barrier}");
    }

    #[test]
    fn no_vram_leak_across_solves() {
        let mg = ctx(2, 4, ExecMode::Spmd);
        let a = Matrix::<f64>::spd_random(16, 9);
        let b = Matrix::<f64>::ones(16, 1);
        for _ in 0..3 {
            mg.potrs(&a, &b).unwrap();
        }
        for rep in mg.mesh().node().memory_reports() {
            assert_eq!(rep.used, 0, "solve leaked device memory");
        }
    }
}
