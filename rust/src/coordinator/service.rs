//! The solve service layer: a plain FIFO job queue ([`JobQueue`]) and a
//! **capacity-aware concurrent solve service** ([`SolveService`]).
//!
//! The real JAXMg lives inside JAX's JIT, so its "request loop" is the
//! XLA program; for a standalone coordinator binary we provide the
//! conventional server shape instead (the vendored crate set has no
//! tokio, so this is a std-thread worker pool — same semantics, no
//! async syntax).
//!
//! [`SolveService`] is the throughput-oriented front: multiple solves
//! are in flight on one shared [`SimNode`] at a time, ordered by the
//! SLO-aware scheduler (see the [`crate::coordinator`] module docs —
//! [`SchedPolicy::Fifo`], the default, is exact seed head-of-line
//! admission; [`SchedPolicy::EdfSjf`] ranks by class, deadline, and
//! [`crate::costmodel::Predictor`] makespan with backfill and an
//! anti-starvation barrier) and admitted only when their declared
//! per-device workspace [`Footprint`] fits against every device's VRAM
//! capacity — the cuSOLVERMg workspace-query-then-allocate discipline —
//! and, when [`SchedConfig::tenant_quota`] is set, within the
//! submitting tenant's admitted-bytes quota. The service assumes it
//! owns the node's VRAM (admission is against capacity, not live free
//! bytes), and the byte-accurate device allocator remains the hard
//! backstop: a solve that outgrows its declared footprint still fails
//! with `DeviceOom` rather than corrupting a neighbour. Per-solve
//! queue-wait and execution times — **cost-model nanoseconds** on the
//! node's simulated timeline, never host wall time — are returned on
//! the [`ServiceHandle`] and aggregated into
//! [`crate::metrics::Metrics`] (`service_*` counters and per-class
//! latency histograms; pipelined solves additionally feed the
//! overlap-efficiency counters through their [`crate::solver::Ctx`]
//! phases). Under [`SchedPolicy::EdfSjf`], non-interactive distributed
//! solves yield at panel boundaries ([`crate::solver::Ctx::preempt_point`])
//! so a queued interactive solve runs between panels instead of behind
//! the whole factorization.

//! ## The batched small-solve path: admission → coalesce → sweep
//!
//! [`SolveService::submit_small`] is the front door for tiny solves
//! (`n ≲ 4·T_A`), where the distributed path's per-solve
//! redistribution and per-panel collectives dwarf the flops:
//!
//! 1. **Admission** — the request is sized against
//!    [`SmallConfig::policy`]'s smallness cut and the cost model's
//!    [`Predictor::batched_wins`] dispatch decision. Requests the
//!    model sends distributed run the ordinary scatter →
//!    `potrf_dist`/`potrs_dist`/`potri_dist` → gather route under a
//!    [`Footprint::for_routine`] reservation.
//! 2. **Coalesce** — batched requests queue in the internal
//!    [`BatchPlanner`] keyed by (routine, dtype, size-class), flushing
//!    at [`BatchPolicy::max_batch`] occupancy or after the policy's
//!    queue-dwell bound in cost-model nanoseconds (checked on every
//!    submit and on [`SolveService::drain`] /
//!    [`SolveService::flush_small`]).
//! 3. **Sweep** — a flushed bucket is admitted as *one* capacity
//!    reservation ([`Footprint::for_pod`], the exact per-device pod
//!    arena bytes) and swept by the fused batched kernels
//!    ([`crate::batch::sweep`]); every request's [`ServiceHandle`]
//!    resolves individually with its bucket occupancy and coalesce
//!    wait in [`SolveStats`], and per-bucket occupancy / wait /
//!    makespan aggregates land in the `batch_*` metrics counters.
//!
//! A failed or panicking small solve re-raises at
//! [`ServiceHandle::wait`], exactly like any other service solve.
//!
//! [`Predictor::batched_wins`]: crate::costmodel::Predictor::batched_wins

use super::admit::{
    handle_pair, panic_message, publish_failure, publish_one, secs_to_ns, DistRoutine,
    GridPlanCache, ServeError, Slot, SloQueue, SloTicket, TenantQuotas,
};
use super::cache::{FactorCache, FactorEntry, FactorKey};
pub use super::admit::{
    Footprint, SchedConfig, SchedPolicy, ServiceHandle, Slo, SloClass, SolveStats,
};
use crate::batch::{
    flusher_tick, run_bucket, BatchPlanner, BatchPolicy, BucketKey, FlushedBucket, SmallRoutine,
};
use crate::costmodel::{GpuCostModel, Predictor};
use crate::device::{DevPtr, SimNode};
use crate::error::{Error, Result};
use crate::layout::TileDim;
use crate::linalg::Matrix;
use crate::obs::{DriftKey, SpanId, TraceId};
use crate::scalar::{DType, Scalar};
use crate::solver::{
    potrf_dist, potri_dist, potrs_dist, syevd_dist, Ctx, MixedCapable, MixedRun, PipelineConfig,
    Precision, RefineOptions, SolverBackend, DEFAULT_REFINE_CAP, DEFAULT_REFINE_TOL,
};
use crate::tile::{DistMatrix, LayoutKind};
use std::any::Any;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct QueueInner {
    jobs: VecDeque<Job>,
    shutdown: bool,
    in_flight: usize,
}

/// A FIFO job queue with a fixed worker pool.
pub struct JobQueue {
    inner: Arc<(Mutex<QueueInner>, Condvar)>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl JobQueue {
    /// Start a queue with `n_workers` executor threads.
    pub fn new(n_workers: usize) -> Self {
        let inner = Arc::new((
            Mutex::new(QueueInner { jobs: VecDeque::new(), shutdown: false, in_flight: 0 }),
            Condvar::new(),
        ));
        let workers = (0..n_workers.max(1))
            .map(|_| {
                let inner = inner.clone();
                std::thread::spawn(move || loop {
                    let job = {
                        let (lock, cv) = &*inner;
                        let mut q = lock.lock().unwrap();
                        loop {
                            if let Some(job) = q.jobs.pop_front() {
                                q.in_flight += 1;
                                break Some(job);
                            }
                            if q.shutdown {
                                break None;
                            }
                            q = cv.wait(q).unwrap();
                        }
                    };
                    match job {
                        Some(job) => {
                            job();
                            let (lock, cv) = &*inner;
                            let mut q = lock.lock().unwrap();
                            q.in_flight -= 1;
                            cv.notify_all();
                        }
                        None => return,
                    }
                })
            })
            .collect();
        JobQueue { inner, workers }
    }

    /// Submit a job returning `T`; get a [`SolveHandle`] to wait on.
    pub fn submit<T: Send + 'static>(
        &self,
        f: impl FnOnce() -> T + Send + 'static,
    ) -> SolveHandle<T> {
        let slot = Arc::new((Mutex::new(None::<T>), Condvar::new()));
        let slot2 = slot.clone();
        let job: Job = Box::new(move || {
            let out = f();
            let (lock, cv) = &*slot2;
            *lock.lock().unwrap() = Some(out);
            cv.notify_all();
        });
        let (lock, cv) = &*self.inner;
        let mut q = lock.lock().unwrap();
        assert!(!q.shutdown, "queue is shut down");
        q.jobs.push_back(job);
        cv.notify_one();
        drop(q);
        SolveHandle { slot }
    }

    /// Number of jobs queued (not yet started).
    pub fn pending(&self) -> usize {
        self.inner.0.lock().unwrap().jobs.len()
    }

    /// Block until the queue is fully drained.
    pub fn drain(&self) {
        let (lock, cv) = &*self.inner;
        let mut q = lock.lock().unwrap();
        while !q.jobs.is_empty() || q.in_flight > 0 {
            q = cv.wait(q).unwrap();
        }
    }
}

impl Drop for JobQueue {
    fn drop(&mut self) {
        {
            let (lock, cv) = &*self.inner;
            lock.lock().unwrap().shutdown = true;
            cv.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Completion handle for a submitted job.
pub struct SolveHandle<T> {
    slot: Arc<(Mutex<Option<T>>, Condvar)>,
}

impl<T> SolveHandle<T> {
    /// Block until the job completes and take its result.
    pub fn wait(self) -> T {
        let (lock, cv) = &*self.slot;
        let mut guard = lock.lock().unwrap();
        loop {
            if let Some(v) = guard.take() {
                return v;
            }
            guard = cv.wait(guard).unwrap();
        }
    }

    /// Non-blocking readiness check.
    pub fn is_ready(&self) -> bool {
        self.slot.0.lock().unwrap().is_some()
    }
}

// ---------------------------------------------------------------------------
// Capacity-aware concurrent solve service
// ---------------------------------------------------------------------------

// `Footprint`, `SolveStats`, and `ServiceHandle` live in
// `coordinator::admit` (shared with the MPMD front in `crate::serve`)
// and are re-exported above.

/// Deferred result publication: runs *after* the worker has released
/// the solve's reservation, so a resolved [`ServiceHandle`] implies
/// the capacity is already free (no wait()/release race).
type PublishFn = Box<dyn FnOnce() + Send + 'static>;
/// An admitted solve body: receives its scheduling ticket and the
/// queue wait the scheduler measured (cost-model ns, enqueue →
/// admission on the node's simulated timeline).
type AdmittedJob = Box<dyn FnOnce(SloTicket, u64) -> PublishFn + Send + 'static>;

struct QueuedSolve {
    footprint: Vec<usize>,
    job: AdmittedJob,
}

impl QueuedSolve {
    /// Bytes summed over devices — the tenant-quota unit.
    fn total_bytes(&self) -> usize {
        self.footprint.iter().sum()
    }
}

struct ServiceState {
    queue: SloQueue<QueuedSolve>,
    reserved: Vec<usize>,
    peak_reserved: Vec<usize>,
    in_flight: usize,
    shutdown: bool,
}

struct ServiceInner {
    node: SimNode,
    capacity: Vec<usize>,
    sched: SchedConfig,
    quotas: TenantQuotas,
    state: Mutex<ServiceState>,
    cv: Condvar,
    /// Monotonicity watermark for [`ServiceInner::sim_now_ns`]: the
    /// service's view of the simulated clock never runs backwards.
    last_seen_ns: AtomicU64,
    /// Resident Cholesky factors ([`SmallConfig::factor_cache`]): each
    /// entry's shards stay allocated on the devices with their bytes
    /// charged into `ServiceState::reserved`, so factors and in-flight
    /// solves share the one capacity budget. Lock order: cache before
    /// `state`, and never held across a solve.
    cache: Mutex<FactorCache<Vec<DevPtr>>>,
}

impl ServiceInner {
    /// Shared enqueue path behind [`SolveService::submit`] and the
    /// batched-bucket flusher: fail-fast footprint/quota checks, the
    /// scheduler push, and submission metrics. The job's returned
    /// [`PublishFn`] runs only after the worker has released the
    /// reservation, so result publication always implies the capacity
    /// is free again.
    fn enqueue_job(&self, footprint: Footprint, slo: Slo, est_ns: u64, job: AdmittedJob) -> Result<()> {
        if footprint.devices() != self.capacity.len() {
            return Err(Error::config(format!(
                "footprint spans {} devices but the service node has {}",
                footprint.devices(),
                self.capacity.len()
            )));
        }
        for (d, (&need, &cap)) in
            footprint.as_slice().iter().zip(self.capacity.iter()).enumerate()
        {
            if need > cap {
                return Err(Error::DeviceOom { device: d, requested: need, free: cap, capacity: cap });
            }
        }
        let total: usize = footprint.as_slice().iter().sum();
        if let Some(quota) = self.quotas.quota() {
            if total > quota {
                return Err(Error::config(format!(
                    "request needs {total} B but tenant {} is capped at {quota} B — \
                     it could never be admitted",
                    slo.tenant
                )));
            }
        }
        let enq_ns = self.sim_now_ns();
        {
            let mut st = self.state.lock().unwrap();
            assert!(!st.shutdown, "service is shut down");
            st.queue.push_back(slo, est_ns, enq_ns, QueuedSolve {
                footprint: footprint.into_per_device(),
                job,
            });
        }
        self.node.metrics().add_service_submission();
        self.cv.notify_all();
        Ok(())
    }

    /// Fold the current per-device reservations into per-island sums
    /// and record the fabric high-water marks
    /// ([`crate::metrics::Metrics::note_island_admitted`]). A no-op on
    /// a flat (1-island) node, so the single-node fronts pay nothing.
    fn note_island_reserved(&self, reserved: &[usize]) {
        let topo = self.node.topology();
        if topo.num_islands() <= 1 {
            return;
        }
        let mut sums = [0u64; 8];
        for (d, &b) in reserved.iter().enumerate() {
            sums[topo.island_of(d).min(sums.len() - 1)] += b as u64;
        }
        let m = self.node.metrics();
        for (i, &s) in sums.iter().enumerate() {
            if s > 0 {
                m.note_island_admitted(i, s);
            }
        }
    }

    /// The simulated clock in integer nanoseconds — the timebase of the
    /// scheduler's queue waits and the coalescer's dwell bound. Taken
    /// straight off the devices' integer-ns [`crate::device::SimClock`]s
    /// (no float round-trip), and clamped through a monotonic watermark:
    /// the service's clock never runs backwards even if the underlying
    /// node is reset out from under it.
    fn sim_now_ns(&self) -> u64 {
        let now = self.node.sim_time_ns();
        let prev = self.last_seen_ns.fetch_max(now, Ordering::AcqRel);
        now.max(prev)
    }

    /// True when any device clock runs with straggler drag — the
    /// degraded-mode signal that relaxes deadline accounting by
    /// [`SchedConfig::degrade_factor`].
    fn degraded(&self) -> bool {
        (0..self.capacity.len())
            .any(|d| self.node.device(d).map(|g| g.clock().drag() > 1.0).unwrap_or(false))
    }

    /// Completion-side accounting: the `service_*` aggregates plus the
    /// per-class latency histogram and deadline-miss counter, all in
    /// cost-model ns. A deadline is judged against the *latency budget*
    /// it implied at enqueue (`deadline − enqueue`), scaled by the
    /// degrade factor when stragglers are active, so a drag-slowed
    /// deployment reports against its relaxed SLO rather than drowning
    /// every class in misses.
    fn note_completion(&self, ticket: &SloTicket, queue_wait_ns: u64, exec_ns: u64) {
        let m = self.node.metrics();
        m.add_service_completion(queue_wait_ns, exec_ns);
        let latency_ns = queue_wait_ns.saturating_add(exec_ns);
        let missed = match ticket.slo.deadline_ns {
            Some(d) => {
                let budget = d.saturating_sub(ticket.enq_ns);
                let scale = if self.degraded() { self.sched.degrade_factor } else { 1.0 };
                latency_ns as f64 > budget as f64 * scale
            }
            None => false,
        };
        m.record_class_latency(ticket.slo.class, latency_ns, missed);
    }

    /// Probe the factor cache, validating that the entry's shards still
    /// exist on the node — an entry whose pointers were freed out from
    /// under the cache (a solve that unwound mid-hit) is discarded and
    /// reported as a miss. A returned hit is **pinned** until the
    /// matching [`PinGuard`] drops.
    fn probe_factor(&self, key: &FactorKey) -> Option<(Vec<DevPtr>, LayoutKind)> {
        let mut cache = self.cache.lock().unwrap();
        let (ptrs, kind) = cache.probe(key)?;
        if ptrs.iter().all(|&p| self.node.ptr_exists(p)) {
            return Some((ptrs, kind));
        }
        // Stale: doom it (we hold its pin), then unpin to extract it.
        cache.invalidate(|k, _| k == key);
        let e = cache.unpin(key);
        drop(cache);
        if let Some(e) = e {
            self.free_entry(&e);
        }
        None
    }

    /// Drop a [`probe_factor`](Self::probe_factor) pin; tears the entry
    /// down if it was invalidated while the hit was in flight.
    fn unpin_factor(&self, key: &FactorKey) {
        let e = self.cache.lock().unwrap().unpin(key);
        if let Some(e) = e {
            self.free_entry(&e);
        }
    }

    /// Admit and insert a freshly factored `L`'s shards. If the bytes
    /// cannot be charged even after evicting every unpinned entry — or
    /// an identical entry raced in first — the shards are freed again
    /// and the solve simply completes uncached.
    fn insert_factor(
        &self,
        key: FactorKey,
        kind: LayoutKind,
        panels: Vec<DevPtr>,
        recompute_ns: u64,
    ) {
        let resident =
            Footprint::for_cached_factor(&kind, key.n, key.dtype).into_per_device();
        if !self.reserve_resident(&resident) {
            for &p in &panels {
                let _ = self.node.free(p);
            }
            return;
        }
        let bytes: usize = resident.iter().sum();
        let refused = self.cache.lock().unwrap().insert(key, panels, kind, resident, recompute_ns);
        match refused {
            Some(e) => self.free_entry(&e),
            None => self.node.metrics().add_cache_resident_bytes(bytes as i64),
        }
    }

    /// Charge `resident` bytes of factor residency against the central
    /// accountant, evicting victims (lowest recompute-cost × reuse
    /// score first) to make room. Leaves reservations untouched and
    /// returns `false` if the bytes cannot fit regardless.
    fn reserve_resident(&self, resident: &[usize]) -> bool {
        loop {
            {
                let mut st = self.state.lock().unwrap();
                let fits = (0..self.capacity.len())
                    .all(|d| st.reserved[d] + resident[d] <= self.capacity[d]);
                if fits {
                    for d in 0..self.capacity.len() {
                        st.reserved[d] += resident[d];
                        if st.reserved[d] > st.peak_reserved[d] {
                            st.peak_reserved[d] = st.reserved[d];
                        }
                    }
                    self.note_island_reserved(&st.reserved);
                    return true;
                }
            }
            if !self.evict_one() {
                return false;
            }
        }
    }

    /// Give back factor residency (eviction, invalidation, shutdown)
    /// and wake the queue — freed bytes may admit a blocked solve.
    fn release_resident(&self, resident: &[usize]) {
        {
            let mut st = self.state.lock().unwrap();
            for d in 0..self.capacity.len() {
                st.reserved[d] -= resident[d];
            }
        }
        self.cv.notify_all();
    }

    /// Evict the lowest-scored unpinned entry: free its shards and
    /// release its charge. `false` when nothing is evictable.
    fn evict_one(&self) -> bool {
        let victim = self.cache.lock().unwrap().pop_victim();
        let Some((_, e)) = victim else { return false };
        let bytes = e.resident_bytes();
        self.free_entry(&e);
        self.node.metrics().add_cache_eviction();
        let tr = self.node.tracer();
        if tr.enabled() {
            tr.decision(
                TraceId(0),
                self.sim_now_ns(),
                "evict",
                format!("factor evicted, {bytes} B released"),
            );
        }
        true
    }

    /// Free a detached cache entry's device shards and give back its
    /// admission charge. Shards already freed out from under the cache
    /// are skipped rather than double-freed.
    fn free_entry(&self, e: &FactorEntry<Vec<DevPtr>>) {
        for &p in &e.payload {
            if self.node.ptr_exists(p) {
                let _ = self.node.free(p);
            }
        }
        self.release_resident(&e.resident);
        self.node.metrics().add_cache_resident_bytes(-(e.resident_bytes() as i64));
    }
}

/// Unpins a probed factor-cache entry when its hit solve finishes — or
/// unwinds: dropping the guard is what allows eviction again (and what
/// tears down an entry invalidated mid-hit), so it must run on every
/// exit path.
struct PinGuard {
    inner: Arc<ServiceInner>,
    key: FactorKey,
}

impl Drop for PinGuard {
    fn drop(&mut self) {
        self.inner.unpin_factor(&self.key);
    }
}

/// Predictor-drift probe riding a planned distributed submission: when
/// the job finishes, its observed makespan (cost-model ns) is recorded
/// against the plan's estimates in the node tracer's
/// [`DriftMonitor`](crate::obs::DriftMonitor) under this key. Cache
/// hits carry no probe — a hit skips the modeled scatter+potrf prefix,
/// so its makespan would poison the per-key statistics.
struct DriftProbe {
    key: DriftKey,
    /// The raw cost-model makespan (no cache or drift adjustments).
    est_model_ns: u64,
    /// The estimate actually queued (after drift correction, if on).
    est_used_ns: u64,
}

/// A chain of Cholesky-family routines against **one** matrix `A`,
/// submitted as a single fused job ([`SolveService::submit_dag`]): `A`
/// is scattered and factored once and every stage runs on the
/// predecessor's resident layout, so the intermediate gather → re-submit
/// → re-scatter → re-factor of chaining the stages as separate requests
/// vanishes. Each stage still resolves on its own [`ServiceHandle`]
/// with its own result matrix.
pub struct SolveDag<S: Scalar> {
    a: Matrix<S>,
    stages: Vec<DagStage<S>>,
}

/// One stage of a [`SolveDag`].
pub enum DagStage<S: Scalar> {
    /// Gather the Cholesky factor `L` itself (a `potrf` result).
    Factor,
    /// Triangular solve against a right-hand side (a `potrs` result).
    Solve(Matrix<S>),
    /// Cholesky-based inverse (a `potri` result). Runs in place and
    /// destroys the resident factor, so it must be the last stage.
    Inverse,
}

impl<S: Scalar> SolveDag<S> {
    /// Start a chain against `a`.
    pub fn new(a: Matrix<S>) -> Self {
        SolveDag { a, stages: Vec::new() }
    }

    /// Append a `potrf` stage (the factor itself).
    pub fn factor(mut self) -> Self {
        self.stages.push(DagStage::Factor);
        self
    }

    /// Append a `potrs` stage against `rhs`.
    pub fn solve(mut self, rhs: Matrix<S>) -> Self {
        self.stages.push(DagStage::Solve(rhs));
        self
    }

    /// Append the (final) `potri` stage.
    pub fn inverse(mut self) -> Self {
        self.stages.push(DagStage::Inverse);
        self
    }
}

/// Pop-and-run one queued **interactive** solve if capacity and quota
/// admit it right now — the panel-boundary preemption body. Called by
/// the [`crate::solver::Ctx::preempt_point`] hook installed on
/// non-interactive distributed solves under [`SchedPolicy::EdfSjf`]:
/// the large solve's own worker thread runs the interactive solve
/// inline between two of its panels (its reservation stays held, so
/// the preemptor is admitted only against the remaining capacity).
fn try_run_interactive(inner: &Arc<ServiceInner>) {
    let popped = {
        let mut st = inner.state.lock().unwrap();
        if st.shutdown {
            return;
        }
        let ServiceState { queue, reserved, peak_reserved, in_flight, .. } = &mut *st;
        let picked = queue.pop_admissible(|t, q| {
            t.slo.class == SloClass::Interactive
                && (0..reserved.len()).all(|d| reserved[d] + q.footprint[d] <= inner.capacity[d])
                && inner.quotas.would_admit(t.slo.tenant, q.total_bytes())
        });
        if let Some((ticket, q)) = picked {
            for d in 0..reserved.len() {
                reserved[d] += q.footprint[d];
                if reserved[d] > peak_reserved[d] {
                    peak_reserved[d] = reserved[d];
                }
            }
            inner.note_island_reserved(reserved);
            inner.quotas.admit(ticket.slo.tenant, q.total_bytes());
            *in_flight += 1;
            Some((ticket, q))
        } else {
            None
        }
    };
    let Some((ticket, q)) = popped else { return };
    let QueuedSolve { footprint, job } = q;
    inner.node.metrics().note_preemption();
    let tr = inner.node.tracer();
    if tr.enabled() {
        tr.decision(
            TraceId(0),
            inner.sim_now_ns(),
            "preempt",
            format!(
                "interactive solve admitted at a panel boundary, tenant {}",
                ticket.slo.tenant
            ),
        );
    }
    let queue_wait_ns = inner.sim_now_ns().saturating_sub(ticket.enq_ns);
    let publish = job(ticket, queue_wait_ns);
    {
        let mut st = inner.state.lock().unwrap();
        for d in 0..inner.capacity.len() {
            st.reserved[d] -= footprint[d];
        }
        st.in_flight -= 1;
    }
    inner.quotas.release(ticket.slo.tenant, footprint.iter().sum());
    inner.cv.notify_all();
    publish();
}

/// Configuration of the batched small-solve path.
#[derive(Clone, Debug)]
pub struct SmallConfig {
    /// `T_A` of the distributed fallback layout; also anchors the
    /// default smallness cut (`small_dim = 4·tile`).
    pub tile: usize,
    /// Coalescing knobs (bucket occupancy, dwell bound, smallness cut).
    pub policy: BatchPolicy,
    /// Cost model behind the batched-vs-distributed dispatch decision
    /// and the sweeps' timeline charges.
    pub model: GpuCostModel,
    /// Process-grid override for distributed solves: `None` lets
    /// [`Predictor::best_grid`] pick the `P × Q` shape per request
    /// (1D for small problems, 2D grids at scale); `Some((p, q))` pins
    /// it (p·q must equal the device count).
    ///
    /// [`Predictor::best_grid`]: crate::costmodel::Predictor::best_grid
    pub grid: Option<(usize, usize)>,
    /// Enable the resident factor cache: a cold `potrf`/`potrs` keeps
    /// `L`'s distributed shards resident in device memory (charged
    /// against the same admission budget as in-flight solves, evicted
    /// by recompute-cost × reuse score under pressure), and a repeat
    /// solve against a byte-identical `A` skips the scatter and the
    /// factorization entirely — only the triangular tail runs, on the
    /// resident shards. Off by default: residency shows up in
    /// [`SolveService::reserved`], which cold-only callers may not
    /// expect.
    pub factor_cache: bool,
    /// Feed observed predictor drift back into admission estimates:
    /// once the node tracer's [`DriftMonitor`] holds enough samples
    /// for a (routine, dtype, n, grid) key, planned makespans are
    /// rescaled by the observed/predicted ratio before entering the
    /// scheduler queue. Barrier-scheduled runs have zero drift by
    /// construction (the plan *is* the model), so this is off by
    /// default and changes nothing until drift actually accumulates.
    ///
    /// [`DriftMonitor`]: crate::obs::DriftMonitor
    pub drift_correction: bool,
}

impl SmallConfig {
    /// Defaults anchored at tile size `tile` (`small_dim = 4·tile`).
    pub fn with_tile(tile: usize) -> Self {
        let policy = BatchPolicy { small_dim: 4 * tile, ..BatchPolicy::default() };
        SmallConfig {
            tile,
            policy,
            model: GpuCostModel::h200(),
            grid: None,
            factor_cache: false,
            drift_correction: false,
        }
    }
}

impl Default for SmallConfig {
    fn default() -> Self {
        Self::with_tile(64)
    }
}

/// One queued small request, type-erased so the planner state can hold
/// every dtype at once; the bucket's flusher (installed by the first
/// `submit_small::<S>` for its key) downcasts back to `SmallJob<S>`.
type SmallPayload = Box<dyn Any + Send>;

/// Executes one flushed bucket: downcast, pack, admit, sweep, publish.
/// Takes the [`ServiceInner`] (not the service) so the background
/// dwell-flusher thread can execute flushes too.
type SmallFlusher =
    dyn Fn(&Arc<ServiceInner>, FlushedBucket, Vec<SmallPayload>) + Send + Sync;

struct SmallJob<S: Scalar> {
    a: Matrix<S>,
    rhs: Option<Matrix<S>>,
    slo: Slo,
    slot: SmallSlot<S>,
}

struct SmallState {
    planner: BatchPlanner,
    payloads: HashMap<u64, SmallPayload>,
    flushers: HashMap<BucketKey, Arc<SmallFlusher>>,
    /// Memoized `Predictor::batched_wins` cut per (routine, dtype,
    /// size-class) — the decision has bucket granularity, so the hot
    /// submit path pays a map lookup, not a topology clone.
    decisions: HashMap<(SmallRoutine, DType, u32), bool>,
}

/// A bucket flush ready to execute once the small-state lock is
/// released (the flusher re-enters the service through `submit`).
type PendingFlush = (Arc<SmallFlusher>, FlushedBucket, Vec<SmallPayload>);

/// Concurrent solve service over one shared [`SimNode`]: FIFO +
/// capacity-aware admission, a fixed worker pool, per-solve stats.
///
/// Admission rule: only the queue **head** may be admitted (strict
/// FIFO — no starvation), and only when `reserved[d] + footprint[d] <=
/// capacity[d]` holds on every device. Completion releases the
/// reservation and wakes the queue.
pub struct SolveService {
    inner: Arc<ServiceInner>,
    cfg: SmallConfig,
    /// Memoized grid-shape selections for the distributed planner.
    plans: GridPlanCache,
    small: Arc<Mutex<SmallState>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    /// Background dwell flusher: ticks the coalescer so dwell-expired
    /// buckets flush even when no further submit/drain ever arrives.
    flusher: Option<std::thread::JoinHandle<()>>,
    flusher_stop: Arc<(Mutex<bool>, Condvar)>,
}

impl SolveService {
    /// Start a service over `node` with `n_workers` executor threads
    /// and the default batched small-solve configuration.
    pub fn new(node: SimNode, n_workers: usize) -> Self {
        Self::with_small_config(node, n_workers, SmallConfig::default())
    }

    /// Start a service with an explicit small-solve configuration and
    /// the default (seed-FIFO) scheduler.
    pub fn with_small_config(node: SimNode, n_workers: usize, cfg: SmallConfig) -> Self {
        Self::with_config(node, n_workers, cfg, SchedConfig::default())
    }

    /// Start a service with explicit small-solve and scheduler
    /// configurations.
    pub fn with_config(
        node: SimNode,
        n_workers: usize,
        cfg: SmallConfig,
        sched: SchedConfig,
    ) -> Self {
        let capacity: Vec<usize> = node.memory_reports().iter().map(|r| r.capacity).collect();
        let ndev = capacity.len();
        let inner = Arc::new(ServiceInner {
            node,
            capacity,
            sched,
            quotas: TenantQuotas::new(sched.tenant_quota),
            state: Mutex::new(ServiceState {
                queue: SloQueue::new(sched.policy, sched.max_skips),
                reserved: vec![0; ndev],
                peak_reserved: vec![0; ndev],
                in_flight: 0,
                shutdown: false,
            }),
            cv: Condvar::new(),
            last_seen_ns: AtomicU64::new(0),
            cache: Mutex::new(FactorCache::new()),
        });
        let workers = (0..n_workers.max(1))
            .map(|_| {
                let inner = inner.clone();
                std::thread::spawn(move || loop {
                    // Admit the scheduler's best-ranked fitting solve
                    // (under FIFO only the head is ever a candidate),
                    // or exit on shutdown with an empty queue.
                    let admitted = {
                        let mut st = inner.state.lock().unwrap();
                        loop {
                            let ServiceState { queue, reserved, peak_reserved, in_flight, .. } =
                                &mut *st;
                            let picked = queue.pop_admissible(|t, q| {
                                (0..reserved.len())
                                    .all(|d| reserved[d] + q.footprint[d] <= inner.capacity[d])
                                    && inner.quotas.would_admit(t.slo.tenant, q.total_bytes())
                            });
                            if let Some((ticket, q)) = picked {
                                for d in 0..reserved.len() {
                                    reserved[d] += q.footprint[d];
                                    if reserved[d] > peak_reserved[d] {
                                        peak_reserved[d] = reserved[d];
                                    }
                                }
                                inner.note_island_reserved(reserved);
                                inner.quotas.admit(ticket.slo.tenant, q.total_bytes());
                                *in_flight += 1;
                                break Some((ticket, q));
                            }
                            if st.shutdown && st.queue.is_empty() {
                                break None;
                            }
                            // A queued solve may be starved by resident
                            // factors rather than in-flight work: give
                            // one back (lowest score first) before
                            // sleeping. Lock order forbids evicting
                            // under the state lock.
                            if !st.queue.is_empty() {
                                drop(st);
                                let evicted = inner.evict_one();
                                st = inner.state.lock().unwrap();
                                if evicted {
                                    continue;
                                }
                            }
                            st = inner.cv.wait(st).unwrap();
                        }
                    };
                    let (ticket, q) = match admitted {
                        Some(adm) => adm,
                        None => return,
                    };
                    let QueuedSolve { footprint, job } = q;
                    let queue_wait_ns = inner.sim_now_ns().saturating_sub(ticket.enq_ns);
                    let publish = job(ticket, queue_wait_ns);
                    {
                        let mut st = inner.state.lock().unwrap();
                        for d in 0..inner.capacity.len() {
                            st.reserved[d] -= footprint[d];
                        }
                        st.in_flight -= 1;
                    }
                    inner.quotas.release(ticket.slo.tenant, footprint.iter().sum());
                    inner.cv.notify_all();
                    // Only now may the waiter observe completion.
                    publish();
                })
            })
            .collect();
        let small = Arc::new(Mutex::new(SmallState {
            planner: BatchPlanner::new(cfg.policy),
            payloads: HashMap::new(),
            flushers: HashMap::new(),
            decisions: HashMap::new(),
        }));
        // The background dwell flusher (ROADMAP PR 3 follow-up): without
        // it a dwell-expired bucket only flushes on the *next* submit or
        // drain — traffic that simply stops would strand its tail. The
        // tick interval tracks the wall backstop; the tick itself also
        // fires buckets whose *simulated* dwell expired (traffic moved
        // the sim clock, then went quiet).
        let flusher_stop = Arc::new((Mutex::new(false), Condvar::new()));
        let flusher = {
            let inner = inner.clone();
            let small = small.clone();
            let stop = flusher_stop.clone();
            let tick = flusher_tick(cfg.policy.max_wall_dwell);
            Some(std::thread::spawn(move || loop {
                {
                    let (lock, cv) = &*stop;
                    let mut stopped = lock.lock().unwrap();
                    while !*stopped {
                        let (guard, timeout) = cv.wait_timeout(stopped, tick).unwrap();
                        stopped = guard;
                        if timeout.timed_out() {
                            break;
                        }
                    }
                    if *stopped {
                        return;
                    }
                }
                let now_ns = inner.sim_now_ns();
                run_flushes(&inner, &small, |st, ready| flush_due_into(st, now_ns, ready));
            }))
        };
        SolveService { inner, cfg, plans: GridPlanCache::new(), small, workers, flusher, flusher_stop }
    }

    /// Submit a solve with its declared workspace footprint under the
    /// default standard-class SLO. Fails fast if the footprint can
    /// never be admitted (exceeds some device's total capacity or the
    /// whole tenant quota) or spans the wrong device count.
    pub fn submit<T: Send + 'static>(
        &self,
        footprint: Footprint,
        f: impl FnOnce() -> T + Send + 'static,
    ) -> Result<ServiceHandle<T>> {
        self.submit_slo(footprint, Slo::standard(), f)
    }

    /// [`SolveService::submit`] with an explicit [`Slo`] (class,
    /// optional deadline, tenant). Opaque closures carry no cost-model
    /// estimate, so under [`SchedPolicy::EdfSjf`] they rank as
    /// zero-length jobs within their class; the planned distributed
    /// paths attach their [`crate::costmodel::Predictor`] makespans.
    pub fn submit_slo<T: Send + 'static>(
        &self,
        footprint: Footprint,
        slo: Slo,
        f: impl FnOnce() -> T + Send + 'static,
    ) -> Result<ServiceHandle<T>> {
        let (trace, root) = self.inner.node.tracer().new_trace();
        self.submit_with_grid(footprint, (1, 1), slo, 0, false, "opaque", trace, root, None, f)
    }

    /// [`SolveService::submit_slo`] with an explicit process-grid stamp
    /// and makespan estimate — the planned-distributed paths pass their
    /// selector's `(P, Q)` and [`DistPlan::est_ns`] through here —
    /// plus the request's pre-minted trace identity and an optional
    /// predictor-drift probe (see [`crate::obs`]).
    ///
    /// [`DistPlan::est_ns`]: super::admit::DistPlan::est_ns
    #[allow(clippy::too_many_arguments)]
    fn submit_with_grid<T: Send + 'static>(
        &self,
        footprint: Footprint,
        grid: (usize, usize),
        slo: Slo,
        est_ns: u64,
        cache_hit: bool,
        req: &'static str,
        trace: TraceId,
        root: SpanId,
        drift: Option<DriftProbe>,
        f: impl FnOnce() -> T + Send + 'static,
    ) -> Result<ServiceHandle<T>> {
        let (handle, slot2) = handle_pair::<T>();
        let inner = self.inner.clone();
        let job: AdmittedJob = Box::new(move |ticket, queue_wait_ns| {
            let tracer = inner.node.tracer().clone();
            let t0_ns = inner.sim_now_ns();
            if trace.0 != 0 {
                tracer.span(
                    trace,
                    root,
                    "queue-wait",
                    "sched",
                    0,
                    "requests",
                    ticket.enq_ns,
                    ticket.enq_ns.saturating_add(queue_wait_ns),
                    0,
                    0,
                );
            }
            // A panicking solve must not kill the worker: the unwinding
            // is contained here so the reservation release in the worker
            // loop always runs, and the panic is re-raised on the waiter
            // (JoinHandle semantics).
            let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f));
            let end_ns = inner.sim_now_ns();
            let exec_ns = end_ns.saturating_sub(t0_ns);
            if trace.0 != 0 {
                tracer.span(trace, root, "exec", "exec", 0, "requests", t0_ns, end_ns, 0, 0);
                tracer.close_root(
                    trace,
                    root,
                    &format!("request:{req}"),
                    0,
                    ticket.enq_ns,
                    end_ns,
                    0,
                    0,
                );
            }
            if let Some(p) = drift {
                tracer.drift().record(p.key, p.est_model_ns, p.est_used_ns, exec_ns);
            }
            inner.note_completion(&ticket, queue_wait_ns, exec_ns);
            let stats = SolveStats {
                queue_wait_ns,
                exec_ns,
                batch_size: 1,
                coalesce_wait_ns: 0,
                grid,
                cache_hit,
                fused_stages: 1,
            };
            let outcome = match out {
                Ok(v) => Ok((v, stats)),
                Err(p) => Err(ServeError::Failed(panic_message(p))),
            };
            let publish: PublishFn = Box::new(move || {
                publish_one(&slot2, outcome);
            });
            publish
        });
        self.inner.enqueue_job(footprint, slo, est_ns, job)?;
        let tr = self.inner.node.tracer();
        if tr.enabled() && trace.0 != 0 {
            tr.decision(
                trace,
                self.inner.sim_now_ns(),
                "admit",
                format!(
                    "req={req} grid={}x{} est_ns={est_ns} cache_hit={cache_hit}",
                    grid.0, grid.1
                ),
            );
        }
        Ok(handle)
    }

    /// Submit a **distributed** solve through the grid planner: the
    /// per-request [`Predictor::best_grid`] selector (or the
    /// [`SmallConfig::grid`] override) picks the `P × Q` shape, the
    /// solve is admitted against the exact per-device shards of that
    /// shape, and runs scatter → `potrf`/`potrs`/`potri_dist` → gather
    /// on the chosen layout — 1D for small problems (bitwise the seed
    /// path), grid-native at scale. The chosen shape is reported in
    /// [`SolveStats::grid`]. Eigendecompositions go through
    /// [`SolveService::submit_syevd`] instead (their result shape
    /// differs).
    ///
    /// [`Predictor::best_grid`]: crate::costmodel::Predictor::best_grid
    pub fn submit_dist<S: Scalar + MixedCapable>(
        &self,
        routine: DistRoutine,
        a: Matrix<S>,
        rhs: Option<Matrix<S>>,
    ) -> Result<ServiceHandle<Matrix<S>>> {
        self.submit_dist_slo(routine, a, rhs, Slo::standard())
    }

    /// [`SolveService::submit_dist`] with an explicit [`Slo`]. The
    /// plan's [`Predictor`] makespan rides into the queue as the
    /// request's EDF/SJF estimate, and — under [`SchedPolicy::EdfSjf`],
    /// for non-interactive requests — a panel-boundary preemption hook
    /// is installed so queued interactive solves run between this
    /// solve's panels.
    ///
    /// [`Predictor`]: crate::costmodel::Predictor
    pub fn submit_dist_slo<S: Scalar + MixedCapable>(
        &self,
        routine: DistRoutine,
        a: Matrix<S>,
        rhs: Option<Matrix<S>>,
        slo: Slo,
    ) -> Result<ServiceHandle<Matrix<S>>> {
        let n = a.require_square()?;
        if n == 0 {
            return Err(Error::shape("cannot solve an empty system"));
        }
        match (routine, &rhs) {
            (DistRoutine::Syevd, _) => {
                return Err(Error::config("use submit_syevd for eigendecompositions"));
            }
            (DistRoutine::Potrs, None) => {
                return Err(Error::config("potrs needs a right-hand side"));
            }
            (DistRoutine::Potrs, Some(b)) if b.rows() != n => {
                return Err(Error::shape(format!(
                    "rhs has {} rows, matrix is {n}x{n}",
                    b.rows()
                )));
            }
            (DistRoutine::Potrf | DistRoutine::Potri, Some(_)) => {
                return Err(Error::config("only potrs takes a right-hand side"));
            }
            _ => {}
        }
        let ndev = self.inner.capacity.len();
        let nrhs = rhs.as_ref().map(|b| b.cols()).unwrap_or(0);
        // Mixed precision only pays off when there is a right-hand side
        // to refine against; potrf/potri callers get the full factor.
        let numeric = if routine == DistRoutine::Potrs { slo.numeric } else { None };
        let plan = self.plans.plan_numeric(
            routine.name(),
            n,
            nrhs,
            self.cfg.tile,
            ndev,
            S::DTYPE,
            &self.cfg.model,
            self.inner.node.topology(),
            self.cfg.grid,
            numeric,
        )?;
        let mixed = plan.precision.is_mixed();
        let refine_opts = RefineOptions {
            tol: numeric.map(|p| p.tol()).unwrap_or(DEFAULT_REFINE_TOL),
            max_iters: DEFAULT_REFINE_CAP,
        };
        let node = self.inner.node.clone();
        let model = self.cfg.model.clone();
        let kind = plan.kind;
        let hook = self.preempt_hook(slo);
        let (trace, root) = node.tracer().new_trace();
        // Factor-cache probe: a resident L for this exact A (content
        // hash) on this exact layout lets the solve skip the scatter
        // and the factorization — only the triangular tail runs, and
        // its EDF/SJF estimate shrinks by the same scatter+potrf
        // prefix the eviction scorer prices (`Predictor::recompute_ns`).
        let pred = Predictor {
            model: model.clone(),
            topo: self.inner.node.topology().clone(),
            dtype: S::DTYPE,
        };
        if mixed {
            let tr = self.inner.node.tracer();
            if tr.enabled() {
                let full_ns = secs_to_ns(pred.dist_makespan(
                    routine.name(),
                    n,
                    nrhs,
                    self.cfg.tile,
                    plan.grid.0,
                    plan.grid.1,
                ));
                tr.decision(
                    trace,
                    self.inner.sim_now_ns(),
                    "mixed-route",
                    format!(
                        "precision={} est_ns={} full_ns={} win_ns={}",
                        plan.precision.name(),
                        plan.est_ns,
                        full_ns,
                        full_ns.saturating_sub(plan.est_ns)
                    ),
                );
            }
        }
        let cache_cfg = if self.cfg.factor_cache {
            // A mixed solve factors (and caches) in the working dtype:
            // key the entry on that dtype so a full-precision factor of
            // the same bytes can never alias it, and price a hit as the
            // mixed scatter+potrf prefix it skips.
            let mut key = FactorKey::of(&a, self.cfg.tile, plan.grid);
            let re_ns = match plan.precision {
                Precision::Mixed(w) => {
                    key.dtype = w;
                    secs_to_ns(pred.potrf2d_mixed(n, self.cfg.tile, plan.grid.0, plan.grid.1))
                }
                Precision::Full => {
                    pred.recompute_ns(n, self.cfg.tile, plan.grid.0, plan.grid.1)
                }
            };
            Some((key, re_ns))
        } else {
            None
        };
        let mut est_ns = plan.est_ns;
        let mut cached_ptrs: Option<Vec<DevPtr>> = None;
        if let Some((key, re_ns)) = cache_cfg {
            let tr = self.inner.node.tracer();
            match self.inner.probe_factor(&key) {
                Some((ptrs, _kind)) => {
                    self.inner.node.metrics().add_cache_hit();
                    est_ns = est_ns.saturating_sub(re_ns);
                    cached_ptrs = Some(ptrs);
                    if tr.enabled() {
                        tr.decision(
                            trace,
                            self.inner.sim_now_ns(),
                            "cache-hit",
                            format!("resident factor skips {re_ns} ns of scatter+potrf"),
                        );
                    }
                }
                None => {
                    self.inner.node.metrics().add_cache_miss();
                    if tr.enabled() {
                        tr.decision(
                            trace,
                            self.inner.sim_now_ns(),
                            "cache-miss",
                            format!("n={n} grid={}x{}", plan.grid.0, plan.grid.1),
                        );
                    }
                }
            }
        }
        let cache_hit = cached_ptrs.is_some();
        let tracer = self.inner.node.tracer();
        let drift_key = DriftKey {
            routine: routine.name().to_string(),
            dtype: S::DTYPE.name().to_string(),
            n: n as u64,
            grid: (plan.grid.0 as u32, plan.grid.1 as u32),
        };
        if self.cfg.drift_correction && !cache_hit {
            est_ns = tracer.drift().corrected_est(&drift_key, est_ns);
        }
        let drift = if !cache_hit && (tracer.enabled() || self.cfg.drift_correction) {
            Some(DriftProbe { key: drift_key, est_model_ns: plan.est_ns, est_used_ns: est_ns })
        } else {
            None
        };
        let inner = self.inner.clone();
        self.submit_with_grid(
            plan.footprint,
            plan.grid,
            slo,
            est_ns,
            cache_hit,
            routine.name(),
            trace,
            root,
            drift,
            move || -> Matrix<S> {
                let mut cached_ptrs = cached_ptrs;
                let run = || -> Result<Matrix<S>> {
                    if mixed {
                        let b = rhs.as_ref().expect("validated above");
                        let mrun = MixedRun {
                            node: &node,
                            model: &model,
                            pipeline: PipelineConfig::barrier(),
                            layout: kind,
                            trace: (trace, root),
                            preempt: hook.clone(),
                        };
                        let fallback = |why: String| {
                            node.metrics().add_mixed_fallback();
                            let tr = node.tracer();
                            if tr.enabled() {
                                tr.decision(trace, node.sim_time_ns(), "mixed-fallback", why);
                            }
                        };
                        let attempt: Result<Matrix<S>> = if let Some(ptrs) = cached_ptrs.take() {
                            // HIT: the resident factor is already in the
                            // working dtype — only the refinement loop
                            // runs, against the full-precision rhs.
                            let (key, _) = cache_cfg.expect("a hit implies the cache is on");
                            let _guard = PinGuard { inner: inner.clone(), key };
                            let dm = DistMatrix::<S::Working>::from_panels(&node, n, kind, ptrs)?;
                            let out = S::mixed_refine(&mrun, &dm, &a, b, refine_opts, false);
                            // Give the panels back to the cache un-freed.
                            let _ = dm.into_panels();
                            out.map(|(x, _)| x)
                        } else {
                            match S::mixed_factor(&mrun, &a) {
                                Ok(l) => {
                                    let out = S::mixed_refine(&mrun, &l, &a, b, refine_opts, true);
                                    match (&out, cache_cfg) {
                                        (Ok(_), Some((key, re_ns))) => {
                                            inner.insert_factor(key, kind, l.into_panels(), re_ns)
                                        }
                                        _ => l.free()?,
                                    }
                                    out.map(|(x, _)| x)
                                }
                                Err(e) => Err(e),
                            }
                        };
                        match attempt {
                            Ok(x) => return Ok(x),
                            Err(Error::RefineStalled { iters, residual, tol }) => fallback(format!(
                                "refine stalled: iters={iters} residual={residual:.3e} tol={tol:.1e}"
                            )),
                            Err(Error::NotPositiveDefinite { minor }) => fallback(format!(
                                "demoted matrix lost definiteness at minor {minor}"
                            )),
                            Err(e) => return Err(e),
                        }
                        // Typed fallback: recover at full precision, cold,
                        // and never seed the cache — the key above carries
                        // the working dtype and must not alias this factor.
                        let backend = SolverBackend::<S>::Native;
                        let mut ctx = Ctx::new(&node, &model, &backend).with_trace(trace, root);
                        if let Some(h) = hook.clone() {
                            ctx = ctx.with_preempt_hook(h);
                        }
                        let mut dm = DistMatrix::scatter(&node, &a, kind)?;
                        potrf_dist(&ctx, &mut dm)?;
                        return potrs_dist(&ctx, &dm, b);
                    }
                    let backend = SolverBackend::<S>::Native;
                    let mut ctx = Ctx::new(&node, &model, &backend).with_trace(trace, root);
                    if let Some(h) = hook {
                        ctx = ctx.with_preempt_hook(h);
                    }
                    if let Some(ptrs) = cached_ptrs.take() {
                        // HIT: view the resident shards (the guard keeps
                        // the entry pinned — and tears it down if it was
                        // invalidated mid-flight — on every exit path).
                        let (key, _) = cache_cfg.expect("a hit implies the cache is on");
                        let _guard = PinGuard { inner, key };
                        let dm = DistMatrix::<S>::from_panels(&node, n, kind, ptrs)?;
                        let out = match routine {
                            DistRoutine::Potrf => dm.gather(),
                            DistRoutine::Potrs => {
                                potrs_dist(&ctx, &dm, rhs.as_ref().expect("validated above"))
                            }
                            DistRoutine::Potri => {
                                // potri destroys its input: run it on a
                                // bitwise round-tripped copy so L stays
                                // resident for the next hit.
                                let l = dm.gather()?;
                                let mut copy = DistMatrix::scatter(&node, &l, kind)?;
                                potri_dist(&ctx, &mut copy)?;
                                copy.gather()
                            }
                            DistRoutine::Syevd => unreachable!("rejected at submit"),
                        };
                        // Give the panels back to the cache un-freed.
                        let _ = dm.into_panels();
                        return out;
                    }
                    // COLD: bitwise the uncached route.
                    let mut dm = DistMatrix::scatter(&node, &a, kind)?;
                    potrf_dist(&ctx, &mut dm)?;
                    let out = match routine {
                        DistRoutine::Potrf => dm.gather(),
                        DistRoutine::Potrs => {
                            potrs_dist(&ctx, &dm, rhs.as_ref().expect("validated above"))
                        }
                        DistRoutine::Potri => {
                            potri_dist(&ctx, &mut dm)?;
                            dm.gather()
                        }
                        DistRoutine::Syevd => unreachable!("rejected at submit"),
                    }?;
                    // Seed the cache with the still-resident L. potri ran
                    // in place and destroyed it — nothing to keep.
                    if let Some((key, re_ns)) = cache_cfg {
                        if routine != DistRoutine::Potri {
                            inner.insert_factor(key, kind, dm.into_panels(), re_ns);
                        }
                    }
                    Ok(out)
                };
                match run() {
                    Ok(x) => x,
                    // Surfaces on the waiter, like any panicking solve.
                    Err(e) => panic!("distributed solve failed: {e}"),
                }
            },
        )
    }

    /// The panel-boundary preemption hook for a non-interactive solve
    /// under [`SchedPolicy::EdfSjf`]; `None` otherwise (FIFO never
    /// reorders, and an interactive solve must not preempt itself).
    fn preempt_hook(&self, slo: Slo) -> Option<Arc<dyn Fn() + Send + Sync>> {
        if self.inner.sched.policy == SchedPolicy::EdfSjf && slo.class != SloClass::Interactive {
            let inner = self.inner.clone();
            Some(Arc::new(move || try_run_interactive(&inner)))
        } else {
            None
        }
    }

    /// Distributed eigendecomposition through the same grid planner:
    /// ascending eigenvalues + eigenvector columns.
    pub fn submit_syevd<S: Scalar>(
        &self,
        a: Matrix<S>,
    ) -> Result<ServiceHandle<(Vec<S::Real>, Matrix<S>)>> {
        self.submit_syevd_slo(a, Slo::standard())
    }

    /// [`SolveService::submit_syevd`] with an explicit [`Slo`].
    pub fn submit_syevd_slo<S: Scalar>(
        &self,
        a: Matrix<S>,
        slo: Slo,
    ) -> Result<ServiceHandle<(Vec<S::Real>, Matrix<S>)>> {
        let n = a.require_square()?;
        if n == 0 {
            return Err(Error::shape("cannot solve an empty system"));
        }
        let ndev = self.inner.capacity.len();
        let plan = self.plans.plan(
            "syevd",
            n,
            0,
            self.cfg.tile,
            ndev,
            S::DTYPE,
            &self.cfg.model,
            self.inner.node.topology(),
            self.cfg.grid,
        )?;
        let node = self.inner.node.clone();
        let model = self.cfg.model.clone();
        let kind = plan.kind;
        let (trace, root) = node.tracer().new_trace();
        let tracer = self.inner.node.tracer();
        let drift_key = DriftKey {
            routine: "syevd".to_string(),
            dtype: S::DTYPE.name().to_string(),
            n: n as u64,
            grid: (plan.grid.0 as u32, plan.grid.1 as u32),
        };
        let mut est_ns = plan.est_ns;
        if self.cfg.drift_correction {
            est_ns = tracer.drift().corrected_est(&drift_key, est_ns);
        }
        let drift = if tracer.enabled() || self.cfg.drift_correction {
            Some(DriftProbe { key: drift_key, est_model_ns: plan.est_ns, est_used_ns: est_ns })
        } else {
            None
        };
        // syevd shares no potrf prefix with the Cholesky family, so it
        // bypasses the factor cache entirely.
        self.submit_with_grid(
            plan.footprint,
            plan.grid,
            slo,
            est_ns,
            false,
            "syevd",
            trace,
            root,
            drift,
            move || -> (Vec<S::Real>, Matrix<S>) {
                let run = || -> Result<(Vec<S::Real>, Matrix<S>)> {
                    let backend = SolverBackend::<S>::Native;
                    let ctx = Ctx::new(&node, &model, &backend).with_trace(trace, root);
                    let mut dm = DistMatrix::scatter(&node, &a, kind)?;
                    let vals = syevd_dist(&ctx, &mut dm)?;
                    Ok((vals, dm.gather()?))
                };
                match run() {
                    Ok(out) => out,
                    Err(e) => panic!("distributed syevd failed: {e}"),
                }
            },
        )
    }

    /// Submit a fused [`SolveDag`] under the default standard-class SLO.
    pub fn submit_dag<S: Scalar>(&self, dag: SolveDag<S>) -> Result<Vec<ServiceHandle<Matrix<S>>>> {
        self.submit_dag_slo(dag, Slo::standard())
    }

    /// Submit a chain of routines against one matrix as a **single
    /// fused job**: the chain is planned once — on the heaviest stage's
    /// preferred grid, so every stage shares one resident layout — `A`
    /// is scattered and factored once, and the stages run back-to-back
    /// on the resident shards. Each stage resolves on its own handle
    /// (in submission order) with [`SolveStats::fused_stages`] set to
    /// the chain length. The fused EDF/SJF estimate is the first
    /// stage's full makespan plus only the *tails* of the rest (each
    /// stage's plan minus the shared scatter+potrf prefix), and the
    /// fused footprint is the elementwise max of the stage footprints
    /// — the stages execute sequentially in one reservation.
    ///
    /// With [`SmallConfig::factor_cache`] on, the chain probes the
    /// cache like any distributed solve: a hit skips the scatter and
    /// factorization for the whole chain, and a cold chain without an
    /// [`DagStage::Inverse`] seeds the cache on completion.
    pub fn submit_dag_slo<S: Scalar>(
        &self,
        dag: SolveDag<S>,
        slo: Slo,
    ) -> Result<Vec<ServiceHandle<Matrix<S>>>> {
        let SolveDag { a, stages } = dag;
        let n = a.require_square()?;
        if n == 0 {
            return Err(Error::shape("cannot solve an empty system"));
        }
        if stages.is_empty() {
            return Err(Error::config("a solve DAG needs at least one stage"));
        }
        for (i, s) in stages.iter().enumerate() {
            match s {
                DagStage::Inverse if i + 1 != stages.len() => {
                    return Err(Error::config(
                        "potri destroys the factor — Inverse must be the last stage",
                    ));
                }
                DagStage::Solve(b) if b.rows() != n => {
                    return Err(Error::shape(format!(
                        "rhs has {} rows, matrix is {n}x{n}",
                        b.rows()
                    )));
                }
                _ => {}
            }
        }
        let ndev = self.inner.capacity.len();
        // Plan the chain on the heaviest stage's preferred grid (potri
        // > potrs > potrf by workspace and tail weight), then re-plan
        // every stage with that shape forced so the whole chain shares
        // one resident layout.
        let (lead_name, lead_nrhs) = if stages.iter().any(|s| matches!(s, DagStage::Inverse)) {
            ("potri", 0)
        } else if let Some(max_rhs) = stages
            .iter()
            .filter_map(|s| match s {
                DagStage::Solve(b) => Some(b.cols()),
                _ => None,
            })
            .max()
        {
            ("potrs", max_rhs)
        } else {
            ("potrf", 0)
        };
        let lead = self.plans.plan(
            lead_name,
            n,
            lead_nrhs,
            self.cfg.tile,
            ndev,
            S::DTYPE,
            &self.cfg.model,
            self.inner.node.topology(),
            self.cfg.grid,
        )?;
        let grid = lead.grid;
        let kind = lead.kind;
        let re_ns = Predictor {
            model: self.cfg.model.clone(),
            topo: self.inner.node.topology().clone(),
            dtype: S::DTYPE,
        }
        .recompute_ns(n, self.cfg.tile, grid.0, grid.1);
        let mut per_dev = vec![0usize; ndev];
        let mut est_ns: u64 = 0;
        for (i, s) in stages.iter().enumerate() {
            let (name, nrhs) = match s {
                DagStage::Factor => ("potrf", 0),
                DagStage::Solve(b) => ("potrs", b.cols()),
                DagStage::Inverse => ("potri", 0),
            };
            let plan = self.plans.plan(
                name,
                n,
                nrhs,
                self.cfg.tile,
                ndev,
                S::DTYPE,
                &self.cfg.model,
                self.inner.node.topology(),
                Some(grid),
            )?;
            for (d, &b) in plan.footprint.as_slice().iter().enumerate() {
                per_dev[d] = per_dev[d].max(b);
            }
            // The scatter+potrf prefix is paid once, by the first stage.
            let cost = if i == 0 { plan.est_ns } else { plan.est_ns.saturating_sub(re_ns) };
            est_ns = est_ns.saturating_add(cost);
        }
        let footprint = Footprint::per_device(per_dev);
        let (trace, root) = self.inner.node.tracer().new_trace();
        // Factor-cache probe, exactly as in `submit_dist_slo`: a hit
        // drops the shared prefix from the whole chain's estimate.
        let cache_cfg = if self.cfg.factor_cache {
            Some((FactorKey::of(&a, self.cfg.tile, grid), re_ns))
        } else {
            None
        };
        let mut cached_ptrs: Option<Vec<DevPtr>> = None;
        if let Some((key, re)) = cache_cfg {
            let tr = self.inner.node.tracer();
            match self.inner.probe_factor(&key) {
                Some((ptrs, _kind)) => {
                    self.inner.node.metrics().add_cache_hit();
                    est_ns = est_ns.saturating_sub(re);
                    cached_ptrs = Some(ptrs);
                    if tr.enabled() {
                        tr.decision(
                            trace,
                            self.inner.sim_now_ns(),
                            "cache-hit",
                            format!("resident factor skips {re} ns of the fused chain"),
                        );
                    }
                }
                None => {
                    self.inner.node.metrics().add_cache_miss();
                    if tr.enabled() {
                        tr.decision(
                            trace,
                            self.inner.sim_now_ns(),
                            "cache-miss",
                            format!("n={n} grid={}x{}", grid.0, grid.1),
                        );
                    }
                }
            }
        }
        let cache_hit = cached_ptrs.is_some();
        let total = stages.len();
        let mut handles = Vec::with_capacity(total);
        let mut slots = Vec::with_capacity(total);
        for _ in 0..total {
            let (h, s) = handle_pair::<Matrix<S>>();
            handles.push(h);
            slots.push(s);
        }
        let has_inverse = matches!(stages.last(), Some(DagStage::Inverse));
        let node = self.inner.node.clone();
        let model = self.cfg.model.clone();
        let hook = self.preempt_hook(slo);
        let inner = self.inner.clone();
        let tracer = self.inner.node.tracer().clone();
        let job: AdmittedJob = Box::new(move |ticket, queue_wait_ns| {
            let t0_ns = inner.sim_now_ns();
            if trace.0 != 0 {
                tracer.span(
                    trace,
                    root,
                    "queue-wait",
                    "sched",
                    0,
                    "requests",
                    ticket.enq_ns,
                    ticket.enq_ns.saturating_add(queue_wait_ns),
                    0,
                    0,
                );
            }
            let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                || -> Result<Vec<Matrix<S>>> {
                    let backend = SolverBackend::<S>::Native;
                    let mut ctx = Ctx::new(&node, &model, &backend).with_trace(trace, root);
                    if let Some(h) = hook {
                        ctx = ctx.with_preempt_hook(h);
                    }
                    // `owned` ⇔ Drop may free dm's panels (they are the
                    // job's own, not the cache's residents).
                    let mut owned = true;
                    let mut guard: Option<PinGuard> = None;
                    let mut dm = match cached_ptrs {
                        Some(ptrs) => {
                            let (key, _) = cache_cfg.expect("a hit implies the cache is on");
                            let g = PinGuard { inner: inner.clone(), key };
                            let view = DistMatrix::<S>::from_panels(&node, n, kind, ptrs)?;
                            if has_inverse {
                                // potri will destroy the factor: run
                                // the whole chain on a bitwise
                                // round-tripped copy and release the
                                // pin right away.
                                let l = view.gather()?;
                                let _ = view.into_panels();
                                drop(g);
                                DistMatrix::scatter(&node, &l, kind)?
                            } else {
                                owned = false;
                                guard = Some(g);
                                view
                            }
                        }
                        None => {
                            let mut dm = DistMatrix::scatter(&node, &a, kind)?;
                            potrf_dist(&ctx, &mut dm)?;
                            dm
                        }
                    };
                    let mut results = Vec::with_capacity(stages.len());
                    for s in &stages {
                        match s {
                            DagStage::Factor => results.push(dm.gather()?),
                            DagStage::Solve(b) => results.push(potrs_dist(&ctx, &dm, b)?),
                            DagStage::Inverse => {
                                potri_dist(&ctx, &mut dm)?;
                                results.push(dm.gather()?);
                            }
                        }
                    }
                    if !owned {
                        // Give the panels back to the cache un-freed.
                        let _ = dm.into_panels();
                        drop(guard);
                    } else if let Some((key, re)) = cache_cfg {
                        if !has_inverse {
                            inner.insert_factor(key, kind, dm.into_panels(), re);
                        }
                    }
                    Ok(results)
                },
            ));
            let end_ns = inner.sim_now_ns();
            let exec_ns = end_ns.saturating_sub(t0_ns);
            if trace.0 != 0 {
                tracer.span(trace, root, "exec", "exec", 0, "requests", t0_ns, end_ns, 0, 0);
                tracer.close_root(trace, root, "request:dag", 0, ticket.enq_ns, end_ns, 0, 0);
            }
            inner.note_completion(&ticket, queue_wait_ns, exec_ns);
            if total > 1 {
                inner.node.metrics().add_dag_fused_stages((total - 1) as u64);
            }
            let stats = SolveStats {
                queue_wait_ns,
                exec_ns,
                batch_size: 1,
                coalesce_wait_ns: 0,
                grid,
                cache_hit,
                fused_stages: total,
            };
            let publish: PublishFn = Box::new(move || match out {
                Ok(Ok(results)) => {
                    for (slot, m) in slots.iter().zip(results) {
                        publish_one(slot, Ok((m, stats)));
                    }
                }
                Ok(Err(e)) => publish_failure(&slots, format!("fused solve failed: {e}")),
                Err(p) => publish_failure(&slots, panic_message(p)),
            });
            publish
        });
        self.inner.enqueue_job(footprint, slo, est_ns, job)?;
        let tr = self.inner.node.tracer();
        if tr.enabled() && trace.0 != 0 {
            tr.decision(
                trace,
                self.inner.sim_now_ns(),
                "admit",
                format!("req=dag stages={total} est_ns={est_ns} cache_hit={cache_hit}"),
            );
        }
        Ok(handles)
    }

    /// Submit a **small** solve through the admission → coalesce →
    /// sweep path (see the module docs). The cost model dispatches:
    /// requests under the smallness cut for which
    /// [`Predictor::batched_wins`] holds are coalesced into a fused
    /// per-device batched sweep with their bucket-mates; everything
    /// else runs the ordinary distributed route. Either way the
    /// returned handle resolves with this request's own result and
    /// [`SolveStats`] (bucket occupancy and coalesce wait included).
    /// A solve that fails numerically (e.g. a non-positive-definite
    /// input) re-raises at [`ServiceHandle::wait`] — and only on its
    /// own handle: a failed bucket sweep reruns its requests one at a
    /// time, so bucket-mates of a bad input still succeed.
    ///
    /// A bucket below its occupancy target flushes when a later submit
    /// — on either path — finds it past the policy's queue-dwell bound
    /// (cost-model nanoseconds, with [`BatchPolicy::max_wall_dwell`]
    /// of real time as the liveness backstop for traffic that never
    /// advances the simulated clock), on
    /// [`SolveService::flush_small`] / [`SolveService::drain`], or —
    /// when traffic stops entirely — by the service's background
    /// dwell-flusher tick, so the latency bound holds without any
    /// follow-up call.
    ///
    /// [`Predictor::batched_wins`]: crate::costmodel::Predictor::batched_wins
    pub fn submit_small<S: Scalar + MixedCapable>(
        &self,
        routine: SmallRoutine,
        a: Matrix<S>,
        rhs: Option<Matrix<S>>,
    ) -> Result<ServiceHandle<Matrix<S>>> {
        self.submit_small_slo(routine, a, rhs, Slo::standard())
    }

    /// [`SolveService::submit_small`] with an explicit [`Slo`]. A
    /// coalesced bucket is enqueued under its **most urgent** member's
    /// class and earliest member deadline (tenant quotas bill the
    /// distributed path only — a shared pod has no single owner).
    pub fn submit_small_slo<S: Scalar + MixedCapable>(
        &self,
        routine: SmallRoutine,
        a: Matrix<S>,
        rhs: Option<Matrix<S>>,
        slo: Slo,
    ) -> Result<ServiceHandle<Matrix<S>>> {
        let n = a.require_square()?;
        if n == 0 {
            return Err(Error::shape("cannot solve an empty system"));
        }
        match (routine, &rhs) {
            (SmallRoutine::Potrs, None) => {
                return Err(Error::config("potrs needs a right-hand side"));
            }
            (SmallRoutine::Potrs, Some(b)) if b.rows() != n => {
                return Err(Error::shape(format!(
                    "rhs has {} rows, matrix is {n}x{n}",
                    b.rows()
                )));
            }
            (SmallRoutine::Potrf | SmallRoutine::Potri, Some(_)) => {
                return Err(Error::config("only potrs takes a right-hand side"));
            }
            _ => {}
        }
        let ndev = self.inner.capacity.len();
        let nrhs = rhs.as_ref().map(|b| b.cols()).unwrap_or(1);
        // Capacity gate: the worst-case bucket this request could join
        // (a full `max_batch` of its size-class, round-robin over the
        // node) must itself be admittable as one pod — coalescing must
        // never turn individually-feasible solves into a bucket that
        // can never be reserved. Oversize traffic runs distributed,
        // under its own per-solve reservation.
        let e = S::DTYPE.size_of();
        let class = crate::batch::size_class(n) as usize;
        let per_system = class * class * e
            + if matches!(routine, SmallRoutine::Potrs) { class * nrhs * e } else { 0 };
        let worst_bucket = self.cfg.policy.max_batch.div_ceil(ndev.max(1)) * per_system;
        let bucket_fits = self.inner.capacity.iter().all(|&cap| worst_bucket <= cap);
        let coalesce = bucket_fits
            && n <= self.cfg.policy.small_dim
            && self.batched_decision::<S>(routine, class);
        if !coalesce {
            // The latency bound holds on *every* submit: buckets other
            // requests left behind flush here even though this request
            // never touches the coalescer.
            self.flush_due_small();
            return self.submit_small_distributed(routine, a, rhs, slo);
        }

        let (handle, slot) = handle_pair::<Matrix<S>>();
        let key = BucketKey::new(routine, S::DTYPE, n);
        let now_ns = self.sim_now_ns();
        let job = SmallJob { a, rhs, slo, slot };
        let model = self.cfg.model.clone();
        run_flushes(&self.inner, &self.small, |st, ready| {
            st.flushers.entry(key).or_insert_with(|| small_flusher::<S>(routine, model));
            let (id, flushed) = st.planner.push(key, now_ns);
            st.payloads.insert(id, Box::new(job));
            if let Some(bucket) = flushed {
                collect_flush(st, bucket, ready);
            }
            // Latency bound: any bucket whose oldest request has
            // dwelled past the policy bound flushes now, whatever its
            // dtype — the stored flusher knows how to downcast it.
            flush_due_into(st, now_ns, ready);
        });
        Ok(handle)
    }

    /// The simulated clock in integer nanoseconds — the timebase of
    /// the coalescer's dwell bound.
    fn sim_now_ns(&self) -> u64 {
        self.inner.sim_now_ns()
    }

    /// Memoized batched-vs-distributed cut: evaluated once per
    /// (routine, dtype, size-class) at the class size (the bucket
    /// granularity; `nrhs = 1`, whose triangular-solve term scales the
    /// two paths alike), then served from the map — the hot submit
    /// path never clones the topology.
    fn batched_decision<S: Scalar>(&self, routine: SmallRoutine, class: usize) -> bool {
        let key = (routine, S::DTYPE, class as u32);
        let mut st = self.small.lock().unwrap();
        if let Some(&win) = st.decisions.get(&key) {
            return win;
        }
        let predictor = Predictor {
            model: self.cfg.model.clone(),
            topo: self.inner.node.topology().clone(),
            dtype: S::DTYPE,
        };
        let win = predictor.batched_wins(
            routine.name(),
            class,
            1,
            self.cfg.tile,
            self.inner.capacity.len(),
            self.cfg.policy.max_batch,
        );
        st.decisions.insert(key, win);
        win
    }

    /// The one-at-a-time fallback of [`SolveService::submit_small`]:
    /// the planner-routed distributed path ([`SolveService::submit_dist`]
    /// — for small shapes the selector keeps the 1D layout, so this is
    /// bitwise the seed route).
    fn submit_small_distributed<S: Scalar + MixedCapable>(
        &self,
        routine: SmallRoutine,
        a: Matrix<S>,
        rhs: Option<Matrix<S>>,
        slo: Slo,
    ) -> Result<ServiceHandle<Matrix<S>>> {
        let dist = match routine {
            SmallRoutine::Potrf => DistRoutine::Potrf,
            SmallRoutine::Potrs => DistRoutine::Potrs,
            SmallRoutine::Potri => DistRoutine::Potri,
        };
        self.submit_dist_slo(dist, a, rhs, slo)
    }

    /// Flush the buckets whose oldest request has dwelled past the
    /// policy bound (cost-model nanoseconds). Runs on every
    /// `submit_small`, whichever path the new request takes.
    pub fn flush_due_small(&self) {
        let now_ns = self.sim_now_ns();
        run_flushes(&self.inner, &self.small, |st, ready| flush_due_into(st, now_ns, ready));
    }

    /// Force-flush every pending coalescer bucket — the drain path,
    /// and the lever for bounding tail latency once traffic stops.
    pub fn flush_small(&self) {
        let now_ns = self.sim_now_ns();
        run_flushes(&self.inner, &self.small, |st, ready| {
            for bucket in st.planner.flush_all(now_ns) {
                collect_flush(st, bucket, ready);
            }
        });
    }

    /// Small solves waiting in the coalescer (not yet flushed).
    pub fn pending_small(&self) -> usize {
        self.small.lock().unwrap().planner.pending()
    }

    /// The batched small-solve configuration.
    pub fn small_config(&self) -> &SmallConfig {
        &self.cfg
    }

    /// The shared node solves run on.
    pub fn node(&self) -> &SimNode {
        &self.inner.node
    }

    /// Per-device VRAM capacities the accountant admits against.
    pub fn capacity(&self) -> &[usize] {
        &self.inner.capacity
    }

    /// Solves queued but not yet admitted.
    pub fn pending(&self) -> usize {
        self.inner.state.lock().unwrap().queue.len()
    }

    /// Solves currently in flight.
    pub fn in_flight(&self) -> usize {
        self.inner.state.lock().unwrap().in_flight
    }

    /// Current per-device reserved bytes.
    pub fn reserved(&self) -> Vec<usize> {
        self.inner.state.lock().unwrap().reserved.clone()
    }

    /// High-water mark of per-device reserved bytes — the accountant's
    /// proof it never over-admitted.
    pub fn peak_reserved(&self) -> Vec<usize> {
        self.inner.state.lock().unwrap().peak_reserved.clone()
    }

    /// The scheduler configuration this service runs under.
    pub fn sched_config(&self) -> SchedConfig {
        self.inner.sched
    }

    /// Bytes currently admitted for `tenant` (0 when quotas are off or
    /// the tenant has nothing in flight).
    pub fn tenant_admitted(&self, tenant: u32) -> usize {
        self.inner.quotas.admitted(tenant)
    }

    /// High-water mark of admitted bytes for `tenant` — the quota
    /// accountant's proof it never over-admitted.
    pub fn tenant_peak(&self, tenant: u32) -> usize {
        self.inner.quotas.peak(tenant)
    }

    /// Live entries in the resident factor cache (0 with
    /// [`SmallConfig::factor_cache`] off).
    pub fn cached_factors(&self) -> usize {
        self.inner.cache.lock().unwrap().len()
    }

    /// Total device bytes held by resident cached factors — charged
    /// inside [`SolveService::reserved`], never in addition to it.
    pub fn cached_factor_bytes(&self) -> usize {
        self.inner.cache.lock().unwrap().resident_bytes()
    }

    /// Evict every evictable cached factor, freeing its shards and
    /// releasing its reservation. Entries pinned by in-flight hits
    /// survive. Returns the number evicted.
    pub fn evict_cached_factors(&self) -> usize {
        let mut n = 0;
        while self.inner.evict_one() {
            n += 1;
        }
        n
    }

    /// Block until every submitted solve has finished executing and
    /// released its reservation. Result *publication* to the handles
    /// happens immediately after release, so a freshly drained
    /// handle's [`ServiceHandle::is_ready`] may still flip a moment
    /// later — [`ServiceHandle::wait`] is the synchronization point
    /// for result availability.
    pub fn drain(&self) {
        // Partial coalescer buckets would otherwise wait forever for
        // bucket-mates that are not coming.
        self.flush_small();
        let mut st = self.inner.state.lock().unwrap();
        while !st.queue.is_empty() || st.in_flight > 0 {
            st = self.inner.cv.wait(st).unwrap();
        }
    }
}

impl Drop for SolveService {
    fn drop(&mut self) {
        // Stop the background flusher first: a tick racing the shutdown
        // below would enqueue into a closed queue.
        {
            let (lock, cv) = &*self.flusher_stop;
            *lock.lock().unwrap() = true;
            cv.notify_all();
        }
        if let Some(f) = self.flusher.take() {
            let _ = f.join();
        }
        // Push any still-coalescing smalls into the queue so their
        // waiters resolve before the workers exit.
        self.flush_small();
        {
            let mut st = self.inner.state.lock().unwrap();
            st.shutdown = true;
        }
        self.inner.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // With the workers gone no pins remain: free every resident
        // factor and give back its reservation.
        let drained = self.inner.cache.lock().unwrap().drain();
        for (_, e) in drained {
            self.inner.free_entry(&e);
        }
    }
}

type SmallSlot<S> = Slot<Matrix<S>>;

/// The one lock-collect-execute choreography every flush path shares:
/// `select` picks buckets under the small-state lock, and the flushers
/// run only after it is released (they re-enter the service through
/// `ServiceInner::enqueue_job`, so running them under the lock would
/// deadlock against concurrent submits). A free function so the
/// background flusher thread can tick without a `&SolveService`.
fn run_flushes(
    inner: &Arc<ServiceInner>,
    small: &Mutex<SmallState>,
    select: impl FnOnce(&mut SmallState, &mut Vec<PendingFlush>),
) {
    let mut ready: Vec<PendingFlush> = Vec::new();
    {
        let mut st = small.lock().unwrap();
        select(&mut st, &mut ready);
    }
    for (flusher, bucket, payloads) in ready {
        flusher(inner, bucket, payloads);
    }
}

/// Move every dwell-expired bucket into `ready` (the shared half of
/// `flush_due_small` and the coalesced-submit path).
fn flush_due_into(st: &mut SmallState, now_ns: u64, ready: &mut Vec<PendingFlush>) {
    for due_key in st.planner.due(now_ns) {
        if let Some(bucket) = st.planner.flush(due_key, now_ns) {
            collect_flush(st, bucket, ready);
        }
    }
}

/// Pull a flushed bucket's payloads and flusher out of the planner
/// state; the caller executes the flush after releasing the lock.
fn collect_flush(st: &mut SmallState, bucket: FlushedBucket, out: &mut Vec<PendingFlush>) {
    let flusher =
        st.flushers.get(&bucket.key).expect("flusher installed on first push").clone();
    let payloads = bucket
        .ids
        .iter()
        .map(|id| st.payloads.remove(id).expect("payload stored with its id"))
        .collect();
    out.push((flusher, bucket, payloads));
}

/// The type-erasure bridge for one bucket key: downcast the payloads
/// back to `SmallJob<S>`, admit the pod against per-device VRAM, run
/// the fused sweep, and publish every request's individual outcome.
fn small_flusher<S: Scalar>(routine: SmallRoutine, model: GpuCostModel) -> Arc<SmallFlusher> {
    Arc::new(move |inner: &Arc<ServiceInner>, bucket: FlushedBucket, payloads: Vec<SmallPayload>| {
        let mut systems = Vec::with_capacity(payloads.len());
        let mut rhss = Vec::with_capacity(payloads.len());
        let mut slots = Vec::with_capacity(payloads.len());
        let mut slos = Vec::with_capacity(payloads.len());
        for p in payloads {
            let job = *p.downcast::<SmallJob<S>>().expect("bucket key pins the dtype");
            systems.push(job.a);
            rhss.push(job.rhs);
            slos.push(job.slo);
            slots.push(job.slot);
        }
        // The pod schedules as its most urgent member: best class,
        // earliest concrete deadline. Tenant 0 — a shared pod has no
        // single quota owner.
        let pod_slo = Slo {
            class: slos.iter().map(|s| s.class).min().unwrap_or(SloClass::Standard),
            deadline_ns: slos.iter().filter_map(|s| s.deadline_ns).min(),
            tenant: 0,
            numeric: None,
        };
        let occupancy = systems.len();
        let dims: Vec<(usize, usize)> = systems
            .iter()
            .zip(&rhss)
            .map(|(a, b)| (a.rows(), b.as_ref().map(|m| m.cols()).unwrap_or(0)))
            .collect();
        let ndev = inner.capacity.len();
        let fp = match Footprint::for_pod(routine.name(), &dims, ndev, S::DTYPE) {
            Ok(fp) => fp,
            Err(e) => return publish_failure(&slots, format!("pod footprint failed: {e}")),
        };
        let node = inner.node.clone();
        let svc_inner = inner.clone();
        let model = model.clone();
        let total_wait: u64 = bucket.waits_ns.iter().sum();
        let waits = bucket.waits_ns.clone();
        let job_slots = slots.clone();
        // The pod is one submission on the service queue: one trace
        // covers the whole fused sweep (its members coalesced before
        // admission, so they share the pod's span tree).
        let (trace, root) = node.tracer().new_trace();
        let tracer = node.tracer().clone();
        // An AdmittedJob rather than a plain submit closure: the
        // per-request publications ride the deferred PublishFn, so —
        // exactly like a non-batched solve — a resolved handle implies
        // the pod's reservation is already released.
        let job: AdmittedJob = Box::new(move |ticket, queue_wait_ns| {
            let t0_ns = svc_inner.sim_now_ns();
            if trace.0 != 0 {
                tracer.span(
                    trace,
                    root,
                    "queue-wait",
                    "sched",
                    0,
                    "requests",
                    ticket.enq_ns,
                    ticket.enq_ns.saturating_add(queue_wait_ns),
                    0,
                    0,
                );
            }
            let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                run_bucket::<S>(routine, &node, &model, &systems, &rhss, None)
            }));
            let publish: PublishFn = match out {
                Ok(Ok((results, makespan_ns))) => {
                    node.metrics().add_batch_bucket(occupancy as u64, total_wait, makespan_ns);
                    let exec_ns = svc_inner.sim_now_ns().saturating_sub(t0_ns);
                    Box::new(move || {
                        for ((slot, x), wait_ns) in
                            job_slots.iter().zip(results).zip(waits.iter().copied())
                        {
                            let stats = SolveStats {
                                queue_wait_ns,
                                exec_ns,
                                batch_size: occupancy,
                                coalesce_wait_ns: wait_ns,
                                grid: (1, 1),
                                cache_hit: false,
                                fused_stages: 1,
                            };
                            publish_one(slot, Ok((x, stats)));
                        }
                    })
                }
                // A sweep aborts at its first failing system; rerun the
                // bucket one system at a time so only the culprit's
                // waiter sees the failure. Each retry is a batch of
                // one *pinned to the device the bucket's round-robin
                // reservation placed that system on*, so the rerun
                // allocates strictly inside the admitted footprint.
                _ => {
                    let deal = TileDim::round_robin(occupancy, ndev)
                        .expect("service nodes have at least one device");
                    let outcomes: Vec<std::result::Result<Matrix<S>, String>> = (0..occupancy)
                        .map(|i| {
                            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                                run_bucket::<S>(
                                    routine,
                                    &node,
                                    &model,
                                    &systems[i..i + 1],
                                    &rhss[i..i + 1],
                                    Some(deal.owner(i)),
                                )
                            }))
                            .map_err(panic_message)
                            .and_then(|r| {
                                r.map(|(mut v, _)| v.pop().expect("batch of one"))
                                    .map_err(|e| format!("small solve failed: {e}"))
                            })
                        })
                        .collect();
                    let exec_ns = svc_inner.sim_now_ns().saturating_sub(t0_ns);
                    Box::new(move || {
                        for ((slot, out), wait_ns) in
                            job_slots.iter().zip(outcomes).zip(waits.iter().copied())
                        {
                            match out {
                                Ok(x) => {
                                    let stats = SolveStats {
                                        queue_wait_ns,
                                        exec_ns,
                                        batch_size: 1,
                                        coalesce_wait_ns: wait_ns,
                                        grid: (1, 1),
                                        cache_hit: false,
                                        fused_stages: 1,
                                    };
                                    publish_one(slot, Ok((x, stats)));
                                }
                                Err(msg) => publish_one(slot, Err(ServeError::Failed(msg))),
                            }
                        }
                    })
                }
            };
            let end_ns = svc_inner.sim_now_ns();
            let exec_ns = end_ns.saturating_sub(t0_ns);
            if trace.0 != 0 {
                tracer.span(trace, root, "exec", "exec", 0, "requests", t0_ns, end_ns, 0, 0);
                tracer.close_root(
                    trace,
                    root,
                    &format!("request:pod:{}", routine.name()),
                    0,
                    ticket.enq_ns,
                    end_ns,
                    0,
                    0,
                );
            }
            svc_inner.note_completion(&ticket, queue_wait_ns, exec_ns);
            publish
        });
        match inner.enqueue_job(fp, pod_slo, 0, job) {
            Ok(()) => {
                let tr = inner.node.tracer();
                if tr.enabled() && trace.0 != 0 {
                    tr.decision(
                        trace,
                        inner.sim_now_ns(),
                        "admit",
                        format!("req=pod:{} occupancy={occupancy}", routine.name()),
                    );
                }
            }
            Err(e) => {
                // The job never ran: close the pod's root here so every
                // minted trace still resolves to exactly one span tree.
                if trace.0 != 0 {
                    let now = inner.sim_now_ns();
                    inner.node.tracer().close_root(
                        trace,
                        root,
                        "request:pod-rejected",
                        0,
                        now,
                        now,
                        0,
                        0,
                    );
                }
                publish_failure(&slots, format!("pod admission failed: {e}"));
            }
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::workspace;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn jobs_run_and_return() {
        let q = JobQueue::new(2);
        let h1 = q.submit(|| 1 + 1);
        let h2 = q.submit(|| "hello".len());
        assert_eq!(h1.wait(), 2);
        assert_eq!(h2.wait(), 5);
    }

    #[test]
    fn many_jobs_all_complete() {
        let q = JobQueue::new(4);
        let handles: Vec<_> = (0..64).map(|i| q.submit(move || i * i)).collect();
        let results: Vec<usize> = handles.into_iter().map(|h| h.wait()).collect();
        for (i, r) in results.iter().enumerate() {
            assert_eq!(*r, i * i);
        }
    }

    #[test]
    fn drain_waits_for_everything() {
        let q = JobQueue::new(2);
        let counter = Arc::new(Mutex::new(0));
        for _ in 0..10 {
            let c = counter.clone();
            q.submit(move || {
                std::thread::sleep(std::time::Duration::from_millis(2));
                *c.lock().unwrap() += 1;
            });
        }
        q.drain();
        assert_eq!(*counter.lock().unwrap(), 10);
    }

    #[test]
    fn is_ready_flips() {
        let q = JobQueue::new(1);
        let h = q.submit(|| 42);
        q.drain();
        assert!(h.is_ready());
        assert_eq!(h.wait(), 42);
    }

    // ---- SolveService ----------------------------------------------------

    #[test]
    fn service_runs_jobs_and_reports_stats() {
        let node = SimNode::new_uniform(2, 1 << 20);
        let svc = SolveService::new(node.clone(), 2);
        let h = svc.submit(Footprint::uniform(2, 1024), || 7usize).unwrap();
        let (v, stats) = h.wait();
        assert_eq!(v, 7);
        // An uncharged closure spans no simulated time; the stats are
        // cost-model ns, not host wall time.
        assert_eq!(stats.exec_ns, 0);
        assert_eq!(stats.exec_secs(), 0.0);
        svc.drain();
        assert_eq!(svc.reserved(), vec![0, 0]);
        let m = node.metrics().snapshot();
        assert_eq!(m.service_submitted, 1);
        assert_eq!(m.service_completed, 1);
    }

    #[test]
    fn service_rejects_unadmittable_footprints() {
        let node = SimNode::new_uniform(2, 1024);
        let svc = SolveService::new(node, 1);
        let err = svc.submit(Footprint::uniform(2, 4096), || ()).unwrap_err();
        assert!(matches!(err, Error::DeviceOom { .. }));
        let err2 = svc.submit(Footprint::uniform(3, 1), || ()).unwrap_err();
        assert!(matches!(err2, Error::Config(_)));
    }

    #[test]
    fn capacity_bounds_concurrency() {
        // Each solve reserves 512 B of a 1100 B device: at most two fit,
        // no matter how many workers are free.
        let node = SimNode::new_uniform(1, 1100);
        let svc = SolveService::new(node, 4);
        let cur = Arc::new(AtomicUsize::new(0));
        let peak = Arc::new(AtomicUsize::new(0));
        let handles: Vec<_> = (0..6)
            .map(|_| {
                let cur = cur.clone();
                let peak = peak.clone();
                svc.submit(Footprint::uniform(1, 512), move || {
                    let now = cur.fetch_add(1, Ordering::SeqCst) + 1;
                    peak.fetch_max(now, Ordering::SeqCst);
                    std::thread::sleep(Duration::from_millis(10));
                    cur.fetch_sub(1, Ordering::SeqCst);
                })
                .unwrap()
            })
            .collect();
        for h in handles {
            h.wait();
        }
        assert!(peak.load(Ordering::SeqCst) <= 2, "accountant over-admitted");
        let pk = svc.peak_reserved();
        assert!(pk[0] <= 1100, "reserved past capacity: {pk:?}");
    }

    #[test]
    fn fifo_order_is_preserved_under_capacity_pressure() {
        // One worker + capacity for one solve: strict serial FIFO.
        let node = SimNode::new_uniform(1, 1000);
        let svc = SolveService::new(node, 1);
        let order = Arc::new(Mutex::new(Vec::new()));
        let handles: Vec<_> = (0..5)
            .map(|i| {
                let order = order.clone();
                svc.submit(Footprint::uniform(1, 900), move || {
                    order.lock().unwrap().push(i);
                })
                .unwrap()
            })
            .collect();
        for h in handles {
            h.wait();
        }
        assert_eq!(*order.lock().unwrap(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn worker_survives_a_panicking_solve() {
        // One worker, footprint = full capacity: the follow-up solve is
        // only admitted if the panicking one released its reservation
        // and the worker thread survived the unwind.
        let node = SimNode::new_uniform(1, 4096);
        let svc = SolveService::new(node, 1);
        #[allow(clippy::unused_unit)]
        let h = svc.submit(Footprint::uniform(1, 4096), || -> () { panic!("boom") }).unwrap();
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| h.wait()));
        assert!(res.is_err(), "waiter must see the solve's panic");
        let h2 = svc.submit(Footprint::uniform(1, 4096), || 5usize).unwrap();
        assert_eq!(h2.wait().0, 5);
        assert_eq!(svc.reserved(), vec![0]);
        assert_eq!(svc.in_flight(), 0);
    }

    #[test]
    fn footprint_for_routine_matches_workspace_model() {
        let fp = Footprint::for_routine("potrs", 256, 1, 32, 4, DType::F64).unwrap();
        assert_eq!(fp.devices(), 4);
        assert_eq!(fp.bytes(0), workspace::potrs_bytes(256, 1, 32, 4, DType::F64));
        // Bare factorization: the potrs working set without the RHS.
        let fpf = Footprint::for_routine("potrf", 256, 0, 32, 4, DType::F64).unwrap();
        assert_eq!(fpf.bytes(0), workspace::potrs_bytes(256, 0, 32, 4, DType::F64));
        assert!(fpf.bytes(0) < fp.bytes(0));
        // Ragged tiling: the declared footprint must dominate the real
        // block-cyclic allocation (whole tiles per device). n=26 T=5
        // d=2: device 0 stores 15 columns, the flat model says 13.
        let ragged = Footprint::for_routine("potrf", 26, 0, 5, 2, DType::F64).unwrap();
        let real_peak = 26 * 15 * 8 + 26 * 5 * 8; // matrix panel + broadcast scratch
        assert!(ragged.bytes(0) >= real_peak, "{} < {real_peak}", ragged.bytes(0));
        assert!(Footprint::for_routine("getrf", 8, 1, 2, 2, DType::F32).is_err());
    }

    #[test]
    fn footprint_for_grid_uses_exact_shards() {
        use crate::layout::{BlockCyclic2D, MatrixLayout};
        // 10×10 in 4×4 tiles on a 2×2 grid: shard shapes differ across
        // the grid (6×6, 6×4, 4×6, 4×4 local blocks).
        let lay = BlockCyclic2D::new(10, 10, 4, 4, 2, 2).unwrap();
        let fp = Footprint::for_grid("syevd", &lay, 0, DType::F64).unwrap();
        assert_eq!(fp.devices(), 4);
        let panel = 4 * 10 * 4 * 8; // panel_terms · n · tile_c · e
        for d in 0..4 {
            assert_eq!(fp.bytes(d), 4 * lay.local_elems(d) * 8 + panel);
        }
        assert!(fp.bytes(0) > fp.bytes(3), "corner shards must dominate");
        // potrs adds the replicated RHS; potrf does not.
        let fs = Footprint::for_grid("potrs", &lay, 3, DType::F64).unwrap();
        let ff = Footprint::for_grid("potrf", &lay, 3, DType::F64).unwrap();
        assert_eq!(fs.bytes(0), ff.bytes(0) + 10 * 3 * 8);
        assert!(Footprint::for_grid("getrf", &lay, 0, DType::F64).is_err());
    }

    #[test]
    fn footprint_for_pod_is_exact_arena_bytes() {
        // Three systems round-robin on 2 devices: dev0 gets systems 0
        // and 2, dev1 gets system 1; potrs adds the RHS entries.
        let dims = [(8usize, 1usize), (4, 2), (6, 1)];
        let fp = Footprint::for_pod("potrs", &dims, 2, DType::F64).unwrap();
        assert_eq!(fp.bytes(0), (8 * 8 + 8 * 1 + 6 * 6 + 6 * 1) * 8);
        assert_eq!(fp.bytes(1), (4 * 4 + 4 * 2) * 8);
        let ff = Footprint::for_pod("potrf", &dims, 2, DType::F64).unwrap();
        assert_eq!(ff.bytes(0), (8 * 8 + 6 * 6) * 8);
        assert!(Footprint::for_pod("getrf", &dims, 2, DType::F64).is_err());
        // And it dominates (equals) a real pod's allocation.
        use crate::batch::PackedPod;
        let node = SimNode::new_uniform(2, 1 << 20);
        let systems: Vec<crate::linalg::Matrix<f64>> =
            dims.iter().map(|&(n, _)| crate::linalg::Matrix::spd_random(n, n as u64)).collect();
        let pod = PackedPod::pack(&node, &systems).unwrap();
        for (d, rep) in node.memory_reports().iter().enumerate() {
            assert!(ff.bytes(d) >= rep.used, "pod footprint under-declares device {d}");
        }
        drop(pod);
    }

    #[test]
    fn submit_small_coalesces_and_solves() {
        use crate::linalg::{self, tol_for, FrobNorm};
        let node = SimNode::new_uniform(4, 1 << 22);
        let mut cfg = SmallConfig::with_tile(64);
        cfg.policy.max_batch = 4;
        cfg.policy.max_dwell_ns = u64::MAX; // occupancy-only flushing
        let svc = SolveService::with_small_config(node.clone(), 2, cfg);
        let systems: Vec<Matrix<f64>> =
            (0..4).map(|i| Matrix::spd_random(10 + i, 70 + i as u64)).collect();
        let rhss: Vec<Matrix<f64>> =
            (0..4).map(|i| Matrix::random(10 + i, 2, 80 + i as u64)).collect();
        let handles: Vec<_> = systems
            .iter()
            .zip(&rhss)
            .map(|(a, b)| {
                svc.submit_small(SmallRoutine::Potrs, a.clone(), Some(b.clone())).unwrap()
            })
            .collect();
        // The fourth submit filled the bucket; nothing should linger.
        assert_eq!(svc.pending_small(), 0);
        for (i, h) in handles.into_iter().enumerate() {
            let (x, stats) = h.wait();
            let l = linalg::potrf(&systems[i]).unwrap();
            let x_ref = linalg::potrs_from_chol(&l, &rhss[i]).unwrap();
            assert!(x.rel_err(&x_ref) < tol_for::<f64>(16), "request {i} wrong");
            assert_eq!(stats.batch_size, 4, "request {i} missed the bucket");
        }
        svc.drain();
        let m = node.metrics().snapshot();
        assert_eq!(m.batch_buckets, 1);
        assert_eq!(m.batch_solves, 4);
        assert_eq!(m.batch_peak_occupancy, 4);
        assert!(m.batch_makespan_ns > 0);
        assert_eq!(svc.reserved(), vec![0; 4]);
        let caps = svc.capacity().to_vec();
        for (d, pk) in svc.peak_reserved().into_iter().enumerate() {
            assert!(pk <= caps[d], "over-admitted device {d}");
        }
    }

    #[test]
    fn drain_flushes_partial_buckets() {
        use crate::linalg::{tol_for, FrobNorm};
        let node = SimNode::new_uniform(2, 1 << 22);
        let mut cfg = SmallConfig::with_tile(64);
        cfg.policy.max_batch = 8;
        cfg.policy.max_dwell_ns = u64::MAX;
        let svc = SolveService::with_small_config(node, 1, cfg);
        let a = Matrix::<f64>::spd_random(12, 5);
        let handles: Vec<_> = (0..3)
            .map(|_| svc.submit_small(SmallRoutine::Potri, a.clone(), None).unwrap())
            .collect();
        assert_eq!(svc.pending_small(), 3);
        svc.drain();
        assert_eq!(svc.pending_small(), 0);
        for h in handles {
            let (inv, stats) = h.wait();
            let prod = a.matmul(&inv);
            assert!(prod.rel_err(&Matrix::eye(12)) < tol_for::<f64>(12) * 10.0);
            assert_eq!(stats.batch_size, 3);
        }
    }

    #[test]
    fn oversized_small_requests_run_distributed() {
        use crate::linalg::{self, tol_for, FrobNorm};
        let node = SimNode::new_uniform(2, 1 << 23);
        let svc = SolveService::new(node, 1); // small_dim = 256
        let n = 300;
        let a = Matrix::<f64>::spd_random(n, 9);
        let b = Matrix::<f64>::random(n, 1, 10);
        let h = svc.submit_small(SmallRoutine::Potrs, a.clone(), Some(b.clone())).unwrap();
        assert_eq!(svc.pending_small(), 0, "oversized request must bypass the coalescer");
        let (x, stats) = h.wait();
        assert_eq!(stats.batch_size, 1);
        assert_eq!(stats.coalesce_wait_ns, 0);
        let l = linalg::potrf(&a).unwrap();
        let x_ref = linalg::potrs_from_chol(&l, &b).unwrap();
        assert!(x.rel_err(&x_ref) < tol_for::<f64>(n) * 10.0);
    }

    #[test]
    fn distributed_submits_flush_expired_buckets() {
        let node = SimNode::new_uniform(2, 1 << 23);
        let mut cfg = SmallConfig::with_tile(64);
        cfg.policy.max_batch = 32;
        cfg.policy.max_dwell_ns = 1_000; // 1 µs of simulated time
        let svc = SolveService::with_small_config(node, 1, cfg);
        let small = svc
            .submit_small(SmallRoutine::Potrf, Matrix::<f64>::spd_random(8, 1), None)
            .unwrap();
        assert_eq!(svc.pending_small(), 1);
        // An oversized request runs distributed and advances the
        // simulated clock well past the dwell bound...
        let big1 = svc
            .submit_small(SmallRoutine::Potrf, Matrix::<f64>::spd_random(300, 2), None)
            .unwrap();
        big1.wait();
        // ...so the next submit — also distributed, never touching the
        // coalescer — must still flush the expired bucket.
        let big2 = svc
            .submit_small(SmallRoutine::Potrf, Matrix::<f64>::spd_random(300, 3), None)
            .unwrap();
        assert_eq!(svc.pending_small(), 0, "expired bucket must flush on a distributed submit");
        let (_, stats) = small.wait();
        assert_eq!(stats.batch_size, 1, "the lone request swept as a bucket of one");
        big2.wait();
        svc.drain();
    }

    #[test]
    fn infeasible_buckets_fall_back_to_distributed() {
        // 1 device × 1 MiB: each n=128 f64 factor fits individually
        // (~192 KiB with workspace) but a full 32-way bucket pod
        // (4 MiB of arenas) never would. Coalescing must step aside,
        // not fail the whole bucket at admission.
        let node = SimNode::new_uniform(1, 1 << 20);
        let svc = SolveService::new(node, 1); // small_dim = 256, max_batch = 32
        let handles: Vec<_> = (0..4)
            .map(|i| {
                svc.submit_small(SmallRoutine::Potrf, Matrix::<f64>::spd_random(128, i), None)
                    .unwrap()
            })
            .collect();
        assert_eq!(svc.pending_small(), 0, "infeasible buckets must not coalesce");
        for h in handles {
            let (l, stats) = h.wait();
            assert_eq!(l.rows(), 128);
            assert_eq!(stats.batch_size, 1, "must have run distributed");
        }
        svc.drain();
    }

    #[test]
    fn submit_small_validates_requests() {
        let node = SimNode::new_uniform(2, 1 << 20);
        let svc = SolveService::new(node, 1);
        let a = Matrix::<f64>::spd_random(8, 1);
        assert!(svc.submit_small(SmallRoutine::Potrs, a.clone(), None).is_err());
        assert!(svc
            .submit_small(SmallRoutine::Potrf, a.clone(), Some(Matrix::ones(8, 1)))
            .is_err());
        assert!(svc
            .submit_small(SmallRoutine::Potrs, a.clone(), Some(Matrix::ones(9, 1)))
            .is_err());
        assert!(svc
            .submit_small(SmallRoutine::Potrf, Matrix::<f64>::zeros(4, 5), None)
            .is_err());
        svc.drain();
    }

    #[test]
    fn background_flusher_drains_idle_buckets() {
        // The PR-3 follow-up: a dwell-expired bucket must flush even
        // when NO further submit/drain/flush call ever arrives. The
        // only live reference here is the pending handle — waiting on
        // it can only resolve if the background tick fires the bucket.
        let node = SimNode::new_uniform(2, 1 << 22);
        let mut cfg = SmallConfig::with_tile(64);
        cfg.policy.max_batch = 32; // never fills
        cfg.policy.max_dwell_ns = u64::MAX; // sim clock never expires it
        cfg.policy.max_wall_dwell = Duration::from_millis(10);
        let svc = SolveService::with_small_config(node, 1, cfg);
        let h = svc
            .submit_small(SmallRoutine::Potrf, Matrix::<f64>::spd_random(8, 1), None)
            .unwrap();
        assert_eq!(svc.pending_small(), 1);
        // No further service calls: the timer must flush it.
        let (l, stats) = h.wait();
        assert_eq!(l.rows(), 8);
        assert_eq!(stats.batch_size, 1);
        assert_eq!(svc.pending_small(), 0);
    }

    #[test]
    fn wall_clock_dwell_flushes_frozen_sim_buckets() {
        // Purely coalesced traffic charges nothing, so the simulated
        // clock freezes; the wall backstop keeps the latency promise.
        let node = SimNode::new_uniform(2, 1 << 22);
        let mut cfg = SmallConfig::with_tile(64);
        cfg.policy.max_batch = 32;
        cfg.policy.max_dwell_ns = u64::MAX;
        cfg.policy.max_wall_dwell = Duration::ZERO;
        let svc = SolveService::with_small_config(node, 1, cfg);
        let h1 = svc
            .submit_small(SmallRoutine::Potrf, Matrix::<f64>::spd_random(8, 1), None)
            .unwrap();
        // A later coalesced submit — a different size-class, so it
        // cannot fill h1's bucket — finds it wall-expired and flushes.
        let h2 = svc
            .submit_small(SmallRoutine::Potrf, Matrix::<f64>::spd_random(24, 2), None)
            .unwrap();
        let (l1, s1) = h1.wait();
        assert_eq!(l1.rows(), 8);
        assert_eq!(s1.batch_size, 1);
        svc.drain();
        let (l2, _) = h2.wait();
        assert_eq!(l2.rows(), 24);
    }

    #[test]
    fn failing_system_does_not_take_down_its_bucket() {
        use crate::linalg;
        let node = SimNode::new_uniform(2, 1 << 22);
        let mut cfg = SmallConfig::with_tile(64);
        cfg.policy.max_batch = 3;
        cfg.policy.max_dwell_ns = u64::MAX;
        let svc = SolveService::with_small_config(node, 1, cfg);
        let good1 = Matrix::<f64>::spd_random(8, 1);
        let mut bad = Matrix::<f64>::spd_random(8, 2);
        bad[(5, 5)] = -40.0; // not positive definite
        let good2 = Matrix::<f64>::spd_random(8, 3);
        let h1 = svc.submit_small(SmallRoutine::Potrf, good1.clone(), None).unwrap();
        let hb = svc.submit_small(SmallRoutine::Potrf, bad, None).unwrap();
        let h2 = svc.submit_small(SmallRoutine::Potrf, good2.clone(), None).unwrap();
        let (l1, s1) = h1.wait();
        assert_eq!(s1.batch_size, 1, "degraded buckets rerun one system at a time");
        assert_eq!(l1.as_slice(), linalg::potrf(&good1).unwrap().as_slice());
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| hb.wait()));
        assert!(res.is_err(), "the culprit must still fail on its own handle");
        let (l2, _) = h2.wait();
        assert_eq!(l2.as_slice(), linalg::potrf(&good2).unwrap().as_slice());
        svc.drain();
    }

    #[test]
    fn failed_small_solve_reraises_on_wait() {
        let node = SimNode::new_uniform(2, 1 << 22);
        let mut cfg = SmallConfig::with_tile(64);
        cfg.policy.max_batch = 1; // immediate flush
        let svc = SolveService::with_small_config(node, 1, cfg);
        let mut a = Matrix::<f64>::spd_random(8, 3);
        a[(5, 5)] = -40.0; // not positive definite
        let h = svc.submit_small(SmallRoutine::Potrf, a, None).unwrap();
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| h.wait()));
        assert!(res.is_err(), "numerical failure must re-raise on the waiter");
        // The service survives and keeps serving.
        let ok = svc.submit_small(SmallRoutine::Potrf, Matrix::<f64>::spd_random(8, 4), None);
        let (_, stats) = ok.unwrap().wait();
        assert_eq!(stats.batch_size, 1);
    }

    #[test]
    fn submit_dist_routes_through_the_grid_planner() {
        use crate::linalg::{self, tol_for, FrobNorm};
        let node = SimNode::new_uniform(4, 1 << 24);
        // Pin a 2×2 grid so the grid-native path runs at a simulatable n.
        let mut cfg = SmallConfig::with_tile(8);
        cfg.grid = Some((2, 2));
        let svc = SolveService::with_small_config(node.clone(), 2, cfg);
        let a = Matrix::<f64>::spd_random(24, 91);
        let b = Matrix::<f64>::random(24, 2, 92);
        let h = svc.submit_dist(DistRoutine::Potrs, a.clone(), Some(b.clone())).unwrap();
        let (x, stats) = h.wait();
        assert_eq!(stats.grid, (2, 2));
        let l = linalg::potrf(&a).unwrap();
        let x_ref = linalg::potrs_from_chol(&l, &b).unwrap();
        assert!(x.rel_err(&x_ref) < tol_for::<f64>(24) * 10.0);
        svc.drain();
        let m = node.metrics().snapshot();
        assert_eq!(m.grid_solves, 2, "potrf + potrs must both run grid-native");
        assert_eq!(m.grid_peak_p, 2);
        assert_eq!(m.grid_peak_q, 2);
        assert!(m.grid_row_bytes > 0 && m.grid_col_bytes > 0, "ring traffic must be tallied");
        assert_eq!(svc.reserved(), vec![0; 4]);

        // Autotuned small solves keep the 1D plan — and the grid-native
        // result above is bitwise identical to the 1D one.
        let node1 = SimNode::new_uniform(4, 1 << 24);
        let mut cfg1 = SmallConfig::with_tile(8);
        cfg1.policy.small_dim = 0;
        let svc1 = SolveService::with_small_config(node1.clone(), 1, cfg1);
        let (x1, s1) =
            svc1.submit_small(SmallRoutine::Potrs, a.clone(), Some(b.clone())).unwrap().wait();
        assert_eq!(s1.grid, (1, 4));
        assert_eq!(node1.metrics().snapshot().grid_solves, 0);
        assert_eq!(x.as_slice(), x1.as_slice(), "2x2 grid numerics diverge from 1D");

        // submit_syevd rides the same planner; submit_dist rejects it.
        let ((vals, _vecs), st) = svc1.submit_syevd(Matrix::<f64>::spd_diag(16)).unwrap().wait();
        assert_eq!(st.grid, (1, 4));
        for (i, v) in vals.iter().enumerate() {
            assert!((v - (i + 1) as f64).abs() < 1e-10);
        }
        assert!(svc1.submit_dist(DistRoutine::Syevd, Matrix::<f64>::spd_diag(8), None).is_err());

        // A grid override that does not cover the node is rejected.
        let mut bad = SmallConfig::with_tile(8);
        bad.grid = Some((3, 2));
        let svc_bad = SolveService::with_small_config(SimNode::new_uniform(4, 1 << 22), 1, bad);
        assert!(svc_bad.submit_dist(DistRoutine::Potrf, Matrix::<f64>::spd_random(16, 1), None).is_err());
    }

    #[test]
    fn edf_sjf_backfills_past_a_blocked_head() {
        // Worker 1 holds 900 of 1000 B behind a gate. The queue then
        // holds [batch 900 B (can never fit now), interactive 100 B].
        // FIFO would wall everyone behind the batch head; EdfSjf must
        // backfill the interactive solve past it.
        let node = SimNode::new_uniform(1, 1000);
        let sched = SchedConfig { policy: SchedPolicy::EdfSjf, ..SchedConfig::default() };
        let svc = SolveService::with_config(node, 2, SmallConfig::default(), sched);
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let order = Arc::new(Mutex::new(Vec::new()));
        let g = gate.clone();
        let o = order.clone();
        let h_hold = svc
            .submit_slo(Footprint::uniform(1, 900), Slo::batch(), move || {
                let (lock, cv) = &*g;
                let mut open = lock.lock().unwrap();
                while !*open {
                    open = cv.wait(open).unwrap();
                }
                o.lock().unwrap().push("hold");
            })
            .unwrap();
        // Wait for the holder to be admitted before queueing the rest.
        while svc.in_flight() == 0 {
            std::thread::yield_now();
        }
        let o = order.clone();
        let h_batch = svc
            .submit_slo(Footprint::uniform(1, 900), Slo::batch(), move || {
                o.lock().unwrap().push("batch");
            })
            .unwrap();
        let o = order.clone();
        let h_int = svc
            .submit_slo(Footprint::uniform(1, 100), Slo::interactive(), move || {
                o.lock().unwrap().push("interactive");
            })
            .unwrap();
        // The interactive solve completes while the gate is still shut —
        // proof it was admitted past the blocked batch head.
        h_int.wait();
        assert_eq!(order.lock().unwrap().as_slice(), ["interactive"]);
        {
            let (lock, cv) = &*gate;
            *lock.lock().unwrap() = true;
            cv.notify_all();
        }
        h_hold.wait();
        h_batch.wait();
        assert_eq!(
            order.lock().unwrap().as_slice(),
            ["interactive", "hold", "batch"],
            "the batch solve must still run once capacity frees (no starvation)"
        );
    }

    #[test]
    fn tenant_quotas_gate_admission_and_fail_fast() {
        let node = SimNode::new_uniform(1, 10_000);
        let sched = SchedConfig {
            policy: SchedPolicy::EdfSjf,
            tenant_quota: Some(1000),
            ..SchedConfig::default()
        };
        let svc = SolveService::with_config(node, 4, SmallConfig::default(), sched);
        // A single request over the whole quota can never be admitted.
        let err = svc
            .submit_slo(Footprint::uniform(1, 1500), Slo::standard().with_tenant(7), || ())
            .unwrap_err();
        assert!(matches!(err, Error::Config(_)));
        // Six 600 B solves from one tenant: the quota admits them one
        // at a time even though device capacity could hold two.
        let handles: Vec<_> = (0..6)
            .map(|_| {
                svc.submit_slo(Footprint::uniform(1, 600), Slo::standard().with_tenant(7), || {
                    std::thread::sleep(Duration::from_millis(2));
                })
                .unwrap()
            })
            .collect();
        for h in handles {
            h.wait();
        }
        assert!(
            svc.tenant_peak(7) <= 1000,
            "quota accountant over-admitted: {}",
            svc.tenant_peak(7)
        );
        assert_eq!(svc.tenant_admitted(7), 0, "all quota bytes released");
    }

    #[test]
    fn class_latency_lands_in_metrics() {
        let node = SimNode::new_uniform(2, 1 << 22);
        let svc = SolveService::new(node.clone(), 1);
        // A distributed solve charges the simulated clock, so its class
        // histogram entry is non-zero ns.
        let h = svc.submit_dist(DistRoutine::Potrf, Matrix::<f64>::spd_random(64, 3), None).unwrap();
        h.wait();
        svc.drain();
        let m = node.metrics().snapshot();
        assert_eq!(m.class_completed[SloClass::Standard.index()], 1);
        assert_eq!(m.class_deadline_misses[SloClass::Standard.index()], 0);
        assert!(m.class_p99_ns[SloClass::Standard.index()] > 0);
    }

    #[test]
    fn zero_wall_dwell_polls_instead_of_spinning() {
        // A zero wall-dwell policy used to make the background flusher
        // tick at `0 / 2 = 0` — a busy spin. The clamped tick must both
        // keep the CPU sane and still flush the stranded bucket.
        let node = SimNode::new_uniform(2, 1 << 22);
        let mut cfg = SmallConfig::with_tile(64);
        cfg.policy.max_batch = 32;
        cfg.policy.max_dwell_ns = u64::MAX;
        cfg.policy.max_wall_dwell = Duration::ZERO;
        assert_eq!(flusher_tick(cfg.policy.max_wall_dwell), Duration::from_millis(5));
        let svc = SolveService::with_small_config(node, 1, cfg);
        let h = svc
            .submit_small(SmallRoutine::Potrf, Matrix::<f64>::spd_random(8, 1), None)
            .unwrap();
        let (l, _) = h.wait();
        assert_eq!(l.rows(), 8);
        assert_eq!(svc.pending_small(), 0);
    }

    #[test]
    fn grid_footprint_admits_real_grid_solve() {
        // The declared 2D footprint must dominate the actual panel
        // allocation of a grid-scattered matrix.
        use crate::layout::BlockCyclic2D;
        use crate::linalg::Matrix;
        use crate::tile::{DistMatrix, LayoutKind};
        let n = 12;
        let lay = BlockCyclic2D::new(n, n, 4, 4, 2, 2).unwrap();
        let fp = Footprint::for_grid("potrf", &lay, 0, DType::F64).unwrap();
        let node = SimNode::new_uniform(4, 1 << 22);
        let a = Matrix::<f64>::spd_random(n, 77);
        let dm = DistMatrix::scatter(&node, &a, LayoutKind::Grid(lay)).unwrap();
        for (d, rep) in node.memory_reports().iter().enumerate() {
            assert!(fp.bytes(d) >= rep.used, "footprint under-declares device {d}");
        }
        drop(dm);
    }
}
