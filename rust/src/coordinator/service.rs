//! Minimal request-loop service: a queue of solve jobs executed by a
//! worker thread, with completion handles.
//!
//! The real JAXMg lives inside JAX's JIT, so its "request loop" is the
//! XLA program; for a standalone coordinator binary we provide the
//! conventional server shape instead (the vendored crate set has no
//! tokio, so this is a std-thread worker pool — same semantics, no
//! async syntax). Used by the CLI's `serve` mode and the e2e example.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

type Job = Box<dyn FnOnce() + Send + 'static>;

struct QueueInner {
    jobs: VecDeque<Job>,
    shutdown: bool,
    in_flight: usize,
}

/// A FIFO job queue with a fixed worker pool.
pub struct JobQueue {
    inner: Arc<(Mutex<QueueInner>, Condvar)>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl JobQueue {
    /// Start a queue with `n_workers` executor threads.
    pub fn new(n_workers: usize) -> Self {
        let inner = Arc::new((
            Mutex::new(QueueInner { jobs: VecDeque::new(), shutdown: false, in_flight: 0 }),
            Condvar::new(),
        ));
        let workers = (0..n_workers.max(1))
            .map(|_| {
                let inner = inner.clone();
                std::thread::spawn(move || loop {
                    let job = {
                        let (lock, cv) = &*inner;
                        let mut q = lock.lock().unwrap();
                        loop {
                            if let Some(job) = q.jobs.pop_front() {
                                q.in_flight += 1;
                                break Some(job);
                            }
                            if q.shutdown {
                                break None;
                            }
                            q = cv.wait(q).unwrap();
                        }
                    };
                    match job {
                        Some(job) => {
                            job();
                            let (lock, cv) = &*inner;
                            let mut q = lock.lock().unwrap();
                            q.in_flight -= 1;
                            cv.notify_all();
                        }
                        None => return,
                    }
                })
            })
            .collect();
        JobQueue { inner, workers }
    }

    /// Submit a job returning `T`; get a [`SolveHandle`] to wait on.
    pub fn submit<T: Send + 'static>(
        &self,
        f: impl FnOnce() -> T + Send + 'static,
    ) -> SolveHandle<T> {
        let slot = Arc::new((Mutex::new(None::<T>), Condvar::new()));
        let slot2 = slot.clone();
        let job: Job = Box::new(move || {
            let out = f();
            let (lock, cv) = &*slot2;
            *lock.lock().unwrap() = Some(out);
            cv.notify_all();
        });
        let (lock, cv) = &*self.inner;
        let mut q = lock.lock().unwrap();
        assert!(!q.shutdown, "queue is shut down");
        q.jobs.push_back(job);
        cv.notify_one();
        drop(q);
        SolveHandle { slot }
    }

    /// Number of jobs queued (not yet started).
    pub fn pending(&self) -> usize {
        self.inner.0.lock().unwrap().jobs.len()
    }

    /// Block until the queue is fully drained.
    pub fn drain(&self) {
        let (lock, cv) = &*self.inner;
        let mut q = lock.lock().unwrap();
        while !q.jobs.is_empty() || q.in_flight > 0 {
            q = cv.wait(q).unwrap();
        }
    }
}

impl Drop for JobQueue {
    fn drop(&mut self) {
        {
            let (lock, cv) = &*self.inner;
            lock.lock().unwrap().shutdown = true;
            cv.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Completion handle for a submitted job.
pub struct SolveHandle<T> {
    slot: Arc<(Mutex<Option<T>>, Condvar)>,
}

impl<T> SolveHandle<T> {
    /// Block until the job completes and take its result.
    pub fn wait(self) -> T {
        let (lock, cv) = &*self.slot;
        let mut guard = lock.lock().unwrap();
        loop {
            if let Some(v) = guard.take() {
                return v;
            }
            guard = cv.wait(guard).unwrap();
        }
    }

    /// Non-blocking readiness check.
    pub fn is_ready(&self) -> bool {
        self.slot.0.lock().unwrap().is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jobs_run_and_return() {
        let q = JobQueue::new(2);
        let h1 = q.submit(|| 1 + 1);
        let h2 = q.submit(|| "hello".len());
        assert_eq!(h1.wait(), 2);
        assert_eq!(h2.wait(), 5);
    }

    #[test]
    fn many_jobs_all_complete() {
        let q = JobQueue::new(4);
        let handles: Vec<_> = (0..64).map(|i| q.submit(move || i * i)).collect();
        let results: Vec<usize> = handles.into_iter().map(|h| h.wait()).collect();
        for (i, r) in results.iter().enumerate() {
            assert_eq!(*r, i * i);
        }
    }

    #[test]
    fn drain_waits_for_everything() {
        let q = JobQueue::new(2);
        let counter = Arc::new(Mutex::new(0));
        for _ in 0..10 {
            let c = counter.clone();
            q.submit(move || {
                std::thread::sleep(std::time::Duration::from_millis(2));
                *c.lock().unwrap() += 1;
            });
        }
        q.drain();
        assert_eq!(*counter.lock().unwrap(), 10);
    }

    #[test]
    fn is_ready_flips() {
        let q = JobQueue::new(1);
        let h = q.submit(|| 42);
        q.drain();
        assert!(h.is_ready());
        assert_eq!(h.wait(), 42);
    }
}
