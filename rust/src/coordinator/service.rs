//! The solve service layer: a plain FIFO job queue ([`JobQueue`]) and a
//! **capacity-aware concurrent solve service** ([`SolveService`]).
//!
//! The real JAXMg lives inside JAX's JIT, so its "request loop" is the
//! XLA program; for a standalone coordinator binary we provide the
//! conventional server shape instead (the vendored crate set has no
//! tokio, so this is a std-thread worker pool — same semantics, no
//! async syntax).
//!
//! [`SolveService`] is the throughput-oriented front: multiple solves
//! are in flight on one shared [`SimNode`] at a time, admitted in
//! strict FIFO order but only when their declared per-device workspace
//! [`Footprint`] fits against every device's VRAM capacity — the
//! cuSOLVERMg workspace-query-then-allocate discipline. The service
//! assumes it owns the node's VRAM (admission is against capacity, not
//! live free bytes), and the byte-accurate device allocator remains
//! the hard backstop: a solve that outgrows its declared footprint
//! still fails with `DeviceOom` rather than corrupting a neighbour.
//! Per-solve queue-wait and execution times are returned on
//! the [`ServiceHandle`] and aggregated into
//! [`crate::metrics::Metrics`] (`service_*` counters; pipelined solves
//! additionally feed the overlap-efficiency counters through their
//! [`crate::solver::Ctx`] phases).

use crate::costmodel::workspace;
use crate::device::SimNode;
use crate::error::{Error, Result};
use crate::scalar::DType;
use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

type Job = Box<dyn FnOnce() + Send + 'static>;

struct QueueInner {
    jobs: VecDeque<Job>,
    shutdown: bool,
    in_flight: usize,
}

/// A FIFO job queue with a fixed worker pool.
pub struct JobQueue {
    inner: Arc<(Mutex<QueueInner>, Condvar)>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl JobQueue {
    /// Start a queue with `n_workers` executor threads.
    pub fn new(n_workers: usize) -> Self {
        let inner = Arc::new((
            Mutex::new(QueueInner { jobs: VecDeque::new(), shutdown: false, in_flight: 0 }),
            Condvar::new(),
        ));
        let workers = (0..n_workers.max(1))
            .map(|_| {
                let inner = inner.clone();
                std::thread::spawn(move || loop {
                    let job = {
                        let (lock, cv) = &*inner;
                        let mut q = lock.lock().unwrap();
                        loop {
                            if let Some(job) = q.jobs.pop_front() {
                                q.in_flight += 1;
                                break Some(job);
                            }
                            if q.shutdown {
                                break None;
                            }
                            q = cv.wait(q).unwrap();
                        }
                    };
                    match job {
                        Some(job) => {
                            job();
                            let (lock, cv) = &*inner;
                            let mut q = lock.lock().unwrap();
                            q.in_flight -= 1;
                            cv.notify_all();
                        }
                        None => return,
                    }
                })
            })
            .collect();
        JobQueue { inner, workers }
    }

    /// Submit a job returning `T`; get a [`SolveHandle`] to wait on.
    pub fn submit<T: Send + 'static>(
        &self,
        f: impl FnOnce() -> T + Send + 'static,
    ) -> SolveHandle<T> {
        let slot = Arc::new((Mutex::new(None::<T>), Condvar::new()));
        let slot2 = slot.clone();
        let job: Job = Box::new(move || {
            let out = f();
            let (lock, cv) = &*slot2;
            *lock.lock().unwrap() = Some(out);
            cv.notify_all();
        });
        let (lock, cv) = &*self.inner;
        let mut q = lock.lock().unwrap();
        assert!(!q.shutdown, "queue is shut down");
        q.jobs.push_back(job);
        cv.notify_one();
        drop(q);
        SolveHandle { slot }
    }

    /// Number of jobs queued (not yet started).
    pub fn pending(&self) -> usize {
        self.inner.0.lock().unwrap().jobs.len()
    }

    /// Block until the queue is fully drained.
    pub fn drain(&self) {
        let (lock, cv) = &*self.inner;
        let mut q = lock.lock().unwrap();
        while !q.jobs.is_empty() || q.in_flight > 0 {
            q = cv.wait(q).unwrap();
        }
    }
}

impl Drop for JobQueue {
    fn drop(&mut self) {
        {
            let (lock, cv) = &*self.inner;
            lock.lock().unwrap().shutdown = true;
            cv.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Completion handle for a submitted job.
pub struct SolveHandle<T> {
    slot: Arc<(Mutex<Option<T>>, Condvar)>,
}

impl<T> SolveHandle<T> {
    /// Block until the job completes and take its result.
    pub fn wait(self) -> T {
        let (lock, cv) = &*self.slot;
        let mut guard = lock.lock().unwrap();
        loop {
            if let Some(v) = guard.take() {
                return v;
            }
            guard = cv.wait(guard).unwrap();
        }
    }

    /// Non-blocking readiness check.
    pub fn is_ready(&self) -> bool {
        self.slot.0.lock().unwrap().is_some()
    }
}

// ---------------------------------------------------------------------------
// Capacity-aware concurrent solve service
// ---------------------------------------------------------------------------

/// Declared per-device workspace footprint of one solve, in bytes —
/// what the admission accountant reserves against each device's VRAM.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Footprint {
    per_device: Vec<usize>,
}

impl Footprint {
    /// The same `bytes` on every one of `ndev` devices.
    pub fn uniform(ndev: usize, bytes: usize) -> Self {
        Footprint { per_device: vec![bytes; ndev] }
    }

    /// Explicit per-device byte counts.
    pub fn per_device(bytes: Vec<usize>) -> Self {
        Footprint { per_device: bytes }
    }

    /// Workspace-model footprint for a routine, mirroring the
    /// cuSOLVERMg workspace-size queries in [`workspace`], plus the
    /// block-cyclic tile-rounding slack: the layout stores whole tiles
    /// per device (up to `ceil(ntiles/ndev)·tile` columns), while the
    /// workspace formulas model `ceil(n/ndev)` flat columns, so each
    /// panel-shaped term is padded to dominate the real allocation.
    pub fn for_routine(
        routine: &str,
        n: usize,
        nrhs: usize,
        tile: usize,
        ndev: usize,
        dtype: DType,
    ) -> Result<Self> {
        let (bytes, panel_terms) = match routine {
            // Factor-only: the potrs working set minus the replicated
            // RHS (`nrhs` is ignored).
            "potrf" => (workspace::potrs_bytes(n, 0, tile, ndev, dtype), 1),
            "potrs" => (workspace::potrs_bytes(n, nrhs, tile, ndev, dtype), 1),
            "potri" => (workspace::potri_bytes(n, tile, ndev, dtype), 2),
            "syevd" => (workspace::syevd_bytes(n, tile, ndev, dtype), 4),
            other => return Err(Error::config(format!("unknown routine {other:?}"))),
        };
        let t = tile.max(1);
        let d = ndev.max(1);
        let cols_flat = n.div_ceil(d);
        let cols_tiled = n.div_ceil(t).div_ceil(d) * t;
        let slack = panel_terms * n * cols_tiled.saturating_sub(cols_flat) * dtype.size_of();
        Ok(Self::uniform(ndev, bytes + slack))
    }

    /// Workspace-model footprint for a routine over a **2D tile grid**
    /// ([`crate::layout::BlockCyclic2D`]): the matrix term uses each
    /// device's *exact* `local_rows × local_cols` shard (ragged edge
    /// tiles included), so per-device reservations differ across the
    /// grid instead of assuming the flat `n·ceil(n/ndev)` column shard.
    /// Scratch terms mirror [`Footprint::for_routine`]: `panel_terms`
    /// broadcast panels of `n × tile_c` plus the replicated RHS.
    pub fn for_grid(
        routine: &str,
        lay: &crate::layout::BlockCyclic2D,
        nrhs: usize,
        dtype: DType,
    ) -> Result<Self> {
        use crate::layout::MatrixLayout;
        let (matrix_copies, panel_terms) = match routine {
            "potrf" => (1usize, 1usize),
            "potrs" => (1, 1),
            "potri" => (2, 2),
            // matrix + eigenvector matrix + 2× back-transform scratch.
            "syevd" => (4, 4),
            other => return Err(Error::config(format!("unknown routine {other:?}"))),
        };
        let e = dtype.size_of();
        let (_, n) = lay.shape();
        let panel = panel_terms * n * lay.tile_c() * e;
        let rhs = if routine == "potrs" { n * nrhs * e } else { 0 };
        let per_device = (0..lay.num_devices())
            .map(|d| matrix_copies * lay.local_elems(d) * e + panel + rhs)
            .collect();
        Ok(Self::per_device(per_device))
    }

    /// Number of devices covered.
    pub fn devices(&self) -> usize {
        self.per_device.len()
    }

    /// Bytes reserved on device `d`.
    pub fn bytes(&self, d: usize) -> usize {
        self.per_device[d]
    }

    /// All per-device byte counts.
    pub fn as_slice(&self) -> &[usize] {
        &self.per_device
    }
}

/// Per-solve service metrics, returned with the result.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct SolveStats {
    /// Real time spent queued before the accountant admitted the solve.
    pub queue_wait: Duration,
    /// Real execution time after admission.
    pub exec: Duration,
}

/// Deferred result publication: runs *after* the worker has released
/// the solve's reservation, so a resolved [`ServiceHandle`] implies
/// the capacity is already free (no wait()/release race).
type PublishFn = Box<dyn FnOnce() + Send + 'static>;
type AdmittedJob = Box<dyn FnOnce(Duration) -> PublishFn + Send + 'static>;

struct QueuedSolve {
    footprint: Vec<usize>,
    job: AdmittedJob,
    enqueued: Instant,
}

struct ServiceState {
    queue: VecDeque<QueuedSolve>,
    reserved: Vec<usize>,
    peak_reserved: Vec<usize>,
    in_flight: usize,
    shutdown: bool,
}

struct ServiceInner {
    node: SimNode,
    capacity: Vec<usize>,
    state: Mutex<ServiceState>,
    cv: Condvar,
}

/// Concurrent solve service over one shared [`SimNode`]: FIFO +
/// capacity-aware admission, a fixed worker pool, per-solve stats.
///
/// Admission rule: only the queue **head** may be admitted (strict
/// FIFO — no starvation), and only when `reserved[d] + footprint[d] <=
/// capacity[d]` holds on every device. Completion releases the
/// reservation and wakes the queue.
pub struct SolveService {
    inner: Arc<ServiceInner>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl SolveService {
    /// Start a service over `node` with `n_workers` executor threads.
    pub fn new(node: SimNode, n_workers: usize) -> Self {
        let capacity: Vec<usize> = node.memory_reports().iter().map(|r| r.capacity).collect();
        let ndev = capacity.len();
        let inner = Arc::new(ServiceInner {
            node,
            capacity,
            state: Mutex::new(ServiceState {
                queue: VecDeque::new(),
                reserved: vec![0; ndev],
                peak_reserved: vec![0; ndev],
                in_flight: 0,
                shutdown: false,
            }),
            cv: Condvar::new(),
        });
        let workers = (0..n_workers.max(1))
            .map(|_| {
                let inner = inner.clone();
                std::thread::spawn(move || loop {
                    // Admit the head solve once it fits, or exit on
                    // shutdown with an empty queue.
                    let admitted = {
                        let mut st = inner.state.lock().unwrap();
                        loop {
                            let fits = match st.queue.front() {
                                Some(head) => (0..inner.capacity.len()).all(|d| {
                                    st.reserved[d] + head.footprint[d] <= inner.capacity[d]
                                }),
                                None => false,
                            };
                            if fits {
                                let q = st.queue.pop_front().unwrap();
                                for d in 0..inner.capacity.len() {
                                    st.reserved[d] += q.footprint[d];
                                    if st.reserved[d] > st.peak_reserved[d] {
                                        st.peak_reserved[d] = st.reserved[d];
                                    }
                                }
                                st.in_flight += 1;
                                break Some(q);
                            }
                            if st.shutdown && st.queue.is_empty() {
                                break None;
                            }
                            st = inner.cv.wait(st).unwrap();
                        }
                    };
                    let q = match admitted {
                        Some(q) => q,
                        None => return,
                    };
                    let wait = q.enqueued.elapsed();
                    let publish = (q.job)(wait);
                    {
                        let mut st = inner.state.lock().unwrap();
                        for d in 0..inner.capacity.len() {
                            st.reserved[d] -= q.footprint[d];
                        }
                        st.in_flight -= 1;
                    }
                    inner.cv.notify_all();
                    // Only now may the waiter observe completion.
                    publish();
                })
            })
            .collect();
        SolveService { inner, workers }
    }

    /// Submit a solve with its declared workspace footprint. Fails fast
    /// if the footprint can never be admitted (exceeds some device's
    /// total capacity) or spans the wrong device count.
    pub fn submit<T: Send + 'static>(
        &self,
        footprint: Footprint,
        f: impl FnOnce() -> T + Send + 'static,
    ) -> Result<ServiceHandle<T>> {
        if footprint.devices() != self.inner.capacity.len() {
            return Err(Error::config(format!(
                "footprint spans {} devices but the service node has {}",
                footprint.devices(),
                self.inner.capacity.len()
            )));
        }
        for (d, (&need, &cap)) in
            footprint.as_slice().iter().zip(self.inner.capacity.iter()).enumerate()
        {
            if need > cap {
                return Err(Error::DeviceOom { device: d, requested: need, free: cap, capacity: cap });
            }
        }
        let slot = Arc::new((Mutex::new(None::<SolveOutcome<T>>), Condvar::new()));
        let slot2 = slot.clone();
        let metrics = self.inner.node.metrics().clone();
        let job: AdmittedJob = Box::new(move |queue_wait| {
            let t0 = Instant::now();
            // A panicking solve must not kill the worker: the unwinding
            // is contained here so the reservation release in the worker
            // loop always runs, and the panic is re-raised on the waiter
            // (JoinHandle semantics).
            let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f));
            let exec = t0.elapsed();
            metrics.add_service_completion(queue_wait.as_nanos() as u64, exec.as_nanos() as u64);
            let stats = SolveStats { queue_wait, exec };
            let outcome = match out {
                Ok(v) => Ok((v, stats)),
                Err(p) => Err(panic_message(p)),
            };
            let publish: PublishFn = Box::new(move || {
                let (lock, cv) = &*slot2;
                *lock.lock().unwrap() = Some(outcome);
                cv.notify_all();
            });
            publish
        });
        {
            let mut st = self.inner.state.lock().unwrap();
            assert!(!st.shutdown, "service is shut down");
            st.queue.push_back(QueuedSolve {
                footprint: footprint.per_device,
                job,
                enqueued: Instant::now(),
            });
        }
        self.inner.node.metrics().add_service_submission();
        self.inner.cv.notify_all();
        Ok(ServiceHandle { slot })
    }

    /// The shared node solves run on.
    pub fn node(&self) -> &SimNode {
        &self.inner.node
    }

    /// Per-device VRAM capacities the accountant admits against.
    pub fn capacity(&self) -> &[usize] {
        &self.inner.capacity
    }

    /// Solves queued but not yet admitted.
    pub fn pending(&self) -> usize {
        self.inner.state.lock().unwrap().queue.len()
    }

    /// Solves currently in flight.
    pub fn in_flight(&self) -> usize {
        self.inner.state.lock().unwrap().in_flight
    }

    /// Current per-device reserved bytes.
    pub fn reserved(&self) -> Vec<usize> {
        self.inner.state.lock().unwrap().reserved.clone()
    }

    /// High-water mark of per-device reserved bytes — the accountant's
    /// proof it never over-admitted.
    pub fn peak_reserved(&self) -> Vec<usize> {
        self.inner.state.lock().unwrap().peak_reserved.clone()
    }

    /// Block until every submitted solve has finished executing and
    /// released its reservation. Result *publication* to the handles
    /// happens immediately after release, so a freshly drained
    /// handle's [`ServiceHandle::is_ready`] may still flip a moment
    /// later — [`ServiceHandle::wait`] is the synchronization point
    /// for result availability.
    pub fn drain(&self) {
        let mut st = self.inner.state.lock().unwrap();
        while !st.queue.is_empty() || st.in_flight > 0 {
            st = self.inner.cv.wait(st).unwrap();
        }
    }
}

impl Drop for SolveService {
    fn drop(&mut self) {
        {
            let mut st = self.inner.state.lock().unwrap();
            st.shutdown = true;
        }
        self.inner.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// `Ok((result, stats))`, or the panic message of a solve that
/// unwound inside a worker.
type SolveOutcome<T> = std::result::Result<(T, SolveStats), String>;

fn panic_message(p: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Completion handle for a service solve: the result plus its stats.
pub struct ServiceHandle<T> {
    slot: Arc<(Mutex<Option<SolveOutcome<T>>>, Condvar)>,
}

impl<T> ServiceHandle<T> {
    /// Block until the solve completes; returns `(result, stats)`.
    /// Re-raises the solve's panic if it unwound inside a worker
    /// (the worker itself survives and the reservation is released).
    pub fn wait(self) -> (T, SolveStats) {
        let (lock, cv) = &*self.slot;
        let mut guard = lock.lock().unwrap();
        loop {
            if let Some(v) = guard.take() {
                drop(guard);
                match v {
                    Ok(out) => return out,
                    Err(msg) => panic!("service solve panicked: {msg}"),
                }
            }
            guard = cv.wait(guard).unwrap();
        }
    }

    /// Non-blocking readiness check.
    pub fn is_ready(&self) -> bool {
        self.slot.0.lock().unwrap().is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn jobs_run_and_return() {
        let q = JobQueue::new(2);
        let h1 = q.submit(|| 1 + 1);
        let h2 = q.submit(|| "hello".len());
        assert_eq!(h1.wait(), 2);
        assert_eq!(h2.wait(), 5);
    }

    #[test]
    fn many_jobs_all_complete() {
        let q = JobQueue::new(4);
        let handles: Vec<_> = (0..64).map(|i| q.submit(move || i * i)).collect();
        let results: Vec<usize> = handles.into_iter().map(|h| h.wait()).collect();
        for (i, r) in results.iter().enumerate() {
            assert_eq!(*r, i * i);
        }
    }

    #[test]
    fn drain_waits_for_everything() {
        let q = JobQueue::new(2);
        let counter = Arc::new(Mutex::new(0));
        for _ in 0..10 {
            let c = counter.clone();
            q.submit(move || {
                std::thread::sleep(std::time::Duration::from_millis(2));
                *c.lock().unwrap() += 1;
            });
        }
        q.drain();
        assert_eq!(*counter.lock().unwrap(), 10);
    }

    #[test]
    fn is_ready_flips() {
        let q = JobQueue::new(1);
        let h = q.submit(|| 42);
        q.drain();
        assert!(h.is_ready());
        assert_eq!(h.wait(), 42);
    }

    // ---- SolveService ----------------------------------------------------

    #[test]
    fn service_runs_jobs_and_reports_stats() {
        let node = SimNode::new_uniform(2, 1 << 20);
        let svc = SolveService::new(node.clone(), 2);
        let h = svc.submit(Footprint::uniform(2, 1024), || 7usize).unwrap();
        let (v, stats) = h.wait();
        assert_eq!(v, 7);
        assert!(stats.exec >= Duration::ZERO);
        svc.drain();
        assert_eq!(svc.reserved(), vec![0, 0]);
        let m = node.metrics().snapshot();
        assert_eq!(m.service_submitted, 1);
        assert_eq!(m.service_completed, 1);
    }

    #[test]
    fn service_rejects_unadmittable_footprints() {
        let node = SimNode::new_uniform(2, 1024);
        let svc = SolveService::new(node, 1);
        let err = svc.submit(Footprint::uniform(2, 4096), || ()).unwrap_err();
        assert!(matches!(err, Error::DeviceOom { .. }));
        let err2 = svc.submit(Footprint::uniform(3, 1), || ()).unwrap_err();
        assert!(matches!(err2, Error::Config(_)));
    }

    #[test]
    fn capacity_bounds_concurrency() {
        // Each solve reserves 512 B of a 1100 B device: at most two fit,
        // no matter how many workers are free.
        let node = SimNode::new_uniform(1, 1100);
        let svc = SolveService::new(node, 4);
        let cur = Arc::new(AtomicUsize::new(0));
        let peak = Arc::new(AtomicUsize::new(0));
        let handles: Vec<_> = (0..6)
            .map(|_| {
                let cur = cur.clone();
                let peak = peak.clone();
                svc.submit(Footprint::uniform(1, 512), move || {
                    let now = cur.fetch_add(1, Ordering::SeqCst) + 1;
                    peak.fetch_max(now, Ordering::SeqCst);
                    std::thread::sleep(Duration::from_millis(10));
                    cur.fetch_sub(1, Ordering::SeqCst);
                })
                .unwrap()
            })
            .collect();
        for h in handles {
            h.wait();
        }
        assert!(peak.load(Ordering::SeqCst) <= 2, "accountant over-admitted");
        let pk = svc.peak_reserved();
        assert!(pk[0] <= 1100, "reserved past capacity: {pk:?}");
    }

    #[test]
    fn fifo_order_is_preserved_under_capacity_pressure() {
        // One worker + capacity for one solve: strict serial FIFO.
        let node = SimNode::new_uniform(1, 1000);
        let svc = SolveService::new(node, 1);
        let order = Arc::new(Mutex::new(Vec::new()));
        let handles: Vec<_> = (0..5)
            .map(|i| {
                let order = order.clone();
                svc.submit(Footprint::uniform(1, 900), move || {
                    order.lock().unwrap().push(i);
                })
                .unwrap()
            })
            .collect();
        for h in handles {
            h.wait();
        }
        assert_eq!(*order.lock().unwrap(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn worker_survives_a_panicking_solve() {
        // One worker, footprint = full capacity: the follow-up solve is
        // only admitted if the panicking one released its reservation
        // and the worker thread survived the unwind.
        let node = SimNode::new_uniform(1, 4096);
        let svc = SolveService::new(node, 1);
        #[allow(clippy::unused_unit)]
        let h = svc.submit(Footprint::uniform(1, 4096), || -> () { panic!("boom") }).unwrap();
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| h.wait()));
        assert!(res.is_err(), "waiter must see the solve's panic");
        let h2 = svc.submit(Footprint::uniform(1, 4096), || 5usize).unwrap();
        assert_eq!(h2.wait().0, 5);
        assert_eq!(svc.reserved(), vec![0]);
        assert_eq!(svc.in_flight(), 0);
    }

    #[test]
    fn footprint_for_routine_matches_workspace_model() {
        let fp = Footprint::for_routine("potrs", 256, 1, 32, 4, DType::F64).unwrap();
        assert_eq!(fp.devices(), 4);
        assert_eq!(fp.bytes(0), workspace::potrs_bytes(256, 1, 32, 4, DType::F64));
        // Bare factorization: the potrs working set without the RHS.
        let fpf = Footprint::for_routine("potrf", 256, 0, 32, 4, DType::F64).unwrap();
        assert_eq!(fpf.bytes(0), workspace::potrs_bytes(256, 0, 32, 4, DType::F64));
        assert!(fpf.bytes(0) < fp.bytes(0));
        // Ragged tiling: the declared footprint must dominate the real
        // block-cyclic allocation (whole tiles per device). n=26 T=5
        // d=2: device 0 stores 15 columns, the flat model says 13.
        let ragged = Footprint::for_routine("potrf", 26, 0, 5, 2, DType::F64).unwrap();
        let real_peak = 26 * 15 * 8 + 26 * 5 * 8; // matrix panel + broadcast scratch
        assert!(ragged.bytes(0) >= real_peak, "{} < {real_peak}", ragged.bytes(0));
        assert!(Footprint::for_routine("getrf", 8, 1, 2, 2, DType::F32).is_err());
    }

    #[test]
    fn footprint_for_grid_uses_exact_shards() {
        use crate::layout::{BlockCyclic2D, MatrixLayout};
        // 10×10 in 4×4 tiles on a 2×2 grid: shard shapes differ across
        // the grid (6×6, 6×4, 4×6, 4×4 local blocks).
        let lay = BlockCyclic2D::new(10, 10, 4, 4, 2, 2).unwrap();
        let fp = Footprint::for_grid("syevd", &lay, 0, DType::F64).unwrap();
        assert_eq!(fp.devices(), 4);
        let panel = 4 * 10 * 4 * 8; // panel_terms · n · tile_c · e
        for d in 0..4 {
            assert_eq!(fp.bytes(d), 4 * lay.local_elems(d) * 8 + panel);
        }
        assert!(fp.bytes(0) > fp.bytes(3), "corner shards must dominate");
        // potrs adds the replicated RHS; potrf does not.
        let fs = Footprint::for_grid("potrs", &lay, 3, DType::F64).unwrap();
        let ff = Footprint::for_grid("potrf", &lay, 3, DType::F64).unwrap();
        assert_eq!(fs.bytes(0), ff.bytes(0) + 10 * 3 * 8);
        assert!(Footprint::for_grid("getrf", &lay, 0, DType::F64).is_err());
    }

    #[test]
    fn grid_footprint_admits_real_grid_solve() {
        // The declared 2D footprint must dominate the actual panel
        // allocation of a grid-scattered matrix.
        use crate::layout::BlockCyclic2D;
        use crate::linalg::Matrix;
        use crate::tile::{DistMatrix, LayoutKind};
        let n = 12;
        let lay = BlockCyclic2D::new(n, n, 4, 4, 2, 2).unwrap();
        let fp = Footprint::for_grid("potrf", &lay, 0, DType::F64).unwrap();
        let node = SimNode::new_uniform(4, 1 << 22);
        let a = Matrix::<f64>::spd_random(n, 77);
        let dm = DistMatrix::scatter(&node, &a, LayoutKind::Grid(lay)).unwrap();
        for (d, rep) in node.memory_reports().iter().enumerate() {
            assert!(fp.bytes(d) >= rep.used, "footprint under-declares device {d}");
        }
        drop(dm);
    }
}
