//! MPMD pointer-gather (Fig. 2, right) — the one-shot demo.
//!
//! One (simulated) process per GPU, each with its own virtual address
//! space — raw device pointers are *undefined* across processes, so
//! each worker exports its shard through the `cudaIpc` analogue and
//! ships the opaque handle to process 0 over a message channel.
//! Process 0 opens every foreign handle in its own space (CUDA forbids
//! opening one's own export, so worker 0's pointer is used directly)
//! and only then calls the solver — the single-caller requirement.
//!
//! This module is the minimal, per-call form of that choreography (it
//! spawns throwaway workers for a single gather). The *serving* shape —
//! persistent one-process-per-GPU workers with their own admission,
//! shard staging, and failure-aware re-routing behind a rank-0
//! frontend — lives in [`crate::serve`].

use crate::device::{DevPtr, SimNode};
use crate::error::{Error, Result};
use crate::ipc::{AddressSpace, IpcHandle, IpcRegistry};
use std::sync::mpsc;
use std::sync::Arc;

/// One worker's message to process 0: its rank and either a raw pointer
/// (rank 0 only) or an exported IPC handle.
enum PtrMsg {
    Own(usize, DevPtr),
    Exported(usize, IpcHandle),
}

/// Simulated-process pointer reconciliation: worker `d` runs in
/// [`AddressSpace`] `d`, exports its panel, and sends the handle to
/// process 0, which opens all of them and returns the device-ordered
/// pointer list.
pub fn gather_pointers_mpmd(node: &SimNode, panels: Vec<DevPtr>) -> Result<Vec<DevPtr>> {
    let ndev = node.num_devices();
    assert_eq!(panels.len(), ndev);
    let registry = Arc::new(IpcRegistry::new());
    let (tx, rx) = mpsc::channel::<PtrMsg>();

    std::thread::scope(|scope| {
        for (d, ptr) in panels.iter().enumerate() {
            let registry = registry.clone();
            let tx = tx.clone();
            let ptr = *ptr;
            scope.spawn(move || {
                let space = AddressSpace(d);
                if d == 0 {
                    // Process 0 uses its own pointer directly (cudaIpc
                    // forbids re-opening one's own export).
                    tx.send(PtrMsg::Own(0, ptr)).expect("send");
                } else {
                    // Bound export: freeing the shard later implicitly
                    // revokes the handle (see `ipc::IpcRegistry`).
                    let handle = registry.export_bound(space, node, ptr).expect("export");
                    tx.send(PtrMsg::Exported(d, handle)).expect("send");
                }
            });
        }
    });
    drop(tx);

    // Process 0: collect one message per worker, open foreign handles.
    let caller = AddressSpace(0);
    let mut out: Vec<Option<DevPtr>> = vec![None; ndev];
    for msg in rx {
        match msg {
            PtrMsg::Own(d, ptr) => out[d] = Some(ptr),
            PtrMsg::Exported(d, handle) => {
                let ptr = registry.open(caller, handle)?;
                out[d] = Some(ptr);
            }
        }
    }
    out.into_iter()
        .enumerate()
        .map(|(d, p)| p.ok_or_else(|| Error::ipc(format!("worker {d} never reported its shard"))))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mpmd_gathers_all_pointers() {
        let node = SimNode::new_uniform(4, 1 << 20);
        let panels: Vec<DevPtr> = (0..4).map(|d| node.alloc(d, 64).unwrap()).collect();
        let gathered = gather_pointers_mpmd(&node, panels.clone()).unwrap();
        assert_eq!(gathered, panels);
    }

    #[test]
    fn mpmd_single_process() {
        let node = SimNode::new_uniform(1, 1 << 20);
        let panels = vec![node.alloc(0, 16).unwrap()];
        let gathered = gather_pointers_mpmd(&node, panels.clone()).unwrap();
        assert_eq!(gathered, panels);
    }
}
