//! Admission accounting and completion plumbing shared by the solve
//! fronts.
//!
//! Both serving fronts — the SPMD [`super::SolveService`] (one shared
//! address space, a central accountant over every device) and the MPMD
//! [`crate::serve::MpmdService`] (one process per GPU, each worker
//! admitting against **its own** device) — obey the same cuSOLVERMg
//! workspace-query-then-reserve discipline and resolve requests through
//! the same handle/stats types. This module is that shared layer:
//!
//! * [`Footprint`] — the declared per-device workspace bytes of one
//!   solve (routine formulas, exact 2D shards, pod arenas);
//! * [`DeviceAdmission`] — a single device's reservation accountant
//!   (the per-worker half of admission; the SPMD service keeps its
//!   all-devices FIFO variant in `service.rs`);
//! * [`ServiceHandle`] / [`SolveStats`] — completion handle and
//!   per-solve metrics, identical across fronts so callers can swap
//!   SPMD for MPMD without touching their wait loops;
//! * [`plan_dist`] / [`DistPlan`] — the **grid-shape planner** both
//!   fronts route distributed solves through: per request,
//!   [`crate::costmodel::Predictor::best_grid`] picks the `P × Q`
//!   factorization of the (live) device count with the smallest
//!   replayed makespan (1D for small problems, 2D grids at scale), the
//!   matching [`crate::tile::LayoutKind`] is built, and admission is
//!   against the **exact per-device shards of the chosen shape**
//!   ([`Footprint::for_grid`] for 2D, the routine formulas for 1D).
//!   Sharing the planner is what keeps the SPMD and MPMD fronts
//!   bitwise-identical: same inputs → same grid → same layout → same
//!   solver schedule.

use crate::costmodel::{workspace, GpuCostModel, Predictor};
use crate::device::NodeTopology;
use crate::error::{Error, Result};
use crate::layout::{BlockCyclic1D, BlockCyclic2D, TileDim};
use crate::scalar::DType;
use crate::solver::Precision;
use crate::tile::LayoutKind;
use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Convert cost-model seconds to integer nanoseconds — THE conversion
/// every scheduler estimate and clock charge uses, so EDF/SJF ordering
/// can be compared bitwise against [`Predictor`] makespans.
#[inline]
pub fn secs_to_ns(seconds: f64) -> u64 {
    debug_assert!(seconds >= 0.0, "negative cost-model duration");
    (seconds * 1e9).round() as u64
}

/// Saturating wall-`Duration` → u64 nanoseconds (replaces the lossy
/// `as_nanos() as u64` casts on wall-clock backstop paths).
#[inline]
pub fn duration_to_ns(d: Duration) -> u64 {
    u64::try_from(d.as_nanos()).unwrap_or(u64::MAX)
}

/// The distributed routines the serving fronts route.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum DistRoutine {
    /// Cholesky factor (returns the factored matrix).
    Potrf,
    /// Factor + solve against a replicated RHS.
    Potrs,
    /// Factor + Cholesky-based inverse.
    Potri,
    /// Symmetric/Hermitian eigendecomposition.
    Syevd,
}

impl DistRoutine {
    /// The cost-model / workspace-formula name of the routine.
    pub fn name(self) -> &'static str {
        match self {
            DistRoutine::Potrf => "potrf",
            DistRoutine::Potrs => "potrs",
            DistRoutine::Potri => "potri",
            DistRoutine::Syevd => "syevd",
        }
    }
}

/// One planned distributed solve: the process-grid shape the selector
/// chose, the concrete layout on it, and the per-device admission
/// footprint against that exact shape.
#[derive(Clone, Debug)]
pub struct DistPlan {
    /// The chosen `(P, Q)` grid ( `(1, ndev)` is the 1D path).
    pub grid: (usize, usize),
    /// Devices the plan actually occupies (`grid.0 * grid.1`) — fewer
    /// than the node width when the fabric router confines a solve to
    /// one island. The footprint is still node-wide (zero bytes on the
    /// idle islands) so both admission accountants stay full-width.
    pub ndev: usize,
    /// The layout solves scatter/stage into.
    pub kind: LayoutKind,
    /// Exact per-device workspace bytes on that layout.
    pub footprint: Footprint,
    /// Predicted makespan of the solve on the chosen grid, in
    /// cost-model nanoseconds — [`Predictor::dist_makespan`] through
    /// [`secs_to_ns`] (or [`Predictor::mixed_potrs`] when the plan is
    /// routed [`Precision::Mixed`]), so EDF/SJF queue ordering compares
    /// bitwise against the autotuner's own replayed numbers for the
    /// tier that will actually run.
    pub est_ns: u64,
    /// The numeric tier the router chose: [`Precision::Full`] unless
    /// the request carried a [`NumericPolicy`] whose tolerance and
    /// condition budget let the mixed-precision replay win.
    pub precision: Precision,
}

/// Plan a distributed solve over `ndev` devices: pick the grid shape
/// (`force` overrides the autotuner — `None` asks
/// [`Predictor::best_grid`]), build the layout, and size the exact
/// per-device footprint. `P = 1` maps to the native 1D block-cyclic
/// layout, keeping small solves bitwise on the seed path; `P > 1`
/// builds a square-tiled [`BlockCyclic2D`] grid admitted via
/// [`Footprint::for_grid`].
///
/// On a multi-island fabric (`topo.num_islands() > 1`) the planner
/// routes **1-node-vs-2-node per request** through
/// [`Predictor::best_fabric_plan`]: a solve whose replayed makespan is
/// best on one island gets a plan over that island's device prefix
/// (fewer devices than the node — [`DistPlan::ndev`] records how
/// many), priced by the island-subset predictor so the estimate is
/// bitwise the flat single-node replay; only solves past the
/// crossover span the inter-node links. Forced grids keep the flat
/// semantics — they must cover every live device.
#[allow(clippy::too_many_arguments)]
pub fn plan_dist(
    routine: &str,
    n: usize,
    nrhs: usize,
    tile: usize,
    ndev: usize,
    dtype: DType,
    model: &GpuCostModel,
    topo: &NodeTopology,
    force: Option<(usize, usize)>,
) -> Result<DistPlan> {
    plan_dist_prec(routine, n, nrhs, tile, ndev, dtype, model, topo, force, None)
}

/// [`plan_dist`] with a numeric policy: after the grid shape is chosen
/// the plan is routed Full-vs-Mixed. A request that carries a
/// [`NumericPolicy`] is eligible for [`Precision::Mixed`] when the
/// routine has a refinement path (`potrf`/`potrs`), the dtype has a
/// narrower working dtype, [`Predictor::est_refine_iters`] predicts
/// convergence under the condition budget, and the replayed mixed
/// schedule ([`Predictor::mixed_potrs`] /
/// [`Predictor::potrf2d_mixed`]) beats the full one on the same grid.
/// The returned [`DistPlan::est_ns`] prices whichever tier was chosen.
#[allow(clippy::too_many_arguments)]
pub fn plan_dist_prec(
    routine: &str,
    n: usize,
    nrhs: usize,
    tile: usize,
    ndev: usize,
    dtype: DType,
    model: &GpuCostModel,
    topo: &NodeTopology,
    force: Option<(usize, usize)>,
    numeric: Option<NumericPolicy>,
) -> Result<DistPlan> {
    let predictor = Predictor { model: model.clone(), topo: topo.clone(), dtype };
    if force.is_none() && topo.num_islands() > 1 && topo.num_devices() == ndev {
        let (used, (p, q)) = predictor.best_fabric_plan(routine, n, nrhs, tile);
        // Price the plan with the predictor that owns the chosen span:
        // the island-subset replay for a confined solve (bitwise the
        // flat single-node estimate), the fabric replay for a spanning
        // one — exactly the costs `best_fabric_plan` compared. The
        // same owner prices the mixed tier, so an island-confined
        // mixed solve replays island-local refinement traffic.
        let (owner, est) = if used < ndev {
            let island = topo.island_devices(0);
            let sub = Predictor { model: model.clone(), topo: topo.subset(&island)?, dtype };
            let est = sub.dist_makespan(routine, n, nrhs, tile, p, q);
            (sub, est)
        } else {
            let est = predictor.dist_makespan(routine, n, nrhs, tile, p, q);
            (predictor, est)
        };
        let (precision, est_ns) =
            route_precision(&owner, routine, n, nrhs, tile, (p, q), numeric, est);
        let plan = build_plan(routine, n, nrhs, tile, used, dtype, (p, q), est_ns, precision)?;
        return Ok(plan.pad_to(ndev));
    }
    let (p, q) = match force {
        Some((p, q)) => {
            if p == 0 || q == 0 || p * q != ndev {
                return Err(Error::config(format!(
                    "forced grid {p}x{q} does not cover the {ndev} live devices"
                )));
            }
            (p, q)
        }
        None => predictor.best_grid(routine, n, nrhs, tile, ndev),
    };
    let full = predictor.dist_makespan(routine, n, nrhs, tile, p, q);
    let (precision, est_ns) =
        route_precision(&predictor, routine, n, nrhs, tile, (p, q), numeric, full);
    build_plan(routine, n, nrhs, tile, ndev, dtype, (p, q), est_ns, precision)
}

/// The Full-vs-Mixed routing decision for one already-shaped plan.
/// Returns the chosen tier plus the matching makespan estimate so the
/// queue prices the schedule that will actually run. Every gate that
/// fails falls back to the full tier with the unmodified estimate:
///
/// | gate                         | why it routes Full                |
/// |------------------------------|-----------------------------------|
/// | no [`NumericPolicy`]         | caller never stated a tolerance   |
/// | routine not potrf/potrs      | no refinement path (potri, syevd) |
/// | dtype has no working dtype   | f32/c64 are already narrow        |
/// | `est_refine_iters` → `None`  | κ·ε_working too close to 1, or    |
/// |                              | tol below the f64 residual floor  |
/// |                              | κ·ε_f64 (a guaranteed stall)      |
/// | mixed replay ≥ full replay   | below the crossover, no win       |
fn route_precision(
    pred: &Predictor,
    routine: &str,
    n: usize,
    nrhs: usize,
    tile: usize,
    (p, q): (usize, usize),
    numeric: Option<NumericPolicy>,
    full_secs: f64,
) -> (Precision, u64) {
    let full = (Precision::Full, secs_to_ns(full_secs));
    let Some(policy) = numeric else { return full };
    if routine != "potrf" && routine != "potrs" {
        return full;
    }
    let Some(working) = pred.dtype.working_dtype() else { return full };
    let Some(iters) = pred.est_refine_iters(policy.tol(), policy.cond()) else {
        return full;
    };
    let mixed_secs = match routine {
        "potrs" => pred.mixed_potrs(n, tile, p, q, nrhs.max(1), iters),
        _ => pred.potrf2d_mixed(n, tile, p, q),
    };
    if mixed_secs < full_secs {
        (Precision::Mixed(working), secs_to_ns(mixed_secs))
    } else {
        full
    }
}

/// Build the layout + footprint for an already-selected grid shape and
/// makespan estimate (no predictor replay — the cache-hit path).
#[allow(clippy::too_many_arguments)]
fn build_plan(
    routine: &str,
    n: usize,
    nrhs: usize,
    tile: usize,
    ndev: usize,
    dtype: DType,
    (p, q): (usize, usize),
    est_ns: u64,
    precision: Precision,
) -> Result<DistPlan> {
    if p > 1 {
        let g = BlockCyclic2D::new(n, n, tile, tile, p, q)?;
        Ok(DistPlan {
            grid: (p, q),
            ndev: p * q,
            kind: LayoutKind::Grid(g),
            footprint: Footprint::for_grid(routine, &g, nrhs, dtype)?,
            est_ns,
            precision,
        })
    } else {
        Ok(DistPlan {
            grid: (1, ndev),
            ndev,
            kind: LayoutKind::BlockCyclic(BlockCyclic1D::new(n, tile, ndev)?),
            footprint: Footprint::for_routine(routine, n, nrhs, tile, ndev, dtype)?,
            est_ns,
            precision,
        })
    }
}

impl DistPlan {
    /// Widen the admission footprint to `total` devices (zero bytes on
    /// the devices the plan does not occupy) without touching the grid
    /// or layout — how an island-confined plan passes the node-wide
    /// `footprint.devices() == capacity.len()` admission check.
    fn pad_to(mut self, total: usize) -> Self {
        self.footprint = self.footprint.padded(total);
        self
    }
}

/// Declared per-device workspace footprint of one solve, in bytes —
/// what the admission accountant reserves against each device's VRAM.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Footprint {
    per_device: Vec<usize>,
}

impl Footprint {
    /// The same `bytes` on every one of `ndev` devices.
    pub fn uniform(ndev: usize, bytes: usize) -> Self {
        Footprint { per_device: vec![bytes; ndev] }
    }

    /// Explicit per-device byte counts.
    pub fn per_device(bytes: Vec<usize>) -> Self {
        Footprint { per_device: bytes }
    }

    /// Workspace-model footprint for a routine, mirroring the
    /// cuSOLVERMg workspace-size queries in [`workspace`], plus the
    /// block-cyclic tile-rounding slack: the layout stores whole tiles
    /// per device (up to `ceil(ntiles/ndev)·tile` columns), while the
    /// workspace formulas model `ceil(n/ndev)` flat columns, so each
    /// panel-shaped term is padded to dominate the real allocation.
    pub fn for_routine(
        routine: &str,
        n: usize,
        nrhs: usize,
        tile: usize,
        ndev: usize,
        dtype: DType,
    ) -> Result<Self> {
        let (bytes, panel_terms) = match routine {
            // Factor-only: the potrs working set minus the replicated
            // RHS (`nrhs` is ignored).
            "potrf" => (workspace::potrs_bytes(n, 0, tile, ndev, dtype), 1),
            "potrs" => (workspace::potrs_bytes(n, nrhs, tile, ndev, dtype), 1),
            "potri" => (workspace::potri_bytes(n, tile, ndev, dtype), 2),
            "syevd" => (workspace::syevd_bytes(n, tile, ndev, dtype), 4),
            other => return Err(Error::config(format!("unknown routine {other:?}"))),
        };
        let t = tile.max(1);
        let d = ndev.max(1);
        let cols_flat = n.div_ceil(d);
        let cols_tiled = n.div_ceil(t).div_ceil(d) * t;
        let slack = panel_terms * n * cols_tiled.saturating_sub(cols_flat) * dtype.size_of();
        Ok(Self::uniform(ndev, bytes + slack))
    }

    /// Workspace-model footprint for a routine over a **2D tile grid**
    /// ([`crate::layout::BlockCyclic2D`]): the matrix term uses each
    /// device's *exact* `local_rows × local_cols` shard (ragged edge
    /// tiles included), so per-device reservations differ across the
    /// grid instead of assuming the flat `n·ceil(n/ndev)` column shard.
    /// Scratch terms mirror [`Footprint::for_routine`]: `panel_terms`
    /// broadcast panels of `n × tile_c` plus the replicated RHS.
    pub fn for_grid(
        routine: &str,
        lay: &crate::layout::BlockCyclic2D,
        nrhs: usize,
        dtype: DType,
    ) -> Result<Self> {
        use crate::layout::MatrixLayout;
        let (matrix_copies, panel_terms) = match routine {
            "potrf" => (1usize, 1usize),
            "potrs" => (1, 1),
            "potri" => (2, 2),
            // matrix + eigenvector matrix + 2× back-transform scratch.
            "syevd" => (4, 4),
            other => return Err(Error::config(format!("unknown routine {other:?}"))),
        };
        let e = dtype.size_of();
        let (_, n) = lay.shape();
        let panel = panel_terms * n * lay.tile_c() * e;
        let rhs = if routine == "potrs" { n * nrhs * e } else { 0 };
        let per_device = (0..lay.num_devices())
            .map(|d| matrix_copies * lay.local_elems(d) * e + panel + rhs)
            .collect();
        Ok(Self::per_device(per_device))
    }

    /// Footprint of one coalesced **pod** of small solves: `dims[i]`
    /// is system `i`'s `(n, nrhs)`, placed by the same
    /// [`TileDim::round_robin`] deal [`crate::batch::PackedPod`] uses
    /// for the actual arenas. Per-device bytes are the *exact* arena
    /// sizes — each system's matrix plus, for `potrs`, its RHS pod
    /// entry; the sweeps run in place, so there is no broadcast-panel
    /// or workspace term to pad for.
    pub fn for_pod(
        routine: &str,
        dims: &[(usize, usize)],
        ndev: usize,
        dtype: DType,
    ) -> Result<Self> {
        let with_rhs = match routine {
            "potrf" | "potri" => false,
            "potrs" => true,
            other => return Err(Error::config(format!("unknown routine {other:?}"))),
        };
        let deal = TileDim::round_robin(dims.len(), ndev)?;
        let e = dtype.size_of();
        let mut per_device = vec![0usize; ndev];
        for (i, &(n, nrhs)) in dims.iter().enumerate() {
            per_device[deal.owner(i)] += n * n * e + if with_rhs { n * nrhs * e } else { 0 };
        }
        Ok(Self::per_device(per_device))
    }

    /// Footprint of a **cached Cholesky factor** kept resident in
    /// device memory: exactly the factor's own distributed shards —
    /// `local_elems` per device for the entry's layout — with no
    /// broadcast-panel, workspace, or RHS terms (a resident factor
    /// runs no kernels; the consuming solve declares its own scratch).
    /// Charged against the same [`DeviceAdmission`] accountant as
    /// in-flight solves so resident factors and live work share one
    /// VRAM budget.
    pub fn for_cached_factor(kind: &crate::tile::LayoutKind, n: usize, dtype: DType) -> Self {
        let e = dtype.size_of();
        let ndev = kind.num_devices();
        Footprint {
            per_device: (0..ndev).map(|d| kind.local_elems(n, d) * e).collect(),
        }
    }

    /// Widen to `total` devices by appending zero-byte entries — a
    /// narrow (island-confined) plan admitted against a full-width
    /// capacity table. Reserving zero bytes on a device is free, so
    /// padding never changes what fits. No-op if already that wide.
    pub fn padded(mut self, total: usize) -> Self {
        if self.per_device.len() < total {
            self.per_device.resize(total, 0);
        }
        self
    }

    /// Number of devices covered.
    pub fn devices(&self) -> usize {
        self.per_device.len()
    }

    /// Bytes reserved on device `d`.
    pub fn bytes(&self, d: usize) -> usize {
        self.per_device[d]
    }

    /// All per-device byte counts.
    pub fn as_slice(&self) -> &[usize] {
        &self.per_device
    }

    /// Consume into the raw per-device byte vector.
    pub(crate) fn into_per_device(self) -> Vec<usize> {
        self.per_device
    }
}

/// Memoized grid-shape selections. [`Predictor::best_grid`] replays
/// full `O(nt²)`–`O(nt³)` schedules per candidate factorization, so
/// the serving fronts cache the chosen shape per
/// `(routine, dtype, n, nrhs, tile, ndev, numeric)` — repeat traffic
/// (the serving common case) pays one map lookup on the dispatch path
/// instead of re-running the replays. The numeric policy is part of
/// the key because it changes the routed [`Precision`] and therefore
/// the estimate; forced grids bypass the cache (they cost nothing to
/// "select"), and `ndev` is part of the key so a shrunk MPMD live set
/// re-plans correctly.
#[derive(Debug, Default)]
pub struct GridPlanCache {
    #[allow(clippy::type_complexity)]
    shapes: Mutex<
        HashMap<
            (&'static str, DType, usize, usize, usize, usize, Option<NumericPolicy>),
            ((usize, usize), usize, u64, Precision),
        >,
    >,
}

impl GridPlanCache {
    /// Fresh empty cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// [`plan_dist`] with the selector memoized.
    #[allow(clippy::too_many_arguments)]
    pub fn plan(
        &self,
        routine: &'static str,
        n: usize,
        nrhs: usize,
        tile: usize,
        ndev: usize,
        dtype: DType,
        model: &GpuCostModel,
        topo: &NodeTopology,
        force: Option<(usize, usize)>,
    ) -> Result<DistPlan> {
        self.plan_numeric(routine, n, nrhs, tile, ndev, dtype, model, topo, force, None)
    }

    /// [`plan_dist_prec`] with the selector memoized — the routed
    /// precision and its estimate are cached alongside the shape, so a
    /// repeat request with the same policy replays nothing.
    #[allow(clippy::too_many_arguments)]
    pub fn plan_numeric(
        &self,
        routine: &'static str,
        n: usize,
        nrhs: usize,
        tile: usize,
        ndev: usize,
        dtype: DType,
        model: &GpuCostModel,
        topo: &NodeTopology,
        force: Option<(usize, usize)>,
        numeric: Option<NumericPolicy>,
    ) -> Result<DistPlan> {
        if force.is_some() {
            return plan_dist_prec(routine, n, nrhs, tile, ndev, dtype, model, topo, force, numeric);
        }
        let key = (routine, dtype, n, nrhs, tile, ndev, numeric);
        let cached = self.shapes.lock().unwrap().get(&key).copied();
        if let Some((g, used, est_ns, precision)) = cached {
            return Ok(
                build_plan(routine, n, nrhs, tile, used, dtype, g, est_ns, precision)?
                    .pad_to(ndev),
            );
        }
        let plan = plan_dist_prec(routine, n, nrhs, tile, ndev, dtype, model, topo, None, numeric)?;
        self.shapes
            .lock()
            .unwrap()
            .insert(key, (plan.grid, plan.ndev, plan.est_ns, plan.precision));
        Ok(plan)
    }
}

// ---- SLO-aware scheduling ------------------------------------------------

/// Request priority class, ordered most- to least-latency-sensitive.
/// Lower discriminant schedules first under [`SchedPolicy::EdfSjf`].
#[derive(Copy, Clone, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum SloClass {
    /// Latency-sensitive foreground traffic (a user is waiting).
    Interactive = 0,
    /// Default class for unremarkable traffic.
    Standard = 1,
    /// Throughput-oriented background work (offline GP refits, sweeps).
    Batch = 2,
}

impl SloClass {
    /// All classes, scheduling order.
    pub const ALL: [SloClass; 3] = [SloClass::Interactive, SloClass::Standard, SloClass::Batch];

    /// Dense index (0..3) for per-class metric arrays.
    pub fn index(self) -> usize {
        self as usize
    }

    /// Human-readable name.
    pub fn name(self) -> &'static str {
        match self {
            SloClass::Interactive => "interactive",
            SloClass::Standard => "standard",
            SloClass::Batch => "batch",
        }
    }
}

/// Numeric-accuracy policy a request carries: the relative-residual
/// tolerance its answer must meet and the condition-number budget the
/// router may assume when predicting refinement convergence. Carried
/// on the [`Slo`] so the planner can route the solve
/// [`Precision::Mixed`] when the mixed-precision replay wins under
/// that budget. Stored as f64 bit patterns so SLOs and plan-cache
/// keys stay `Eq + Hash`.
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
pub struct NumericPolicy {
    tol_bits: u64,
    cond_bits: u64,
}

impl NumericPolicy {
    /// Policy from a relative-residual tolerance and a condition-number
    /// estimate κ(A) (use an upper bound when the exact value is
    /// unknown — an over-estimate only makes routing conservative).
    pub fn new(tol: f64, cond: f64) -> Self {
        NumericPolicy { tol_bits: tol.to_bits(), cond_bits: cond.to_bits() }
    }

    /// Relative-residual target: ‖b − A·x‖_F / ‖b‖_F ≤ tol.
    pub fn tol(self) -> f64 {
        f64::from_bits(self.tol_bits)
    }

    /// Condition-number budget the router prices refinement with.
    pub fn cond(self) -> f64 {
        f64::from_bits(self.cond_bits)
    }
}

/// The service-level objective a request carries into the queue.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct Slo {
    /// Priority class.
    pub class: SloClass,
    /// Optional absolute completion deadline, cost-model ns on the
    /// node's simulated timeline. `None` ranks after every concrete
    /// deadline within the class.
    pub deadline_ns: Option<u64>,
    /// Tenant id for per-tenant admission quotas.
    pub tenant: u32,
    /// Optional numeric policy: a tolerance plus condition budget that
    /// makes the request eligible for mixed-precision routing. `None`
    /// always runs the full-precision path.
    pub numeric: Option<NumericPolicy>,
}

impl Slo {
    /// Interactive-class SLO, no deadline, tenant 0.
    pub fn interactive() -> Self {
        Slo { class: SloClass::Interactive, deadline_ns: None, tenant: 0, numeric: None }
    }

    /// Standard-class SLO, no deadline, tenant 0 — what legacy submit
    /// paths default to.
    pub fn standard() -> Self {
        Slo { class: SloClass::Standard, deadline_ns: None, tenant: 0, numeric: None }
    }

    /// Batch-class SLO, no deadline, tenant 0.
    pub fn batch() -> Self {
        Slo { class: SloClass::Batch, deadline_ns: None, tenant: 0, numeric: None }
    }

    /// Attach an absolute deadline (cost-model ns).
    pub fn with_deadline_ns(mut self, deadline_ns: u64) -> Self {
        self.deadline_ns = Some(deadline_ns);
        self
    }

    /// Attach a tenant id.
    pub fn with_tenant(mut self, tenant: u32) -> Self {
        self.tenant = tenant;
        self
    }

    /// Attach a numeric policy (tolerance + condition budget), opting
    /// the request into mixed-precision routing.
    pub fn with_tolerance(mut self, tol: f64, cond: f64) -> Self {
        self.numeric = Some(NumericPolicy::new(tol, cond));
        self
    }
}

impl Default for Slo {
    fn default() -> Self {
        Slo::standard()
    }
}

/// Queue-ordering policy of a serving front.
#[derive(Copy, Clone, Debug, PartialEq, Eq, Default)]
pub enum SchedPolicy {
    /// Strict arrival order, head-of-line admission only — the seed
    /// behavior, and the baseline the benches compare against.
    #[default]
    Fifo,
    /// Earliest deadline first with shortest-job-first tie-break:
    /// rank = `(class, deadline, est_ns, seq)`. FIFO within equal rank,
    /// and an anti-starvation barrier (see [`SchedConfig::max_skips`])
    /// bounds how often any request can be bypassed.
    EdfSjf,
}

/// Scheduler configuration shared by both serving fronts.
#[derive(Copy, Clone, Debug)]
pub struct SchedConfig {
    /// Queue-ordering policy.
    pub policy: SchedPolicy,
    /// Per-tenant cap on *admitted* footprint bytes (summed over
    /// devices). `None` disables quotas.
    pub tenant_quota: Option<usize>,
    /// Anti-starvation bound: once a queued request has been bypassed
    /// by `max_skips` younger requests, it becomes an urgent barrier —
    /// nothing else is admitted until it fits.
    pub max_skips: u32,
    /// Degraded-mode SLO relaxation under straggler injection: a front
    /// running with stragglers multiplies deadline-miss accounting by
    /// this factor (≥ 1.0). Scheduling order is unchanged — a uniform
    /// deadline scale preserves EDF order.
    pub degrade_factor: f64,
}

impl Default for SchedConfig {
    fn default() -> Self {
        SchedConfig {
            policy: SchedPolicy::default(),
            tenant_quota: None,
            max_skips: 16,
            degrade_factor: 2.0,
        }
    }
}

/// The scheduling envelope a queued request carries: its SLO, the
/// Predictor makespan estimate, enqueue timestamp, arrival sequence
/// number, and how many younger requests have bypassed it.
#[derive(Copy, Clone, Debug)]
pub struct SloTicket {
    /// The request's service-level objective.
    pub slo: Slo,
    /// Predictor-estimated makespan, cost-model ns ([`DistPlan::est_ns`]).
    pub est_ns: u64,
    /// Cost-model enqueue timestamp (node sim time at submit).
    pub enq_ns: u64,
    /// Arrival sequence number — the FIFO total order.
    pub seq: u64,
    /// Times a younger request was admitted past this one.
    pub skips: u32,
}

impl SloTicket {
    /// Scheduling rank under [`SchedPolicy::EdfSjf`]: class, then
    /// deadline (none sorts last), then estimated makespan, then
    /// arrival order. Smaller ranks schedule first.
    fn rank(&self) -> (usize, u64, u64, u64) {
        (
            self.slo.class.index(),
            self.slo.deadline_ns.unwrap_or(u64::MAX),
            self.est_ns,
            self.seq,
        )
    }
}

/// The SLO-aware queue both fronts route through. Holds `(ticket,
/// item)` pairs; candidate selection depends on the policy:
///
/// * [`SchedPolicy::Fifo`] — only the oldest entry is ever a
///   candidate (exact seed head-of-line semantics);
/// * [`SchedPolicy::EdfSjf`] — entries are tried in rank order, so a
///   small latency-sensitive solve can be admitted past a large batch
///   solve the capacity predicate rejects (backfill). Every admission
///   past an older entry increments that entry's skip count; once any
///   entry reaches `max_skips` it becomes an **urgent barrier**: the
///   oldest such entry is the only candidate until it is admitted,
///   which restores the FIFO no-starvation guarantee.
#[derive(Debug)]
pub(crate) struct SloQueue<T> {
    entries: Vec<(SloTicket, T)>,
    next_seq: u64,
    policy: SchedPolicy,
    max_skips: u32,
}

impl<T> SloQueue<T> {
    pub(crate) fn new(policy: SchedPolicy, max_skips: u32) -> Self {
        SloQueue { entries: Vec::new(), next_seq: 0, policy, max_skips: max_skips.max(1) }
    }

    pub(crate) fn len(&self) -> usize {
        self.entries.len()
    }

    pub(crate) fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Enqueue a fresh request; assigns the next arrival sequence.
    pub(crate) fn push_back(&mut self, slo: Slo, est_ns: u64, enq_ns: u64, item: T) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.entries.push((SloTicket { slo, est_ns, enq_ns, seq, skips: 0 }, item));
    }

    /// Re-insert a previously popped entry, keeping its original
    /// sequence number and skip count (MPMD requeue-after-failure and
    /// admission-rollback paths: the request keeps its queue age).
    pub(crate) fn restore(&mut self, ticket: SloTicket, item: T) {
        debug_assert!(ticket.seq < self.next_seq, "restored ticket from a different queue");
        self.entries.push((ticket, item));
    }

    /// Indices of admission candidates, in scheduling order.
    fn candidates(&self) -> Vec<usize> {
        if self.entries.is_empty() {
            return Vec::new();
        }
        // Urgent barrier: the oldest over-skipped entry (if any) is the
        // only candidate, under either policy.
        if let Some(urgent) = self
            .entries
            .iter()
            .enumerate()
            .filter(|(_, (t, _))| t.skips >= self.max_skips)
            .min_by_key(|(_, (t, _))| t.seq)
            .map(|(i, _)| i)
        {
            return vec![urgent];
        }
        match self.policy {
            SchedPolicy::Fifo => {
                // Head-of-line only: exact seed admission semantics.
                let head = self
                    .entries
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, (t, _))| t.seq)
                    .map(|(i, _)| i)
                    .unwrap();
                vec![head]
            }
            SchedPolicy::EdfSjf => {
                let mut idx: Vec<usize> = (0..self.entries.len()).collect();
                idx.sort_by_key(|&i| self.entries[i].0.rank());
                idx
            }
        }
    }

    /// Pop the best-ranked entry the `fits` predicate admits, aging
    /// every older entry it was admitted past. Returns `None` when no
    /// candidate fits — the caller waits for capacity.
    pub(crate) fn pop_admissible(
        &mut self,
        mut fits: impl FnMut(&SloTicket, &T) -> bool,
    ) -> Option<(SloTicket, T)> {
        let pick = self
            .candidates()
            .into_iter()
            .find(|&i| fits(&self.entries[i].0, &self.entries[i].1))?;
        let (ticket, item) = self.entries.swap_remove(pick);
        for (t, _) in &mut self.entries {
            if t.seq < ticket.seq {
                t.skips += 1;
            }
        }
        Some((ticket, item))
    }

    /// Pop the best-ranked entry unconditionally (admission happens
    /// outside the queue lock — the MPMD dispatcher path).
    pub(crate) fn pop_next(&mut self) -> Option<(SloTicket, T)> {
        self.pop_admissible(|_, _| true)
    }

    /// Sequence numbers in current scheduling order (test inspection).
    #[cfg(test)]
    pub(crate) fn order(&self) -> Vec<u64> {
        self.candidates().into_iter().map(|i| self.entries[i].0.seq).collect()
    }
}

/// Per-tenant admitted-footprint accounting. All methods take `&self`;
/// callers serialize check-then-admit under their own scheduler lock,
/// this mutex only guards interior mutability.
#[derive(Debug)]
pub(crate) struct TenantQuotas {
    quota: Option<usize>,
    state: Mutex<HashMap<u32, TenantUsage>>,
}

#[derive(Debug, Default, Copy, Clone)]
struct TenantUsage {
    admitted: usize,
    peak: usize,
}

impl TenantQuotas {
    pub(crate) fn new(quota: Option<usize>) -> Self {
        TenantQuotas { quota, state: Mutex::new(HashMap::new()) }
    }

    /// Would admitting `bytes` more for `tenant` stay within quota?
    pub(crate) fn would_admit(&self, tenant: u32, bytes: usize) -> bool {
        match self.quota {
            None => true,
            Some(q) => {
                let st = self.state.lock().unwrap();
                let cur = st.get(&tenant).map(|u| u.admitted).unwrap_or(0);
                cur + bytes <= q
            }
        }
    }

    /// Record an admission (caller already checked [`Self::would_admit`]
    /// under its scheduler lock).
    pub(crate) fn admit(&self, tenant: u32, bytes: usize) {
        if self.quota.is_none() {
            return;
        }
        let mut st = self.state.lock().unwrap();
        let u = st.entry(tenant).or_default();
        u.admitted += bytes;
        u.peak = u.peak.max(u.admitted);
    }

    /// Release a completed request's footprint.
    pub(crate) fn release(&self, tenant: u32, bytes: usize) {
        if self.quota.is_none() {
            return;
        }
        let mut st = self.state.lock().unwrap();
        if let Some(u) = st.get_mut(&tenant) {
            u.admitted = u.admitted.saturating_sub(bytes);
        }
    }

    /// Currently admitted bytes for `tenant`.
    pub(crate) fn admitted(&self, tenant: u32) -> usize {
        self.state.lock().unwrap().get(&tenant).map(|u| u.admitted).unwrap_or(0)
    }

    /// High-water mark for `tenant` — the over-admission proof.
    pub(crate) fn peak(&self, tenant: u32) -> usize {
        self.state.lock().unwrap().get(&tenant).map(|u| u.peak).unwrap_or(0)
    }

    /// The configured quota, if any.
    pub(crate) fn quota(&self) -> Option<usize> {
        self.quota
    }
}

/// A single device's reservation accountant — the per-worker half of
/// admission in MPMD mode, where each one-process-per-GPU worker admits
/// solves against **its own** device's VRAM capacity instead of a
/// central accountant seeing the whole node.
#[derive(Debug)]
pub struct DeviceAdmission {
    device: usize,
    capacity: usize,
    state: Mutex<AdmissionState>,
}

#[derive(Debug, Default)]
struct AdmissionState {
    reserved: usize,
    peak: usize,
}

impl DeviceAdmission {
    /// Accountant for device `device` with `capacity` bytes of VRAM.
    pub fn new(device: usize, capacity: usize) -> Self {
        DeviceAdmission { device, capacity, state: Mutex::new(AdmissionState::default()) }
    }

    /// The device this accountant guards.
    pub fn device(&self) -> usize {
        self.device
    }

    /// Total VRAM capacity admitted against.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Reserve `bytes`, failing with [`Error::DeviceOom`] when the
    /// reservation would exceed capacity (non-blocking; the caller owns
    /// the retry policy).
    pub fn try_reserve(&self, bytes: usize) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        if st.reserved + bytes > self.capacity {
            return Err(Error::DeviceOom {
                device: self.device,
                requested: bytes,
                free: self.capacity - st.reserved,
                capacity: self.capacity,
            });
        }
        st.reserved += bytes;
        if st.reserved > st.peak {
            st.peak = st.reserved;
        }
        Ok(())
    }

    /// Release a prior reservation.
    pub fn release(&self, bytes: usize) {
        let mut st = self.state.lock().unwrap();
        st.reserved = st.reserved.saturating_sub(bytes);
    }

    /// Currently reserved bytes.
    pub fn reserved(&self) -> usize {
        self.state.lock().unwrap().reserved
    }

    /// High-water mark of reserved bytes — the proof the worker never
    /// over-admitted its device.
    pub fn peak_reserved(&self) -> usize {
        self.state.lock().unwrap().peak
    }
}

/// Per-solve service metrics, returned with the result.
///
/// Every duration is **cost-model (simulated) nanoseconds** on the
/// node's integer-ns timeline — the same clock the golden timelines and
/// the projected wall-clock columns use. Host wall time never leaks in:
/// mixing `Instant::elapsed()` with simulated nanoseconds made latency
/// stats depend on the simulator's CPU speed instead of the modeled
/// machine's.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct SolveStats {
    /// Simulated ns spent queued before the scheduler admitted the
    /// solve (enqueue timestamp → admission timestamp).
    pub queue_wait_ns: u64,
    /// Simulated ns from admission to completion.
    pub exec_ns: u64,
    /// Solves that shared this solve's admitted job — the coalesced
    /// bucket occupancy on the batched small-solve path, `1` otherwise.
    pub batch_size: usize,
    /// Cost-model (simulated) nanoseconds this solve dwelled in the
    /// coalescer before its bucket flushed; `0` off the batched path.
    pub coalesce_wait_ns: u64,
    /// The `(P, Q)` process grid the solve executed on: `(1, ndev)`
    /// for 1D distributed solves, the selector's shape for grid-native
    /// ones, `(1, 1)` for single-device / batched-pod work.
    pub grid: (usize, usize),
    /// Whether this solve ran against a resident cached factor (the
    /// scatter + potrf skipped entirely); always `false` with the
    /// factor cache disabled.
    pub cache_hit: bool,
    /// Stages of the fused solve DAG this request executed as part of
    /// (`1` for a standalone solve; a fused `potrf→potrs→potri` chain
    /// reports `3` on each of its per-stage results).
    pub fused_stages: usize,
}

impl SolveStats {
    /// Queue wait in seconds (convenience for reporting).
    pub fn queue_wait_secs(&self) -> f64 {
        self.queue_wait_ns as f64 * 1e-9
    }

    /// Execution time in seconds (convenience for reporting).
    pub fn exec_secs(&self) -> f64 {
        self.exec_ns as f64 * 1e-9
    }
}

/// Why a service solve did not produce a result — the typed error a
/// [`ServiceHandle`] resolves to. `Clone` so one failure can fan out to
/// every waiter of a coalesced batch.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// Every worker in the MPMD deployment is dead: no live device
    /// subset remains, so re-queueing would spin forever. Surfaced to
    /// the submitter instead.
    NoLiveWorkers {
        /// Total workers the deployment started with.
        total: usize,
    },
    /// The solve panicked (or failed terminally) inside a worker; the
    /// worker survived and this carries the panic/failure message.
    Failed(String),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::NoLiveWorkers { total } => {
                write!(f, "no live workers left (all {total} dead); request cannot be served")
            }
            ServeError::Failed(msg) => write!(f, "service solve panicked: {msg}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// `Ok((result, stats))`, or the typed reason the solve failed.
pub(crate) type SolveOutcome<T> = std::result::Result<(T, SolveStats), ServeError>;

/// The shared completion slot a [`ServiceHandle`] waits on.
pub(crate) type Slot<T> = Arc<(Mutex<Option<SolveOutcome<T>>>, Condvar)>;

/// A fresh handle plus the slot its producer publishes into.
pub(crate) fn handle_pair<T>() -> (ServiceHandle<T>, Slot<T>) {
    let slot: Slot<T> = Arc::new((Mutex::new(None), Condvar::new()));
    (ServiceHandle { slot: slot.clone() }, slot)
}

/// Publish one solve's outcome and wake its waiter.
pub(crate) fn publish_one<T>(slot: &Slot<T>, outcome: SolveOutcome<T>) {
    let (lock, cv) = &**slot;
    *lock.lock().unwrap() = Some(outcome);
    cv.notify_all();
}

/// Publish the same panic/failure message to a whole batch of waiters.
pub(crate) fn publish_failure<T>(slots: &[Slot<T>], msg: String) {
    publish_error(slots, ServeError::Failed(msg));
}

/// Publish the same typed error to a whole batch of waiters.
pub(crate) fn publish_error<T>(slots: &[Slot<T>], err: ServeError) {
    for slot in slots {
        publish_one(slot, Err(err.clone()));
    }
}

/// Render a caught panic payload as the message re-raised on waiters.
pub(crate) fn panic_message(p: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Completion handle for a service solve: the result plus its stats.
pub struct ServiceHandle<T> {
    pub(crate) slot: Slot<T>,
}

impl<T> ServiceHandle<T> {
    /// Block until the solve completes; returns `(result, stats)`.
    /// Re-raises the solve's panic if it unwound inside a worker
    /// (the worker itself survives and the reservation is released),
    /// and panics on typed serve errors too — use
    /// [`ServiceHandle::wait_result`] to handle those gracefully.
    pub fn wait(self) -> (T, SolveStats) {
        match self.wait_result() {
            Ok(out) => out,
            Err(e) => panic!("{e}"),
        }
    }

    /// Block until the solve completes; returns the typed outcome. An
    /// all-workers-dead MPMD deployment resolves every waiter with
    /// [`ServeError::NoLiveWorkers`] instead of panicking the caller.
    pub fn wait_result(self) -> std::result::Result<(T, SolveStats), ServeError> {
        let (lock, cv) = &*self.slot;
        let mut guard = lock.lock().unwrap();
        loop {
            if let Some(v) = guard.take() {
                return v;
            }
            guard = cv.wait(guard).unwrap();
        }
    }

    /// Non-blocking readiness check.
    pub fn is_ready(&self) -> bool {
        self.slot.0.lock().unwrap().is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn device_admission_reserves_and_releases() {
        let adm = DeviceAdmission::new(3, 1000);
        assert_eq!(adm.capacity(), 1000);
        assert_eq!(adm.device(), 3);
        adm.try_reserve(600).unwrap();
        match adm.try_reserve(500) {
            Err(Error::DeviceOom { device, requested, free, capacity }) => {
                assert_eq!(device, 3);
                assert_eq!(requested, 500);
                assert_eq!(free, 400);
                assert_eq!(capacity, 1000);
            }
            other => panic!("expected OOM, got {other:?}"),
        }
        adm.try_reserve(400).unwrap();
        assert_eq!(adm.reserved(), 1000);
        adm.release(600);
        assert_eq!(adm.reserved(), 400);
        assert_eq!(adm.peak_reserved(), 1000);
        // Releasing more than reserved saturates instead of wrapping.
        adm.release(10_000);
        assert_eq!(adm.reserved(), 0);
    }

    #[test]
    fn plan_dist_respects_force_and_small_shapes_stay_1d() {
        use crate::layout::MatrixLayout;
        let model = GpuCostModel::h200();
        let topo = NodeTopology::nvlink_all_to_all(4);
        // Small solve: autotuner keeps the 1D layout.
        let p1 = plan_dist("potrs", 192, 1, 32, 4, DType::F64, &model, &topo, None).unwrap();
        assert_eq!(p1.grid, (1, 4));
        assert!(matches!(p1.kind, LayoutKind::BlockCyclic(_)));
        // Forced 2x2: grid layout + exact 2D shard footprint.
        let p2 = plan_dist("potrs", 192, 1, 32, 4, DType::F64, &model, &topo, Some((2, 2))).unwrap();
        assert_eq!(p2.grid, (2, 2));
        match p2.kind {
            LayoutKind::Grid(g) => {
                assert_eq!(g.grid(), (2, 2));
                assert_eq!(g.tile_shape(), (32, 32));
                assert_eq!(
                    p2.footprint,
                    Footprint::for_grid("potrs", &g, 1, DType::F64).unwrap()
                );
            }
            other => panic!("expected a grid layout, got {other:?}"),
        }
        // Paper scale: the autotuner goes 2D on its own.
        let p3 = plan_dist("potrf", 16384, 0, 256, 4, DType::F64, &model, &topo, None).unwrap();
        assert!(p3.grid.0 > 1, "paper-scale plan stayed 1D: {:?}", p3.grid);
        // A grid that does not cover the device count is rejected.
        assert!(plan_dist("potrf", 64, 0, 8, 4, DType::F64, &model, &topo, Some((3, 2))).is_err());
        assert_eq!(DistRoutine::Syevd.name(), "syevd");
    }

    #[test]
    fn grid_plan_cache_memoizes_the_selector() {
        let model = GpuCostModel::h200();
        let topo = NodeTopology::nvlink_all_to_all(4);
        let cache = GridPlanCache::new();
        let a = cache.plan("potrs", 192, 1, 32, 4, DType::F64, &model, &topo, None).unwrap();
        let b = cache.plan("potrs", 192, 1, 32, 4, DType::F64, &model, &topo, None).unwrap();
        assert_eq!(a.grid, b.grid);
        assert_eq!(a.kind, b.kind);
        assert_eq!(a.footprint, b.footprint);
        // The memo matches the uncached planner exactly.
        let fresh = plan_dist("potrs", 192, 1, 32, 4, DType::F64, &model, &topo, None).unwrap();
        assert_eq!(b.grid, fresh.grid);
        // A different live-set size is a different key.
        let topo3 = NodeTopology::nvlink_all_to_all(3);
        let c = cache.plan("potrs", 192, 1, 32, 3, DType::F64, &model, &topo3, None).unwrap();
        assert_eq!(c.grid.0 * c.grid.1, 3);
        // Forced grids bypass the memo.
        let f = cache.plan("potrs", 192, 1, 32, 4, DType::F64, &model, &topo, Some((2, 2))).unwrap();
        assert_eq!(f.grid, (2, 2));
    }

    #[test]
    fn handle_pair_roundtrip() {
        let (h, slot) = handle_pair::<u32>();
        assert!(!h.is_ready());
        let stats = SolveStats {
            queue_wait_ns: 0,
            exec_ns: 0,
            batch_size: 1,
            coalesce_wait_ns: 0,
            grid: (1, 1),
            cache_hit: false,
            fused_stages: 1,
        };
        publish_one(&slot, Ok((7, stats)));
        assert!(h.is_ready());
        assert_eq!(h.wait().0, 7);
    }

    #[test]
    fn typed_errors_resolve_without_panicking() {
        let (h, slot) = handle_pair::<u32>();
        publish_error(&[slot], ServeError::NoLiveWorkers { total: 4 });
        match h.wait_result() {
            Err(ServeError::NoLiveWorkers { total }) => assert_eq!(total, 4),
            other => panic!("expected NoLiveWorkers, got {other:?}"),
        }
    }

    #[test]
    fn plan_estimates_match_the_predictor_bitwise() {
        let model = GpuCostModel::h200();
        let topo = NodeTopology::nvlink_all_to_all(4);
        let pred = Predictor { model: model.clone(), topo: topo.clone(), dtype: DType::F64 };
        let plan = plan_dist("potrs", 192, 1, 32, 4, DType::F64, &model, &topo, None).unwrap();
        let (p, q) = plan.grid;
        assert_eq!(plan.est_ns, secs_to_ns(pred.dist_makespan("potrs", 192, 1, 32, p, q)));
        assert!(plan.est_ns > 0);
        // Cache hits carry the identical estimate.
        let cache = GridPlanCache::new();
        let a = cache.plan("potrs", 192, 1, 32, 4, DType::F64, &model, &topo, None).unwrap();
        let b = cache.plan("potrs", 192, 1, 32, 4, DType::F64, &model, &topo, None).unwrap();
        assert_eq!(a.est_ns, plan.est_ns);
        assert_eq!(b.est_ns, plan.est_ns);
    }

    fn slo_ticket_queue() -> SloQueue<u32> {
        SloQueue::new(SchedPolicy::EdfSjf, 16)
    }

    #[test]
    fn fifo_policy_only_offers_the_head() {
        let mut q = SloQueue::new(SchedPolicy::Fifo, 16);
        q.push_back(Slo::batch(), 50, 0, 0);
        q.push_back(Slo::interactive(), 1, 0, 1);
        assert_eq!(q.order(), vec![0]);
        // Head does not fit -> nothing pops, even though entry 1 would.
        assert!(q.pop_admissible(|_, &item| item == 1).is_none());
        let (t, item) = q.pop_next().unwrap();
        assert_eq!((t.seq, item), (0, 0));
        assert_eq!(q.pop_next().unwrap().1, 1);
    }

    #[test]
    fn edf_sjf_ranks_class_then_deadline_then_estimate() {
        let mut q = slo_ticket_queue();
        q.push_back(Slo::batch(), 10, 0, 0);
        q.push_back(Slo::standard().with_deadline_ns(900), 10, 0, 1);
        q.push_back(Slo::standard().with_deadline_ns(500), 10, 0, 2);
        q.push_back(Slo::interactive(), 7, 0, 3);
        q.push_back(Slo::interactive(), 3, 0, 4);
        // interactive first (SJF within: est 3 before 7), then standard
        // by deadline, batch last.
        assert_eq!(q.order(), vec![4, 3, 2, 1, 0]);
        // Backfill: if the best candidate does not fit, the next does.
        let (t, _) = q.pop_admissible(|t, _| t.est_ns != 3).unwrap();
        assert_eq!(t.seq, 3);
    }

    #[test]
    fn over_skipped_entry_becomes_an_urgent_barrier() {
        let mut q = SloQueue::new(SchedPolicy::EdfSjf, 2);
        q.push_back(Slo::batch(), 100, 0, 0); // the starvation victim
        q.push_back(Slo::interactive(), 1, 0, 1);
        q.push_back(Slo::interactive(), 1, 0, 2);
        q.push_back(Slo::interactive(), 1, 0, 3);
        assert_eq!(q.pop_next().unwrap().1, 1);
        assert_eq!(q.pop_next().unwrap().1, 2);
        // Two skips recorded: the batch entry is now the sole candidate.
        assert_eq!(q.order(), vec![0]);
        // Even a fit-everything predicate must take the barrier entry.
        assert_eq!(q.pop_next().unwrap().1, 0);
        assert_eq!(q.pop_next().unwrap().1, 3);
        assert!(q.pop_next().is_none());
    }

    #[test]
    fn restore_keeps_queue_age() {
        let mut q = slo_ticket_queue();
        q.push_back(Slo::interactive(), 1, 0, 10);
        q.push_back(Slo::interactive(), 2, 0, 11);
        let (t, item) = q.pop_next().unwrap();
        assert_eq!(item, 10);
        q.restore(t, item);
        // Restored entry keeps seq 0 and still ranks first (same est).
        assert_eq!(q.pop_next().unwrap().1, 10);
    }

    #[test]
    fn tenant_quotas_never_over_admit() {
        let quotas = TenantQuotas::new(Some(100));
        assert!(quotas.would_admit(7, 60));
        quotas.admit(7, 60);
        assert!(!quotas.would_admit(7, 50));
        assert!(quotas.would_admit(7, 40));
        // A different tenant has its own budget.
        assert!(quotas.would_admit(8, 100));
        quotas.admit(7, 40);
        assert_eq!(quotas.admitted(7), 100);
        assert_eq!(quotas.peak(7), 100);
        quotas.release(7, 60);
        assert_eq!(quotas.admitted(7), 40);
        assert_eq!(quotas.peak(7), 100);
        // No quota configured -> everything admits, nothing tracked.
        let open = TenantQuotas::new(None);
        assert!(open.would_admit(1, usize::MAX));
        assert_eq!(open.quota(), None);
    }

    #[test]
    fn numeric_policy_routes_mixed_above_the_crossover() {
        let model = GpuCostModel::h200();
        let topo = NodeTopology::nvlink_all_to_all(8);
        let pol = NumericPolicy::new(1e-10, 1e3);
        // Paper scale: the mixed replay wins, the estimate shrinks, and
        // the grid shape is the same one the full planner chose.
        let full = plan_dist("potrs", 16384, 1, 1024, 8, DType::F64, &model, &topo, None).unwrap();
        let mixed =
            plan_dist_prec("potrs", 16384, 1, 1024, 8, DType::F64, &model, &topo, None, Some(pol))
                .unwrap();
        assert_eq!(full.precision, Precision::Full);
        assert_eq!(mixed.precision, Precision::Mixed(DType::F32));
        assert_eq!(mixed.grid, full.grid);
        assert!(
            mixed.est_ns < full.est_ns,
            "mixed estimate {} not below full {}",
            mixed.est_ns,
            full.est_ns
        );
        // Below the crossover the launch-bound refinement tail loses:
        // the router keeps the full tier and the full estimate.
        let small =
            plan_dist_prec("potrs", 192, 1, 32, 8, DType::F64, &model, &topo, None, Some(pol))
                .unwrap();
        assert_eq!(small.precision, Precision::Full);
        // A condition budget past the convergence bound routes Full
        // even at scale.
        let ill = plan_dist_prec(
            "potrs", 16384, 1, 1024, 8, DType::F64, &model, &topo, None,
            Some(NumericPolicy::new(1e-10, 1e9)),
        )
        .unwrap();
        assert_eq!(ill.precision, Precision::Full);
        assert_eq!(ill.est_ns, full.est_ns);
        // Narrow dtypes have no working tier; syevd has no refinement
        // path — both stay Full under the same policy.
        let narrow =
            plan_dist_prec("potrs", 16384, 1, 1024, 8, DType::F32, &model, &topo, None, Some(pol))
                .unwrap();
        assert_eq!(narrow.precision, Precision::Full);
        let ev =
            plan_dist_prec("syevd", 4096, 0, 256, 8, DType::F64, &model, &topo, None, Some(pol))
                .unwrap();
        assert_eq!(ev.precision, Precision::Full);
    }

    #[test]
    fn grid_plan_cache_keys_on_the_numeric_policy() {
        let model = GpuCostModel::h200();
        let topo = NodeTopology::nvlink_all_to_all(8);
        let cache = GridPlanCache::new();
        let pol = NumericPolicy::new(1e-10, 1e3);
        let plain = cache
            .plan("potrs", 16384, 1, 1024, 8, DType::F64, &model, &topo, None)
            .unwrap();
        let routed = cache
            .plan_numeric("potrs", 16384, 1, 1024, 8, DType::F64, &model, &topo, None, Some(pol))
            .unwrap();
        assert_eq!(plain.precision, Precision::Full);
        assert!(routed.precision.is_mixed());
        // Cache hits replay nothing and carry the routed tier bitwise.
        let hit = cache
            .plan_numeric("potrs", 16384, 1, 1024, 8, DType::F64, &model, &topo, None, Some(pol))
            .unwrap();
        assert_eq!(hit.precision, routed.precision);
        assert_eq!(hit.est_ns, routed.est_ns);
        assert_eq!(hit.grid, routed.grid);
        // The memo matches the uncached planner exactly.
        let fresh =
            plan_dist_prec("potrs", 16384, 1, 1024, 8, DType::F64, &model, &topo, None, Some(pol))
                .unwrap();
        assert_eq!(hit.est_ns, fresh.est_ns);
        assert_eq!(hit.precision, fresh.precision);
    }

    #[test]
    fn slo_carries_the_numeric_policy() {
        let slo = Slo::interactive().with_tolerance(1e-9, 1e4);
        let pol = slo.numeric.unwrap();
        assert_eq!(pol.tol(), 1e-9);
        assert_eq!(pol.cond(), 1e4);
        assert_eq!(Slo::standard().numeric, None);
        // Policies are value-keyed: same inputs compare equal.
        assert_eq!(pol, NumericPolicy::new(1e-9, 1e4));
        assert_ne!(pol, NumericPolicy::new(1e-8, 1e4));
    }

    #[test]
    fn conversions_round_and_saturate() {
        assert_eq!(secs_to_ns(1.5e-3), 1_500_000);
        assert_eq!(secs_to_ns(0.0), 0);
        assert_eq!(duration_to_ns(Duration::from_nanos(42)), 42);
        assert_eq!(duration_to_ns(Duration::from_secs(u64::MAX / 2)), u64::MAX);
    }
}
