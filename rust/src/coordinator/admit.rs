//! Admission accounting and completion plumbing shared by the solve
//! fronts.
//!
//! Both serving fronts — the SPMD [`super::SolveService`] (one shared
//! address space, a central accountant over every device) and the MPMD
//! [`crate::serve::MpmdService`] (one process per GPU, each worker
//! admitting against **its own** device) — obey the same cuSOLVERMg
//! workspace-query-then-reserve discipline and resolve requests through
//! the same handle/stats types. This module is that shared layer:
//!
//! * [`Footprint`] — the declared per-device workspace bytes of one
//!   solve (routine formulas, exact 2D shards, pod arenas);
//! * [`DeviceAdmission`] — a single device's reservation accountant
//!   (the per-worker half of admission; the SPMD service keeps its
//!   all-devices FIFO variant in `service.rs`);
//! * [`ServiceHandle`] / [`SolveStats`] — completion handle and
//!   per-solve metrics, identical across fronts so callers can swap
//!   SPMD for MPMD without touching their wait loops;
//! * [`plan_dist`] / [`DistPlan`] — the **grid-shape planner** both
//!   fronts route distributed solves through: per request,
//!   [`crate::costmodel::Predictor::best_grid`] picks the `P × Q`
//!   factorization of the (live) device count with the smallest
//!   replayed makespan (1D for small problems, 2D grids at scale), the
//!   matching [`crate::tile::LayoutKind`] is built, and admission is
//!   against the **exact per-device shards of the chosen shape**
//!   ([`Footprint::for_grid`] for 2D, the routine formulas for 1D).
//!   Sharing the planner is what keeps the SPMD and MPMD fronts
//!   bitwise-identical: same inputs → same grid → same layout → same
//!   solver schedule.

use crate::costmodel::{workspace, GpuCostModel, Predictor};
use crate::device::NodeTopology;
use crate::error::{Error, Result};
use crate::layout::{BlockCyclic1D, BlockCyclic2D, TileDim};
use crate::scalar::DType;
use crate::tile::LayoutKind;
use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// The distributed routines the serving fronts route.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum DistRoutine {
    /// Cholesky factor (returns the factored matrix).
    Potrf,
    /// Factor + solve against a replicated RHS.
    Potrs,
    /// Factor + Cholesky-based inverse.
    Potri,
    /// Symmetric/Hermitian eigendecomposition.
    Syevd,
}

impl DistRoutine {
    /// The cost-model / workspace-formula name of the routine.
    pub fn name(self) -> &'static str {
        match self {
            DistRoutine::Potrf => "potrf",
            DistRoutine::Potrs => "potrs",
            DistRoutine::Potri => "potri",
            DistRoutine::Syevd => "syevd",
        }
    }
}

/// One planned distributed solve: the process-grid shape the selector
/// chose, the concrete layout on it, and the per-device admission
/// footprint against that exact shape.
#[derive(Clone, Debug)]
pub struct DistPlan {
    /// The chosen `(P, Q)` grid ( `(1, ndev)` is the 1D path).
    pub grid: (usize, usize),
    /// The layout solves scatter/stage into.
    pub kind: LayoutKind,
    /// Exact per-device workspace bytes on that layout.
    pub footprint: Footprint,
}

/// Plan a distributed solve over `ndev` devices: pick the grid shape
/// (`force` overrides the autotuner — `None` asks
/// [`Predictor::best_grid`]), build the layout, and size the exact
/// per-device footprint. `P = 1` maps to the native 1D block-cyclic
/// layout, keeping small solves bitwise on the seed path; `P > 1`
/// builds a square-tiled [`BlockCyclic2D`] grid admitted via
/// [`Footprint::for_grid`].
#[allow(clippy::too_many_arguments)]
pub fn plan_dist(
    routine: &str,
    n: usize,
    nrhs: usize,
    tile: usize,
    ndev: usize,
    dtype: DType,
    model: &GpuCostModel,
    topo: &NodeTopology,
    force: Option<(usize, usize)>,
) -> Result<DistPlan> {
    let (p, q) = match force {
        Some((p, q)) => {
            if p == 0 || q == 0 || p * q != ndev {
                return Err(Error::config(format!(
                    "forced grid {p}x{q} does not cover the {ndev} live devices"
                )));
            }
            (p, q)
        }
        None => {
            let predictor = Predictor { model: model.clone(), topo: topo.clone(), dtype };
            predictor.best_grid(routine, n, nrhs, tile, ndev)
        }
    };
    if p > 1 {
        let g = BlockCyclic2D::new(n, n, tile, tile, p, q)?;
        Ok(DistPlan {
            grid: (p, q),
            kind: LayoutKind::Grid(g),
            footprint: Footprint::for_grid(routine, &g, nrhs, dtype)?,
        })
    } else {
        Ok(DistPlan {
            grid: (1, ndev),
            kind: LayoutKind::BlockCyclic(BlockCyclic1D::new(n, tile, ndev)?),
            footprint: Footprint::for_routine(routine, n, nrhs, tile, ndev, dtype)?,
        })
    }
}

/// Declared per-device workspace footprint of one solve, in bytes —
/// what the admission accountant reserves against each device's VRAM.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Footprint {
    per_device: Vec<usize>,
}

impl Footprint {
    /// The same `bytes` on every one of `ndev` devices.
    pub fn uniform(ndev: usize, bytes: usize) -> Self {
        Footprint { per_device: vec![bytes; ndev] }
    }

    /// Explicit per-device byte counts.
    pub fn per_device(bytes: Vec<usize>) -> Self {
        Footprint { per_device: bytes }
    }

    /// Workspace-model footprint for a routine, mirroring the
    /// cuSOLVERMg workspace-size queries in [`workspace`], plus the
    /// block-cyclic tile-rounding slack: the layout stores whole tiles
    /// per device (up to `ceil(ntiles/ndev)·tile` columns), while the
    /// workspace formulas model `ceil(n/ndev)` flat columns, so each
    /// panel-shaped term is padded to dominate the real allocation.
    pub fn for_routine(
        routine: &str,
        n: usize,
        nrhs: usize,
        tile: usize,
        ndev: usize,
        dtype: DType,
    ) -> Result<Self> {
        let (bytes, panel_terms) = match routine {
            // Factor-only: the potrs working set minus the replicated
            // RHS (`nrhs` is ignored).
            "potrf" => (workspace::potrs_bytes(n, 0, tile, ndev, dtype), 1),
            "potrs" => (workspace::potrs_bytes(n, nrhs, tile, ndev, dtype), 1),
            "potri" => (workspace::potri_bytes(n, tile, ndev, dtype), 2),
            "syevd" => (workspace::syevd_bytes(n, tile, ndev, dtype), 4),
            other => return Err(Error::config(format!("unknown routine {other:?}"))),
        };
        let t = tile.max(1);
        let d = ndev.max(1);
        let cols_flat = n.div_ceil(d);
        let cols_tiled = n.div_ceil(t).div_ceil(d) * t;
        let slack = panel_terms * n * cols_tiled.saturating_sub(cols_flat) * dtype.size_of();
        Ok(Self::uniform(ndev, bytes + slack))
    }

    /// Workspace-model footprint for a routine over a **2D tile grid**
    /// ([`crate::layout::BlockCyclic2D`]): the matrix term uses each
    /// device's *exact* `local_rows × local_cols` shard (ragged edge
    /// tiles included), so per-device reservations differ across the
    /// grid instead of assuming the flat `n·ceil(n/ndev)` column shard.
    /// Scratch terms mirror [`Footprint::for_routine`]: `panel_terms`
    /// broadcast panels of `n × tile_c` plus the replicated RHS.
    pub fn for_grid(
        routine: &str,
        lay: &crate::layout::BlockCyclic2D,
        nrhs: usize,
        dtype: DType,
    ) -> Result<Self> {
        use crate::layout::MatrixLayout;
        let (matrix_copies, panel_terms) = match routine {
            "potrf" => (1usize, 1usize),
            "potrs" => (1, 1),
            "potri" => (2, 2),
            // matrix + eigenvector matrix + 2× back-transform scratch.
            "syevd" => (4, 4),
            other => return Err(Error::config(format!("unknown routine {other:?}"))),
        };
        let e = dtype.size_of();
        let (_, n) = lay.shape();
        let panel = panel_terms * n * lay.tile_c() * e;
        let rhs = if routine == "potrs" { n * nrhs * e } else { 0 };
        let per_device = (0..lay.num_devices())
            .map(|d| matrix_copies * lay.local_elems(d) * e + panel + rhs)
            .collect();
        Ok(Self::per_device(per_device))
    }

    /// Footprint of one coalesced **pod** of small solves: `dims[i]`
    /// is system `i`'s `(n, nrhs)`, placed by the same
    /// [`TileDim::round_robin`] deal [`crate::batch::PackedPod`] uses
    /// for the actual arenas. Per-device bytes are the *exact* arena
    /// sizes — each system's matrix plus, for `potrs`, its RHS pod
    /// entry; the sweeps run in place, so there is no broadcast-panel
    /// or workspace term to pad for.
    pub fn for_pod(
        routine: &str,
        dims: &[(usize, usize)],
        ndev: usize,
        dtype: DType,
    ) -> Result<Self> {
        let with_rhs = match routine {
            "potrf" | "potri" => false,
            "potrs" => true,
            other => return Err(Error::config(format!("unknown routine {other:?}"))),
        };
        let deal = TileDim::round_robin(dims.len(), ndev)?;
        let e = dtype.size_of();
        let mut per_device = vec![0usize; ndev];
        for (i, &(n, nrhs)) in dims.iter().enumerate() {
            per_device[deal.owner(i)] += n * n * e + if with_rhs { n * nrhs * e } else { 0 };
        }
        Ok(Self::per_device(per_device))
    }

    /// Number of devices covered.
    pub fn devices(&self) -> usize {
        self.per_device.len()
    }

    /// Bytes reserved on device `d`.
    pub fn bytes(&self, d: usize) -> usize {
        self.per_device[d]
    }

    /// All per-device byte counts.
    pub fn as_slice(&self) -> &[usize] {
        &self.per_device
    }

    /// Consume into the raw per-device byte vector.
    pub(crate) fn into_per_device(self) -> Vec<usize> {
        self.per_device
    }
}

/// Memoized grid-shape selections. [`Predictor::best_grid`] replays
/// full `O(nt²)`–`O(nt³)` schedules per candidate factorization, so
/// the serving fronts cache the chosen shape per
/// `(routine, dtype, n, nrhs, tile, ndev)` — repeat traffic (the
/// serving common case) pays one map lookup on the dispatch path
/// instead of re-running the replays. Forced grids bypass the cache
/// (they cost nothing to "select"), and `ndev` is part of the key so a
/// shrunk MPMD live set re-plans correctly.
#[derive(Debug, Default)]
pub struct GridPlanCache {
    shapes: Mutex<HashMap<(&'static str, DType, usize, usize, usize, usize), (usize, usize)>>,
}

impl GridPlanCache {
    /// Fresh empty cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// [`plan_dist`] with the selector memoized.
    #[allow(clippy::too_many_arguments)]
    pub fn plan(
        &self,
        routine: &'static str,
        n: usize,
        nrhs: usize,
        tile: usize,
        ndev: usize,
        dtype: DType,
        model: &GpuCostModel,
        topo: &NodeTopology,
        force: Option<(usize, usize)>,
    ) -> Result<DistPlan> {
        if force.is_some() {
            return plan_dist(routine, n, nrhs, tile, ndev, dtype, model, topo, force);
        }
        let key = (routine, dtype, n, nrhs, tile, ndev);
        let cached = self.shapes.lock().unwrap().get(&key).copied();
        if let Some(g) = cached {
            return plan_dist(routine, n, nrhs, tile, ndev, dtype, model, topo, Some(g));
        }
        let plan = plan_dist(routine, n, nrhs, tile, ndev, dtype, model, topo, None)?;
        self.shapes.lock().unwrap().insert(key, plan.grid);
        Ok(plan)
    }
}

/// A single device's reservation accountant — the per-worker half of
/// admission in MPMD mode, where each one-process-per-GPU worker admits
/// solves against **its own** device's VRAM capacity instead of a
/// central accountant seeing the whole node.
#[derive(Debug)]
pub struct DeviceAdmission {
    device: usize,
    capacity: usize,
    state: Mutex<AdmissionState>,
}

#[derive(Debug, Default)]
struct AdmissionState {
    reserved: usize,
    peak: usize,
}

impl DeviceAdmission {
    /// Accountant for device `device` with `capacity` bytes of VRAM.
    pub fn new(device: usize, capacity: usize) -> Self {
        DeviceAdmission { device, capacity, state: Mutex::new(AdmissionState::default()) }
    }

    /// The device this accountant guards.
    pub fn device(&self) -> usize {
        self.device
    }

    /// Total VRAM capacity admitted against.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Reserve `bytes`, failing with [`Error::DeviceOom`] when the
    /// reservation would exceed capacity (non-blocking; the caller owns
    /// the retry policy).
    pub fn try_reserve(&self, bytes: usize) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        if st.reserved + bytes > self.capacity {
            return Err(Error::DeviceOom {
                device: self.device,
                requested: bytes,
                free: self.capacity - st.reserved,
                capacity: self.capacity,
            });
        }
        st.reserved += bytes;
        if st.reserved > st.peak {
            st.peak = st.reserved;
        }
        Ok(())
    }

    /// Release a prior reservation.
    pub fn release(&self, bytes: usize) {
        let mut st = self.state.lock().unwrap();
        st.reserved = st.reserved.saturating_sub(bytes);
    }

    /// Currently reserved bytes.
    pub fn reserved(&self) -> usize {
        self.state.lock().unwrap().reserved
    }

    /// High-water mark of reserved bytes — the proof the worker never
    /// over-admitted its device.
    pub fn peak_reserved(&self) -> usize {
        self.state.lock().unwrap().peak
    }
}

/// Per-solve service metrics, returned with the result.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct SolveStats {
    /// Real time spent queued before the accountant admitted the solve.
    pub queue_wait: Duration,
    /// Real execution time after admission.
    pub exec: Duration,
    /// Solves that shared this solve's admitted job — the coalesced
    /// bucket occupancy on the batched small-solve path, `1` otherwise.
    pub batch_size: usize,
    /// Cost-model (simulated) nanoseconds this solve dwelled in the
    /// coalescer before its bucket flushed; `0` off the batched path.
    pub coalesce_wait_ns: u64,
    /// The `(P, Q)` process grid the solve executed on: `(1, ndev)`
    /// for 1D distributed solves, the selector's shape for grid-native
    /// ones, `(1, 1)` for single-device / batched-pod work.
    pub grid: (usize, usize),
}

/// `Ok((result, stats))`, or the panic message of a solve that
/// unwound inside a worker.
pub(crate) type SolveOutcome<T> = std::result::Result<(T, SolveStats), String>;

/// The shared completion slot a [`ServiceHandle`] waits on.
pub(crate) type Slot<T> = Arc<(Mutex<Option<SolveOutcome<T>>>, Condvar)>;

/// A fresh handle plus the slot its producer publishes into.
pub(crate) fn handle_pair<T>() -> (ServiceHandle<T>, Slot<T>) {
    let slot: Slot<T> = Arc::new((Mutex::new(None), Condvar::new()));
    (ServiceHandle { slot: slot.clone() }, slot)
}

/// Publish one solve's outcome and wake its waiter.
pub(crate) fn publish_one<T>(slot: &Slot<T>, outcome: SolveOutcome<T>) {
    let (lock, cv) = &**slot;
    *lock.lock().unwrap() = Some(outcome);
    cv.notify_all();
}

/// Publish the same failure to a whole batch of waiters.
pub(crate) fn publish_failure<T>(slots: &[Slot<T>], msg: String) {
    for slot in slots {
        publish_one(slot, Err(msg.clone()));
    }
}

/// Render a caught panic payload as the message re-raised on waiters.
pub(crate) fn panic_message(p: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Completion handle for a service solve: the result plus its stats.
pub struct ServiceHandle<T> {
    pub(crate) slot: Slot<T>,
}

impl<T> ServiceHandle<T> {
    /// Block until the solve completes; returns `(result, stats)`.
    /// Re-raises the solve's panic if it unwound inside a worker
    /// (the worker itself survives and the reservation is released).
    pub fn wait(self) -> (T, SolveStats) {
        let (lock, cv) = &*self.slot;
        let mut guard = lock.lock().unwrap();
        loop {
            if let Some(v) = guard.take() {
                drop(guard);
                match v {
                    Ok(out) => return out,
                    Err(msg) => panic!("service solve panicked: {msg}"),
                }
            }
            guard = cv.wait(guard).unwrap();
        }
    }

    /// Non-blocking readiness check.
    pub fn is_ready(&self) -> bool {
        self.slot.0.lock().unwrap().is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn device_admission_reserves_and_releases() {
        let adm = DeviceAdmission::new(3, 1000);
        assert_eq!(adm.capacity(), 1000);
        assert_eq!(adm.device(), 3);
        adm.try_reserve(600).unwrap();
        match adm.try_reserve(500) {
            Err(Error::DeviceOom { device, requested, free, capacity }) => {
                assert_eq!(device, 3);
                assert_eq!(requested, 500);
                assert_eq!(free, 400);
                assert_eq!(capacity, 1000);
            }
            other => panic!("expected OOM, got {other:?}"),
        }
        adm.try_reserve(400).unwrap();
        assert_eq!(adm.reserved(), 1000);
        adm.release(600);
        assert_eq!(adm.reserved(), 400);
        assert_eq!(adm.peak_reserved(), 1000);
        // Releasing more than reserved saturates instead of wrapping.
        adm.release(10_000);
        assert_eq!(adm.reserved(), 0);
    }

    #[test]
    fn plan_dist_respects_force_and_small_shapes_stay_1d() {
        use crate::layout::MatrixLayout;
        let model = GpuCostModel::h200();
        let topo = NodeTopology::nvlink_all_to_all(4);
        // Small solve: autotuner keeps the 1D layout.
        let p1 = plan_dist("potrs", 192, 1, 32, 4, DType::F64, &model, &topo, None).unwrap();
        assert_eq!(p1.grid, (1, 4));
        assert!(matches!(p1.kind, LayoutKind::BlockCyclic(_)));
        // Forced 2x2: grid layout + exact 2D shard footprint.
        let p2 = plan_dist("potrs", 192, 1, 32, 4, DType::F64, &model, &topo, Some((2, 2))).unwrap();
        assert_eq!(p2.grid, (2, 2));
        match p2.kind {
            LayoutKind::Grid(g) => {
                assert_eq!(g.grid(), (2, 2));
                assert_eq!(g.tile_shape(), (32, 32));
                assert_eq!(
                    p2.footprint,
                    Footprint::for_grid("potrs", &g, 1, DType::F64).unwrap()
                );
            }
            other => panic!("expected a grid layout, got {other:?}"),
        }
        // Paper scale: the autotuner goes 2D on its own.
        let p3 = plan_dist("potrf", 16384, 0, 256, 4, DType::F64, &model, &topo, None).unwrap();
        assert!(p3.grid.0 > 1, "paper-scale plan stayed 1D: {:?}", p3.grid);
        // A grid that does not cover the device count is rejected.
        assert!(plan_dist("potrf", 64, 0, 8, 4, DType::F64, &model, &topo, Some((3, 2))).is_err());
        assert_eq!(DistRoutine::Syevd.name(), "syevd");
    }

    #[test]
    fn grid_plan_cache_memoizes_the_selector() {
        let model = GpuCostModel::h200();
        let topo = NodeTopology::nvlink_all_to_all(4);
        let cache = GridPlanCache::new();
        let a = cache.plan("potrs", 192, 1, 32, 4, DType::F64, &model, &topo, None).unwrap();
        let b = cache.plan("potrs", 192, 1, 32, 4, DType::F64, &model, &topo, None).unwrap();
        assert_eq!(a.grid, b.grid);
        assert_eq!(a.kind, b.kind);
        assert_eq!(a.footprint, b.footprint);
        // The memo matches the uncached planner exactly.
        let fresh = plan_dist("potrs", 192, 1, 32, 4, DType::F64, &model, &topo, None).unwrap();
        assert_eq!(b.grid, fresh.grid);
        // A different live-set size is a different key.
        let topo3 = NodeTopology::nvlink_all_to_all(3);
        let c = cache.plan("potrs", 192, 1, 32, 3, DType::F64, &model, &topo3, None).unwrap();
        assert_eq!(c.grid.0 * c.grid.1, 3);
        // Forced grids bypass the memo.
        let f = cache.plan("potrs", 192, 1, 32, 4, DType::F64, &model, &topo, Some((2, 2))).unwrap();
        assert_eq!(f.grid, (2, 2));
    }

    #[test]
    fn handle_pair_roundtrip() {
        let (h, slot) = handle_pair::<u32>();
        assert!(!h.is_ready());
        let stats = SolveStats {
            queue_wait: Duration::ZERO,
            exec: Duration::ZERO,
            batch_size: 1,
            coalesce_wait_ns: 0,
            grid: (1, 1),
        };
        publish_one(&slot, Ok((7, stats)));
        assert!(h.is_ready());
        assert_eq!(h.wait().0, 7);
    }
}
