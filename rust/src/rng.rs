//! Deterministic RNG utilities.
//!
//! The vendored crate set has no `rand`/`proptest`, so we carry a small
//! splitmix64-seeded xoshiro256** generator. It backs workload
//! generation (random SPD matrices), the property-test harness in
//! `rust/tests/`, and benchmark inputs — all fully reproducible from a
//! 64-bit seed.

use crate::scalar::{Complex, RealScalar, Scalar};

/// xoshiro256** PRNG, seeded via splitmix64 (Blackman & Vigna).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create from a 64-bit seed. Identical seeds give identical streams.
    pub fn new(seed: u64) -> Self {
        // splitmix64 to fill the state; avoids the all-zero state.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Next raw 64 bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, bound) (Lemire's method, bound > 0).
    #[inline]
    pub fn next_below(&mut self, bound: usize) -> usize {
        assert!(bound > 0);
        (self.next_u64() % bound as u64) as usize
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.next_below(hi - lo + 1)
    }

    /// Uniform in [-1, 1).
    #[inline]
    pub fn next_signed(&mut self) -> f64 {
        2.0 * self.next_f64() - 1.0
    }

    /// A random scalar with entries uniform in [-1, 1) (per plane for complex).
    pub fn scalar<S: Scalar>(&mut self) -> S {
        S::from_parts(
            <S::Real as RealScalar>::from_f64(self.next_signed()),
            <S::Real as RealScalar>::from_f64(self.next_signed()),
        )
    }

    /// Fill a slice with random scalars.
    pub fn fill<S: Scalar>(&mut self, buf: &mut [S]) {
        for v in buf.iter_mut() {
            *v = self.scalar();
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Random complex on the unit circle (used for Hermitian test matrices).
    pub fn unit_phase<T: RealScalar>(&mut self) -> Complex<T> {
        let theta = self.next_f64() * std::f64::consts::TAU;
        Complex::new(T::from_f64(theta.cos()), T::from_f64(theta.sin()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn permutation_is_bijection() {
        let mut r = Rng::new(3);
        let p = r.permutation(257);
        let mut seen = vec![false; 257];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..500 {
            let v = r.range(3, 9);
            assert!((3..=9).contains(&v));
        }
        assert_eq!(r.range(5, 5), 5);
    }

    #[test]
    fn complex_scalar_has_imag() {
        let mut r = Rng::new(11);
        let z: crate::scalar::c64 = r.scalar();
        // overwhelmingly likely nonzero
        assert!(z.im != 0.0 || z.re != 0.0);
        let x: f64 = r.scalar();
        assert!((-1.0..1.0).contains(&x));
    }

    #[test]
    fn unit_phase_on_circle() {
        let mut r = Rng::new(13);
        for _ in 0..100 {
            let z = r.unit_phase::<f64>();
            assert!((z.norm_sqr() - 1.0).abs() < 1e-12);
        }
    }
}
