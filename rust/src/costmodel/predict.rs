//! Analytic replay of the distributed algorithms at paper scale.
//!
//! The simulator executes real data movement, so it cannot reach the
//! paper's N = 524 288. This module replays the *schedule* of each
//! solver — same tile loops, same per-device clocks, same cost model —
//! without touching data, which evaluates in microseconds at any N.
//! The benches use it to regenerate the full Fig. 3 curves; its
//! correctness anchor is `tests in this module` + the benches, which
//! check it against the simulator's projected time at small N (same
//! code path constants, so they agree by construction).

use super::GpuCostModel;
use crate::device::NodeTopology;
use crate::layout::BlockCyclic1D;
use crate::scalar::DType;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

/// Memo key for the planner-facing replay entry points. The model and
/// topology enter as fingerprints (f64 fields have no `Hash`), the
/// routine as a dense code, and `kind` separates the three cached
/// shapes so their value tuples can share one table.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
struct MemoKey {
    model: u64,
    topo: u64,
    dtype: DType,
    routine: u8,
    kind: u8,
    n: usize,
    nrhs: usize,
    t: usize,
    ndev: usize,
    p: usize,
    q: usize,
}

const MEMO_BEST_GRID: u8 = 0;
const MEMO_FABRIC_PLAN: u8 = 1;
const MEMO_RECOMPUTE_NS: u8 = 2;

/// Upper bound on the replay memo: a long-lived service sweeping many
/// (model, topo, shape) combinations must not grow it without bound.
/// On overflow the whole table is dropped — misses then refill the
/// live working set, which is the cheap epoch-style eviction a pure
/// cache can afford (every entry is recomputable).
const MEMO_CAP: usize = 1 << 16;

/// Safety margin over the κ·ε_f64 residual floor below which
/// [`Predictor::est_refine_iters`] refuses to route Mixed.
const REFINE_FLOOR_SAFETY: f64 = 4.0;

static PLAN_MEMO: OnceLock<Mutex<HashMap<MemoKey, (u64, u64, u64)>>> = OnceLock::new();
static MEMO_HITS: AtomicU64 = AtomicU64::new(0);
static MEMO_MISSES: AtomicU64 = AtomicU64::new(0);

fn routine_code(routine: &str) -> Option<u8> {
    match routine {
        "potrf" => Some(0),
        "potrs" => Some(1),
        "potri" => Some(2),
        "syevd" => Some(3),
        _ => None,
    }
}

fn model_sig(m: &GpuCostModel) -> u64 {
    const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const FNV_PRIME: u64 = 0x100_0000_01b3;
    let mut h = FNV_OFFSET;
    for v in [
        m.f32_flops,
        m.f64_flops,
        m.panel_efficiency,
        m.blas2_bytes_per_s,
        m.launch_overhead,
        m.ipc_export_s,
        m.ipc_open_s,
    ] {
        for b in v.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
    }
    h
}

fn memo_lookup(key: &MemoKey) -> Option<(u64, u64, u64)> {
    let memo = PLAN_MEMO.get_or_init(|| Mutex::new(HashMap::new()));
    let found = memo.lock().unwrap_or_else(|e| e.into_inner()).get(key).copied();
    match found {
        Some(v) => {
            MEMO_HITS.fetch_add(1, Ordering::Relaxed);
            Some(v)
        }
        None => {
            MEMO_MISSES.fetch_add(1, Ordering::Relaxed);
            None
        }
    }
}

fn memo_store(key: MemoKey, val: (u64, u64, u64)) {
    let memo = PLAN_MEMO.get_or_init(|| Mutex::new(HashMap::new()));
    let mut map = memo.lock().unwrap_or_else(|e| e.into_inner());
    if map.len() >= MEMO_CAP {
        map.clear();
    }
    map.insert(key, val);
}

/// `(hits, misses)` of the process-wide replay memo — the counters the
/// planner satellites assert on (a repeat submission must hit, not
/// re-replay both fabric arms).
pub fn plan_memo_stats() -> (u64, u64) {
    (MEMO_HITS.load(Ordering::Relaxed), MEMO_MISSES.load(Ordering::Relaxed))
}

/// Per-device analytic clocks.
struct Clocks {
    t: Vec<f64>,
}

impl Clocks {
    fn new(n: usize) -> Self {
        Clocks { t: vec![0.0; n] }
    }
    fn advance(&mut self, d: usize, dt: f64) {
        self.t[d] += dt;
    }
    fn sync(&mut self, to: usize, from: usize) {
        if self.t[to] < self.t[from] {
            self.t[to] = self.t[from];
        }
    }
    fn max(&self) -> f64 {
        self.t.iter().cloned().fold(0.0, f64::max)
    }
}

/// Configuration for a prediction.
#[derive(Clone, Debug)]
pub struct Predictor {
    pub model: GpuCostModel,
    pub topo: NodeTopology,
    pub dtype: DType,
}

impl Predictor {
    /// H200 node predictor, matching `SimNode::new_uniform` defaults.
    pub fn h200(ndev: usize, dtype: DType) -> Self {
        Predictor {
            model: GpuCostModel::h200(),
            topo: NodeTopology::nvlink_all_to_all(ndev),
            dtype,
        }
    }

    /// Two-tier fabric predictor: `islands × per_island` H200 devices
    /// joined by the inter-node interconnect
    /// ([`NodeTopology::two_tier`]) — the replay twin of
    /// `fabric::Fabric::h200`.
    pub fn fabric(islands: usize, per_island: usize, dtype: DType) -> Self {
        Predictor {
            model: GpuCostModel::h200(),
            topo: NodeTopology::two_tier(islands, per_island),
            dtype,
        }
    }

    fn esize(&self) -> usize {
        self.dtype.size_of()
    }

    /// Number of distinct islands among devices `0..ndev` (1 on a flat
    /// node — the gate every fabric pricing term hides behind, keeping
    /// the flat replays bitwise the historical arithmetic).
    fn islands_spanned(&self, ndev: usize) -> usize {
        let nd = ndev.min(self.topo.num_devices());
        let mut seen: Vec<usize> = Vec::new();
        for d in 0..nd {
            let isl = self.topo.island_of(d);
            if !seen.contains(&isl) {
                seen.push(isl);
            }
        }
        seen.len().max(1)
    }

    /// First cross-island device pair within `0..ndev`, if any.
    fn cross_pair(&self, ndev: usize) -> Option<(usize, usize)> {
        let nd = ndev.min(self.topo.num_devices());
        (1..nd)
            .find(|&d| self.topo.island_of(d) != self.topo.island_of(0))
            .map(|d| (0, d))
    }

    /// Representative link time for a devices-wide collective over
    /// `0..ndev`: the inter-node link when the span crosses islands
    /// (the fabric's shared pipe bounds every such step), otherwise
    /// bitwise `copy_time(0, 1, bytes)` — the flat formula.
    fn step_link_time(&self, ndev: usize, bytes: usize) -> f64 {
        match self.cross_pair(ndev) {
            Some((i, j)) => self.topo.copy_time(i, j, bytes),
            None => self.topo.copy_time(0, 1, bytes),
        }
    }

    /// Does any grid row group (`q` consecutive devices under the
    /// row-major `dev(r, c) = r·q + c` map) straddle an island
    /// boundary? Row-group collectives stay island-local exactly when
    /// `q` divides the island width — the alignment
    /// [`Predictor::best_grid`] rewards on a fabric.
    fn row_groups_cross(&self, p: usize, q: usize) -> bool {
        let nd = self.topo.num_devices();
        (0..p).any(|r| {
            (1..q).any(|c| {
                let a = r * q;
                let b = r * q + c;
                a < nd && b < nd && self.topo.island_of(a) != self.topo.island_of(b)
            })
        })
    }

    /// Barrier ring-broadcast replay: the exact arithmetic of the
    /// simulator's barrier group broadcast, on analytic clocks. A flat
    /// span pays per-receiver link shares serialized on the sender
    /// (`concurrent == 1` is bitwise the historical
    /// `copy_time / recv` form); a span crossing islands runs the
    /// hierarchical ring-of-rings — one representative per remote
    /// island crosses the inter-node link at full (contended) cost,
    /// the home island takes flat shares, then each remote island
    /// fans out in parallel on its representative's clock.
    fn ring_bcast_replay(
        &self,
        clk: &mut Clocks,
        from: usize,
        members: &[usize],
        bytes: usize,
        concurrent: usize,
    ) {
        let recv = members.iter().filter(|&&d| d != from).count();
        if recv == 0 || bytes == 0 {
            return;
        }
        let mut locals: Vec<usize> = Vec::new();
        let mut remotes: Vec<(usize, Vec<usize>)> = Vec::new();
        if self.topo.num_islands() > 1 {
            let home = self.topo.island_of(from);
            let mut islands: Vec<usize> = Vec::new();
            for &d in members {
                if d == from {
                    continue;
                }
                let isl = self.topo.island_of(d);
                if isl == home {
                    locals.push(d);
                } else {
                    match islands.iter().position(|&x| x == isl) {
                        Some(i) => remotes[i].1.push(d),
                        None => {
                            islands.push(isl);
                            remotes.push((d, Vec::new()));
                        }
                    }
                }
            }
        }
        if remotes.is_empty() {
            for &d in members {
                if d == from {
                    continue;
                }
                clk.advance(from, self.topo.ring_share_time(from, d, bytes, recv, concurrent));
                clk.sync(d, from);
            }
            return;
        }
        for (rep, _) in &remotes {
            clk.advance(from, self.topo.contended_time(from, *rep, bytes, concurrent));
            clk.sync(*rep, from);
        }
        for &d in &locals {
            clk.advance(from, self.topo.ring_share_time(from, d, bytes, locals.len(), concurrent));
            clk.sync(d, from);
        }
        for (rep, rest) in &remotes {
            for &d in rest {
                clk.advance(*rep, self.topo.ring_share_time(*rep, d, bytes, rest.len(), concurrent));
                clk.sync(d, *rep);
            }
        }
    }

    /// §2.1 redistribution: every column moves once, peer-to-peer.
    pub fn redistribute(&self, n: usize, ndev: usize) -> f64 {
        if ndev <= 1 {
            return 0.0;
        }
        let col_bytes = n * self.esize();
        // ~ (ndev-1)/ndev of columns cross devices; staging doubles the
        // copy count (save + forward per slot).
        let moves = 2.0 * n as f64 * (ndev as f64 - 1.0) / ndev as f64;
        let per_link = moves / ndev as f64; // links run in parallel
        match self.cross_pair(ndev) {
            Some((i, j)) => {
                // Columns target devices uniformly, so on a span of
                // `s` islands (s-1)/s of the moves cross the fabric.
                let s = self.islands_spanned(ndev) as f64;
                let cf = (s - 1.0) / s;
                per_link
                    * ((1.0 - cf) * self.topo.copy_time(0, 1, col_bytes)
                        + cf * self.topo.copy_time(i, j, col_bytes))
            }
            None => per_link * self.topo.copy_time(0, 1, col_bytes),
        }
    }

    /// Distributed right-looking Cholesky (the potrf schedule).
    pub fn potrf(&self, n: usize, t: usize, ndev: usize) -> f64 {
        let lay = BlockCyclic1D::new(n, t, ndev).unwrap();
        let mut clk = Clocks::new(ndev);
        let ntiles = lay.num_tiles();
        for tt in 0..ntiles {
            let owner = lay.owner_of_tile(tt);
            let tk = lay.tile_cols(tt);
            let k1 = lay.tile_start(tt) + tk;
            let below = n - k1;
            clk.advance(owner, self.model.panel_time(self.dtype, GpuCostModel::flops_potf2(self.dtype, tk)));
            if below == 0 {
                continue;
            }
            clk.advance(owner, self.model.panel_time(self.dtype, GpuCostModel::flops_trsm(self.dtype, below, tk, tk)));
            // Broadcast packed panel to the other devices
            // (hierarchical on a fabric; bitwise the flat per-receiver
            // `copy_time / (ndev-1)` shares on one island).
            let panel_bytes = below * tk * self.esize();
            let members: Vec<usize> = (0..ndev).collect();
            self.ring_bcast_replay(&mut clk, owner, &members, panel_bytes, 1);
            // Trailing updates in parallel across owners.
            for j in (tt + 1)..ntiles {
                let d = lay.owner_of_tile(j);
                let tj = lay.tile_cols(j);
                let height = n - lay.tile_start(j);
                clk.advance(d, self.model.gemm_time(self.dtype, height, tj, tk));
            }
            // Next step's owner waits for its own updates (same clock) —
            // nothing extra to sync.
        }
        clk.max()
    }

    /// Distributed right-looking Cholesky with k-step panel lookahead —
    /// the analytic replay of the stream schedule in
    /// `solver::potrf_dist` (compute/panel/copy horizons per device,
    /// same gating rules), which accounts for compute/copy overlap.
    /// `lookahead == 0` degenerates to the barrier replay
    /// ([`Predictor::potrf`]).
    pub fn potrf_lookahead(&self, n: usize, t: usize, ndev: usize, lookahead: usize) -> f64 {
        if lookahead == 0 {
            return self.potrf(n, t, ndev);
        }
        let lay = BlockCyclic1D::new(n, t, ndev).unwrap();
        let ntiles = lay.num_tiles();
        // Per-device stream horizons (seconds).
        let mut compute = vec![0.0f64; ndev];
        let mut panel = vec![0.0f64; ndev];
        let mut copys = vec![0.0f64; ndev];
        // Dataflow state mirroring potrf_dist's pipelined path.
        let mut col_updated = vec![0.0f64; ntiles];
        let mut step_done = vec![0.0f64; ntiles];
        for tt in 0..ntiles {
            let owner = lay.owner_of_tile(tt);
            let tk = lay.tile_cols(tt);
            let k1 = lay.tile_start(tt) + tk;
            let below = n - k1;
            // Panel ops on the priority stream, gated by the column's
            // last update and the lookahead depth.
            let mut nb = col_updated[tt];
            if tt > lookahead {
                nb = nb.max(step_done[tt - 1 - lookahead]);
            }
            let mut pd = panel[owner].max(nb)
                + self.model.panel_time(self.dtype, GpuCostModel::flops_potf2(self.dtype, tk));
            if below > 0 {
                pd += self
                    .model
                    .panel_time(self.dtype, GpuCostModel::flops_trsm(self.dtype, below, tk, tk));
            }
            panel[owner] = pd;
            if below == 0 || tt + 1 == ntiles {
                continue;
            }
            // Broadcast on the owner's copy stream, one full copy per
            // receiving device, gated on the panel completion.
            let panel_bytes = below * tk * self.esize();
            let mut needs = vec![false; ndev];
            for j in (tt + 1)..ntiles {
                needs[lay.owner_of_tile(j)] = true;
            }
            let mut recv = vec![0.0f64; ndev];
            for d in 0..ndev {
                if d == owner || !needs[d] {
                    continue;
                }
                copys[owner] =
                    copys[owner].max(pd) + self.topo.copy_time(owner, d, panel_bytes);
                recv[d] = copys[owner];
            }
            // Trailing updates on each owner's compute stream.
            let mut smax = 0.0f64;
            for j in (tt + 1)..ntiles {
                let d = lay.owner_of_tile(j);
                let tj = lay.tile_cols(j);
                let height = n - lay.tile_start(j);
                let dep = if d == owner { pd } else { recv[d] };
                let done = compute[d].max(dep).max(col_updated[j])
                    + self.model.gemm_time(self.dtype, height, tj, tk);
                compute[d] = done;
                col_updated[j] = done;
                if done > smax {
                    smax = done;
                }
            }
            step_done[tt] = smax;
        }
        let mut max = 0.0f64;
        for d in 0..ndev {
            max = max.max(compute[d]).max(panel[d]).max(copys[d]);
        }
        max
    }

    /// Pipelined two-sweep solve (the potrs schedule).
    pub fn potrs_solve(&self, n: usize, t: usize, ndev: usize, nrhs: usize) -> f64 {
        let lay = BlockCyclic1D::new(n, t, ndev).unwrap();
        let mut clk = Clocks::new(ndev);
        let ntiles = lay.num_tiles();
        for sweep in 0..2 {
            let tiles: Vec<usize> =
                if sweep == 0 { (0..ntiles).collect() } else { (0..ntiles).rev().collect() };
            for (i, &tt) in tiles.iter().enumerate() {
                let owner = lay.owner_of_tile(tt);
                let tk = lay.tile_cols(tt);
                let k1 = lay.tile_start(tt) + tk;
                let below = n - k1;
                clk.advance(owner, self.model.panel_time(self.dtype, GpuCostModel::flops_trsm(self.dtype, tk, nrhs, tk)));
                if below > 0 {
                    clk.advance(owner, self.model.gemm_time(self.dtype, below, nrhs, tk));
                }
                if i + 1 < tiles.len() {
                    let next = lay.owner_of_tile(tiles[i + 1]);
                    if next != owner {
                        let tail = (n - lay.tile_start(tt).min(k1)) * nrhs * self.esize();
                        clk.advance(owner, self.topo.copy_time(owner, next, tail));
                        clk.sync(next, owner);
                    }
                }
            }
        }
        clk.max()
    }

    /// Full potrs (factor + solve + §2.1 redistribution) — Fig. 3a.
    pub fn potrs(&self, n: usize, t: usize, ndev: usize, nrhs: usize) -> f64 {
        self.redistribute(n, ndev) + self.potrf(n, t, ndev) + self.potrs_solve(n, t, ndev, nrhs)
    }

    /// Distributed trtri + lauum (the potri schedule) — Fig. 3b.
    pub fn potri(&self, n: usize, t: usize, ndev: usize) -> f64 {
        let lay = BlockCyclic1D::new(n, t, ndev).unwrap();
        let ntiles = lay.num_tiles();
        let mut clk = Clocks::new(ndev);
        // Phase 1: trtri — one pipelined column sweep per column tile.
        for tt in 0..ntiles {
            let t_owner = lay.owner_of_tile(tt);
            let tk = lay.tile_cols(tt);
            for j in tt..ntiles {
                let j_owner = lay.owner_of_tile(j);
                let tj = lay.tile_cols(j);
                let j1 = lay.tile_start(j) + tj;
                let below = n - j1;
                clk.advance(j_owner, self.model.panel_time(self.dtype, GpuCostModel::flops_trsm(self.dtype, tj, tk, tj)));
                if j_owner != t_owner {
                    clk.advance(j_owner, self.topo.copy_time(j_owner, t_owner, tj * tk * self.esize()));
                    clk.sync(t_owner, j_owner);
                }
                if below > 0 {
                    clk.advance(j_owner, self.model.gemm_time(self.dtype, below, tk, tj));
                    let next = lay.owner_of_tile(j + 1);
                    if next != j_owner {
                        clk.advance(j_owner, self.topo.copy_time(j_owner, next, below * tk * self.esize()));
                        clk.sync(next, j_owner);
                    }
                }
            }
        }
        // Phase 2: lauum — panel broadcast per round + GEMMs everywhere.
        for ti in 0..ntiles {
            let i_owner = lay.owner_of_tile(ti);
            let tki = lay.tile_cols(ti);
            let k0i = lay.tile_start(ti);
            let pi_rows = n - k0i;
            let members: Vec<usize> = (0..ndev).collect();
            self.ring_bcast_replay(&mut clk, i_owner, &members, pi_rows * tki * self.esize(), 1);
            for tj in 0..ntiles {
                let j_owner = lay.owner_of_tile(tj);
                let tkj = lay.tile_cols(tj);
                let kmax = k0i.max(lay.tile_start(tj));
                clk.advance(j_owner, self.model.gemm_time(self.dtype, tki, tkj, n - kmax));
            }
        }
        self.redistribute(n, ndev) + self.potrf(n, t, ndev) + clk.max()
    }

    /// Distributed Householder + QL + back-transform (the syevd
    /// schedule) — Fig. 3c. Closed-form per-device sums instead of the
    /// O(n) loop (identical totals).
    pub fn syevd(&self, n: usize, t: usize, ndev: usize) -> f64 {
        let e = self.esize() as f64;
        let nf = n as f64;
        let lc = nf / ndev as f64; // balanced local columns
        let bw = self.model.blas2_bytes_per_s;
        let ov = self.model.launch_overhead;
        let steps = nf - 2.0;

        // Stage 1 per step: reflector broadcast (n·e bytes), distributed
        // matvec (n·lc·e bytes per device), reduce+broadcast (2n·e),
        // rank-2 update (2n·lc·e per device). Devices run in parallel.
        let per_step_compute = (3.0 * nf * lc * e) / bw + 3.0 * ov;
        let per_step_comm = 3.0 * self.step_link_time(ndev, n * self.esize());
        let stage1 = steps * (per_step_compute + per_step_comm);

        // Stage 2: QL with eigenvectors on the lead device, ~6n³
        // bandwidth-bound flops (T_A-independent — the Fig. 3c flatness).
        let stage2 = (6.0 * nf * nf * nf * e / 8.0) / bw / 8.0
            + self.step_link_time(ndev, (nf * lc) as usize * self.esize());

        // Stage 3: back-transform, 4n·lc flops per reflector per device.
        let stage3 = steps * ((4.0 * nf * lc * e / 8.0) / bw + ov / 64.0);

        let _ = t; // T_A does not enter: the reduction is unblocked (paper: "negligible impact for syevd")
        self.redistribute(n, ndev) + stage1 + stage2 + stage3
    }

    /// syevd on a `p × q` 2D block-cyclic grid — the §5 future-work
    /// replay. Per-device compute is identical to the 1D layout (blocks
    /// hold `n²/(p·q)` elements either way); what changes is the
    /// communication: the per-step Householder collectives (`u`
    /// broadcast, partial-`A·u` reduce, `w` fan-out) are born
    /// row-distributed, so their critical path carries `⌈n/p⌉`-long row
    /// segments through `p` parallel row groups on disjoint links
    /// instead of full length-`n` vectors through one owner. The
    /// back-transform's column-group dot-product reductions (`p > 1`
    /// only) amortize over `t`-wide reflector blocks (blocked WY
    /// application). `p = 1` reproduces [`Predictor::syevd`] exactly.
    pub fn syevd2d(&self, n: usize, t: usize, p: usize, q: usize) -> f64 {
        let e = self.esize() as f64;
        let nf = n as f64;
        let ndev = p * q;
        let lc = nf / ndev as f64; // balanced per-device block elems / n
        let bw = self.model.blas2_bytes_per_s;
        let ov = self.model.launch_overhead;
        let steps = nf - 2.0;

        // Stage 1: same three bandwidth-bound passes over each device's
        // block; collectives carry row segments. Row groups are `q`
        // consecutive devices, so when `q` divides the island width
        // they never touch the fabric — the island-alignment the
        // selector rewards; a straddling row group is bounded by the
        // inter-node pipe instead.
        let per_step_compute = (3.0 * nf * lc * e) / bw + 3.0 * ov;
        let seg_bytes = n.div_ceil(p) * self.esize();
        let per_step_comm = 3.0
            * if self.row_groups_cross(p, q) {
                self.step_link_time(ndev, seg_bytes)
            } else {
                self.topo.copy_time(0, 1, seg_bytes)
            };
        let stage1 = steps * (per_step_compute + per_step_comm);

        // Stage 2: lead-device QL, layout-independent (the gather
        // crosses the fabric when the grid spans islands).
        let stage2 = (6.0 * nf * nf * nf * e / 8.0) / bw / 8.0
            + self.step_link_time(ndev, (nf * lc) as usize * self.esize());

        // Stage 3: back-transform; the row split adds blocked
        // column-group reductions of the uᴴv partials. A column group
        // is one device per grid row — on a fabric its p−1 hops split
        // into intra-island hops plus one fabric crossing per extra
        // island spanned.
        let mut stage3 = steps * ((4.0 * nf * lc * e / 8.0) / bw + ov / 64.0);
        if p > 1 {
            let blocks = (nf / t.max(1) as f64).ceil();
            let bseg = n.div_ceil(q) * self.esize();
            let nd = self.topo.num_devices();
            let mut col_islands: Vec<usize> = Vec::new();
            for r in 0..p {
                if r * q < nd {
                    let isl = self.topo.island_of(r * q);
                    if !col_islands.contains(&isl) {
                        col_islands.push(isl);
                    }
                }
            }
            let s = col_islands.len().max(1);
            if s > 1 {
                let cross = self
                    .cross_pair(ndev)
                    .map(|(i, j)| self.topo.copy_time(i, j, bseg))
                    .unwrap_or_else(|| self.topo.copy_time(0, 1, bseg));
                stage3 += blocks
                    * ((p - s) as f64 * self.topo.copy_time(0, 1, bseg)
                        + (s - 1) as f64 * cross);
            } else {
                stage3 += blocks * (p - 1) as f64 * self.topo.copy_time(0, 1, bseg);
            }
        }

        self.redistribute(n, ndev) + stage1 + stage2 + stage3
    }

    // ---- 2D grid replays (the grid-native Cholesky stack) ---------------

    /// Distributed right-looking Cholesky on a `p × q` block-cyclic
    /// grid — the analytic replay of the grid-native
    /// `solver::potrf_dist` barrier schedule (same step structure:
    /// diagonal potf2, `L_tt` column ring, per-grid-row panel trsm,
    /// row/column panel rings, one fused local trailing GEMM per
    /// device per step). `p = 1` degenerates to the 1D formula
    /// [`Predictor::potrf`] **bitwise** (it returns it directly).
    pub fn potrf2d(&self, n: usize, t: usize, p: usize, q: usize) -> f64 {
        if p == 1 {
            return self.potrf(n, t, q);
        }
        let nt = n.div_ceil(t);
        let tile_len = |tt: usize| -> usize { t.min(n - tt * t) };
        let e = self.esize();
        let mut clk = Clocks::new(p * q);
        let dev = |r: usize, c: usize| r * q + c;
        for tt in 0..nt {
            let tk = tile_len(tt);
            let k1 = tt * t + tk;
            let rt = tt % p;
            let ct = tt % q;
            let diag = dev(rt, ct);
            clk.advance(diag, self.model.panel_time(self.dtype, GpuCostModel::flops_potf2(self.dtype, tk)));
            let below = n - k1;
            if below == 0 {
                continue;
            }
            let mut seg = vec![0usize; p];
            for j in (tt + 1)..nt {
                seg[j % p] += tile_len(j);
            }
            let mut cols_of = vec![0usize; q];
            for k in (tt + 1)..nt {
                cols_of[k % q] += tile_len(k);
            }
            // L_tt column ring to the panel's row owners
            // (hierarchical on a fabric, bitwise the flat shares on
            // one island).
            let members: Vec<usize> =
                (0..p).filter(|&r| r != rt && seg[r] > 0).map(|r| dev(r, ct)).collect();
            self.ring_bcast_replay(&mut clk, diag, &members, tk * tk * e, 1);
            // Panel trsm split across the P row owners.
            for r in 0..p {
                if seg[r] > 0 {
                    clk.advance(
                        dev(r, ct),
                        self.model
                            .panel_time(self.dtype, GpuCostModel::flops_trsm(self.dtype, seg[r], tk, tk)),
                    );
                }
            }
            // Row rings: solved segments move sideways.
            for r in 0..p {
                if seg[r] == 0 {
                    continue;
                }
                let src = dev(r, ct);
                let members: Vec<usize> =
                    (0..q).filter(|&c| c != ct && cols_of[c] > 0).map(|c| dev(r, c)).collect();
                if members.is_empty() {
                    continue;
                }
                let bytes = seg[r] * tk * e;
                self.ring_bcast_replay(&mut clk, src, &members, bytes, 1);
            }
            // Column rings: transposed panel blocks move down.
            for c in 0..q {
                if cols_of[c] == 0 {
                    continue;
                }
                let mut blk = vec![0usize; p];
                for k in (tt + 1)..nt {
                    if k % q == c {
                        blk[k % p] += tile_len(k);
                    }
                }
                // Contention: every source row with a nonzero block
                // broadcasts down this column at once, so each
                // receiver's link carries `conc` concurrent transfers
                // — the per-link sharing term tall grids (large P) pay
                // and wide grids do not. Mirrors the simulator's grid
                // potrf stage 5 exactly.
                let conc = blk.iter().filter(|&&b| b > 0).count();
                for (rs, &b) in blk.iter().enumerate() {
                    if b == 0 {
                        continue;
                    }
                    let src = dev(rs, c);
                    let members: Vec<usize> =
                        (0..p).filter(|&r| r != rs && seg[r] > 0).map(|r| dev(r, c)).collect();
                    if members.is_empty() {
                        continue;
                    }
                    let bytes = b * tk * e;
                    self.ring_bcast_replay(&mut clk, src, &members, bytes, conc);
                }
            }
            // Fused local trailing GEMMs, split lookahead-first (the
            // next panel column as its own launch) — mirroring the
            // grid-native solver's charge structure.
            let mut fl_next = vec![0u64; p * q];
            let mut fl_rest = vec![0u64; p * q];
            for j in (tt + 1)..nt {
                let r = j % p;
                for k in (tt + 1)..=j {
                    let f = GpuCostModel::flops_gemm(self.dtype, tile_len(j), tile_len(k), tk);
                    if k == tt + 1 {
                        fl_next[dev(r, k % q)] += f;
                    } else {
                        fl_rest[dev(r, k % q)] += f;
                    }
                }
            }
            let next_w = tile_len(tt + 1);
            let cnext = (tt + 1) % q;
            for r in 0..p {
                for c in 0..q {
                    let d = dev(r, c);
                    if fl_next[d] > 0 {
                        let util = GpuCostModel::gemm_utilization(tk.min(seg[r]).min(next_w));
                        clk.advance(d, self.model.launch_overhead + fl_next[d] as f64 / (self.model.rate(self.dtype) * util));
                    }
                    if fl_rest[d] > 0 {
                        let rest_w = cols_of[c] - if c == cnext { next_w } else { 0 };
                        let util = GpuCostModel::gemm_utilization(tk.min(seg[r]).min(rest_w));
                        clk.advance(d, self.model.launch_overhead + fl_rest[d] as f64 / (self.model.rate(self.dtype) * util));
                    }
                }
            }
        }
        clk.max()
    }

    /// Full potrs on a `p × q` grid (§2.1 redistribution + grid-native
    /// factor + grid-native two-sweep solve). `p = 1` degenerates to
    /// [`Predictor::potrs`] bitwise.
    pub fn potrs2d(&self, n: usize, t: usize, p: usize, q: usize, nrhs: usize) -> f64 {
        if p == 1 {
            return self.potrs(n, t, q, nrhs);
        }
        self.redistribute(n, p * q) + self.potrf2d(n, t, p, q) + self.potrs2d_solve(n, t, p, q, nrhs)
    }

    /// The grid-native two-sweep solve replay (row-split tail updates,
    /// column-ring solved-block broadcasts and partial reductions, row
    /// tail hand-offs).
    fn potrs2d_solve(&self, n: usize, t: usize, p: usize, q: usize, nrhs: usize) -> f64 {
        let nt = n.div_ceil(t);
        let tile_len = |tt: usize| -> usize { t.min(n - tt * t) };
        let e = self.esize();
        let mut clk = Clocks::new(p * q);
        let dev = |r: usize, c: usize| r * q + c;
        let seg_below = |tt: usize| -> Vec<usize> {
            let mut seg = vec![0usize; p];
            for j in (tt + 1)..nt {
                seg[j % p] += tile_len(j);
            }
            seg
        };
        // Forward sweep.
        for tt in 0..nt {
            let tk = tile_len(tt);
            let k1 = tt * t + tk;
            let rt = tt % p;
            let ct = tt % q;
            let diag = dev(rt, ct);
            clk.advance(diag, self.model.panel_time(self.dtype, GpuCostModel::flops_trsm(self.dtype, tk, nrhs, tk)));
            let below = n - k1;
            if below == 0 {
                continue;
            }
            let seg = seg_below(tt);
            let members: Vec<usize> =
                (0..p).filter(|&r| r != rt && seg[r] > 0).map(|r| dev(r, ct)).collect();
            self.ring_bcast_replay(&mut clk, diag, &members, tk * nrhs * e, 1);
            for r in 0..p {
                if seg[r] > 0 {
                    clk.advance(dev(r, ct), self.model.gemm_time(self.dtype, seg[r], nrhs, tk));
                }
            }
            let cn = (tt + 1) % q;
            if cn != ct {
                for r in 0..p {
                    if seg[r] > 0 {
                        clk.advance(dev(r, ct), self.topo.copy_time(dev(r, ct), dev(r, cn), seg[r] * nrhs * e));
                        clk.sync(dev(r, cn), dev(r, ct));
                    }
                }
            }
        }
        // Backward sweep.
        for tt in (0..nt).rev() {
            let tk = tile_len(tt);
            let k1 = tt * t + tk;
            let rt = tt % p;
            let ct = tt % q;
            let diag = dev(rt, ct);
            let below = n - k1;
            if below > 0 {
                let seg = seg_below(tt);
                for r in 0..p {
                    if seg[r] > 0 {
                        clk.advance(dev(r, ct), self.model.gemm_time(self.dtype, tk, nrhs, seg[r]));
                    }
                }
                for r in 0..p {
                    if r != rt && seg[r] > 0 {
                        clk.advance(dev(r, ct), self.topo.copy_time(dev(r, ct), diag, tk * nrhs * e));
                        clk.sync(diag, dev(r, ct));
                    }
                }
            }
            clk.advance(diag, self.model.panel_time(self.dtype, GpuCostModel::flops_trsm(self.dtype, tk, nrhs, tk)));
            if tt > 0 {
                let cprev = (tt - 1) % q;
                if cprev != ct {
                    let mut rows_ge = vec![0usize; p];
                    for j in tt..nt {
                        rows_ge[j % p] += tile_len(j);
                    }
                    for r in 0..p {
                        if rows_ge[r] > 0 {
                            clk.advance(dev(r, ct), self.topo.copy_time(dev(r, ct), dev(r, cprev), rows_ge[r] * nrhs * e));
                            clk.sync(dev(r, cprev), dev(r, ct));
                        }
                    }
                }
            }
        }
        clk.max()
    }

    /// Full potri on a `p × q` grid (§2.1 redistribution + grid-native
    /// factor + grid-native trtri/lauum replay: row-split column
    /// pipelines, row-ring lauum panel segments, column-ring partial
    /// reductions). `p = 1` degenerates to [`Predictor::potri`]
    /// bitwise.
    pub fn potri2d(&self, n: usize, t: usize, p: usize, q: usize) -> f64 {
        if p == 1 {
            return self.potri(n, t, q);
        }
        let nt = n.div_ceil(t);
        let tile_len = |tt: usize| -> usize { t.min(n - tt * t) };
        let e = self.esize();
        let mut clk = Clocks::new(p * q);
        let dev = |r: usize, c: usize| r * q + c;
        // Phase 1: trtri column pipelines.
        for tt in 0..nt {
            let tk = tile_len(tt);
            let ct = tt % q;
            for j in tt..nt {
                let tj = tile_len(j);
                let j1 = j * t + tj;
                let rj = j % p;
                let cj = j % q;
                let djj = dev(rj, cj);
                clk.advance(djj, self.model.panel_time(self.dtype, GpuCostModel::flops_trsm(self.dtype, tj, tk, tj)));
                let x_owner = dev(rj, ct);
                if x_owner != djj {
                    clk.advance(djj, self.topo.copy_time(djj, x_owner, tj * tk * e));
                    clk.sync(x_owner, djj);
                }
                let below = n - j1;
                if below > 0 {
                    let mut segb = vec![0usize; p];
                    for jj in (j + 1)..nt {
                        segb[jj % p] += tile_len(jj);
                    }
                    let members: Vec<usize> =
                        (0..p).filter(|&r| r != rj && segb[r] > 0).map(|r| dev(r, cj)).collect();
                    self.ring_bcast_replay(&mut clk, djj, &members, tj * tk * e, 1);
                    for r in 0..p {
                        if segb[r] > 0 {
                            clk.advance(dev(r, cj), self.model.gemm_time(self.dtype, segb[r], tk, tj));
                        }
                    }
                    let cnext = (j + 1) % q;
                    if cnext != cj {
                        for r in 0..p {
                            if segb[r] > 0 {
                                clk.advance(dev(r, cj), self.topo.copy_time(dev(r, cj), dev(r, cnext), segb[r] * tk * e));
                                clk.sync(dev(r, cnext), dev(r, cj));
                            }
                        }
                    }
                }
            }
        }
        // Phase 2: lauum rounds.
        for ti in 0..nt {
            let tki = tile_len(ti);
            let ri = ti % p;
            let ci = ti % q;
            let mut segi = vec![0usize; p];
            for j in ti..nt {
                segi[j % p] += tile_len(j);
            }
            for r in 0..p {
                if segi[r] == 0 {
                    continue;
                }
                let members: Vec<usize> = (0..q).filter(|&c| c != ci).map(|c| dev(r, c)).collect();
                if members.is_empty() {
                    continue;
                }
                self.ring_bcast_replay(&mut clk, dev(r, ci), &members, segi[r] * tki * e, 1);
            }
            for tj in 0..nt {
                let tkj = tile_len(tj);
                let cj = tj % q;
                let tmax = ti.max(tj);
                let mut segm = vec![0usize; p];
                for jj in tmax..nt {
                    segm[jj % p] += tile_len(jj);
                }
                for r in 0..p {
                    if segm[r] > 0 {
                        clk.advance(dev(r, cj), self.model.gemm_time(self.dtype, tki, tkj, segm[r]));
                    }
                }
                for r in 0..p {
                    if r != ri && segm[r] > 0 {
                        clk.advance(dev(r, cj), self.topo.copy_time(dev(r, cj), dev(ri, cj), tki * tkj * e));
                        clk.sync(dev(ri, cj), dev(r, cj));
                    }
                }
            }
        }
        self.redistribute(n, p * q) + self.potrf2d(n, t, p, q) + clk.max()
    }

    /// The grid-shape selector: the `(P, Q)` factorization of `ndev`
    /// with the smallest replayed end-to-end makespan for this routine
    /// and shape (the way Lineax dispatches solvers by operator
    /// structure — here the operator structure is the node itself).
    /// Ties, unknown routines, and small problems (where ring latency
    /// dominates) keep the 1D `(1, ndev)` shape, which the services
    /// map to the native 1D layout so existing paths are bitwise
    /// untouched. At paper scale the selector favors tall grids — the
    /// per-step panel trsm is the serial term and splits across `P` —
    /// tempered by the column-ring contention term (`P` concurrent
    /// senders share each receiver link), which hands moderate shapes
    /// to squarer grids.
    /// Replayed makespan of `routine` on a `(p, q)` process grid — the
    /// exact per-candidate cost [`Predictor::best_grid`] minimizes,
    /// exposed so scheduler makespan estimates (EDF/SJF ordering) are
    /// **bitwise** the autotuner's own numbers. `p == 1` is the 1D
    /// block-cyclic path over `q` devices.
    pub fn dist_makespan(
        &self,
        routine: &str,
        n: usize,
        nrhs: usize,
        t: usize,
        p: usize,
        q: usize,
    ) -> f64 {
        let ndev = p * q;
        match routine {
            "potrf" => self.redistribute(n, ndev) + self.potrf2d(n, t, p, q),
            "potrs" => self.potrs2d(n, t, p, q, nrhs.max(1)),
            "potri" => self.potri2d(n, t, p, q),
            "syevd" => {
                if p == 1 {
                    self.syevd(n, t, ndev)
                } else {
                    self.syevd2d(n, t, p, q)
                }
            }
            _ => f64::INFINITY,
        }
    }

    /// The **recompute cost** of a cached Cholesky factor: §2.1
    /// redistribution + the grid-native factorization on a `(p, q)`
    /// grid. This is the exact additive prefix shared by every
    /// factor-consuming makespan — `potrs2d`/`potri2d` (and their
    /// `p == 1` degenerate 1D forms) are all
    /// `redistribute + potrf + <routine tail>` — so a cache **hit**'s
    /// remaining work is `dist_makespan(...) - recompute(...)`
    /// bitwise, and the eviction scorer charges exactly what a miss
    /// would pay to rebuild the entry.
    pub fn recompute(&self, n: usize, t: usize, p: usize, q: usize) -> f64 {
        self.redistribute(n, p * q) + self.potrf2d(n, t, p, q)
    }

    /// [`Predictor::recompute`] in integer cost-model nanoseconds —
    /// the unit the `SloQueue` estimates and the factor-cache eviction
    /// scores are kept in (rounded and saturated exactly like the
    /// planner's `est_ns`).
    pub fn recompute_ns(&self, n: usize, t: usize, p: usize, q: usize) -> u64 {
        let key = MemoKey {
            model: model_sig(&self.model),
            topo: self.topo.signature(),
            dtype: self.dtype,
            routine: 0,
            kind: MEMO_RECOMPUTE_NS,
            n,
            nrhs: 0,
            t,
            ndev: p * q,
            p,
            q,
        };
        if let Some((ns, _, _)) = memo_lookup(&key) {
            return ns;
        }
        let ns = crate::coordinator::secs_to_ns(self.recompute(n, t, p, q));
        memo_store(key, (ns, 0, 0));
        ns
    }

    // ---- mixed-precision replays (the refinement tier's twin) -----------

    /// Working-precision twin of this predictor (f64→f32, c128→c64);
    /// `None` when the dtype has no narrower working precision.
    fn working(&self) -> Option<Predictor> {
        self.dtype.working_dtype().map(|w| Predictor {
            model: self.model.clone(),
            topo: self.topo.clone(),
            dtype: w,
        })
    }

    /// Machine epsilon of the mixed tier's *working* real dtype, if one
    /// exists (f32 epsilon for both f64 and c128 requests).
    pub fn working_eps(&self) -> Option<f64> {
        self.dtype.working_dtype().map(|_| f32::EPSILON as f64)
    }

    /// The demotion charge of a mixed factor: every device streams its
    /// full-precision shard through the cast kernel once
    /// (bandwidth-bound), devices in parallel — the exact per-device
    /// `blas2_time(local_elems · esize)` the mixed tier charges.
    pub fn convert_secs(&self, n: usize, t: usize, ndev: usize) -> f64 {
        let lay = BlockCyclic1D::new(n, t, ndev).unwrap();
        let mut worst = 0.0f64;
        for d in 0..ndev {
            let mut cols = 0usize;
            for tt in 0..lay.num_tiles() {
                if lay.owner_of_tile(tt) == d {
                    cols += lay.tile_cols(tt);
                }
            }
            if cols > 0 {
                worst = worst.max(self.model.blas2_time((n * cols * self.esize()) as u64));
            }
        }
        worst
    }

    /// One full-precision residual pass (`r = b − A·x`): a distributed
    /// GEMV over each device's shard of `A` plus the iterate broadcast
    /// from the root — the mixed tier's per-iteration charge.
    pub fn residual_secs(&self, n: usize, t: usize, ndev: usize, nrhs: usize) -> f64 {
        let gemv = self.convert_secs(n, t, ndev); // same bytes: one pass over the shard
        let mut clk = Clocks::new(ndev);
        let members: Vec<usize> = (0..ndev).collect();
        self.ring_bcast_replay(&mut clk, 0, &members, n * nrhs * self.esize(), 1);
        gemv + clk.max()
    }

    /// The solve tail (two triangular sweeps) on a `(p, q)` grid —
    /// `p == 1` is the 1D schedule.
    fn solve_tail(&self, n: usize, t: usize, p: usize, q: usize, nrhs: usize) -> f64 {
        if p == 1 {
            self.potrs_solve(n, t, q, nrhs)
        } else {
            self.potrs2d_solve(n, t, p, q, nrhs)
        }
    }

    /// Replay of the **mixed factor**: demotion cast + §2.1
    /// redistribution and grid-native Cholesky in the working dtype
    /// (half the flops-time and bytes of [`Predictor::recompute`]).
    /// Narrow dtypes (no working precision) return the full-precision
    /// recompute — the planner never routes them Mixed.
    pub fn potrf2d_mixed(&self, n: usize, t: usize, p: usize, q: usize) -> f64 {
        match self.working() {
            Some(w) => self.convert_secs(n, t, p * q) + w.recompute(n, t, p, q),
            None => self.recompute(n, t, p, q),
        }
    }

    /// One refinement iteration: a full-precision residual pass plus a
    /// working-dtype correction solve. Zero for narrow dtypes.
    pub fn refine_secs(&self, n: usize, t: usize, p: usize, q: usize, nrhs: usize) -> f64 {
        match self.working() {
            Some(w) => {
                self.residual_secs(n, t, p * q, nrhs) + w.solve_tail(n, t, p, q, nrhs)
            }
            None => 0.0,
        }
    }

    /// The refinement phase in integer cost-model ns: the loop runs
    /// `iters + 1` residual passes and `iters` correction solves.
    pub fn refine_ns(
        &self,
        n: usize,
        t: usize,
        p: usize,
        q: usize,
        nrhs: usize,
        iters: usize,
    ) -> u64 {
        let secs = match self.working() {
            Some(_) => {
                self.residual_secs(n, t, p * q, nrhs)
                    + iters as f64 * self.refine_secs(n, t, p, q, nrhs)
            }
            None => 0.0,
        };
        crate::coordinator::secs_to_ns(secs)
    }

    /// End-to-end mixed potrs makespan at an assumed refinement depth:
    /// mixed factor + `iters + 1` working solves interleaved with
    /// `iters + 1` residual passes. Narrow dtypes return the
    /// full-precision [`Predictor::potrs2d`] — mixed never wins there.
    pub fn mixed_potrs(
        &self,
        n: usize,
        t: usize,
        p: usize,
        q: usize,
        nrhs: usize,
        iters: usize,
    ) -> f64 {
        match self.working() {
            Some(w) => {
                self.potrf2d_mixed(n, t, p, q)
                    + (iters + 1) as f64
                        * (self.residual_secs(n, t, p * q, nrhs)
                            + w.solve_tail(n, t, p, q, nrhs))
            }
            None => self.potrs2d(n, t, p, q, nrhs.max(1)),
        }
    }

    /// Estimated correction-solve count for a condition-number budget:
    /// each iteration contracts the residual by ≈ κ·ε_working, so
    /// `κ·ε^(k+1) ≤ tol` gives `k`. Returns `None` — the planner routes
    /// those requests Full — when refinement cannot be trusted to reach
    /// `tol` at all:
    ///
    /// * the contraction factor is not comfortably below the stall
    ///   detector's 0.9 bound (κ·ε_working ≥ 0.25), or
    /// * `tol` sits below the attainable full-precision residual floor
    ///   ≈ κ·ε_f64 (residuals are computed in f64, so no amount of
    ///   iteration pushes under it — the runtime would stall by
    ///   construction, pay the mixed attempt *and* the full-precision
    ///   fallback, and the queue would have priced only the cheaper
    ///   mixed estimate).
    pub fn est_refine_iters(&self, tol: f64, cond: f64) -> Option<usize> {
        let eps = self.working_eps()?;
        let rho = cond.max(1.0) * eps;
        if !(rho < 0.25) {
            return None;
        }
        if tol < REFINE_FLOOR_SAFETY * cond.max(1.0) * f64::EPSILON {
            return None;
        }
        let tol = tol.clamp(f64::MIN_POSITIVE, 0.5);
        let solves = (tol.ln() / rho.ln()).ceil().max(1.0);
        let iters = (solves as usize).saturating_sub(1);
        Some(iters.min(crate::solver::DEFAULT_REFINE_CAP))
    }

    /// [`Predictor::potrf2d`] on a two-tier fabric topology — the
    /// named hierarchical replay. The topology itself carries the
    /// fabric structure (Lineax-style dispatch by operator structure),
    /// so this is the same arithmetic `potrf2d` runs once
    /// `self.topo` spans islands; the named form documents intent at
    /// call sites and is what the fabric benches pin.
    pub fn potrf2d_fabric(&self, n: usize, t: usize, p: usize, q: usize) -> f64 {
        self.potrf2d(n, t, p, q)
    }

    /// [`Predictor::syevd2d`] on a two-tier fabric topology — the
    /// named hierarchical replay (see [`Predictor::potrf2d_fabric`]).
    pub fn syevd2d_fabric(&self, n: usize, t: usize, p: usize, q: usize) -> f64 {
        self.syevd2d(n, t, p, q)
    }

    /// The 1-node-vs-2-node router: compare the best grid confined to
    /// one island (its subset topology is flat, so every collective
    /// prices at NVLink rates) against the best grid spanning the
    /// whole fabric (hierarchical collectives, inter-node crossings),
    /// and return `(devices_used, (p, q))` for the cheaper one. Ties
    /// stay on one island — spanning must pay for itself. On a flat
    /// node this is exactly `best_grid` over all devices.
    pub fn best_fabric_plan(
        &self,
        routine: &str,
        n: usize,
        nrhs: usize,
        t: usize,
    ) -> (usize, (usize, usize)) {
        let key = routine_code(routine).map(|rc| MemoKey {
            model: model_sig(&self.model),
            topo: self.topo.signature(),
            dtype: self.dtype,
            routine: rc,
            kind: MEMO_FABRIC_PLAN,
            n,
            nrhs,
            t,
            ndev: self.topo.num_devices(),
            p: 0,
            q: 0,
        });
        if let Some(k) = &key {
            if let Some((used, p, q)) = memo_lookup(k) {
                return (used as usize, (p as usize, q as usize));
            }
        }
        let out = self.best_fabric_plan_replay(routine, n, nrhs, t);
        if let Some(k) = key {
            memo_store(k, (out.0 as u64, out.1 .0 as u64, out.1 .1 as u64));
        }
        out
    }

    /// The uncached replay behind [`Predictor::best_fabric_plan`].
    fn best_fabric_plan_replay(
        &self,
        routine: &str,
        n: usize,
        nrhs: usize,
        t: usize,
    ) -> (usize, (usize, usize)) {
        let ndev = self.topo.num_devices();
        if self.topo.num_islands() <= 1 {
            return (ndev, self.best_grid(routine, n, nrhs, t, ndev));
        }
        let island = self.topo.island_devices(0);
        let sub = Predictor {
            model: self.model.clone(),
            topo: self.topo.subset(&island).expect("island devices are in range"),
            dtype: self.dtype,
        };
        let k = island.len();
        let sg = sub.best_grid(routine, n, nrhs, t, k);
        let sub_cost = sub.dist_makespan(routine, n, nrhs, t, sg.0, sg.1);
        let fg = self.best_grid(routine, n, nrhs, t, ndev);
        let full_cost = self.dist_makespan(routine, n, nrhs, t, fg.0, fg.1);
        if full_cost < sub_cost {
            (ndev, fg)
        } else {
            (k, sg)
        }
    }

    pub fn best_grid(&self, routine: &str, n: usize, nrhs: usize, t: usize, ndev: usize) -> (usize, usize) {
        let key = routine_code(routine).map(|rc| MemoKey {
            model: model_sig(&self.model),
            topo: self.topo.signature(),
            dtype: self.dtype,
            routine: rc,
            kind: MEMO_BEST_GRID,
            n,
            nrhs,
            t,
            ndev,
            p: 0,
            q: 0,
        });
        if let Some(k) = &key {
            if let Some((p, q, _)) = memo_lookup(k) {
                return (p as usize, q as usize);
            }
        }
        let out = self.best_grid_replay(routine, n, nrhs, t, ndev);
        if let Some(k) = key {
            memo_store(k, (out.0 as u64, out.1 as u64, 0));
        }
        out
    }

    /// The uncached grid scan behind [`Predictor::best_grid`].
    fn best_grid_replay(
        &self,
        routine: &str,
        n: usize,
        nrhs: usize,
        t: usize,
        ndev: usize,
    ) -> (usize, usize) {
        if ndev <= 1 {
            return (1, ndev.max(1));
        }
        let cost = |p: usize, q: usize| -> f64 { self.dist_makespan(routine, n, nrhs, t, p, q) };
        let mut best = (1usize, ndev);
        let mut best_cost = cost(1, ndev);
        for p in 2..=ndev {
            if ndev % p != 0 {
                continue;
            }
            let q = ndev / p;
            let c = cost(p, q);
            if c < best_cost {
                best_cost = c;
                best = (p, q);
            }
        }
        best
    }

    // ---- MPMD control-plane overhead ------------------------------------

    /// Per-solve control-plane cost MPMD serving adds over the SPMD
    /// shared-address-space path (Fig. 2 right vs left): each of the
    /// `ndev - 1` non-caller workers exports its shard
    /// (`cudaIpcGetMemHandle`), ships the 64-byte opaque handle to the
    /// rank-0 caller over the host, and the caller opens it
    /// (`cudaIpcOpenMemHandle`). Data-plane charges — staging, the
    /// solve schedule, gathers — are identical between the modes, so
    /// this handle round-trip is the *entire* modeled gap; the serve
    /// layer charges exactly this quantity onto the caller's timeline
    /// per opened handle, so the projection and the live path agree by
    /// construction. The cost is per *solve* and O(ndev), independent
    /// of N — negligible against any paper-scale solve, visible only
    /// for tiny ones (which the coalesced pod path keeps off the
    /// distributed route anyway).
    pub fn mpmd_overhead(&self, ndev: usize) -> f64 {
        if ndev <= 1 {
            return 0.0;
        }
        let per_handle =
            self.model.ipc_export_s + self.model.ipc_open_s + self.topo.h2d_time(64);
        (ndev - 1) as f64 * per_handle
    }

    // ---- batched small-solve path (the coalescer's cost cut) -----------

    /// Makespan of one **batched pod sweep**: `batch` independent
    /// `n × n` systems (each with `nrhs` RHS columns where the routine
    /// takes one) dealt round-robin onto `ndev` devices and swept with
    /// one fused kernel per device per stage — the analytic replay of
    /// [`crate::batch::sweep`]. Systems never leave their device, so
    /// there is no communication term; the makespan is the most-loaded
    /// device (`⌈batch/ndev⌉` systems), each stage paying a single
    /// launch overhead plus the summed per-system kernel time.
    ///
    /// Host staging is excluded here **and** in
    /// [`Predictor::small_serial`], keeping the comparison symmetric:
    /// the pod stages the same matrix bytes the serial path's
    /// per-solve scatters do, just in `ndev` copies instead of
    /// `batch·ndev` — so including staging on both sides only widens
    /// the batched win. The serial side's `redistribute` term is the
    /// §2.1 *device-side* layout conversion, which the pod genuinely
    /// skips.
    pub fn pod_sweep(&self, routine: &str, n: usize, nrhs: usize, ndev: usize, batch: usize) -> f64 {
        if batch == 0 {
            return 0.0;
        }
        let ov = self.model.launch_overhead;
        let c0 = batch.div_ceil(ndev.max(1)) as f64;
        let potf2 =
            self.model.panel_time(self.dtype, GpuCostModel::flops_potf2(self.dtype, n)) - ov;
        let factor = ov + c0 * potf2;
        match routine {
            "potrf" => factor,
            "potrs" => {
                let trsm = self
                    .model
                    .panel_time(self.dtype, GpuCostModel::flops_trsm(self.dtype, n, nrhs, n))
                    - ov;
                factor + ov + c0 * (2.0 * trsm)
            }
            "potri" => {
                let trsm = self
                    .model
                    .panel_time(self.dtype, GpuCostModel::flops_trsm(self.dtype, n, n, n))
                    - ov;
                let gemm = self.model.gemm_time(self.dtype, n, n, n) - ov;
                factor + ov + c0 * (trsm + gemm)
            }
            _ => f64::INFINITY,
        }
    }

    /// Makespan of the **serial one-at-a-time** alternative: `batch`
    /// distributed solves back to back, each paying the full §2.1
    /// redistribution and per-panel collectives. With `batch = 1` this
    /// *is* the distributed path's formula
    /// ([`Predictor::potrs`]/[`Predictor::potri`]/redistribute+potrf),
    /// exactly — the degeneracy the unit tests pin.
    pub fn small_serial(
        &self,
        routine: &str,
        n: usize,
        nrhs: usize,
        t: usize,
        ndev: usize,
        batch: usize,
    ) -> f64 {
        let per = match routine {
            "potrf" => self.redistribute(n, ndev) + self.potrf(n, t, ndev),
            "potrs" => self.potrs(n, t, ndev, nrhs),
            "potri" => self.potri(n, t, ndev),
            _ => f64::INFINITY,
        };
        batch as f64 * per
    }

    /// The coalescer's dispatch cut: should `batch` size-`n` requests
    /// run as one fused pod sweep rather than one-at-a-time
    /// distributed solves?
    pub fn batched_wins(
        &self,
        routine: &str,
        n: usize,
        nrhs: usize,
        t: usize,
        ndev: usize,
        batch: usize,
    ) -> bool {
        self.pod_sweep(routine, n, nrhs, ndev, batch)
            < self.small_serial(routine, n, nrhs, t, ndev, batch)
    }

    /// Smallest power-of-two size-class at which batching **stops**
    /// winning for the given shape (batched wins strictly below the
    /// returned class). Scans the coalescer's size-class ladder up to
    /// `2^17` — far beyond any "small" solve, and the cap keeps the
    /// `O(ntiles²)` serial replays cheap; returns `usize::MAX` when
    /// batching wins across the whole scanned ladder.
    pub fn batched_crossover(
        &self,
        routine: &str,
        nrhs: usize,
        t: usize,
        ndev: usize,
        batch: usize,
    ) -> usize {
        let mut n = 4usize;
        while n <= (1 << 17) {
            if !self.batched_wins(routine, n, nrhs, t, ndev, batch) {
                return n;
            }
            n *= 2;
        }
        usize::MAX
    }

    // ---- single-GPU baselines (cuSOLVERDn / native JAX) -----------------

    /// `cho_factor` + `cho_solve` on one device.
    pub fn single_potrs(&self, n: usize, nrhs: usize) -> f64 {
        let fl = GpuCostModel::flops_potf2(self.dtype, n) as f64;
        let factor = fl / (self.model.rate(self.dtype) * 0.7) + self.model.launch_overhead;
        let solve_bytes = (n * n * self.esize()) as f64;
        let solve = 2.0 * nrhs as f64 * solve_bytes / self.model.blas2_bytes_per_s;
        factor + solve
    }

    /// `jnp.linalg.inv` on one device.
    pub fn single_potri(&self, n: usize) -> f64 {
        // LU + triangular inverse + product ≈ 2n³ at ~0.6 gemm rate.
        let fl = 2.0 * (n as f64).powi(3) * if self.dtype.is_complex() { 4.0 } else { 1.0 };
        fl / (self.model.rate(self.dtype) * 0.6) + self.model.launch_overhead
    }

    /// `jnp.linalg.eigh` on one device.
    pub fn single_syevd(&self, n: usize) -> f64 {
        let e = self.esize() as f64;
        let nf = n as f64;
        // Tridiagonalization: BLAS-2, n passes over n² data.
        let tridiag = nf * (nf * nf * e) / self.model.blas2_bytes_per_s / 4.0;
        // QL + back-transform: ~6n³ at a degraded gemm rate.
        let rest = 6.0 * nf * nf * nf * if self.dtype.is_complex() { 4.0 } else { 1.0 }
            / (self.model.rate(self.dtype) * 0.3);
        tridiag + rest
    }

    // ---- capacity walls --------------------------------------------------

    /// Largest N the single-GPU baseline can hold (bytes for matrix +
    /// routine workspace ≤ vram).
    pub fn single_capacity(&self, routine: &str, vram: usize) -> usize {
        let e = self.esize();
        let factor = match routine {
            "potrs" => 1,
            "potri" => 2,
            "syevd" => 3,
            _ => usize::MAX,
        };
        ((vram / (factor * e)) as f64).sqrt() as usize
    }

    /// Largest N the distributed solver can hold per device.
    pub fn dist_capacity(&self, routine: &str, vram: usize, ndev: usize, t: usize) -> usize {
        super::workspace::largest_n(vram, ndev, t, self.dtype, routine, 1024)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crossover_exists_for_potrs_f32() {
        // Fig. 3a: single GPU wins at small N, 8×GPU wins at large N.
        let p = Predictor::h200(8, DType::F32);
        let small_mg = p.potrs(1024, 256, 8, 1);
        let small_dn = p.single_potrs(1024, 1);
        assert!(small_dn < small_mg, "baseline must win at N=1024: {small_dn} vs {small_mg}");
        let large_mg = p.potrs(131072, 1024, 8, 1);
        let large_dn = p.single_potrs(131072, 1);
        assert!(large_mg < large_dn, "JAXMg must win at N=131072: {large_mg} vs {large_dn}");
    }

    #[test]
    fn larger_tiles_help_at_large_n_potrs() {
        // "larger tile sizes improve performance only once the problem
        // size is sufficiently large".
        let p = Predictor::h200(8, DType::F32);
        let t_small = p.potrs(262144, 128, 8, 1);
        let t_large = p.potrs(262144, 1024, 8, 1);
        assert!(t_large < t_small, "T=1024 {t_large} !< T=128 {t_small} at N=262144");
    }

    #[test]
    fn potri_strong_tile_dependence_syevd_flat() {
        // Fig. 3 caption: "Tile size has negligible impact for syevd,
        // while potri shows a strong dependence on T_A."
        let p = Predictor::h200(8, DType::C128);
        let n = 32768;
        let potri_ratio = p.potri(n, 64, 8) / p.potri(n, 512, 8);
        let p2 = Predictor::h200(8, DType::F64);
        let syevd_ratio = p2.syevd(n, 64, 8) / p2.syevd(n, 512, 8);
        assert!(potri_ratio > 1.5, "potri should speed up a lot with bigger tiles: {potri_ratio}");
        assert!((syevd_ratio - 1.0).abs() < 0.05, "syevd should be tile-insensitive: {syevd_ratio}");
    }

    #[test]
    fn capacity_walls_ordered_like_paper() {
        let vram = 143usize * 1000 * 1000 * 1000;
        let p32 = Predictor::h200(8, DType::F32);
        // Single-GPU f32 potrs wall ~ sqrt(143e9/4) ≈ 189k; JAXMg reaches ~524k.
        let single = p32.single_capacity("potrs", vram);
        let dist = p32.dist_capacity("potrs", vram, 8, 1024);
        assert!(dist > single, "distributed capacity {dist} !> single {single}");
        assert!(dist >= 400_000, "paper reaches N=524288, model gives {dist}");
    }

    #[test]
    fn eigh_slower_than_solve() {
        // §3: syevd/potri reach smaller sizes & run longer than potrs.
        let p = Predictor::h200(8, DType::F64);
        let n = 16384;
        assert!(p.syevd(n, 256, 8) > p.potrs(n, 256, 8, 1));
    }

    #[test]
    fn lookahead_replay_beats_barrier_at_scale() {
        // The overlap-aware replay must shrink the potrf makespan on
        // paper-scale problems (where trailing GEMMs dominate and the
        // panel/copy offload pays), and depth 0 must degenerate to the
        // barrier replay exactly.
        let p = Predictor::h200(8, DType::F32);
        let barrier = p.potrf(16384, 512, 8);
        let look = p.potrf_lookahead(16384, 512, 8, 2);
        assert!(look < barrier, "lookahead {look} !< barrier {barrier}");
        assert_eq!(p.potrf_lookahead(16384, 512, 8, 0), barrier);
        assert!(look.is_finite() && look > 0.0);
    }

    #[test]
    fn syevd_2x2_grid_beats_1d_at_paper_scale() {
        // Acceptance: the 2×2 grid's simulated syevd makespan strictly
        // beats the 1D layout at paper-scale shapes — the §5 claim the
        // 2D distribution exists to deliver. Same device count, same
        // compute; the row-parallel collectives are the whole win.
        let p = Predictor::h200(4, DType::F64);
        for &n in &[32768usize, 65536, 131072] {
            let t = 256;
            let one_d = p.syevd(n, t, 4);
            let grid = p.syevd2d(n, t, 2, 2);
            assert!(
                grid < one_d,
                "2x2 syevd {grid} must strictly beat 1D {one_d} at n={n}"
            );
        }
        // An 8-device 2×4 grid also beats 1D×8.
        let p8 = Predictor::h200(8, DType::F64);
        assert!(p8.syevd2d(65536, 256, 2, 4) < p8.syevd(65536, 256, 8));
    }

    #[test]
    fn syevd2d_with_p1_degenerates_to_1d_exactly() {
        let p = Predictor::h200(4, DType::F64);
        assert_eq!(p.syevd2d(16384, 256, 1, 4), p.syevd(16384, 256, 4));
        let pc = Predictor::h200(8, DType::C128);
        assert_eq!(pc.syevd2d(8192, 128, 1, 8), pc.syevd(8192, 128, 8));
    }

    #[test]
    fn potrf2d_2x2_beats_1d_at_paper_scale() {
        // Acceptance: the grid-native potrf replay strictly beats the
        // 1D layout at paper-scale shapes — same device count, same
        // flops; the row-split panel trsm and ring collectives are the
        // win — and p = 1 degenerates to the 1D formula bitwise.
        let p = Predictor::h200(4, DType::F64);
        for &n in &[16384usize, 65536, 131072] {
            let one_d = p.potrf(n, 1024, 4);
            let grid = p.potrf2d(n, 1024, 2, 2);
            assert!(grid < one_d, "2x2 potrf {grid} must beat 1D {one_d} at n={n}");
        }
        assert_eq!(p.potrf2d(16384, 1024, 1, 4), p.potrf(16384, 1024, 4));
        let p8 = Predictor::h200(8, DType::F64);
        assert!(p8.potrf2d(65536, 1024, 2, 4) < p8.potrf(65536, 1024, 8));
        let p32 = Predictor::h200(4, DType::F32);
        assert!(p32.potrf2d(131072, 1024, 2, 2) < p32.potrf(131072, 1024, 4));
    }

    #[test]
    fn potrs2d_and_potri2d_beat_1d_and_degenerate_at_p1() {
        let p = Predictor::h200(4, DType::F64);
        for &n in &[16384usize, 65536, 131072] {
            assert!(p.potrs2d(n, 1024, 2, 2, 1) < p.potrs(n, 1024, 4, 1), "potrs2d at n={n}");
        }
        assert_eq!(p.potrs2d(8192, 1024, 1, 4, 1), p.potrs(8192, 1024, 4, 1));
        let pc = Predictor::h200(4, DType::C128);
        for &n in &[8192usize, 32768] {
            assert!(pc.potri2d(n, 256, 2, 2) < pc.potri(n, 256, 4), "potri2d at n={n}");
        }
        assert_eq!(pc.potri2d(4096, 256, 1, 4), pc.potri(4096, 256, 4));
    }

    #[test]
    fn best_grid_keeps_small_solves_1d_and_goes_2d_at_scale() {
        let p = Predictor::h200(4, DType::F64);
        // Service-scale shapes (the serving tests/benches) stay 1D —
        // ring latency dominates, and (1, ndev) maps to the bitwise
        // seed path.
        assert_eq!(p.best_grid("potrs", 192, 1, 32, 4), (1, 4));
        assert_eq!(p.best_grid("potrs", 24, 2, 8, 4), (1, 4));
        assert_eq!(p.best_grid("potrf", 1024, 0, 256, 4), (1, 4));
        // Paper scale flips 2D. The row split shortens the serial
        // panel trsm, but the column-ring contention term (P
        // concurrent senders per receiver link) taxes the fully tall
        // (4, 1) shape, so the moderate shape wins here; at larger N
        // (potrs below) the trsm term dominates and tall returns.
        let big = p.best_grid("potrf", 16384, 0, 256, 4);
        assert_eq!(big.0 * big.1, 4);
        assert!(big.0 > 1, "paper-scale potrf must select a 2D grid, got {big:?}");
        assert_eq!(big, (2, 2));
        let bs = p.best_grid("potrs", 65536, 1, 1024, 4);
        assert!(bs.0 > 1);
        // syevd's selector rides the existing replay pair.
        let se = p.best_grid("syevd", 65536, 0, 256, 4);
        assert!(se.0 > 1);
        // Unknown routines and single-device nodes stay 1D.
        assert_eq!(p.best_grid("getrf", 65536, 0, 256, 4), (1, 4));
        assert_eq!(Predictor::h200(1, DType::F64).best_grid("potrf", 65536, 0, 256, 1), (1, 1));
    }

    #[test]
    fn batched_crossover_pins_the_size_class() {
        // The coalescer's cut: batching wins below a size-class and
        // stops winning at it. For f64 potrs/potrf on the paper node
        // (T_A = 256, 8 devices, 32-way buckets) the crossover class is
        // 32768; f32's faster serial GEMM rate pushes it to 65536.
        let p = Predictor::h200(8, DType::F64);
        assert_eq!(p.batched_crossover("potrs", 1, 256, 8, 32), 32768);
        assert_eq!(p.batched_crossover("potrf", 1, 256, 8, 32), 32768);
        assert!(p.batched_wins("potrs", 64, 1, 256, 8, 32));
        assert!(p.batched_wins("potrs", 16384, 1, 256, 8, 32));
        assert!(!p.batched_wins("potrs", 65536, 1, 256, 8, 32));
        let p32 = Predictor::h200(8, DType::F32);
        assert_eq!(p32.batched_crossover("potrs", 1, 256, 8, 32), 65536);
        let pc = Predictor::h200(8, DType::C128);
        assert_eq!(pc.batched_crossover("potrs", 1, 256, 8, 32), 32768);
        // potri's serial path carries per-round panel broadcasts on top
        // of the factor: batching wins across the whole scanned ladder.
        assert_eq!(p.batched_crossover("potri", 0, 256, 8, 32), usize::MAX);
        // Unknown routines never win.
        assert!(!p.batched_wins("getrf", 64, 1, 256, 8, 32));
    }

    #[test]
    fn small_serial_degenerates_to_distributed_formula_at_b1() {
        // B = 1 must reproduce the distributed path's formula *exactly*
        // (bitwise f64 equality, not approximately).
        let p = Predictor::h200(8, DType::F64);
        for &(n, t) in &[(64usize, 256usize), (1024, 256), (4096, 128)] {
            assert_eq!(p.small_serial("potrs", n, 1, t, 8, 1), p.potrs(n, t, 8, 1));
            assert_eq!(p.small_serial("potri", n, 0, t, 8, 1), p.potri(n, t, 8));
            assert_eq!(
                p.small_serial("potrf", n, 0, t, 8, 1),
                p.redistribute(n, 8) + p.potrf(n, t, 8)
            );
        }
        // Even a single tiny solve is better off batched: the serial
        // path's redistribution latency alone dwarfs the fused kernels.
        assert!(p.batched_wins("potrs", 64, 1, 256, 8, 1));
    }

    #[test]
    fn batched_sweep_beats_serial_for_256_small_solves() {
        // The acceptance workload: 256 small solves (n = 64). The fused
        // pod sweep must be strictly below the serial one-at-a-time
        // distributed path — for every routine and dtype.
        for dtype in [DType::F32, DType::F64, DType::C64, DType::C128] {
            let p = Predictor::h200(8, dtype);
            for routine in ["potrf", "potrs", "potri"] {
                let pod = p.pod_sweep(routine, 64, 1, 8, 256);
                let serial = p.small_serial(routine, 64, 1, 256, 8, 256);
                assert!(
                    pod < serial,
                    "{routine} {dtype:?}: pod {pod} !< serial {serial}"
                );
                // The modeled win is orders of magnitude, not noise.
                assert!(serial / pod > 100.0, "{routine} {dtype:?} win too thin");
                assert!(pod.is_finite() && pod > 0.0);
            }
        }
        // An empty batch costs nothing.
        let p = Predictor::h200(8, DType::F64);
        assert_eq!(p.pod_sweep("potrs", 64, 1, 8, 0), 0.0);
    }

    #[test]
    fn mpmd_overhead_pins_the_handle_round_trip() {
        let p = Predictor::h200(8, DType::F64);
        // Single process: no handles, no overhead.
        assert_eq!(p.mpmd_overhead(1), 0.0);
        // Linear in the non-caller worker count, dtype-independent.
        let per = p.mpmd_overhead(2);
        assert!(per > 0.0);
        assert!((p.mpmd_overhead(8) - 7.0 * per).abs() < 1e-15);
        assert_eq!(Predictor::h200(8, DType::C128).mpmd_overhead(8), p.mpmd_overhead(8));
        // H200 constants: ~25 µs per handle (5 export + 15 open + ~5 µs
        // host-link latency for the 64-byte blob), ~175 µs at 8 devices.
        assert!(per > 20e-6 && per < 30e-6, "{per}");
        let eight = p.mpmd_overhead(8);
        assert!(eight > 140e-6 && eight < 210e-6, "{eight}");
        // Context: invisible against a paper-scale solve, dominant
        // against a tiny one — the regime split the serve layer's
        // batched-vs-distributed routing already encodes.
        assert!(eight < p.potrs(131072, 1024, 8, 1) * 1e-3);
        assert!(eight > p.pod_sweep("potrs", 64, 1, 8, 32));
    }

    #[test]
    fn recompute_is_the_exact_additive_factor_prefix() {
        // The factor-cache invariant: every factor-consuming makespan
        // is `recompute + <routine tail>` *bitwise*, so a hit's
        // remaining-work estimate (`dist_makespan - recompute`) never
        // goes negative and the eviction scorer charges exactly the
        // rebuild cost. Checked on 1D and 2×2 grids across dtypes.
        for dtype in [DType::F32, DType::F64, DType::C64, DType::C128] {
            let p = Predictor::h200(4, dtype);
            for &(pp, qq) in &[(1usize, 4usize), (2, 2)] {
                for &(n, t) in &[(256usize, 32usize), (4096, 256)] {
                    let re = p.recompute(n, t, pp, qq);
                    assert!(re > 0.0 && re.is_finite());
                    for routine in ["potrf", "potrs", "potri"] {
                        let full = p.dist_makespan(routine, n, 1, t, pp, qq);
                        assert!(
                            full >= re,
                            "{routine} {dtype:?} ({pp},{qq}) n={n}: full {full} < recompute {re}"
                        );
                    }
                    // potrf *is* the recompute prefix, bitwise.
                    assert_eq!(p.dist_makespan("potrf", n, 1, t, pp, qq), re);
                }
            }
            // p = 1 degenerates to the 1D formula bitwise.
            assert_eq!(
                p.recompute(1024, 64, 1, 4),
                p.redistribute(1024, 4) + p.potrf(1024, 64, 4)
            );
        }
        // The ns form rounds exactly like the planner's est_ns.
        let p = Predictor::h200(4, DType::F64);
        assert_eq!(
            p.recompute_ns(1024, 64, 1, 4),
            crate::coordinator::secs_to_ns(p.recompute(1024, 64, 1, 4))
        );
    }

    #[test]
    fn predictions_are_finite_and_positive() {
        let p = Predictor::h200(8, DType::F64);
        for &n in &[256usize, 4096, 65536] {
            for v in [
                p.potrs(n, 256, 8, 1),
                p.potri(n, 256, 8),
                p.syevd(n, 256, 8),
                p.single_potrs(n, 1),
                p.single_potri(n),
                p.single_syevd(n),
            ] {
                assert!(v.is_finite() && v > 0.0);
            }
        }
    }

    #[test]
    fn mixed_replay_beats_full_by_a_quarter_at_paper_scale() {
        // The acceptance bar: at N ≥ 16384 on 8 devices, the mixed
        // replay (f32 factor + a handful of refinement iterations) must
        // beat the full-precision makespan by ≥ 25%.
        let p = Predictor::h200(8, DType::F64);
        let (gp, gq) = p.best_grid("potrs", 16384, 1, 1024, 8);
        let full = p.dist_makespan("potrs", 16384, 1, 1024, gp, gq);
        let mixed = p.mixed_potrs(16384, 1024, gp, gq, 1, 3);
        assert!(
            mixed < 0.75 * full,
            "mixed {mixed} must be ≥25% under full {full} at N=16384"
        );
        // The win holds for complex and grows with N.
        let pc = Predictor::h200(8, DType::C128);
        let fullc = pc.dist_makespan("potrs", 16384, 1, 1024, gp, gq);
        assert!(pc.mixed_potrs(16384, 1024, gp, gq, 1, 3) < 0.75 * fullc);
        let full64 = p.dist_makespan("potrs", 65536, 1, 1024, gp, gq);
        assert!(p.mixed_potrs(65536, 1024, gp, gq, 1, 3) < 0.75 * full64);
        // Mixed factor alone also clears the bar vs the full recompute.
        assert!(p.potrf2d_mixed(16384, 1024, gp, gq) < 0.75 * p.recompute(16384, 1024, gp, gq));
    }

    #[test]
    fn mixed_replay_degenerates_for_narrow_dtypes() {
        // f32/c64 have no working precision: the mixed replays return
        // the full-precision numbers bitwise and iteration estimates
        // are refused.
        let p = Predictor::h200(8, DType::F32);
        assert_eq!(p.mixed_potrs(4096, 256, 1, 8, 1, 3), p.potrs2d(4096, 256, 1, 8, 1));
        assert_eq!(p.potrf2d_mixed(4096, 256, 1, 8), p.recompute(4096, 256, 1, 8));
        assert_eq!(p.refine_secs(4096, 256, 1, 8, 1), 0.0);
        assert_eq!(p.refine_ns(4096, 256, 1, 8, 1, 3), 0);
        assert!(p.working_eps().is_none());
        assert!(p.est_refine_iters(1e-10, 1e3).is_none());
    }

    #[test]
    fn est_refine_iters_tracks_condition_budget() {
        let p = Predictor::h200(8, DType::F64);
        // κ = 1e3: contraction ≈ 1.2e-4 per iteration; 1e-10 needs 3
        // solves = 2 corrections.
        assert_eq!(p.est_refine_iters(1e-10, 1e3), Some(2));
        // Well conditioned, loose tolerance: the initial solve suffices.
        assert_eq!(p.est_refine_iters(1e-4, 1.0), Some(0));
        // Tighter tolerance or worse conditioning costs iterations,
        // monotonically.
        let a = p.est_refine_iters(1e-6, 1e2).unwrap();
        let b = p.est_refine_iters(1e-12, 1e2).unwrap();
        assert!(b >= a);
        // κ·ε ≥ 0.25: refinement cannot be trusted to contract — refuse.
        assert_eq!(p.est_refine_iters(1e-10, 1e7), None);
        assert_eq!(p.est_refine_iters(1e-10, 1e12), None);
        // Tolerance below the attainable f64 residual floor κ·ε_f64:
        // the f32 contraction is fine, but the runtime would stall by
        // construction — refuse so the queue never prices a guaranteed
        // mixed-attempt + full-solve double makespan as the cheap tier.
        assert_eq!(p.est_refine_iters(1e-15, 1e4), None);
        // Just above the floor (4·κ·ε_f64 ≈ 8.9e-12 at κ=1e4) stays
        // routable.
        assert!(p.est_refine_iters(1e-11, 1e4).is_some());
        // Complex carries the same f32 working epsilon.
        let pc = Predictor::h200(8, DType::C128);
        assert_eq!(pc.est_refine_iters(1e-10, 1e3), Some(2));
        // refine_ns is consistent with its parts and monotone in iters.
        assert!(p.refine_ns(8192, 512, 1, 8, 1, 4) > p.refine_ns(8192, 512, 1, 8, 1, 1));
    }

    #[test]
    fn plan_memo_returns_cached_results() {
        // An awkward shape no other test uses, so the first call is a
        // genuine miss and the second a genuine hit even with tests
        // running concurrently against the process-wide memo.
        let p = Predictor::h200(8, DType::C128);
        let (h0, m0) = super::plan_memo_stats();
        let first = p.best_grid("potrs", 3391, 7, 193, 8);
        let (_, m1) = super::plan_memo_stats();
        assert!(m1 > m0, "first call must miss");
        let second = p.best_grid("potrs", 3391, 7, 193, 8);
        let (h2, _) = super::plan_memo_stats();
        assert!(h2 > h0, "second call must hit");
        assert_eq!(first, second);
        assert_eq!(second, p.best_grid_replay("potrs", 3391, 7, 193, 8));
        // recompute_ns memoizes too, and stays equal to the replay.
        let r1 = p.recompute_ns(3391, 193, 2, 4);
        let r2 = p.recompute_ns(3391, 193, 2, 4);
        assert_eq!(r1, r2);
        assert_eq!(r1, crate::coordinator::secs_to_ns(p.recompute(3391, 193, 2, 4)));
        // The fabric router's memo keys on the fabric topology, so the
        // flat predictor's entries cannot collide with it.
        let pf = Predictor::fabric(2, 4, DType::C128);
        let f1 = pf.best_fabric_plan("potrs", 3391, 7, 193);
        let f2 = pf.best_fabric_plan("potrs", 3391, 7, 193);
        assert_eq!(f1, f2);
        assert_eq!(f1, pf.best_fabric_plan_replay("potrs", 3391, 7, 193));
        // Unknown routines bypass the memo and stay 1D.
        assert_eq!(p.best_grid("getrf", 3391, 7, 193, 8), (1, 8));
        // A different dtype at the same shape is a different key.
        let pf64 = Predictor::h200(8, DType::F64);
        assert_eq!(
            pf64.best_grid("potrs", 3391, 7, 193, 8),
            pf64.best_grid_replay("potrs", 3391, 7, 193, 8)
        );
    }

    #[test]
    fn fabric_one_island_predictor_is_bitwise_flat() {
        // A 1-island fabric topology is the flat node: identical link
        // map, identical island gate, so every replay — hierarchical
        // code paths included — returns the flat number bitwise, and
        // the router degenerates to plain best_grid over all devices.
        let flat = Predictor::h200(8, DType::F64);
        let fab = Predictor::fabric(1, 8, DType::F64);
        for &n in &[1024usize, 16384] {
            assert_eq!(fab.potrf2d_fabric(n, 256, 2, 4), flat.potrf2d(n, 256, 2, 4));
            assert_eq!(fab.syevd2d_fabric(n, 256, 2, 4), flat.syevd2d(n, 256, 2, 4));
            assert_eq!(fab.potrs2d(n, 256, 2, 4, 1), flat.potrs2d(n, 256, 2, 4, 1));
        }
        let (used, grid) = fab.best_fabric_plan("potrf", 16384, 0, 1024);
        assert_eq!(used, 8);
        assert_eq!(grid, flat.best_grid("potrf", 16384, 0, 1024, 8));
    }

    #[test]
    fn fabric_router_pins_the_two_node_crossover() {
        // The 1-node-vs-2-node decision on a 2×8 H200 fabric, f64.
        // potrf T=1024: at N=16384 the inter-node collectives cost
        // more than the second island's compute saves — the router
        // confines the solve to one island (8 devices, flat NVLink
        // pricing). By N=65536 the trailing-update flops dominate and
        // spanning all 16 devices wins strictly. syevd's stage-1 is
        // compute-bound from tiny N (its collectives carry row
        // segments, not panels), so the fabric pays for itself by
        // N=4096 already.
        let pf = Predictor::fabric(2, 8, DType::F64);
        let (used_small, grid_small) = pf.best_fabric_plan("potrf", 16384, 0, 1024);
        assert_eq!(used_small, 8, "N=16384 potrf must stay on one island, got {grid_small:?}");
        let (used_big, grid_big) = pf.best_fabric_plan("potrf", 65536, 0, 1024);
        assert_eq!(used_big, 16, "N=65536 potrf must span the fabric");
        assert_eq!(grid_big.0 * grid_big.1, 16);
        let (used_sy, grid_sy) = pf.best_fabric_plan("syevd", 4096, 0, 256);
        assert_eq!(used_sy, 16, "N=4096 syevd must span the fabric, got {grid_sy:?}");
        // The spanning decision is a strict win, not a tie-break: the
        // router keeps ties on one island.
        let island = pf.topo.island_devices(0);
        let sub = Predictor {
            model: pf.model.clone(),
            topo: pf.topo.subset(&island).unwrap(),
            dtype: pf.dtype,
        };
        let sg = sub.best_grid("potrf", 65536, 0, 1024, 8);
        let fg = pf.best_grid("potrf", 65536, 0, 1024, 16);
        assert!(
            pf.dist_makespan("potrf", 65536, 0, 1024, fg.0, fg.1)
                < sub.dist_makespan("potrf", 65536, 0, 1024, sg.0, sg.1)
        );
    }

    #[test]
    fn fabric_island_alignment_beats_straddling_rows() {
        // Grid-shape pricing on the fabric: row groups are `q`
        // consecutive devices, so they stay island-local exactly when
        // `q` divides the island width. On a 2×8 fabric every proper
        // factorization of 16 aligns (q ∈ {1, 2, 4, 8}); only the 1D
        // (1, 16) row spans both islands. A 2×6 fabric exposes a true
        // straddle: q = 4 does not divide 6.
        let pf = Predictor::fabric(2, 8, DType::F64);
        assert!(!pf.row_groups_cross(2, 8));
        assert!(!pf.row_groups_cross(4, 4));
        assert!(pf.row_groups_cross(1, 16));
        let pf26 = Predictor::fabric(2, 6, DType::F64);
        assert!(!pf26.row_groups_cross(2, 6));
        assert!(pf26.row_groups_cross(3, 4));
        // Hierarchical collectives price the inter-node pipe: the same
        // spanning grid is strictly slower on the fabric than on a
        // flat 16-device node.
        let flat = Predictor::h200(16, DType::F64);
        assert!(pf.potrf2d_fabric(16384, 1024, 4, 4) > flat.potrf2d(16384, 1024, 4, 4));
        assert!(pf.syevd2d_fabric(16384, 256, 4, 4) > flat.syevd2d(16384, 256, 4, 4));
    }
}
