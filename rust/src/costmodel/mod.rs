//! Analytic H200 performance model.
//!
//! The paper's Figure 3 reports wall-clock on 8×H200; our substrate is
//! a CPU simulator, so absolute times cannot match. Instead the
//! benchmark harness reports two columns:
//!
//! 1. **measured** — real wall-clock of the simulator (structure only),
//! 2. **projected** — simulated-clock time accumulated from this model:
//!    each tile kernel charges `flops / rate + launch_overhead` to its
//!    device's timeline, each peer copy charges the NVLink link model.
//!
//! The *shape* of the projected curves (who wins at which N, how T_A
//! moves the potri curve but not syevd, where the single-GPU baseline
//! runs out of memory) is what reproduces the paper; see
//! EXPERIMENTS.md for the side-by-side.
//!
//! Rates are public constants so the benches can print the assumptions
//! next to the results.

pub mod predict;

pub use predict::{plan_memo_stats, Predictor};

use crate::scalar::DType;

/// Throughput/latency constants for one GPU class.
#[derive(Clone, Debug)]
pub struct GpuCostModel {
    /// Dense f32 GEMM throughput, FLOP/s (H200 ~60 TFLOP/s sustained FP32 CUDA cores;
    /// cuSOLVER dense kernels do not hit TF32 tensor peaks).
    pub f32_flops: f64,
    /// Dense f64 GEMM throughput, FLOP/s (H200 ~30 TFLOP/s sustained).
    pub f64_flops: f64,
    /// Efficiency factor for panel kernels (potf2/trsm are memory- and
    /// latency-bound relative to GEMM).
    pub panel_efficiency: f64,
    /// Effective bandwidth for BLAS-2 (HBM-bound) eigensolver stages.
    pub blas2_bytes_per_s: f64,
    /// Kernel launch + cuSOLVERMg bookkeeping overhead per call, s.
    pub launch_overhead: f64,
    /// `cudaIpcGetMemHandle` cost per export, s (MPMD mode only —
    /// driver bookkeeping in the exporting process).
    pub ipc_export_s: f64,
    /// `cudaIpcOpenMemHandle` cost per open, s (MPMD mode only — the
    /// dominant term: the opening process maps the foreign allocation
    /// into its virtual address space).
    pub ipc_open_s: f64,
}

impl Default for GpuCostModel {
    fn default() -> Self {
        Self::h200()
    }
}

impl GpuCostModel {
    /// H200-class constants.
    pub fn h200() -> Self {
        GpuCostModel {
            f32_flops: 60e12,
            f64_flops: 30e12,
            panel_efficiency: 0.25,
            blas2_bytes_per_s: 4.0e12, // ~83% of 4.8 TB/s HBM3e
            launch_overhead: 8e-6,
            ipc_export_s: 5e-6,
            ipc_open_s: 15e-6,
        }
    }

    /// GEMM-class rate for a dtype, FLOP/s. Complex arithmetic runs on
    /// the same FMA pipes; FLOP counts below already scale by 4× for
    /// complex so the *rate* stays the real-field rate.
    pub fn rate(&self, dtype: DType) -> f64 {
        match dtype.real_dtype() {
            DType::F32 => self.f32_flops,
            _ => self.f64_flops,
        }
    }

    /// FLOPs of `C += A·B` with shapes m×k · k×n (×4 for complex,
    /// counting one complex multiply-add as 4 real multiply-adds).
    pub fn flops_gemm(dtype: DType, m: usize, n: usize, k: usize) -> u64 {
        let base = 2.0 * m as f64 * n as f64 * k as f64;
        (if dtype.is_complex() { 4.0 * base } else { base }) as u64
    }

    /// FLOPs of a tile Cholesky (n³/3).
    pub fn flops_potf2(dtype: DType, n: usize) -> u64 {
        let base = (n as f64).powi(3) / 3.0;
        (if dtype.is_complex() { 4.0 * base } else { base }) as u64
    }

    /// FLOPs of a triangular solve: `m×n` RHS against a `tri×tri` triangle.
    pub fn flops_trsm(dtype: DType, m: usize, n: usize, tri: usize) -> u64 {
        let base = m as f64 * n as f64 * tri as f64;
        (if dtype.is_complex() { 4.0 * base } else { base }) as u64
    }

    /// GEMM utilization ramp: small blocks under-fill the SMs/MXU, so
    /// effective throughput scales with the smallest dimension. This is
    /// the term behind the paper's "larger tile sizes improve
    /// performance only once the problem size is sufficiently large"
    /// (Fig. 3) — T_A sets the block sizes of every trailing update.
    pub fn gemm_utilization(min_dim: usize) -> f64 {
        let d = min_dim as f64;
        d / (d + 192.0)
    }

    /// Modeled duration of a GEMM-class kernel.
    pub fn gemm_time(&self, dtype: DType, m: usize, n: usize, k: usize) -> f64 {
        let util = Self::gemm_utilization(m.min(n).min(k));
        self.launch_overhead + Self::flops_gemm(dtype, m, n, k) as f64 / (self.rate(dtype) * util)
    }

    /// Modeled duration of a panel kernel (potf2/trsm), which runs at a
    /// fraction of GEMM throughput.
    pub fn panel_time(&self, dtype: DType, flops: u64) -> f64 {
        self.launch_overhead + flops as f64 / (self.rate(dtype) * self.panel_efficiency)
    }

    /// Modeled duration of a BLAS-2 (bandwidth-bound) stage touching
    /// `bytes` of HBM.
    pub fn blas2_time(&self, bytes: u64) -> f64 {
        self.launch_overhead + bytes as f64 / self.blas2_bytes_per_s
    }
}

/// Workspace-size formulas (bytes) mirroring cuSOLVERMg's requirements;
/// these drive the "largest solvable N" capacity tables (§3: syevd and
/// potri need significantly more workspace than potrs).
pub mod workspace {
    use crate::scalar::DType;

    /// potrs: the factored matrix itself plus one panel of width `t` and
    /// the replicated right-hand side, per device.
    pub fn potrs_bytes(n: usize, nrhs: usize, t: usize, ndev: usize, dtype: DType) -> usize {
        let e = dtype.size_of();
        let matrix_per_dev = n * n.div_ceil(ndev) * e;
        let panel = n * t * e; // broadcast panel scratch
        let rhs = n * nrhs * e; // replicated b
        matrix_per_dev + panel + rhs
    }

    /// potri: adds the L⁻¹ working copy (the inverse is accumulated
    /// out-of-place before the symmetric product).
    pub fn potri_bytes(n: usize, t: usize, ndev: usize, dtype: DType) -> usize {
        let e = dtype.size_of();
        let matrix_per_dev = n * n.div_ceil(ndev) * e;
        let linv_per_dev = n * n.div_ceil(ndev) * e;
        let panel = 2 * n * t * e;
        matrix_per_dev + linv_per_dev + panel
    }

    /// syevd: matrix + full eigenvector matrix + back-transform scratch
    /// (the dominant workspace term in cuSOLVERMg).
    pub fn syevd_bytes(n: usize, t: usize, ndev: usize, dtype: DType) -> usize {
        let e = dtype.size_of();
        let matrix_per_dev = n * n.div_ceil(ndev) * e;
        let vectors_per_dev = n * n.div_ceil(ndev) * e;
        let scratch = 2 * n * n.div_ceil(ndev) * e;
        let panel = n * t.max(1) * e;
        matrix_per_dev + vectors_per_dev + scratch + panel
    }

    /// Largest N (refined in `step` increments) whose per-device
    /// footprint fits in `vram_bytes`.
    pub fn largest_n(
        vram_bytes: usize,
        ndev: usize,
        t: usize,
        dtype: DType,
        routine: &str,
        step: usize,
    ) -> usize {
        let fits = |n: usize| -> bool {
            let need = match routine {
                "potrs" => potrs_bytes(n, 1, t, ndev, dtype),
                "potri" => potri_bytes(n, t, ndev, dtype),
                "syevd" => syevd_bytes(n, t, ndev, dtype),
                _ => usize::MAX,
            };
            need <= vram_bytes
        };
        let mut n = step;
        if !fits(n) {
            return 0;
        }
        while fits(n * 2) {
            n *= 2;
        }
        while fits(n + step) {
            n += step;
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn complex_flops_scale_4x() {
        assert_eq!(
            GpuCostModel::flops_gemm(DType::C128, 8, 8, 8),
            4 * GpuCostModel::flops_gemm(DType::F64, 8, 8, 8)
        );
    }

    #[test]
    fn f64_slower_than_f32() {
        let m = GpuCostModel::h200();
        assert!(m.gemm_time(DType::F64, 512, 512, 512) > m.gemm_time(DType::F32, 512, 512, 512));
    }

    #[test]
    fn launch_overhead_dominates_tiny_kernels() {
        let m = GpuCostModel::h200();
        let t = m.gemm_time(DType::F32, 4, 4, 4);
        assert!((t - m.launch_overhead) / m.launch_overhead < 0.01);
    }

    #[test]
    fn workspace_ordering_matches_paper() {
        // §3: "Both syevd and potri require significantly more workspace
        // memory than potrs, which is reflected in the matrix sizes that
        // can be reached."
        let n = 1 << 14;
        let t = 256;
        let d = 8;
        let potrs = workspace::potrs_bytes(n, 1, t, d, DType::F64);
        let potri = workspace::potri_bytes(n, t, d, DType::F64);
        let syevd = workspace::syevd_bytes(n, t, d, DType::F64);
        assert!(potri > potrs);
        assert!(syevd > potri);
    }

    #[test]
    fn largest_n_monotone_in_vram() {
        let small = workspace::largest_n(1 << 30, 8, 256, DType::F32, "potrs", 1024);
        let large = workspace::largest_n(1 << 34, 8, 256, DType::F32, "potrs", 1024);
        assert!(large > small);
    }

    #[test]
    fn paper_scale_largest_potrs_n() {
        // Paper: largest solvable potrs float32 problem on 8×143 GB is
        // N = 524288 (>1 TB aggregate). Our formula should land in the
        // same order of magnitude.
        let vram = 143usize * 1000 * 1000 * 1000;
        let n = workspace::largest_n(vram, 8, 1024, DType::F32, "potrs", 4096);
        assert!((400_000..=700_000).contains(&n), "largest potrs N = {n}");
    }

    #[test]
    fn tiny_vram_gives_zero() {
        assert_eq!(workspace::largest_n(16, 8, 256, DType::F64, "syevd", 1024), 0);
    }
}
