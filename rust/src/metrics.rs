//! Lightweight atomic counters for the coordinator hot path.
//!
//! Everything the benchmark harness reports — bytes moved over peer
//! links, kernel launches, redistribution cycle counts — flows through
//! [`Metrics`]. Counters are lock-free atomics so SPMD worker threads
//! can bump them concurrently without serializing the hot path.

use std::sync::atomic::{AtomicU64, Ordering};

/// Shared counters; cloned cheaply via `Arc` by every subsystem.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Bytes moved device→device (the `cudaMemcpyPeerAsync` analogue).
    pub peer_bytes: AtomicU64,
    /// Number of peer-to-peer copy operations.
    pub peer_copies: AtomicU64,
    /// Bytes moved host→device.
    pub h2d_bytes: AtomicU64,
    /// Bytes moved device→host.
    pub d2h_bytes: AtomicU64,
    /// Bytes copied within a single device.
    pub local_bytes: AtomicU64,
    /// Tile-kernel launches (potf2/trsm/gemm/...).
    pub kernel_launches: AtomicU64,
    /// Floating-point operations charged by kernels.
    pub flops: AtomicU64,
    /// Device allocations made.
    pub allocs: AtomicU64,
    /// Device allocations released.
    pub frees: AtomicU64,
    /// Permutation cycles executed by the redistributor.
    pub redist_cycles: AtomicU64,
    /// Columns rotated by the redistributor.
    pub redist_columns: AtomicU64,
}

impl Metrics {
    /// Fresh zeroed metrics.
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn add_peer(&self, bytes: u64) {
        self.peer_bytes.fetch_add(bytes, Ordering::Relaxed);
        self.peer_copies.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add_h2d(&self, bytes: u64) {
        self.h2d_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    #[inline]
    pub fn add_d2h(&self, bytes: u64) {
        self.d2h_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    #[inline]
    pub fn add_local(&self, bytes: u64) {
        self.local_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    #[inline]
    pub fn add_kernel(&self, flops: u64) {
        self.kernel_launches.fetch_add(1, Ordering::Relaxed);
        self.flops.fetch_add(flops, Ordering::Relaxed);
    }

    /// Snapshot all counters (for reports; not atomic across fields).
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            peer_bytes: self.peer_bytes.load(Ordering::Relaxed),
            peer_copies: self.peer_copies.load(Ordering::Relaxed),
            h2d_bytes: self.h2d_bytes.load(Ordering::Relaxed),
            d2h_bytes: self.d2h_bytes.load(Ordering::Relaxed),
            local_bytes: self.local_bytes.load(Ordering::Relaxed),
            kernel_launches: self.kernel_launches.load(Ordering::Relaxed),
            flops: self.flops.load(Ordering::Relaxed),
            allocs: self.allocs.load(Ordering::Relaxed),
            frees: self.frees.load(Ordering::Relaxed),
            redist_cycles: self.redist_cycles.load(Ordering::Relaxed),
            redist_columns: self.redist_columns.load(Ordering::Relaxed),
        }
    }

    /// Reset all counters to zero (between benchmark repetitions).
    pub fn reset(&self) {
        for c in [
            &self.peer_bytes,
            &self.peer_copies,
            &self.h2d_bytes,
            &self.d2h_bytes,
            &self.local_bytes,
            &self.kernel_launches,
            &self.flops,
            &self.allocs,
            &self.frees,
            &self.redist_cycles,
            &self.redist_columns,
        ] {
            c.store(0, Ordering::Relaxed);
        }
    }
}

/// Plain-old-data copy of the counters at one instant.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct MetricsSnapshot {
    pub peer_bytes: u64,
    pub peer_copies: u64,
    pub h2d_bytes: u64,
    pub d2h_bytes: u64,
    pub local_bytes: u64,
    pub kernel_launches: u64,
    pub flops: u64,
    pub allocs: u64,
    pub frees: u64,
    pub redist_cycles: u64,
    pub redist_columns: u64,
}

impl MetricsSnapshot {
    /// Difference against an earlier snapshot (per-phase accounting).
    pub fn delta(&self, earlier: &MetricsSnapshot) -> MetricsSnapshot {
        MetricsSnapshot {
            peer_bytes: self.peer_bytes - earlier.peer_bytes,
            peer_copies: self.peer_copies - earlier.peer_copies,
            h2d_bytes: self.h2d_bytes - earlier.h2d_bytes,
            d2h_bytes: self.d2h_bytes - earlier.d2h_bytes,
            local_bytes: self.local_bytes - earlier.local_bytes,
            kernel_launches: self.kernel_launches - earlier.kernel_launches,
            flops: self.flops - earlier.flops,
            allocs: self.allocs - earlier.allocs,
            frees: self.frees - earlier.frees,
            redist_cycles: self.redist_cycles - earlier.redist_cycles,
            redist_columns: self.redist_columns - earlier.redist_columns,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.add_peer(100);
        m.add_peer(50);
        m.add_kernel(1000);
        let s = m.snapshot();
        assert_eq!(s.peer_bytes, 150);
        assert_eq!(s.peer_copies, 2);
        assert_eq!(s.kernel_launches, 1);
        assert_eq!(s.flops, 1000);
    }

    #[test]
    fn reset_zeroes() {
        let m = Metrics::new();
        m.add_h2d(7);
        m.reset();
        assert_eq!(m.snapshot(), MetricsSnapshot::default());
    }

    #[test]
    fn delta_subtracts() {
        let m = Metrics::new();
        m.add_peer(10);
        let a = m.snapshot();
        m.add_peer(30);
        let b = m.snapshot();
        let d = b.delta(&a);
        assert_eq!(d.peer_bytes, 30);
        assert_eq!(d.peer_copies, 1);
    }

    #[test]
    fn concurrent_bumps() {
        use std::sync::Arc;
        let m = Arc::new(Metrics::new());
        let mut handles = vec![];
        for _ in 0..8 {
            let m = m.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    m.add_peer(1);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.snapshot().peer_bytes, 8000);
    }
}
