//! Lightweight atomic counters for the coordinator hot path.
//!
//! Everything the benchmark harness reports — bytes moved over peer
//! links, kernel launches, redistribution cycle counts — flows through
//! [`Metrics`]. Counters are lock-free atomics so SPMD worker threads
//! can bump them concurrently without serializing the hot path.

use crate::coordinator::SloClass;
use std::sync::atomic::{AtomicU64, Ordering};

/// Log-bucketed latency histogram for one SLO class: bucket `k` counts
/// completions with latency in `[2^k, 2^(k+1))` cost-model ns (bucket 0
/// also holds zero-latency completions). 64 buckets span all of `u64`,
/// updates are a single `fetch_add`, and percentile reads resolve to
/// the bucket's inclusive upper bound — a conservative (never
/// under-reported) estimate.
#[derive(Debug)]
pub struct ClassLatency {
    completed: AtomicU64,
    deadline_misses: AtomicU64,
    buckets: [AtomicU64; 64],
}

impl Default for ClassLatency {
    fn default() -> Self {
        ClassLatency {
            completed: AtomicU64::new(0),
            deadline_misses: AtomicU64::new(0),
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }
}

impl ClassLatency {
    fn bucket(latency_ns: u64) -> usize {
        (63 - latency_ns.max(1).leading_zeros()) as usize
    }

    fn record(&self, latency_ns: u64) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.buckets[Self::bucket(latency_ns)].fetch_add(1, Ordering::Relaxed);
    }

    fn percentile(&self, q: f64) -> u64 {
        let total = self.completed.load(Ordering::Relaxed);
        if total == 0 {
            return 0;
        }
        let target = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut cum = 0u64;
        for (k, b) in self.buckets.iter().enumerate() {
            cum += b.load(Ordering::Relaxed);
            if cum >= target {
                return if k >= 63 { u64::MAX } else { (1u64 << (k + 1)) - 1 };
            }
        }
        u64::MAX
    }

    fn reset(&self) {
        self.completed.store(0, Ordering::Relaxed);
        self.deadline_misses.store(0, Ordering::Relaxed);
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
    }

    /// Non-empty buckets as `(inclusive_upper_bound_ns, count)` pairs,
    /// in increasing bound order (bucket 63's bound saturates at
    /// `u64::MAX`). The shape Prometheus histogram exposition wants.
    fn histogram(&self) -> Vec<(u64, u64)> {
        self.buckets
            .iter()
            .enumerate()
            .filter_map(|(k, b)| {
                let n = b.load(Ordering::Relaxed);
                if n == 0 {
                    return None;
                }
                let bound = if k >= 63 { u64::MAX } else { (1u64 << (k + 1)) - 1 };
                Some((bound, n))
            })
            .collect()
    }
}

/// Shared counters; cloned cheaply via `Arc` by every subsystem.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Bytes moved device→device (the `cudaMemcpyPeerAsync` analogue).
    pub peer_bytes: AtomicU64,
    /// Number of peer-to-peer copy operations.
    pub peer_copies: AtomicU64,
    /// Bytes moved host→device.
    pub h2d_bytes: AtomicU64,
    /// Bytes moved device→host.
    pub d2h_bytes: AtomicU64,
    /// Bytes copied within a single device.
    pub local_bytes: AtomicU64,
    /// Tile-kernel launches (potf2/trsm/gemm/...).
    pub kernel_launches: AtomicU64,
    /// Floating-point operations charged by kernels.
    pub flops: AtomicU64,
    /// Device allocations made.
    pub allocs: AtomicU64,
    /// Device allocations released.
    pub frees: AtomicU64,
    /// Permutation cycles executed by the redistributor.
    pub redist_cycles: AtomicU64,
    /// Columns rotated by the redistributor.
    pub redist_columns: AtomicU64,
    /// Solve requests submitted to the concurrent solve service.
    pub service_submitted: AtomicU64,
    /// Solve requests completed by the concurrent solve service.
    pub service_completed: AtomicU64,
    /// Total **cost-model (simulated)** ns solves spent queued before
    /// admission — same integer-ns timeline as the golden timelines.
    pub service_queue_wait_ns: AtomicU64,
    /// Total cost-model ns from admission to completion.
    pub service_exec_ns: AtomicU64,
    /// Large solves preempted at a panel boundary so a
    /// latency-sensitive request could run.
    pub service_preemptions: AtomicU64,
    /// Per-SLO-class latency histograms (queue wait + exec, cost-model
    /// ns), indexed by [`SloClass::index`].
    pub class_latency: [ClassLatency; 3],
    /// Busy stream-seconds issued by pipelined phases, ns
    /// (overlap-efficiency numerator).
    pub overlap_busy_ns: AtomicU64,
    /// Device-seconds spanned by pipelined phases (`ndev × span`), ns
    /// (overlap-efficiency denominator).
    pub overlap_span_ns: AtomicU64,
    /// Coalesced buckets swept by the batched small-solve path.
    pub batch_buckets: AtomicU64,
    /// Small solves served through a batched sweep (occupancy
    /// numerator; `batch_solves / batch_buckets` is the mean bucket
    /// occupancy).
    pub batch_solves: AtomicU64,
    /// Largest bucket occupancy seen.
    pub batch_peak_occupancy: AtomicU64,
    /// Total cost-model ns small solves dwelled in the coalescer
    /// before their bucket flushed.
    pub batch_coalesce_wait_ns: AtomicU64,
    /// Total charged makespan of the batched sweeps, ns (one entry per
    /// bucket: the sum over the bucket's sweeps of each sweep's
    /// largest per-device fused-kernel charge — measured from the
    /// charges themselves, so concurrent tenants on the shared node
    /// cannot skew it).
    pub batch_makespan_ns: AtomicU64,
    /// `cudaIpcGetMemHandle` analogues issued (MPMD shard exports).
    pub ipc_exports: AtomicU64,
    /// `cudaIpcOpenMemHandle` analogues issued by the single caller.
    pub ipc_opens: AtomicU64,
    /// `cudaIpcCloseMemHandle` analogues issued by the single caller.
    pub ipc_closes: AtomicU64,
    /// Handles revoked (explicitly, or by freeing an exported shard).
    pub ipc_revokes: AtomicU64,
    /// Requests the MPMD frontend routed (dispatched to workers).
    pub mpmd_routed: AtomicU64,
    /// Total frontend routing latency, ns: submit → dispatch handoff
    /// (queueing + admission across the live worker set).
    pub mpmd_routing_ns: AtomicU64,
    /// Requests re-queued after a worker panic/kill, with the dead
    /// device excluded from the retry.
    pub mpmd_requeues: AtomicU64,
    /// Deepest per-worker mailbox observed at enqueue time.
    pub mpmd_peak_worker_queue: AtomicU64,
    /// Distributed solves executed grid-natively on a `P > 1` grid
    /// (the 2D execution path; 1D solves do not count here).
    pub grid_solves: AtomicU64,
    /// Largest grid-row count `P` chosen for any grid-native solve.
    pub grid_peak_p: AtomicU64,
    /// Largest grid-column count `Q` chosen for any grid-native solve.
    pub grid_peak_q: AtomicU64,
    /// Bytes carried by **row-ring** collectives (panel segments moving
    /// along grid rows — the 2D replacement for devices-wide panel
    /// broadcasts).
    pub grid_row_bytes: AtomicU64,
    /// Bytes carried by **column-ring** collectives (diagonal blocks,
    /// transposed panels and partial-result reductions moving along
    /// grid columns).
    pub grid_col_bytes: AtomicU64,
    /// Distributed solves served from a resident cached factor (the
    /// potrf — and its scatter — skipped entirely).
    pub cache_hits: AtomicU64,
    /// Cache probes that found no usable entry (cold factorizations
    /// with the cache enabled).
    pub cache_misses: AtomicU64,
    /// Resident factors evicted to make room (scored by predicted
    /// recompute cost × observed reuse).
    pub cache_evictions: AtomicU64,
    /// Bytes of factor shards currently resident in device memory
    /// across the cache (a gauge, not a flow).
    pub cache_resident_bytes: AtomicU64,
    /// Extra stages executed inside fused solve DAGs: a fused
    /// `potrf→potrs→potri` chain counts its stages beyond the first
    /// (each one skipped a scatter/factor round-trip).
    pub dag_fused_stages: AtomicU64,
    /// Bytes that crossed the inter-node fabric (island-crossing
    /// transfers: hierarchical-broadcast representative hops and
    /// direct cross-island peer copies).
    pub fabric_inter_bytes: AtomicU64,
    /// Bytes moved island-locally by hierarchical collectives (home
    /// fan-out shares plus representative relays).
    pub fabric_intra_bytes: AtomicU64,
    /// Hierarchical (ring-of-rings) broadcasts executed.
    pub fabric_bcasts: AtomicU64,
    /// Total stages across hierarchical broadcasts (fabric crossing +
    /// home fan-out + one relay per remote island with members beyond
    /// its representative); `/ fabric_bcasts` is the mean depth.
    pub fabric_bcast_stages: AtomicU64,
    /// Peak admitted bytes per island (high-water marks, one slot per
    /// island; islands beyond slot 7 share the last slot).
    pub fabric_island_peak_bytes: [AtomicU64; 8],
    /// Distributed solves completed through the mixed-precision tier
    /// (working-dtype factor + f64 iterative refinement).
    pub mixed_solves: AtomicU64,
    /// Mixed attempts that hit the refinement cap (or stalled, or lost
    /// definiteness when demoted) and fell back to full precision.
    pub mixed_fallbacks: AtomicU64,
    /// Modeled bytes the working dtype saved vs running the same
    /// solves at full precision (factor storage/traffic + RHS round
    /// trips at half the element size).
    pub mixed_bytes_saved: AtomicU64,
    /// Histogram of refinement iteration counts per successful mixed
    /// solve: bucket `k` counts solves that needed `k` correction
    /// solves; bucket 15 holds `>= 15`.
    pub refine_iters: [AtomicU64; 16],
}

impl Metrics {
    /// Fresh zeroed metrics.
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn add_peer(&self, bytes: u64) {
        self.peer_bytes.fetch_add(bytes, Ordering::Relaxed);
        self.peer_copies.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add_h2d(&self, bytes: u64) {
        self.h2d_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    #[inline]
    pub fn add_d2h(&self, bytes: u64) {
        self.d2h_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    #[inline]
    pub fn add_local(&self, bytes: u64) {
        self.local_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    #[inline]
    pub fn add_kernel(&self, flops: u64) {
        self.kernel_launches.fetch_add(1, Ordering::Relaxed);
        self.flops.fetch_add(flops, Ordering::Relaxed);
    }

    #[inline]
    pub fn add_service_submission(&self) {
        self.service_submitted.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add_service_completion(&self, queue_wait_ns: u64, exec_ns: u64) {
        self.service_completed.fetch_add(1, Ordering::Relaxed);
        self.service_queue_wait_ns.fetch_add(queue_wait_ns, Ordering::Relaxed);
        self.service_exec_ns.fetch_add(exec_ns, Ordering::Relaxed);
    }

    /// Record one completed request's end-to-end latency (queue wait +
    /// exec, cost-model ns) against its SLO class; `missed_deadline`
    /// marks it against the class's deadline-miss count too.
    #[inline]
    pub fn record_class_latency(&self, class: SloClass, latency_ns: u64, missed_deadline: bool) {
        let h = &self.class_latency[class.index()];
        h.record(latency_ns);
        if missed_deadline {
            h.deadline_misses.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Record one panel-boundary preemption.
    #[inline]
    pub fn note_preemption(&self) {
        self.service_preemptions.fetch_add(1, Ordering::Relaxed);
    }

    /// Latency percentile (`q` in `[0, 1]`) for one SLO class, from
    /// the live histogram — `0` when the class has no completions.
    pub fn latency_percentile(&self, class: SloClass, q: f64) -> u64 {
        self.class_latency[class.index()].percentile(q)
    }

    /// One class's latency histogram as `(inclusive_upper_bound_ns,
    /// count)` pairs over the non-empty log buckets — empty classes
    /// yield an empty vec. Feeds the Prometheus text exposition in
    /// `obs::export`.
    pub fn class_histogram(&self, class: SloClass) -> Vec<(u64, u64)> {
        self.class_latency[class.index()].histogram()
    }

    #[inline]
    pub fn add_overlap(&self, busy_ns: u64, span_ns: u64) {
        self.overlap_busy_ns.fetch_add(busy_ns, Ordering::Relaxed);
        self.overlap_span_ns.fetch_add(span_ns, Ordering::Relaxed);
    }

    /// Record one swept bucket of the batched small-solve path.
    #[inline]
    pub fn add_batch_bucket(&self, occupancy: u64, coalesce_wait_ns: u64, makespan_ns: u64) {
        self.batch_buckets.fetch_add(1, Ordering::Relaxed);
        self.batch_solves.fetch_add(occupancy, Ordering::Relaxed);
        self.batch_peak_occupancy.fetch_max(occupancy, Ordering::Relaxed);
        self.batch_coalesce_wait_ns.fetch_add(coalesce_wait_ns, Ordering::Relaxed);
        self.batch_makespan_ns.fetch_add(makespan_ns, Ordering::Relaxed);
    }

    #[inline]
    pub fn add_ipc_export(&self) {
        self.ipc_exports.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add_ipc_open(&self) {
        self.ipc_opens.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add_ipc_close(&self) {
        self.ipc_closes.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add_ipc_revokes(&self, n: u64) {
        self.ipc_revokes.fetch_add(n, Ordering::Relaxed);
    }

    /// Record one MPMD frontend routing decision (submit → dispatch).
    #[inline]
    pub fn add_mpmd_routed(&self, routing_ns: u64) {
        self.mpmd_routed.fetch_add(1, Ordering::Relaxed);
        self.mpmd_routing_ns.fetch_add(routing_ns, Ordering::Relaxed);
    }

    /// Record one failure-driven re-queue (device excluded on retry).
    #[inline]
    pub fn add_mpmd_requeue(&self) {
        self.mpmd_requeues.fetch_add(1, Ordering::Relaxed);
    }

    /// Track the deepest worker mailbox seen at enqueue time.
    #[inline]
    pub fn note_worker_queue_depth(&self, depth: u64) {
        self.mpmd_peak_worker_queue.fetch_max(depth, Ordering::Relaxed);
    }

    /// Record one grid-native (`P > 1`) distributed solve and the grid
    /// shape it executed on.
    #[inline]
    pub fn note_grid_solve(&self, p: u64, q: u64) {
        self.grid_solves.fetch_add(1, Ordering::Relaxed);
        self.grid_peak_p.fetch_max(p, Ordering::Relaxed);
        self.grid_peak_q.fetch_max(q, Ordering::Relaxed);
    }

    /// Count bytes carried by a row-ring collective.
    #[inline]
    pub fn add_grid_row_bytes(&self, bytes: u64) {
        self.grid_row_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Count bytes carried by a column-ring collective.
    #[inline]
    pub fn add_grid_col_bytes(&self, bytes: u64) {
        self.grid_col_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Record one factor-cache hit.
    #[inline]
    pub fn add_cache_hit(&self) {
        self.cache_hits.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one factor-cache miss (cold factorization, cache on).
    #[inline]
    pub fn add_cache_miss(&self) {
        self.cache_misses.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one factor eviction.
    #[inline]
    pub fn add_cache_eviction(&self) {
        self.cache_evictions.fetch_add(1, Ordering::Relaxed);
    }

    /// Adjust the resident-factor-bytes gauge by `delta` (positive on
    /// insert, negative on eviction/invalidation).
    #[inline]
    pub fn add_cache_resident_bytes(&self, delta: i64) {
        if delta >= 0 {
            self.cache_resident_bytes.fetch_add(delta as u64, Ordering::Relaxed);
        } else {
            self.cache_resident_bytes.fetch_sub((-delta) as u64, Ordering::Relaxed);
        }
    }

    /// Record the extra stages of one fused solve DAG (`stages - 1`
    /// for a chain of `stages` routines).
    #[inline]
    pub fn add_dag_fused_stages(&self, extra: u64) {
        self.dag_fused_stages.fetch_add(extra, Ordering::Relaxed);
    }

    /// Count bytes that crossed the inter-node fabric.
    #[inline]
    pub fn add_fabric_inter(&self, bytes: u64) {
        self.fabric_inter_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Count bytes a hierarchical collective moved island-locally.
    #[inline]
    pub fn add_fabric_intra(&self, bytes: u64) {
        self.fabric_intra_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Record one hierarchical broadcast and its stage count.
    #[inline]
    pub fn add_fabric_bcast(&self, stages: u64) {
        self.fabric_bcasts.fetch_add(1, Ordering::Relaxed);
        self.fabric_bcast_stages.fetch_add(stages, Ordering::Relaxed);
    }

    /// Raise island `island`'s peak-admitted-bytes high-water mark.
    #[inline]
    pub fn note_island_admitted(&self, island: usize, bytes: u64) {
        let slot = island.min(self.fabric_island_peak_bytes.len() - 1);
        self.fabric_island_peak_bytes[slot].fetch_max(bytes, Ordering::Relaxed);
    }

    /// Record one completed mixed-precision solve.
    #[inline]
    pub fn add_mixed_solve(&self) {
        self.mixed_solves.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one mixed attempt that fell back to full precision.
    #[inline]
    pub fn add_mixed_fallback(&self) {
        self.mixed_fallbacks.fetch_add(1, Ordering::Relaxed);
    }

    /// Count modeled bytes saved by a mixed solve's working dtype.
    #[inline]
    pub fn add_mixed_bytes_saved(&self, bytes: u64) {
        self.mixed_bytes_saved.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Record a successful mixed solve's refinement iteration count.
    #[inline]
    pub fn record_refine_iters(&self, iters: u64) {
        let slot = (iters as usize).min(self.refine_iters.len() - 1);
        self.refine_iters[slot].fetch_add(1, Ordering::Relaxed);
    }

    /// Snapshot all counters (for reports; not atomic across fields).
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            peer_bytes: self.peer_bytes.load(Ordering::Relaxed),
            peer_copies: self.peer_copies.load(Ordering::Relaxed),
            h2d_bytes: self.h2d_bytes.load(Ordering::Relaxed),
            d2h_bytes: self.d2h_bytes.load(Ordering::Relaxed),
            local_bytes: self.local_bytes.load(Ordering::Relaxed),
            kernel_launches: self.kernel_launches.load(Ordering::Relaxed),
            flops: self.flops.load(Ordering::Relaxed),
            allocs: self.allocs.load(Ordering::Relaxed),
            frees: self.frees.load(Ordering::Relaxed),
            redist_cycles: self.redist_cycles.load(Ordering::Relaxed),
            redist_columns: self.redist_columns.load(Ordering::Relaxed),
            service_submitted: self.service_submitted.load(Ordering::Relaxed),
            service_completed: self.service_completed.load(Ordering::Relaxed),
            service_queue_wait_ns: self.service_queue_wait_ns.load(Ordering::Relaxed),
            service_exec_ns: self.service_exec_ns.load(Ordering::Relaxed),
            service_preemptions: self.service_preemptions.load(Ordering::Relaxed),
            class_completed: std::array::from_fn(|i| {
                self.class_latency[i].completed.load(Ordering::Relaxed)
            }),
            class_deadline_misses: std::array::from_fn(|i| {
                self.class_latency[i].deadline_misses.load(Ordering::Relaxed)
            }),
            class_p50_ns: std::array::from_fn(|i| self.class_latency[i].percentile(0.50)),
            class_p99_ns: std::array::from_fn(|i| self.class_latency[i].percentile(0.99)),
            overlap_busy_ns: self.overlap_busy_ns.load(Ordering::Relaxed),
            overlap_span_ns: self.overlap_span_ns.load(Ordering::Relaxed),
            batch_buckets: self.batch_buckets.load(Ordering::Relaxed),
            batch_solves: self.batch_solves.load(Ordering::Relaxed),
            batch_peak_occupancy: self.batch_peak_occupancy.load(Ordering::Relaxed),
            batch_coalesce_wait_ns: self.batch_coalesce_wait_ns.load(Ordering::Relaxed),
            batch_makespan_ns: self.batch_makespan_ns.load(Ordering::Relaxed),
            ipc_exports: self.ipc_exports.load(Ordering::Relaxed),
            ipc_opens: self.ipc_opens.load(Ordering::Relaxed),
            ipc_closes: self.ipc_closes.load(Ordering::Relaxed),
            ipc_revokes: self.ipc_revokes.load(Ordering::Relaxed),
            mpmd_routed: self.mpmd_routed.load(Ordering::Relaxed),
            mpmd_routing_ns: self.mpmd_routing_ns.load(Ordering::Relaxed),
            mpmd_requeues: self.mpmd_requeues.load(Ordering::Relaxed),
            mpmd_peak_worker_queue: self.mpmd_peak_worker_queue.load(Ordering::Relaxed),
            grid_solves: self.grid_solves.load(Ordering::Relaxed),
            grid_peak_p: self.grid_peak_p.load(Ordering::Relaxed),
            grid_peak_q: self.grid_peak_q.load(Ordering::Relaxed),
            grid_row_bytes: self.grid_row_bytes.load(Ordering::Relaxed),
            grid_col_bytes: self.grid_col_bytes.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.cache_misses.load(Ordering::Relaxed),
            cache_evictions: self.cache_evictions.load(Ordering::Relaxed),
            cache_resident_bytes: self.cache_resident_bytes.load(Ordering::Relaxed),
            dag_fused_stages: self.dag_fused_stages.load(Ordering::Relaxed),
            fabric_inter_bytes: self.fabric_inter_bytes.load(Ordering::Relaxed),
            fabric_intra_bytes: self.fabric_intra_bytes.load(Ordering::Relaxed),
            fabric_bcasts: self.fabric_bcasts.load(Ordering::Relaxed),
            fabric_bcast_stages: self.fabric_bcast_stages.load(Ordering::Relaxed),
            fabric_island_peak_bytes: std::array::from_fn(|i| {
                self.fabric_island_peak_bytes[i].load(Ordering::Relaxed)
            }),
            mixed_solves: self.mixed_solves.load(Ordering::Relaxed),
            mixed_fallbacks: self.mixed_fallbacks.load(Ordering::Relaxed),
            mixed_bytes_saved: self.mixed_bytes_saved.load(Ordering::Relaxed),
            refine_iters: std::array::from_fn(|i| self.refine_iters[i].load(Ordering::Relaxed)),
        }
    }

    /// Reset all counters to zero (between benchmark repetitions).
    pub fn reset(&self) {
        for c in [
            &self.peer_bytes,
            &self.peer_copies,
            &self.h2d_bytes,
            &self.d2h_bytes,
            &self.local_bytes,
            &self.kernel_launches,
            &self.flops,
            &self.allocs,
            &self.frees,
            &self.redist_cycles,
            &self.redist_columns,
            &self.service_submitted,
            &self.service_completed,
            &self.service_queue_wait_ns,
            &self.service_exec_ns,
            &self.service_preemptions,
            &self.overlap_busy_ns,
            &self.overlap_span_ns,
            &self.batch_buckets,
            &self.batch_solves,
            &self.batch_peak_occupancy,
            &self.batch_coalesce_wait_ns,
            &self.batch_makespan_ns,
            &self.ipc_exports,
            &self.ipc_opens,
            &self.ipc_closes,
            &self.ipc_revokes,
            &self.mpmd_routed,
            &self.mpmd_routing_ns,
            &self.mpmd_requeues,
            &self.mpmd_peak_worker_queue,
            &self.grid_solves,
            &self.grid_peak_p,
            &self.grid_peak_q,
            &self.grid_row_bytes,
            &self.grid_col_bytes,
            &self.cache_hits,
            &self.cache_misses,
            &self.cache_evictions,
            &self.cache_resident_bytes,
            &self.dag_fused_stages,
            &self.fabric_inter_bytes,
            &self.fabric_intra_bytes,
            &self.fabric_bcasts,
            &self.fabric_bcast_stages,
            &self.mixed_solves,
            &self.mixed_fallbacks,
            &self.mixed_bytes_saved,
        ] {
            c.store(0, Ordering::Relaxed);
        }
        for c in &self.fabric_island_peak_bytes {
            c.store(0, Ordering::Relaxed);
        }
        for c in &self.refine_iters {
            c.store(0, Ordering::Relaxed);
        }
        for h in &self.class_latency {
            h.reset();
        }
    }
}

/// Plain-old-data copy of the counters at one instant.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct MetricsSnapshot {
    pub peer_bytes: u64,
    pub peer_copies: u64,
    pub h2d_bytes: u64,
    pub d2h_bytes: u64,
    pub local_bytes: u64,
    pub kernel_launches: u64,
    pub flops: u64,
    pub allocs: u64,
    pub frees: u64,
    pub redist_cycles: u64,
    pub redist_columns: u64,
    pub service_submitted: u64,
    pub service_completed: u64,
    pub service_queue_wait_ns: u64,
    pub service_exec_ns: u64,
    pub service_preemptions: u64,
    /// Completions per SLO class, indexed by [`SloClass::index`].
    pub class_completed: [u64; 3],
    /// Deadline misses per SLO class (degraded-mode-adjusted).
    pub class_deadline_misses: [u64; 3],
    /// p50 end-to-end latency per class at snapshot time, cost-model
    /// ns (log-bucket upper bound; `0` = no completions).
    pub class_p50_ns: [u64; 3],
    /// p99 end-to-end latency per class at snapshot time, cost-model ns.
    pub class_p99_ns: [u64; 3],
    pub overlap_busy_ns: u64,
    pub overlap_span_ns: u64,
    pub batch_buckets: u64,
    pub batch_solves: u64,
    pub batch_peak_occupancy: u64,
    pub batch_coalesce_wait_ns: u64,
    pub batch_makespan_ns: u64,
    pub ipc_exports: u64,
    pub ipc_opens: u64,
    pub ipc_closes: u64,
    pub ipc_revokes: u64,
    pub mpmd_routed: u64,
    pub mpmd_routing_ns: u64,
    pub mpmd_requeues: u64,
    pub mpmd_peak_worker_queue: u64,
    pub grid_solves: u64,
    pub grid_peak_p: u64,
    pub grid_peak_q: u64,
    pub grid_row_bytes: u64,
    pub grid_col_bytes: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_evictions: u64,
    /// A gauge (bytes resident at snapshot time), not a flow.
    pub cache_resident_bytes: u64,
    pub dag_fused_stages: u64,
    pub fabric_inter_bytes: u64,
    pub fabric_intra_bytes: u64,
    pub fabric_bcasts: u64,
    pub fabric_bcast_stages: u64,
    /// Peak admitted bytes per island (high-water marks).
    pub fabric_island_peak_bytes: [u64; 8],
    pub mixed_solves: u64,
    pub mixed_fallbacks: u64,
    pub mixed_bytes_saved: u64,
    /// Refinement iteration histogram: slot `k` counts successful
    /// mixed solves that needed `k` correction solves (slot 15 = ≥15).
    pub refine_iters: [u64; 16],
}

impl MetricsSnapshot {
    /// Mean device utilization across pipelined phases: busy stream
    /// time over `ndev × span` device-seconds. Above the barrier
    /// schedule's value means compute/copy/panel overlap happened.
    pub fn overlap_efficiency(&self) -> f64 {
        if self.overlap_span_ns == 0 {
            0.0
        } else {
            self.overlap_busy_ns as f64 / self.overlap_span_ns as f64
        }
    }

    /// Mean queue wait of completed service solves, seconds.
    pub fn avg_queue_wait(&self) -> f64 {
        if self.service_completed == 0 {
            0.0
        } else {
            self.service_queue_wait_ns as f64 / self.service_completed as f64 * 1e-9
        }
    }

    /// Mean bucket occupancy of the batched small-solve path — how
    /// many solves each fused sweep amortized its launches over.
    pub fn avg_batch_occupancy(&self) -> f64 {
        if self.batch_buckets == 0 {
            0.0
        } else {
            self.batch_solves as f64 / self.batch_buckets as f64
        }
    }

    /// Mean cost-model coalesce wait of batched solves, seconds.
    pub fn avg_coalesce_wait(&self) -> f64 {
        if self.batch_solves == 0 {
            0.0
        } else {
            self.batch_coalesce_wait_ns as f64 / self.batch_solves as f64 * 1e-9
        }
    }

    /// Mean MPMD frontend routing latency (submit → dispatch), seconds.
    pub fn avg_routing_latency(&self) -> f64 {
        if self.mpmd_routed == 0 {
            0.0
        } else {
            self.mpmd_routing_ns as f64 / self.mpmd_routed as f64 * 1e-9
        }
    }

    /// IPC handles currently open according to the counters
    /// (opens minus closes) — the caller-side leak balance.
    pub fn ipc_open_balance(&self) -> i64 {
        self.ipc_opens as i64 - self.ipc_closes as i64
    }

    /// Factor-cache hit rate over all probes (`0` before any probe).
    pub fn cache_hit_rate(&self) -> f64 {
        let probes = self.cache_hits + self.cache_misses;
        if probes == 0 {
            0.0
        } else {
            self.cache_hits as f64 / probes as f64
        }
    }

    /// Difference against an earlier snapshot (per-phase accounting).
    pub fn delta(&self, earlier: &MetricsSnapshot) -> MetricsSnapshot {
        MetricsSnapshot {
            peer_bytes: self.peer_bytes - earlier.peer_bytes,
            peer_copies: self.peer_copies - earlier.peer_copies,
            h2d_bytes: self.h2d_bytes - earlier.h2d_bytes,
            d2h_bytes: self.d2h_bytes - earlier.d2h_bytes,
            local_bytes: self.local_bytes - earlier.local_bytes,
            kernel_launches: self.kernel_launches - earlier.kernel_launches,
            flops: self.flops - earlier.flops,
            allocs: self.allocs - earlier.allocs,
            frees: self.frees - earlier.frees,
            redist_cycles: self.redist_cycles - earlier.redist_cycles,
            redist_columns: self.redist_columns - earlier.redist_columns,
            service_submitted: self.service_submitted - earlier.service_submitted,
            service_completed: self.service_completed - earlier.service_completed,
            service_queue_wait_ns: self.service_queue_wait_ns - earlier.service_queue_wait_ns,
            service_exec_ns: self.service_exec_ns - earlier.service_exec_ns,
            service_preemptions: self.service_preemptions - earlier.service_preemptions,
            class_completed: std::array::from_fn(|i| {
                self.class_completed[i] - earlier.class_completed[i]
            }),
            class_deadline_misses: std::array::from_fn(|i| {
                self.class_deadline_misses[i] - earlier.class_deadline_misses[i]
            }),
            // Distribution stats, not flows: the later values stand.
            class_p50_ns: self.class_p50_ns,
            class_p99_ns: self.class_p99_ns,
            overlap_busy_ns: self.overlap_busy_ns - earlier.overlap_busy_ns,
            overlap_span_ns: self.overlap_span_ns - earlier.overlap_span_ns,
            batch_buckets: self.batch_buckets - earlier.batch_buckets,
            batch_solves: self.batch_solves - earlier.batch_solves,
            // A high-water mark, not a flow: deltas take the max so a
            // stale `earlier` (or cross-source compare) can't report a
            // peak below either snapshot's.
            batch_peak_occupancy: self.batch_peak_occupancy.max(earlier.batch_peak_occupancy),
            batch_coalesce_wait_ns: self.batch_coalesce_wait_ns - earlier.batch_coalesce_wait_ns,
            batch_makespan_ns: self.batch_makespan_ns - earlier.batch_makespan_ns,
            ipc_exports: self.ipc_exports - earlier.ipc_exports,
            ipc_opens: self.ipc_opens - earlier.ipc_opens,
            ipc_closes: self.ipc_closes - earlier.ipc_closes,
            ipc_revokes: self.ipc_revokes - earlier.ipc_revokes,
            mpmd_routed: self.mpmd_routed - earlier.mpmd_routed,
            mpmd_routing_ns: self.mpmd_routing_ns - earlier.mpmd_routing_ns,
            mpmd_requeues: self.mpmd_requeues - earlier.mpmd_requeues,
            // A high-water mark, like batch_peak_occupancy.
            mpmd_peak_worker_queue: self
                .mpmd_peak_worker_queue
                .max(earlier.mpmd_peak_worker_queue),
            grid_solves: self.grid_solves - earlier.grid_solves,
            // High-water marks: max of the two snapshots.
            grid_peak_p: self.grid_peak_p.max(earlier.grid_peak_p),
            grid_peak_q: self.grid_peak_q.max(earlier.grid_peak_q),
            grid_row_bytes: self.grid_row_bytes - earlier.grid_row_bytes,
            grid_col_bytes: self.grid_col_bytes - earlier.grid_col_bytes,
            cache_hits: self.cache_hits - earlier.cache_hits,
            cache_misses: self.cache_misses - earlier.cache_misses,
            cache_evictions: self.cache_evictions - earlier.cache_evictions,
            // A gauge, not a flow: the later residency stands.
            cache_resident_bytes: self.cache_resident_bytes,
            dag_fused_stages: self.dag_fused_stages - earlier.dag_fused_stages,
            fabric_inter_bytes: self.fabric_inter_bytes - earlier.fabric_inter_bytes,
            fabric_intra_bytes: self.fabric_intra_bytes - earlier.fabric_intra_bytes,
            fabric_bcasts: self.fabric_bcasts - earlier.fabric_bcasts,
            fabric_bcast_stages: self.fabric_bcast_stages - earlier.fabric_bcast_stages,
            // High-water marks, like the other peaks.
            fabric_island_peak_bytes: std::array::from_fn(|i| {
                self.fabric_island_peak_bytes[i].max(earlier.fabric_island_peak_bytes[i])
            }),
            mixed_solves: self.mixed_solves - earlier.mixed_solves,
            mixed_fallbacks: self.mixed_fallbacks - earlier.mixed_fallbacks,
            mixed_bytes_saved: self.mixed_bytes_saved - earlier.mixed_bytes_saved,
            refine_iters: std::array::from_fn(|i| self.refine_iters[i] - earlier.refine_iters[i]),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.add_peer(100);
        m.add_peer(50);
        m.add_kernel(1000);
        let s = m.snapshot();
        assert_eq!(s.peer_bytes, 150);
        assert_eq!(s.peer_copies, 2);
        assert_eq!(s.kernel_launches, 1);
        assert_eq!(s.flops, 1000);
    }

    #[test]
    fn reset_zeroes() {
        let m = Metrics::new();
        m.add_h2d(7);
        m.reset();
        assert_eq!(m.snapshot(), MetricsSnapshot::default());
    }

    #[test]
    fn fabric_counters_accumulate_and_peak() {
        let m = Metrics::new();
        m.add_fabric_inter(100);
        m.add_fabric_intra(40);
        m.add_fabric_intra(10);
        m.add_fabric_bcast(3);
        m.add_fabric_bcast(2);
        m.note_island_admitted(0, 500);
        m.note_island_admitted(0, 300);
        m.note_island_admitted(1, 700);
        m.note_island_admitted(63, 9); // clamps into the last slot
        let s = m.snapshot();
        assert_eq!(s.fabric_inter_bytes, 100);
        assert_eq!(s.fabric_intra_bytes, 50);
        assert_eq!(s.fabric_bcasts, 2);
        assert_eq!(s.fabric_bcast_stages, 5);
        assert_eq!(s.fabric_island_peak_bytes[0], 500);
        assert_eq!(s.fabric_island_peak_bytes[1], 700);
        assert_eq!(s.fabric_island_peak_bytes[7], 9);
        // Peaks are high-water marks across deltas; flows zero out.
        let d = m.snapshot().delta(&s);
        assert_eq!(d.fabric_inter_bytes, 0);
        assert_eq!(d.fabric_island_peak_bytes[1], 700);
        m.reset();
        assert_eq!(m.snapshot(), MetricsSnapshot::default());
    }

    #[test]
    fn delta_subtracts() {
        let m = Metrics::new();
        m.add_peer(10);
        let a = m.snapshot();
        m.add_peer(30);
        let b = m.snapshot();
        let d = b.delta(&a);
        assert_eq!(d.peer_bytes, 30);
        assert_eq!(d.peer_copies, 1);
    }

    #[test]
    fn service_and_overlap_counters() {
        let m = Metrics::new();
        m.add_service_submission();
        m.add_service_completion(500, 1500);
        m.add_overlap(100, 400);
        let s = m.snapshot();
        assert_eq!(s.service_submitted, 1);
        assert_eq!(s.service_completed, 1);
        assert_eq!(s.service_exec_ns, 1500);
        assert!((s.overlap_efficiency() - 0.25).abs() < 1e-12);
        assert!((s.avg_queue_wait() - 500e-9).abs() < 1e-15);
        // Empty snapshots report zero, not NaN.
        assert_eq!(MetricsSnapshot::default().overlap_efficiency(), 0.0);
        assert_eq!(MetricsSnapshot::default().avg_queue_wait(), 0.0);
    }

    #[test]
    fn batch_counters() {
        let m = Metrics::new();
        m.add_batch_bucket(8, 4_000, 100_000);
        m.add_batch_bucket(4, 2_000, 60_000);
        let s = m.snapshot();
        assert_eq!(s.batch_buckets, 2);
        assert_eq!(s.batch_solves, 12);
        assert_eq!(s.batch_peak_occupancy, 8);
        assert_eq!(s.batch_coalesce_wait_ns, 6_000);
        assert_eq!(s.batch_makespan_ns, 160_000);
        assert!((s.avg_batch_occupancy() - 6.0).abs() < 1e-12);
        assert!((s.avg_coalesce_wait() - 500e-9).abs() < 1e-15);
        assert_eq!(MetricsSnapshot::default().avg_batch_occupancy(), 0.0);
        assert_eq!(MetricsSnapshot::default().avg_coalesce_wait(), 0.0);
        m.reset();
        assert_eq!(m.snapshot(), MetricsSnapshot::default());
    }

    #[test]
    fn ipc_and_mpmd_counters() {
        let m = Metrics::new();
        m.add_ipc_export();
        m.add_ipc_export();
        m.add_ipc_open();
        m.add_ipc_close();
        m.add_ipc_revokes(2);
        m.add_mpmd_routed(1_000);
        m.add_mpmd_routed(3_000);
        m.add_mpmd_requeue();
        m.note_worker_queue_depth(3);
        m.note_worker_queue_depth(1);
        let s = m.snapshot();
        assert_eq!(s.ipc_exports, 2);
        assert_eq!(s.ipc_opens, 1);
        assert_eq!(s.ipc_closes, 1);
        assert_eq!(s.ipc_revokes, 2);
        assert_eq!(s.ipc_open_balance(), 0);
        assert_eq!(s.mpmd_routed, 2);
        assert_eq!(s.mpmd_requeues, 1);
        assert_eq!(s.mpmd_peak_worker_queue, 3);
        assert!((s.avg_routing_latency() - 2e-6).abs() < 1e-15);
        assert_eq!(MetricsSnapshot::default().avg_routing_latency(), 0.0);
        m.reset();
        assert_eq!(m.snapshot(), MetricsSnapshot::default());
    }

    #[test]
    fn grid_counters() {
        let m = Metrics::new();
        m.note_grid_solve(2, 2);
        m.note_grid_solve(2, 4);
        m.add_grid_row_bytes(1000);
        m.add_grid_col_bytes(300);
        m.add_grid_col_bytes(200);
        let s = m.snapshot();
        assert_eq!(s.grid_solves, 2);
        assert_eq!(s.grid_peak_p, 2);
        assert_eq!(s.grid_peak_q, 4);
        assert_eq!(s.grid_row_bytes, 1000);
        assert_eq!(s.grid_col_bytes, 500);
        m.reset();
        assert_eq!(m.snapshot(), MetricsSnapshot::default());
    }

    #[test]
    fn class_latency_percentiles() {
        let m = Metrics::new();
        // 99 fast interactive completions in [64, 128) ns, one slow
        // outlier in [65536, 131072) ns.
        for _ in 0..99 {
            m.record_class_latency(SloClass::Interactive, 100, false);
        }
        m.record_class_latency(SloClass::Interactive, 100_000, true);
        let s = m.snapshot();
        assert_eq!(s.class_completed[SloClass::Interactive.index()], 100);
        assert_eq!(s.class_deadline_misses[SloClass::Interactive.index()], 1);
        // p50 lands in the fast bucket, p99 falls on the 99th
        // completion (still fast), p100 would hit the outlier.
        assert_eq!(s.class_p50_ns[SloClass::Interactive.index()], 127);
        assert_eq!(s.class_p99_ns[SloClass::Interactive.index()], 127);
        assert_eq!(m.latency_percentile(SloClass::Interactive, 1.0), 131_071);
        // Untouched classes stay empty.
        assert_eq!(s.class_completed[SloClass::Batch.index()], 0);
        assert_eq!(s.class_p99_ns[SloClass::Batch.index()], 0);
        // Zero latency is representable (bucket 0).
        m.record_class_latency(SloClass::Batch, 0, false);
        assert_eq!(m.latency_percentile(SloClass::Batch, 0.5), 1);
        m.note_preemption();
        assert_eq!(m.snapshot().service_preemptions, 1);
        m.reset();
        assert_eq!(m.snapshot(), MetricsSnapshot::default());
    }

    #[test]
    fn delta_gauges_are_last_value_and_peaks_are_max() {
        let m = Metrics::new();
        m.add_batch_bucket(8, 0, 0);
        m.note_worker_queue_depth(5);
        m.note_grid_solve(4, 2);
        m.add_cache_resident_bytes(1000);
        let a = m.snapshot();
        // Later phase: residency shrinks, no new peaks.
        m.add_cache_resident_bytes(-400);
        m.add_batch_bucket(3, 0, 0);
        m.note_worker_queue_depth(2);
        m.note_grid_solve(2, 2);
        let b = m.snapshot();
        let d = b.delta(&a);
        // Gauge: last value, never `later - earlier` (which would be a
        // bogus 600-1000 underflow-style number).
        assert_eq!(d.cache_resident_bytes, 600);
        // Peaks: max across both snapshots, even though the second
        // phase alone never reached them.
        assert_eq!(d.batch_peak_occupancy, 8);
        assert_eq!(d.mpmd_peak_worker_queue, 5);
        assert_eq!(d.grid_peak_p, 4);
        assert_eq!(d.grid_peak_q, 2);
        // Cross-source compare (earlier holds a peak the later metrics
        // instance never saw) still reports the true high-water mark.
        let fresh = Metrics::new();
        fresh.note_grid_solve(2, 2);
        let d2 = fresh.snapshot().delta(&a);
        assert_eq!(d2.grid_peak_p, 4);
        assert_eq!(d2.batch_peak_occupancy, 8);
        // Flows still subtract.
        assert_eq!(d.batch_buckets, 1);
        assert_eq!(d.batch_solves, 3);
    }

    #[test]
    fn percentiles_and_averages_are_total_per_class() {
        let m = Metrics::new();
        // Empty histograms: every class and quantile returns 0, never
        // NaN or a panic.
        for class in [SloClass::Interactive, SloClass::Standard, SloClass::Batch] {
            for q in [0.0, 0.5, 0.99, 1.0] {
                assert_eq!(m.latency_percentile(class, q), 0);
            }
            assert!(m.class_histogram(class).is_empty());
        }
        // Zero-valued snapshot: all avg helpers are exactly 0.0.
        let s = MetricsSnapshot::default();
        assert_eq!(s.avg_queue_wait(), 0.0);
        assert_eq!(s.avg_batch_occupancy(), 0.0);
        assert_eq!(s.avg_coalesce_wait(), 0.0);
        assert_eq!(s.avg_routing_latency(), 0.0);
        assert_eq!(s.overlap_efficiency(), 0.0);
        assert_eq!(s.cache_hit_rate(), 0.0);
        assert!(s.avg_queue_wait().is_finite());
        // One zero-latency completion per class is representable and
        // keeps everything finite.
        for class in [SloClass::Interactive, SloClass::Standard, SloClass::Batch] {
            m.record_class_latency(class, 0, false);
            assert_eq!(m.latency_percentile(class, 0.5), 1);
            assert_eq!(m.class_histogram(class), vec![(1, 1)]);
        }
    }

    #[test]
    fn class_histogram_matches_recordings() {
        let m = Metrics::new();
        m.record_class_latency(SloClass::Standard, 100, false); // [64,128)
        m.record_class_latency(SloClass::Standard, 100, false);
        m.record_class_latency(SloClass::Standard, 5_000, false); // [4096,8192)
        let h = m.class_histogram(SloClass::Standard);
        assert_eq!(h, vec![(127, 2), (8_191, 1)]);
        // Counts across buckets equal completions.
        let total: u64 = h.iter().map(|&(_, n)| n).sum();
        assert_eq!(total, m.snapshot().class_completed[SloClass::Standard.index()]);
    }

    #[test]
    fn mixed_counters_and_refine_histogram() {
        let m = Metrics::new();
        m.add_mixed_solve();
        m.add_mixed_solve();
        m.add_mixed_fallback();
        m.add_mixed_bytes_saved(1_000);
        m.add_mixed_bytes_saved(500);
        m.record_refine_iters(0);
        m.record_refine_iters(3);
        m.record_refine_iters(99); // clamps into the last slot
        let s = m.snapshot();
        assert_eq!(s.mixed_solves, 2);
        assert_eq!(s.mixed_fallbacks, 1);
        assert_eq!(s.mixed_bytes_saved, 1_500);
        assert_eq!(s.refine_iters[0], 1);
        assert_eq!(s.refine_iters[3], 1);
        assert_eq!(s.refine_iters[15], 1);
        // Flows subtract across deltas.
        m.add_mixed_solve();
        m.record_refine_iters(3);
        let d = m.snapshot().delta(&s);
        assert_eq!(d.mixed_solves, 1);
        assert_eq!(d.refine_iters[3], 1);
        assert_eq!(d.refine_iters[0], 0);
        m.reset();
        assert_eq!(m.snapshot(), MetricsSnapshot::default());
    }

    #[test]
    fn concurrent_bumps() {
        use std::sync::Arc;
        let m = Arc::new(Metrics::new());
        let mut handles = vec![];
        for _ in 0..8 {
            let m = m.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    m.add_peer(1);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.snapshot().peer_bytes, 8000);
    }
}
