//! # jaxmg — a reproduction of *JAXMg: A multi-GPU linear solver in JAX*
//!
//! JAXMg exposes NVIDIA cuSOLVERMg's multi-GPU dense solvers (`potrs`,
//! `potri`, `syevd`) to JAX through an XLA FFI extension. This crate
//! reproduces the full system as a three-layer Rust + JAX + Pallas stack
//! on a **simulated multi-GPU node** (this environment has no CUDA
//! devices — see `DESIGN.md` for the substitution table):
//!
//! * **Layer 3 (this crate)** — the coordinator: simulated GPU devices
//!   with VRAM accounting and peer-to-peer copies, the paper's 1D
//!   block-cyclic redistribution via permutation cycles (§2.1) —
//!   generalized to the 2D tile-grid model of §5's future work
//!   (`layout::BlockCyclic2D`, tile-slot cycles, row-parallel `syevd`
//!   collectives) — the SPMD/MPMD single-caller pointer reconciliation
//!   (§2.2), and the distributed solvers themselves (blocked Cholesky,
//!   triangular solves, inverse, symmetric/Hermitian
//!   eigendecomposition).
//! * **Layer 2 (`python/compile/model.py`)** — blocked tile algorithms in
//!   JAX, AOT-lowered to HLO text artifacts.
//! * **Layer 1 (`python/compile/kernels/`)** — Pallas tile kernels (GEMM
//!   family) that dominate the FLOP count, lowered into the same HLO.
//!
//! At runtime the Rust coordinator loads the AOT artifacts through the
//! PJRT CPU client (`runtime`); Python is never on the request path.
//!
//! ## Quick start
//!
//! ```no_run
//! use jaxmg::prelude::*;
//!
//! let node = SimNode::new_uniform(4, 1 << 30); // 4 GPUs, 1 GiB VRAM each
//! let mesh = Mesh::new_1d(node, "x");
//! let ctx = JaxMg::builder().mesh(mesh).tile_size(64).build().unwrap();
//!
//! let n = 512;
//! let a = jaxmg::linalg::Matrix::<f64>::spd_diag(n); // diag(1..N), as in the paper
//! let b = jaxmg::linalg::Matrix::<f64>::ones(n, 1);
//! let x = ctx.potrs(&a, &b).unwrap();
//! ```

pub mod baseline;
pub mod batch;
pub mod cli;
pub mod coordinator;
pub mod costmodel;
pub mod device;
pub mod error;
pub mod fabric;
pub mod ipc;
pub mod layout;
pub mod linalg;
pub mod metrics;
pub mod obs;
pub mod rng;
pub mod runtime;
pub mod scalar;
pub mod serve;
pub mod solver;
pub mod tile;
pub mod workload;

/// Convenient re-exports for the common API surface.
pub mod prelude {
    pub use crate::batch::{BatchPolicy, PackedPod, SmallRoutine};
    pub use crate::coordinator::{
        BackendKind, DistRoutine, ExecMode, Footprint, JaxMg, Mesh, PartitionSpec, SolveService,
    };
    pub use crate::device::{SimGpu, SimNode};
    pub use crate::error::{Error, Result};
    pub use crate::fabric::Fabric;
    pub use crate::layout::{BlockCyclic1D, BlockCyclic2D};
    pub use crate::linalg::Matrix;
    pub use crate::scalar::{c32, c64, Complex, Scalar};
    pub use crate::serve::{MpmdConfig, MpmdService};
    pub use crate::solver::{PipelineConfig, SolverBackend};
    pub use crate::workload::{ArrivalProcess, ClosedLoop, OpenLoop, Population};
}

pub use error::{Error, Result};
