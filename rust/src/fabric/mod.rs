//! # Two-tier multi-node fabric
//!
//! The paper's testbed stops at one 8×H200 NVLink node; this module is
//! the next regime — `N` NVLink **islands** joined by a slower
//! inter-node interconnect. Following JetSCI's hybrid
//! single-program/distributed-runtime split, the fabric is *not* a
//! fork of the solver stack: a [`Fabric`] is one [`SimNode`] whose
//! [`NodeTopology::two_tier`] link table marks cross-island pairs
//! [`LinkKind::InterNode`], so every existing solver, scheduler, and
//! serving front runs unchanged and **numerics stay bitwise-identical
//! to the single-node path** — only transfer pricing and collective
//! *shape* respond to the topology (Lineax-style dispatch by operator
//! structure, where the operator structure is the machine itself).
//!
//! ## Two-tier cost model
//!
//! | tier | link | bandwidth | latency | fan-out sharing |
//! |---|---|---|---|---|
//! | intra-island | NVLink | 450 GB/s | 5 µs | full: `copy_time / fanout` (switch serves receivers in parallel) |
//! | inter-island | InterNode (NDR-class RDMA) | 50 GB/s | 10 µs | latency only: payloads serialize on the shared pipe |
//!
//! Hierarchical (ring-of-rings) collectives follow from the table:
//! a broadcast sends **one representative copy per remote island**
//! across the fabric, fans out to the home island in parallel, and
//! each representative relays island-locally on its own copy stream —
//! so an island-crossing broadcast pays `O(islands)` fabric transfers
//! instead of `O(devices)`. The `Ctx::charge_*` collective layer
//! (`solver`) prices both tiers on the integer-ns clock, and
//! `Predictor`'s replays mirror the same arithmetic through
//! [`NodeTopology::ring_share_time`], so est == obs by construction.
//!
//! ## 1-node vs 2-node decision table
//!
//! The planner's per-request routing (`Predictor::best_fabric_plan`,
//! used by `coordinator::plan_dist`) reduces to:
//!
//! | regime | dominant term | winner |
//! |---|---|---|
//! | small `N` (ring latency bound) | per-step collective latency | 1 island, 1D grid |
//! | mid `N` (panel/comm bound) | NVLink ring bytes | 1 island, island-local 2D grid |
//! | `N ≥ N*` (trailing GEMMs bound) | per-device flops `n³/P` | 2 islands, island-aligned grid (`Q` divides island width) |
//! | VRAM wall (`n²·e >` island VRAM) | capacity | 2 islands regardless |
//!
//! `N*` is pinned by `benches/fabric.rs` end-to-end through the
//! service; EXPERIMENTS.md records the crossover ladder.

use crate::device::{NodeTopology, SimNode};
use crate::error::{Error, Result};

/// A two-tier fabric: `islands` × `per_island` devices over one shared
/// integer-ns clock domain. Internally a single [`SimNode`] carrying
/// the [`NodeTopology::two_tier`] link table — which is exactly why
/// every solver runs on it unchanged (see the module docs).
#[derive(Clone, Debug)]
pub struct Fabric {
    node: SimNode,
    islands: usize,
    per_island: usize,
}

impl Fabric {
    /// A fabric of `islands` × `per_island` identical devices with
    /// `vram_bytes` each, NVLink all-to-all within an island,
    /// inter-node links across.
    pub fn new(islands: usize, per_island: usize, vram_bytes: usize) -> Self {
        assert!(islands > 0 && per_island > 0, "fabric needs at least one device");
        let node = SimNode::with_topology(
            islands * per_island,
            vram_bytes,
            NodeTopology::two_tier(islands, per_island),
        );
        Fabric { node, islands, per_island }
    }

    /// The paper's testbed island, multiplied: `islands` × 8 H200s
    /// (143 GB each) over the inter-node fabric.
    pub fn h200(islands: usize) -> Self {
        Self::new(islands, 8, 143 * 1000 * 1000 * 1000)
    }

    /// The composed node spanning every island. Solvers, services, and
    /// schedulers take this exactly like a flat node; with one island
    /// it *is* a flat node (the topology carries no `InterNode` links
    /// and every timeline is bitwise `SimNode::new_uniform`'s).
    pub fn node(&self) -> &SimNode {
        &self.node
    }

    /// Number of islands.
    pub fn num_islands(&self) -> usize {
        self.islands
    }

    /// Devices per island.
    pub fn devices_per_island(&self) -> usize {
        self.per_island
    }

    /// Total devices across the fabric.
    pub fn num_devices(&self) -> usize {
        self.islands * self.per_island
    }

    /// Island ordinal of a global device index.
    pub fn island_of(&self, device: usize) -> usize {
        device / self.per_island
    }

    /// Global device indices of island `i`, in device order.
    pub fn island_devices(&self, i: usize) -> Result<Vec<usize>> {
        if i >= self.islands {
            return Err(Error::config(format!(
                "island {i} out of range (fabric has {})",
                self.islands
            )));
        }
        Ok((i * self.per_island..(i + 1) * self.per_island).collect())
    }

    /// A [`SimNode`] view of island `i`, **sharing** its devices'
    /// VRAM tables, clocks, and metrics with the fabric. The subset
    /// topology re-densifies island ordinals, so the view is a flat
    /// 1-island node and everything scheduled through it prices at
    /// NVLink rates — the substrate for one-worker-set-per-island
    /// serving placements.
    pub fn island(&self, i: usize) -> Result<SimNode> {
        self.node.subset(&self.island_devices(i)?)
    }

    /// Split a device budget across islands for admission control:
    /// `per_device[d]` grouped into per-island sums, in island order.
    pub fn per_island_bytes(&self, per_device: &[usize]) -> Vec<u64> {
        let mut out = vec![0u64; self.islands];
        for (d, &b) in per_device.iter().enumerate() {
            let isl = (d / self.per_island).min(self.islands - 1);
            out[isl] += b as u64;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::LinkKind;

    #[test]
    fn fabric_composes_islands_over_internode_links() {
        let f = Fabric::new(2, 4, 1 << 28);
        assert_eq!(f.num_devices(), 8);
        assert_eq!(f.num_islands(), 2);
        assert_eq!(f.devices_per_island(), 4);
        let topo = f.node().topology();
        assert_eq!(topo.num_islands(), 2);
        assert!(matches!(topo.link(0, 3), LinkKind::NvLink));
        assert!(matches!(topo.link(0, 4), LinkKind::InterNode));
        assert_eq!(f.island_of(3), 0);
        assert_eq!(f.island_of(4), 1);
        assert_eq!(f.island_devices(1).unwrap(), vec![4, 5, 6, 7]);
        assert!(f.island_devices(2).is_err());
    }

    #[test]
    fn island_view_is_flat_and_shares_accounting() {
        let f = Fabric::new(2, 4, 1 << 28);
        let isl = f.island(1).unwrap();
        assert_eq!(isl.num_devices(), 4);
        // Re-densified: the view is a 1-island (flat) topology.
        assert_eq!(isl.topology().num_islands(), 1);
        assert!(matches!(isl.topology().link(0, 3), LinkKind::NvLink));
        // Shared metrics sink: charges through the view land on the
        // fabric's counters.
        isl.metrics().add_fabric_intra(64);
        assert_eq!(f.node().metrics().snapshot().fabric_intra_bytes, 64);
    }

    #[test]
    fn one_island_fabric_is_a_flat_node() {
        let f = Fabric::new(1, 4, 1 << 28);
        let topo = f.node().topology();
        assert_eq!(topo.num_islands(), 1);
        for i in 0..4 {
            for j in 0..4 {
                if i != j {
                    assert!(matches!(topo.link(i, j), LinkKind::NvLink));
                }
            }
        }
    }

    #[test]
    fn per_island_bytes_groups_device_budgets() {
        let f = Fabric::new(2, 2, 1 << 28);
        assert_eq!(f.per_island_bytes(&[1, 2, 3, 4]), vec![3, 7]);
        // Short budgets cover a prefix; extra devices clamp to the
        // last island rather than panicking.
        assert_eq!(f.per_island_bytes(&[5]), vec![5, 0]);
        assert_eq!(f.per_island_bytes(&[1, 1, 1, 1, 9]), vec![2, 11]);
    }
}
