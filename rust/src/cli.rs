//! CLI option parsing (hand-rolled; the vendored crate set has no clap).
//!
//! `--key value` pairs plus bare flags; typed accessors with defaults.
//! Lives in the library so it is unit-testable and reusable by the
//! examples.

use crate::error::{Error, Result};
use std::collections::HashMap;

/// Bare flags that take no value.
const FLAGS: &[&str] = &["random"];

/// Parsed command-line options.
#[derive(Debug, Default)]
pub struct Opts {
    map: HashMap<String, String>,
}

impl Opts {
    /// Parse `--key value` pairs (and bare flags) from `args`.
    pub fn parse(args: &[String]) -> Result<Opts> {
        let mut map = HashMap::new();
        let mut it = args.iter();
        while let Some(a) = it.next() {
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| Error::config(format!("expected --option, got {a:?}")))?;
            if FLAGS.contains(&key) {
                map.insert(key.to_string(), "true".to_string());
            } else {
                let v = it.next().ok_or_else(|| Error::config(format!("--{key} needs a value")))?;
                map.insert(key.to_string(), v.clone());
            }
        }
        Ok(Opts { map })
    }

    /// Integer option with default.
    pub fn usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.map.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| Error::config(format!("--{key} must be an integer"))),
        }
    }

    /// String option with default.
    pub fn str(&self, key: &str, default: &str) -> String {
        self.map.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Bare-flag presence.
    pub fn flag(&self, key: &str) -> bool {
        self.map.contains_key(key)
    }

    /// Raw access (e.g. optional seeds).
    pub fn get(&self, key: &str) -> Option<&str> {
        self.map.get(key).map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_pairs_and_flags() {
        let o = Opts::parse(&args(&["--n", "512", "--dtype", "c128", "--random"])).unwrap();
        assert_eq!(o.usize("n", 0).unwrap(), 512);
        assert_eq!(o.str("dtype", "f32"), "c128");
        assert!(o.flag("random"));
        assert!(!o.flag("diag"));
    }

    #[test]
    fn defaults_apply() {
        let o = Opts::parse(&args(&[])).unwrap();
        assert_eq!(o.usize("tile", 64).unwrap(), 64);
        assert_eq!(o.str("mode", "spmd"), "spmd");
    }

    #[test]
    fn rejects_positional() {
        assert!(Opts::parse(&args(&["solve"])).is_err());
    }

    #[test]
    fn rejects_missing_value() {
        assert!(Opts::parse(&args(&["--n"])).is_err());
    }

    #[test]
    fn rejects_non_integer() {
        let o = Opts::parse(&args(&["--n", "many"])).unwrap();
        assert!(o.usize("n", 1).is_err());
    }
}
