//! Batched small-solve subsystem: request coalescing, packed pod
//! layouts, and fused per-device kernel sweeps.
//!
//! The distributed solvers exist for matrices that exceed one device;
//! service traffic at the millions-of-users scale is dominated by the
//! opposite shape — *tiny* solves (`n ≲ 4·T_A`) where per-solve
//! scatter/redistribution and per-panel collectives swamp the actual
//! flops. This module is the throughput path for that traffic. A small
//! request admitted by [`SolveService::submit_small`] flows through
//! three stages:
//!
//! 1. **Admission** (`coordinator::service`) — the cost-model cut:
//!    [`Predictor::batched_wins`] compares the fused pod-sweep makespan
//!    against the one-at-a-time distributed path; requests that are too
//!    large (or that the model says should run distributed) fall back
//!    to the ordinary scatter → `potrf_dist`/`potrs_dist`/`potri_dist`
//!    → gather route. Whole pods are admitted against per-device VRAM
//!    via [`Footprint::for_pod`], the same capacity accounting every
//!    other service solve obeys.
//! 2. **Coalescing** ([`coalesce`]) — admitted small requests queue in
//!    a [`BatchPlanner`] bucket keyed by (routine, dtype, power-of-two
//!    size-class), and flush as one batch when the bucket reaches
//!    [`BatchPolicy::max_batch`] or its oldest request has dwelled past
//!    [`BatchPolicy::max_dwell_ns`] **cost-model nanoseconds** — the
//!    latency bound that keeps coalescing from trading unbounded tail
//!    latency for throughput.
//! 3. **Sweep** ([`pod`] + [`sweep`]) — the flushed bucket's systems
//!    are packed into a [`PackedPod`] (round-robin over the node via
//!    the [`TileDim`](crate::layout::TileDim) deal arithmetic, one
//!    staged copy per device) and solved by
//!    [`potrf_batched`]/[`potrs_batched`]/[`potri_batched`]: one fused
//!    kernel charge per device per stage on the existing device
//!    timelines, zero peer traffic, numerics bitwise-identical to the
//!    systems run one at a time.
//!
//! The Lineax front-end (uniform solve entry dispatching to
//! structure-specialized paths) and MPAX's batched operator evaluation
//! are the JAX-side precedents (see PAPERS.md); this is the Rust
//! coordinator's analogue, with the cost model deciding the dispatch.
//!
//! [`SolveService::submit_small`]: crate::coordinator::SolveService::submit_small
//! [`Footprint::for_pod`]: crate::coordinator::Footprint::for_pod
//! [`Predictor::batched_wins`]: crate::costmodel::Predictor::batched_wins

mod coalesce;
mod pod;
pub mod sweep;

pub use coalesce::{
    flusher_tick, size_class, BatchPlanner, BatchPolicy, BucketKey, FlushedBucket, SmallRoutine,
};
pub use pod::PackedPod;
pub use sweep::{potrf_batched, potri_batched, potrs_batched, run_bucket, SweepReport};
