//! Batched kernel sweeps over a [`PackedPod`]: one fused per-device
//! kernel charge per bucket, numerics bitwise-identical to solving
//! each system individually.
//!
//! The numerical payload of every system still flows through the same
//! [`TileKernels`](crate::solver::TileKernels) calls a one-system solve
//! makes (`potf2`, the two `trsm` sweeps, the `trsm` + `gemm_hn`
//! inverse), so a coalesced batch reproduces the individual results
//! **bitwise** — the property tests in `rust/tests/batch.rs` pin this
//! for all four dtypes. What the sweep fuses is the *cost*: where the
//! one-at-a-time path charges one launch overhead per kernel per
//! system (plus per-solve redistribution and per-panel collectives),
//! the sweep charges each device **one** fused kernel per stage —
//! `launch_overhead + Σ per-system kernel time` — on the existing
//! per-device timelines (barrier clocks, or the compute [`Stream`]s
//! when the [`Ctx`] is pipelined; see [`Ctx::charge_device_time`]).
//! Systems never leave their device, so a sweep moves zero peer bytes.
//!
//! [`Stream`]: crate::device::Stream

use super::coalesce::SmallRoutine;
use super::pod::PackedPod;
use crate::costmodel::GpuCostModel;
use crate::device::SimNode;
use crate::error::{Error, Result};
use crate::linalg::Matrix;
use crate::scalar::Scalar;
use crate::solver::{Ctx, SolverBackend};

/// What one sweep did — per-bucket accounting for the metrics layer.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct SweepReport {
    /// Systems the sweep processed.
    pub systems: usize,
    /// Fused kernel launches charged (at most one per device).
    pub fused_launches: usize,
    /// The sweep's charged critical path in integer nanoseconds: the
    /// *largest* per-device fused-kernel charge. Devices run their
    /// fused kernels in parallel, so this is the sweep's own makespan
    /// contribution — well-defined even when other tenants share the
    /// node's clocks.
    pub charged_ns: u64,
}

/// Accumulates one fused per-device kernel charge: per-system kernel
/// durations (each modeled with its own launch overhead by the cost
/// model) collapse into `overhead + Σ (duration − overhead)`.
struct FusedCharge {
    seconds: f64,
    flops: u64,
    kernels: usize,
}

impl FusedCharge {
    fn new() -> Self {
        FusedCharge { seconds: 0.0, flops: 0, kernels: 0 }
    }

    fn add(&mut self, one_at_a_time_seconds: f64, overhead: f64, flops: u64) {
        self.seconds += one_at_a_time_seconds - overhead;
        self.flops += flops;
        self.kernels += 1;
    }

    /// Issue the fused charge; returns the charged duration (`None`
    /// when the device had no systems and nothing was launched).
    fn charge<S: Scalar>(self, ctx: &Ctx<'_, S>, dev: usize) -> Result<Option<f64>> {
        if self.kernels == 0 {
            return Ok(None);
        }
        let secs = ctx.model.launch_overhead + self.seconds;
        ctx.charge_device_time(dev, secs, self.flops)?;
        Ok(Some(secs))
    }
}

/// Fold one device's fused-charge outcome into the sweep totals.
fn tally(charged: Option<f64>, launches: &mut usize, crit: &mut f64) {
    if let Some(secs) = charged {
        *launches += 1;
        if secs > *crit {
            *crit = secs;
        }
    }
}

/// Factor every system of the pod in place (`A_i → L_i`), one fused
/// kernel charge per device.
pub fn potrf_batched<S: Scalar>(ctx: &Ctx<'_, S>, pod: &mut PackedPod<S>) -> Result<SweepReport> {
    let ov = ctx.model.launch_overhead;
    let mut launches = 0;
    let mut crit = 0.0f64;
    for d in 0..ctx.node.num_devices() {
        let ids: Vec<usize> = pod.systems_on(d).collect();
        if ids.is_empty() {
            continue;
        }
        let mut tiles = Vec::with_capacity(ids.len());
        let mut fused = FusedCharge::new();
        for &i in &ids {
            let (r, c) = pod.dims(i);
            if r != c {
                return Err(Error::shape(format!("potrf pod system {i} is {r}x{c}, not square")));
            }
            tiles.push(pod.read_system(i)?);
            let fl = GpuCostModel::flops_potf2(S::DTYPE, r);
            fused.add(ctx.model.panel_time(S::DTYPE, fl), ov, fl);
        }
        let factors = ctx.kernels.potf2_batch(&tiles)?;
        for (&i, l) in ids.iter().zip(factors.iter()) {
            pod.write_system(i, l)?;
        }
        tally(fused.charge(ctx, d)?, &mut launches, &mut crit);
    }
    Ok(SweepReport {
        systems: pod.batch(),
        fused_launches: launches,
        charged_ns: (crit * 1e9).round() as u64,
    })
}

/// Solve `L_i·L_iᴴ·X_i = B_i` for every aligned pair of pod systems,
/// in place over the RHS pod; one fused kernel charge per device.
pub fn potrs_batched<S: Scalar>(
    ctx: &Ctx<'_, S>,
    factors: &PackedPod<S>,
    rhs: &mut PackedPod<S>,
) -> Result<SweepReport> {
    if !factors.aligned_with(rhs) {
        return Err(Error::shape("factor and RHS pods must pack the same batch"));
    }
    let ov = ctx.model.launch_overhead;
    let mut launches = 0;
    let mut crit = 0.0f64;
    for d in 0..ctx.node.num_devices() {
        let mut fused = FusedCharge::new();
        for i in factors.systems_on(d) {
            let (n, _) = factors.dims(i);
            let (br, nrhs) = rhs.dims(i);
            if br != n {
                return Err(Error::shape(format!(
                    "pod system {i}: factor is {n}x{n} but RHS has {br} rows"
                )));
            }
            let l = factors.read_system(i)?;
            let b = rhs.read_system(i)?;
            // The exact single-tile potrs kernel sequence: forward then
            // backward triangular solve over the whole small system.
            let y = ctx.kernels.trsm_llnn(&l, &b)?;
            let x = ctx.kernels.trsm_llhn(&l, &y)?;
            rhs.write_system(i, &x)?;
            let fl = GpuCostModel::flops_trsm(S::DTYPE, n, nrhs, n);
            fused.add(ctx.model.panel_time(S::DTYPE, fl), ov, fl);
            fused.add(ctx.model.panel_time(S::DTYPE, fl), ov, fl);
        }
        tally(fused.charge(ctx, d)?, &mut launches, &mut crit);
    }
    Ok(SweepReport {
        systems: factors.batch(),
        fused_launches: launches,
        charged_ns: (crit * 1e9).round() as u64,
    })
}

/// Invert every factored system in place (`L_i → A_i⁻¹ = L_i⁻ᴴ·L_i⁻¹`),
/// one fused kernel charge per device.
pub fn potri_batched<S: Scalar>(ctx: &Ctx<'_, S>, pod: &mut PackedPod<S>) -> Result<SweepReport> {
    let ov = ctx.model.launch_overhead;
    let mut launches = 0;
    let mut crit = 0.0f64;
    for d in 0..ctx.node.num_devices() {
        let mut fused = FusedCharge::new();
        for i in pod.systems_on(d) {
            let (n, c) = pod.dims(i);
            if n != c {
                return Err(Error::shape(format!("potri pod system {i} is {n}x{c}, not square")));
            }
            let l = pod.read_system(i)?;
            // The exact single-tile potri kernel sequence: Z = L⁻¹ by a
            // triangular solve against the identity, then A⁻¹ = Zᴴ·Z.
            let z = ctx.kernels.trsm_llnn(&l, &Matrix::<S>::eye(n))?;
            let mut inv = Matrix::<S>::zeros(n, n);
            ctx.kernels.gemm_hn(&mut inv, &z, &z, S::one())?;
            pod.write_system(i, &inv)?;
            let trsm_fl = GpuCostModel::flops_trsm(S::DTYPE, n, n, n);
            fused.add(ctx.model.panel_time(S::DTYPE, trsm_fl), ov, trsm_fl);
            let gemm_fl = GpuCostModel::flops_gemm(S::DTYPE, n, n, n);
            fused.add(ctx.model.gemm_time(S::DTYPE, n, n, n), ov, gemm_fl);
        }
        tally(fused.charge(ctx, d)?, &mut launches, &mut crit);
    }
    Ok(SweepReport {
        systems: pod.batch(),
        fused_launches: launches,
        charged_ns: (crit * 1e9).round() as u64,
    })
}

/// Pack → sweep → gather for one flushed bucket; returns the
/// per-request results and the bucket's charged sweep makespan in
/// integer nanoseconds (the sum of each sweep's per-device critical
/// path — see [`SweepReport::charged_ns`] — which stays correct when
/// other tenants advance the shared node's clocks concurrently).
///
/// `pin` packs every system onto one explicit device instead of the
/// round-robin deal — the degraded-retry placement (SPMD service) and
/// the per-worker pod pinning of the MPMD serve layer (`crate::serve`),
/// which both execute whole buckets on a single device.
pub fn run_bucket<S: Scalar>(
    routine: SmallRoutine,
    node: &SimNode,
    model: &GpuCostModel,
    systems: &[Matrix<S>],
    rhss: &[Option<Matrix<S>>],
    pin: Option<usize>,
) -> Result<(Vec<Matrix<S>>, u64)> {
    let pack = |mats: &[Matrix<S>]| match pin {
        Some(dev) => PackedPod::pack_on(node, mats, dev),
        None => PackedPod::pack(node, mats),
    };
    let backend = SolverBackend::<S>::Native;
    let ctx = Ctx::new(node, model, &backend);
    let mut pod = pack(systems)?;
    let factor = potrf_batched(&ctx, &mut pod)?;
    let mut makespan_ns = factor.charged_ns;
    let results = match routine {
        SmallRoutine::Potrf => pod.gather()?,
        SmallRoutine::Potrs => {
            let rhs_mats: Vec<Matrix<S>> = rhss
                .iter()
                .map(|b| b.as_ref().expect("potrs request carries a rhs").clone())
                .collect();
            let mut pod_b = pack(&rhs_mats)?;
            makespan_ns += potrs_batched(&ctx, &pod, &mut pod_b)?.charged_ns;
            let out = pod_b.gather()?;
            pod_b.free()?;
            out
        }
        SmallRoutine::Potri => {
            makespan_ns += potri_batched(&ctx, &mut pod)?.charged_ns;
            pod.gather()?
        }
    };
    pod.free()?;
    Ok((results, makespan_ns))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{self, tol_for, FrobNorm};

    fn model_backend() -> (GpuCostModel, SolverBackend<f64>) {
        (GpuCostModel::h200(), SolverBackend::Native)
    }

    #[test]
    fn batched_factor_solve_correct() {
        let node = SimNode::new_uniform(4, 1 << 22);
        let (model, backend) = model_backend();
        let ctx = Ctx::new(&node, &model, &backend);
        let systems: Vec<Matrix<f64>> =
            (0..6).map(|i| Matrix::spd_random(8 + i, 40 + i as u64)).collect();
        let rhs: Vec<Matrix<f64>> =
            (0..6).map(|i| Matrix::random(8 + i, 2, 50 + i as u64)).collect();
        let mut pod_a = PackedPod::pack(&node, &systems).unwrap();
        let mut pod_b = PackedPod::pack(&node, &rhs).unwrap();
        let rep = potrf_batched(&ctx, &mut pod_a).unwrap();
        assert_eq!(rep.systems, 6);
        assert!(rep.fused_launches <= 4);
        potrs_batched(&ctx, &pod_a, &mut pod_b).unwrap();
        for (i, x) in pod_b.gather().unwrap().into_iter().enumerate() {
            let l = linalg::potrf(&systems[i]).unwrap();
            let x_ref = linalg::potrs_from_chol(&l, &rhs[i]).unwrap();
            assert!(x.rel_err(&x_ref) < tol_for::<f64>(16), "system {i} wrong");
        }
    }

    #[test]
    fn batched_inverse_correct() {
        let node = SimNode::new_uniform(2, 1 << 22);
        let (model, backend) = model_backend();
        let ctx = Ctx::new(&node, &model, &backend);
        let systems: Vec<Matrix<f64>> = (0..3).map(|i| Matrix::spd_random(7, 60 + i)).collect();
        let mut pod = PackedPod::pack(&node, &systems).unwrap();
        potrf_batched(&ctx, &mut pod).unwrap();
        potri_batched(&ctx, &mut pod).unwrap();
        for (i, inv) in pod.gather().unwrap().into_iter().enumerate() {
            let prod = systems[i].matmul(&inv);
            assert!(prod.rel_err(&Matrix::eye(7)) < tol_for::<f64>(7) * 10.0, "system {i}");
        }
    }

    #[test]
    fn one_fused_launch_per_device() {
        let node = SimNode::new_uniform(4, 1 << 22);
        let (model, backend) = model_backend();
        let ctx = Ctx::new(&node, &model, &backend);
        let systems: Vec<Matrix<f64>> = (0..8).map(|i| Matrix::spd_random(6, i)).collect();
        let mut pod = PackedPod::pack(&node, &systems).unwrap();
        node.metrics().reset();
        let rep = potrf_batched(&ctx, &mut pod).unwrap();
        assert_eq!(rep.fused_launches, 4);
        // Critical path ≥ one launch overhead, well under two.
        assert!(rep.charged_ns >= 8_000 && rep.charged_ns < 16_000, "{}", rep.charged_ns);
        let m = node.metrics().snapshot();
        // 8 systems, but only 4 kernel launches — and zero peer traffic.
        assert_eq!(m.kernel_launches, 4);
        assert_eq!(m.peer_bytes, 0);
    }

    #[test]
    fn shape_mismatches_rejected() {
        let node = SimNode::new_uniform(2, 1 << 22);
        let (model, backend) = model_backend();
        let ctx = Ctx::new(&node, &model, &backend);
        let rect = vec![Matrix::<f64>::random(4, 3, 1)];
        let mut pod = PackedPod::pack(&node, &rect).unwrap();
        assert!(potrf_batched(&ctx, &mut pod).is_err());
        let spd = vec![Matrix::<f64>::spd_random(4, 2); 2];
        let factors = PackedPod::pack(&node, &spd).unwrap();
        let mut short = PackedPod::pack(&node, &[Matrix::<f64>::random(4, 1, 3)]).unwrap();
        assert!(potrs_batched(&ctx, &factors, &mut short).is_err());
    }
}
