//! Packed pod storage: `B` small same-dtype matrices in one
//! device-resident arena per device.
//!
//! A small solve (`n ≲ 4·T_A`) dealt through [`crate::tile::DistMatrix`]
//! pays one host↔device staging charge **per device per solve** plus
//! the per-panel collectives of the distributed schedules — pure
//! overhead when the whole system fits comfortably on one device. A
//! [`PackedPod`] instead packs the `B` systems of one coalesced bucket
//! into a *single* contiguous arena per device:
//!
//! * systems are dealt round-robin (`system i → device i mod ndev`)
//!   via the [`TileDim`] deal arithmetic the tile-grid layouts use
//!   ([`TileDim::round_robin`] — the degenerate tile-size-1 cyclic
//!   deal), so occupancy differs by at most one system per device;
//! * each device's systems are concatenated column-major inside its
//!   arena; [`PackedPod::pack`]/[`PackedPod::gather`] move the whole
//!   arena in **one staged copy per device** (one `h2d` latency charge
//!   each) instead of `B` per-system scatters/redistributes;
//! * systems keep their exact shapes (pods may mix sizes within a
//!   bucket's size-class) — no padding, so the batched sweeps in
//!   [`super::sweep`] are bitwise-identical to solving each system
//!   individually.

use crate::device::{DevPtr, SimNode};
use crate::error::{Error, Result};
use crate::layout::TileDim;
use crate::linalg::Matrix;
use crate::scalar::Scalar;

/// `B` small matrices packed into one arena per device.
pub struct PackedPod<S: Scalar> {
    node: SimNode,
    /// Owning device of each system.
    devs: Vec<usize>,
    dims: Vec<(usize, usize)>,
    /// Elem offset of system `i` inside its device's arena.
    offsets: Vec<usize>,
    arenas: Vec<Option<DevPtr>>,
    arena_elems: Vec<usize>,
    _marker: std::marker::PhantomData<S>,
}

impl<S: Scalar> PackedPod<S> {
    /// Pack `systems` onto `node`'s devices round-robin, one staged
    /// copy (and one `h2d` timing charge) per device.
    pub fn pack(node: &SimNode, systems: &[Matrix<S>]) -> Result<Self> {
        let deal = TileDim::round_robin(systems.len(), node.num_devices())?;
        let devs = (0..systems.len()).map(|i| deal.owner(i)).collect();
        Self::pack_with(node, systems, devs)
    }

    /// Pack every system onto one explicit device. This is the
    /// degraded-bucket retry path's placement: a system rerun after a
    /// bucket-mate failed must stay on the device its original
    /// round-robin reservation lives on, or the retry would allocate
    /// outside the admitted footprint.
    pub fn pack_on(node: &SimNode, systems: &[Matrix<S>], dev: usize) -> Result<Self> {
        if dev >= node.num_devices() {
            return Err(Error::config(format!(
                "pod device {dev} out of range (node has {})",
                node.num_devices()
            )));
        }
        Self::pack_with(node, systems, vec![dev; systems.len()])
    }

    fn pack_with(node: &SimNode, systems: &[Matrix<S>], devs: Vec<usize>) -> Result<Self> {
        if systems.is_empty() {
            return Err(Error::config("a pod needs at least one system"));
        }
        let ndev = node.num_devices();
        let dims: Vec<(usize, usize)> = systems.iter().map(|m| (m.rows(), m.cols())).collect();
        // Per-system arena offsets: prefix sums in each device's
        // storage order (ascending system index).
        let mut offsets = vec![0usize; systems.len()];
        let mut arena_elems = vec![0usize; ndev];
        for i in 0..systems.len() {
            let d = devs[i];
            offsets[i] = arena_elems[d];
            arena_elems[d] += dims[i].0 * dims[i].1;
        }
        let mut arenas: Vec<Option<DevPtr>> = Vec::with_capacity(ndev);
        for (d, &elems) in arena_elems.iter().enumerate() {
            if elems == 0 {
                arenas.push(None);
                continue;
            }
            let ptr = node.alloc_scalars::<S>(d, elems)?;
            // Build the device's arena host-side, then one staged write.
            let mut buf = Vec::with_capacity(elems);
            for (i, sys) in systems.iter().enumerate() {
                if devs[i] == d {
                    buf.extend_from_slice(sys.as_slice());
                }
            }
            debug_assert_eq!(buf.len(), elems);
            node.write_slice(ptr, 0, &buf)?;
            node.charge_h2d(d, std::mem::size_of_val(buf.as_slice()))?;
            arenas.push(Some(ptr));
        }
        Ok(PackedPod {
            node: node.clone(),
            devs,
            dims,
            offsets,
            arenas,
            arena_elems,
            _marker: std::marker::PhantomData,
        })
    }

    /// Number of systems packed.
    pub fn batch(&self) -> usize {
        self.dims.len()
    }

    /// The node the pod lives on.
    pub fn node(&self) -> &SimNode {
        &self.node
    }

    /// `(rows, cols)` of system `i`.
    pub fn dims(&self, i: usize) -> (usize, usize) {
        self.dims[i]
    }

    /// Owning device of system `i`.
    pub fn device_of(&self, i: usize) -> usize {
        self.devs[i]
    }

    /// Systems resident on device `d`, in arena storage order.
    pub fn systems_on(&self, d: usize) -> impl Iterator<Item = usize> + '_ {
        self.devs.iter().enumerate().filter(move |&(_, &dd)| dd == d).map(|(i, _)| i)
    }

    /// Arena bytes resident on device `d`.
    pub fn arena_bytes(&self, d: usize) -> usize {
        self.arena_elems[d] * std::mem::size_of::<S>()
    }

    /// Whether `other` packs the same batch with the same placement
    /// (the precondition for running a two-pod sweep such as `potrs`).
    pub fn aligned_with<T: Scalar>(&self, other: &PackedPod<T>) -> bool {
        self.devs == other.devs
    }

    /// Host copy of system `i` (the sweep staging path; no timing
    /// charge, like [`DistMatrix::read_block`](crate::tile::DistMatrix::read_block)).
    /// Zero-element systems (an `n × 0` RHS, say) never touch an arena.
    pub fn read_system(&self, i: usize) -> Result<Matrix<S>> {
        let (r, c) = self.dims[i];
        if r * c == 0 {
            return Ok(Matrix::zeros(r, c));
        }
        let d = self.device_of(i);
        let ptr = self.arenas[d].ok_or_else(|| Error::layout("pod arena missing"))?;
        let mut buf = vec![S::zero(); r * c];
        self.node.read_slice(ptr, self.offsets[i], &mut buf)?;
        Ok(Matrix::from_vec(r, c, buf))
    }

    /// Write a host block back over system `i` (shape must match).
    pub fn write_system(&self, i: usize, m: &Matrix<S>) -> Result<()> {
        let (r, c) = self.dims[i];
        if m.rows() != r || m.cols() != c {
            return Err(Error::shape(format!(
                "system {i} is {r}x{c} but the write is {}x{}",
                m.rows(),
                m.cols()
            )));
        }
        if r * c == 0 {
            return Ok(());
        }
        let d = self.device_of(i);
        let ptr = self.arenas[d].ok_or_else(|| Error::layout("pod arena missing"))?;
        self.node.write_slice(ptr, self.offsets[i], m.as_slice())
    }

    /// Gather every system back to the host: one staged read (and one
    /// `h2d` timing charge) per device.
    pub fn gather(&self) -> Result<Vec<Matrix<S>>> {
        let mut out: Vec<Option<Matrix<S>>> = self
            .dims
            .iter()
            // Zero-element systems live on no arena (a device whose
            // systems are all empty allocates nothing); seed them here.
            .map(|&(r, c)| if r * c == 0 { Some(Matrix::zeros(r, c)) } else { None })
            .collect();
        for (d, arena) in self.arenas.iter().enumerate() {
            let Some(ptr) = arena else { continue };
            let mut buf = vec![S::zero(); self.arena_elems[d]];
            self.node.read_slice(*ptr, 0, &mut buf)?;
            self.node.charge_h2d(d, std::mem::size_of_val(buf.as_slice()))?;
            for i in self.systems_on(d) {
                let (r, c) = self.dims[i];
                let off = self.offsets[i];
                out[i] = Some(Matrix::from_vec(r, c, buf[off..off + r * c].to_vec()));
            }
        }
        Ok(out.into_iter().map(|m| m.expect("every system gathered")).collect())
    }

    /// Free the device arenas. (Also on drop; explicit form propagates
    /// errors.)
    pub fn free(mut self) -> Result<()> {
        for p in std::mem::take(&mut self.arenas).into_iter().flatten() {
            self.node.free(p)?;
        }
        Ok(())
    }
}

impl<S: Scalar> Drop for PackedPod<S> {
    fn drop(&mut self) {
        for p in self.arenas.drain(..).flatten() {
            let _ = self.node.free(p);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scalar::c64;

    #[test]
    fn pack_gather_roundtrip_mixed_sizes() {
        let node = SimNode::new_uniform(3, 1 << 22);
        let systems: Vec<Matrix<f64>> =
            (0..7).map(|i| Matrix::random(4 + i, 4 + i, i as u64)).collect();
        let pod = PackedPod::pack(&node, &systems).unwrap();
        assert_eq!(pod.batch(), 7);
        // Round-robin deal: system i on device i mod 3.
        for i in 0..7 {
            assert_eq!(pod.device_of(i), i % 3);
        }
        assert_eq!(pod.systems_on(0).collect::<Vec<_>>(), vec![0, 3, 6]);
        let back = pod.gather().unwrap();
        for (a, b) in systems.iter().zip(back.iter()) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn read_write_system_in_place() {
        let node = SimNode::new_uniform(2, 1 << 20);
        let systems: Vec<Matrix<c64>> = (0..4).map(|i| Matrix::random(5, 3, 10 + i)).collect();
        let pod = PackedPod::pack(&node, &systems).unwrap();
        assert_eq!(pod.read_system(2).unwrap(), systems[2]);
        let repl = Matrix::<c64>::random(5, 3, 99);
        pod.write_system(2, &repl).unwrap();
        assert_eq!(pod.read_system(2).unwrap(), repl);
        // Neighbours untouched.
        assert_eq!(pod.read_system(0).unwrap(), systems[0]);
        assert!(pod.write_system(1, &Matrix::<c64>::zeros(2, 2)).is_err());
    }

    #[test]
    fn one_staged_copy_per_device() {
        let node = SimNode::new_uniform(4, 1 << 22);
        let systems: Vec<Matrix<f32>> = (0..8).map(|i| Matrix::random(6, 6, i)).collect();
        node.reset_accounting();
        let pod = PackedPod::pack(&node, &systems).unwrap();
        // Each device holds one arena allocation only.
        for (d, rep) in node.memory_reports().iter().enumerate() {
            assert_eq!(rep.allocations, 1, "device {d} must hold exactly one arena");
            assert_eq!(rep.used, pod.arena_bytes(d));
        }
        drop(pod);
        for rep in node.memory_reports() {
            assert_eq!(rep.used, 0);
        }
    }

    #[test]
    fn pack_on_pins_every_system_to_one_device() {
        let node = SimNode::new_uniform(3, 1 << 20);
        let systems: Vec<Matrix<f64>> = (0..4).map(|i| Matrix::random(3, 3, i)).collect();
        let pod = PackedPod::pack_on(&node, &systems, 2).unwrap();
        for i in 0..4 {
            assert_eq!(pod.device_of(i), 2);
        }
        assert_eq!(pod.arena_bytes(0), 0);
        assert_eq!(pod.systems_on(2).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        assert_eq!(pod.gather().unwrap()[3], systems[3]);
        // A round-robin pod is not aligned with a pinned one.
        let rr = PackedPod::pack(&node, &systems).unwrap();
        assert!(!rr.aligned_with(&pod));
        assert!(PackedPod::pack_on(&node, &systems, 7).is_err());
    }

    #[test]
    fn zero_element_systems_roundtrip() {
        // An n×0 system (an empty RHS) on a device of its own: no
        // arena exists there, yet read/write/gather all hold.
        let node = SimNode::new_uniform(2, 1 << 20);
        let systems = vec![Matrix::<f64>::random(4, 2, 1), Matrix::<f64>::zeros(4, 0)];
        let pod = PackedPod::pack(&node, &systems).unwrap();
        assert_eq!(pod.arena_bytes(1), 0);
        assert_eq!(pod.read_system(1).unwrap().shape(), (4, 0));
        pod.write_system(1, &Matrix::<f64>::zeros(4, 0)).unwrap();
        let back = pod.gather().unwrap();
        assert_eq!(back[0], systems[0]);
        assert_eq!(back[1].shape(), (4, 0));
    }

    #[test]
    fn fewer_systems_than_devices() {
        let node = SimNode::new_uniform(4, 1 << 20);
        let systems = vec![Matrix::<f64>::random(3, 3, 1)];
        let pod = PackedPod::pack(&node, &systems).unwrap();
        assert_eq!(pod.arena_bytes(1), 0);
        assert_eq!(pod.gather().unwrap()[0], systems[0]);
        assert!(PackedPod::<f64>::pack(&node, &[]).is_err());
    }
}
