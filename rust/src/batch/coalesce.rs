//! Request coalescing: buckets of queued small solves, flushed into
//! batched sweeps under a latency bound.
//!
//! The planner is pure bookkeeping — it never touches matrices, so the
//! service layer can keep per-request payloads type-erased and the
//! planner stays trivially unit-testable. Requests are keyed by
//! [`BucketKey`] (routine × dtype × power-of-two [`size_class`]); a
//! bucket flushes when
//!
//! * it reaches [`BatchPolicy::max_batch`] requests (flushed by the
//!   submit that filled it), or
//! * its **oldest** request has dwelled longer than
//!   [`BatchPolicy::max_dwell_ns`] in *cost-model nanoseconds* (the
//!   simulated clock — the latency bound is a promise about the
//!   modeled system) **or** longer than [`BatchPolicy::max_wall_dwell`]
//!   of real time (the liveness backstop: purely coalesced traffic
//!   charges nothing, so the simulated clock alone could freeze and
//!   strand a bucket forever), checked by [`BatchPlanner::due`] on
//!   every subsequent submit and on drain. With no timer thread, a
//!   bucket on an otherwise idle service still needs an explicit
//!   `flush_small`/drain.
//!
//! Whether a request should be coalesced at all — batched-vs-
//! distributed — is the cost model's call:
//! [`crate::costmodel::Predictor::batched_wins`] compares the fused
//! pod-sweep makespan against the one-at-a-time distributed path, and
//! [`BatchPolicy::small_dim`] caps the size the coalescer will even
//! consider (the `n ≲ 4·T_A` rule of thumb).

use crate::scalar::DType;
use std::collections::HashMap;

/// The three routines the batched small-solve path serves.
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
pub enum SmallRoutine {
    /// Cholesky factorization only.
    Potrf,
    /// Factor + two-sweep solve against a per-system RHS.
    Potrs,
    /// Factor + Cholesky-based inverse.
    Potri,
}

impl SmallRoutine {
    /// The cost-model / workspace-table name.
    pub fn name(self) -> &'static str {
        match self {
            SmallRoutine::Potrf => "potrf",
            SmallRoutine::Potrs => "potrs",
            SmallRoutine::Potri => "potri",
        }
    }
}

/// Power-of-two size class of an `n × n` system (minimum class 4):
/// requests within a class share a bucket, so one fused sweep serves
/// systems of slightly different sizes without padding.
pub fn size_class(n: usize) -> u32 {
    n.max(4).next_power_of_two() as u32
}

/// What a queued small solve is grouped by.
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
pub struct BucketKey {
    pub routine: SmallRoutine,
    pub dtype: DType,
    pub size_class: u32,
}

impl BucketKey {
    /// Key for an `n × n` request.
    pub fn new(routine: SmallRoutine, dtype: DType, n: usize) -> Self {
        BucketKey { routine, dtype, size_class: size_class(n) }
    }
}

/// Coalescing knobs.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct BatchPolicy {
    /// Flush a bucket as soon as it holds this many requests.
    pub max_batch: usize,
    /// Flush a bucket once its oldest request has waited this long on
    /// the simulated clock (cost-model nanoseconds).
    pub max_dwell_ns: u64,
    /// Wall-clock liveness backstop: flush a bucket once its oldest
    /// request has waited this long in real time, whether or not the
    /// simulated clock moved (coalesced-only traffic charges nothing,
    /// so the modeled dwell alone could never fire).
    pub max_wall_dwell: std::time::Duration,
    /// Largest `n` the coalescer considers small (the `4·T_A` rule);
    /// larger requests take the distributed path unconditionally.
    pub small_dim: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        // 32-way fusion, a 50 µs modeled dwell bound (≈ ten NVLink
        // latencies), a half-second real-time backstop, and the 4·T_A
        // smallness cut at the default tile.
        BatchPolicy {
            max_batch: 32,
            max_dwell_ns: 50_000,
            max_wall_dwell: std::time::Duration::from_millis(500),
            small_dim: 4 * 64,
        }
    }
}

/// Background-ticker interval for a wall-dwell backstop: half the
/// dwell bound for responsiveness, clamped to a 5 ms floor (a
/// zero/tiny dwell policy polls instead of busy-spinning the CPU) and
/// a 250 ms cap (a huge dwell still reacts within a quarter second).
/// Shared by the SPMD background dwell flusher and the MPMD
/// dispatcher's idle wait so neither front re-grows the spin bug.
pub fn flusher_tick(max_wall_dwell: std::time::Duration) -> std::time::Duration {
    (max_wall_dwell / 2)
        .clamp(std::time::Duration::from_millis(5), std::time::Duration::from_millis(250))
}

/// One bucket ready to sweep: the request ids in FIFO order and each
/// request's coalesce wait (cost-model ns) at flush time.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FlushedBucket {
    pub key: BucketKey,
    pub ids: Vec<u64>,
    pub waits_ns: Vec<u64>,
}

struct Bucket {
    ids: Vec<u64>,
    enqueued_ns: Vec<u64>,
    /// Real time the bucket opened (the wall-dwell backstop's anchor).
    opened: std::time::Instant,
}

/// FIFO bucket planner for the batched small-solve path.
pub struct BatchPlanner {
    policy: BatchPolicy,
    buckets: HashMap<BucketKey, Bucket>,
    next_id: u64,
}

impl BatchPlanner {
    /// New planner under `policy`.
    pub fn new(policy: BatchPolicy) -> Self {
        BatchPlanner { policy, buckets: HashMap::new(), next_id: 0 }
    }

    /// The active policy.
    pub fn policy(&self) -> &BatchPolicy {
        &self.policy
    }

    /// Enqueue a request into its bucket at simulated time `now_ns`.
    /// Returns the request id and, when this push filled the bucket to
    /// `max_batch`, the flushed bucket.
    pub fn push(&mut self, key: BucketKey, now_ns: u64) -> (u64, Option<FlushedBucket>) {
        let id = self.next_id;
        self.next_id += 1;
        let bucket = self.buckets.entry(key).or_insert_with(|| Bucket {
            ids: Vec::new(),
            enqueued_ns: Vec::new(),
            opened: std::time::Instant::now(),
        });
        bucket.ids.push(id);
        bucket.enqueued_ns.push(now_ns);
        let flushed = if bucket.ids.len() >= self.policy.max_batch {
            self.flush(key, now_ns)
        } else {
            None
        };
        (id, flushed)
    }

    /// Buckets whose oldest request has dwelled past the policy bound
    /// — on the simulated clock, or (the liveness backstop) in real
    /// time.
    pub fn due(&self, now_ns: u64) -> Vec<BucketKey> {
        self.buckets
            .iter()
            .filter(|(_, b)| {
                let sim_due = b
                    .enqueued_ns
                    .first()
                    .is_some_and(|&t0| now_ns.saturating_sub(t0) >= self.policy.max_dwell_ns);
                sim_due || b.opened.elapsed() >= self.policy.max_wall_dwell
            })
            .map(|(k, _)| *k)
            .collect()
    }

    /// Flush one bucket (requests in FIFO order), recording each
    /// request's coalesce wait as of `now_ns`.
    pub fn flush(&mut self, key: BucketKey, now_ns: u64) -> Option<FlushedBucket> {
        let bucket = self.buckets.remove(&key)?;
        if bucket.ids.is_empty() {
            return None;
        }
        let waits_ns =
            bucket.enqueued_ns.iter().map(|&t| now_ns.saturating_sub(t)).collect();
        Some(FlushedBucket { key, ids: bucket.ids, waits_ns })
    }

    /// Flush every non-empty bucket (drain path).
    pub fn flush_all(&mut self, now_ns: u64) -> Vec<FlushedBucket> {
        let keys: Vec<BucketKey> = self.buckets.keys().copied().collect();
        keys.into_iter().filter_map(|k| self.flush(k, now_ns)).collect()
    }

    /// Requests currently waiting across all buckets.
    pub fn pending(&self) -> usize {
        self.buckets.values().map(|b| b.ids.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(n: usize) -> BucketKey {
        BucketKey::new(SmallRoutine::Potrs, DType::F64, n)
    }

    #[test]
    fn size_classes_are_powers_of_two() {
        assert_eq!(size_class(1), 4);
        assert_eq!(size_class(4), 4);
        assert_eq!(size_class(5), 8);
        assert_eq!(size_class(64), 64);
        assert_eq!(size_class(65), 128);
        // Neighbouring sizes within a class share a bucket.
        assert_eq!(key(33), key(64));
        assert_ne!(key(64), key(65));
    }

    #[test]
    fn bucket_flushes_at_max_batch() {
        let mut p = BatchPlanner::new(BatchPolicy { max_batch: 3, ..Default::default() });
        let (a, f) = p.push(key(16), 0);
        assert!(f.is_none());
        let (b, f) = p.push(key(16), 10);
        assert!(f.is_none());
        assert_eq!(p.pending(), 2);
        let (c, f) = p.push(key(16), 20);
        let f = f.expect("third push fills the bucket");
        assert_eq!(f.ids, vec![a, b, c]);
        assert_eq!(f.waits_ns, vec![20, 10, 0]);
        assert_eq!(p.pending(), 0);
    }

    #[test]
    fn distinct_keys_do_not_share_buckets() {
        let mut p = BatchPlanner::new(BatchPolicy { max_batch: 2, ..Default::default() });
        let k1 = key(16);
        let k2 = BucketKey::new(SmallRoutine::Potrf, DType::F64, 16);
        let k3 = BucketKey::new(SmallRoutine::Potrs, DType::F32, 16);
        p.push(k1, 0);
        p.push(k2, 0);
        p.push(k3, 0);
        assert_eq!(p.pending(), 3);
        let (_, f) = p.push(k1, 5);
        assert_eq!(f.unwrap().key, k1);
        assert_eq!(p.pending(), 2);
    }

    #[test]
    fn dwell_bound_marks_buckets_due() {
        let policy = BatchPolicy { max_batch: 100, max_dwell_ns: 1_000, ..Default::default() };
        let mut p = BatchPlanner::new(policy);
        p.push(key(8), 500);
        assert!(p.due(600).is_empty());
        assert_eq!(p.due(1_500), vec![key(8)]);
        let f = p.flush(key(8), 1_500).unwrap();
        assert_eq!(f.waits_ns, vec![1_000]);
        assert!(p.flush(key(8), 2_000).is_none(), "bucket already flushed");
    }

    #[test]
    fn wall_clock_backstop_marks_buckets_due() {
        // A frozen simulated clock cannot strand a bucket: the wall
        // backstop fires independently of now_ns.
        let policy = BatchPolicy {
            max_batch: 100,
            max_dwell_ns: u64::MAX,
            max_wall_dwell: std::time::Duration::ZERO,
            ..Default::default()
        };
        let mut p = BatchPlanner::new(policy);
        p.push(key(8), 0);
        assert_eq!(p.due(0), vec![key(8)], "zero wall bound is due immediately");
    }

    #[test]
    fn flusher_tick_clamps_to_a_poll_floor_and_cap() {
        use std::time::Duration;
        // Zero/tiny dwell must not busy-spin: floor at 5 ms.
        assert_eq!(flusher_tick(Duration::ZERO), Duration::from_millis(5));
        assert_eq!(flusher_tick(Duration::from_micros(1)), Duration::from_millis(5));
        // Mid-range: half the dwell.
        assert_eq!(flusher_tick(Duration::from_millis(100)), Duration::from_millis(50));
        // Huge dwell still reacts within a quarter second.
        assert_eq!(flusher_tick(Duration::from_secs(60)), Duration::from_millis(250));
    }

    #[test]
    fn flush_all_drains_everything() {
        let mut p = BatchPlanner::new(BatchPolicy { max_batch: 100, ..Default::default() });
        p.push(key(8), 0);
        p.push(key(16), 0);
        p.push(key(16), 1);
        let flushed = p.flush_all(10);
        assert_eq!(flushed.len(), 2);
        assert_eq!(flushed.iter().map(|f| f.ids.len()).sum::<usize>(), 3);
        assert_eq!(p.pending(), 0);
        assert!(p.flush_all(20).is_empty());
    }
}
