//! Predictor-drift monitoring: estimated vs observed makespans.
//!
//! Every traced distributed solve records three numbers under its
//! `(routine, dtype, n, grid)` key:
//!
//! * `est_model_ns` — the planner's uncorrected estimate, which on
//!   barrier schedules is **bitwise** `secs_to_ns(Predictor::
//!   dist_makespan(...))` (asserted by `plan_estimates_match_the_
//!   predictor_bitwise` and the golden obs tests);
//! * `est_used_ns` — the estimate the `SloQueue` actually scheduled
//!   with (equal to the model estimate unless drift correction or a
//!   cache deduction adjusted it);
//! * `obs_ns` — the observed exec makespan of the request.
//!
//! Lookahead pipelining, cache hits, IPC charges, and degraded-mode
//! runs all make `obs_ns` diverge from the barrier model; the per-key
//! ratio `obs_sum / est_model_sum` becomes a multiplicative correction
//! factor that the serving fronts can opt into
//! (`drift_correction: true`), tightening future `SloQueue` estimates.
//! All arithmetic is integer (u128 sums, ratio applied in u128), so
//! the correction is deterministic and bit-stable.

use std::collections::BTreeMap;
use std::sync::Mutex;

/// Key a drift sample is accumulated under.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct DriftKey {
    pub routine: String,
    pub dtype: String,
    pub n: u64,
    pub grid: (u32, u32),
}

/// Accumulated drift statistics for one key.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct DriftStat {
    pub samples: u64,
    /// Sum of uncorrected model estimates (ns).
    pub est_model_sum: u128,
    /// Sum of estimates the scheduler actually used (ns).
    pub est_used_sum: u128,
    /// Sum of observed exec makespans (ns).
    pub obs_sum: u128,
    /// Sum of |obs - est_model| per sample (ns).
    pub abs_err_model_sum: u128,
    /// Sum of |obs - est_used| per sample (ns).
    pub abs_err_used_sum: u128,
}

impl DriftStat {
    /// Signed mean drift of observation vs the raw model, in ns:
    /// positive means the model underestimates.
    pub fn mean_drift_ns(&self) -> i128 {
        if self.samples == 0 {
            return 0;
        }
        (self.obs_sum as i128 - self.est_model_sum as i128) / self.samples as i128
    }
}

/// Thread-safe per-key drift accumulator with an integer-ratio
/// correction factor. Keys live in a `BTreeMap` so every snapshot and
/// rendered table is deterministically ordered.
pub struct DriftMonitor {
    stats: Mutex<BTreeMap<DriftKey, DriftStat>>,
    /// Minimum samples under a key before `corrected_est` starts
    /// adjusting estimates (avoids correcting off one noisy point).
    min_samples: u64,
}

impl Default for DriftMonitor {
    fn default() -> Self {
        Self::new()
    }
}

impl DriftMonitor {
    pub fn new() -> Self {
        DriftMonitor {
            stats: Mutex::new(BTreeMap::new()),
            min_samples: 2,
        }
    }

    /// Record one completed solve under `key`.
    pub fn record(&self, key: DriftKey, est_model_ns: u64, est_used_ns: u64, obs_ns: u64) {
        let mut map = self.stats.lock().unwrap();
        let st = map.entry(key).or_default();
        st.samples += 1;
        st.est_model_sum += est_model_ns as u128;
        st.est_used_sum += est_used_ns as u128;
        st.obs_sum += obs_ns as u128;
        st.abs_err_model_sum += est_model_ns.abs_diff(obs_ns) as u128;
        st.abs_err_used_sum += est_used_ns.abs_diff(obs_ns) as u128;
    }

    /// Apply the accumulated correction for `key` to a fresh model
    /// estimate. Returns `est_ns` unchanged until the key has
    /// `min_samples` observations; afterwards scales by the integer
    /// ratio `obs_sum / est_model_sum` (computed in u128, saturating).
    pub fn corrected_est(&self, key: &DriftKey, est_ns: u64) -> u64 {
        let map = self.stats.lock().unwrap();
        match map.get(key) {
            Some(st) if st.samples >= self.min_samples && st.est_model_sum > 0 => {
                let scaled = est_ns as u128 * st.obs_sum / st.est_model_sum;
                scaled.min(u64::MAX as u128) as u64
            }
            _ => est_ns,
        }
    }

    /// Deterministic snapshot of all keys and their stats.
    pub fn stats(&self) -> Vec<(DriftKey, DriftStat)> {
        self.stats
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect()
    }

    /// Total |obs - est_used| across every key: the headline "how
    /// wrong were the estimates the scheduler ran with" number.
    pub fn total_abs_err_used(&self) -> u128 {
        self.stats
            .lock()
            .unwrap()
            .values()
            .map(|s| s.abs_err_used_sum)
            .sum()
    }

    /// Total |obs - est_model| across every key (correction-blind).
    pub fn total_abs_err_model(&self) -> u128 {
        self.stats
            .lock()
            .unwrap()
            .values()
            .map(|s| s.abs_err_model_sum)
            .sum()
    }

    /// Total samples across every key.
    pub fn total_samples(&self) -> u64 {
        self.stats.lock().unwrap().values().map(|s| s.samples).sum()
    }

    pub fn clear(&self) {
        self.stats.lock().unwrap().clear();
    }

    /// Human-readable drift table (deterministic order).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(
            "routine dtype     n  grid   samples   est_model_ns      obs_ns   mean_drift_ns\n",
        );
        for (k, s) in self.stats() {
            out.push_str(&format!(
                "{:<7} {:<5} {:>6}  {}x{}  {:>8}  {:>13}  {:>10}  {:>14}\n",
                k.routine,
                k.dtype,
                k.n,
                k.grid.0,
                k.grid.1,
                s.samples,
                s.est_model_sum,
                s.obs_sum,
                s.mean_drift_ns(),
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(routine: &str, n: u64) -> DriftKey {
        DriftKey {
            routine: routine.into(),
            dtype: "f64".into(),
            n,
            grid: (2, 2),
        }
    }

    #[test]
    fn zero_drift_keeps_estimates_exact() {
        let m = DriftMonitor::new();
        for _ in 0..5 {
            m.record(key("potrf", 128), 1000, 1000, 1000);
        }
        assert_eq!(m.corrected_est(&key("potrf", 128), 1000), 1000);
        assert_eq!(m.corrected_est(&key("potrf", 128), 777), 777);
        assert_eq!(m.total_abs_err_model(), 0);
        assert_eq!(m.total_abs_err_used(), 0);
        let stats = m.stats();
        assert_eq!(stats.len(), 1);
        assert_eq!(stats[0].1.mean_drift_ns(), 0);
    }

    #[test]
    fn correction_waits_for_min_samples_then_scales() {
        let m = DriftMonitor::new();
        // One sample: no correction yet.
        m.record(key("potrs", 64), 1000, 1000, 1500);
        assert_eq!(m.corrected_est(&key("potrs", 64), 1000), 1000);
        // Second sample crosses min_samples: ratio = 3000/2000 = 1.5x.
        m.record(key("potrs", 64), 1000, 1000, 1500);
        assert_eq!(m.corrected_est(&key("potrs", 64), 1000), 1500);
        assert_eq!(m.corrected_est(&key("potrs", 64), 2000), 3000);
        // Unknown key untouched.
        assert_eq!(m.corrected_est(&key("potrs", 65), 1000), 1000);
    }

    #[test]
    fn integer_ratio_is_deterministic_and_saturating() {
        let m = DriftMonitor::new();
        m.record(key("syevd", 32), 3, 3, 10);
        m.record(key("syevd", 32), 3, 3, 10);
        // ratio 20/6 applied in u128: 9 * 20 / 6 = 30 exactly.
        assert_eq!(m.corrected_est(&key("syevd", 32), 9), 30);
        // 7 * 20 / 6 = 23 (floor), not a float round.
        assert_eq!(m.corrected_est(&key("syevd", 32), 7), 23);
        // Saturation instead of overflow.
        let m2 = DriftMonitor::new();
        m2.record(key("potrf", 8), 1, 1, u64::MAX);
        m2.record(key("potrf", 8), 1, 1, u64::MAX);
        assert_eq!(m2.corrected_est(&key("potrf", 8), u64::MAX), u64::MAX);
    }

    #[test]
    fn keys_snapshot_in_sorted_order() {
        let m = DriftMonitor::new();
        m.record(key("syevd", 128), 1, 1, 1);
        m.record(key("potrf", 64), 1, 1, 1);
        m.record(key("potrf", 128), 1, 1, 1);
        let keys: Vec<String> = m
            .stats()
            .iter()
            .map(|(k, _)| format!("{}-{}", k.routine, k.n))
            .collect();
        assert_eq!(keys, vec!["potrf-64", "potrf-128", "syevd-128"]);
        let table = m.render();
        assert!(table.contains("potrf"));
        assert!(table.contains("syevd"));
    }
}
