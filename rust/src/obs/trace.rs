//! Request-scoped span and decision recording on the sim clock.
//!
//! A [`Tracer`] is purely passive: it never charges simulated time and
//! never takes a lock when disabled, so enabling tracing changes no
//! golden timeline by a single nanosecond. All timestamps are the
//! integer cost-model nanoseconds already maintained by
//! `SimClock`/`Stream`; the tracer just snapshots them.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use super::drift::DriftMonitor;

/// Identifies one end-to-end request (one submission on a serving
/// front, one pod flush, one fused DAG). `TraceId(0)` means "tracing
/// disabled / no trace" and is never recorded.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TraceId(pub u64);

/// Identifies one span within a trace. `SpanId(0)` doubles as "no
/// parent" on root spans and as the null span when tracing is off.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct SpanId(pub u64);

/// One completed span: a named interval `[t0_ns, t1_ns]` of simulated
/// time attributed to a device×stream track.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SpanRec {
    pub trace: TraceId,
    pub span: SpanId,
    /// `SpanId(0)` marks a root span.
    pub parent: SpanId,
    pub name: String,
    /// Coarse category: "request", "sched", "cache", "xfer",
    /// "compute", "collective", ...
    pub cat: &'static str,
    /// Device index the span is attributed to (track pid). Service-
    /// level spans that belong to no single device use device 0 with
    /// the "requests" stream.
    pub device: usize,
    /// Stream/track name: "requests", "compute", "panel", "copy".
    pub stream: &'static str,
    pub t0_ns: u64,
    pub t1_ns: u64,
    /// Bytes moved (transfers/collectives), 0 otherwise.
    pub bytes: u64,
    /// Floating-point ops charged (compute spans), 0 otherwise.
    pub flops: u64,
}

/// One scheduler/cache/failure decision, timestamped on the sim clock.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DecisionRec {
    pub t_ns: u64,
    /// Trace the decision concerns; `TraceId(0)` for global events
    /// (worker kill, straggler injection) not tied to one request.
    pub trace: TraceId,
    /// "admit", "skip-barrier", "preempt", "evict", "invalidate",
    /// "requeue", "kill", "straggler", "cache-hit", "cache-miss",
    /// "arrival", ...
    pub kind: &'static str,
    pub detail: String,
}

/// Passive span/decision recorder shared by every layer of a node.
///
/// Disabled by default. `enable()` turns on recording; every recording
/// entry point first checks the flag with one relaxed atomic load, so
/// the disabled cost is negligible and — more importantly — the tracer
/// never advances any simulated clock either way.
pub struct Tracer {
    enabled: AtomicBool,
    next_trace: AtomicU64,
    next_span: AtomicU64,
    spans: Mutex<Vec<SpanRec>>,
    decisions: Mutex<Vec<DecisionRec>>,
    drift: DriftMonitor,
}

impl Default for Tracer {
    fn default() -> Self {
        Self::new()
    }
}

impl std::fmt::Debug for Tracer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Tracer").field("enabled", &self.enabled()).finish()
    }
}

impl Tracer {
    pub fn new() -> Self {
        Tracer {
            enabled: AtomicBool::new(false),
            next_trace: AtomicU64::new(1),
            next_span: AtomicU64::new(1),
            spans: Mutex::new(Vec::new()),
            decisions: Mutex::new(Vec::new()),
            drift: DriftMonitor::new(),
        }
    }

    pub fn enable(&self) {
        self.enabled.store(true, Ordering::Relaxed);
    }

    pub fn disable(&self) {
        self.enabled.store(false, Ordering::Relaxed);
    }

    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Mint a fresh trace id plus the pre-assigned id of its root
    /// span. The root span *record* is emitted exactly once, by
    /// whichever attempt publishes (or terminally fails) the request;
    /// pre-minting the id lets child spans reference the root before
    /// the request resolves. Returns zeros when disabled.
    pub fn new_trace(&self) -> (TraceId, SpanId) {
        if !self.enabled() {
            return (TraceId(0), SpanId(0));
        }
        let t = TraceId(self.next_trace.fetch_add(1, Ordering::Relaxed));
        let s = SpanId(self.next_span.fetch_add(1, Ordering::Relaxed));
        (t, s)
    }

    /// Record a completed span. No-op (returning `SpanId(0)`) when
    /// disabled or when `trace` is the null trace.
    #[allow(clippy::too_many_arguments)]
    pub fn span(
        &self,
        trace: TraceId,
        parent: SpanId,
        name: &str,
        cat: &'static str,
        device: usize,
        stream: &'static str,
        t0_ns: u64,
        t1_ns: u64,
        bytes: u64,
        flops: u64,
    ) -> SpanId {
        if !self.enabled() || trace.0 == 0 {
            return SpanId(0);
        }
        let id = SpanId(self.next_span.fetch_add(1, Ordering::Relaxed));
        self.spans.lock().unwrap().push(SpanRec {
            trace,
            span: id,
            parent,
            name: name.to_string(),
            cat,
            device,
            stream,
            t0_ns,
            t1_ns: t1_ns.max(t0_ns),
            bytes,
            flops,
        });
        id
    }

    /// Record a span whose id was pre-minted by [`new_trace`]; used to
    /// close out root spans. No-op when disabled or `trace`/`span` is
    /// null.
    ///
    /// [`new_trace`]: Tracer::new_trace
    #[allow(clippy::too_many_arguments)]
    pub fn close_root(
        &self,
        trace: TraceId,
        span: SpanId,
        name: &str,
        device: usize,
        t0_ns: u64,
        t1_ns: u64,
        bytes: u64,
        flops: u64,
    ) {
        if !self.enabled() || trace.0 == 0 || span.0 == 0 {
            return;
        }
        self.spans.lock().unwrap().push(SpanRec {
            trace,
            span,
            parent: SpanId(0),
            name: name.to_string(),
            cat: "request",
            device,
            stream: "requests",
            t0_ns,
            t1_ns: t1_ns.max(t0_ns),
            bytes,
            flops,
        });
    }

    /// Record a decision event. No-op when disabled. `TraceId(0)` is
    /// allowed here (global events: kill, straggler).
    pub fn decision(&self, trace: TraceId, t_ns: u64, kind: &'static str, detail: String) {
        if !self.enabled() {
            return;
        }
        self.decisions.lock().unwrap().push(DecisionRec {
            t_ns,
            trace,
            kind,
            detail,
        });
    }

    /// Snapshot of all recorded spans, sorted by (trace, span) for a
    /// deterministic order regardless of recording interleaving.
    pub fn spans(&self) -> Vec<SpanRec> {
        let mut v = self.spans.lock().unwrap().clone();
        v.sort_by_key(|s| (s.trace, s.span));
        v
    }

    /// Snapshot of all recorded decisions, sorted by (t_ns, trace,
    /// kind) for determinism.
    pub fn decisions(&self) -> Vec<DecisionRec> {
        let mut v = self.decisions.lock().unwrap().clone();
        v.sort_by(|a, b| {
            (a.t_ns, a.trace, a.kind, &a.detail).cmp(&(b.t_ns, b.trace, b.kind, &b.detail))
        });
        v
    }

    /// Drop all recorded spans/decisions and reset drift stats. Id
    /// counters are *not* reset, so ids stay unique across clears.
    pub fn clear(&self) {
        self.spans.lock().unwrap().clear();
        self.decisions.lock().unwrap().clear();
        self.drift.clear();
    }

    /// The predictor-drift monitor owned by this tracer.
    pub fn drift(&self) -> &DriftMonitor {
        &self.drift
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_tracer_records_nothing() {
        let t = Tracer::new();
        assert!(!t.enabled());
        let (tr, root) = t.new_trace();
        assert_eq!(tr, TraceId(0));
        assert_eq!(root, SpanId(0));
        let s = t.span(TraceId(7), SpanId(0), "x", "request", 0, "requests", 0, 1, 0, 0);
        assert_eq!(s, SpanId(0));
        t.decision(TraceId(7), 5, "admit", "x".into());
        assert!(t.spans().is_empty());
        assert!(t.decisions().is_empty());
    }

    #[test]
    fn spans_sorted_and_ids_unique() {
        let t = Tracer::new();
        t.enable();
        let (tr1, r1) = t.new_trace();
        let (tr2, r2) = t.new_trace();
        assert_ne!(tr1, tr2);
        assert_ne!(r1, r2);
        // Record out of order; snapshot must sort by (trace, span).
        let c2 = t.span(tr2, r2, "b", "compute", 1, "compute", 10, 20, 0, 5);
        let c1 = t.span(tr1, r1, "a", "compute", 0, "compute", 0, 10, 0, 5);
        t.close_root(tr2, r2, "req", 0, 0, 20, 0, 0);
        t.close_root(tr1, r1, "req", 0, 0, 10, 0, 0);
        let spans = t.spans();
        assert_eq!(spans.len(), 4);
        let traces: Vec<u64> = spans.iter().map(|s| s.trace.0).collect();
        let mut sorted = traces.clone();
        sorted.sort_unstable();
        assert_eq!(traces, sorted);
        assert_ne!(c1, c2);
        // Exactly one root per trace.
        for tr in [tr1, tr2] {
            let roots = spans
                .iter()
                .filter(|s| s.trace == tr && s.parent == SpanId(0))
                .count();
            assert_eq!(roots, 1);
        }
    }

    #[test]
    fn clamp_and_clear() {
        let t = Tracer::new();
        t.enable();
        let (tr, root) = t.new_trace();
        t.span(tr, root, "neg", "compute", 0, "compute", 10, 4, 0, 0);
        assert_eq!(t.spans()[0].t1_ns, 10); // clamped to t0
        t.decision(TraceId(0), 1, "kill", "worker 2".into());
        assert_eq!(t.decisions().len(), 1);
        t.clear();
        assert!(t.spans().is_empty() && t.decisions().is_empty());
        let (tr2, _) = t.new_trace();
        assert!(tr2.0 > tr.0); // ids keep advancing across clear
    }
}
