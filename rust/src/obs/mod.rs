//! End-to-end observability: request-scoped tracing, deterministic
//! exports, and predictor-drift monitoring.
//!
//! See `OBSERVABILITY.md` at the repo root for the trace model, the
//! span taxonomy, how to load exports in Perfetto, and how to read the
//! drift monitor.
//!
//! The subsystem has three parts:
//!
//! * [`Tracer`] ([`trace`]) — a passive, request-scoped span and
//!   decision recorder on the **existing integer-ns simulated clock**.
//!   Every submission on either serving front mints a [`TraceId`];
//!   spans (queue wait, cache probe, scatter/stage, pipeline stages,
//!   collectives, publish) and scheduler/cache/failure decisions
//!   (admit, skip-barrier, preempt, evict, invalidate, requeue, kill,
//!   straggler) attach to it. The tracer **never charges simulated
//!   time**: with tracing on or off, every golden timeline is
//!   bit-identical — it only reads clocks and stream horizons that the
//!   cost model already advanced. Disabled (the default) it is a
//!   handful of relaxed atomic loads.
//! * [`export`] — deterministic renderers: Chrome-trace/Perfetto JSON
//!   ([`chrome_trace_json`], loadable in `chrome://tracing` or
//!   <https://ui.perfetto.dev>, one track per device×stream),
//!   Prometheus text exposition of a
//!   [`MetricsSnapshot`](crate::metrics::MetricsSnapshot) including the
//!   per-class latency histograms ([`prometheus_text`]), and a JSONL
//!   decision log ([`decisions_jsonl`]). All three are pure functions
//!   of the recorded data — byte-stable, golden-pinnable.
//! * [`DriftMonitor`] ([`drift`]) — per-`(routine, dtype, n, grid)`
//!   accounting of `Predictor` estimates vs observed makespans. On
//!   barrier schedules the planner's `est_ns` **is** the model's
//!   replayed makespan bitwise (asserted on golden runs); lookahead
//!   and degraded-mode runs accumulate real drift, which feeds back as
//!   an integer-ratio correction factor into the `SloQueue` estimates
//!   when [`SmallConfig::drift_correction`] /
//!   [`MpmdConfig::drift_correction`] is enabled.
//!
//! [`SmallConfig::drift_correction`]: crate::coordinator::SmallConfig
//! [`MpmdConfig::drift_correction`]: crate::serve::MpmdConfig

pub mod drift;
pub mod export;
pub mod trace;

pub use drift::{DriftKey, DriftMonitor, DriftStat};
pub use export::{
    chrome_trace_json, chrome_trace_with_islands, decisions_jsonl, prometheus_text, stream_tid,
    validate_chrome_json,
};
pub use trace::{DecisionRec, SpanId, SpanRec, TraceId, Tracer};
