//! Deterministic renderers for recorded traces and metrics.
//!
//! Everything here is a pure function of its inputs — no clocks, no
//! randomness, no hash-map iteration order — so every export is
//! byte-stable and golden-pinnable. No external JSON/serde crates are
//! used; the formats are small enough to emit (and validate) by hand.

use super::trace::{DecisionRec, SpanRec};
use crate::metrics::MetricsSnapshot;

/// SLO class labels in `SloClass::index()` order; kept as plain
/// strings so the exporter has no coordinator dependency.
const CLASS_LABELS: [&str; 3] = ["interactive", "standard", "batch"];

/// Minimal JSON string escaping (quotes, backslashes, control chars).
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Integer nanoseconds rendered as exact decimal microseconds — the
/// unit Chrome-trace `ts`/`dur` fields use. Emitting the text
/// ourselves (never via f64) keeps the export bit-stable.
fn ns_as_us(ns: u64) -> String {
    format!("{}.{:03}", ns / 1000, ns % 1000)
}

/// Track (tid) a stream name maps to within its device's pid:
/// `requests`=0, `compute`=1, `panel`=2, `copy`=3, `fabric`=4
/// (inter-node hops get their own track), anything else 9.
pub fn stream_tid(stream: &str) -> u64 {
    match stream {
        "requests" => 0,
        "compute" => 1,
        "panel" => 2,
        "copy" => 3,
        "fabric" => 4,
        _ => 9,
    }
}

/// Render spans as Chrome-trace/Perfetto JSON (`chrome://tracing` or
/// <https://ui.perfetto.dev> loadable).
///
/// One process (`pid`) per device, one thread (`tid`) per stream, with
/// `thread_name` metadata events naming each track. Spans are
/// complete (`"ph":"X"`) events whose `args` carry the trace/span/
/// parent ids and the byte/flop attribution, so a loaded trace can be
/// filtered per request.
pub fn chrome_trace_json(spans: &[SpanRec]) -> String {
    chrome_trace_impl(spans, &[])
}

/// [`chrome_trace_json`] with a fabric island map: `island_of[d]` is
/// device `d`'s island ordinal, and every track label gains the
/// `node{i}.dev{d}` prefix plus a `process_name` metadata event per
/// pid, so Perfetto groups the timeline by island. Devices beyond the
/// map (or an empty map — what [`chrome_trace_json`] delegates with)
/// keep the flat `dev{d}` labels byte-for-byte.
pub fn chrome_trace_with_islands(spans: &[SpanRec], island_of: &[usize]) -> String {
    chrome_trace_impl(spans, island_of)
}

fn chrome_trace_impl(spans: &[SpanRec], island_of: &[usize]) -> String {
    // Collect the (pid, tid, name) tracks actually used, sorted.
    let mut tracks: Vec<(u64, u64, &str)> = spans
        .iter()
        .map(|s| (s.device as u64, stream_tid(s.stream), s.stream))
        .collect();
    tracks.sort_unstable();
    tracks.dedup();

    let mut out = String::from("{\"traceEvents\":[");
    let mut first = true;
    let mut last_pid = None;
    for (pid, tid, stream) in &tracks {
        if let Some(&isl) = island_of.get(*pid as usize) {
            if last_pid != Some(*pid) {
                last_pid = Some(*pid);
                if !first {
                    out.push(',');
                }
                first = false;
                out.push_str(&format!(
                    "\n{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":{},\
                     \"args\":{{\"name\":\"node{}.dev{}\"}}}}",
                    pid, isl, pid
                ));
            }
        }
        if !first {
            out.push(',');
        }
        first = false;
        let label = match island_of.get(*pid as usize) {
            Some(&isl) => format!("node{}.dev{}/{}", isl, pid, stream),
            None => format!("dev{}/{}", pid, stream),
        };
        out.push_str(&format!(
            "\n{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":{},\"tid\":{},\
             \"args\":{{\"name\":\"{}\"}}}}",
            pid, tid, label
        ));
    }
    for s in spans {
        if !first {
            out.push(',');
        }
        first = false;
        out.push_str(&format!(
            "\n{{\"name\":\"{}\",\"ph\":\"X\",\"cat\":\"{}\",\"pid\":{},\"tid\":{},\
             \"ts\":{},\"dur\":{},\"args\":{{\"trace\":{},\"span\":{},\"parent\":{},\
             \"bytes\":{},\"flops\":{}}}}}",
            json_escape(&s.name),
            s.cat,
            s.device,
            stream_tid(s.stream),
            ns_as_us(s.t0_ns),
            ns_as_us(s.t1_ns - s.t0_ns),
            s.trace.0,
            s.span.0,
            s.parent.0,
            s.bytes,
            s.flops
        ));
    }
    out.push_str("\n]}\n");
    out
}

/// Validate a Chrome-trace export: overall JSON shape (balanced
/// braces/brackets outside strings, the `traceEvents` array wrapper)
/// plus per-event schema completeness — every `"ph":"X"` event must
/// carry name/cat/pid/tid/ts/dur/args keys. Returns the number of `X`
/// (span) events on success.
pub fn validate_chrome_json(json: &str) -> Result<usize, String> {
    let body = json.trim();
    if !body.starts_with("{\"traceEvents\":[") || !body.ends_with("]}") {
        return Err("missing {\"traceEvents\":[...]} wrapper".into());
    }
    // Balance check, string-aware.
    let (mut depth_obj, mut depth_arr) = (0i64, 0i64);
    let mut in_str = false;
    let mut esc = false;
    let mut events: Vec<String> = Vec::new();
    let mut cur = String::new();
    for c in body.chars() {
        if in_str {
            cur.push(c);
            if esc {
                esc = false;
            } else if c == '\\' {
                esc = true;
            } else if c == '"' {
                in_str = false;
            }
            continue;
        }
        match c {
            '"' => {
                in_str = true;
                cur.push(c);
            }
            '{' => {
                depth_obj += 1;
                cur.push(c);
            }
            '}' => {
                depth_obj -= 1;
                cur.push(c);
                if depth_obj < 0 {
                    return Err("unbalanced '}'".into());
                }
                // An event object closes at depth 1 (inside the root
                // object's traceEvents array).
                if depth_obj == 1 && depth_arr == 1 {
                    events.push(std::mem::take(&mut cur));
                }
            }
            '[' => {
                depth_arr += 1;
                cur.push(c);
            }
            ']' => {
                depth_arr -= 1;
                if depth_arr < 0 {
                    return Err("unbalanced ']'".into());
                }
            }
            ',' | '\n' | ' ' if depth_obj == 1 && depth_arr == 1 => {
                // Separators between events; start collecting fresh.
                if cur.trim() == "{\"traceEvents\":[" || cur.trim().is_empty() {
                    cur.clear();
                }
            }
            c => cur.push(c),
        }
        if depth_obj == 1 && depth_arr == 1 && cur.trim_start().starts_with("{\"traceEvents\":[") {
            cur = cur.trim_start()["{\"traceEvents\":[".len()..].to_string();
        }
    }
    if in_str {
        return Err("unterminated string".into());
    }
    if depth_obj != 0 || depth_arr != 0 {
        return Err(format!(
            "unbalanced document (obj depth {depth_obj}, arr depth {depth_arr})"
        ));
    }
    let mut x_events = 0usize;
    for (i, ev) in events.iter().enumerate() {
        let ev = ev.trim();
        if !ev.starts_with('{') || !ev.ends_with('}') {
            return Err(format!("event {i} is not an object: {ev:.60}"));
        }
        if ev.contains("\"ph\":\"X\"") {
            for key in [
                "\"name\":", "\"cat\":", "\"pid\":", "\"tid\":", "\"ts\":", "\"dur\":",
                "\"args\":",
            ] {
                if !ev.contains(key) {
                    return Err(format!("X event {i} missing {key}"));
                }
            }
            for arg in ["\"trace\":", "\"span\":", "\"parent\":", "\"bytes\":", "\"flops\":"] {
                if !ev.contains(arg) {
                    return Err(format!("X event {i} args missing {arg}"));
                }
            }
            x_events += 1;
        } else if ev.contains("\"ph\":\"M\"") {
            if !ev.contains("\"thread_name\"") {
                return Err(format!("metadata event {i} is not a thread_name record"));
            }
        } else {
            return Err(format!("event {i} has unknown ph: {ev:.60}"));
        }
    }
    Ok(x_events)
}

/// Render a [`MetricsSnapshot`] (plus per-class latency histograms
/// from [`Metrics::class_histogram`]) in the Prometheus text
/// exposition format. `hists` pairs a class label with its non-empty
/// `(upper_bound_ns, count)` buckets; pass labels in class-index
/// order for a deterministic export.
///
/// [`Metrics::class_histogram`]: crate::metrics::Metrics::class_histogram
pub fn prometheus_text(snap: &MetricsSnapshot, hists: &[(String, Vec<(u64, u64)>)]) -> String {
    let mut out = String::new();
    let mut counter = |name: &str, help: &str, v: u64| {
        out.push_str(&format!(
            "# HELP jaxmg_{name} {help}\n# TYPE jaxmg_{name} counter\njaxmg_{name} {v}\n"
        ));
    };
    counter("peer_bytes_total", "Bytes moved device to device.", snap.peer_bytes);
    counter("peer_copies_total", "Peer-to-peer copy operations.", snap.peer_copies);
    counter("h2d_bytes_total", "Bytes moved host to device.", snap.h2d_bytes);
    counter("d2h_bytes_total", "Bytes moved device to host.", snap.d2h_bytes);
    counter("local_bytes_total", "Bytes copied within one device.", snap.local_bytes);
    counter("kernel_launches_total", "Tile-kernel launches.", snap.kernel_launches);
    counter("flops_total", "Floating-point operations charged.", snap.flops);
    counter("redist_cycles_total", "Redistribution permutation cycles.", snap.redist_cycles);
    counter(
        "service_submitted_total",
        "Solve requests submitted to the SPMD service.",
        snap.service_submitted,
    );
    counter(
        "service_completed_total",
        "Solve requests completed by the SPMD service.",
        snap.service_completed,
    );
    counter(
        "service_queue_wait_ns_total",
        "Cost-model ns spent queued before admission.",
        snap.service_queue_wait_ns,
    );
    counter(
        "service_exec_ns_total",
        "Cost-model ns from admission to completion.",
        snap.service_exec_ns,
    );
    counter(
        "service_preemptions_total",
        "Panel-boundary preemptions of batch solves.",
        snap.service_preemptions,
    );
    counter("batch_buckets_total", "Coalesced small-solve buckets swept.", snap.batch_buckets);
    counter("batch_solves_total", "Small solves served batched.", snap.batch_solves);
    counter("ipc_exports_total", "IPC memory-handle exports.", snap.ipc_exports);
    counter("ipc_opens_total", "IPC memory-handle opens.", snap.ipc_opens);
    counter("ipc_closes_total", "IPC memory-handle closes.", snap.ipc_closes);
    counter("mpmd_routed_total", "Requests routed by the MPMD frontend.", snap.mpmd_routed);
    counter("mpmd_requeues_total", "Failure-driven MPMD requeues.", snap.mpmd_requeues);
    counter("grid_solves_total", "Grid-native (P>1) distributed solves.", snap.grid_solves);
    counter("grid_row_bytes_total", "Bytes carried by row-ring collectives.", snap.grid_row_bytes);
    counter(
        "grid_col_bytes_total",
        "Bytes carried by column-ring collectives.",
        snap.grid_col_bytes,
    );
    counter("cache_hits_total", "Factor-cache hits.", snap.cache_hits);
    counter("cache_misses_total", "Factor-cache misses.", snap.cache_misses);
    counter("cache_evictions_total", "Factor-cache evictions.", snap.cache_evictions);
    counter("dag_fused_stages_total", "Extra stages fused into solve DAGs.", snap.dag_fused_stages);
    counter(
        "fabric_inter_bytes_total",
        "Bytes carried over inter-island fabric links.",
        snap.fabric_inter_bytes,
    );
    counter(
        "fabric_intra_bytes_total",
        "Bytes relayed island-locally by hierarchical collectives.",
        snap.fabric_intra_bytes,
    );
    counter(
        "fabric_bcasts_total",
        "Hierarchical (ring-of-rings) broadcasts issued.",
        snap.fabric_bcasts,
    );
    counter(
        "fabric_bcast_stages_total",
        "Stages executed across hierarchical broadcasts.",
        snap.fabric_bcast_stages,
    );
    counter(
        "mixed_solves_total",
        "Distributed solves completed through the mixed-precision tier.",
        snap.mixed_solves,
    );
    counter(
        "mixed_fallbacks_total",
        "Mixed attempts recovered at full precision.",
        snap.mixed_fallbacks,
    );
    counter(
        "mixed_bytes_saved_total",
        "Modeled bytes the working dtype saved vs full precision.",
        snap.mixed_bytes_saved,
    );

    let mut gauge = |name: &str, help: &str, v: u64| {
        out.push_str(&format!(
            "# HELP jaxmg_{name} {help}\n# TYPE jaxmg_{name} gauge\njaxmg_{name} {v}\n"
        ));
    };
    gauge(
        "cache_resident_bytes",
        "Factor bytes currently resident in device memory.",
        snap.cache_resident_bytes,
    );
    gauge("batch_peak_occupancy", "Largest bucket occupancy seen.", snap.batch_peak_occupancy);
    gauge(
        "mpmd_peak_worker_queue",
        "Deepest worker mailbox observed.",
        snap.mpmd_peak_worker_queue,
    );
    gauge("grid_peak_p", "Largest grid-row count P chosen.", snap.grid_peak_p);
    gauge("grid_peak_q", "Largest grid-column count Q chosen.", snap.grid_peak_q);

    // Per-island admission high-water marks — the labeled series
    // appears only when a fabric actually admitted bytes, so flat
    // nodes never expose phantom islands.
    if snap.fabric_island_peak_bytes.iter().any(|&b| b > 0) {
        out.push_str(
            "# HELP jaxmg_fabric_island_peak_admitted_bytes Peak admitted bytes per island.\n\
             # TYPE jaxmg_fabric_island_peak_admitted_bytes gauge\n",
        );
        for (i, &b) in snap.fabric_island_peak_bytes.iter().enumerate() {
            if b > 0 {
                out.push_str(&format!(
                    "jaxmg_fabric_island_peak_admitted_bytes{{island=\"{i}\"}} {b}\n"
                ));
            }
        }
    }

    // Per-class counters.
    out.push_str(
        "# HELP jaxmg_class_completed_total Completions per SLO class.\n\
         # TYPE jaxmg_class_completed_total counter\n",
    );
    for (i, label) in CLASS_LABELS.iter().enumerate() {
        out.push_str(&format!(
            "jaxmg_class_completed_total{{class=\"{label}\"}} {}\n",
            snap.class_completed[i]
        ));
    }
    out.push_str(
        "# HELP jaxmg_class_deadline_misses_total Deadline misses per SLO class.\n\
         # TYPE jaxmg_class_deadline_misses_total counter\n",
    );
    for (i, label) in CLASS_LABELS.iter().enumerate() {
        out.push_str(&format!(
            "jaxmg_class_deadline_misses_total{{class=\"{label}\"}} {}\n",
            snap.class_deadline_misses[i]
        ));
    }

    // Per-class latency histograms, cumulative le buckets.
    out.push_str(
        "# HELP jaxmg_class_latency_ns End-to-end latency per SLO class, cost-model ns \
         (log-bucket upper bounds; sum is bucket-bound weighted, conservative).\n\
         # TYPE jaxmg_class_latency_ns histogram\n",
    );
    for (label, buckets) in hists {
        let mut cum = 0u64;
        let mut sum = 0u128;
        for &(bound, n) in buckets {
            cum += n;
            sum += bound as u128 * n as u128;
            out.push_str(&format!(
                "jaxmg_class_latency_ns_bucket{{class=\"{label}\",le=\"{bound}\"}} {cum}\n"
            ));
        }
        out.push_str(&format!(
            "jaxmg_class_latency_ns_bucket{{class=\"{label}\",le=\"+Inf\"}} {cum}\n\
             jaxmg_class_latency_ns_sum{{class=\"{label}\"}} {sum}\n\
             jaxmg_class_latency_ns_count{{class=\"{label}\"}} {cum}\n"
        ));
    }

    // Refinement-iteration histogram: correction solves per successful
    // mixed solve. The slot array clamps at 15, so the last slot feeds
    // only the +Inf bucket and its sum contribution is the clamped
    // value (a conservative lower bound).
    out.push_str(
        "# HELP jaxmg_refine_iterations Correction solves per successful mixed solve \
         (last slot clamps at 15+; sum is clamped, conservative).\n\
         # TYPE jaxmg_refine_iterations histogram\n",
    );
    let mut cum = 0u64;
    let mut sum = 0u128;
    for (i, &n) in snap.refine_iters.iter().enumerate() {
        cum += n;
        sum += i as u128 * n as u128;
        if i < snap.refine_iters.len() - 1 {
            out.push_str(&format!("jaxmg_refine_iterations_bucket{{le=\"{i}\"}} {cum}\n"));
        }
    }
    out.push_str(&format!(
        "jaxmg_refine_iterations_bucket{{le=\"+Inf\"}} {cum}\n\
         jaxmg_refine_iterations_sum {sum}\n\
         jaxmg_refine_iterations_count {cum}\n"
    ));
    out
}

/// Render the decision log as JSONL — one object per line, in the
/// deterministic order `Tracer::decisions` returns.
pub fn decisions_jsonl(decisions: &[DecisionRec]) -> String {
    let mut out = String::new();
    for d in decisions {
        out.push_str(&format!(
            "{{\"t_ns\":{},\"trace\":{},\"kind\":\"{}\",\"detail\":\"{}\"}}\n",
            d.t_ns,
            d.trace.0,
            json_escape(d.kind),
            json_escape(&d.detail)
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::{SpanId, TraceId};

    fn span(trace: u64, id: u64, parent: u64, dev: usize, stream: &'static str) -> SpanRec {
        SpanRec {
            trace: TraceId(trace),
            span: SpanId(id),
            parent: SpanId(parent),
            name: format!("s{id}"),
            cat: "compute",
            device: dev,
            stream,
            t0_ns: 1_500,
            t1_ns: 3_750,
            bytes: 64,
            flops: 128,
        }
    }

    #[test]
    fn chrome_trace_is_deterministic_and_valid() {
        let spans = vec![
            span(1, 1, 0, 0, "requests"),
            span(1, 2, 1, 0, "compute"),
            span(1, 3, 1, 1, "copy"),
        ];
        let a = chrome_trace_json(&spans);
        let b = chrome_trace_json(&spans);
        assert_eq!(a, b);
        // Exact microsecond text, not float formatting.
        assert!(a.contains("\"ts\":1.500"), "{a}");
        assert!(a.contains("\"dur\":2.250"), "{a}");
        assert!(a.contains("\"name\":\"dev1/copy\""));
        assert_eq!(validate_chrome_json(&a).unwrap(), 3);
    }

    #[test]
    fn island_trace_groups_pids_and_keeps_flat_output() {
        let spans = vec![
            span(1, 1, 0, 0, "compute"),
            span(1, 2, 1, 0, "fabric"),
            span(1, 3, 1, 2, "copy"),
        ];
        // An empty island map is the flat exporter, byte for byte.
        assert_eq!(chrome_trace_with_islands(&spans, &[]), chrome_trace_json(&spans));
        let t = chrome_trace_with_islands(&spans, &[0, 0, 1, 1]);
        // pid grouping: process_name metadata plus node-prefixed tracks.
        assert!(t.contains("\"name\":\"process_name\""), "{t}");
        assert!(t.contains("\"name\":\"node0.dev0\""), "{t}");
        assert!(t.contains("\"name\":\"node0.dev0/compute\""), "{t}");
        assert!(t.contains("\"name\":\"node1.dev2/copy\""), "{t}");
        // Inter-node hops ride their own track within the pid.
        assert_eq!(stream_tid("fabric"), 4);
        assert!(t.contains("\"name\":\"node0.dev0/fabric\""), "{t}");
        assert_eq!(validate_chrome_json(&t).unwrap(), 3);
    }

    #[test]
    fn validator_rejects_malformed_documents() {
        assert!(validate_chrome_json("not json").is_err());
        assert!(validate_chrome_json("{\"traceEvents\":[").is_err());
        // An X event missing required keys fails schema validation.
        let bad = "{\"traceEvents\":[\n{\"name\":\"x\",\"ph\":\"X\",\"pid\":0}\n]}";
        assert!(validate_chrome_json(bad).is_err());
        // Empty event list is fine (0 spans).
        assert_eq!(validate_chrome_json("{\"traceEvents\":[\n]}").unwrap(), 0);
    }

    #[test]
    fn prometheus_text_renders_counters_gauges_histograms() {
        let mut refine_iters = [0u64; 16];
        refine_iters[0] = 2;
        refine_iters[3] = 1;
        refine_iters[15] = 1;
        let snap = MetricsSnapshot {
            peer_bytes: 42,
            cache_resident_bytes: 1024,
            class_completed: [3, 0, 0],
            mixed_solves: 4,
            mixed_fallbacks: 1,
            mixed_bytes_saved: 9_000,
            refine_iters,
            ..Default::default()
        };
        let hists = vec![
            ("interactive".to_string(), vec![(127u64, 2u64), (8191, 1)]),
            ("standard".to_string(), vec![]),
            ("batch".to_string(), vec![]),
        ];
        let text = prometheus_text(&snap, &hists);
        assert!(text.contains("# TYPE jaxmg_peer_bytes_total counter"));
        assert!(text.contains("jaxmg_peer_bytes_total 42"));
        assert!(text.contains("# TYPE jaxmg_cache_resident_bytes gauge"));
        assert!(text.contains("jaxmg_cache_resident_bytes 1024"));
        assert!(text.contains("jaxmg_class_completed_total{class=\"interactive\"} 3"));
        // Cumulative buckets: le=8191 counts both buckets.
        assert!(text.contains("jaxmg_class_latency_ns_bucket{class=\"interactive\",le=\"127\"} 2"));
        assert!(
            text.contains("jaxmg_class_latency_ns_bucket{class=\"interactive\",le=\"8191\"} 3")
        );
        assert!(
            text.contains("jaxmg_class_latency_ns_bucket{class=\"interactive\",le=\"+Inf\"} 3")
        );
        assert!(text.contains("jaxmg_class_latency_ns_count{class=\"interactive\"} 3"));
        // Empty classes still expose a zero +Inf bucket and count.
        assert!(text.contains("jaxmg_class_latency_ns_bucket{class=\"batch\",le=\"+Inf\"} 0"));
        // Mixed-precision tier counters.
        assert!(text.contains("# TYPE jaxmg_mixed_solves_total counter"));
        assert!(text.contains("jaxmg_mixed_solves_total 4"));
        assert!(text.contains("jaxmg_mixed_fallbacks_total 1"));
        assert!(text.contains("jaxmg_mixed_bytes_saved_total 9000"));
        // Refinement histogram: cumulative buckets, clamped-slot sum.
        assert!(text.contains("# TYPE jaxmg_refine_iterations histogram"));
        assert!(text.contains("jaxmg_refine_iterations_bucket{le=\"0\"} 2"));
        assert!(text.contains("jaxmg_refine_iterations_bucket{le=\"3\"} 3"));
        assert!(text.contains("jaxmg_refine_iterations_bucket{le=\"14\"} 3"));
        assert!(text.contains("jaxmg_refine_iterations_bucket{le=\"+Inf\"} 4"));
        assert!(text.contains("jaxmg_refine_iterations_sum 18"));
        assert!(text.contains("jaxmg_refine_iterations_count 4"));
        // Deterministic.
        assert_eq!(text, prometheus_text(&snap, &hists));
    }

    #[test]
    fn decisions_jsonl_escapes_and_orders() {
        let decisions = vec![
            DecisionRec {
                t_ns: 5,
                trace: TraceId(1),
                kind: "admit",
                detail: "potrf n=64 \"quoted\"\npath".into(),
            },
            DecisionRec { t_ns: 9, trace: TraceId(0), kind: "kill", detail: "worker 2".into() },
        ];
        let text = decisions_jsonl(&decisions);
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].contains("\\\"quoted\\\""));
        assert!(lines[0].contains("\\n"));
        assert!(lines[1].contains("\"kind\":\"kill\""));
        assert!(lines[1].starts_with('{') && lines[1].ends_with('}'));
    }
}
