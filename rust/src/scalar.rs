//! Scalar abstraction over the four dtypes the paper supports:
//! `float32`, `float64`, `complex64`, `complex128`.
//!
//! The vendored crate set has no `num-complex`, so we carry our own
//! minimal [`Complex`] type. The [`Scalar`] trait is what every tile
//! kernel, layout routine and solver is generic over; it also defines
//! how each dtype crosses the Rust ↔ XLA boundary (complex values are
//! **split into real/imag planes**, because the `xla` crate's `Literal`
//! API only exposes real element types — see DESIGN.md §Complex dtypes).

use std::fmt;
use std::iter::Sum;
use std::ops::{Add, AddAssign, Div, DivAssign, Mul, MulAssign, Neg, Sub, SubAssign};

/// Minimal complex number (we cannot use `num-complex`: not vendored).
#[derive(Copy, Clone, PartialEq, Default)]
#[repr(C)]
pub struct Complex<T> {
    /// Real part.
    pub re: T,
    /// Imaginary part.
    pub im: T,
}

/// `complex64` (two f32s), matching JAX's `jnp.complex64`.
#[allow(non_camel_case_types)]
pub type c32 = Complex<f32>;
/// `complex128` (two f64s), matching JAX's `jnp.complex128`.
#[allow(non_camel_case_types)]
pub type c64 = Complex<f64>;

impl<T> Complex<T> {
    /// Construct from real and imaginary parts.
    pub const fn new(re: T, im: T) -> Self {
        Complex { re, im }
    }
}

impl<T: RealScalar> Complex<T> {
    /// The complex conjugate.
    #[inline]
    pub fn conj(self) -> Self {
        Complex::new(self.re, -self.im)
    }

    /// Squared magnitude |z|².
    #[inline]
    pub fn norm_sqr(self) -> T {
        self.re * self.re + self.im * self.im
    }

    /// Magnitude |z|.
    #[inline]
    pub fn abs(self) -> T {
        // Hypot-style scaling for robustness against overflow.
        let (a, b) = (self.re.rabs(), self.im.rabs());
        let (hi, lo) = if a >= b { (a, b) } else { (b, a) };
        if hi == T::rzero() {
            return T::rzero();
        }
        let r = lo / hi;
        hi * (T::rone() + r * r).rsqrt_val()
    }
}

impl<T: fmt::Debug> fmt::Debug for Complex<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "({:?}+{:?}i)", self.re, self.im)
    }
}

impl<T: fmt::Display> fmt::Display for Complex<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "({}+{}i)", self.re, self.im)
    }
}

impl<T: RealScalar> Add for Complex<T> {
    type Output = Self;
    #[inline]
    fn add(self, o: Self) -> Self {
        Complex::new(self.re + o.re, self.im + o.im)
    }
}
impl<T: RealScalar> Sub for Complex<T> {
    type Output = Self;
    #[inline]
    fn sub(self, o: Self) -> Self {
        Complex::new(self.re - o.re, self.im - o.im)
    }
}
impl<T: RealScalar> Mul for Complex<T> {
    type Output = Self;
    #[inline]
    fn mul(self, o: Self) -> Self {
        Complex::new(self.re * o.re - self.im * o.im, self.re * o.im + self.im * o.re)
    }
}
impl<T: RealScalar> Div for Complex<T> {
    type Output = Self;
    #[inline]
    fn div(self, o: Self) -> Self {
        // Smith's algorithm for robust complex division.
        if o.re.rabs() >= o.im.rabs() {
            if o.re == T::rzero() && o.im == T::rzero() {
                return Complex::new(self.re / o.re, self.im / o.re); // NaN propagation
            }
            let r = o.im / o.re;
            let d = o.re + o.im * r;
            Complex::new((self.re + self.im * r) / d, (self.im - self.re * r) / d)
        } else {
            let r = o.re / o.im;
            let d = o.re * r + o.im;
            Complex::new((self.re * r + self.im) / d, (self.im * r - self.re) / d)
        }
    }
}
impl<T: RealScalar> Neg for Complex<T> {
    type Output = Self;
    #[inline]
    fn neg(self) -> Self {
        Complex::new(-self.re, -self.im)
    }
}
impl<T: RealScalar> AddAssign for Complex<T> {
    #[inline]
    fn add_assign(&mut self, o: Self) {
        *self = *self + o;
    }
}
impl<T: RealScalar> SubAssign for Complex<T> {
    #[inline]
    fn sub_assign(&mut self, o: Self) {
        *self = *self - o;
    }
}
impl<T: RealScalar> MulAssign for Complex<T> {
    #[inline]
    fn mul_assign(&mut self, o: Self) {
        *self = *self * o;
    }
}
impl<T: RealScalar> DivAssign for Complex<T> {
    #[inline]
    fn div_assign(&mut self, o: Self) {
        *self = *self / o;
    }
}
impl<T: RealScalar> Sum for Complex<T> {
    fn sum<I: Iterator<Item = Self>>(iter: I) -> Self {
        iter.fold(Complex::new(T::rzero(), T::rzero()), |a, b| a + b)
    }
}

/// Internal helper trait for the real field underlying a scalar.
pub trait RealScalar:
    Copy
    + Clone
    + PartialEq
    + PartialOrd
    + fmt::Debug
    + fmt::Display
    + Default
    + Add<Output = Self>
    + Sub<Output = Self>
    + Mul<Output = Self>
    + Div<Output = Self>
    + Neg<Output = Self>
    + AddAssign
    + SubAssign
    + Send
    + Sync
    + 'static
{
    fn rzero() -> Self;
    fn rone() -> Self;
    fn rabs(self) -> Self;
    fn rsqrt_val(self) -> Self;
    fn from_f64(v: f64) -> Self;
    fn to_f64(self) -> f64;
    /// Machine epsilon.
    fn eps() -> Self;
    fn max_val(self, o: Self) -> Self;
}

impl RealScalar for f32 {
    #[inline]
    fn rzero() -> Self {
        0.0
    }
    #[inline]
    fn rone() -> Self {
        1.0
    }
    #[inline]
    fn rabs(self) -> Self {
        self.abs()
    }
    #[inline]
    fn rsqrt_val(self) -> Self {
        self.sqrt()
    }
    #[inline]
    fn from_f64(v: f64) -> Self {
        v as f32
    }
    #[inline]
    fn to_f64(self) -> f64 {
        self as f64
    }
    #[inline]
    fn eps() -> Self {
        f32::EPSILON
    }
    #[inline]
    fn max_val(self, o: Self) -> Self {
        self.max(o)
    }
}

impl RealScalar for f64 {
    #[inline]
    fn rzero() -> Self {
        0.0
    }
    #[inline]
    fn rone() -> Self {
        1.0
    }
    #[inline]
    fn rabs(self) -> Self {
        self.abs()
    }
    #[inline]
    fn rsqrt_val(self) -> Self {
        self.sqrt()
    }
    #[inline]
    fn from_f64(v: f64) -> Self {
        v
    }
    #[inline]
    fn to_f64(self) -> f64 {
        self
    }
    #[inline]
    fn eps() -> Self {
        f64::EPSILON
    }
    #[inline]
    fn max_val(self, o: Self) -> Self {
        self.max(o)
    }
}

/// The dtype tag carried through layouts, artifacts and the cost model.
#[derive(Copy, Clone, PartialEq, Eq, Hash, Debug)]
pub enum DType {
    F32,
    F64,
    C64,
    C128,
}

impl DType {
    /// JAX-style dtype name; also the artifact filename component.
    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "float32",
            DType::F64 => "float64",
            DType::C64 => "complex64",
            DType::C128 => "complex128",
        }
    }

    /// Bytes per element.
    pub fn size_of(self) -> usize {
        match self {
            DType::F32 => 4,
            DType::F64 => 8,
            DType::C64 => 8,
            DType::C128 => 16,
        }
    }

    /// Whether this dtype is complex (crosses the XLA boundary as split planes).
    pub fn is_complex(self) -> bool {
        matches!(self, DType::C64 | DType::C128)
    }

    /// The real dtype backing this dtype's planes.
    pub fn real_dtype(self) -> DType {
        match self {
            DType::F32 | DType::C64 => DType::F32,
            DType::F64 | DType::C128 => DType::F64,
        }
    }

    /// The working dtype the mixed-precision tier factors in, if this
    /// dtype has one (f64→f32, c128→c64; the narrow dtypes have none).
    pub fn working_dtype(self) -> Option<DType> {
        match self {
            DType::F64 => Some(DType::F32),
            DType::C128 => Some(DType::C64),
            DType::F32 | DType::C64 => None,
        }
    }

    /// Parse a JAX-style dtype name.
    pub fn parse(s: &str) -> Option<DType> {
        match s {
            "float32" | "f32" => Some(DType::F32),
            "float64" | "f64" => Some(DType::F64),
            "complex64" | "c64" => Some(DType::C64),
            "complex128" | "c128" => Some(DType::C128),
            _ => None,
        }
    }
}

impl fmt::Display for DType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// The scalar trait every layout / solver / kernel is generic over.
///
/// `Real` is the underlying real field (`f32` or `f64`); complex scalars
/// expose conjugation that actually flips the imaginary sign, real
/// scalars implement it as the identity, so one generic Hermitian
/// algorithm covers the symmetric case too (exactly how LAPACK's
/// `zhetrd`/`dsytrd` pairs relate).
pub trait Scalar:
    Copy
    + Clone
    + PartialEq
    + fmt::Debug
    + fmt::Display
    + Default
    + Add<Output = Self>
    + Sub<Output = Self>
    + Mul<Output = Self>
    + Div<Output = Self>
    + Neg<Output = Self>
    + AddAssign
    + SubAssign
    + MulAssign
    + Sum
    + Send
    + Sync
    + 'static
{
    /// Underlying real field.
    type Real: RealScalar;

    /// Static dtype tag.
    const DTYPE: DType;

    fn zero() -> Self;
    fn one() -> Self;
    /// Complex conjugate (identity for real scalars).
    fn conj(self) -> Self;
    /// Real part.
    fn re(self) -> Self::Real;
    /// Imaginary part (zero for real scalars).
    fn im(self) -> Self::Real;
    /// |x| as the real field.
    fn abs(self) -> Self::Real;
    /// |x|² as the real field (cheaper than `abs` for complex).
    fn abs_sqr(self) -> Self::Real;
    /// Lift a real value.
    fn from_real(r: Self::Real) -> Self;
    /// Lift from f64 (real part only).
    fn from_f64(v: f64) -> Self;
    /// Construct from real/imag planes (imag ignored for real types).
    fn from_parts(re: Self::Real, im: Self::Real) -> Self;
    /// Real square root of a (assumed real non-negative) scalar —
    /// used on Cholesky pivots.
    fn sqrt_real(self) -> Self;
    /// 1/x.
    fn recip(self) -> Self {
        Self::one() / self
    }

    /// Number of `Real` words per element when crossing the XLA boundary
    /// (1 for real dtypes, 2 for complex split planes).
    const PLANES: usize;

    /// Scatter `src` into `PLANES` real planes (plane-major: all re then all im).
    fn split_planes(src: &[Self], planes: &mut [Self::Real]);
    /// Gather from `PLANES` real planes back into scalars.
    fn merge_planes(planes: &[Self::Real], dst: &mut [Self]);
}

impl Scalar for f32 {
    type Real = f32;
    const DTYPE: DType = DType::F32;
    const PLANES: usize = 1;

    #[inline]
    fn zero() -> Self {
        0.0
    }
    #[inline]
    fn one() -> Self {
        1.0
    }
    #[inline]
    fn conj(self) -> Self {
        self
    }
    #[inline]
    fn re(self) -> f32 {
        self
    }
    #[inline]
    fn im(self) -> f32 {
        0.0
    }
    #[inline]
    fn abs(self) -> f32 {
        f32::abs(self)
    }
    #[inline]
    fn abs_sqr(self) -> f32 {
        self * self
    }
    #[inline]
    fn from_real(r: f32) -> Self {
        r
    }
    #[inline]
    fn from_f64(v: f64) -> Self {
        v as f32
    }
    #[inline]
    fn from_parts(re: f32, _im: f32) -> Self {
        re
    }
    #[inline]
    fn sqrt_real(self) -> Self {
        self.sqrt()
    }

    fn split_planes(src: &[Self], planes: &mut [f32]) {
        planes.copy_from_slice(src);
    }
    fn merge_planes(planes: &[f32], dst: &mut [Self]) {
        dst.copy_from_slice(planes);
    }
}

impl Scalar for f64 {
    type Real = f64;
    const DTYPE: DType = DType::F64;
    const PLANES: usize = 1;

    #[inline]
    fn zero() -> Self {
        0.0
    }
    #[inline]
    fn one() -> Self {
        1.0
    }
    #[inline]
    fn conj(self) -> Self {
        self
    }
    #[inline]
    fn re(self) -> f64 {
        self
    }
    #[inline]
    fn im(self) -> f64 {
        0.0
    }
    #[inline]
    fn abs(self) -> f64 {
        f64::abs(self)
    }
    #[inline]
    fn abs_sqr(self) -> f64 {
        self * self
    }
    #[inline]
    fn from_real(r: f64) -> Self {
        r
    }
    #[inline]
    fn from_f64(v: f64) -> Self {
        v
    }
    #[inline]
    fn from_parts(re: f64, _im: f64) -> Self {
        re
    }
    #[inline]
    fn sqrt_real(self) -> Self {
        self.sqrt()
    }

    fn split_planes(src: &[Self], planes: &mut [f64]) {
        planes.copy_from_slice(src);
    }
    fn merge_planes(planes: &[f64], dst: &mut [Self]) {
        dst.copy_from_slice(planes);
    }
}

macro_rules! impl_scalar_complex {
    ($real:ty, $dtype:expr) => {
        impl Scalar for Complex<$real> {
            type Real = $real;
            const DTYPE: DType = $dtype;
            const PLANES: usize = 2;

            #[inline]
            fn zero() -> Self {
                Complex::new(0.0, 0.0)
            }
            #[inline]
            fn one() -> Self {
                Complex::new(1.0, 0.0)
            }
            #[inline]
            fn conj(self) -> Self {
                Complex::conj(self)
            }
            #[inline]
            fn re(self) -> $real {
                self.re
            }
            #[inline]
            fn im(self) -> $real {
                self.im
            }
            #[inline]
            fn abs(self) -> $real {
                Complex::abs(self)
            }
            #[inline]
            fn abs_sqr(self) -> $real {
                Complex::norm_sqr(self)
            }
            #[inline]
            fn from_real(r: $real) -> Self {
                Complex::new(r, 0.0)
            }
            #[inline]
            fn from_f64(v: f64) -> Self {
                Complex::new(v as $real, 0.0)
            }
            #[inline]
            fn from_parts(re: $real, im: $real) -> Self {
                Complex::new(re, im)
            }
            #[inline]
            fn sqrt_real(self) -> Self {
                // Used on Cholesky pivots which must be real positive;
                // take the real square root of the real part.
                Complex::new(self.re.sqrt(), 0.0)
            }

            fn split_planes(src: &[Self], planes: &mut [$real]) {
                let n = src.len();
                assert_eq!(planes.len(), 2 * n, "plane buffer must hold 2n reals");
                let (re, im) = planes.split_at_mut(n);
                for (i, z) in src.iter().enumerate() {
                    re[i] = z.re;
                    im[i] = z.im;
                }
            }
            fn merge_planes(planes: &[$real], dst: &mut [Self]) {
                let n = dst.len();
                assert_eq!(planes.len(), 2 * n, "plane buffer must hold 2n reals");
                let (re, im) = planes.split_at(n);
                for i in 0..n {
                    dst[i] = Complex::new(re[i], im[i]);
                }
            }
        }
    };
}

impl_scalar_complex!(f32, DType::C64);
impl_scalar_complex!(f64, DType::C128);

/// Demotion to the narrower working dtype used by the mixed-precision
/// tier: `f64 → f32` and `c128 → c64` (elementwise plane rounding).
///
/// Conversion is the deterministic IEEE round-to-nearest-even cast; any
/// value already representable in the working dtype round-trips
/// **bitwise** through [`Promote::promote`]. The narrow dtypes do not
/// implement this trait, which is what makes the mixed tier statically
/// ineligible for f32/c64 requests.
pub trait Demote: Scalar {
    /// The working (narrow) scalar.
    type Lo: Scalar<Real = f32> + Promote<Hi = Self>;

    /// Elementwise narrowing cast.
    fn demote(self) -> Self::Lo;
}

/// Promotion from a working dtype back to its full-precision parent
/// (`f32 → f64`, `c64 → c128`). Always exact.
pub trait Promote: Scalar {
    /// The full-precision (wide) scalar.
    type Hi: Scalar<Real = f64> + Demote<Lo = Self>;

    /// Elementwise exact widening cast.
    fn promote(self) -> Self::Hi;
}

impl Demote for f64 {
    type Lo = f32;
    #[inline]
    fn demote(self) -> f32 {
        self as f32
    }
}

impl Promote for f32 {
    type Hi = f64;
    #[inline]
    fn promote(self) -> f64 {
        self as f64
    }
}

impl Demote for c64 {
    type Lo = c32;
    #[inline]
    fn demote(self) -> c32 {
        Complex::new(self.re as f32, self.im as f32)
    }
}

impl Promote for c32 {
    type Hi = c64;
    #[inline]
    fn promote(self) -> c64 {
        Complex::new(self.re as f64, self.im as f64)
    }
}

/// Demote a shard into a freshly allocated working-dtype buffer.
pub fn demote_slice<S: Demote>(src: &[S]) -> Vec<S::Lo> {
    src.iter().map(|&v| v.demote()).collect()
}

/// Demote a shard into an existing working-dtype buffer (lengths must match).
pub fn demote_into<S: Demote>(src: &[S], dst: &mut [S::Lo]) {
    assert_eq!(src.len(), dst.len(), "demote_into: length mismatch");
    for (d, &s) in dst.iter_mut().zip(src.iter()) {
        *d = s.demote();
    }
}

/// Promote a working-dtype shard back to full precision (always exact).
pub fn promote_slice<L: Promote>(src: &[L]) -> Vec<L::Hi> {
    src.iter().map(|&v| v.promote()).collect()
}

/// Promote a shard into an existing full-precision buffer (lengths must match).
pub fn promote_into<L: Promote>(src: &[L], dst: &mut [L::Hi]) {
    assert_eq!(src.len(), dst.len(), "promote_into: length mismatch");
    for (d, &s) in dst.iter_mut().zip(src.iter()) {
        *d = s.promote();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn complex_field_ops() {
        let a = c64::new(1.0, 2.0);
        let b = c64::new(3.0, -1.0);
        assert_eq!(a + b, c64::new(4.0, 1.0));
        assert_eq!(a - b, c64::new(-2.0, 3.0));
        // (1+2i)(3-i) = 3 - i + 6i - 2i² = 5 + 5i
        assert_eq!(a * b, c64::new(5.0, 5.0));
        let q = (a * b) / b;
        assert!((q.re - a.re).abs() < 1e-12 && (q.im - a.im).abs() < 1e-12);
    }

    #[test]
    fn complex_division_robust() {
        // Denominator with tiny real part exercises both Smith branches.
        let a = c64::new(1.0, 1.0);
        let b = c64::new(1e-300, 1.0);
        let q = a / b;
        let back = q * b;
        assert!((back.re - 1.0).abs() < 1e-10);
        assert!((back.im - 1.0).abs() < 1e-10);
    }

    #[test]
    fn conj_and_abs() {
        let z = c32::new(3.0, 4.0);
        assert_eq!(z.conj(), c32::new(3.0, -4.0));
        assert!((Scalar::abs(z) - 5.0).abs() < 1e-6);
        assert_eq!(z.abs_sqr(), 25.0);
        // Real conj is identity.
        assert_eq!(2.5f64.conj(), 2.5);
    }

    #[test]
    fn abs_avoids_overflow() {
        let z = c64::new(1e200, 1e200);
        let a = Scalar::abs(z);
        assert!(a.is_finite());
        assert!((a / 1e200 - std::f64::consts::SQRT_2).abs() < 1e-12);
    }

    #[test]
    fn dtype_metadata() {
        assert_eq!(<f32 as Scalar>::DTYPE.name(), "float32");
        assert_eq!(<c64 as Scalar>::DTYPE.name(), "complex128");
        assert_eq!(DType::C128.size_of(), 16);
        assert_eq!(DType::C64.real_dtype(), DType::F32);
        assert!(!DType::F64.is_complex());
        assert_eq!(DType::parse("complex64"), Some(DType::C64));
        assert_eq!(DType::parse("nope"), None);
    }

    #[test]
    fn split_merge_roundtrip_real() {
        let src = vec![1.0f32, 2.0, 3.0];
        let mut planes = vec![0.0f32; 3];
        f32::split_planes(&src, &mut planes);
        let mut back = vec![0.0f32; 3];
        f32::merge_planes(&planes, &mut back);
        assert_eq!(src, back);
    }

    #[test]
    fn split_merge_roundtrip_complex() {
        let src = vec![c64::new(1.0, -1.0), c64::new(2.0, -2.0)];
        let mut planes = vec![0.0f64; 4];
        c64::split_planes(&src, &mut planes);
        assert_eq!(planes, vec![1.0, 2.0, -1.0, -2.0]); // plane-major
        let mut back = vec![c64::zero(); 2];
        c64::merge_planes(&planes, &mut back);
        assert_eq!(src, back);
    }

    #[test]
    fn sqrt_real_on_pivot() {
        let p = c64::new(4.0, 0.0);
        assert_eq!(p.sqrt_real(), c64::new(2.0, 0.0));
        assert_eq!(9.0f64.sqrt_real(), 3.0);
    }

    /// Deterministic pseudo-random f32 stream (splitmix-style) so the
    /// round-trip property runs over a spread of exponents/signs.
    fn prop_f32s(n: usize, mut state: u64) -> Vec<f32> {
        (0..n)
            .map(|_| {
                state = state.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(1);
                let z = (state >> 33) as u32;
                // Map to a finite float in a wide range, including negatives.
                let v = (z as f64 / u32::MAX as f64 - 0.5) * 2.0;
                (v * 1e12f64.powf(v)) as f32
            })
            .collect()
    }

    #[test]
    fn demote_promote_roundtrip_f32_representable() {
        // Values that originate in f32 survive f64 → f32 → f64 bitwise.
        let lo = prop_f32s(512, 0xD15C0);
        let hi: Vec<f64> = promote_slice(&lo);
        let back = demote_slice(&hi);
        for (a, b) in lo.iter().zip(back.iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "f32 round-trip must be bitwise");
        }
        // And the promoted values re-promote identically (promotion exact).
        let hi2: Vec<f64> = promote_slice(&back);
        for (a, b) in hi.iter().zip(hi2.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn demote_promote_roundtrip_complex() {
        let re = prop_f32s(256, 0xABCD);
        let im = prop_f32s(256, 0x1234);
        let lo: Vec<c32> = re
            .iter()
            .zip(im.iter())
            .map(|(&r, &i)| c32::new(r, i))
            .collect();
        let hi: Vec<c64> = promote_slice(&lo);
        let back: Vec<c32> = demote_slice(&hi);
        for (a, b) in lo.iter().zip(back.iter()) {
            assert_eq!(a.re.to_bits(), b.re.to_bits());
            assert_eq!(a.im.to_bits(), b.im.to_bits());
        }
    }

    #[test]
    fn demote_is_deterministic_elementwise() {
        // Slice conversion must equal per-element conversion, in order.
        let hi: Vec<f64> = (0..257).map(|i| (i as f64) * 0.1 + 1.0 / 3.0).collect();
        let a = demote_slice(&hi);
        let b: Vec<f32> = hi.iter().map(|&v| v.demote()).collect();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        // Repeated runs are identical (pure function of input).
        let again = demote_slice(&hi);
        for (x, y) in a.iter().zip(again.iter()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        // In-place variants agree with the allocating ones.
        let mut dst = vec![0.0f32; hi.len()];
        demote_into(&hi, &mut dst);
        assert_eq!(dst, a);
        let mut up = vec![0.0f64; hi.len()];
        promote_into(&a, &mut up);
        assert_eq!(up, promote_slice(&a));
    }

    #[test]
    fn demote_rounds_to_nearest() {
        // 1 + 2^-40 is not representable in f32; rounds to 1.0 exactly.
        let v: f64 = 1.0 + 2.0f64.powi(-40);
        assert_eq!(v.demote(), 1.0f32);
        // Overflow saturates to infinity deterministically.
        assert_eq!(1e60f64.demote(), f32::INFINITY);
        assert_eq!((-1e60f64).demote(), f32::NEG_INFINITY);
    }

    #[test]
    fn working_dtype_mapping() {
        assert_eq!(DType::F64.working_dtype(), Some(DType::F32));
        assert_eq!(DType::C128.working_dtype(), Some(DType::C64));
        assert_eq!(DType::F32.working_dtype(), None);
        assert_eq!(DType::C64.working_dtype(), None);
    }
}
