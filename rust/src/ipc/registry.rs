//! MPMD pointer transport: the `cudaIpc` analogue of Figure 2 (right).
//!
//! In MPMD mode every device is driven by its own *process*; raw device
//! pointers are meaningless across process boundaries. CUDA's answer is
//! `cudaIpcGetMemHandle` / `cudaIpcOpenMemHandle`: export an allocation
//! as an opaque handle, ship the handle over any transport (the paper
//! funnels them to process 0), and re-open it in the consuming process.
//!
//! [`IpcRegistry`] reproduces the lifecycle **and its failure modes**:
//!
//! * a handle cannot be opened in the process that exported it
//!   (CUDA returns `cudaErrorDeviceUninitialized`/invalid context);
//! * a handle opened twice in one process is an error;
//! * a closed (revoked) handle cannot be opened;
//! * handles are unguessable opaque tokens, like the 64-byte
//!   `cudaIpcMemHandle_t` blob.

use crate::device::{DevPtr, SimNode};
use crate::error::{Error, Result};
use std::collections::{HashMap, HashSet};
use std::sync::Mutex;

/// A simulated process (virtual address space) identifier.
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct AddressSpace(pub usize);

/// Opaque transportable handle to an exported device allocation.
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
pub struct IpcHandle {
    token: u64,
}

#[derive(Debug)]
struct ExportEntry {
    ptr: DevPtr,
    exporter: AddressSpace,
    opened_in: HashSet<AddressSpace>,
    revoked: bool,
    /// Node the allocation lives on, for **bound** exports
    /// ([`IpcRegistry::export_bound`]): `open` checks the allocation is
    /// still live, so a handle whose backing memory was freed behaves
    /// as revoked instead of yielding a dangling pointer.
    node: Option<SimNode>,
}

/// Node-wide registry of exported allocations.
#[derive(Debug, Default)]
pub struct IpcRegistry {
    inner: Mutex<RegistryInner>,
}

#[derive(Debug, Default)]
struct RegistryInner {
    next_token: u64,
    exports: HashMap<u64, ExportEntry>,
}

impl IpcRegistry {
    /// Empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// `cudaIpcGetMemHandle`: export `ptr` from `exporter`'s space.
    /// Only base pointers (offset 0) are exportable, as in CUDA.
    pub fn export(&self, exporter: AddressSpace, ptr: DevPtr) -> Result<IpcHandle> {
        self.export_inner(exporter, ptr, None)
    }

    /// [`IpcRegistry::export`] **bound to the allocation's node**: every
    /// subsequent `open` verifies the backing allocation is still live,
    /// so freeing the memory implicitly revokes the handle (the
    /// lifecycle CUDA enforces — `cudaIpcOpenMemHandle` on a freed
    /// export fails rather than mapping dead memory). The MPMD serve
    /// workers export through this path.
    pub fn export_bound(
        &self,
        exporter: AddressSpace,
        node: &SimNode,
        ptr: DevPtr,
    ) -> Result<IpcHandle> {
        if !node.ptr_exists(ptr) {
            return Err(Error::ipc("cannot export a freed allocation"));
        }
        self.export_inner(exporter, ptr, Some(node.clone()))
    }

    fn export_inner(
        &self,
        exporter: AddressSpace,
        ptr: DevPtr,
        node: Option<SimNode>,
    ) -> Result<IpcHandle> {
        if ptr.offset != 0 {
            return Err(Error::ipc("only base allocation pointers can be exported"));
        }
        let mut inner = self.inner.lock().unwrap();
        // Token stream is deliberately non-sequential (splitmix) so tests
        // can't accidentally forge handles from small integers.
        inner.next_token += 1;
        let mut z = inner.next_token.wrapping_mul(0x9E3779B97F4A7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        let token = z ^ (z >> 31);
        inner.exports.insert(
            token,
            ExportEntry { ptr, exporter, opened_in: HashSet::new(), revoked: false, node },
        );
        Ok(IpcHandle { token })
    }

    /// `cudaIpcOpenMemHandle`: map an exported allocation into
    /// `opener`'s space, yielding a pointer usable there.
    pub fn open(&self, opener: AddressSpace, handle: IpcHandle) -> Result<DevPtr> {
        let mut inner = self.inner.lock().unwrap();
        let entry = inner
            .exports
            .get_mut(&handle.token)
            .ok_or_else(|| Error::ipc(format!("unknown ipc handle {:#x}", handle.token)))?;
        if entry.revoked {
            return Err(Error::ipc("handle has been closed by the exporter"));
        }
        // Bound exports: freeing the allocation implicitly revokes every
        // handle over it — a stale handle must not map dead memory.
        let stale = entry.node.as_ref().is_some_and(|n| !n.ptr_exists(entry.ptr));
        if stale {
            entry.revoked = true;
            return Err(Error::ipc("stale ipc handle: the exported allocation was freed"));
        }
        if entry.exporter == opener {
            return Err(Error::ipc(
                "cudaIpcOpenMemHandle cannot be called in the exporting process",
            ));
        }
        if !entry.opened_in.insert(opener) {
            return Err(Error::ipc(format!("handle already open in process {}", opener.0)));
        }
        Ok(entry.ptr)
    }

    /// `cudaIpcCloseMemHandle` from the consumer side: release the
    /// mapping in `opener`'s space.
    pub fn close(&self, opener: AddressSpace, handle: IpcHandle) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        let entry = inner
            .exports
            .get_mut(&handle.token)
            .ok_or_else(|| Error::ipc(format!("unknown ipc handle {:#x}", handle.token)))?;
        if !entry.opened_in.remove(&opener) {
            return Err(Error::ipc(format!("handle not open in process {}", opener.0)));
        }
        Ok(())
    }

    /// Exporter revokes the handle (e.g. frees the allocation). Any
    /// subsequent `open` fails.
    pub fn revoke(&self, exporter: AddressSpace, handle: IpcHandle) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        let entry = inner
            .exports
            .get_mut(&handle.token)
            .ok_or_else(|| Error::ipc(format!("unknown ipc handle {:#x}", handle.token)))?;
        if entry.exporter != exporter {
            return Err(Error::ipc("only the exporting process may revoke a handle"));
        }
        entry.revoked = true;
        Ok(())
    }

    /// Revoke **every** live handle `exporter` holds over `ptr` — the
    /// free-path hook: a worker deallocating an exported shard calls
    /// this first, so no stale handle survives the free. Returns how
    /// many handles were revoked.
    pub fn revoke_all_for(&self, exporter: AddressSpace, ptr: DevPtr) -> usize {
        let mut inner = self.inner.lock().unwrap();
        let mut n = 0;
        for entry in inner.exports.values_mut() {
            if entry.exporter == exporter
                && entry.ptr.device == ptr.device
                && entry.ptr.alloc_id == ptr.alloc_id
                && !entry.revoked
            {
                entry.revoked = true;
                n += 1;
            }
        }
        n
    }

    /// How many spaces currently have `handle` open (diagnostics).
    pub fn open_count(&self, handle: IpcHandle) -> usize {
        self.inner
            .lock()
            .unwrap()
            .exports
            .get(&handle.token)
            .map(|e| e.opened_in.len())
            .unwrap_or(0)
    }

    /// Per-process open accounting: how many handles `space` currently
    /// has mapped (the `cudaIpcOpenMemHandle` minus `Close` balance a
    /// leak checker watches per process).
    pub fn open_count_in(&self, space: AddressSpace) -> usize {
        self.inner
            .lock()
            .unwrap()
            .exports
            .values()
            .filter(|e| e.opened_in.contains(&space))
            .count()
    }

    /// Per-process export accounting: how many live (un-revoked)
    /// exports `space` currently owns.
    pub fn exports_by(&self, space: AddressSpace) -> usize {
        self.inner
            .lock()
            .unwrap()
            .exports
            .values()
            .filter(|e| e.exporter == space && !e.revoked)
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ptr(device: usize, id: u64) -> DevPtr {
        DevPtr { device, alloc_id: id, offset: 0 }
    }

    #[test]
    fn export_open_roundtrip() {
        let reg = IpcRegistry::new();
        let h = reg.export(AddressSpace(1), ptr(1, 42)).unwrap();
        let p = reg.open(AddressSpace(0), h).unwrap();
        assert_eq!(p.alloc_id, 42);
        assert_eq!(p.device, 1);
        assert_eq!(reg.open_count(h), 1);
    }

    #[test]
    fn open_in_exporting_process_rejected() {
        let reg = IpcRegistry::new();
        let h = reg.export(AddressSpace(2), ptr(2, 1)).unwrap();
        let err = reg.open(AddressSpace(2), h).unwrap_err();
        assert!(format!("{err}").contains("exporting process"));
    }

    #[test]
    fn double_open_same_space_rejected() {
        let reg = IpcRegistry::new();
        let h = reg.export(AddressSpace(1), ptr(1, 1)).unwrap();
        reg.open(AddressSpace(0), h).unwrap();
        assert!(reg.open(AddressSpace(0), h).is_err());
        // A third space may still open it.
        reg.open(AddressSpace(3), h).unwrap();
        assert_eq!(reg.open_count(h), 2);
    }

    #[test]
    fn revoked_handle_unopenable() {
        let reg = IpcRegistry::new();
        let h = reg.export(AddressSpace(1), ptr(1, 1)).unwrap();
        reg.revoke(AddressSpace(1), h).unwrap();
        assert!(reg.open(AddressSpace(0), h).is_err());
    }

    #[test]
    fn only_exporter_can_revoke() {
        let reg = IpcRegistry::new();
        let h = reg.export(AddressSpace(1), ptr(1, 1)).unwrap();
        assert!(reg.revoke(AddressSpace(0), h).is_err());
    }

    #[test]
    fn close_releases_mapping() {
        let reg = IpcRegistry::new();
        let h = reg.export(AddressSpace(1), ptr(1, 1)).unwrap();
        reg.open(AddressSpace(0), h).unwrap();
        reg.close(AddressSpace(0), h).unwrap();
        assert_eq!(reg.open_count(h), 0);
        // Re-open after close is allowed (fresh mapping).
        reg.open(AddressSpace(0), h).unwrap();
    }

    #[test]
    fn offset_pointer_not_exportable() {
        let reg = IpcRegistry::new();
        let p = DevPtr { device: 0, alloc_id: 5, offset: 16 };
        assert!(reg.export(AddressSpace(0), p).is_err());
    }

    #[test]
    fn freed_allocation_implicitly_revokes_bound_handle() {
        // The hardening bugfix: an exported allocation that is freed
        // must not be openable through a stale handle.
        let node = SimNode::new_uniform(2, 1 << 16);
        let reg = IpcRegistry::new();
        let p = node.alloc(1, 128).unwrap();
        let h = reg.export_bound(AddressSpace(1), &node, p).unwrap();
        // Live: opens fine.
        assert_eq!(reg.open(AddressSpace(0), h).unwrap(), p);
        reg.close(AddressSpace(0), h).unwrap();
        // Freed: the open fails with a typed ipc error and the handle
        // is permanently revoked.
        node.free(p).unwrap();
        let err = reg.open(AddressSpace(0), h).unwrap_err();
        assert!(matches!(err, Error::Ipc(_)), "{err}");
        assert!(format!("{err}").contains("stale"), "{err}");
        // Even if the alloc id is recycled later, the handle stays dead.
        let err2 = reg.open(AddressSpace(0), h).unwrap_err();
        assert!(format!("{err2}").contains("closed") || format!("{err2}").contains("stale"));
    }

    #[test]
    fn export_bound_rejects_freed_ptr() {
        let node = SimNode::new_uniform(1, 1 << 10);
        let reg = IpcRegistry::new();
        let p = node.alloc(0, 64).unwrap();
        node.free(p).unwrap();
        assert!(reg.export_bound(AddressSpace(0), &node, p).is_err());
    }

    #[test]
    fn revoke_all_for_kills_every_handle_over_a_ptr() {
        let reg = IpcRegistry::new();
        let p = ptr(1, 9);
        let h1 = reg.export(AddressSpace(1), p).unwrap();
        let h2 = reg.export(AddressSpace(1), p).unwrap();
        let other = reg.export(AddressSpace(1), ptr(1, 10)).unwrap();
        // A different exporter's handle over the "same" ptr is not ours.
        let foreign = reg.export(AddressSpace(2), p).unwrap();
        assert_eq!(reg.revoke_all_for(AddressSpace(1), p), 2);
        assert!(reg.open(AddressSpace(0), h1).is_err());
        assert!(reg.open(AddressSpace(0), h2).is_err());
        reg.open(AddressSpace(0), other).unwrap();
        reg.open(AddressSpace(0), foreign).unwrap();
        // Idempotent: nothing left to revoke.
        assert_eq!(reg.revoke_all_for(AddressSpace(1), p), 0);
    }

    #[test]
    fn per_process_accounting() {
        let reg = IpcRegistry::new();
        let h1 = reg.export(AddressSpace(1), ptr(1, 1)).unwrap();
        let h2 = reg.export(AddressSpace(2), ptr(2, 1)).unwrap();
        assert_eq!(reg.exports_by(AddressSpace(1)), 1);
        reg.open(AddressSpace(0), h1).unwrap();
        reg.open(AddressSpace(0), h2).unwrap();
        reg.open(AddressSpace(3), h1).unwrap();
        assert_eq!(reg.open_count_in(AddressSpace(0)), 2);
        assert_eq!(reg.open_count_in(AddressSpace(3)), 1);
        reg.close(AddressSpace(0), h1).unwrap();
        assert_eq!(reg.open_count_in(AddressSpace(0)), 1);
        reg.revoke(AddressSpace(1), h1).unwrap();
        assert_eq!(reg.exports_by(AddressSpace(1)), 0);
        assert_eq!(reg.exports_by(AddressSpace(2)), 1);
    }

    #[test]
    fn forged_handle_rejected() {
        let reg = IpcRegistry::new();
        let _h = reg.export(AddressSpace(1), ptr(1, 1)).unwrap();
        assert!(reg.open(AddressSpace(0), IpcHandle { token: 1 }).is_err());
    }
}
