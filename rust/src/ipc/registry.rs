//! MPMD pointer transport: the `cudaIpc` analogue of Figure 2 (right).
//!
//! In MPMD mode every device is driven by its own *process*; raw device
//! pointers are meaningless across process boundaries. CUDA's answer is
//! `cudaIpcGetMemHandle` / `cudaIpcOpenMemHandle`: export an allocation
//! as an opaque handle, ship the handle over any transport (the paper
//! funnels them to process 0), and re-open it in the consuming process.
//!
//! [`IpcRegistry`] reproduces the lifecycle **and its failure modes**:
//!
//! * a handle cannot be opened in the process that exported it
//!   (CUDA returns `cudaErrorDeviceUninitialized`/invalid context);
//! * a handle opened twice in one process is an error;
//! * a closed (revoked) handle cannot be opened;
//! * handles are unguessable opaque tokens, like the 64-byte
//!   `cudaIpcMemHandle_t` blob.

use crate::device::DevPtr;
use crate::error::{Error, Result};
use std::collections::{HashMap, HashSet};
use std::sync::Mutex;

/// A simulated process (virtual address space) identifier.
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct AddressSpace(pub usize);

/// Opaque transportable handle to an exported device allocation.
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
pub struct IpcHandle {
    token: u64,
}

#[derive(Debug)]
struct ExportEntry {
    ptr: DevPtr,
    exporter: AddressSpace,
    opened_in: HashSet<AddressSpace>,
    revoked: bool,
}

/// Node-wide registry of exported allocations.
#[derive(Debug, Default)]
pub struct IpcRegistry {
    inner: Mutex<RegistryInner>,
}

#[derive(Debug, Default)]
struct RegistryInner {
    next_token: u64,
    exports: HashMap<u64, ExportEntry>,
}

impl IpcRegistry {
    /// Empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// `cudaIpcGetMemHandle`: export `ptr` from `exporter`'s space.
    /// Only base pointers (offset 0) are exportable, as in CUDA.
    pub fn export(&self, exporter: AddressSpace, ptr: DevPtr) -> Result<IpcHandle> {
        if ptr.offset != 0 {
            return Err(Error::ipc("only base allocation pointers can be exported"));
        }
        let mut inner = self.inner.lock().unwrap();
        // Token stream is deliberately non-sequential (splitmix) so tests
        // can't accidentally forge handles from small integers.
        inner.next_token += 1;
        let mut z = inner.next_token.wrapping_mul(0x9E3779B97F4A7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        let token = z ^ (z >> 31);
        inner.exports.insert(
            token,
            ExportEntry { ptr, exporter, opened_in: HashSet::new(), revoked: false },
        );
        Ok(IpcHandle { token })
    }

    /// `cudaIpcOpenMemHandle`: map an exported allocation into
    /// `opener`'s space, yielding a pointer usable there.
    pub fn open(&self, opener: AddressSpace, handle: IpcHandle) -> Result<DevPtr> {
        let mut inner = self.inner.lock().unwrap();
        let entry = inner
            .exports
            .get_mut(&handle.token)
            .ok_or_else(|| Error::ipc(format!("unknown ipc handle {:#x}", handle.token)))?;
        if entry.revoked {
            return Err(Error::ipc("handle has been closed by the exporter"));
        }
        if entry.exporter == opener {
            return Err(Error::ipc(
                "cudaIpcOpenMemHandle cannot be called in the exporting process",
            ));
        }
        if !entry.opened_in.insert(opener) {
            return Err(Error::ipc(format!("handle already open in process {}", opener.0)));
        }
        Ok(entry.ptr)
    }

    /// `cudaIpcCloseMemHandle` from the consumer side: release the
    /// mapping in `opener`'s space.
    pub fn close(&self, opener: AddressSpace, handle: IpcHandle) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        let entry = inner
            .exports
            .get_mut(&handle.token)
            .ok_or_else(|| Error::ipc(format!("unknown ipc handle {:#x}", handle.token)))?;
        if !entry.opened_in.remove(&opener) {
            return Err(Error::ipc(format!("handle not open in process {}", opener.0)));
        }
        Ok(())
    }

    /// Exporter revokes the handle (e.g. frees the allocation). Any
    /// subsequent `open` fails.
    pub fn revoke(&self, exporter: AddressSpace, handle: IpcHandle) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        let entry = inner
            .exports
            .get_mut(&handle.token)
            .ok_or_else(|| Error::ipc(format!("unknown ipc handle {:#x}", handle.token)))?;
        if entry.exporter != exporter {
            return Err(Error::ipc("only the exporting process may revoke a handle"));
        }
        entry.revoked = true;
        Ok(())
    }

    /// How many spaces currently have `handle` open (diagnostics).
    pub fn open_count(&self, handle: IpcHandle) -> usize {
        self.inner
            .lock()
            .unwrap()
            .exports
            .get(&handle.token)
            .map(|e| e.opened_in.len())
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ptr(device: usize, id: u64) -> DevPtr {
        DevPtr { device, alloc_id: id, offset: 0 }
    }

    #[test]
    fn export_open_roundtrip() {
        let reg = IpcRegistry::new();
        let h = reg.export(AddressSpace(1), ptr(1, 42)).unwrap();
        let p = reg.open(AddressSpace(0), h).unwrap();
        assert_eq!(p.alloc_id, 42);
        assert_eq!(p.device, 1);
        assert_eq!(reg.open_count(h), 1);
    }

    #[test]
    fn open_in_exporting_process_rejected() {
        let reg = IpcRegistry::new();
        let h = reg.export(AddressSpace(2), ptr(2, 1)).unwrap();
        let err = reg.open(AddressSpace(2), h).unwrap_err();
        assert!(format!("{err}").contains("exporting process"));
    }

    #[test]
    fn double_open_same_space_rejected() {
        let reg = IpcRegistry::new();
        let h = reg.export(AddressSpace(1), ptr(1, 1)).unwrap();
        reg.open(AddressSpace(0), h).unwrap();
        assert!(reg.open(AddressSpace(0), h).is_err());
        // A third space may still open it.
        reg.open(AddressSpace(3), h).unwrap();
        assert_eq!(reg.open_count(h), 2);
    }

    #[test]
    fn revoked_handle_unopenable() {
        let reg = IpcRegistry::new();
        let h = reg.export(AddressSpace(1), ptr(1, 1)).unwrap();
        reg.revoke(AddressSpace(1), h).unwrap();
        assert!(reg.open(AddressSpace(0), h).is_err());
    }

    #[test]
    fn only_exporter_can_revoke() {
        let reg = IpcRegistry::new();
        let h = reg.export(AddressSpace(1), ptr(1, 1)).unwrap();
        assert!(reg.revoke(AddressSpace(0), h).is_err());
    }

    #[test]
    fn close_releases_mapping() {
        let reg = IpcRegistry::new();
        let h = reg.export(AddressSpace(1), ptr(1, 1)).unwrap();
        reg.open(AddressSpace(0), h).unwrap();
        reg.close(AddressSpace(0), h).unwrap();
        assert_eq!(reg.open_count(h), 0);
        // Re-open after close is allowed (fresh mapping).
        reg.open(AddressSpace(0), h).unwrap();
    }

    #[test]
    fn offset_pointer_not_exportable() {
        let reg = IpcRegistry::new();
        let p = DevPtr { device: 0, alloc_id: 5, offset: 16 };
        assert!(reg.export(AddressSpace(0), p).is_err());
    }

    #[test]
    fn forged_handle_rejected() {
        let reg = IpcRegistry::new();
        let _h = reg.export(AddressSpace(1), ptr(1, 1)).unwrap();
        assert!(reg.open(AddressSpace(0), IpcHandle { token: 1 }).is_err());
    }
}
