//! Single-caller pointer reconciliation (paper §2.2, Figure 2).
//!
//! cuSOLVERMg must be called from **one** thread/process that can see
//! every device's shard pointer, but `jax.shard_map` launches one
//! thread (SPMD) or one process (MPMD) per GPU. JAXMg bridges this two
//! ways, both reproduced here:
//!
//! * **SPMD** — all workers share one virtual address space, so a POSIX
//!   shared-memory table of raw pointers suffices:
//!   [`SharedPtrTable`] is that table (a slot per device + rendezvous).
//! * **MPMD** — separate address spaces; raw pointers are *undefined*
//!   across processes, so allocations must be exported through the
//!   `cudaIpc` API and re-opened in the caller's space:
//!   [`IpcRegistry`] models the export/open/close lifecycle, including
//!   the failure modes (open in the exporting process, open of a
//!   revoked handle), over simulated [`AddressSpace`]s.

mod registry;
mod shared_table;

pub use registry::{AddressSpace, IpcHandle, IpcRegistry};
pub use shared_table::SharedPtrTable;
