//! Single-caller pointer reconciliation (paper §2.2, Figure 2).
//!
//! cuSOLVERMg must be called from **one** thread/process that can see
//! every device's shard pointer, but `jax.shard_map` launches one
//! thread (SPMD) or one process (MPMD) per GPU. JAXMg bridges this two
//! ways, both reproduced here:
//!
//! * **SPMD** (Fig. 2, left) — all workers share one virtual address
//!   space, so a POSIX shared-memory table of raw pointers suffices:
//!   [`SharedPtrTable`] is that table (a slot per device + rendezvous).
//! * **MPMD** (Fig. 2, right) — separate address spaces; raw pointers
//!   are *undefined* across processes, so allocations must be exported
//!   through the `cudaIpc` API and re-opened in the caller's space:
//!   [`IpcRegistry`] models the export/open/close lifecycle over
//!   simulated [`AddressSpace`]s.
//!
//! ## Handle lifecycle (and its failure modes)
//!
//! The registry reproduces the full `cudaIpcMemHandle_t` life cycle the
//! MPMD serve layer (`crate::serve`) leans on:
//!
//! | event                                   | result                        |
//! |-----------------------------------------|-------------------------------|
//! | `export` / `export_bound`               | opaque unguessable handle     |
//! | `open` in a foreign space               | the exporter's [`crate::device::DevPtr`] |
//! | `open` in the **exporting** space       | `Error::Ipc` (CUDA forbids it)|
//! | second `open` in one space              | `Error::Ipc` (double-open)    |
//! | `open` after `revoke`                   | `Error::Ipc`                  |
//! | `open` after the allocation was *freed* | `Error::Ipc` — a **bound** export ([`IpcRegistry::export_bound`]) checks liveness and marks the handle revoked, so a stale handle can never map dead memory |
//! | worker frees an exported shard          | [`IpcRegistry::revoke_all_for`] revokes every handle over the pointer first |
//!
//! Per-process accounting ([`IpcRegistry::open_count_in`],
//! [`IpcRegistry::exports_by`]) gives the serve layer's leak checks and
//! the `ipc_*` metrics counters their ground truth.
//!
//! `coordinator::mpmd::gather_pointers_mpmd` is the minimal
//! one-shot demo of this machinery; `crate::serve` is the production
//! shape — persistent one-process-per-GPU workers exporting shards to a
//! rank-0 frontend with failure-aware routing.

mod registry;
mod shared_table;

pub use registry::{AddressSpace, IpcHandle, IpcRegistry};
pub use shared_table::SharedPtrTable;
