//! SPMD pointer rendezvous: the POSIX-shared-memory table of Figure 2
//! (left). Every worker thread publishes its device's shard pointer
//! into its slot; the single caller (thread 0) gathers all slots once
//! every worker has arrived.

use crate::device::DevPtr;
use crate::error::{Error, Result};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// A fixed-size table of per-device pointers with blocking gather.
///
/// Semantics mirror the shm segment in the real system: publishing
/// twice to a slot is an error (a shard was bound twice), gathering
/// blocks until all `n` workers have published or the timeout fires.
#[derive(Debug)]
pub struct SharedPtrTable {
    slots: Mutex<Vec<Option<DevPtr>>>,
    arrived: Condvar,
}

impl SharedPtrTable {
    /// Table with one slot per device.
    pub fn new(n_devices: usize) -> Self {
        SharedPtrTable { slots: Mutex::new(vec![None; n_devices]), arrived: Condvar::new() }
    }

    /// Number of slots.
    pub fn len(&self) -> usize {
        self.slots.lock().unwrap().len()
    }

    /// True when no slots exist.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Worker `device` publishes its shard pointer.
    pub fn publish(&self, device: usize, ptr: DevPtr) -> Result<()> {
        let mut slots = self.slots.lock().unwrap();
        let n = slots.len();
        let slot = slots.get_mut(device).ok_or(Error::InvalidDevice { device, count: n })?;
        if slot.is_some() {
            return Err(Error::ipc(format!("slot {device} already published")));
        }
        *slot = Some(ptr);
        drop(slots);
        self.arrived.notify_all();
        Ok(())
    }

    /// Count of already-published slots (non-blocking).
    pub fn published(&self) -> usize {
        self.slots.lock().unwrap().iter().filter(|s| s.is_some()).count()
    }

    /// The single caller gathers every device's pointer, blocking until
    /// all workers have published (or `timeout`).
    pub fn gather(&self, timeout: Duration) -> Result<Vec<DevPtr>> {
        let mut slots = self.slots.lock().unwrap();
        let deadline = std::time::Instant::now() + timeout;
        while slots.iter().any(|s| s.is_none()) {
            let now = std::time::Instant::now();
            if now >= deadline {
                let missing: Vec<usize> =
                    slots.iter().enumerate().filter(|(_, s)| s.is_none()).map(|(i, _)| i).collect();
                return Err(Error::ipc(format!("gather timed out waiting for slots {missing:?}")));
            }
            let (guard, _) = self.arrived.wait_timeout(slots, deadline - now).unwrap();
            slots = guard;
        }
        Ok(slots.iter().map(|s| s.unwrap()).collect())
    }

    /// Clear all slots for reuse in the next solve.
    pub fn reset(&self) {
        let mut slots = self.slots.lock().unwrap();
        for s in slots.iter_mut() {
            *s = None;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn ptr(device: usize, id: u64) -> DevPtr {
        DevPtr { device, alloc_id: id, offset: 0 }
    }

    #[test]
    fn publish_then_gather() {
        let t = SharedPtrTable::new(3);
        t.publish(0, ptr(0, 1)).unwrap();
        t.publish(2, ptr(2, 3)).unwrap();
        t.publish(1, ptr(1, 2)).unwrap();
        let all = t.gather(Duration::from_millis(10)).unwrap();
        assert_eq!(all.len(), 3);
        assert_eq!(all[2].alloc_id, 3);
    }

    #[test]
    fn double_publish_rejected() {
        let t = SharedPtrTable::new(2);
        t.publish(0, ptr(0, 1)).unwrap();
        assert!(t.publish(0, ptr(0, 9)).is_err());
    }

    #[test]
    fn gather_times_out_when_worker_missing() {
        let t = SharedPtrTable::new(2);
        t.publish(0, ptr(0, 1)).unwrap();
        let err = t.gather(Duration::from_millis(20)).unwrap_err();
        assert!(format!("{err}").contains("[1]"), "{err}");
    }

    #[test]
    fn gather_blocks_until_concurrent_publish() {
        let t = Arc::new(SharedPtrTable::new(4));
        let mut handles = vec![];
        for d in 0..4 {
            let t = t.clone();
            handles.push(std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(5 * d as u64));
                t.publish(d, ptr(d, d as u64 + 1)).unwrap();
            }));
        }
        let all = t.gather(Duration::from_secs(5)).unwrap();
        for h in handles {
            h.join().unwrap();
        }
        for (d, p) in all.iter().enumerate() {
            assert_eq!(p.device, d);
        }
    }

    #[test]
    fn reset_allows_reuse() {
        let t = SharedPtrTable::new(1);
        t.publish(0, ptr(0, 1)).unwrap();
        t.gather(Duration::from_millis(5)).unwrap();
        t.reset();
        assert_eq!(t.published(), 0);
        t.publish(0, ptr(0, 2)).unwrap();
        assert_eq!(t.gather(Duration::from_millis(5)).unwrap()[0].alloc_id, 2);
    }

    #[test]
    fn out_of_range_slot_rejected() {
        let t = SharedPtrTable::new(2);
        assert!(t.publish(2, ptr(2, 1)).is_err());
    }
}
