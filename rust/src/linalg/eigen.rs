//! Reference symmetric/Hermitian eigensolver (`syevd` semantics).
//!
//! Pipeline, mirroring LAPACK `zheevd`/`dsyevd`:
//!
//! 1. [`tridiagonalize`]: Householder reduction `Qᴴ A Q = T` with `T`
//!    real symmetric tridiagonal (complex off-diagonals are rotated real
//!    by a diagonal phase similarity folded into `Q`).
//! 2. [`tql2`]: implicit-shift QL on `(d, e)` accumulating the rotations
//!    into the supplied vector matrix.
//!
//! The distributed `solver::syevd` reuses exactly these pieces, but runs
//! the reduction and back-transformation over tiles spread across the
//! simulated devices.

use crate::error::{Error, Result};
use crate::linalg::dense::Matrix;
use crate::scalar::{RealScalar, Scalar};

/// Real symmetric tridiagonal matrix: diagonal `d` (len n) and
/// sub-diagonal `e` (len n−1).
#[derive(Clone, Debug)]
pub struct Tridiagonal<R> {
    pub d: Vec<R>,
    pub e: Vec<R>,
}

/// Result of a symmetric eigendecomposition: ascending eigenvalues and
/// the matching orthonormal eigenvector columns (`A = V Λ Vᴴ`).
#[derive(Clone, Debug)]
pub struct EigenDecomposition<S: Scalar> {
    pub values: Vec<S::Real>,
    pub vectors: Matrix<S>,
}

/// Householder reduction of a Hermitian matrix to *real* tridiagonal
/// form. Returns `(T, Q)` with `A = Q · T · Qᴴ` and `Q` unitary.
pub fn tridiagonalize<S: Scalar>(a: &Matrix<S>) -> Result<(Tridiagonal<S::Real>, Matrix<S>)> {
    let n = a.require_square()?;
    let mut w = a.clone();
    let mut q = Matrix::<S>::eye(n);
    let mut u = vec![S::zero(); n]; // Householder vector, zero above k+1
    for k in 0..n.saturating_sub(2) {
        // x = W[k+1.., k]
        let mut xnorm_sq = <S::Real as RealScalar>::rzero();
        for i in (k + 1)..n {
            xnorm_sq = xnorm_sq + w[(i, k)].abs_sqr();
        }
        let xnorm = xnorm_sq.rsqrt_val();
        if xnorm.to_f64() == 0.0 {
            continue; // column already reduced
        }
        let alpha = w[(k + 1, k)];
        let aabs = alpha.abs();
        // β = −phase(α)·‖x‖ (phase = 1 when α = 0).
        let phase = if aabs.to_f64() == 0.0 { S::one() } else { alpha * S::from_real(<S::Real as RealScalar>::rone() / aabs) };
        let beta = -phase * S::from_real(xnorm);
        // u = x − β e₁ ; H = I − τ u uᴴ with real τ = 2/‖u‖².
        for v in u.iter_mut() {
            *v = S::zero();
        }
        let mut unorm_sq = <S::Real as RealScalar>::rzero();
        for i in (k + 1)..n {
            let ui = if i == k + 1 { w[(i, k)] - beta } else { w[(i, k)] };
            u[i] = ui;
            unorm_sq = unorm_sq + ui.abs_sqr();
        }
        if unorm_sq.to_f64() == 0.0 {
            continue;
        }
        let tau = S::from_real(<S::Real as RealScalar>::from_f64(2.0) / unorm_sq);

        // W ← H W: W -= τ u (uᴴ W)   (rows k+1.. only are touched)
        let mut uhw = vec![S::zero(); n];
        for j in 0..n {
            let mut acc = S::zero();
            for i in (k + 1)..n {
                acc += u[i].conj() * w[(i, j)];
            }
            uhw[j] = acc;
        }
        for j in 0..n {
            let t = tau * uhw[j];
            for i in (k + 1)..n {
                let d = u[i] * t;
                let v = w[(i, j)] - d;
                w[(i, j)] = v;
            }
        }
        // W ← W H: W -= τ (W u) uᴴ
        let mut wu = vec![S::zero(); n];
        for i in 0..n {
            let mut acc = S::zero();
            for j in (k + 1)..n {
                acc += w[(i, j)] * u[j];
            }
            wu[i] = acc;
        }
        for i in 0..n {
            let t = tau * wu[i];
            for j in (k + 1)..n {
                let v = w[(i, j)] - t * u[j].conj();
                w[(i, j)] = v;
            }
        }
        // Q ← Q H: Q -= τ (Q u) uᴴ
        let mut qu = vec![S::zero(); n];
        for i in 0..n {
            let mut acc = S::zero();
            for j in (k + 1)..n {
                acc += q[(i, j)] * u[j];
            }
            qu[i] = acc;
        }
        for i in 0..n {
            let t = tau * qu[i];
            for j in (k + 1)..n {
                let v = q[(i, j)] - t * u[j].conj();
                q[(i, j)] = v;
            }
        }
    }

    // Extract T; rotate complex sub-diagonals real with a phase
    // similarity folded into Q (A = Q D T_real Dᴴ Qᴴ = (QD) T_real (QD)ᴴ).
    let mut d = vec![<S::Real as RealScalar>::rzero(); n];
    let mut e = vec![<S::Real as RealScalar>::rzero(); n.saturating_sub(1)];
    let mut p = S::one(); // running phase p[k]
    let mut phases = vec![S::one(); n];
    for i in 0..n {
        d[i] = w[(i, i)].re();
    }
    for k in 0..n.saturating_sub(1) {
        let ek = w[(k + 1, k)];
        let eabs = ek.abs();
        e[k] = eabs;
        let phase = if eabs.to_f64() == 0.0 { S::one() } else { ek * S::from_real(<S::Real as RealScalar>::rone() / eabs) };
        p = p * phase;
        phases[k + 1] = p;
    }
    // Q ← Q·D
    let mut qd = q;
    for j in 0..n {
        let pj = phases[j];
        for i in 0..n {
            let v = qd[(i, j)] * pj;
            qd[(i, j)] = v;
        }
    }
    Ok((Tridiagonal { d, e }, qd))
}

/// Implicit-shift QL on a real symmetric tridiagonal `(d, e)`,
/// accumulating the Givens rotations into the columns of `z`
/// (pass `Q` from [`tridiagonalize`] to get eigenvectors of `A`,
/// or the identity to get eigenvectors of `T`).
///
/// On success `d` holds ascending eigenvalues and `z`'s columns the
/// matching eigenvectors. Classic EISPACK `tql2` port.
pub fn tql2<S: Scalar>(tri: &Tridiagonal<S::Real>, z: &mut Matrix<S>) -> Result<Vec<S::Real>> {
    let n = tri.d.len();
    if n == 0 {
        return Ok(vec![]);
    }
    let mut d: Vec<f64> = tri.d.iter().map(|v| v.to_f64()).collect();
    let mut e: Vec<f64> = tri.e.iter().map(|v| v.to_f64()).collect();
    e.push(0.0);
    let zn = z.rows();
    assert_eq!(z.cols(), n, "z must have n columns");

    const MAX_ITER: usize = 50;
    for l in 0..n {
        let mut iter = 0;
        loop {
            // Find small off-diagonal to split.
            let mut m = l;
            while m < n - 1 {
                let dd = d[m].abs() + d[m + 1].abs();
                if e[m].abs() <= f64::EPSILON * dd {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            if iter > MAX_ITER {
                return Err(Error::NoConvergence { index: l, iters: MAX_ITER });
            }
            // Form implicit shift.
            let mut g = (d[l + 1] - d[l]) / (2.0 * e[l]);
            let mut r = g.hypot(1.0);
            g = d[m] - d[l] + e[l] / (g + if g >= 0.0 { r.abs() } else { -r.abs() });
            let (mut s, mut c) = (1.0f64, 1.0f64);
            let mut p = 0.0f64;
            for i in (l..m).rev() {
                let f = s * e[i];
                let b = c * e[i];
                r = f.hypot(g);
                e[i + 1] = r;
                if r == 0.0 {
                    d[i + 1] -= p;
                    e[m] = 0.0;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[i + 1] - p;
                r = (d[i] - g) * s + 2.0 * c * b;
                p = s * r;
                d[i + 1] = g + p;
                g = c * r - b;
                // Accumulate rotation in z columns i and i+1.
                let cs = S::from_f64(c);
                let sn = S::from_f64(s);
                for k in 0..zn {
                    let f2 = z[(k, i + 1)];
                    let zi = z[(k, i)];
                    z[(k, i + 1)] = sn * zi + cs * f2;
                    z[(k, i)] = cs * zi - sn * f2;
                }
            }
            if r == 0.0 && m > l + 1 {
                continue;
            }
            d[l] -= p;
            e[l] = g;
            e[m] = 0.0;
        }
    }

    // Sort ascending, permuting eigenvector columns to match.
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&i, &j| d[i].partial_cmp(&d[j]).unwrap());
    let sorted_vals: Vec<S::Real> = idx.iter().map(|&i| <S::Real as RealScalar>::from_f64(d[i])).collect();
    let mut sorted_z = Matrix::<S>::zeros(zn, n);
    for (new_j, &old_j) in idx.iter().enumerate() {
        for i in 0..zn {
            sorted_z[(i, new_j)] = z[(i, old_j)];
        }
    }
    *z = sorted_z;
    Ok(sorted_vals)
}

/// Full host `syevd`: eigenvalues (ascending) and eigenvectors of a
/// Hermitian matrix. The oracle for the distributed eigensolver and the
/// compute of the single-device baseline.
pub fn syevd_host<S: Scalar>(a: &Matrix<S>) -> Result<EigenDecomposition<S>> {
    let (tri, mut q) = tridiagonalize(a)?;
    let values = tql2(&tri, &mut q)?;
    Ok(EigenDecomposition { values, vectors: q })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dense::{tol_for, FrobNorm};
    use crate::scalar::{c64, Scalar};

    fn check_eigen<S: Scalar>(n: usize, seed: u64) {
        let a = Matrix::<S>::hermitian_random(n, seed);
        let eig = syevd_host(&a).unwrap();
        // A·V = V·Λ
        let av = a.matmul(&eig.vectors);
        let mut vl = eig.vectors.clone();
        for j in 0..n {
            let lam = S::from_real(eig.values[j]);
            for i in 0..n {
                let v = vl[(i, j)] * lam;
                vl[(i, j)] = v;
            }
        }
        assert!(av.rel_err(&vl) < tol_for::<S>(n) * 10.0, "A·V != V·Λ for {:?} n={n}", S::DTYPE);
        // Vᴴ·V = I
        let vhv = eig.vectors.adjoint().matmul(&eig.vectors);
        assert!(vhv.rel_err(&Matrix::eye(n)) < tol_for::<S>(n) * 10.0);
        // ascending
        for k in 1..n {
            assert!(eig.values[k - 1].to_f64() <= eig.values[k].to_f64() + 1e-12);
        }
    }

    #[test]
    fn eigen_real_f64() {
        check_eigen::<f64>(30, 1);
    }

    #[test]
    fn eigen_complex_c128() {
        check_eigen::<c64>(25, 2);
    }

    #[test]
    fn eigen_real_f32() {
        check_eigen::<f32>(16, 3);
    }

    #[test]
    fn eigen_diag_matches_paper_matrix() {
        // diag(1..N): eigenvalues are exactly 1..N.
        let n = 12;
        let a = Matrix::<f64>::spd_diag(n);
        let eig = syevd_host(&a).unwrap();
        for i in 0..n {
            assert!((eig.values[i] - (i + 1) as f64).abs() < 1e-10);
        }
    }

    #[test]
    fn tridiagonalize_preserves_similarity() {
        let n = 20;
        let a = Matrix::<c64>::hermitian_random(n, 5);
        let (tri, q) = tridiagonalize(&a).unwrap();
        // Rebuild T as dense real-in-S matrix and check A = Q T Qᴴ.
        let mut t = Matrix::<c64>::zeros(n, n);
        for i in 0..n {
            t[(i, i)] = c64::new(tri.d[i], 0.0);
        }
        for k in 0..n - 1 {
            t[(k + 1, k)] = c64::new(tri.e[k], 0.0);
            t[(k, k + 1)] = c64::new(tri.e[k], 0.0);
        }
        let rebuilt = q.matmul(&t).matmul(&q.adjoint());
        assert!(rebuilt.rel_err(&a) < 1e-12);
        // Q unitary.
        let qhq = q.adjoint().matmul(&q);
        assert!(qhq.rel_err(&Matrix::eye(n)) < 1e-12);
        // Sub-diagonal must be real non-negative by construction.
        for k in 0..n - 1 {
            assert!(tri.e[k] >= 0.0);
        }
    }

    #[test]
    fn tql2_identity_gives_tridiag_vectors() {
        // Known 2x2: [[2,1],[1,2]] -> eigenvalues 1, 3.
        let tri = Tridiagonal { d: vec![2.0f64, 2.0], e: vec![1.0] };
        let mut z = Matrix::<f64>::eye(2);
        let vals = tql2(&tri, &mut z).unwrap();
        assert!((vals[0] - 1.0).abs() < 1e-12);
        assert!((vals[1] - 3.0).abs() < 1e-12);
        // Eigenvector for λ=1 is (1,-1)/√2 up to sign.
        let r = (z[(0, 0)] / z[(1, 0)]).abs();
        assert!((r - 1.0).abs() < 1e-10);
    }

    #[test]
    fn empty_and_single() {
        let a = Matrix::<f64>::from_vec(1, 1, vec![5.0]);
        let eig = syevd_host(&a).unwrap();
        assert_eq!(eig.values, vec![5.0]);
    }
}
