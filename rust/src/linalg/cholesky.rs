//! Reference Cholesky factorization and the solves built on it.
//!
//! These are the host-side oracles mirroring LAPACK `potrf` / `potrs` /
//! `potri` semantics (lower triangular, `A = L·Lᴴ`), used to validate
//! the distributed solvers and as the single-device baseline's compute.

use crate::error::{Error, Result};
use crate::linalg::dense::Matrix;
use crate::linalg::tri::{trsm_left_lower, trsm_left_lower_h, trtri_lower};
use crate::scalar::{RealScalar, Scalar};

/// Unblocked lower Cholesky: returns `L` with `A = L·Lᴴ`.
///
/// Fails with [`Error::NotPositiveDefinite`] on a non-positive pivot —
/// the analogue of cuSOLVER's `info > 0`.
pub fn potrf<S: Scalar>(a: &Matrix<S>) -> Result<Matrix<S>> {
    let n = a.require_square()?;
    let mut l = a.clone();
    for j in 0..n {
        // d = A[j,j] - Σ_{k<j} |L[j,k]|²  (real for Hermitian input)
        let mut d = l[(j, j)].re();
        for k in 0..j {
            d = d - l[(j, k)].abs_sqr();
        }
        if !(d.to_f64() > 0.0) || !d.to_f64().is_finite() {
            return Err(Error::NotPositiveDefinite { minor: j + 1 });
        }
        let djj = d.rsqrt_val();
        l[(j, j)] = S::from_real(djj);
        let inv = S::from_real(<S::Real as RealScalar>::rone() / djj);
        for i in (j + 1)..n {
            let mut v = l[(i, j)];
            for k in 0..j {
                v = v - l[(i, k)] * l[(j, k)].conj();
            }
            l[(i, j)] = v * inv;
        }
    }
    l.tril_in_place();
    Ok(l)
}

/// Solve `A·X = B` given the Cholesky factor `L` (`A = L·Lᴴ`):
/// forward solve `L·Y = B`, then backward solve `Lᴴ·X = Y`.
pub fn potrs_from_chol<S: Scalar>(l: &Matrix<S>, b: &Matrix<S>) -> Result<Matrix<S>> {
    let n = l.require_square()?;
    if b.rows() != n {
        return Err(Error::shape(format!("potrs rhs rows {} != n {}", b.rows(), n)));
    }
    let y = trsm_left_lower(l, b);
    Ok(trsm_left_lower_h(l, &y))
}

/// Inverse of `A` from its Cholesky factor: `A⁻¹ = L⁻ᴴ · L⁻¹`
/// (LAPACK `potri` semantics, returning the full Hermitian inverse).
pub fn potri_from_chol<S: Scalar>(l: &Matrix<S>) -> Result<Matrix<S>> {
    l.require_square()?;
    let linv = trtri_lower(l)?;
    let mut inv = linv.adjoint().matmul(&linv);
    inv.hermitianize();
    Ok(inv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dense::{tol_for, FrobNorm};
    use crate::scalar::{c32, c64};

    fn check_potrf<S: Scalar>(n: usize, seed: u64) {
        let a = Matrix::<S>::spd_random(n, seed);
        let l = potrf(&a).unwrap();
        let llh = l.matmul(&l.adjoint());
        assert!(llh.rel_err(&a) < tol_for::<S>(n), "LLᴴ != A for n={n} {:?}", S::DTYPE);
        // Strict upper triangle must be zero.
        for j in 1..n {
            for i in 0..j {
                assert_eq!(l[(i, j)], S::zero());
            }
        }
    }

    #[test]
    fn potrf_reconstructs_all_dtypes() {
        check_potrf::<f32>(20, 1);
        check_potrf::<f64>(33, 2);
        check_potrf::<c32>(17, 3);
        check_potrf::<c64>(40, 4);
    }

    #[test]
    fn potrf_rejects_indefinite() {
        let mut a = Matrix::<f64>::eye(4);
        a[(2, 2)] = -1.0;
        match potrf(&a) {
            Err(Error::NotPositiveDefinite { minor }) => assert_eq!(minor, 3),
            other => panic!("expected NotPositiveDefinite, got {other:?}"),
        }
    }

    #[test]
    fn potrf_diag_matches_sqrt() {
        // diag(1..n) factorizes to diag(sqrt(1..n)) — the paper's benchmark matrix.
        let a = Matrix::<f64>::spd_diag(6);
        let l = potrf(&a).unwrap();
        for i in 0..6 {
            assert!((l[(i, i)] - ((i + 1) as f64).sqrt()).abs() < 1e-14);
        }
    }

    #[test]
    fn potrs_solves() {
        let n = 24;
        let a = Matrix::<c64>::spd_random(n, 9);
        let x_true = Matrix::<c64>::random(n, 3, 10);
        let b = a.matmul(&x_true);
        let l = potrf(&a).unwrap();
        let x = potrs_from_chol(&l, &b).unwrap();
        assert!(x.rel_err(&x_true) < tol_for::<c64>(n));
    }

    #[test]
    fn potri_inverts() {
        let n = 18;
        let a = Matrix::<f64>::spd_random(n, 11);
        let l = potrf(&a).unwrap();
        let ainv = potri_from_chol(&l).unwrap();
        let prod = a.matmul(&ainv);
        assert!(prod.rel_err(&Matrix::eye(n)) < tol_for::<f64>(n));
        // potri result must be Hermitian.
        assert!(ainv.rel_err(&ainv.adjoint()) < 1e-14);
    }

    #[test]
    fn potrs_shape_errors() {
        let a = Matrix::<f64>::spd_random(4, 1);
        let l = potrf(&a).unwrap();
        let b = Matrix::<f64>::ones(5, 1);
        assert!(potrs_from_chol(&l, &b).is_err());
    }
}
