//! Triangular solves and inversion (reference versions).
//!
//! Naming follows BLAS `trsm` conventions specialized to the cases the
//! Cholesky-based solvers need; all take the *lower* factor `L` and do
//! not require unit diagonals.

use crate::error::{Error, Result};
use crate::linalg::dense::Matrix;
use crate::scalar::Scalar;

/// Solve `L · X = B` (left, lower, no transpose) by forward substitution.
pub fn trsm_left_lower<S: Scalar>(l: &Matrix<S>, b: &Matrix<S>) -> Matrix<S> {
    let n = l.rows();
    assert_eq!(l.cols(), n);
    assert_eq!(b.rows(), n);
    let mut x = b.clone();
    for j in 0..x.cols() {
        for i in 0..n {
            let mut v = x[(i, j)];
            for k in 0..i {
                v = v - l[(i, k)] * x[(k, j)];
            }
            x[(i, j)] = v / l[(i, i)];
        }
    }
    x
}

/// Solve `Lᴴ · X = B` (left, lower-adjoint) by backward substitution.
pub fn trsm_left_lower_h<S: Scalar>(l: &Matrix<S>, b: &Matrix<S>) -> Matrix<S> {
    let n = l.rows();
    assert_eq!(l.cols(), n);
    assert_eq!(b.rows(), n);
    let mut x = b.clone();
    for j in 0..x.cols() {
        for i in (0..n).rev() {
            let mut v = x[(i, j)];
            for k in (i + 1)..n {
                // (Lᴴ)[i,k] = conj(L[k,i])
                v = v - l[(k, i)].conj() * x[(k, j)];
            }
            x[(i, j)] = v / l[(i, i)].conj();
        }
    }
    x
}

/// Solve `X · Lᴴ = B` (right, lower-adjoint): the panel update of
/// right-looking Cholesky, `L[i,k] = A[i,k] · L[k,k]⁻ᴴ`.
pub fn trsm_right_lower_h<S: Scalar>(b: &Matrix<S>, l: &Matrix<S>) -> Matrix<S> {
    let n = l.rows();
    assert_eq!(l.cols(), n);
    assert_eq!(b.cols(), n);
    let mut x = b.clone();
    // X Lᴴ = B  ⇔  column-by-column: (Lᴴ is upper with (Lᴴ)[k,j] = conj(L[j,k]))
    // X[:,j]·(Lᴴ)[j,j] = B[:,j] - Σ_{k<j} X[:,k]·(Lᴴ)[k,j]
    for j in 0..n {
        for i in 0..x.rows() {
            let mut v = x[(i, j)];
            for k in 0..j {
                v = v - x[(i, k)] * l[(j, k)].conj();
            }
            x[(i, j)] = v / l[(j, j)].conj();
        }
    }
    x
}

/// Invert a lower-triangular matrix; the result is lower-triangular.
pub fn trtri_lower<S: Scalar>(l: &Matrix<S>) -> Result<Matrix<S>> {
    let n = l.require_square()?;
    for i in 0..n {
        if l[(i, i)] == S::zero() {
            return Err(Error::solver(format!("trtri: zero diagonal at {i}")));
        }
    }
    // Solve L·X = I column by column; X inherits the lower triangle.
    let mut x = Matrix::<S>::zeros(n, n);
    for j in 0..n {
        // Forward substitution starting at row j (entries above are zero).
        x[(j, j)] = S::one() / l[(j, j)];
        for i in (j + 1)..n {
            let mut v = S::zero();
            for k in j..i {
                v = v - l[(i, k)] * x[(k, j)];
            }
            x[(i, j)] = v / l[(i, i)];
        }
    }
    Ok(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::cholesky::potrf;
    use crate::linalg::dense::{tol_for, FrobNorm};
    use crate::scalar::c64;

    fn lower_factor<S: Scalar>(n: usize, seed: u64) -> Matrix<S> {
        potrf(&Matrix::<S>::spd_random(n, seed)).unwrap()
    }

    #[test]
    fn left_lower_solve() {
        let l = lower_factor::<f64>(12, 1);
        let x_true = Matrix::<f64>::random(12, 3, 2);
        let b = l.matmul(&x_true);
        let x = trsm_left_lower(&l, &b);
        assert!(x.rel_err(&x_true) < tol_for::<f64>(12));
    }

    #[test]
    fn left_lower_h_solve() {
        let l = lower_factor::<c64>(12, 3);
        let x_true = Matrix::<c64>::random(12, 2, 4);
        let b = l.adjoint().matmul(&x_true);
        let x = trsm_left_lower_h(&l, &b);
        assert!(x.rel_err(&x_true) < tol_for::<c64>(12));
    }

    #[test]
    fn right_lower_h_solve() {
        let l = lower_factor::<c64>(10, 5);
        let x_true = Matrix::<c64>::random(6, 10, 6);
        let b = x_true.matmul(&l.adjoint());
        let x = trsm_right_lower_h(&b, &l);
        assert!(x.rel_err(&x_true) < tol_for::<c64>(10));
    }

    #[test]
    fn trtri_inverts() {
        let l = lower_factor::<f64>(15, 7);
        let linv = trtri_lower(&l).unwrap();
        let prod = l.matmul(&linv);
        assert!(prod.rel_err(&Matrix::eye(15)) < tol_for::<f64>(15));
        // Result stays lower triangular.
        for j in 1..15 {
            for i in 0..j {
                assert_eq!(linv[(i, j)], 0.0);
            }
        }
    }

    #[test]
    fn trtri_rejects_singular() {
        let mut l = Matrix::<f64>::eye(3);
        l[(1, 1)] = 0.0;
        assert!(trtri_lower(&l).is_err());
    }
}
