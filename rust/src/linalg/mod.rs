//! Host-side dense linear algebra.
//!
//! This module is the *reference* layer: a column-major [`Matrix`] type
//! plus straightforward implementations of the kernels the distributed
//! solvers are built from (Cholesky, triangular solves, GEMM/HERK,
//! Householder tridiagonalization, implicit-shift QL). It serves three
//! roles:
//!
//! 1. correctness oracle for the distributed solvers and XLA kernels,
//! 2. compute backend for `solver::NativeKernels` (tile ops), and
//! 3. the single-device `baseline` (the paper's cuSOLVERDn comparator).

mod cholesky;
pub mod dense;
mod eigen;
mod tri;

pub use cholesky::{potrf, potri_from_chol, potrs_from_chol};
pub use dense::{
    gemm_acc as dense_gemm_acc, gemm_hn_acc as dense_gemm_hn_acc, gemv_acc, tol_for, FrobNorm,
    Matrix,
};
pub use eigen::{syevd_host, tql2, tridiagonalize, EigenDecomposition, Tridiagonal};
pub use tri::{trsm_left_lower, trsm_left_lower_h, trsm_right_lower_h, trtri_lower};
