//! Column-major dense matrix and the BLAS-3 style primitives used by
//! the reference implementations and the native tile backend.

use crate::error::{Error, Result};
use crate::rng::Rng;
use crate::scalar::{RealScalar, Scalar};
use std::fmt;
use std::ops::{Index, IndexMut};

/// Column-major dense matrix over a [`Scalar`].
///
/// Column-major matches cuSOLVERMg / LAPACK conventions and makes
/// "column panel" the natural contiguous unit for the 1D layout — the
/// same reason the paper redistributes *columns*.
#[derive(Clone, PartialEq)]
pub struct Matrix<S> {
    rows: usize,
    cols: usize,
    data: Vec<S>,
}

impl<S: Scalar> Matrix<S> {
    /// Zero matrix of shape `rows × cols`.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![S::zero(); rows * cols] }
    }

    /// All-ones matrix (the paper's `b = (1, ..., 1)ᵀ`).
    pub fn ones(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![S::one(); rows * cols] }
    }

    /// Identity of order `n`.
    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = S::one();
        }
        m
    }

    /// From a column-major data vector.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<S>) -> Self {
        assert_eq!(data.len(), rows * cols, "data length must equal rows*cols");
        Matrix { rows, cols, data }
    }

    /// Build from a closure over (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> S) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for j in 0..cols {
            for i in 0..rows {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    /// The paper's benchmark matrix `A = diag(1, ..., N)` (footnote 1:
    /// random SPD matrices give the same timings).
    pub fn spd_diag(n: usize) -> Self {
        Self::from_fn(n, n, |i, j| if i == j { S::from_f64((i + 1) as f64) } else { S::zero() })
    }

    /// Random Hermitian positive-definite matrix: `A = Bᴴ B + n·I`,
    /// deterministic in `seed`.
    pub fn spd_random(n: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut b = Self::zeros(n, n);
        rng.fill(&mut b.data);
        let mut a = b.hermitian_of(&b); // Bᴴ B, PSD
        for i in 0..n {
            a[(i, i)] += S::from_f64(n as f64);
        }
        // Force exact Hermitian symmetry (and real diagonal) to kill
        // rounding asymmetry from the GEMM.
        a.hermitianize();
        a
    }

    /// Random Hermitian positive-definite matrix with a prescribed
    /// 2-norm condition number: `A = Q D Qᴴ` for a random Householder
    /// reflector `Q = I − 2wwᴴ` and a log-spaced diagonal running from
    /// `cond` down to 1. `Q` is exactly unitary, so the eigenvalues of
    /// `A` are exactly `D` and κ₂(A) = `cond` up to rounding. Built from
    /// rank-1 updates in O(n²); deterministic in `seed`. This is the
    /// generator the mixed-precision refinement tests use to place
    /// requests on either side of the κ·ε_f32 convergence guard.
    pub fn spd_random_cond(n: usize, seed: u64, cond: f64) -> Self {
        assert!(cond >= 1.0, "condition number must be >= 1");
        let mut rng = Rng::new(seed);
        let mut v = vec![S::zero(); n];
        rng.fill(&mut v);
        let norm = v.iter().map(|z| z.abs_sqr().to_f64()).sum::<f64>().sqrt();
        // w = v/‖v‖ (e₀ for the degenerate all-zero draw).
        let w: Vec<S> = if norm > 0.0 {
            v.iter().map(|&z| z * S::from_f64(1.0 / norm)).collect()
        } else {
            (0..n).map(|i| if i == 0 { S::one() } else { S::zero() }).collect()
        };
        let d: Vec<f64> = (0..n)
            .map(|i| {
                let t = if n > 1 { i as f64 / (n - 1) as f64 } else { 0.0 };
                cond.powf(1.0 - t)
            })
            .collect();
        // QDQ = D − 2·w(wᴴD) − 2·(Dw)wᴴ + 4·(wᴴDw)·wwᴴ.
        let dw: Vec<S> = w.iter().zip(&d).map(|(&wi, &di)| wi * S::from_f64(di)).collect();
        let wdw: f64 =
            w.iter().zip(&dw).map(|(&wi, &dwi)| (wi.conj() * dwi).re().to_f64()).sum();
        let mut a = Self::zeros(n, n);
        for j in 0..n {
            for i in 0..n {
                let mut val = if i == j { S::from_f64(d[i]) } else { S::zero() };
                val += (w[i] * dw[j].conj()) * S::from_f64(-2.0);
                val += (dw[i] * w[j].conj()) * S::from_f64(-2.0);
                val += (w[i] * w[j].conj()) * S::from_f64(4.0 * wdw);
                a[(i, j)] = val;
            }
        }
        a.hermitianize();
        a
    }

    /// Random Hermitian (not necessarily definite) matrix.
    pub fn hermitian_random(n: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut a = Self::zeros(n, n);
        rng.fill(&mut a.data);
        a.hermitianize();
        a
    }

    /// Random general matrix.
    pub fn random(rows: usize, cols: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut a = Self::zeros(rows, cols);
        rng.fill(&mut a.data);
        a
    }

    /// Number of rows.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// `(rows, cols)`.
    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Borrow the column-major backing storage.
    #[inline]
    pub fn as_slice(&self) -> &[S] {
        &self.data
    }

    /// Mutably borrow the column-major backing storage.
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [S] {
        &mut self.data
    }

    /// Consume into the backing storage.
    pub fn into_vec(self) -> Vec<S> {
        self.data
    }

    /// Contiguous column `j`.
    #[inline]
    pub fn col(&self, j: usize) -> &[S] {
        &self.data[j * self.rows..(j + 1) * self.rows]
    }

    /// Mutable contiguous column `j`.
    #[inline]
    pub fn col_mut(&mut self, j: usize) -> &mut [S] {
        &mut self.data[j * self.rows..(j + 1) * self.rows]
    }

    /// Copy of the submatrix `[r0, r0+nr) × [c0, c0+nc)`.
    pub fn submatrix(&self, r0: usize, c0: usize, nr: usize, nc: usize) -> Matrix<S> {
        assert!(r0 + nr <= self.rows && c0 + nc <= self.cols, "submatrix out of bounds");
        Matrix::from_fn(nr, nc, |i, j| self[(r0 + i, c0 + j)])
    }

    /// Write `block` into `self` at offset `(r0, c0)`.
    pub fn set_submatrix(&mut self, r0: usize, c0: usize, block: &Matrix<S>) {
        assert!(r0 + block.rows <= self.rows && c0 + block.cols <= self.cols);
        for j in 0..block.cols {
            for i in 0..block.rows {
                self[(r0 + i, c0 + j)] = block[(i, j)];
            }
        }
    }

    /// Conjugate transpose.
    pub fn adjoint(&self) -> Matrix<S> {
        Matrix::from_fn(self.cols, self.rows, |i, j| self[(j, i)].conj())
    }

    /// Plain transpose (no conjugation).
    pub fn transpose(&self) -> Matrix<S> {
        Matrix::from_fn(self.cols, self.rows, |i, j| self[(j, i)])
    }

    /// `self · other`.
    pub fn matmul(&self, other: &Matrix<S>) -> Matrix<S> {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut c = Matrix::zeros(self.rows, other.cols);
        gemm_acc(&mut c, self, other, S::one());
        c
    }

    /// `Bᴴ · B` for `B = other` (helper for SPD construction).
    fn hermitian_of(&self, b: &Matrix<S>) -> Matrix<S> {
        let bh = b.adjoint();
        bh.matmul(b)
    }

    /// Force exact Hermitian symmetry: `A ← (A + Aᴴ)/2` with a real diagonal.
    pub fn hermitianize(&mut self) {
        assert_eq!(self.rows, self.cols);
        let half = S::from_f64(0.5);
        for j in 0..self.cols {
            for i in 0..j {
                let v = (self[(i, j)] + self[(j, i)].conj()) * half;
                self[(i, j)] = v;
                self[(j, i)] = v.conj();
            }
            let d = self[(j, j)];
            self[(j, j)] = S::from_real(d.re());
        }
    }

    /// Elementwise subtraction.
    pub fn sub(&self, other: &Matrix<S>) -> Matrix<S> {
        assert_eq!(self.shape(), other.shape());
        let data = self.data.iter().zip(&other.data).map(|(&a, &b)| a - b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// Elementwise addition.
    pub fn add(&self, other: &Matrix<S>) -> Matrix<S> {
        assert_eq!(self.shape(), other.shape());
        let data = self.data.iter().zip(&other.data).map(|(&a, &b)| a + b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// Scale by a scalar.
    pub fn scale(&self, s: S) -> Matrix<S> {
        let data = self.data.iter().map(|&a| a * s).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// Frobenius norm.
    pub fn norm_fro(&self) -> f64 {
        self.data.iter().map(|v| v.abs_sqr().to_f64()).sum::<f64>().sqrt()
    }

    /// Max-abs entry.
    pub fn norm_max(&self) -> f64 {
        self.data.iter().map(|v| v.abs().to_f64()).fold(0.0, f64::max)
    }

    /// Zero out the strict upper triangle (canonical lower-Cholesky form).
    pub fn tril_in_place(&mut self) {
        for j in 0..self.cols {
            for i in 0..j.min(self.rows) {
                self[(i, j)] = S::zero();
            }
        }
    }

    /// Validate square shape, returning a crate error.
    pub fn require_square(&self) -> Result<usize> {
        if self.rows != self.cols {
            return Err(Error::shape(format!("expected square matrix, got {}x{}", self.rows, self.cols)));
        }
        Ok(self.rows)
    }
}

impl<S: Scalar> Index<(usize, usize)> for Matrix<S> {
    type Output = S;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &S {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[j * self.rows + i]
    }
}

impl<S: Scalar> IndexMut<(usize, usize)> for Matrix<S> {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut S {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[j * self.rows + i]
    }
}

impl<S: Scalar> fmt::Debug for Matrix<S> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        let show_r = self.rows.min(8);
        let show_c = self.cols.min(8);
        for i in 0..show_r {
            write!(f, "  ")?;
            for j in 0..show_c {
                write!(f, "{} ", self[(i, j)])?;
            }
            writeln!(f, "{}", if self.cols > show_c { "..." } else { "" })?;
        }
        if self.rows > show_r {
            writeln!(f, "  ...")?;
        }
        write!(f, "]")
    }
}

/// `C += alpha · A · B` on raw column-major buffers — the single GEMM
/// used everywhere host-side. The innermost loop is a contiguous axpy
/// over a column (autovectorizes); output columns are processed in
/// blocks of four so every streamed column of `A` is reused four times
/// before leaving cache — a 1.5–2× win at n ≥ 256 (EXPERIMENTS.md
/// §Perf L3-2).
pub fn gemm_acc<S: Scalar>(c: &mut Matrix<S>, a: &Matrix<S>, b: &Matrix<S>, alpha: S) {
    assert_eq!(a.cols, b.rows, "gemm inner dims");
    assert_eq!(c.rows, a.rows, "gemm output rows");
    assert_eq!(c.cols, b.cols, "gemm output cols");
    let m = a.rows;
    if m == 0 {
        return;
    }
    let n = b.cols;
    let mut j = 0;
    // 4-column blocks: load A's column once, update 4 C columns.
    while j + 4 <= n {
        let (c0, rest) = c.data[j * m..].split_at_mut(m);
        let (c1, rest) = rest.split_at_mut(m);
        let (c2, rest) = rest.split_at_mut(m);
        let c3 = &mut rest[..m];
        for l in 0..a.cols {
            let b0 = alpha * b[(l, j)];
            let b1 = alpha * b[(l, j + 1)];
            let b2 = alpha * b[(l, j + 2)];
            let b3 = alpha * b[(l, j + 3)];
            if b0 == S::zero() && b1 == S::zero() && b2 == S::zero() && b3 == S::zero() {
                continue;
            }
            let al = &a.data[l * m..(l + 1) * m];
            for i in 0..m {
                let ai = al[i];
                c0[i] += ai * b0;
                c1[i] += ai * b1;
                c2[i] += ai * b2;
                c3[i] += ai * b3;
            }
        }
        j += 4;
    }
    // Remainder columns.
    while j < n {
        let cj = &mut c.data[j * m..(j + 1) * m];
        for l in 0..a.cols {
            let blj = alpha * b[(l, j)];
            if blj == S::zero() {
                continue;
            }
            let al = &a.data[l * m..(l + 1) * m];
            for i in 0..m {
                cj[i] += al[i] * blj;
            }
        }
        j += 1;
    }
}

/// `C += alpha · Aᴴ · B` without materializing `Aᴴ`.
pub fn gemm_hn_acc<S: Scalar>(c: &mut Matrix<S>, a: &Matrix<S>, b: &Matrix<S>, alpha: S) {
    assert_eq!(a.rows, b.rows, "gemm_hn inner dims");
    assert_eq!(c.rows, a.cols, "gemm_hn output rows");
    assert_eq!(c.cols, b.cols, "gemm_hn output cols");
    let k = a.rows;
    for j in 0..b.cols {
        for i in 0..a.cols {
            let ai = &a.data[i * k..(i + 1) * k];
            let bj = &b.data[j * k..(j + 1) * k];
            let mut acc = S::zero();
            for l in 0..k {
                acc += ai[l].conj() * bj[l];
            }
            c[(i, j)] += alpha * acc;
        }
    }
}

/// Matrix–vector product `y += alpha · A · x`.
pub fn gemv_acc<S: Scalar>(y: &mut [S], a: &Matrix<S>, x: &[S], alpha: S) {
    assert_eq!(a.cols, x.len());
    assert_eq!(a.rows, y.len());
    for (l, &xl) in x.iter().enumerate() {
        let axl = alpha * xl;
        if axl == S::zero() {
            continue;
        }
        let col = a.col(l);
        for i in 0..y.len() {
            y[i] += col[i] * axl;
        }
    }
}

/// Relative Frobenius-norm distance, the assertion currency of the test
/// suites: `‖a − b‖_F / max(1, ‖b‖_F)`.
pub trait FrobNorm<S: Scalar> {
    fn rel_err(&self, other: &Matrix<S>) -> f64;
}

impl<S: Scalar> FrobNorm<S> for Matrix<S> {
    fn rel_err(&self, other: &Matrix<S>) -> f64 {
        self.sub(other).norm_fro() / other.norm_fro().max(1.0)
    }
}

/// Dtype-appropriate tolerance for `rel_err` assertions: f32-backed
/// scalars get a looser bound.
pub fn tol_for<S: Scalar>(n: usize) -> f64 {
    let eps = <S::Real as RealScalar>::eps().to_f64();
    // Scaled by problem size: Cholesky/eig error grows ~ n·eps.
    (n.max(8) as f64) * eps * 100.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scalar::c64;

    #[test]
    fn index_is_column_major() {
        let m = Matrix::<f64>::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(m[(0, 0)], 1.0);
        assert_eq!(m[(1, 0)], 2.0);
        assert_eq!(m[(0, 1)], 3.0);
        assert_eq!(m[(1, 1)], 4.0);
        assert_eq!(m.col(1), &[3.0, 4.0]);
    }

    #[test]
    fn matmul_small() {
        let a = Matrix::<f64>::from_vec(2, 2, vec![1.0, 3.0, 2.0, 4.0]); // [[1,2],[3,4]]
        let b = Matrix::<f64>::ones(2, 2);
        let c = a.matmul(&b);
        assert_eq!(c[(0, 0)], 3.0);
        assert_eq!(c[(1, 0)], 7.0);
        assert_eq!(c[(0, 1)], 3.0);
        assert_eq!(c[(1, 1)], 7.0);
    }

    #[test]
    fn adjoint_conjugates() {
        let a = Matrix::<c64>::from_fn(2, 3, |i, j| c64::new(i as f64, j as f64));
        let ah = a.adjoint();
        assert_eq!(ah.shape(), (3, 2));
        assert_eq!(ah[(2, 1)], c64::new(1.0, -2.0));
    }

    #[test]
    fn spd_random_is_hermitian_pd() {
        let a = Matrix::<c64>::spd_random(24, 7);
        let ah = a.adjoint();
        assert!(a.rel_err(&ah) < 1e-14);
        // Diagonal dominance by construction ⇒ positive diagonal.
        for i in 0..24 {
            assert!(a[(i, i)].re > 0.0);
            assert_eq!(a[(i, i)].im, 0.0);
        }
    }

    #[test]
    fn spd_random_cond_has_prescribed_spectrum() {
        // trace(QDQᴴ) = trace(D) exactly; Hermitian with real diagonal.
        let cond = 1e4;
        let n = 16;
        let a = Matrix::<c64>::spd_random_cond(n, 11, cond);
        assert!(a.rel_err(&a.adjoint()) < 1e-14);
        let want: f64 = (0..n)
            .map(|i| cond.powf(1.0 - i as f64 / (n - 1) as f64))
            .sum();
        let got: f64 = (0..n).map(|i| a[(i, i)].re).sum();
        assert!((got - want).abs() / want < 1e-12, "trace {got} vs {want}");
        // cond = 1 collapses to the identity.
        let i4 = Matrix::<f64>::spd_random_cond(4, 3, 1.0);
        assert!(i4.rel_err(&Matrix::<f64>::eye(4)) < 1e-14);
    }

    #[test]
    fn gemm_hn_matches_explicit_adjoint() {
        let a = Matrix::<c64>::random(5, 4, 1);
        let b = Matrix::<c64>::random(5, 3, 2);
        let mut c1 = Matrix::<c64>::zeros(4, 3);
        gemm_hn_acc(&mut c1, &a, &b, c64::new(1.0, 0.0));
        let c2 = a.adjoint().matmul(&b);
        assert!(c1.rel_err(&c2) < 1e-14);
    }

    #[test]
    fn gemv_matches_matmul() {
        let a = Matrix::<f64>::random(6, 4, 3);
        let x = Matrix::<f64>::random(4, 1, 4);
        let mut y = vec![0.0; 6];
        gemv_acc(&mut y, &a, x.col(0), 1.0);
        let c = a.matmul(&x);
        for i in 0..6 {
            assert!((y[i] - c[(i, 0)]).abs() < 1e-14);
        }
    }

    #[test]
    fn submatrix_roundtrip() {
        let a = Matrix::<f64>::random(8, 8, 5);
        let sub = a.submatrix(2, 3, 4, 5);
        let mut b = Matrix::<f64>::zeros(8, 8);
        b.set_submatrix(2, 3, &sub);
        assert_eq!(b[(2, 3)], a[(2, 3)]);
        assert_eq!(b[(5, 7)], a[(5, 7)]);
        assert_eq!(b[(0, 0)], 0.0);
    }

    #[test]
    fn spd_diag_matches_paper() {
        let a = Matrix::<f32>::spd_diag(4);
        for i in 0..4 {
            assert_eq!(a[(i, i)], (i + 1) as f32);
        }
        assert_eq!(a[(0, 1)], 0.0);
    }

    #[test]
    fn norms() {
        let a = Matrix::<f64>::from_vec(1, 2, vec![3.0, 4.0]);
        assert!((a.norm_fro() - 5.0).abs() < 1e-15);
        assert_eq!(a.norm_max(), 4.0);
    }

    #[test]
    fn tril_zeroes_upper() {
        let mut a = Matrix::<f64>::ones(3, 3);
        a.tril_in_place();
        assert_eq!(a[(0, 1)], 0.0);
        assert_eq!(a[(0, 2)], 0.0);
        assert_eq!(a[(1, 2)], 0.0);
        assert_eq!(a[(1, 0)], 1.0);
        assert_eq!(a[(2, 2)], 1.0);
    }
}
