//! PJRT runtime: loads the AOT-compiled HLO artifacts produced by the
//! Python compile path (`python/compile/aot.py`) and executes them from
//! the Rust hot path. Python never runs at solve time.
//!
//! Interchange format is **HLO text**, not serialized protos: jax ≥ 0.5
//! emits 64-bit instruction ids that the crate's xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see /opt/xla-example).
//!
//! Artifacts are named `<op>_<dtype>_<T>.hlo.txt` with `dtype ∈ {f32,
//! f64}` — complex kernels take split real/imag planes (`c<op>_...`),
//! because the crate's `Literal` API only exposes real element types.
//! [`XlaKernels`] adapts the fixed `T×T` executables to the arbitrary
//! tile shapes the solvers produce by chunking and zero/identity
//! padding — the same shape-specialization discipline a real XLA AOT
//! deployment lives with.
//!
//! ## Thread safety
//!
//! The `xla` crate's wrappers are `Rc`-based and not `Send`/`Sync`, but
//! the underlying PJRT CPU client is thread-safe. We keep every XLA
//! object inside one mutex-guarded state and never let one escape, so
//! the (documented) `unsafe impl Send/Sync` below is sound: all
//! refcount traffic and C-API calls are serialized by the lock.

mod xla_kernels;

pub use xla_kernels::XlaKernels;

use crate::error::{Error, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

struct XlaState {
    client: xla::PjRtClient,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

/// A PJRT CPU client + executable cache keyed by artifact name.
pub struct PjRtRuntime {
    state: Mutex<XlaState>,
    dir: PathBuf,
}

// Safety: see module docs — all access to the non-Send XLA wrappers is
// serialized behind `state`; no wrapper object ever leaves the lock.
unsafe impl Send for PjRtRuntime {}
unsafe impl Sync for PjRtRuntime {}

impl PjRtRuntime {
    /// Create a runtime reading artifacts from `dir`.
    pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
        let client = xla::PjRtClient::cpu()?;
        Ok(PjRtRuntime {
            state: Mutex::new(XlaState { client, cache: HashMap::new() }),
            dir: dir.as_ref().to_path_buf(),
        })
    }

    /// Default artifact directory: `$JAXMG_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("JAXMG_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    /// Artifact directory in use.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.state.lock().unwrap().client.platform_name()
    }

    /// True if the artifact file for `name` exists.
    pub fn has_artifact(&self, name: &str) -> bool {
        self.dir.join(format!("{name}.hlo.txt")).exists()
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        self.state.lock().unwrap().cache.len()
    }

    /// Pre-compile an artifact into the cache (fails fast on a missing
    /// or unparsable artifact).
    pub fn warm(&self, name: &str) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        self.ensure_loaded(&mut st, name)?;
        Ok(())
    }

    fn ensure_loaded<'a>(
        &self,
        st: &'a mut XlaState,
        name: &str,
    ) -> Result<&'a xla::PjRtLoadedExecutable> {
        if !st.cache.contains_key(name) {
            let path = self.dir.join(format!("{name}.hlo.txt"));
            if !path.exists() {
                return Err(Error::runtime(format!(
                    "missing AOT artifact {path:?} — run `make artifacts` first"
                )));
            }
            let proto = xla::HloModuleProto::from_text_file(&path)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = st.client.compile(&comp)?;
            st.cache.insert(name.to_string(), exe);
        }
        Ok(st.cache.get(name).unwrap())
    }

    /// Execute the artifact `name` on real-typed input buffers, each
    /// given as (flat row-major data, dims; empty dims = scalar).
    /// Returns the flattened outputs of the result tuple.
    ///
    /// Compiles on first use, cached thereafter.
    pub fn execute<T: xla::NativeType + xla::ArrayElement>(
        &self,
        name: &str,
        inputs: &[(&[T], &[i64])],
    ) -> Result<Vec<Vec<T>>> {
        let mut st = self.state.lock().unwrap();
        // Build literals inside the lock (Literal is not Send either).
        // Shaped literals go through create_from_shape_and_untyped_data:
        // one copy instead of vec1 + reshape's two (§Perf RT-1).
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, dims) in inputs {
            let lit = if dims.is_empty() {
                xla::Literal::scalar(data[0])
            } else {
                let dims_usize: Vec<usize> = dims.iter().map(|&d| d as usize).collect();
                let bytes = unsafe {
                    std::slice::from_raw_parts(
                        data.as_ptr() as *const u8,
                        std::mem::size_of_val(*data),
                    )
                };
                xla::Literal::create_from_shape_and_untyped_data(T::TY, &dims_usize, bytes)?
            };
            literals.push(lit);
        }
        let exe = self.ensure_loaded(&mut st, name)?;
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True.
        let parts = result.to_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            out.push(p.to_vec::<T>()?);
        }
        Ok(out)
    }
}

impl std::fmt::Debug for PjRtRuntime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "PjRtRuntime(dir={:?}, cached={})", self.dir, self.cached())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_artifact_is_a_clear_error() {
        let rt = PjRtRuntime::new("/nonexistent-artifacts").unwrap();
        let err = rt.warm("potf2_f64_64").unwrap_err();
        assert!(format!("{err}").contains("make artifacts"));
    }

    #[test]
    fn platform_is_cpu() {
        let rt = PjRtRuntime::new("artifacts").unwrap();
        assert_eq!(rt.platform().to_lowercase(), "cpu");
        assert_eq!(rt.cached(), 0);
    }

    #[test]
    fn runtime_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<PjRtRuntime>();
    }
}
