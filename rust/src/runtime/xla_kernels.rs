//! [`TileKernels`] backend over the AOT-compiled XLA executables.
//!
//! Every executable is shape-specialized to `T×T` tiles (AOT has no
//! dynamic shapes), so this adapter chunks arbitrary solver tiles into
//! `T`-sized pieces and pads edges — zeros for GEMM operands, the
//! identity for triangular factors (so padded solves stay well-posed
//! and padded rows come out zero).
//!
//! Complex scalars cross the boundary as split real/imag planes
//! (`c<op>` artifacts take twice the inputs); the Python kernels
//! recombine them internally. See DESIGN.md §Complex dtypes.

use super::PjRtRuntime;
use crate::error::{Error, Result};
use crate::linalg::Matrix;
use crate::scalar::{RealScalar, Scalar};
use crate::solver::TileKernels;
use std::sync::Arc;

/// XLA-backed tile kernels for scalar type `S` at tile size `tile`.
pub struct XlaKernels<S: Scalar> {
    rt: Arc<PjRtRuntime>,
    tile: usize,
    _marker: std::marker::PhantomData<fn() -> S>,
}

/// All ops the solvers need; names match the artifact files.
const OPS: [&str; 7] =
    ["potf2", "trsm_rlhc", "trsm_llnn", "trsm_llhn", "gemm_nn", "gemm_nh", "gemm_hn"];

impl<S: Scalar> XlaKernels<S>
where
    S::Real: xla::NativeType + xla::ArrayElement,
{
    /// Real plane dtype token in artifact names.
    fn dtype_token() -> &'static str {
        match S::DTYPE.real_dtype() {
            crate::scalar::DType::F32 => "f32",
            _ => "f64",
        }
    }

    /// Artifact name for an op at this dtype/tile.
    fn artifact(&self, op: &str) -> String {
        let prefix = if S::DTYPE.is_complex() { "c" } else { "" };
        format!("{prefix}{op}_{}_{}", Self::dtype_token(), self.tile)
    }

    /// Create a backend, verifying all artifacts exist (compiles lazily).
    pub fn new(rt: Arc<PjRtRuntime>, tile: usize) -> Result<Self> {
        let k = XlaKernels { rt, tile, _marker: std::marker::PhantomData };
        for op in OPS {
            let name = k.artifact(op);
            if !k.rt.has_artifact(&name) {
                return Err(Error::runtime(format!(
                    "missing AOT artifact {name}.hlo.txt in {:?} — run `make artifacts`",
                    k.rt.dir()
                )));
            }
        }
        Ok(k)
    }

    /// The tile size the executables are specialized to.
    pub fn tile(&self) -> usize {
        self.tile
    }

    // ---- helpers -----------------------------------------------------

    /// Extract a padded `T×T` block from `m` at (r0, c0) as row-major
    /// real planes (all-re then all-im for complex). `diag_pad` puts
    /// ones on the padded diagonal (for triangular factors).
    fn pack(&self, m: &Matrix<S>, r0: usize, c0: usize, diag_pad: bool) -> Vec<S::Real> {
        let t = self.tile;
        let nr = m.rows().saturating_sub(r0).min(t);
        let nc = m.cols().saturating_sub(c0).min(t);
        let mut tilebuf = vec![S::zero(); t * t]; // row-major scalars
        for i in 0..t {
            for j in 0..t {
                let v = if i < nr && j < nc {
                    m[(r0 + i, c0 + j)]
                } else if diag_pad && i == j {
                    S::one()
                } else {
                    S::zero()
                };
                tilebuf[i * t + j] = v;
            }
        }
        let mut planes = vec![<S::Real as RealScalar>::rzero(); S::PLANES * t * t];
        S::split_planes(&tilebuf, &mut planes);
        planes
    }

    /// Write a row-major plane buffer back into `m` at (r0, c0),
    /// clipping padding.
    fn unpack(&self, planes: &[S::Real], m: &mut Matrix<S>, r0: usize, c0: usize) {
        let t = self.tile;
        let mut tilebuf = vec![S::zero(); t * t];
        S::merge_planes(planes, &mut tilebuf);
        let nr = m.rows().saturating_sub(r0).min(t);
        let nc = m.cols().saturating_sub(c0).min(t);
        for i in 0..nr {
            for j in 0..nc {
                m[(r0 + i, c0 + j)] = tilebuf[i * t + j];
            }
        }
    }

    /// Split a plane buffer into per-plane input slices with dims.
    fn plane_inputs<'a>(&self, buf: &'a [S::Real]) -> Vec<(&'a [S::Real], Vec<i64>)> {
        let t = self.tile as i64;
        let n = (self.tile * self.tile) as usize;
        (0..S::PLANES).map(|p| (&buf[p * n..(p + 1) * n], vec![t, t])).collect()
    }

    /// Run an artifact with tile-plane inputs plus an optional scalar α.
    fn run(
        &self,
        op: &str,
        tiles: &[&[S::Real]],
        alpha: Option<S>,
    ) -> Result<Vec<Vec<S::Real>>> {
        let mut inputs: Vec<(&[S::Real], Vec<i64>)> = Vec::new();
        for buf in tiles {
            for inp in self.plane_inputs(buf) {
                inputs.push(inp);
            }
        }
        let alpha_planes;
        if let Some(a) = alpha {
            alpha_planes = [a.re(), a.im()];
            inputs.push((&alpha_planes[0..1], vec![]));
            if S::PLANES == 2 {
                inputs.push((&alpha_planes[1..2], vec![]));
            }
        }
        let refs: Vec<(&[S::Real], &[i64])> =
            inputs.iter().map(|(d, dims)| (*d, dims.as_slice())).collect();
        self.rt.execute::<S::Real>(&self.artifact(op), &refs)
    }

    /// Merge multi-plane outputs back into one plane buffer per tile.
    fn merge_out(&self, out: Vec<Vec<S::Real>>) -> Vec<S::Real> {
        if S::PLANES == 1 {
            out.into_iter().next().unwrap()
        } else {
            let mut merged = out[0].clone();
            merged.extend_from_slice(&out[1]);
            merged
        }
    }

    /// Generic chunked GEMM-family driver: `C ← C + α·op_A(A)·op_B(B)`,
    /// where the artifact computes one `T×T×T` block step.
    fn gemm_chunked(
        &self,
        op: &str,
        c: &mut Matrix<S>,
        a: &Matrix<S>,
        b: &Matrix<S>,
        alpha: S,
        // (a_rows_indexed_by, a_cols_indexed_by): which of (i, l) picks
        // the row/col block of A for output block (i, j) at depth l.
        a_idx: fn(usize, usize) -> (usize, usize),
        b_idx: fn(usize, usize, usize) -> (usize, usize),
        kdim: usize,
    ) -> Result<()> {
        let t = self.tile;
        let mi = c.rows().div_ceil(t);
        let nj = c.cols().div_ceil(t);
        let kl = kdim.div_ceil(t);
        for bi in 0..mi {
            for bj in 0..nj {
                let mut acc = self.pack(c, bi * t, bj * t, false);
                for bl in 0..kl {
                    let (ar, ac) = a_idx(bi, bl);
                    let (br, bc) = b_idx(bi, bj, bl);
                    let at = self.pack(a, ar * t, ac * t, false);
                    let bt = self.pack(b, br * t, bc * t, false);
                    let out = self.run(op, &[&acc, &at, &bt], Some(alpha))?;
                    acc = self.merge_out(out);
                }
                self.unpack(&acc, c, bi * t, bj * t);
            }
        }
        Ok(())
    }
}

impl<S: Scalar> TileKernels<S> for XlaKernels<S>
where
    S::Real: xla::NativeType + xla::ArrayElement,
{
    fn potf2(&self, a: &Matrix<S>) -> Result<Matrix<S>> {
        let n = a.require_square()?;
        let t = self.tile;
        if n > t {
            // The solvers only potf2 single tiles; blocked potf2 of a
            // bigger block falls back to chunked right-looking steps.
            return Err(Error::runtime(format!(
                "potf2 artifact specialized to T={t}, got {n}x{n} block"
            )));
        }
        // Identity padding keeps the factorization well posed.
        let packed = self.pack(a, 0, 0, true);
        let out = self.run("potf2", &[&packed], None)?;
        let merged = self.merge_out(out);
        let mut l = Matrix::<S>::zeros(n, n);
        self.unpack(&merged, &mut l, 0, 0);
        // NaN from a non-PD pivot mirrors cuSOLVER's info > 0.
        for j in 0..n {
            let d = l[(j, j)].re().to_f64();
            if !d.is_finite() || d <= 0.0 {
                return Err(Error::NotPositiveDefinite { minor: j + 1 });
            }
        }
        l.tril_in_place();
        Ok(l)
    }

    fn trsm_rlhc(&self, b: &Matrix<S>, l: &Matrix<S>) -> Result<Matrix<S>> {
        // X = B·L⁻ᴴ, chunked over row blocks of B (each row block is an
        // independent T×T solve against the same factor tile).
        let t = self.tile;
        if l.rows() > t {
            return Err(Error::runtime(format!("trsm factor exceeds tile T={t}")));
        }
        let lt = self.pack(l, 0, 0, true);
        let mut x = Matrix::<S>::zeros(b.rows(), b.cols());
        for br in 0..b.rows().div_ceil(t) {
            let bt = self.pack(b, br * t, 0, false);
            let out = self.run("trsm_rlhc", &[&bt, &lt], None)?;
            let merged = self.merge_out(out);
            self.unpack(&merged, &mut x, br * t, 0);
        }
        Ok(x)
    }

    fn trsm_llnn(&self, l: &Matrix<S>, b: &Matrix<S>) -> Result<Matrix<S>> {
        let t = self.tile;
        if l.rows() > t {
            return Err(Error::runtime(format!("trsm factor exceeds tile T={t}")));
        }
        let lt = self.pack(l, 0, 0, true);
        let mut x = Matrix::<S>::zeros(b.rows(), b.cols());
        for bc in 0..b.cols().div_ceil(t) {
            let bt = self.pack(b, 0, bc * t, false);
            let out = self.run("trsm_llnn", &[&lt, &bt], None)?;
            let merged = self.merge_out(out);
            self.unpack(&merged, &mut x, 0, bc * t);
        }
        Ok(x)
    }

    fn trsm_llhn(&self, l: &Matrix<S>, b: &Matrix<S>) -> Result<Matrix<S>> {
        let t = self.tile;
        if l.rows() > t {
            return Err(Error::runtime(format!("trsm factor exceeds tile T={t}")));
        }
        let lt = self.pack(l, 0, 0, true);
        let mut x = Matrix::<S>::zeros(b.rows(), b.cols());
        for bc in 0..b.cols().div_ceil(t) {
            let bt = self.pack(b, 0, bc * t, false);
            let out = self.run("trsm_llhn", &[&lt, &bt], None)?;
            let merged = self.merge_out(out);
            self.unpack(&merged, &mut x, 0, bc * t);
        }
        Ok(x)
    }

    fn gemm_nn(&self, c: &mut Matrix<S>, a: &Matrix<S>, b: &Matrix<S>, alpha: S) -> Result<()> {
        let k = a.cols();
        self.gemm_chunked("gemm_nn", c, a, b, alpha, |i, l| (i, l), |_i, j, l| (l, j), k)
    }

    fn gemm_nh(&self, c: &mut Matrix<S>, a: &Matrix<S>, b: &Matrix<S>, alpha: S) -> Result<()> {
        // C += α·A·Bᴴ: depth over A's cols == B's cols; B block (j, l).
        let k = a.cols();
        self.gemm_chunked("gemm_nh", c, a, b, alpha, |i, l| (i, l), |_i, j, l| (j, l), k)
    }

    fn gemm_hn(&self, c: &mut Matrix<S>, a: &Matrix<S>, b: &Matrix<S>, alpha: S) -> Result<()> {
        // C += α·Aᴴ·B: depth over A's rows == B's rows; A block (l, i).
        let k = a.rows();
        self.gemm_chunked("gemm_hn", c, a, b, alpha, |i, l| (l, i), |_i, j, l| (l, j), k)
    }

    fn name(&self) -> &'static str {
        "xla-aot"
    }
}

#[cfg(test)]
mod tests {
    //! Cross-checks against NativeKernels live in `rust/tests/` (they
    //! need built artifacts); here we only test the packing helpers.
    use super::*;
    use crate::scalar::c64;

    fn dummy<S: Scalar>(tile: usize) -> XlaKernels<S>
    where
        S::Real: xla::NativeType + xla::ArrayElement,
    {
        XlaKernels {
            rt: Arc::new(PjRtRuntime::new("artifacts").unwrap()),
            tile,
            _marker: std::marker::PhantomData,
        }
    }

    #[test]
    fn pack_pads_identity() {
        let k = dummy::<f64>(4);
        let a = Matrix::<f64>::from_fn(2, 2, |i, j| (i * 2 + j + 1) as f64);
        let p = k.pack(&a, 0, 0, true);
        // Row-major 4x4: a00 a01 0 0 / a10 a11 0 0 / 0 0 1 0 / 0 0 0 1
        assert_eq!(p[0], 1.0);
        assert_eq!(p[1], 2.0);
        assert_eq!(p[4], 3.0);
        assert_eq!(p[5], 4.0);
        assert_eq!(p[10], 1.0);
        assert_eq!(p[15], 1.0);
        assert_eq!(p[2], 0.0);
    }

    #[test]
    fn pack_unpack_roundtrip_complex() {
        let k = dummy::<c64>(3);
        let a = Matrix::<c64>::random(3, 3, 5);
        let p = k.pack(&a, 0, 0, false);
        assert_eq!(p.len(), 2 * 9);
        let mut b = Matrix::<c64>::zeros(3, 3);
        k.unpack(&p, &mut b, 0, 0);
        assert_eq!(a, b);
    }

    #[test]
    fn artifact_names() {
        let k = dummy::<c64>(64);
        assert_eq!(k.artifact("gemm_nn"), "cgemm_nn_f64_64");
        let k2 = dummy::<f32>(128);
        assert_eq!(k2.artifact("potf2"), "potf2_f32_128");
    }
}
