//! jaxmg — the coordinator CLI.
//!
//! Subcommands (hand-rolled parser; the vendored crate set has no clap):
//!
//! ```text
//! jaxmg info                         PJRT platform + artifact inventory
//! jaxmg solve   [opts]               potrs:  A·x = b        (Fig. 3a workload)
//! jaxmg invert  [opts]               potri:  A⁻¹            (Fig. 3b workload)
//! jaxmg eigh    [opts]               syevd:  eigendecomposition (Fig. 3c)
//! jaxmg capacity [--vram-gb G]       largest-solvable-N table (paper §3)
//! jaxmg predict --routine R [opts]   analytic Fig. 3 curves at paper scale
//! jaxmg serve   [--jobs J]           request-loop demo over the job queue
//!
//! common opts: --n N --tile T --devices D --dtype f32|f64|c64|c128
//!              --mode spmd|mpmd --backend native|xla --rhs K --random
//! ```

use jaxmg::cli::Opts;
use jaxmg::coordinator::{BackendKind, ExecMode, JaxMg, JobQueue, Mesh};
use jaxmg::costmodel::Predictor;
use jaxmg::device::SimNode;
use jaxmg::linalg::{FrobNorm, Matrix};
use jaxmg::prelude::*;
use jaxmg::runtime::PjRtRuntime;
use jaxmg::scalar::DType;
use std::time::Instant;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    };
    std::process::exit(code);
}

fn context(o: &Opts) -> Result<JaxMg> {
    let ndev = o.usize("devices", 8)?;
    let vram_gb = o.usize("vram-gb", 4)?;
    let node = SimNode::new_uniform(ndev, vram_gb << 30);
    let mode = match o.str("mode", "spmd").as_str() {
        "spmd" => ExecMode::Spmd,
        "mpmd" => ExecMode::Mpmd,
        other => return Err(Error::config(format!("unknown --mode {other}"))),
    };
    let backend = match o.str("backend", "native").as_str() {
        "native" => BackendKind::Native,
        "xla" => BackendKind::Xla,
        other => return Err(Error::config(format!("unknown --backend {other}"))),
    };
    JaxMg::builder()
        .mesh(Mesh::new_1d(node, "x"))
        .tile_size(o.usize("tile", 64)?)
        .exec_mode(mode)
        .backend(backend)
        .build()
}

fn dtype_of(o: &Opts, default: &str) -> Result<DType> {
    DType::parse(&o.str("dtype", default))
        .ok_or_else(|| Error::config("--dtype must be f32|f64|c64|c128"))
}

/// Dispatch a closure per dtype (the CLI's runtime-dtype erasure).
macro_rules! with_dtype {
    ($dt:expr, $S:ident => $body:expr) => {
        match $dt {
            DType::F32 => {
                type $S = f32;
                $body
            }
            DType::F64 => {
                type $S = f64;
                $body
            }
            DType::C64 => {
                type $S = jaxmg::scalar::c32;
                $body
            }
            DType::C128 => {
                type $S = jaxmg::scalar::c64;
                $body
            }
        }
    };
}

fn workload<S: Scalar>(o: &Opts, n: usize) -> Matrix<S> {
    if o.flag("random") {
        Matrix::<S>::spd_random(n, o.get("seed").and_then(|s| s.parse().ok()).unwrap_or(42))
    } else {
        // The paper's benchmark matrix: A = diag(1..N).
        Matrix::<S>::spd_diag(n)
    }
}

fn report(ctx: &JaxMg, wall: f64, extra: &str) {
    let m = ctx.metrics();
    println!("  wall-clock (simulator): {wall:.3} s");
    println!("  projected (H200 model): {:.6} s", ctx.projected_time());
    println!(
        "  peer traffic: {:.2} MiB in {} copies | kernels: {} ({:.2} GF)",
        m.peer_bytes as f64 / (1 << 20) as f64,
        m.peer_copies,
        m.kernel_launches,
        m.flops as f64 / 1e9
    );
    if !extra.is_empty() {
        println!("  {extra}");
    }
}

fn run(args: &[String]) -> Result<()> {
    let (cmd, rest) = match args.split_first() {
        Some((c, r)) => (c.as_str(), r),
        None => {
            print_usage();
            return Ok(());
        }
    };
    let o = Opts::parse(rest)?;
    match cmd {
        "info" => info(&o),
        "solve" => solve(&o),
        "invert" => invert(&o),
        "eigh" => eigh(&o),
        "capacity" => capacity(&o),
        "predict" => predict(&o),
        "serve" => serve(&o),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => Err(Error::config(format!("unknown subcommand {other:?} (try `jaxmg help`)"))),
    }
}

fn print_usage() {
    println!(
        "jaxmg — multi-GPU dense linear solver coordinator (JAXMg reproduction)\n\n\
         usage: jaxmg <info|solve|invert|eigh|capacity|predict|serve> [--opt value ...]\n\n\
         common options: --n N --tile T --devices D --dtype f32|f64|c64|c128\n\
         \x20                --mode spmd|mpmd --backend native|xla --rhs K --random --vram-gb G"
    );
}

fn info(_o: &Opts) -> Result<()> {
    let rt = PjRtRuntime::new(PjRtRuntime::default_dir())?;
    println!("platform: {}", rt.platform());
    println!("artifacts dir: {:?}", rt.dir());
    let mut count = 0;
    if let Ok(entries) = std::fs::read_dir(rt.dir()) {
        for e in entries.flatten() {
            if e.path().extension().is_some_and(|x| x == "txt") {
                count += 1;
            }
        }
    }
    println!("artifacts present: {count}");
    Ok(())
}

fn solve(o: &Opts) -> Result<()> {
    let n = o.usize("n", 512)?;
    let nrhs = o.usize("rhs", 1)?;
    let dt = dtype_of(o, "f32")?;
    let ctx = context(o)?;
    println!(
        "potrs: n={n} nrhs={nrhs} dtype={dt} T_A={} devices={}",
        ctx.tile_size(),
        ctx.mesh().num_devices()
    );
    with_dtype!(dt, S => {
        let a = workload::<S>(o, n);
        let b = Matrix::<S>::ones(n, nrhs);
        let t0 = Instant::now();
        let x = ctx.potrs(&a, &b)?;
        let wall = t0.elapsed().as_secs_f64();
        let resid = a.matmul(&x).rel_err(&b);
        report(&ctx, wall, &format!("residual = {resid:.3e}"));
    });
    Ok(())
}

fn invert(o: &Opts) -> Result<()> {
    let n = o.usize("n", 256)?;
    let dt = dtype_of(o, "c128")?;
    let ctx = context(o)?;
    println!("potri: n={n} dtype={dt} T_A={} devices={}", ctx.tile_size(), ctx.mesh().num_devices());
    with_dtype!(dt, S => {
        let a = workload::<S>(o, n);
        let t0 = Instant::now();
        let inv = ctx.potri(&a)?;
        let wall = t0.elapsed().as_secs_f64();
        let resid = a.matmul(&inv).rel_err(&Matrix::eye(n));
        report(&ctx, wall, &format!("residual = {resid:.3e}"));
    });
    Ok(())
}

fn eigh(o: &Opts) -> Result<()> {
    let n = o.usize("n", 256)?;
    let dt = dtype_of(o, "f64")?;
    let ctx = context(o)?;
    println!("syevd: n={n} dtype={dt} T_A={} devices={}", ctx.tile_size(), ctx.mesh().num_devices());
    with_dtype!(dt, S => {
        let a = workload::<S>(o, n);
        let t0 = Instant::now();
        let (vals, vecs) = ctx.syevd(&a)?;
        let wall = t0.elapsed().as_secs_f64();
        let av = a.matmul(&vecs);
        let mut vl = vecs.clone();
        for j in 0..n {
            let lam = <S as Scalar>::from_real(vals[j]);
            for i in 0..n {
                let v = vl[(i, j)] * lam;
                vl[(i, j)] = v;
            }
        }
        let lo = jaxmg::scalar::RealScalar::to_f64(vals[0]);
        let hi = jaxmg::scalar::RealScalar::to_f64(vals[n - 1]);
        report(&ctx, wall, &format!(
            "spectrum [{lo:.4}, {hi:.4}]  residual = {:.3e}", av.rel_err(&vl)
        ));
    });
    Ok(())
}

fn capacity(o: &Opts) -> Result<()> {
    let vram_gb = o.usize("vram-gb", 143)?;
    let ndev = o.usize("devices", 8)?;
    let t = o.usize("tile", 1024)?;
    let vram = vram_gb * 1000 * 1000 * 1000;
    println!("largest solvable N  ({ndev} devices x {vram_gb} GB, T_A={t})");
    println!("{:<10} {:>10} {:>14} {:>14}", "routine", "dtype", "single-GPU", "jaxmg");
    for routine in ["potrs", "potri", "syevd"] {
        for dt in [DType::F32, DType::F64, DType::C64, DType::C128] {
            let p = Predictor::h200(ndev, dt);
            println!(
                "{:<10} {:>10} {:>14} {:>14}",
                routine,
                dt.name(),
                p.single_capacity(routine, vram),
                p.dist_capacity(routine, vram, ndev, t)
            );
        }
    }
    println!("\n(paper §3: potrs float32 reaches N = 524288 on 8x143 GB — >1 TB aggregate)");
    Ok(())
}

fn predict(o: &Opts) -> Result<()> {
    let routine = o.str("routine", "potrs");
    let ndev = o.usize("devices", 8)?;
    let dt = dtype_of(
        o,
        match routine.as_str() {
            "potri" => "c128",
            "syevd" => "f64",
            _ => "f32",
        },
    )?;
    let p = Predictor::h200(ndev, dt);
    let tiles = [128usize, 256, 512, 1024];
    println!("analytic Fig. 3 curve: {routine} {dt} on {ndev}xH200 (seconds)");
    print!("{:>9}", "N");
    for t in tiles {
        print!("  T={t:>5}");
    }
    println!("  single-GPU");
    let mut n = 2048usize;
    while n <= 262144 {
        print!("{n:>9}");
        for t in tiles {
            let v = match routine.as_str() {
                "potrs" => p.potrs(n, t, ndev, 1),
                "potri" => p.potri(n, t, ndev),
                "syevd" => p.syevd(n, t, ndev),
                other => return Err(Error::config(format!("unknown --routine {other}"))),
            };
            print!("  {v:>7.3}");
        }
        let single = match routine.as_str() {
            "potrs" => p.single_potrs(n, 1),
            "potri" => p.single_potri(n),
            _ => p.single_syevd(n),
        };
        println!("  {single:>9.3}");
        n *= 2;
    }
    Ok(())
}

fn serve(o: &Opts) -> Result<()> {
    let jobs = o.usize("jobs", 8)?;
    let n = o.usize("n", 128)?;
    let queue = JobQueue::new(o.usize("workers", 4)?);
    println!("request loop: {jobs} solve requests of n={n} over the job queue");
    let t0 = Instant::now();
    let handles: Vec<_> = (0..jobs)
        .map(|i| {
            let node = SimNode::new_uniform(4, 1 << 28);
            let ctx = JaxMg::builder().mesh(Mesh::new_1d(node, "x")).tile_size(16).build().unwrap();
            queue.submit(move || {
                let a = Matrix::<f64>::spd_random(n, 1000 + i as u64);
                let b = Matrix::<f64>::ones(n, 1);
                let x = ctx.potrs(&a, &b).unwrap();
                a.matmul(&x).rel_err(&b)
            })
        })
        .collect();
    for (i, h) in handles.into_iter().enumerate() {
        let r = h.wait();
        println!("  request {i}: residual {r:.3e}");
    }
    println!("served {jobs} requests in {:.3} s", t0.elapsed().as_secs_f64());
    Ok(())
}
