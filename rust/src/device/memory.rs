//! Simulated device memory: allocation table + VRAM accounting.
//!
//! Each allocation is a real host `Vec<u8>` addressed by an opaque id,
//! so data movement in the simulator is byte-accurate. Capacity is
//! charged per allocation and over-subscription fails exactly like
//! `cudaMalloc` returning `cudaErrorMemoryAllocation` — this is what
//! makes the paper's "largest solvable N" tables reproducible.

use crate::error::{Error, Result};
use std::collections::HashMap;

/// Opaque device pointer: (device ordinal, allocation id, byte offset).
///
/// Mirrors a raw CUDA device pointer in the ways that matter here: it
/// is meaningless outside the owning node, it can be offset, and it can
/// be smuggled across "process" boundaries only via `crate::ipc`.
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
pub struct DevPtr {
    pub device: usize,
    pub alloc_id: u64,
    pub offset: usize,
}

impl DevPtr {
    /// A pointer `bytes` further into the same allocation.
    pub fn add(self, bytes: usize) -> DevPtr {
        DevPtr { offset: self.offset + bytes, ..self }
    }
}

/// Usage summary for one device.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct MemoryReport {
    pub capacity: usize,
    pub used: usize,
    pub allocations: usize,
    pub peak_used: usize,
}

/// Allocation table for a single simulated device.
#[derive(Debug)]
pub struct DeviceMemory {
    capacity: usize,
    used: usize,
    peak_used: usize,
    next_id: u64,
    allocs: HashMap<u64, Vec<u8>>,
}

impl DeviceMemory {
    /// Device memory with `capacity` bytes of VRAM.
    pub fn new(capacity: usize) -> Self {
        DeviceMemory { capacity, used: 0, peak_used: 0, next_id: 1, allocs: HashMap::new() }
    }

    /// Allocate `bytes`; fails with OOM when capacity would be exceeded.
    pub fn alloc(&mut self, device: usize, bytes: usize) -> Result<DevPtr> {
        if self.used + bytes > self.capacity {
            return Err(Error::DeviceOom {
                device,
                requested: bytes,
                free: self.capacity - self.used,
                capacity: self.capacity,
            });
        }
        let id = self.next_id;
        self.next_id += 1;
        self.allocs.insert(id, vec![0u8; bytes]);
        self.used += bytes;
        self.peak_used = self.peak_used.max(self.used);
        Ok(DevPtr { device, alloc_id: id, offset: 0 })
    }

    /// Free an allocation (must address its base or any offset into it).
    pub fn free(&mut self, ptr: DevPtr) -> Result<()> {
        match self.allocs.remove(&ptr.alloc_id) {
            Some(buf) => {
                self.used -= buf.len();
                Ok(())
            }
            None => Err(Error::InvalidPointer { device: ptr.device, alloc_id: ptr.alloc_id }),
        }
    }

    /// Size in bytes of the allocation behind `ptr`.
    pub fn size_of(&self, ptr: DevPtr) -> Result<usize> {
        self.allocs
            .get(&ptr.alloc_id)
            .map(|b| b.len())
            .ok_or(Error::InvalidPointer { device: ptr.device, alloc_id: ptr.alloc_id })
    }

    fn buf(&self, ptr: DevPtr) -> Result<&Vec<u8>> {
        self.allocs.get(&ptr.alloc_id).ok_or(Error::InvalidPointer { device: ptr.device, alloc_id: ptr.alloc_id })
    }

    fn buf_mut(&mut self, ptr: DevPtr) -> Result<&mut Vec<u8>> {
        self.allocs
            .get_mut(&ptr.alloc_id)
            .ok_or(Error::InvalidPointer { device: ptr.device, alloc_id: ptr.alloc_id })
    }

    /// Write raw bytes at `ptr.offset + extra_off`.
    pub fn write_bytes(&mut self, ptr: DevPtr, extra_off: usize, src: &[u8]) -> Result<()> {
        let base = ptr.offset + extra_off;
        let buf = self.buf_mut(ptr)?;
        if base + src.len() > buf.len() {
            return Err(Error::OutOfBounds { offset: base, len: src.len(), size: buf.len() });
        }
        buf[base..base + src.len()].copy_from_slice(src);
        Ok(())
    }

    /// Read raw bytes from `ptr.offset + extra_off`.
    pub fn read_bytes(&self, ptr: DevPtr, extra_off: usize, dst: &mut [u8]) -> Result<()> {
        let base = ptr.offset + extra_off;
        let buf = self.buf(ptr)?;
        if base + dst.len() > buf.len() {
            return Err(Error::OutOfBounds { offset: base, len: dst.len(), size: buf.len() });
        }
        dst.copy_from_slice(&buf[base..base + dst.len()]);
        Ok(())
    }

    /// Copy bytes between two allocations on *this* device
    /// (or within one allocation; ranges must not overlap).
    pub fn copy_within_device(
        &mut self,
        src: DevPtr,
        src_off: usize,
        dst: DevPtr,
        dst_off: usize,
        len: usize,
    ) -> Result<()> {
        if src.alloc_id == dst.alloc_id {
            let s = src.offset + src_off;
            let d = dst.offset + dst_off;
            let buf = self.buf_mut(src)?;
            if s + len > buf.len() || d + len > buf.len() {
                return Err(Error::OutOfBounds { offset: s.max(d), len, size: buf.len() });
            }
            assert!(s + len <= d || d + len <= s, "overlapping same-alloc copy");
            buf.copy_within(s..s + len, d);
            return Ok(());
        }
        // Split-borrow via temporary take; cheap because Vec move.
        let src_base = src.offset + src_off;
        let mut sbuf = match self.allocs.remove(&src.alloc_id) {
            Some(b) => b,
            None => return Err(Error::InvalidPointer { device: src.device, alloc_id: src.alloc_id }),
        };
        let res = (|| {
            if src_base + len > sbuf.len() {
                return Err(Error::OutOfBounds { offset: src_base, len, size: sbuf.len() });
            }
            let dbuf = self.buf_mut(dst)?;
            let dst_base = dst.offset + dst_off;
            if dst_base + len > dbuf.len() {
                return Err(Error::OutOfBounds { offset: dst_base, len, size: dbuf.len() });
            }
            dbuf[dst_base..dst_base + len].copy_from_slice(&sbuf[src_base..src_base + len]);
            Ok(())
        })();
        self.allocs.insert(src.alloc_id, std::mem::take(&mut sbuf));
        res
    }

    /// Copy bytes from an allocation on this device into an allocation
    /// on `other` (a different device's table) without host staging —
    /// the simulator's peer-DMA fast path.
    pub fn copy_into(
        &self,
        src: DevPtr,
        src_off: usize,
        other: &mut DeviceMemory,
        dst: DevPtr,
        dst_off: usize,
        len: usize,
    ) -> Result<()> {
        let sbuf = self.buf(src)?;
        let src_base = src.offset + src_off;
        if src_base + len > sbuf.len() {
            return Err(Error::OutOfBounds { offset: src_base, len, size: sbuf.len() });
        }
        let dbuf = other.buf_mut(dst)?;
        let dst_base = dst.offset + dst_off;
        if dst_base + len > dbuf.len() {
            return Err(Error::OutOfBounds { offset: dst_base, len, size: dbuf.len() });
        }
        dbuf[dst_base..dst_base + len].copy_from_slice(&sbuf[src_base..src_base + len]);
        Ok(())
    }

    /// Usage report.
    pub fn report(&self) -> MemoryReport {
        MemoryReport {
            capacity: self.capacity,
            used: self.used,
            allocations: self.allocs.len(),
            peak_used: self.peak_used,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_tracks_usage_and_peak() {
        let mut m = DeviceMemory::new(100);
        let a = m.alloc(0, 60).unwrap();
        assert_eq!(m.report().used, 60);
        m.free(a).unwrap();
        let _b = m.alloc(0, 40).unwrap();
        let r = m.report();
        assert_eq!(r.used, 40);
        assert_eq!(r.peak_used, 60);
        assert_eq!(r.allocations, 1);
    }

    #[test]
    fn oob_write_rejected() {
        let mut m = DeviceMemory::new(100);
        let a = m.alloc(0, 8).unwrap();
        assert!(m.write_bytes(a, 4, &[0u8; 8]).is_err());
        assert!(m.write_bytes(a, 0, &[0u8; 8]).is_ok());
    }

    #[test]
    fn stale_pointer_rejected() {
        let mut m = DeviceMemory::new(100);
        let a = m.alloc(0, 8).unwrap();
        m.free(a).unwrap();
        let mut buf = [0u8; 4];
        assert!(matches!(m.read_bytes(a, 0, &mut buf), Err(Error::InvalidPointer { .. })));
    }

    #[test]
    fn copy_within_device_cross_alloc() {
        let mut m = DeviceMemory::new(100);
        let a = m.alloc(0, 8).unwrap();
        let b = m.alloc(0, 8).unwrap();
        m.write_bytes(a, 0, &[1, 2, 3, 4, 5, 6, 7, 8]).unwrap();
        m.copy_within_device(a, 2, b, 0, 4).unwrap();
        let mut out = [0u8; 8];
        m.read_bytes(b, 0, &mut out).unwrap();
        assert_eq!(out, [3, 4, 5, 6, 0, 0, 0, 0]);
    }

    #[test]
    fn copy_same_alloc_disjoint() {
        let mut m = DeviceMemory::new(100);
        let a = m.alloc(0, 8).unwrap();
        m.write_bytes(a, 0, &[9, 8, 7, 6, 0, 0, 0, 0]).unwrap();
        m.copy_within_device(a, 0, a, 4, 4).unwrap();
        let mut out = [0u8; 8];
        m.read_bytes(a, 0, &mut out).unwrap();
        assert_eq!(out, [9, 8, 7, 6, 9, 8, 7, 6]);
    }

    #[test]
    fn devptr_add_offsets() {
        let p = DevPtr { device: 1, alloc_id: 7, offset: 16 };
        let q = p.add(8);
        assert_eq!(q.offset, 24);
        assert_eq!(q.alloc_id, 7);
    }
}
