//! Simulated multi-GPU node.
//!
//! The paper runs on a single node with 8 NVIDIA H200 GPUs connected by
//! NVLink. This environment has no GPUs, so we substitute a *simulated*
//! node that preserves the behaviours the system exercises (see
//! DESIGN.md §Hardware substitution):
//!
//! * **VRAM accounting** — every allocation is charged against the
//!   device's capacity and fails with [`crate::Error::DeviceOom`] when
//!   exceeded, so "largest solvable N" limits reproduce.
//! * **Device pointers** — allocations are addressed by opaque
//!   [`DevPtr`]s; honouring them across simulated address spaces is the
//!   job of `crate::ipc`, exactly as `cudaIpc` is in the real system.
//! * **Peer-to-peer copies** — `peer_copy_async` is the
//!   `cudaMemcpyPeerAsync` analogue: byte-accurate data movement plus a
//!   simulated-time charge from the NVLink cost model.
//! * **Streams/events** — per-device ordered timelines over a
//!   [`SimClock`], giving the projected wall-clock that the benchmark
//!   harness reports next to real (CPU) wall-clock.

mod clock;
mod memory;
mod peer;
mod stream;
mod topology;

pub use clock::SimClock;
pub use memory::{DevPtr, DeviceMemory, MemoryReport};
pub use peer::PeerCopyEngine;
pub use stream::{Event, Stream};
pub use topology::{LinkKind, NodeTopology};

use crate::error::{Error, Result};
use crate::metrics::Metrics;
use crate::obs::Tracer;
use crate::scalar::Scalar;
use std::sync::{Arc, Mutex};

/// One simulated GPU: VRAM + a timeline.
#[derive(Debug)]
pub struct SimGpu {
    id: usize,
    mem: Mutex<DeviceMemory>,
    clock: SimClock,
}

impl SimGpu {
    fn new(id: usize, capacity: usize) -> Self {
        SimGpu { id, mem: Mutex::new(DeviceMemory::new(capacity)), clock: SimClock::new() }
    }

    /// Device ordinal within the node.
    pub fn id(&self) -> usize {
        self.id
    }

    /// This device's simulated timeline.
    pub fn clock(&self) -> &SimClock {
        &self.clock
    }

    /// VRAM usage report.
    pub fn memory_report(&self) -> MemoryReport {
        self.mem.lock().unwrap().report()
    }
}

/// A simulated multi-GPU node — the substrate everything else runs on.
///
/// Cheap to clone (`Arc` inside); all methods take `&self` and are
/// thread-safe so SPMD worker threads can drive their own devices.
#[derive(Clone, Debug)]
pub struct SimNode {
    inner: Arc<NodeInner>,
}

#[derive(Debug)]
struct NodeInner {
    /// `Arc` so a [`SimNode::subset`] view can share the *same* devices
    /// (VRAM tables, clocks) as its parent node.
    gpus: Vec<Arc<SimGpu>>,
    topology: NodeTopology,
    metrics: Arc<Metrics>,
    /// Request-scoped tracing sink (`crate::obs`); disabled by default
    /// and purely passive — it never advances a simulated clock.
    tracer: Arc<Tracer>,
}

impl SimNode {
    /// A node of `n` identical devices with `vram_bytes` capacity each,
    /// wired all-to-all with NVLink-class links (the paper's testbed
    /// shape: 8 × H200 over NVLink).
    pub fn new_uniform(n: usize, vram_bytes: usize) -> Self {
        Self::with_topology(n, vram_bytes, NodeTopology::nvlink_all_to_all(n))
    }

    /// The paper's testbed at full scale: 8 devices × 143 GB.
    pub fn h200_node() -> Self {
        Self::new_uniform(8, 143 * 1000 * 1000 * 1000)
    }

    /// A node with an explicit link topology (e.g. PCIe fallback links).
    pub fn with_topology(n: usize, vram_bytes: usize, topology: NodeTopology) -> Self {
        assert!(n > 0, "node needs at least one device");
        assert_eq!(topology.num_devices(), n, "topology size mismatch");
        let gpus = (0..n).map(|i| Arc::new(SimGpu::new(i, vram_bytes))).collect();
        SimNode {
            inner: Arc::new(NodeInner {
                gpus,
                topology,
                metrics: Arc::new(Metrics::new()),
                tracer: Arc::new(Tracer::new()),
            }),
        }
    }

    /// A node view over a subset of this node's devices, **sharing**
    /// their VRAM tables, clocks, and metrics sink: allocations made
    /// through the view land on (and are accounted against) the same
    /// physical devices. Device `i` of the view is `devices[i]` of the
    /// parent; [`DevPtr`]s are view-relative, so a pointer must be used
    /// with the node it was allocated through. This is the MPMD serve
    /// layer's degraded-mode substrate: after a worker dies, re-queued
    /// solves run on a subset view that excludes its device.
    pub fn subset(&self, devices: &[usize]) -> Result<SimNode> {
        if devices.is_empty() {
            return Err(Error::config("a node subset needs at least one device"));
        }
        let mut gpus = Vec::with_capacity(devices.len());
        for &d in devices {
            let gpu = self
                .inner
                .gpus
                .get(d)
                .ok_or(Error::InvalidDevice { device: d, count: self.num_devices() })?;
            gpus.push(gpu.clone());
        }
        let topology = self.inner.topology.subset(devices)?;
        Ok(SimNode {
            inner: Arc::new(NodeInner {
                gpus,
                topology,
                metrics: self.inner.metrics.clone(),
                tracer: self.inner.tracer.clone(),
            }),
        })
    }

    /// Number of devices on the node.
    pub fn num_devices(&self) -> usize {
        self.inner.gpus.len()
    }

    /// Borrow a device.
    pub fn device(&self, i: usize) -> Result<&SimGpu> {
        self.inner
            .gpus
            .get(i)
            .map(|g| &**g)
            .ok_or(Error::InvalidDevice { device: i, count: self.num_devices() })
    }

    /// Whether `ptr` still addresses a live allocation on this node —
    /// the liveness check behind the IPC registry's stale-handle
    /// rejection (`crate::ipc`): a freed export must not be re-openable.
    pub fn ptr_exists(&self, ptr: DevPtr) -> bool {
        match self.device(ptr.device) {
            Ok(gpu) => gpu.mem.lock().unwrap().size_of(ptr).is_ok(),
            Err(_) => false,
        }
    }

    /// The node's link topology.
    pub fn topology(&self) -> &NodeTopology {
        &self.inner.topology
    }

    /// Shared metrics sink.
    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.inner.metrics
    }

    /// Shared tracing sink — subset views trace into their parent's
    /// tracer, so degraded-mode retries land in the same trace store.
    pub fn tracer(&self) -> &Arc<Tracer> {
        &self.inner.tracer
    }

    /// Allocate `bytes` on device `dev`.
    pub fn alloc(&self, dev: usize, bytes: usize) -> Result<DevPtr> {
        let gpu = self.device(dev)?;
        let ptr = gpu.mem.lock().unwrap().alloc(dev, bytes)?;
        self.inner.metrics.allocs.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        Ok(ptr)
    }

    /// Allocate space for `len` scalars of type `S` on device `dev`.
    pub fn alloc_scalars<S: Scalar>(&self, dev: usize, len: usize) -> Result<DevPtr> {
        self.alloc(dev, len * std::mem::size_of::<S>())
    }

    /// Free an allocation.
    pub fn free(&self, ptr: DevPtr) -> Result<()> {
        let gpu = self.device(ptr.device)?;
        gpu.mem.lock().unwrap().free(ptr)?;
        self.inner.metrics.frees.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        Ok(())
    }

    /// Host→device write of typed scalars at `ptr + offset_elems`.
    ///
    /// No simulated-time charge: in the simulator host staging is also
    /// how "on-device" kernels touch data, which the real system does
    /// without PCIe traffic. True H2D cost is charged explicitly by
    /// `DistMatrix::scatter`/`gather` (the `jax.device_put` boundary).
    pub fn write_slice<S: Scalar>(&self, ptr: DevPtr, offset_elems: usize, src: &[S]) -> Result<()> {
        let gpu = self.device(ptr.device)?;
        let bytes = std::mem::size_of_val(src);
        gpu.mem.lock().unwrap().write_bytes(ptr, offset_elems * std::mem::size_of::<S>(), as_bytes(src))?;
        self.inner.metrics.add_h2d(bytes as u64);
        Ok(())
    }

    /// Device→host read of typed scalars from `ptr + offset_elems`.
    /// (See `write_slice` for why this carries no simulated-time charge.)
    pub fn read_slice<S: Scalar>(&self, ptr: DevPtr, offset_elems: usize, dst: &mut [S]) -> Result<()> {
        let gpu = self.device(ptr.device)?;
        let bytes = std::mem::size_of_val(dst);
        gpu.mem.lock().unwrap().read_bytes(ptr, offset_elems * std::mem::size_of::<S>(), as_bytes_mut(dst))?;
        self.inner.metrics.add_d2h(bytes as u64);
        Ok(())
    }

    /// Explicitly charge a device timeline with host↔device transfer
    /// time for `bytes` (used at the scatter/gather boundary).
    pub fn charge_h2d(&self, dev: usize, bytes: usize) -> Result<()> {
        let t = self.inner.topology.h2d_time(bytes);
        self.device(dev)?.clock().advance(t);
        Ok(())
    }

    /// Charge a device timeline with `seconds` of kernel time (the cost
    /// model computes the duration; the device clock owns the timeline).
    pub fn charge_kernel(&self, dev: usize, seconds: f64, flops: u64) -> Result<()> {
        self.device(dev)?.clock().advance(seconds);
        self.inner.metrics.add_kernel(flops);
        Ok(())
    }

    /// `cudaMemcpyPeerAsync` analogue: copy `len_bytes` from
    /// `src + src_off` (device i) to `dst + dst_off` (device j).
    /// Byte-accurate, and charges both device timelines with the link
    /// cost. Same-device copies are allowed (charged at local bandwidth).
    pub fn peer_copy(
        &self,
        src: DevPtr,
        src_off: usize,
        dst: DevPtr,
        dst_off: usize,
        len_bytes: usize,
    ) -> Result<()> {
        PeerCopyEngine::copy(self, src, src_off, dst, dst_off, len_bytes)
    }

    /// Peer copy without a clock charge (metrics still count). The
    /// pipelined solver schedule moves bytes through this and charges
    /// the transfer time to a dedicated copy [`Stream`] so the device
    /// clock only advances when the timeline is finalized.
    pub fn peer_copy_untimed(
        &self,
        src: DevPtr,
        src_off: usize,
        dst: DevPtr,
        dst_off: usize,
        len_bytes: usize,
    ) -> Result<()> {
        PeerCopyEngine::copy_untimed(self, src, src_off, dst, dst_off, len_bytes)
    }

    /// Simulated global time: the max over device timelines (a barrier
    /// "now"). This is what the projected-time column of the benchmark
    /// tables reads.
    pub fn sim_time(&self) -> f64 {
        self.inner.gpus.iter().map(|g| g.clock.now()).fold(0.0, f64::max)
    }

    /// [`SimNode::sim_time`] on the exact integer-ns timeline. The serve
    /// layers' coalescer clocks read this: no float round-trip, so the
    /// value can never regress under accumulated rounding.
    pub fn sim_time_ns(&self) -> u64 {
        self.inner.gpus.iter().map(|g| g.clock.now_ns()).max().unwrap_or(0)
    }

    /// Synchronize **all** device timelines forward to at least
    /// `target_ns`. The open-loop traffic driver uses this to pace
    /// arrivals: a request arriving at t advances the idle fleet to t so
    /// cost-model queue waits are measured from the arrival instant.
    pub fn sync_clocks_to_ns(&self, target_ns: u64) {
        for g in &self.inner.gpus {
            g.clock.sync_to_ns(target_ns);
        }
    }

    /// Reset all device timelines and metrics (between bench reps).
    pub fn reset_accounting(&self) {
        for g in &self.inner.gpus {
            g.clock.reset();
        }
        self.inner.metrics.reset();
    }

    /// Total free VRAM per device.
    pub fn memory_reports(&self) -> Vec<MemoryReport> {
        self.inner.gpus.iter().map(|g| g.memory_report()).collect()
    }

    pub(crate) fn mem_of(&self, dev: usize) -> Result<std::sync::MutexGuard<'_, DeviceMemory>> {
        Ok(self.device(dev)?.mem.lock().unwrap())
    }
}

/// Reinterpret a scalar slice as bytes (scalars are plain-old-data).
pub(crate) fn as_bytes<S: Scalar>(s: &[S]) -> &[u8] {
    // Safety: S is Copy + repr-compatible plain data; lifetime tied to input.
    unsafe { std::slice::from_raw_parts(s.as_ptr() as *const u8, std::mem::size_of_val(s)) }
}

/// Reinterpret a mutable scalar slice as bytes.
pub(crate) fn as_bytes_mut<S: Scalar>(s: &mut [S]) -> &mut [u8] {
    // Safety: as above; all bit patterns of the backing floats are valid.
    unsafe { std::slice::from_raw_parts_mut(s.as_mut_ptr() as *mut u8, std::mem::size_of_val(s)) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scalar::c64;

    #[test]
    fn alloc_write_read_roundtrip() {
        let node = SimNode::new_uniform(2, 1 << 20);
        let ptr = node.alloc_scalars::<f64>(0, 16).unwrap();
        let src: Vec<f64> = (0..16).map(|i| i as f64).collect();
        node.write_slice(ptr, 0, &src).unwrap();
        let mut dst = vec![0.0f64; 16];
        node.read_slice(ptr, 0, &mut dst).unwrap();
        assert_eq!(src, dst);
    }

    #[test]
    fn oom_at_capacity() {
        let node = SimNode::new_uniform(1, 1024);
        let _a = node.alloc(0, 512).unwrap();
        let _b = node.alloc(0, 512).unwrap();
        match node.alloc(0, 1) {
            Err(Error::DeviceOom { device, .. }) => assert_eq!(device, 0),
            other => panic!("expected OOM, got {other:?}"),
        }
    }

    #[test]
    fn free_releases_capacity() {
        let node = SimNode::new_uniform(1, 1024);
        let a = node.alloc(0, 1024).unwrap();
        node.free(a).unwrap();
        let _b = node.alloc(0, 1024).unwrap();
        // Double free is an error.
        assert!(node.free(a).is_err());
    }

    #[test]
    fn peer_copy_moves_data_and_charges_clock() {
        let node = SimNode::new_uniform(2, 1 << 20);
        let a = node.alloc_scalars::<c64>(0, 8).unwrap();
        let b = node.alloc_scalars::<c64>(1, 8).unwrap();
        let src: Vec<c64> = (0..8).map(|i| c64::new(i as f64, -(i as f64))).collect();
        node.write_slice(a, 0, &src).unwrap();
        let t0 = node.sim_time();
        node.peer_copy(a, 0, b, 0, 8 * 16).unwrap();
        let mut dst = vec![c64::zero(); 8];
        node.read_slice(b, 0, &mut dst).unwrap();
        assert_eq!(src, dst);
        assert!(node.sim_time() > t0, "peer copy must advance simulated time");
        assert_eq!(node.metrics().snapshot().peer_bytes, 128);
    }

    #[test]
    fn offsets_respected() {
        let node = SimNode::new_uniform(2, 1 << 16);
        let a = node.alloc_scalars::<f32>(0, 8).unwrap();
        let b = node.alloc_scalars::<f32>(1, 8).unwrap();
        node.write_slice(a, 0, &[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]).unwrap();
        node.write_slice(b, 0, &[0.0f32; 8]).unwrap();
        // Copy elements 2..6 of a into positions 1..5 of b.
        node.peer_copy(a, 2 * 4, b, 1 * 4, 4 * 4).unwrap();
        let mut out = vec![0.0f32; 8];
        node.read_slice(b, 0, &mut out).unwrap();
        assert_eq!(out, vec![0.0, 3.0, 4.0, 5.0, 6.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn invalid_device_rejected() {
        let node = SimNode::new_uniform(2, 1024);
        assert!(matches!(node.alloc(5, 16), Err(Error::InvalidDevice { device: 5, count: 2 })));
    }

    #[test]
    fn reset_accounting_clears() {
        let node = SimNode::new_uniform(2, 1 << 16);
        let a = node.alloc_scalars::<f32>(0, 4).unwrap();
        let b = node.alloc_scalars::<f32>(1, 4).unwrap();
        node.write_slice(a, 0, &[1.0f32; 4]).unwrap();
        node.peer_copy(a, 0, b, 0, 16).unwrap();
        assert!(node.sim_time() > 0.0);
        node.reset_accounting();
        assert_eq!(node.sim_time(), 0.0);
        assert_eq!(node.metrics().snapshot().peer_bytes, 0);
    }

    #[test]
    fn subset_shares_devices_and_accounting() {
        let node = SimNode::new_uniform(4, 1024);
        let sub = node.subset(&[1, 3]).unwrap();
        assert_eq!(sub.num_devices(), 2);
        // Sub-device 0 is physical device 1: the parent sees the bytes.
        let p = sub.alloc(0, 256).unwrap();
        assert_eq!(node.memory_reports()[1].used, 256);
        assert!(sub.ptr_exists(p));
        // Clocks are shared too.
        sub.charge_kernel(1, 1e-3, 10).unwrap(); // physical device 3
        assert!(node.sim_time() >= 1e-3);
        sub.free(p).unwrap();
        assert_eq!(node.memory_reports()[1].used, 0);
        assert!(!sub.ptr_exists(p));
        // Metrics sink is the parent's, and so is the tracer.
        assert_eq!(node.metrics().snapshot().allocs, 1);
        assert!(Arc::ptr_eq(node.tracer(), sub.tracer()));
        // Invalid subsets are rejected.
        assert!(node.subset(&[]).is_err());
        assert!(node.subset(&[0, 7]).is_err());
    }

    #[test]
    fn h200_node_shape() {
        let node = SimNode::h200_node();
        assert_eq!(node.num_devices(), 8);
        let rep = node.memory_reports();
        assert_eq!(rep[0].capacity, 143 * 1000 * 1000 * 1000);
    }
}
