//! Peer-to-peer copy engine — the `cudaMemcpyPeerAsync` analogue.
//!
//! Data is moved byte-accurately between the two devices' allocation
//! tables; the link cost model charges both timelines (source reads,
//! destination writes, and the destination cannot observe the data
//! before the transfer completes on the source side).

use super::{DevPtr, SimNode};
use crate::error::Result;

/// Stateless engine; lives in its own module to keep the locking
/// discipline (ordered two-device lock) in one place.
pub struct PeerCopyEngine;

impl PeerCopyEngine {
    /// Copy `len` bytes from `src + src_off` to `dst + dst_off`,
    /// possibly across devices.
    pub fn copy(
        node: &SimNode,
        src: DevPtr,
        src_off: usize,
        dst: DevPtr,
        dst_off: usize,
        len: usize,
    ) -> Result<()> {
        if len == 0 {
            return Ok(());
        }
        Self::copy_untimed(node, src, src_off, dst, dst_off, len)?;
        let t = node.topology().copy_time(src.device, dst.device, len);
        if src.device == dst.device {
            // Device-local copy: no peer traffic, but still charged at
            // local (HBM) bandwidth.
            node.device(src.device)?.clock().advance(t);
        } else {
            // The transfer occupies the source link; the destination
            // can't see the bytes before the source-side completion.
            let src_clock = node.device(src.device)?.clock();
            src_clock.advance(t);
            node.device(dst.device)?.clock().sync_to(src_clock.now());
        }
        Ok(())
    }

    /// Data-plane-only copy: bytes move and the metrics count, but no
    /// simulated time is charged to either device clock. The lookahead
    /// scheduler uses this and charges the transfer to an explicit copy
    /// *stream* instead, so copies overlap compute on the timeline.
    pub fn copy_untimed(
        node: &SimNode,
        src: DevPtr,
        src_off: usize,
        dst: DevPtr,
        dst_off: usize,
        len: usize,
    ) -> Result<()> {
        if len == 0 {
            return Ok(());
        }
        if src.device == dst.device {
            let mut mem = node.mem_of(src.device)?;
            mem.copy_within_device(src, src_off, dst, dst_off, len)?;
            drop(mem);
            node.metrics().add_local(len as u64);
            return Ok(());
        }
        {
            let (first, second) = if src.device < dst.device {
                (src.device, dst.device)
            } else {
                (dst.device, src.device)
            };
            let mem_a = node.mem_of(first)?;
            let mem_b = node.mem_of(second)?;
            let (src_mem, mut dst_mem) =
                if src.device == first { (mem_a, mem_b) } else { (mem_b, mem_a) };
            src_mem.copy_into(src, src_off, &mut dst_mem, dst, dst_off, len)?;
        }
        node.metrics().add_peer(len as u64);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use crate::device::SimNode;

    #[test]
    fn zero_length_is_noop() {
        let node = SimNode::new_uniform(2, 1024);
        let a = node.alloc(0, 16).unwrap();
        let b = node.alloc(1, 16).unwrap();
        node.peer_copy(a, 0, b, 0, 0).unwrap();
        assert_eq!(node.metrics().snapshot().peer_copies, 0);
        assert_eq!(node.sim_time(), 0.0);
    }

    #[test]
    fn dest_clock_synced_past_source() {
        let node = SimNode::new_uniform(2, 1 << 20);
        let a = node.alloc(0, 1 << 16).unwrap();
        let b = node.alloc(1, 1 << 16).unwrap();
        node.peer_copy(a, 0, b, 0, 1 << 16).unwrap();
        let t0 = node.device(0).unwrap().clock().now();
        let t1 = node.device(1).unwrap().clock().now();
        assert!(t0 > 0.0);
        assert!(t1 >= t0, "destination must not observe data early");
    }

    #[test]
    fn local_copy_charges_local_not_peer() {
        let node = SimNode::new_uniform(1, 1 << 20);
        let a = node.alloc(0, 64).unwrap();
        let b = node.alloc(0, 64).unwrap();
        node.peer_copy(a, 0, b, 0, 64).unwrap();
        let s = node.metrics().snapshot();
        assert_eq!(s.peer_bytes, 0);
        assert_eq!(s.local_bytes, 64);
    }
}
