//! Node and fabric link topology and the two-tier transfer cost model.
//!
//! **Tier 1 — inside an island.** Encodes the paper's testbed: up to 8
//! GPUs fully connected over NVLink. Transfer times are
//! `latency + bytes / bandwidth` per link class.
//!
//! **Tier 2 — across islands.** A [`NodeTopology`] built with
//! [`NodeTopology::two_tier`] composes several NVLink islands over an
//! inter-node interconnect ([`LinkKind::InterNode`]) with its own
//! bandwidth/latency terms. The fabric link is a *shared pipe*: a
//! fan-out across it does not amortize the payload term the way an
//! NVLink switch does ([`NodeTopology::copy_time_shared`]), and
//! concurrent transfers into one endpoint share the link
//! ([`NodeTopology::contended_time`]).
//!
//! Numbers are H200/NVLink/NDR-class defaults; the cost model only
//! needs to preserve the *relative* structure
//! (HBM ≫ NVLink ≫ PCIe ≈ inter-node ≫ host link) for the benchmark
//! shapes to match the paper.
//!
//! ## Two-tier cost model
//!
//! | term                    | intra-island (NVLink)            | inter-island (fabric)                |
//! |-------------------------|----------------------------------|--------------------------------------|
//! | point-to-point          | `5 µs + B / 450 GB/s`            | `10 µs + B / 50 GB/s`                |
//! | fan-out to `f` peers    | `(5 µs + B / 450 GB/s) / f`      | `10 µs / f + B / 50 GB/s` (serial)   |
//! | `c`-way contended       | `5 µs + c·B / 450 GB/s`          | `10 µs + c·B / 50 GB/s`              |
//!
//! ## 1-node vs 2-node decision table
//!
//! The planner (`coordinator::plan_dist` via the fabric-aware
//! `Predictor::best_grid`) prices both placements per request; the
//! regimes it resolves to:
//!
//! | regime                            | placement  | why                                          |
//! |-----------------------------------|------------|----------------------------------------------|
//! | small N (ring latency dominates)  | 1 island   | every collective pays the fabric latency     |
//! | paper N (comm ≈ compute)          | 1 island   | N² fabric bytes eat the 2× compute win       |
//! | super-paper N (compute dominates) | 2 islands  | N³ flops split 2×, N² fabric bytes amortize  |
//! | per-island VRAM exceeded          | 2 islands  | capacity forces the spill across the fabric  |

/// Link classes between two endpoints.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum LinkKind {
    /// Same device (device-local bandwidth, e.g. HBM3e on H200).
    Local,
    /// NVLink peer connection.
    NvLink,
    /// PCIe fallback peer connection.
    Pcie,
    /// Inter-island fabric link (NIC-class: RDMA over the node
    /// interconnect). A shared pipe — see the module docs.
    InterNode,
}

/// All-pairs link map plus bandwidth/latency constants.
#[derive(Clone, Debug)]
pub struct NodeTopology {
    n: usize,
    /// links[i][j] — link class between devices i and j.
    links: Vec<Vec<LinkKind>>,
    /// island_of[d] — dense island ordinal of device d (all 0 on a
    /// flat single-island node).
    island_of: Vec<usize>,
    /// Effective bandwidths in bytes/second.
    pub local_bw: f64,
    pub nvlink_bw: f64,
    pub pcie_bw: f64,
    pub h2d_bw: f64,
    /// Inter-island fabric bandwidth, bytes/second.
    pub inter_bw: f64,
    /// Per-operation latencies in seconds.
    pub copy_latency: f64,
    /// Per-operation latency of an inter-island transfer, seconds.
    pub inter_latency: f64,
}

impl NodeTopology {
    /// Fully connected NVLink topology (the paper's 8×H200 node).
    pub fn nvlink_all_to_all(n: usize) -> Self {
        let links = (0..n)
            .map(|i| (0..n).map(|j| if i == j { LinkKind::Local } else { LinkKind::NvLink }).collect())
            .collect();
        NodeTopology {
            n,
            links,
            island_of: vec![0; n],
            // H200: ~4.8 TB/s HBM3e; NVLink4: ~450 GB/s effective per pair;
            // PCIe gen5 x16: ~50 GB/s; host link: ~55 GB/s;
            // inter-node fabric (NDR-class RDMA): ~50 GB/s, ~10 µs.
            local_bw: 4.8e12,
            nvlink_bw: 450e9,
            pcie_bw: 50e9,
            h2d_bw: 55e9,
            inter_bw: 50e9,
            copy_latency: 5e-6,
            inter_latency: 10e-6,
        }
    }

    /// PCIe-only topology (the no-NVLink ablation in the benches).
    pub fn pcie_all_to_all(n: usize) -> Self {
        let mut t = Self::nvlink_all_to_all(n);
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    t.links[i][j] = LinkKind::Pcie;
                }
            }
        }
        t
    }

    /// Two-tier fabric: `islands` NVLink islands of `per_island`
    /// devices each, joined by [`LinkKind::InterNode`] fabric links.
    /// Device `d` lives on island `d / per_island`; islands are
    /// contiguous device ranges. `islands == 1` produces the exact
    /// flat [`NodeTopology::nvlink_all_to_all`] link map, so a 1-island
    /// fabric is bitwise the single-node topology.
    pub fn two_tier(islands: usize, per_island: usize) -> Self {
        assert!(islands > 0 && per_island > 0, "fabric needs at least one device");
        let n = islands * per_island;
        let mut t = Self::nvlink_all_to_all(n);
        for i in 0..n {
            for j in 0..n {
                if i != j && i / per_island != j / per_island {
                    t.links[i][j] = LinkKind::InterNode;
                }
            }
            t.island_of[i] = i / per_island;
        }
        t
    }

    /// Number of devices covered by this topology.
    pub fn num_devices(&self) -> usize {
        self.n
    }

    /// Number of islands (1 on a flat node).
    pub fn num_islands(&self) -> usize {
        self.island_of.iter().copied().max().map_or(1, |m| m + 1)
    }

    /// Island ordinal of device `d`.
    pub fn island_of(&self, d: usize) -> usize {
        self.island_of[d]
    }

    /// Devices on island `i`, in device order.
    pub fn island_devices(&self, i: usize) -> Vec<usize> {
        (0..self.n).filter(|&d| self.island_of[d] == i).collect()
    }

    /// Link class between two devices.
    pub fn link(&self, i: usize, j: usize) -> LinkKind {
        self.links[i][j]
    }

    /// Bandwidth of the link between two devices, bytes/second.
    pub fn bandwidth(&self, i: usize, j: usize) -> f64 {
        match self.link(i, j) {
            LinkKind::Local => self.local_bw,
            LinkKind::NvLink => self.nvlink_bw,
            LinkKind::Pcie => self.pcie_bw,
            LinkKind::InterNode => self.inter_bw,
        }
    }

    /// Per-operation latency of the link between two devices, seconds.
    pub fn link_latency(&self, i: usize, j: usize) -> f64 {
        match self.link(i, j) {
            LinkKind::InterNode => self.inter_latency,
            _ => self.copy_latency,
        }
    }

    /// Modeled duration of a `bytes`-sized copy between two devices.
    pub fn copy_time(&self, i: usize, j: usize, bytes: usize) -> f64 {
        self.link_latency(i, j) + bytes as f64 / self.bandwidth(i, j)
    }

    /// Per-receiver cost of a `fanout`-way fan-out of `bytes` from `i`
    /// to `j`. Intra-island links amortize the full transfer across
    /// the fan-out (the NVLink switch serves receivers in parallel) —
    /// exactly `copy_time / fanout`, bitwise the flat-node arithmetic.
    /// The inter-island fabric is a shared pipe: only the latency
    /// amortizes, every receiver's payload is serialized.
    pub fn copy_time_shared(&self, i: usize, j: usize, bytes: usize, fanout: usize) -> f64 {
        self.ring_share_time(i, j, bytes, fanout, 1)
    }

    /// The per-receiver share of a ring collective: a `fanout`-way
    /// fan-out of `bytes` from `i` to `j` with `concurrent` transfers
    /// sharing the destination link. This is THE arithmetic both the
    /// simulator's collective charges and the `Predictor` replays call,
    /// so est == obs by construction. `fanout == 1, concurrent == 1`
    /// is bitwise [`NodeTopology::copy_time`]; intra-island links with
    /// `concurrent == 1` are bitwise the flat `copy_time / fanout`
    /// single-node arithmetic.
    pub fn ring_share_time(
        &self,
        i: usize,
        j: usize,
        bytes: usize,
        fanout: usize,
        concurrent: usize,
    ) -> f64 {
        let f = fanout.max(1) as f64;
        match self.link(i, j) {
            LinkKind::InterNode => {
                self.inter_latency / f
                    + bytes as f64 * concurrent.max(1) as f64 / self.inter_bw
            }
            _ => self.contended_time(i, j, bytes, concurrent) / f,
        }
    }

    /// Modeled duration of a `bytes`-sized copy when `concurrent`
    /// transfers share the `i → j` link (receiver-ingress sharing:
    /// the per-link concurrent-transfer term the grid selectors
    /// price). `concurrent == 1` is bitwise
    /// [`NodeTopology::copy_time`].
    pub fn contended_time(&self, i: usize, j: usize, bytes: usize, concurrent: usize) -> f64 {
        self.link_latency(i, j)
            + bytes as f64 * concurrent.max(1) as f64 / self.bandwidth(i, j)
    }

    /// Modeled duration of a host↔device transfer.
    pub fn h2d_time(&self, bytes: usize) -> f64 {
        self.copy_latency + bytes as f64 / self.h2d_bw
    }

    /// Structural fingerprint of this topology: FNV-1a over the link
    /// map, island assignment, and the bit patterns of every
    /// bandwidth/latency constant. Two topologies with equal signatures
    /// price every transfer identically, which is what the planner's
    /// replay memo keys on (`Predictor::best_grid` et al.) —
    /// [`NodeTopology`] deliberately carries no `Eq`/`Hash` (f64
    /// fields), so this is its hashable stand-in.
    pub fn signature(&self) -> u64 {
        const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const FNV_PRIME: u64 = 0x100_0000_01b3;
        let mut h = FNV_OFFSET;
        let mut eat = |v: u64| {
            for b in v.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(FNV_PRIME);
            }
        };
        eat(self.n as u64);
        for row in &self.links {
            for &l in row {
                eat(match l {
                    LinkKind::Local => 0,
                    LinkKind::NvLink => 1,
                    LinkKind::Pcie => 2,
                    LinkKind::InterNode => 3,
                });
            }
        }
        for &isl in &self.island_of {
            eat(isl as u64);
        }
        for v in [
            self.local_bw,
            self.nvlink_bw,
            self.pcie_bw,
            self.h2d_bw,
            self.inter_bw,
            self.copy_latency,
            self.inter_latency,
        ] {
            eat(v.to_bits());
        }
        h
    }

    /// Topology restricted to a device subset (the MPMD serve layer's
    /// degraded-mode view after a worker dies, and the fabric's
    /// per-island view): device `i` of the subset is `devices[i]`
    /// here, links and constants are inherited. Island ordinals are
    /// re-densified in order of first appearance, so a subset drawn
    /// from one island is a flat (1-island) topology and prices every
    /// collective with the exact single-node arithmetic.
    pub fn subset(&self, devices: &[usize]) -> crate::error::Result<Self> {
        for &d in devices {
            if d >= self.n {
                return Err(crate::error::Error::InvalidDevice { device: d, count: self.n });
            }
        }
        let links = devices
            .iter()
            .map(|&i| devices.iter().map(|&j| self.links[i][j]).collect())
            .collect();
        let mut dense: Vec<usize> = Vec::new();
        let island_of = devices
            .iter()
            .map(|&d| {
                let isl = self.island_of[d];
                match dense.iter().position(|&x| x == isl) {
                    Some(i) => i,
                    None => {
                        dense.push(isl);
                        dense.len() - 1
                    }
                }
            })
            .collect();
        Ok(NodeTopology {
            n: devices.len(),
            links,
            island_of,
            local_bw: self.local_bw,
            nvlink_bw: self.nvlink_bw,
            pcie_bw: self.pcie_bw,
            h2d_bw: self.h2d_bw,
            inter_bw: self.inter_bw,
            copy_latency: self.copy_latency,
            inter_latency: self.inter_latency,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_to_all_shape() {
        let t = NodeTopology::nvlink_all_to_all(4);
        assert_eq!(t.num_devices(), 4);
        assert_eq!(t.link(0, 0), LinkKind::Local);
        assert_eq!(t.link(0, 3), LinkKind::NvLink);
        assert_eq!(t.link(3, 0), LinkKind::NvLink);
        assert_eq!(t.num_islands(), 1);
        assert_eq!(t.island_devices(0), vec![0, 1, 2, 3]);
    }

    #[test]
    fn local_faster_than_peer() {
        let t = NodeTopology::nvlink_all_to_all(2);
        let local = t.copy_time(0, 0, 1 << 30);
        let peer = t.copy_time(0, 1, 1 << 30);
        assert!(local < peer);
    }

    #[test]
    fn nvlink_faster_than_pcie() {
        let nv = NodeTopology::nvlink_all_to_all(2);
        let pc = NodeTopology::pcie_all_to_all(2);
        assert!(nv.copy_time(0, 1, 1 << 30) < pc.copy_time(0, 1, 1 << 30));
    }

    #[test]
    fn latency_dominates_small_copies() {
        let t = NodeTopology::nvlink_all_to_all(2);
        let tiny = t.copy_time(0, 1, 8);
        assert!((tiny - t.copy_latency) / t.copy_latency < 0.01);
    }

    #[test]
    fn two_tier_links_and_islands() {
        let t = NodeTopology::two_tier(2, 4);
        assert_eq!(t.num_devices(), 8);
        assert_eq!(t.num_islands(), 2);
        assert_eq!(t.island_of(0), 0);
        assert_eq!(t.island_of(3), 0);
        assert_eq!(t.island_of(4), 1);
        assert_eq!(t.island_devices(1), vec![4, 5, 6, 7]);
        assert_eq!(t.link(0, 3), LinkKind::NvLink);
        assert_eq!(t.link(0, 4), LinkKind::InterNode);
        assert_eq!(t.link(4, 0), LinkKind::InterNode);
        assert_eq!(t.link(5, 5), LinkKind::Local);
        // The fabric link is strictly slower than NVLink.
        assert!(t.copy_time(0, 4, 1 << 30) > t.copy_time(0, 1, 1 << 30));
        assert!(t.link_latency(0, 4) > t.link_latency(0, 1));
    }

    #[test]
    fn one_island_fabric_is_bitwise_flat() {
        let fab = NodeTopology::two_tier(1, 4);
        let flat = NodeTopology::nvlink_all_to_all(4);
        assert_eq!(fab.num_islands(), 1);
        for i in 0..4 {
            for j in 0..4 {
                assert_eq!(fab.link(i, j), flat.link(i, j));
                assert_eq!(fab.copy_time(i, j, 12345).to_bits(), flat.copy_time(i, j, 12345).to_bits());
            }
        }
    }

    #[test]
    fn shared_and_contended_degenerate_to_copy_time() {
        let t = NodeTopology::two_tier(2, 2);
        // fanout 1 / concurrency 1 are bitwise the plain copy on every
        // link class.
        for (i, j) in [(0usize, 1usize), (0, 2), (1, 3)] {
            assert_eq!(t.copy_time_shared(i, j, 4096, 1).to_bits(), t.copy_time(i, j, 4096).to_bits());
            assert_eq!(t.contended_time(i, j, 4096, 1).to_bits(), t.copy_time(i, j, 4096).to_bits());
        }
        // NVLink fan-out amortizes the payload; the fabric pipe does not.
        let b = 1 << 24;
        assert_eq!(
            t.copy_time_shared(0, 1, b, 4).to_bits(),
            (t.copy_time(0, 1, b) / 4.0).to_bits()
        );
        assert!(t.copy_time_shared(0, 2, b, 4) > t.copy_time(0, 2, b) / 2.0);
        // Contention scales the payload term linearly.
        let c3 = t.contended_time(0, 1, b, 3);
        assert!(c3 > t.copy_time(0, 1, b) * 2.0 && c3 < t.copy_time(0, 1, b) * 3.0 + 1e-9);
    }

    #[test]
    fn signature_separates_structures_and_constants() {
        let a = NodeTopology::nvlink_all_to_all(8);
        let b = NodeTopology::nvlink_all_to_all(8);
        assert_eq!(a.signature(), b.signature());
        // Device count, link classes, islands, and constants all move
        // the fingerprint.
        assert_ne!(a.signature(), NodeTopology::nvlink_all_to_all(4).signature());
        assert_ne!(a.signature(), NodeTopology::pcie_all_to_all(8).signature());
        assert_ne!(a.signature(), NodeTopology::two_tier(2, 4).signature());
        let mut c = NodeTopology::nvlink_all_to_all(8);
        c.nvlink_bw *= 2.0;
        assert_ne!(a.signature(), c.signature());
        // A one-island subset of a fabric prices like the flat node and
        // fingerprints like it too.
        let sub = NodeTopology::two_tier(2, 4).subset(&[0, 1, 2, 3]).unwrap();
        assert_eq!(sub.signature(), NodeTopology::nvlink_all_to_all(4).signature());
    }

    #[test]
    fn subset_redensifies_islands() {
        let t = NodeTopology::two_tier(2, 4);
        // One island's worth of devices -> flat single-island view.
        let sub = t.subset(&[4, 5, 6, 7]).unwrap();
        assert_eq!(sub.num_islands(), 1);
        assert_eq!(sub.link(0, 1), LinkKind::NvLink);
        let flat = NodeTopology::nvlink_all_to_all(4);
        assert_eq!(
            sub.copy_time(0, 1, 9999).to_bits(),
            flat.copy_time(0, 1, 9999).to_bits()
        );
        // A straddling subset keeps two dense islands.
        let mix = t.subset(&[6, 7, 0]).unwrap();
        assert_eq!(mix.num_islands(), 2);
        assert_eq!(mix.island_of(0), 0);
        assert_eq!(mix.island_of(1), 0);
        assert_eq!(mix.island_of(2), 1);
        assert_eq!(mix.link(0, 2), LinkKind::InterNode);
        // Out-of-range devices are rejected.
        assert!(t.subset(&[0, 99]).is_err());
    }
}
