//! Node link topology and transfer cost model.
//!
//! Encodes the paper's testbed: 8 GPUs fully connected over NVLink.
//! Transfer times are `latency + bytes / bandwidth` per link class.
//! Numbers are H200/NVLink-class defaults; the cost model only needs to
//! preserve the *relative* structure (NVLink ≫ PCIe ≫ host link) for
//! the benchmark shapes to match the paper.

/// Link classes between two endpoints.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum LinkKind {
    /// Same device (device-local bandwidth, e.g. HBM3e on H200).
    Local,
    /// NVLink peer connection.
    NvLink,
    /// PCIe fallback peer connection.
    Pcie,
}

/// All-pairs link map plus bandwidth/latency constants.
#[derive(Clone, Debug)]
pub struct NodeTopology {
    n: usize,
    /// links[i][j] — link class between devices i and j.
    links: Vec<Vec<LinkKind>>,
    /// Effective bandwidths in bytes/second.
    pub local_bw: f64,
    pub nvlink_bw: f64,
    pub pcie_bw: f64,
    pub h2d_bw: f64,
    /// Per-operation latencies in seconds.
    pub copy_latency: f64,
}

impl NodeTopology {
    /// Fully connected NVLink topology (the paper's 8×H200 node).
    pub fn nvlink_all_to_all(n: usize) -> Self {
        let links = (0..n)
            .map(|i| (0..n).map(|j| if i == j { LinkKind::Local } else { LinkKind::NvLink }).collect())
            .collect();
        NodeTopology {
            n,
            links,
            // H200: ~4.8 TB/s HBM3e; NVLink4: ~450 GB/s effective per pair;
            // PCIe gen5 x16: ~50 GB/s; host link: ~55 GB/s.
            local_bw: 4.8e12,
            nvlink_bw: 450e9,
            pcie_bw: 50e9,
            h2d_bw: 55e9,
            copy_latency: 5e-6,
        }
    }

    /// PCIe-only topology (the no-NVLink ablation in the benches).
    pub fn pcie_all_to_all(n: usize) -> Self {
        let mut t = Self::nvlink_all_to_all(n);
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    t.links[i][j] = LinkKind::Pcie;
                }
            }
        }
        t
    }

    /// Number of devices covered by this topology.
    pub fn num_devices(&self) -> usize {
        self.n
    }

    /// Link class between two devices.
    pub fn link(&self, i: usize, j: usize) -> LinkKind {
        self.links[i][j]
    }

    /// Bandwidth of the link between two devices, bytes/second.
    pub fn bandwidth(&self, i: usize, j: usize) -> f64 {
        match self.link(i, j) {
            LinkKind::Local => self.local_bw,
            LinkKind::NvLink => self.nvlink_bw,
            LinkKind::Pcie => self.pcie_bw,
        }
    }

    /// Modeled duration of a `bytes`-sized copy between two devices.
    pub fn copy_time(&self, i: usize, j: usize, bytes: usize) -> f64 {
        self.copy_latency + bytes as f64 / self.bandwidth(i, j)
    }

    /// Modeled duration of a host↔device transfer.
    pub fn h2d_time(&self, bytes: usize) -> f64 {
        self.copy_latency + bytes as f64 / self.h2d_bw
    }

    /// Topology restricted to a device subset (the MPMD serve layer's
    /// degraded-mode view after a worker dies): device `i` of the
    /// subset is `devices[i]` here, links and constants are inherited.
    pub fn subset(&self, devices: &[usize]) -> crate::error::Result<Self> {
        for &d in devices {
            if d >= self.n {
                return Err(crate::error::Error::InvalidDevice { device: d, count: self.n });
            }
        }
        let links = devices
            .iter()
            .map(|&i| devices.iter().map(|&j| self.links[i][j]).collect())
            .collect();
        Ok(NodeTopology {
            n: devices.len(),
            links,
            local_bw: self.local_bw,
            nvlink_bw: self.nvlink_bw,
            pcie_bw: self.pcie_bw,
            h2d_bw: self.h2d_bw,
            copy_latency: self.copy_latency,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_to_all_shape() {
        let t = NodeTopology::nvlink_all_to_all(4);
        assert_eq!(t.num_devices(), 4);
        assert_eq!(t.link(0, 0), LinkKind::Local);
        assert_eq!(t.link(0, 3), LinkKind::NvLink);
        assert_eq!(t.link(3, 0), LinkKind::NvLink);
    }

    #[test]
    fn local_faster_than_peer() {
        let t = NodeTopology::nvlink_all_to_all(2);
        let local = t.copy_time(0, 0, 1 << 30);
        let peer = t.copy_time(0, 1, 1 << 30);
        assert!(local < peer);
    }

    #[test]
    fn nvlink_faster_than_pcie() {
        let nv = NodeTopology::nvlink_all_to_all(2);
        let pc = NodeTopology::pcie_all_to_all(2);
        assert!(nv.copy_time(0, 1, 1 << 30) < pc.copy_time(0, 1, 1 << 30));
    }

    #[test]
    fn latency_dominates_small_copies() {
        let t = NodeTopology::nvlink_all_to_all(2);
        let tiny = t.copy_time(0, 1, 8);
        assert!((tiny - t.copy_latency) / t.copy_latency < 0.01);
    }
}
