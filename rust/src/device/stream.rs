//! Streams and events over the simulated timeline.
//!
//! `cudaMemcpyPeerAsync` is *async*: the paper's two-staging-buffer
//! rotation exists precisely because copies are issued onto streams and
//! must not overwrite data still in flight. The simulator executes
//! copies eagerly (data is host-resident), but the *ordering/timing*
//! semantics are modeled here: a [`Stream`] serializes the completion
//! times of the work issued onto it, an [`Event`] captures a stream's
//! current horizon, and `wait_event` makes one stream's future work
//! start no earlier than another's recorded point — exactly CUDA's
//! contract. The redistributor uses two streams to model the staging
//! double-buffering; the projected-time column of the benches reflects
//! the overlap.

use super::SimClock;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// An ordered work queue on a device's timeline.
///
/// `horizon` is the simulated time at which all work issued so far
/// completes. Issuing `duration`-long work advances the horizon to
/// `max(horizon, not_before) + duration`.
#[derive(Debug, Clone)]
pub struct Stream {
    device: usize,
    horizon: Arc<AtomicU64>, // nanoseconds
}

/// A captured point on a stream's timeline (cudaEvent analogue).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct Event {
    nanos: u64,
}

impl Stream {
    /// New stream on device `device`, starting at t = 0.
    pub fn new(device: usize) -> Self {
        Stream { device, horizon: Arc::new(AtomicU64::new(0)) }
    }

    /// The owning device.
    pub fn device(&self) -> usize {
        self.device
    }

    /// Completion time of all currently issued work, seconds.
    pub fn horizon(&self) -> f64 {
        self.horizon.load(Ordering::Relaxed) as f64 * 1e-9
    }

    /// [`Stream::horizon`] on the exact integer-ns timeline — what the
    /// tracing layer snapshots for span endpoints, so span bounds are
    /// bitwise the cost model's charges with no float round-trip.
    pub fn horizon_ns(&self) -> u64 {
        self.horizon.load(Ordering::Relaxed)
    }

    /// Issue `seconds` of work; returns its completion time.
    /// The work starts when the stream is free.
    pub fn issue(&self, seconds: f64) -> f64 {
        let dur = (seconds * 1e9).round() as u64;
        let new = self.horizon.fetch_add(dur, Ordering::Relaxed) + dur;
        new as f64 * 1e-9
    }

    /// Issue `seconds` of work that additionally cannot start before
    /// `not_before` (a dependency from another stream/event).
    pub fn issue_after(&self, not_before: f64, seconds: f64) -> f64 {
        let nb = (not_before * 1e9).round() as u64;
        let dur = (seconds * 1e9).round() as u64;
        // CAS loop: horizon = max(horizon, nb) + dur.
        loop {
            let cur = self.horizon.load(Ordering::Relaxed);
            let start = cur.max(nb);
            let new = start + dur;
            if self
                .horizon
                .compare_exchange(cur, new, Ordering::Relaxed, Ordering::Relaxed)
                .is_ok()
            {
                return new as f64 * 1e-9;
            }
        }
    }

    /// Record an event at the stream's current horizon.
    pub fn record(&self) -> Event {
        Event { nanos: self.horizon.load(Ordering::Relaxed) }
    }

    /// Make subsequent work on this stream wait for `event`
    /// (cudaStreamWaitEvent): the horizon is pulled forward to the
    /// event's timestamp if it is earlier.
    pub fn wait_event(&self, event: Event) {
        self.horizon.fetch_max(event.nanos, Ordering::Relaxed);
    }

    /// Block the (simulated) host until the stream drains: pushes the
    /// device clock to the stream horizon (cudaStreamSynchronize).
    pub fn synchronize(&self, clock: &SimClock) {
        clock.sync_to(self.horizon());
    }
}

impl Event {
    /// The event's simulated timestamp in seconds.
    pub fn time(&self) -> f64 {
        self.nanos as f64 * 1e-9
    }

    /// An event at an absolute simulated time. Lets non-stream
    /// timelines (device clocks, cross-stream completion times carried
    /// as plain seconds) gate stream work: the lookahead scheduler
    /// records kernel/copy completion times and replays them as events
    /// on consumer streams.
    pub fn at(seconds: f64) -> Event {
        debug_assert!(seconds >= 0.0, "events cannot precede t = 0");
        Event { nanos: (seconds * 1e9).round() as u64 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn work_serializes_on_one_stream() {
        let s = Stream::new(0);
        let t1 = s.issue(1e-6);
        let t2 = s.issue(2e-6);
        assert!((t1 - 1e-6).abs() < 1e-12);
        assert!((t2 - 3e-6).abs() < 1e-12);
        assert!((s.horizon() - 3e-6).abs() < 1e-12);
    }

    #[test]
    fn two_streams_overlap() {
        // Independent streams: total time = max, not sum.
        let a = Stream::new(0);
        let b = Stream::new(0);
        a.issue(5e-6);
        b.issue(3e-6);
        assert!((a.horizon().max(b.horizon()) - 5e-6).abs() < 1e-12);
    }

    #[test]
    fn event_orders_across_streams() {
        let producer = Stream::new(0);
        let consumer = Stream::new(1);
        producer.issue(4e-6);
        let ev = producer.record();
        consumer.issue(1e-6); // early independent work
        consumer.wait_event(ev); // now gated on the producer
        let done = consumer.issue(1e-6);
        // Consumer work starts at 4µs (the event), finishes at 5µs.
        assert!((done - 5e-6).abs() < 1e-12, "got {done}");
    }

    #[test]
    fn issue_after_respects_dependency() {
        let s = Stream::new(0);
        let done = s.issue_after(10e-6, 1e-6);
        assert!((done - 11e-6).abs() < 1e-12);
        // Later dependency earlier than horizon: no effect.
        let done2 = s.issue_after(5e-6, 1e-6);
        assert!((done2 - 12e-6).abs() < 1e-12);
    }

    #[test]
    fn synchronize_pushes_device_clock() {
        let s = Stream::new(0);
        s.issue(7e-6);
        let clock = SimClock::new();
        s.synchronize(&clock);
        assert!((clock.now() - 7e-6).abs() < 1e-12);
    }

    #[test]
    fn absolute_events_gate_streams() {
        let s = Stream::new(0);
        s.wait_event(Event::at(3e-6));
        let done = s.issue(1e-6);
        assert!((done - 4e-6).abs() < 1e-12, "got {done}");
        // Earlier absolute event is a no-op.
        s.wait_event(Event::at(1e-6));
        assert!((s.horizon() - 4e-6).abs() < 1e-12);
    }

    #[test]
    fn double_buffer_pattern_overlaps() {
        // The §2.1 pattern: save(i+1) on stream A may run while
        // write(i) on stream B is in flight; a single stream would
        // serialize them.
        // Saves stream ahead on one stream (alternating between the two
        // staging buffers); each forward-write is gated only on its own
        // save, so save(i+1) overlaps write(i).
        let saves = Stream::new(0);
        let writes = Stream::new(0);
        let copy = 2e-6;
        let mut last_write = 0.0f64;
        for _ in 0..8 {
            let saved_at = saves.issue(copy);
            last_write = writes.issue_after(saved_at, copy);
        }
        let single = Stream::new(0);
        let mut serial = 0.0;
        for _ in 0..16 {
            serial = single.issue(copy);
        }
        assert!(last_write < serial, "double buffering must beat serial: {last_write} vs {serial}");
    }
}
