//! Per-device simulated timeline.
//!
//! Each device accumulates "busy time" in simulated seconds. Copies and
//! kernels charge their modeled duration; the node-level `sim_time()` is
//! the max over devices. This gives the *projected* wall-clock column of
//! the benchmark tables (the real-H200 estimate), measured alongside the
//! actual CPU wall-clock of the simulation.

use std::sync::atomic::{AtomicU64, Ordering};

/// Monotone simulated clock, nanosecond resolution, thread-safe.
///
/// Time is stored as **integer nanoseconds**; `now_ns`/`sync_to_ns`
/// expose the exact integer timeline (the serve-layer coalescer clock
/// reads this path so its timestamps never regress under float
/// rounding), while `now`/`advance` keep the f64-seconds interface the
/// cost model speaks.
#[derive(Debug, Default)]
pub struct SimClock {
    nanos: AtomicU64,
    /// Straggler drag: busy-time charges are multiplied by this factor
    /// (f64 bit-pattern, 1.0 = healthy). The MPMD straggler drill sets
    /// it > 1 to slow one device without killing it.
    drag_bits: AtomicU64,
}

impl SimClock {
    /// New clock at t = 0.
    pub fn new() -> Self {
        SimClock { nanos: AtomicU64::new(0), drag_bits: AtomicU64::new(1.0f64.to_bits()) }
    }

    /// Current time in seconds.
    pub fn now(&self) -> f64 {
        self.nanos.load(Ordering::Relaxed) as f64 * 1e-9
    }

    /// Current time in exact integer nanoseconds.
    pub fn now_ns(&self) -> u64 {
        self.nanos.load(Ordering::Relaxed)
    }

    /// Advance by `seconds` of busy time (scaled by the drag factor).
    pub fn advance(&self, seconds: f64) {
        debug_assert!(seconds >= 0.0, "cannot advance clock backwards");
        let drag = f64::from_bits(self.drag_bits.load(Ordering::Relaxed));
        let ns = (seconds * drag * 1e9).round() as u64;
        self.nanos.fetch_add(ns, Ordering::Relaxed);
    }

    /// Synchronize this clock forward to at least `seconds` (barrier
    /// semantics: a device waiting on a peer's data cannot proceed
    /// before the peer's timeline).
    pub fn sync_to(&self, seconds: f64) {
        let target = (seconds * 1e9).round() as u64;
        self.nanos.fetch_max(target, Ordering::Relaxed);
    }

    /// Integer-ns variant of [`SimClock::sync_to`] — no float round-trip.
    pub fn sync_to_ns(&self, target_ns: u64) {
        self.nanos.fetch_max(target_ns, Ordering::Relaxed);
    }

    /// Set the straggler drag factor (1.0 = healthy, 3.0 = 3× slower).
    /// Affects subsequent `advance` charges only, never recorded time.
    pub fn set_drag(&self, factor: f64) {
        assert!(factor.is_finite() && factor >= 1.0, "drag factor must be >= 1.0");
        self.drag_bits.store(factor.to_bits(), Ordering::Relaxed);
    }

    /// Current drag factor.
    pub fn drag(&self) -> f64 {
        f64::from_bits(self.drag_bits.load(Ordering::Relaxed))
    }

    /// Reset to t = 0 (drag factor is preserved).
    pub fn reset(&self) {
        self.nanos.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advances_and_reads() {
        let c = SimClock::new();
        assert_eq!(c.now(), 0.0);
        c.advance(1.5e-3);
        assert!((c.now() - 1.5e-3).abs() < 1e-12);
        c.advance(0.5e-3);
        assert!((c.now() - 2.0e-3).abs() < 1e-12);
    }

    #[test]
    fn sync_to_only_moves_forward() {
        let c = SimClock::new();
        c.advance(5e-6);
        c.sync_to(3e-6); // earlier: no-op
        assert!((c.now() - 5e-6).abs() < 1e-12);
        c.sync_to(9e-6);
        assert!((c.now() - 9e-6).abs() < 1e-12);
    }

    #[test]
    fn reset_zeroes() {
        let c = SimClock::new();
        c.advance(1.0);
        c.reset();
        assert_eq!(c.now(), 0.0);
    }

    #[test]
    fn integer_ns_path_is_exact() {
        let c = SimClock::new();
        c.sync_to_ns(1_000_000_007);
        assert_eq!(c.now_ns(), 1_000_000_007);
        c.sync_to_ns(999); // earlier: no-op
        assert_eq!(c.now_ns(), 1_000_000_007);
        c.advance(1e-9);
        assert_eq!(c.now_ns(), 1_000_000_008);
    }

    #[test]
    fn drag_scales_advances() {
        let c = SimClock::new();
        assert_eq!(c.drag(), 1.0);
        c.set_drag(4.0);
        c.advance(1e-6);
        assert_eq!(c.now_ns(), 4_000);
        c.set_drag(1.0);
        c.advance(1e-6);
        assert_eq!(c.now_ns(), 5_000);
    }
}
