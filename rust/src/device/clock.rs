//! Per-device simulated timeline.
//!
//! Each device accumulates "busy time" in simulated seconds. Copies and
//! kernels charge their modeled duration; the node-level `sim_time()` is
//! the max over devices. This gives the *projected* wall-clock column of
//! the benchmark tables (the real-H200 estimate), measured alongside the
//! actual CPU wall-clock of the simulation.

use std::sync::atomic::{AtomicU64, Ordering};

/// Monotone simulated clock, nanosecond resolution, thread-safe.
#[derive(Debug, Default)]
pub struct SimClock {
    nanos: AtomicU64,
}

impl SimClock {
    /// New clock at t = 0.
    pub fn new() -> Self {
        SimClock { nanos: AtomicU64::new(0) }
    }

    /// Current time in seconds.
    pub fn now(&self) -> f64 {
        self.nanos.load(Ordering::Relaxed) as f64 * 1e-9
    }

    /// Advance by `seconds` of busy time.
    pub fn advance(&self, seconds: f64) {
        debug_assert!(seconds >= 0.0, "cannot advance clock backwards");
        let ns = (seconds * 1e9).round() as u64;
        self.nanos.fetch_add(ns, Ordering::Relaxed);
    }

    /// Synchronize this clock forward to at least `seconds` (barrier
    /// semantics: a device waiting on a peer's data cannot proceed
    /// before the peer's timeline).
    pub fn sync_to(&self, seconds: f64) {
        let target = (seconds * 1e9).round() as u64;
        self.nanos.fetch_max(target, Ordering::Relaxed);
    }

    /// Reset to t = 0.
    pub fn reset(&self) {
        self.nanos.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advances_and_reads() {
        let c = SimClock::new();
        assert_eq!(c.now(), 0.0);
        c.advance(1.5e-3);
        assert!((c.now() - 1.5e-3).abs() < 1e-12);
        c.advance(0.5e-3);
        assert!((c.now() - 2.0e-3).abs() < 1e-12);
    }

    #[test]
    fn sync_to_only_moves_forward() {
        let c = SimClock::new();
        c.advance(5e-6);
        c.sync_to(3e-6); // earlier: no-op
        assert!((c.now() - 5e-6).abs() < 1e-12);
        c.sync_to(9e-6);
        assert!((c.now() - 9e-6).abs() < 1e-12);
    }

    #[test]
    fn reset_zeroes() {
        let c = SimClock::new();
        c.advance(1.0);
        c.reset();
        assert_eq!(c.now(), 0.0);
    }
}
