//! Permutation-cycle decomposition of a layout conversion.
//!
//! "To apply this redistribution efficiently in-place, we decompose the
//! column-index mapping into disjoint permutation cycles" (paper §2.1).
//! A cycle `[s₀, s₁, ..., s_{m−1}]` means: the content in slot `sᵢ`
//! must move to slot `s_{i+1 mod m}`.
//!
//! Two slot granularities share the machinery:
//!
//! * **column slots** over a [`ColumnLayout`] (the original 1D path),
//!   via [`permutation_between`];
//! * **tile slots** over a [`MatrixLayout`] 2D tile grid, via
//!   [`tile_permutation_between`] — one slot per `tile_r × tile_c`
//!   tile, devices concatenated in order, tiles in storage order.
//!
//! Both build a precomputed [`SlotMap`] / [`TileSlotMap`] first: slot
//! arithmetic via per-device prefix sums and a dense inverse table, so
//! permutation construction is `O(1)` per slot instead of the old
//! `O(ndev)` trait-default scan — this is on the redistribution
//! planning hot path.

use super::block_cyclic::ColumnLayout;
use super::grid::MatrixLayout;
use crate::error::{Error, Result};

/// One rotation cycle over storage slots.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Cycle {
    /// Slots in movement order: content of `slots[i]` goes to
    /// `slots[(i+1) % len]`.
    pub slots: Vec<usize>,
}

impl Cycle {
    /// Cycle length.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// Cycles of length 1 are fixed points (no data movement).
    pub fn is_trivial(&self) -> bool {
        self.slots.len() <= 1
    }

    /// Never empty by construction.
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }
}

/// Precomputed column-slot arithmetic for one [`ColumnLayout`].
///
/// The trait's default `slot_of`/`slot_to_place` scan the per-device
/// column counts on every call (`O(ndev)` each). Building this map once
/// per layout (`O(n)`) makes both directions `O(1)` per slot, which is
/// what permutation construction and the cycle walk want.
pub struct SlotMap {
    /// `prefix[d]` = total columns on devices `< d`; `prefix[ndev]` = n.
    prefix: Vec<usize>,
    /// Dense inverse: `place[slot] = (device, local)`.
    place: Vec<(usize, usize)>,
}

impl SlotMap {
    /// Build the map for `layout`.
    pub fn new(layout: &dyn ColumnLayout) -> Self {
        let ndev = layout.num_devices();
        let mut prefix = Vec::with_capacity(ndev + 1);
        prefix.push(0);
        for d in 0..ndev {
            prefix.push(prefix[d] + layout.local_cols(d));
        }
        let total = prefix[ndev];
        let mut place = Vec::with_capacity(total);
        for d in 0..ndev {
            for loc in 0..(prefix[d + 1] - prefix[d]) {
                place.push((d, loc));
            }
        }
        SlotMap { prefix, place }
    }

    /// Total slots (columns) covered.
    pub fn total(&self) -> usize {
        self.place.len()
    }

    /// Flat storage slot of `(device, local)` — `O(1)`.
    #[inline]
    pub fn slot_of(&self, d: usize, local: usize) -> usize {
        self.prefix[d] + local
    }

    /// Inverse of [`SlotMap::slot_of`] — `O(1)`.
    #[inline]
    pub fn place_of(&self, slot: usize) -> (usize, usize) {
        self.place[slot]
    }
}

/// The explicit slot permutation taking layout `src` to layout `dst`:
/// `perm[s]` is the destination slot of the column content currently
/// stored in slot `s`.
///
/// Fails unless the two layouts distribute the same number of columns
/// to each device (the in-place precondition; callers fall back to
/// out-of-place redistribution otherwise).
pub fn permutation_between(src: &dyn ColumnLayout, dst: &dyn ColumnLayout) -> Result<Vec<usize>> {
    if src.n_cols() != dst.n_cols() {
        return Err(Error::layout(format!(
            "layout sizes differ: {} vs {}",
            src.n_cols(),
            dst.n_cols()
        )));
    }
    if src.num_devices() != dst.num_devices() {
        return Err(Error::layout("layouts span different device counts"));
    }
    for d in 0..src.num_devices() {
        if src.local_cols(d) != dst.local_cols(d) {
            return Err(Error::layout(format!(
                "in-place redistribution needs matching per-device counts; device {d} holds {} vs {}",
                src.local_cols(d),
                dst.local_cols(d)
            )));
        }
    }
    let smap = SlotMap::new(src);
    let dmap = SlotMap::new(dst);
    let n = src.n_cols();
    let mut perm = vec![usize::MAX; n];
    for g in 0..n {
        let (sd, sl) = src.place(g);
        let (dd, dl) = dst.place(g);
        perm[smap.slot_of(sd, sl)] = dmap.slot_of(dd, dl);
    }
    debug_assert!(perm.iter().all(|&p| p != usize::MAX));
    Ok(perm)
}

/// Precomputed tile-slot arithmetic for one [`MatrixLayout`]: one slot
/// per tile, devices concatenated in ordinal order, tiles in each
/// device's storage order. The 2D analogue of [`SlotMap`].
pub struct TileSlotMap {
    /// `prefix[d]` = tiles on devices `< d`; `prefix[ndev]` = total.
    prefix: Vec<usize>,
    /// Dense inverse: `tile[slot] = (device, local ordinal, tr, tc)`.
    tiles: Vec<(usize, usize, usize, usize)>,
}

impl TileSlotMap {
    /// Build the map for `layout`.
    pub fn new(layout: &dyn MatrixLayout) -> Self {
        let ndev = layout.num_devices();
        let mut prefix = Vec::with_capacity(ndev + 1);
        prefix.push(0);
        for d in 0..ndev {
            prefix.push(prefix[d] + layout.tiles_on(d));
        }
        let mut tiles = Vec::with_capacity(prefix[ndev]);
        for d in 0..ndev {
            for ord in 0..(prefix[d + 1] - prefix[d]) {
                let (tr, tc) = layout.tile_at(d, ord);
                tiles.push((d, ord, tr, tc));
            }
        }
        TileSlotMap { prefix, tiles }
    }

    /// Total tile slots covered.
    pub fn total(&self) -> usize {
        self.tiles.len()
    }

    /// Flat tile slot of `(device, local ordinal)` — `O(1)`.
    #[inline]
    pub fn slot_of(&self, d: usize, ordinal: usize) -> usize {
        self.prefix[d] + ordinal
    }

    /// `(device, local ordinal)` stored at `slot` — `O(1)`.
    #[inline]
    pub fn place_of(&self, slot: usize) -> (usize, usize) {
        let (d, ord, _, _) = self.tiles[slot];
        (d, ord)
    }

    /// Global `(tile row, tile col)` stored at `slot` — `O(1)`.
    #[inline]
    pub fn tile_of(&self, slot: usize) -> (usize, usize) {
        let (_, _, tr, tc) = self.tiles[slot];
        (tr, tc)
    }
}

/// The tile-slot permutation taking tile layout `src` to `dst`:
/// `perm[s]` is the destination tile slot of the tile currently stored
/// in slot `s` — the 2D generalization of [`permutation_between`],
/// with tiles instead of columns as the movement unit.
///
/// Fails unless the two layouts share the matrix shape, the tile shape
/// and the device count, and give every device the same number of
/// tiles (the in-place precondition; callers fall back to the generic
/// out-of-place conversion otherwise — in particular for 1D↔2D
/// re-tilings where the movement units differ).
pub fn tile_permutation_between(
    src: &dyn MatrixLayout,
    dst: &dyn MatrixLayout,
) -> Result<Vec<usize>> {
    if src.shape() != dst.shape() {
        return Err(Error::layout(format!(
            "layout shapes differ: {:?} vs {:?}",
            src.shape(),
            dst.shape()
        )));
    }
    if src.tile_shape() != dst.tile_shape() {
        return Err(Error::layout(format!(
            "tile shapes differ: {:?} vs {:?} — re-tiling cannot be a tile permutation",
            src.tile_shape(),
            dst.tile_shape()
        )));
    }
    if src.num_devices() != dst.num_devices() {
        return Err(Error::layout("layouts span different device counts"));
    }
    for d in 0..src.num_devices() {
        if src.tiles_on(d) != dst.tiles_on(d) {
            return Err(Error::layout(format!(
                "in-place tile redistribution needs matching per-device tile counts; \
                 device {d} holds {} vs {}",
                src.tiles_on(d),
                dst.tiles_on(d)
            )));
        }
    }
    let smap = TileSlotMap::new(src);
    let dmap = TileSlotMap::new(dst);
    let (tr_n, tc_n) = src.tile_grid();
    let mut perm = vec![usize::MAX; smap.total()];
    for tr in 0..tr_n {
        for tc in 0..tc_n {
            let s = smap.slot_of(src.owner_of_tile(tr, tc), src.local_tile_ordinal(tr, tc));
            let t = dmap.slot_of(dst.owner_of_tile(tr, tc), dst.local_tile_ordinal(tr, tc));
            perm[s] = t;
        }
    }
    debug_assert!(perm.iter().all(|&p| p != usize::MAX));
    Ok(perm)
}

/// Decompose a permutation into its disjoint cycles (fixed points are
/// returned as length-1 cycles so callers can count them, but they
/// trigger no copies).
pub fn cycle_decomposition(perm: &[usize]) -> Vec<Cycle> {
    let n = perm.len();
    let mut visited = vec![false; n];
    let mut cycles = Vec::new();
    for start in 0..n {
        if visited[start] {
            continue;
        }
        let mut slots = vec![start];
        visited[start] = true;
        let mut cur = perm[start];
        while cur != start {
            assert!(!visited[cur], "input is not a permutation");
            visited[cur] = true;
            slots.push(cur);
            cur = perm[cur];
        }
        cycles.push(Cycle { slots });
    }
    cycles
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::layout::{BlockCyclic1D, ContiguousBlock};

    #[test]
    fn identity_permutation_all_trivial() {
        let perm: Vec<usize> = (0..8).collect();
        let cycles = cycle_decomposition(&perm);
        assert_eq!(cycles.len(), 8);
        assert!(cycles.iter().all(|c| c.is_trivial()));
    }

    #[test]
    fn single_swap() {
        let perm = vec![1, 0, 2];
        let cycles = cycle_decomposition(&perm);
        let nontrivial: Vec<_> = cycles.iter().filter(|c| !c.is_trivial()).collect();
        assert_eq!(nontrivial.len(), 1);
        assert_eq!(nontrivial[0].slots, vec![0, 1]);
    }

    #[test]
    fn rotation_is_one_cycle() {
        // 0→1→2→3→0
        let perm = vec![1, 2, 3, 0];
        let cycles = cycle_decomposition(&perm);
        assert_eq!(cycles.len(), 1);
        assert_eq!(cycles[0].len(), 4);
    }

    #[test]
    fn cycles_cover_all_slots_exactly_once() {
        let src = ContiguousBlock::new(24, 3).unwrap();
        let dst = BlockCyclic1D::new(24, 2, 3).unwrap();
        let perm = permutation_between(&src, &dst).unwrap();
        let cycles = cycle_decomposition(&perm);
        let mut count = vec![0usize; 24];
        for c in &cycles {
            for &s in &c.slots {
                count[s] += 1;
            }
        }
        assert!(count.iter().all(|&c| c == 1));
    }

    #[test]
    fn permutation_moves_columns_to_cyclic_owners() {
        let n = 16;
        let ndev = 4;
        let tile = 2;
        let src = ContiguousBlock::new(n, ndev).unwrap();
        let dst = BlockCyclic1D::new(n, tile, ndev).unwrap();
        let perm = permutation_between(&src, &dst).unwrap();
        // Column g sits in src slot, must land in dst slot.
        use crate::layout::ColumnLayout;
        for g in 0..n {
            let (sd, sl) = src.place(g);
            let s = src.slot_of(sd, sl);
            let target = perm[s];
            let (dd, dl) = dst.slot_to_place(target);
            assert_eq!(dst.global_index(dd, dl), g);
        }
    }

    #[test]
    fn unbalanced_layouts_rejected() {
        let src = ContiguousBlock::new(10, 2).unwrap(); // 5/5
        let dst = BlockCyclic1D::new(10, 4, 2).unwrap(); // 6/4
        assert!(permutation_between(&src, &dst).is_err());
    }

    #[test]
    fn mismatched_sizes_rejected() {
        let src = ContiguousBlock::new(10, 2).unwrap();
        let dst = BlockCyclic1D::new(12, 2, 2).unwrap();
        assert!(permutation_between(&src, &dst).is_err());
    }

    #[test]
    fn tile_equals_block_size_is_identity_like() {
        // When T·ndev == n and T == n/ndev the layouts coincide.
        let src = ContiguousBlock::new(12, 3).unwrap();
        let dst = BlockCyclic1D::new(12, 4, 3).unwrap();
        let perm = permutation_between(&src, &dst).unwrap();
        assert_eq!(perm, (0..12).collect::<Vec<_>>());
    }

    #[test]
    fn slot_map_matches_trait_defaults() {
        let l = BlockCyclic1D::new(17, 3, 4).unwrap();
        let map = SlotMap::new(&l);
        assert_eq!(map.total(), 17);
        for s in 0..map.total() {
            let (d, loc) = map.place_of(s);
            assert_eq!((d, loc), l.slot_to_place(s));
            assert_eq!(map.slot_of(d, loc), l.slot_of(d, loc));
        }
    }

    #[test]
    fn tile_permutation_covers_all_tile_slots_once() {
        use crate::layout::{BlockCyclic2D, ContiguousGrid2D};
        let src = ContiguousGrid2D::new(16, 24, 4, 4, 2, 2).unwrap();
        let dst = BlockCyclic2D::new(16, 24, 4, 4, 2, 2).unwrap();
        let perm = tile_permutation_between(&src, &dst).unwrap();
        assert_eq!(perm.len(), 4 * 6);
        let cycles = cycle_decomposition(&perm);
        let mut count = vec![0usize; perm.len()];
        for c in &cycles {
            for &s in &c.slots {
                count[s] += 1;
            }
        }
        assert!(count.iter().all(|&c| c == 1), "cycles must partition the tile slots");
    }

    #[test]
    fn tile_permutation_sends_tiles_home() {
        use crate::layout::BlockCyclic2D;
        // Regrid 2×2 ↔ 4×1 over the same tiling: a genuine 2D shuffle.
        let src = BlockCyclic2D::new(16, 16, 4, 4, 2, 2).unwrap();
        let dst = BlockCyclic2D::new(16, 16, 4, 4, 4, 1).unwrap();
        let perm = tile_permutation_between(&src, &dst).unwrap();
        let smap = TileSlotMap::new(&src);
        let dmap = TileSlotMap::new(&dst);
        for s in 0..perm.len() {
            let (tr, tc) = smap.tile_of(s);
            let (dd, dord) = dmap.place_of(perm[s]);
            assert_eq!(dst.owner_of_tile(tr, tc), dd);
            assert_eq!(dst.local_tile_ordinal(tr, tc), dord);
        }
    }

    #[test]
    fn tile_permutation_rejects_incompatible_layouts() {
        use crate::layout::BlockCyclic2D;
        let a = BlockCyclic2D::new(16, 16, 4, 4, 2, 2).unwrap();
        let b = BlockCyclic2D::new(16, 16, 2, 4, 2, 2).unwrap(); // different tiling
        assert!(tile_permutation_between(&a, &b).is_err());
        let c = BlockCyclic2D::new(16, 12, 4, 4, 2, 2).unwrap(); // different shape
        assert!(tile_permutation_between(&a, &c).is_err());
        let d = BlockCyclic2D::new(16, 16, 4, 4, 4, 1).unwrap();
        // 2×2 vs 4×1 over a 4×4 tile grid: counts match → Ok.
        assert!(tile_permutation_between(&a, &d).is_ok());
    }
}
