//! Permutation-cycle decomposition of a layout conversion.
//!
//! "To apply this redistribution efficiently in-place, we decompose the
//! column-index mapping into disjoint permutation cycles" (paper §2.1).
//! A cycle `[s₀, s₁, ..., s_{m−1}]` means: the column content in slot
//! `sᵢ` must move to slot `s_{i+1 mod m}`.

use super::block_cyclic::ColumnLayout;
use crate::error::{Error, Result};

/// One rotation cycle over storage slots.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Cycle {
    /// Slots in movement order: content of `slots[i]` goes to
    /// `slots[(i+1) % len]`.
    pub slots: Vec<usize>,
}

impl Cycle {
    /// Cycle length.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// Cycles of length 1 are fixed points (no data movement).
    pub fn is_trivial(&self) -> bool {
        self.slots.len() <= 1
    }

    /// Never empty by construction.
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }
}

/// The explicit slot permutation taking layout `src` to layout `dst`:
/// `perm[s]` is the destination slot of the column content currently
/// stored in slot `s`.
///
/// Fails unless the two layouts distribute the same number of columns
/// to each device (the in-place precondition; callers fall back to
/// out-of-place redistribution otherwise).
pub fn permutation_between(src: &dyn ColumnLayout, dst: &dyn ColumnLayout) -> Result<Vec<usize>> {
    if src.n_cols() != dst.n_cols() {
        return Err(Error::layout(format!(
            "layout sizes differ: {} vs {}",
            src.n_cols(),
            dst.n_cols()
        )));
    }
    if src.num_devices() != dst.num_devices() {
        return Err(Error::layout("layouts span different device counts"));
    }
    for d in 0..src.num_devices() {
        if src.local_cols(d) != dst.local_cols(d) {
            return Err(Error::layout(format!(
                "in-place redistribution needs matching per-device counts; device {d} holds {} vs {}",
                src.local_cols(d),
                dst.local_cols(d)
            )));
        }
    }
    let n = src.n_cols();
    let mut perm = vec![usize::MAX; n];
    for g in 0..n {
        let (sd, sl) = src.place(g);
        let (dd, dl) = dst.place(g);
        perm[src.slot_of(sd, sl)] = dst.slot_of(dd, dl);
    }
    debug_assert!(perm.iter().all(|&p| p != usize::MAX));
    Ok(perm)
}

/// Decompose a permutation into its disjoint cycles (fixed points are
/// returned as length-1 cycles so callers can count them, but they
/// trigger no copies).
pub fn cycle_decomposition(perm: &[usize]) -> Vec<Cycle> {
    let n = perm.len();
    let mut visited = vec![false; n];
    let mut cycles = Vec::new();
    for start in 0..n {
        if visited[start] {
            continue;
        }
        let mut slots = vec![start];
        visited[start] = true;
        let mut cur = perm[start];
        while cur != start {
            assert!(!visited[cur], "input is not a permutation");
            visited[cur] = true;
            slots.push(cur);
            cur = perm[cur];
        }
        cycles.push(Cycle { slots });
    }
    cycles
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::layout::{BlockCyclic1D, ContiguousBlock};

    #[test]
    fn identity_permutation_all_trivial() {
        let perm: Vec<usize> = (0..8).collect();
        let cycles = cycle_decomposition(&perm);
        assert_eq!(cycles.len(), 8);
        assert!(cycles.iter().all(|c| c.is_trivial()));
    }

    #[test]
    fn single_swap() {
        let perm = vec![1, 0, 2];
        let cycles = cycle_decomposition(&perm);
        let nontrivial: Vec<_> = cycles.iter().filter(|c| !c.is_trivial()).collect();
        assert_eq!(nontrivial.len(), 1);
        assert_eq!(nontrivial[0].slots, vec![0, 1]);
    }

    #[test]
    fn rotation_is_one_cycle() {
        // 0→1→2→3→0
        let perm = vec![1, 2, 3, 0];
        let cycles = cycle_decomposition(&perm);
        assert_eq!(cycles.len(), 1);
        assert_eq!(cycles[0].len(), 4);
    }

    #[test]
    fn cycles_cover_all_slots_exactly_once() {
        let src = ContiguousBlock::new(24, 3).unwrap();
        let dst = BlockCyclic1D::new(24, 2, 3).unwrap();
        let perm = permutation_between(&src, &dst).unwrap();
        let cycles = cycle_decomposition(&perm);
        let mut count = vec![0usize; 24];
        for c in &cycles {
            for &s in &c.slots {
                count[s] += 1;
            }
        }
        assert!(count.iter().all(|&c| c == 1));
    }

    #[test]
    fn permutation_moves_columns_to_cyclic_owners() {
        let n = 16;
        let ndev = 4;
        let tile = 2;
        let src = ContiguousBlock::new(n, ndev).unwrap();
        let dst = BlockCyclic1D::new(n, tile, ndev).unwrap();
        let perm = permutation_between(&src, &dst).unwrap();
        // Column g sits in src slot, must land in dst slot.
        use crate::layout::ColumnLayout;
        for g in 0..n {
            let (sd, sl) = src.place(g);
            let s = src.slot_of(sd, sl);
            let target = perm[s];
            let (dd, dl) = dst.slot_to_place(target);
            assert_eq!(dst.global_index(dd, dl), g);
        }
    }

    #[test]
    fn unbalanced_layouts_rejected() {
        let src = ContiguousBlock::new(10, 2).unwrap(); // 5/5
        let dst = BlockCyclic1D::new(10, 4, 2).unwrap(); // 6/4
        assert!(permutation_between(&src, &dst).is_err());
    }

    #[test]
    fn mismatched_sizes_rejected() {
        let src = ContiguousBlock::new(10, 2).unwrap();
        let dst = BlockCyclic1D::new(12, 2, 2).unwrap();
        assert!(permutation_between(&src, &dst).is_err());
    }

    #[test]
    fn tile_equals_block_size_is_identity_like() {
        // When T·ndev == n and T == n/ndev the layouts coincide.
        let src = ContiguousBlock::new(12, 3).unwrap();
        let dst = BlockCyclic1D::new(12, 4, 3).unwrap();
        let perm = permutation_between(&src, &dst).unwrap();
        assert_eq!(perm, (0..12).collect::<Vec<_>>());
    }
}
