//! The two column layouts and their index arithmetic.

use crate::error::{Error, Result};

/// A 1D distribution of `n` matrix columns over `ndev` devices.
///
/// Both layouts implement this; the redistributor and the solvers only
/// talk to the trait, so further layouts (e.g. 2D block-cyclic from the
/// paper's future work) can slot in.
pub trait ColumnLayout {
    /// Total number of columns.
    fn n_cols(&self) -> usize;
    /// Number of devices.
    fn num_devices(&self) -> usize;
    /// Owning device of global column `g`.
    fn owner_of(&self, g: usize) -> usize;
    /// Local column index of global column `g` on its owner.
    fn local_index(&self, g: usize) -> usize;
    /// Number of columns stored on device `d`.
    fn local_cols(&self, d: usize) -> usize;
    /// Global column stored at `(d, local)`.
    fn global_index(&self, d: usize, local: usize) -> usize;

    /// `(owner, local)` pair for a global column.
    fn place(&self, g: usize) -> (usize, usize) {
        (self.owner_of(g), self.local_index(g))
    }

    /// Flat *storage slot* of a `(device, local)` pair: devices
    /// concatenated in order. The permutation in `cycles.rs` is over
    /// these slots.
    ///
    /// This default is an `O(ndev)` scan kept for one-off queries; the
    /// redistribution planning hot path precomputes a
    /// [`super::SlotMap`] (per-device prefix sums + dense inverse) so
    /// every slot lookup is `O(1)`.
    fn slot_of(&self, d: usize, local: usize) -> usize {
        let mut base = 0;
        for dd in 0..d {
            base += self.local_cols(dd);
        }
        base + local
    }

    /// Inverse of [`ColumnLayout::slot_of`].
    fn slot_to_place(&self, slot: usize) -> (usize, usize) {
        let mut rem = slot;
        for d in 0..self.num_devices() {
            let lc = self.local_cols(d);
            if rem < lc {
                return (d, rem);
            }
            rem -= lc;
        }
        panic!("slot {slot} out of range");
    }
}

/// cuSOLVERMg's layout: columns grouped into tiles of `tile` columns,
/// tiles dealt round-robin (tile `t` → device `t mod ndev`). The last
/// tile may be short.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct BlockCyclic1D {
    n: usize,
    tile: usize,
    ndev: usize,
}

impl BlockCyclic1D {
    /// New layout; `tile` is the paper's `T_A`.
    pub fn new(n: usize, tile: usize, ndev: usize) -> Result<Self> {
        if tile == 0 {
            return Err(Error::layout("tile size T_A must be positive"));
        }
        if ndev == 0 {
            return Err(Error::layout("need at least one device"));
        }
        Ok(BlockCyclic1D { n, tile, ndev })
    }

    /// The tile size `T_A`.
    pub fn tile(&self) -> usize {
        self.tile
    }

    /// Number of column tiles (the last may be short).
    pub fn num_tiles(&self) -> usize {
        self.n.div_ceil(self.tile)
    }

    /// Width of tile `t`.
    pub fn tile_cols(&self, t: usize) -> usize {
        debug_assert!(t < self.num_tiles());
        if (t + 1) * self.tile <= self.n {
            self.tile
        } else {
            self.n - t * self.tile
        }
    }

    /// First global column of tile `t`.
    pub fn tile_start(&self, t: usize) -> usize {
        t * self.tile
    }

    /// Owning device of tile `t` (round-robin).
    pub fn owner_of_tile(&self, t: usize) -> usize {
        t % self.ndev
    }

    /// Local *tile* ordinal of tile `t` on its owner.
    pub fn local_tile_index(&self, t: usize) -> usize {
        t / self.ndev
    }

    /// Global tile indices owned by device `d`, in storage order.
    pub fn tiles_of(&self, d: usize) -> impl Iterator<Item = usize> + '_ {
        (0..self.num_tiles()).filter(move |t| t % self.ndev == d)
    }

    /// Local column offset of tile `t` within its owner's storage.
    /// With a uniform tile size this is `(t / ndev) * tile`, and edge
    /// tiles can only be last so the formula holds generally.
    pub fn tile_local_offset(&self, t: usize) -> usize {
        self.local_tile_index(t) * self.tile
    }

    /// Whether per-device column counts are identical to `other`'s —
    /// the precondition for in-place redistribution.
    pub fn balanced_with(&self, other: &dyn ColumnLayout) -> bool {
        self.num_devices() == other.num_devices()
            && (0..self.ndev).all(|d| self.local_cols(d) == other.local_cols(d))
    }
}

impl ColumnLayout for BlockCyclic1D {
    fn n_cols(&self) -> usize {
        self.n
    }
    fn num_devices(&self) -> usize {
        self.ndev
    }
    fn owner_of(&self, g: usize) -> usize {
        debug_assert!(g < self.n);
        (g / self.tile) % self.ndev
    }
    fn local_index(&self, g: usize) -> usize {
        let t = g / self.tile;
        self.tile_local_offset(t) + (g % self.tile)
    }
    fn local_cols(&self, d: usize) -> usize {
        // numroc: sum of widths of tiles owned by d.
        self.tiles_of(d).map(|t| self.tile_cols(t)).sum()
    }
    fn global_index(&self, d: usize, local: usize) -> usize {
        let lt = local / self.tile; // local tile ordinal
        let t = lt * self.ndev + d; // global tile
        self.tile_start(t) + (local % self.tile)
    }
}

/// JAX's input layout: equal contiguous blocks per device (the shard
/// produced by `NamedSharding(mesh, P("x", None))` on a row-sharded
/// array, viewed column-major — see DESIGN.md). Device `d` owns columns
/// `[start(d), start(d+1))`, sizes differing by at most one.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct ContiguousBlock {
    n: usize,
    ndev: usize,
}

impl ContiguousBlock {
    /// New contiguous block layout.
    pub fn new(n: usize, ndev: usize) -> Result<Self> {
        if ndev == 0 {
            return Err(Error::layout("need at least one device"));
        }
        Ok(ContiguousBlock { n, ndev })
    }

    /// First global column owned by device `d`.
    pub fn start(&self, d: usize) -> usize {
        let base = self.n / self.ndev;
        let rem = self.n % self.ndev;
        d * base + d.min(rem)
    }
}

impl ColumnLayout for ContiguousBlock {
    fn n_cols(&self) -> usize {
        self.n
    }
    fn num_devices(&self) -> usize {
        self.ndev
    }
    fn owner_of(&self, g: usize) -> usize {
        debug_assert!(g < self.n);
        // Invert `start`: devices 0..rem own (base+1) columns.
        let base = self.n / self.ndev;
        let rem = self.n % self.ndev;
        let big = (base + 1) * rem; // columns owned by the first `rem` devices
        if g < big {
            g / (base + 1)
        } else {
            rem + (g - big) / base.max(1)
        }
    }
    fn local_index(&self, g: usize) -> usize {
        g - self.start(self.owner_of(g))
    }
    fn local_cols(&self, d: usize) -> usize {
        self.start(d + 1).min(self.n) - self.start(d)
    }
    fn global_index(&self, d: usize, local: usize) -> usize {
        self.start(d) + local
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_layout_bijection(l: &dyn ColumnLayout) {
        let n = l.n_cols();
        let mut seen = vec![false; n];
        for d in 0..l.num_devices() {
            for loc in 0..l.local_cols(d) {
                let g = l.global_index(d, loc);
                assert!(g < n, "g={g} out of range");
                assert!(!seen[g], "column {g} mapped twice");
                seen[g] = true;
                assert_eq!(l.owner_of(g), d);
                assert_eq!(l.local_index(g), loc);
            }
        }
        assert!(seen.iter().all(|&b| b), "not all columns mapped");
        // Sum of local cols is n.
        let total: usize = (0..l.num_devices()).map(|d| l.local_cols(d)).sum();
        assert_eq!(total, n);
    }

    #[test]
    fn block_cyclic_bijection_even() {
        let l = BlockCyclic1D::new(64, 4, 4).unwrap();
        check_layout_bijection(&l);
    }

    #[test]
    fn block_cyclic_bijection_ragged() {
        // n not divisible by tile or ndev.
        for (n, t, d) in [(10, 4, 2), (17, 3, 4), (5, 8, 3), (33, 5, 7), (1, 1, 1)] {
            let l = BlockCyclic1D::new(n, t, d).unwrap();
            check_layout_bijection(&l);
        }
    }

    #[test]
    fn contiguous_bijection() {
        for (n, d) in [(10, 2), (17, 4), (5, 8), (33, 7), (8, 8), (3, 5)] {
            let l = ContiguousBlock::new(n, d).unwrap();
            check_layout_bijection(&l);
        }
    }

    #[test]
    fn round_robin_matches_figure1() {
        // Figure 1: tiles dealt round-robin. n=8, T=2, 2 devices:
        // tiles 0,1,2,3 → devices 0,1,0,1; cols 0,1,4,5 on dev0.
        let l = BlockCyclic1D::new(8, 2, 2).unwrap();
        assert_eq!(l.owner_of(0), 0);
        assert_eq!(l.owner_of(1), 0);
        assert_eq!(l.owner_of(2), 1);
        assert_eq!(l.owner_of(3), 1);
        assert_eq!(l.owner_of(4), 0);
        assert_eq!(l.owner_of(5), 0);
        assert_eq!(l.local_index(4), 2);
        assert_eq!(l.local_index(5), 3);
        assert_eq!(l.global_index(1, 2), 6);
    }

    #[test]
    fn tile_arithmetic() {
        let l = BlockCyclic1D::new(10, 4, 2).unwrap();
        assert_eq!(l.num_tiles(), 3);
        assert_eq!(l.tile_cols(0), 4);
        assert_eq!(l.tile_cols(2), 2); // short edge tile
        assert_eq!(l.owner_of_tile(2), 0);
        assert_eq!(l.local_tile_index(2), 1);
        assert_eq!(l.tiles_of(0).collect::<Vec<_>>(), vec![0, 2]);
        assert_eq!(l.local_cols(0), 6);
        assert_eq!(l.local_cols(1), 4);
    }

    #[test]
    fn slots_are_flat_and_invertible() {
        let l = BlockCyclic1D::new(12, 2, 3).unwrap();
        let total: usize = (0..3).map(|d| l.local_cols(d)).sum();
        for s in 0..total {
            let (d, loc) = l.slot_to_place(s);
            assert_eq!(l.slot_of(d, loc), s);
        }
    }

    #[test]
    fn balanced_when_divisible() {
        let bc = BlockCyclic1D::new(16, 2, 4).unwrap();
        let cb = ContiguousBlock::new(16, 4).unwrap();
        assert!(bc.balanced_with(&cb));
        let bc2 = BlockCyclic1D::new(10, 4, 2).unwrap();
        let cb2 = ContiguousBlock::new(10, 2).unwrap();
        assert!(!bc2.balanced_with(&cb2)); // 6/4 vs 5/5
    }

    #[test]
    fn contiguous_start_offsets() {
        let l = ContiguousBlock::new(10, 3).unwrap();
        // 4, 3, 3
        assert_eq!(l.local_cols(0), 4);
        assert_eq!(l.local_cols(1), 3);
        assert_eq!(l.start(1), 4);
        assert_eq!(l.owner_of(3), 0);
        assert_eq!(l.owner_of(4), 1);
        assert_eq!(l.owner_of(9), 2);
    }

    #[test]
    fn invalid_params_rejected() {
        assert!(BlockCyclic1D::new(8, 0, 2).is_err());
        assert!(BlockCyclic1D::new(8, 2, 0).is_err());
        assert!(ContiguousBlock::new(8, 0).is_err());
    }
}
