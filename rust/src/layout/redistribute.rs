//! In-place layout conversion via permutation cycles + two staging
//! buffers — the execution half of paper §2.1, generalized from column
//! slots to tile slots.
//!
//! For each non-trivial cycle `s₀ → s₁ → ... → s_{m−1} → s₀` the
//! rotation runs *forward* with two alternating staging buffers: before
//! slot `s_{i+1}` is overwritten with the content of `s_i`, its own
//! content is saved into the staging buffer the previous step is not
//! using. This is exactly why two buffers suffice "to avoid overwriting
//! data before it is forwarded": step `i`'s save and step `i−1`'s write
//! target different buffers, so consecutive async copies never race on
//! staging storage.
//!
//! Three execution paths, chosen by the slot structure of the two
//! layouts:
//!
//! * **column cycles** — both layouts columnar (the original 1D path,
//!   including `P = 1` grids whose storage is bitwise columnar) with
//!   matching per-device column counts: one-column slots, one-column
//!   staging buffers. Byte-for-byte the seed behaviour, so plans and
//!   data movement are identical whether the handle is a 1D descriptor
//!   or its `P = 1` 2D re-expression.
//! * **tile cycles** — both layouts on the *same* uniform tile grid
//!   (`m % tile_r == 0`, `n % tile_c == 0`) with matching per-device
//!   tile counts, e.g. regridding `2×2 ↔ 4×1` or blocked → cyclic tile
//!   deals: whole contiguous tiles rotate through two tile-sized
//!   staging buffers.
//! * **generic out-of-place** — everything else (ragged tiles,
//!   mismatched per-device counts, and the 1D↔2D re-tilings where the
//!   movement units differ): fresh panels in the target layout, one
//!   peer copy per overlapping tile-row segment of each column.

use crate::device::DevPtr;
use crate::error::{Error, Result};
use crate::layout::{
    cycle_decomposition, permutation_between, tile_permutation_between, BlockCyclic1D,
    ColumnLayout, ContiguousBlock, SlotMap, TileSlotMap,
};
use crate::scalar::Scalar;
use crate::tile::{DistMatrix, LayoutKind};

/// Statistics of one redistribution, for tests and the Fig. 1 bench.
#[derive(Copy, Clone, Debug, Default, PartialEq, Eq)]
pub struct RedistPlan {
    /// Total cycles including fixed points.
    pub cycles: usize,
    /// Cycles that actually moved data.
    pub nontrivial_cycles: usize,
    /// Columns physically moved (column path and generic path).
    pub columns_moved: usize,
    /// Of which crossed a device boundary.
    pub columns_cross_device: usize,
    /// Tiles physically moved (tile path; 0 on the column path).
    pub tiles_moved: usize,
    /// Of which crossed a device boundary.
    pub tiles_cross_device: usize,
    /// True if executed in place (cycles + staging), false if the
    /// out-of-place fallback ran.
    pub in_place: bool,
}

/// A column-layout view of a [`LayoutKind`], owned so that `P = 1`
/// grids can synthesize their equivalent 1D descriptor.
enum ColView {
    Contig(ContiguousBlock),
    Cyclic(BlockCyclic1D),
}

impl ColView {
    fn as_dyn(&self) -> &dyn ColumnLayout {
        match self {
            ColView::Contig(l) => l,
            ColView::Cyclic(l) => l,
        }
    }
}

/// The columnar view of `kind` for a `rows`-high matrix, if its storage
/// follows the full-height column-panel contract.
fn column_view(kind: &LayoutKind, rows: usize) -> Option<ColView> {
    match kind {
        LayoutKind::Contiguous(l) => Some(ColView::Contig(*l)),
        LayoutKind::BlockCyclic(l) => Some(ColView::Cyclic(*l)),
        LayoutKind::Grid(_) => kind.compat_1d(rows).map(ColView::Cyclic),
        LayoutKind::GridContig(_) => None,
    }
}

/// The shared forward-rotation executor behind both in-place paths:
/// runs every non-trivial cycle through two `slot_elems`-sized staging
/// buffers on the cycle-leader device (the paper's two-buffer
/// argument: step `i`'s save and step `i−1`'s write target different
/// buffers, so consecutive async copies never race on staging).
///
/// `place` resolves a slot to `(device, panel ptr, byte offset)`;
/// `moved(from, to)` is called once per executed slot move;
/// `cycle_done(len)` once per completed non-trivial cycle (after its
/// staging is freed), for metrics. Returns the non-trivial cycle count.
fn rotate_cycles<S, P, M, C>(
    node: &crate::device::SimNode,
    cycles: &[crate::layout::Cycle],
    slot_elems: usize,
    slot_bytes: usize,
    place: P,
    mut moved: M,
    mut cycle_done: C,
) -> Result<usize>
where
    S: Scalar,
    P: Fn(usize) -> (usize, DevPtr, usize),
    M: FnMut(usize, usize),
    C: FnMut(usize),
{
    let mut nontrivial = 0;
    for cycle in cycles {
        if cycle.is_trivial() {
            continue;
        }
        nontrivial += 1;
        let mlen = cycle.len();

        // Two staging buffers on the cycle-leader device.
        let (lead_dev, _, _) = place(cycle.slots[0]);
        let stage = [
            node.alloc_scalars::<S>(lead_dev, slot_elems)?,
            node.alloc_scalars::<S>(lead_dev, slot_elems)?,
        ];

        // Forward rotation: content(s_i) → s_{i+1}.
        //   save  s_1 → stage[0]
        //   write s_0 → s_1
        //   save  s_2 → stage[1]      (other buffer: step i−1 still owns stage[0] conceptually)
        //   write stage[0] → s_2      (old s_1 content)
        //   ...
        //   write stage[(m−2)%2] → s_0 (old s_{m−1} content closes the cycle)
        let (d1, p1, o1) = place(cycle.slots[1 % mlen]);
        node.peer_copy(p1, o1, stage[0], 0, slot_bytes)?;
        let (d0, p0, o0) = place(cycle.slots[0]);
        node.peer_copy(p0, o0, p1, o1, slot_bytes)?;
        moved(d0, d1);

        // Steps 1..m−1: save s_{i+1} into the free buffer, then write
        // the previously staged content into s_{i+1}.
        for i in 1..mlen {
            let nxt = cycle.slots[(i + 1) % mlen];
            let (dn, pn, on) = place(nxt);
            let cur_stage = stage[(i - 1) % 2];
            if (i + 1) % mlen == 0 {
                // Closing step: s_0 receives old content of s_{m−1},
                // which sits in cur_stage; nothing left to save.
                node.peer_copy(cur_stage, 0, pn, on, slot_bytes)?;
                let (dprev, _, _) = place(cycle.slots[i]);
                moved(dprev, dn);
            } else {
                let next_stage = stage[i % 2];
                node.peer_copy(pn, on, next_stage, 0, slot_bytes)?;
                node.peer_copy(cur_stage, 0, pn, on, slot_bytes)?;
                let (dprev, _, _) = place(cycle.slots[i]);
                moved(dprev, dn);
            }
        }

        node.free(stage[0])?;
        node.free(stage[1])?;
        cycle_done(mlen);
    }
    Ok(nontrivial)
}

/// Executes layout conversions on a [`DistMatrix`].
pub struct Redistributor;

impl Redistributor {
    /// Convert `m` to `target` layout, physically permuting its storage.
    pub fn convert<S: Scalar>(m: &mut DistMatrix<S>, target: LayoutKind) -> Result<RedistPlan> {
        let src_kind = *m.layout();
        if src_kind.n_cols() != target.n_cols() {
            return Err(Error::layout(format!(
                "layout sizes differ: {} vs {}",
                src_kind.n_cols(),
                target.n_cols()
            )));
        }
        if src_kind.num_devices() != target.num_devices() {
            return Err(Error::layout("layouts span different device counts"));
        }
        if !target.rows_match(m.rows()) {
            return Err(Error::shape(format!(
                "target grid layout does not distribute {} rows",
                m.rows()
            )));
        }

        // Columnar fast path (1D↔1D, and P=1 grids re-expressed as 1D).
        if let (Some(s), Some(t)) = (column_view(&src_kind, m.rows()), column_view(&target, m.rows()))
        {
            let (s, t) = (s.as_dyn(), t.as_dyn());
            let balanced = (0..s.num_devices()).all(|d| s.local_cols(d) == t.local_cols(d));
            if balanced {
                return Self::convert_in_place_columns(m, target, s, t);
            }
            return Self::convert_generic(m, target);
        }

        // Tile cycle walk: same uniform tiling, matching per-device
        // tile counts ⇒ tile slots are interchangeable storage units.
        if let (Some(sg), Some(tg)) = (src_kind.matrix_layout(), target.matrix_layout()) {
            let compatible = sg.tile_shape() == tg.tile_shape()
                && sg.uniform_tiles()
                && (0..sg.num_devices()).all(|d| sg.tiles_on(d) == tg.tiles_on(d));
            if compatible {
                return Self::convert_in_place_tiles(m, target);
            }
        }

        Self::convert_generic(m, target)
    }

    /// The paper's algorithm at column granularity: explicit permutation
    /// → disjoint cycles → forward rotation with two staging buffers and
    /// peer copies.
    fn convert_in_place_columns<S: Scalar>(
        m: &mut DistMatrix<S>,
        target: LayoutKind,
        src: &dyn ColumnLayout,
        dst: &dyn ColumnLayout,
    ) -> Result<RedistPlan> {
        let node = m.node().clone();
        let col_bytes = m.col_bytes();
        let col_elems = m.rows();

        let perm = permutation_between(src, dst)?;
        let cycles = cycle_decomposition(&perm);
        // O(1) slot lookups on the cycle walk (satellite fix: the trait
        // defaults scan per-device counts on every call).
        let smap = SlotMap::new(src);

        let mut plan = RedistPlan { cycles: cycles.len(), in_place: true, ..Default::default() };
        let mut columns_moved = 0usize;
        let mut columns_cross = 0usize;

        // Slot → (device, panel ptr, byte offset). Slots are identical
        // between layouts because per-device counts match.
        let place = |slot: usize| -> (usize, DevPtr, usize) {
            let (d, loc) = smap.place_of(slot);
            (d, m.panels()[d], loc * col_bytes)
        };

        plan.nontrivial_cycles = rotate_cycles::<S, _, _, _>(
            &node,
            &cycles,
            col_elems,
            col_bytes,
            place,
            |from, to| {
                columns_moved += 1;
                if from != to {
                    columns_cross += 1;
                }
            },
            |mlen| {
                node.metrics().redist_cycles.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                node.metrics()
                    .redist_columns
                    .fetch_add(mlen as u64, std::sync::atomic::Ordering::Relaxed);
            },
        )?;
        plan.columns_moved = columns_moved;
        plan.columns_cross_device = columns_cross;

        m.set_layout(target);
        Ok(plan)
    }

    /// The same rotation at tile granularity: whole contiguous
    /// `tile_r × tile_c` tiles move through two tile-sized staging
    /// buffers (requires the uniform-tiling/matching-counts
    /// precondition checked by [`Redistributor::convert`]).
    fn convert_in_place_tiles<S: Scalar>(
        m: &mut DistMatrix<S>,
        target: LayoutKind,
    ) -> Result<RedistPlan> {
        let node = m.node().clone();
        let src_kind = *m.layout();
        let sg = src_kind.matrix_layout().expect("tile path needs a grid source");
        let tg = target.matrix_layout().expect("tile path needs a grid target");
        let (th, tw) = sg.tile_shape();
        let tile_elems = th * tw;
        let tile_bytes = tile_elems * std::mem::size_of::<S>();

        let perm = tile_permutation_between(sg, tg)?;
        let cycles = cycle_decomposition(&perm);
        let smap = TileSlotMap::new(sg);

        let mut plan = RedistPlan { cycles: cycles.len(), in_place: true, ..Default::default() };
        let mut tiles_moved = 0usize;
        let mut tiles_cross = 0usize;

        // With uniform tiles, local tile `ord` sits at byte offset
        // `ord · tile_bytes` — slots are interchangeable storage units.
        let place = |slot: usize| -> (usize, DevPtr, usize) {
            let (d, ord) = smap.place_of(slot);
            (d, m.panels()[d], ord * tile_bytes)
        };

        plan.nontrivial_cycles = rotate_cycles::<S, _, _, _>(
            &node,
            &cycles,
            tile_elems,
            tile_bytes,
            place,
            |from, to| {
                tiles_moved += 1;
                if from != to {
                    tiles_cross += 1;
                }
            },
            |_mlen| {
                node.metrics().redist_cycles.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            },
        )?;
        plan.tiles_moved = tiles_moved;
        plan.tiles_cross_device = tiles_cross;
        // Column-equivalents for the shared volume counter: a tile
        // holds a `tile_r`-high slice of `tile_c` columns, i.e.
        // `th·tw/rows` of a full column — not `tw` whole columns.
        // (Rounded down; exact when whole tile columns move.)
        if m.rows() > 0 {
            let equiv = (tiles_moved * th * tw) / m.rows();
            node.metrics()
                .redist_columns
                .fetch_add(equiv as u64, std::sync::atomic::Ordering::Relaxed);
        }

        m.set_layout(target);
        Ok(plan)
    }

    /// Out-of-place fallback for every remaining pair (unbalanced
    /// columnar shapes, ragged tile grids, 1D↔2D re-tilings): fresh
    /// panels in the target layout, one peer copy per overlapping
    /// tile-row segment of each column, old panels freed.
    fn convert_generic<S: Scalar>(m: &mut DistMatrix<S>, target: LayoutKind) -> Result<RedistPlan> {
        let node = m.node().clone();
        let rows = m.rows();
        let esize = std::mem::size_of::<S>();
        let src_kind = *m.layout();

        let mut new_panels = Vec::with_capacity(node.num_devices());
        for d in 0..node.num_devices() {
            new_panels.push(node.alloc_scalars::<S>(d, target.local_elems(rows, d))?);
        }

        let mut plan = RedistPlan { in_place: false, ..Default::default() };
        if rows > 0 {
            for j in 0..src_kind.n_cols() {
                let src_segs = src_kind.col_segments(rows, j);
                let dst_segs = target.col_segments(rows, j);
                let mut crossed = false;
                let (mut si, mut di) = (0usize, 0usize);
                while si < src_segs.len() && di < dst_segs.len() {
                    let s = src_segs[si];
                    let t = dst_segs[di];
                    let lo = s.r0.max(t.r0);
                    let hi = (s.r0 + s.len).min(t.r0 + t.len);
                    debug_assert!(lo < hi, "column segments must tile the rows");
                    node.peer_copy(
                        m.panels()[s.dev],
                        (s.elem_off + (lo - s.r0)) * esize,
                        new_panels[t.dev],
                        (t.elem_off + (lo - t.r0)) * esize,
                        (hi - lo) * esize,
                    )?;
                    if s.dev != t.dev {
                        crossed = true;
                    }
                    if s.r0 + s.len == hi {
                        si += 1;
                    }
                    if t.r0 + t.len == hi {
                        di += 1;
                    }
                }
                plan.columns_moved += 1;
                if crossed {
                    plan.columns_cross_device += 1;
                }
            }
        }
        m.replace_panels(new_panels, target)?;
        Ok(plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::SimNode;
    use crate::layout::{BlockCyclic2D, ContiguousGrid2D};
    use crate::linalg::Matrix;
    use crate::scalar::c64;
    use crate::tile::Layout1D;

    fn roundtrip_case<S: Scalar>(n: usize, rows: usize, tile: usize, ndev: usize, seed: u64) {
        let node = SimNode::new_uniform(ndev, 1 << 26);
        let a = Matrix::<S>::random(rows, n, seed);
        let contig = Layout1D::Contiguous(ContiguousBlock::new(n, ndev).unwrap());
        let cyclic = Layout1D::BlockCyclic(BlockCyclic1D::new(n, tile, ndev).unwrap());

        let mut dm = DistMatrix::scatter(&node, &a, contig).unwrap();
        let plan = Redistributor::convert(&mut dm, cyclic).unwrap();
        // Content correct in the new layout.
        let b = dm.gather().unwrap();
        assert_eq!(a, b, "content corrupted by redistribution (n={n} T={tile} d={ndev})");

        // Convert back and re-check.
        let plan2 = Redistributor::convert(&mut dm, contig).unwrap();
        let c = dm.gather().unwrap();
        assert_eq!(a, c, "content corrupted by inverse redistribution");
        assert_eq!(plan.in_place, plan2.in_place);
    }

    #[test]
    fn in_place_balanced_roundtrip() {
        // n divisible by tile*ndev ⇒ balanced ⇒ in-place cycles.
        roundtrip_case::<f64>(16, 8, 2, 4, 1);
        roundtrip_case::<f32>(24, 5, 2, 3, 2);
        roundtrip_case::<c64>(32, 4, 4, 2, 3);
    }

    #[test]
    fn out_of_place_unbalanced_roundtrip() {
        roundtrip_case::<f64>(10, 4, 4, 2, 4); // 6/4 vs 5/5 → fallback
        roundtrip_case::<f32>(17, 3, 3, 4, 5);
        roundtrip_case::<c64>(33, 2, 5, 7, 6);
    }

    #[test]
    fn in_place_reports_cycles() {
        let node = SimNode::new_uniform(4, 1 << 24);
        let n = 16;
        let a = Matrix::<f64>::random(4, n, 7);
        let contig = Layout1D::Contiguous(ContiguousBlock::new(n, 4).unwrap());
        let cyclic = Layout1D::BlockCyclic(BlockCyclic1D::new(n, 2, 4).unwrap());
        let mut dm = DistMatrix::scatter(&node, &a, contig).unwrap();
        let plan = Redistributor::convert(&mut dm, cyclic).unwrap();
        assert!(plan.in_place);
        assert!(plan.nontrivial_cycles > 0);
        assert!(plan.columns_moved > 0);
        assert_eq!(node.metrics().snapshot().redist_cycles, plan.nontrivial_cycles as u64);
        // Staging buffers must all be freed.
        for rep in node.memory_reports() {
            assert_eq!(rep.allocations, 1, "only the panel must remain");
        }
    }

    #[test]
    fn identity_conversion_moves_nothing() {
        // tile == n/ndev makes block-cyclic equal contiguous.
        let node = SimNode::new_uniform(4, 1 << 24);
        let n = 16;
        let a = Matrix::<f64>::random(4, n, 8);
        let contig = Layout1D::Contiguous(ContiguousBlock::new(n, 4).unwrap());
        let cyclic = Layout1D::BlockCyclic(BlockCyclic1D::new(n, 4, 4).unwrap());
        let mut dm = DistMatrix::scatter(&node, &a, contig).unwrap();
        let plan = Redistributor::convert(&mut dm, cyclic).unwrap();
        assert!(plan.in_place);
        assert_eq!(plan.nontrivial_cycles, 0);
        assert_eq!(plan.columns_moved, 0);
    }

    #[test]
    fn single_device_is_local_only() {
        let node = SimNode::new_uniform(1, 1 << 24);
        let n = 12;
        let a = Matrix::<f64>::random(6, n, 9);
        let contig = Layout1D::Contiguous(ContiguousBlock::new(n, 1).unwrap());
        let cyclic = Layout1D::BlockCyclic(BlockCyclic1D::new(n, 4, 1).unwrap());
        let mut dm = DistMatrix::scatter(&node, &a, contig).unwrap();
        let plan = Redistributor::convert(&mut dm, cyclic).unwrap();
        // One device: every tile is owned by device 0 in both layouts ⇒ identity.
        assert_eq!(plan.columns_cross_device, 0);
        assert_eq!(dm.gather().unwrap(), a);
    }

    #[test]
    fn large_randomized_roundtrips() {
        // Sweep of shapes; rows kept small to bound test time.
        for (i, &(n, t, d)) in
            [(48usize, 2usize, 4usize), (60, 5, 4), (64, 8, 2), (96, 4, 8), (40, 10, 2)].iter().enumerate()
        {
            roundtrip_case::<f64>(n, 3, t, d, 100 + i as u64);
        }
    }

    // ---- 2D tile-grid conversions ------------------------------------

    #[test]
    fn tile_regrid_in_place_roundtrip() {
        // Same uniform 4×4 tiling, 2×2 ↔ 4×1 grids: whole tiles rotate
        // in place through the two staging buffers.
        let node = SimNode::new_uniform(4, 1 << 24);
        let a = Matrix::<f64>::random(16, 16, 11);
        let g22 = LayoutKind::Grid(BlockCyclic2D::new(16, 16, 4, 4, 2, 2).unwrap());
        let g41 = LayoutKind::Grid(BlockCyclic2D::new(16, 16, 4, 4, 4, 1).unwrap());
        let mut dm = DistMatrix::scatter(&node, &a, g22).unwrap();
        let plan = Redistributor::convert(&mut dm, g41).unwrap();
        assert!(plan.in_place, "uniform regrid must run in place");
        assert!(plan.tiles_moved > 0);
        assert_eq!(dm.gather().unwrap(), a);
        let plan2 = Redistributor::convert(&mut dm, g22).unwrap();
        assert!(plan2.in_place);
        assert_eq!(dm.gather().unwrap(), a);
        // Staging tiles all freed: one panel allocation per device.
        for rep in node.memory_reports() {
            assert_eq!(rep.allocations, 1, "staging tiles must be freed");
        }
    }

    #[test]
    fn blocked_to_cyclic_tiles_in_place() {
        // The 2D analogue of Fig. 1: 2D-mesh shard input → 2D cyclic
        // compute layout, same tiling ⇒ in-place tile cycles.
        let node = SimNode::new_uniform(4, 1 << 24);
        let a = Matrix::<f32>::random(16, 24, 12);
        let shard = LayoutKind::GridContig(ContiguousGrid2D::new(16, 24, 4, 4, 2, 2).unwrap());
        let cyclic = LayoutKind::Grid(BlockCyclic2D::new(16, 24, 4, 4, 2, 2).unwrap());
        let mut dm = DistMatrix::scatter(&node, &a, shard).unwrap();
        let plan = Redistributor::convert(&mut dm, cyclic).unwrap();
        assert!(plan.in_place);
        assert!(plan.tiles_cross_device > 0, "a 2×2 redeal must cross devices");
        assert_eq!(dm.gather().unwrap(), a);
    }

    #[test]
    fn one_d_to_two_d_retiling_is_out_of_place() {
        // Different movement units (full columns vs 4×4 tiles): the
        // generic segment path must run, and content must survive.
        let node = SimNode::new_uniform(4, 1 << 24);
        let a = Matrix::<f64>::random(16, 16, 13);
        let contig = LayoutKind::Contiguous(ContiguousBlock::new(16, 4).unwrap());
        let grid = LayoutKind::Grid(BlockCyclic2D::new(16, 16, 4, 4, 2, 2).unwrap());
        let mut dm = DistMatrix::scatter(&node, &a, contig).unwrap();
        let plan = Redistributor::convert(&mut dm, grid).unwrap();
        assert!(!plan.in_place);
        assert_eq!(plan.columns_moved, 16);
        assert_eq!(dm.gather().unwrap(), a);
        // And back to the 1D cyclic compute layout.
        let cyc = LayoutKind::BlockCyclic(BlockCyclic1D::new(16, 4, 4).unwrap());
        Redistributor::convert(&mut dm, cyc).unwrap();
        assert_eq!(dm.gather().unwrap(), a);
    }

    #[test]
    fn ragged_tiles_fall_back_out_of_place() {
        // 10×14 in 4×3 tiles is ragged ⇒ no tile cycle walk.
        let node = SimNode::new_uniform(4, 1 << 24);
        let a = Matrix::<c64>::random(10, 14, 14);
        let shard = LayoutKind::GridContig(ContiguousGrid2D::new(10, 14, 4, 3, 2, 2).unwrap());
        let cyclic = LayoutKind::Grid(BlockCyclic2D::new(10, 14, 4, 3, 2, 2).unwrap());
        let mut dm = DistMatrix::scatter(&node, &a, shard).unwrap();
        let plan = Redistributor::convert(&mut dm, cyclic).unwrap();
        assert!(!plan.in_place);
        assert_eq!(dm.gather().unwrap(), a);
    }

    #[test]
    fn p1_grid_conversion_plan_matches_1d_plan_bitwise() {
        // Acceptance: converting contiguous → P=1 grid must produce the
        // exact same RedistPlan (and data movement) as contiguous → the
        // equivalent 1D block-cyclic layout.
        let (rows, n, t, ndev) = (8, 24, 2, 4);
        let a = Matrix::<f64>::random(rows, n, 15);
        let contig = LayoutKind::Contiguous(ContiguousBlock::new(n, ndev).unwrap());

        let node1 = SimNode::new_uniform(ndev, 1 << 24);
        let mut d1 = DistMatrix::scatter(&node1, &a, contig).unwrap();
        let plan1 =
            Redistributor::convert(&mut d1, LayoutKind::BlockCyclic(BlockCyclic1D::new(n, t, ndev).unwrap()))
                .unwrap();

        let node2 = SimNode::new_uniform(ndev, 1 << 24);
        let mut d2 = DistMatrix::scatter(&node2, &a, contig).unwrap();
        let plan2 = Redistributor::convert(
            &mut d2,
            LayoutKind::Grid(BlockCyclic2D::new(rows, n, rows, t, 1, ndev).unwrap()),
        )
        .unwrap();

        assert_eq!(plan1, plan2, "P=1 grid must redistribute exactly like the 1D path");
        // The per-device panels are bitwise identical afterwards.
        for d in 0..ndev {
            let p1 = d1.read_block(d, 0, rows, 0, 6).unwrap();
            let p2 = d2.read_block(d, 0, rows, 0, 6).unwrap();
            assert_eq!(p1.as_slice(), p2.as_slice(), "panel {d} diverged");
        }
        assert_eq!(d1.gather().unwrap(), d2.gather().unwrap());
    }
}
