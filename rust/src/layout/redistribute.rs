//! In-place layout conversion via permutation cycles + two staging
//! buffers — the execution half of paper §2.1.
//!
//! For each non-trivial cycle `s₀ → s₁ → ... → s_{m−1} → s₀` the
//! rotation runs *forward* with two alternating one-column staging
//! buffers: before slot `s_{i+1}` is overwritten with the content of
//! `s_i`, its own content is saved into the staging buffer the previous
//! step is not using. This is exactly why two buffers suffice "to avoid
//! overwriting data before it is forwarded": step `i`'s save and step
//! `i−1`'s write target different buffers, so consecutive async copies
//! never race on staging storage.
//!
//! When the source and target layouts give some device different column
//! counts (N not divisible by T_A·ndev), in-place rotation is
//! impossible; [`Redistributor::convert`] then falls back to an
//! out-of-place pass through freshly allocated panels (still
//! peer-to-peer copies, just not in place). The paper's benchmarked
//! configurations are all balanced.

use crate::device::DevPtr;
use crate::error::Result;
use crate::layout::{cycle_decomposition, permutation_between};
use crate::scalar::Scalar;
use crate::tile::{DistMatrix, Layout1D};

/// Statistics of one redistribution, for tests and the Fig. 1 bench.
#[derive(Copy, Clone, Debug, Default, PartialEq, Eq)]
pub struct RedistPlan {
    /// Total cycles including fixed points.
    pub cycles: usize,
    /// Cycles that actually moved data.
    pub nontrivial_cycles: usize,
    /// Columns physically moved.
    pub columns_moved: usize,
    /// Of which crossed a device boundary.
    pub columns_cross_device: usize,
    /// True if executed in place (cycles + staging), false if the
    /// out-of-place fallback ran.
    pub in_place: bool,
}

/// Executes layout conversions on a [`DistMatrix`].
pub struct Redistributor;

impl Redistributor {
    /// Convert `m` to `target` layout, physically permuting columns.
    pub fn convert<S: Scalar>(m: &mut DistMatrix<S>, target: Layout1D) -> Result<RedistPlan> {
        let src_kind = *m.layout();
        let src = src_kind.as_layout();
        let dst = target.as_layout();
        let balanced = (0..src.num_devices()).all(|d| src.local_cols(d) == dst.local_cols(d));
        if balanced {
            Self::convert_in_place(m, target)
        } else {
            Self::convert_out_of_place(m, target)
        }
    }

    /// The paper's algorithm: explicit permutation → disjoint cycles →
    /// forward rotation with two staging buffers and peer copies.
    fn convert_in_place<S: Scalar>(m: &mut DistMatrix<S>, target: Layout1D) -> Result<RedistPlan> {
        let node = m.node().clone();
        let col_bytes = m.col_bytes();
        let col_elems = m.rows();
        let src_kind = *m.layout();
        let src = src_kind.as_layout();
        let dst = target.as_layout();

        let perm = permutation_between(src, dst)?;
        let cycles = cycle_decomposition(&perm);

        let mut plan = RedistPlan { cycles: cycles.len(), in_place: true, ..Default::default() };

        // Slot → (device, panel ptr, byte offset). Slots are identical
        // between layouts because per-device counts match.
        let place = |slot: usize| -> (usize, DevPtr, usize) {
            let (d, loc) = src.slot_to_place(slot);
            (d, m.panels()[d], loc * col_bytes)
        };

        for cycle in &cycles {
            if cycle.is_trivial() {
                continue;
            }
            plan.nontrivial_cycles += 1;
            let mlen = cycle.len();

            // Two one-column staging buffers on the cycle-leader device.
            let (lead_dev, _, _) = place(cycle.slots[0]);
            let stage =
                [node.alloc_scalars::<S>(lead_dev, col_elems)?, node.alloc_scalars::<S>(lead_dev, col_elems)?];

            // Forward rotation: content(s_i) → s_{i+1}.
            //   save  s_1 → stage[0]
            //   write s_0 → s_1
            //   save  s_2 → stage[1]      (other buffer: step i−1 still owns stage[0] conceptually)
            //   write stage[0] → s_2      (old s_1 content)
            //   ...
            //   write stage[(m−2)%2] → s_0 (old s_{m−1} content closes the cycle)
            //
            // Track statistics per executed copy.
            let mut charge = |from_dev: usize, to_dev: usize| {
                plan.columns_moved += 1;
                if from_dev != to_dev {
                    plan.columns_cross_device += 1;
                }
            };

            // Step 0: save s_1, then write s_0 → s_1 directly.
            let (d1, p1, o1) = place(cycle.slots[1 % mlen]);
            node.peer_copy(p1, o1, stage[0], 0, col_bytes)?;
            let (d0, p0, o0) = place(cycle.slots[0]);
            node.peer_copy(p0, o0, p1, o1, col_bytes)?;
            charge(d0, d1);

            // Steps 1..m−1: save s_{i+1} into the free buffer, then
            // write the previously staged content into s_{i+1}.
            for i in 1..mlen {
                let nxt = cycle.slots[(i + 1) % mlen];
                let (dn, pn, on) = place(nxt);
                let cur_stage = stage[(i - 1) % 2];
                if (i + 1) % mlen == 0 {
                    // Closing step: s_0 receives old content of s_{m−1},
                    // which sits in cur_stage; nothing left to save.
                    node.peer_copy(cur_stage, 0, pn, on, col_bytes)?;
                    let (dprev, _, _) = place(cycle.slots[i]);
                    charge(dprev, dn);
                } else {
                    let next_stage = stage[i % 2];
                    node.peer_copy(pn, on, next_stage, 0, col_bytes)?;
                    node.peer_copy(cur_stage, 0, pn, on, col_bytes)?;
                    let (dprev, _, _) = place(cycle.slots[i]);
                    charge(dprev, dn);
                }
            }

            node.free(stage[0])?;
            node.free(stage[1])?;

            node.metrics().redist_cycles.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            node.metrics()
                .redist_columns
                .fetch_add(mlen as u64, std::sync::atomic::Ordering::Relaxed);
        }

        m.set_layout(target);
        Ok(plan)
    }

    /// Out-of-place fallback for unbalanced shapes: fresh panels in the
    /// target layout, one peer copy per column, old panels freed.
    fn convert_out_of_place<S: Scalar>(m: &mut DistMatrix<S>, target: Layout1D) -> Result<RedistPlan> {
        let node = m.node().clone();
        let col_bytes = m.col_bytes();
        let src_kind = *m.layout();
        let src = src_kind.as_layout();
        let dst = target.as_layout();

        let mut new_panels = Vec::with_capacity(node.num_devices());
        for d in 0..node.num_devices() {
            new_panels.push(node.alloc_scalars::<S>(d, m.rows() * dst.local_cols(d))?);
        }

        let mut plan = RedistPlan { in_place: false, ..Default::default() };
        for g in 0..src.n_cols() {
            let (sd, sl) = src.place(g);
            let (dd, dl) = dst.place(g);
            node.peer_copy(m.panels()[sd], sl * col_bytes, new_panels[dd], dl * col_bytes, col_bytes)?;
            plan.columns_moved += 1;
            if sd != dd {
                plan.columns_cross_device += 1;
            }
        }
        m.replace_panels(new_panels, target)?;
        Ok(plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::SimNode;
    use crate::layout::{BlockCyclic1D, ContiguousBlock};
    use crate::linalg::Matrix;
    use crate::scalar::c64;

    fn roundtrip_case<S: Scalar>(n: usize, rows: usize, tile: usize, ndev: usize, seed: u64) {
        let node = SimNode::new_uniform(ndev, 1 << 26);
        let a = Matrix::<S>::random(rows, n, seed);
        let contig = Layout1D::Contiguous(ContiguousBlock::new(n, ndev).unwrap());
        let cyclic = Layout1D::BlockCyclic(BlockCyclic1D::new(n, tile, ndev).unwrap());

        let mut dm = DistMatrix::scatter(&node, &a, contig).unwrap();
        let plan = Redistributor::convert(&mut dm, cyclic).unwrap();
        // Content correct in the new layout.
        let b = dm.gather().unwrap();
        assert_eq!(a, b, "content corrupted by redistribution (n={n} T={tile} d={ndev})");

        // Convert back and re-check.
        let plan2 = Redistributor::convert(&mut dm, contig).unwrap();
        let c = dm.gather().unwrap();
        assert_eq!(a, c, "content corrupted by inverse redistribution");
        assert_eq!(plan.in_place, plan2.in_place);
    }

    #[test]
    fn in_place_balanced_roundtrip() {
        // n divisible by tile*ndev ⇒ balanced ⇒ in-place cycles.
        roundtrip_case::<f64>(16, 8, 2, 4, 1);
        roundtrip_case::<f32>(24, 5, 2, 3, 2);
        roundtrip_case::<c64>(32, 4, 4, 2, 3);
    }

    #[test]
    fn out_of_place_unbalanced_roundtrip() {
        roundtrip_case::<f64>(10, 4, 4, 2, 4); // 6/4 vs 5/5 → fallback
        roundtrip_case::<f32>(17, 3, 3, 4, 5);
        roundtrip_case::<c64>(33, 2, 5, 7, 6);
    }

    #[test]
    fn in_place_reports_cycles() {
        let node = SimNode::new_uniform(4, 1 << 24);
        let n = 16;
        let a = Matrix::<f64>::random(4, n, 7);
        let contig = Layout1D::Contiguous(ContiguousBlock::new(n, 4).unwrap());
        let cyclic = Layout1D::BlockCyclic(BlockCyclic1D::new(n, 2, 4).unwrap());
        let mut dm = DistMatrix::scatter(&node, &a, contig).unwrap();
        let plan = Redistributor::convert(&mut dm, cyclic).unwrap();
        assert!(plan.in_place);
        assert!(plan.nontrivial_cycles > 0);
        assert!(plan.columns_moved > 0);
        assert_eq!(node.metrics().snapshot().redist_cycles, plan.nontrivial_cycles as u64);
        // Staging buffers must all be freed.
        for rep in node.memory_reports() {
            assert_eq!(rep.allocations, 1, "only the panel must remain");
        }
    }

    #[test]
    fn identity_conversion_moves_nothing() {
        // tile == n/ndev makes block-cyclic equal contiguous.
        let node = SimNode::new_uniform(4, 1 << 24);
        let n = 16;
        let a = Matrix::<f64>::random(4, n, 8);
        let contig = Layout1D::Contiguous(ContiguousBlock::new(n, 4).unwrap());
        let cyclic = Layout1D::BlockCyclic(BlockCyclic1D::new(n, 4, 4).unwrap());
        let mut dm = DistMatrix::scatter(&node, &a, contig).unwrap();
        let plan = Redistributor::convert(&mut dm, cyclic).unwrap();
        assert!(plan.in_place);
        assert_eq!(plan.nontrivial_cycles, 0);
        assert_eq!(plan.columns_moved, 0);
    }

    #[test]
    fn single_device_is_local_only() {
        let node = SimNode::new_uniform(1, 1 << 24);
        let n = 12;
        let a = Matrix::<f64>::random(6, n, 9);
        let contig = Layout1D::Contiguous(ContiguousBlock::new(n, 1).unwrap());
        let cyclic = Layout1D::BlockCyclic(BlockCyclic1D::new(n, 4, 1).unwrap());
        let mut dm = DistMatrix::scatter(&node, &a, contig).unwrap();
        let plan = Redistributor::convert(&mut dm, cyclic).unwrap();
        // One device: every tile is owned by device 0 in both layouts ⇒ identity.
        assert_eq!(plan.columns_cross_device, 0);
        assert_eq!(dm.gather().unwrap(), a);
    }

    #[test]
    fn large_randomized_roundtrips() {
        // Sweep of shapes; rows kept small to bound test time.
        for (i, &(n, t, d)) in
            [(48usize, 2usize, 4usize), (60, 5, 4), (64, 8, 2), (96, 4, 8), (40, 10, 2)].iter().enumerate()
        {
            roundtrip_case::<f64>(n, 3, t, d, 100 + i as u64);
        }
    }
}
